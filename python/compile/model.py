"""L2 — jax compute graphs wrapping the L1 Pallas kernels.

Each public function here is an AOT export target: ``aot.py`` lowers it
at one or more fixed shape *buckets* (PJRT executables are
shape-monomorphic) and the rust runtime picks the smallest bucket a
matrix fits after padding (``runtime::registry``).

Exported graphs:

* ``ell_spmv_graph``      — single ELL SpMV (the workhorse).
* ``seg_spmv_graph``      — CSR5-style segmented SpMV.
* ``power_iter_graph``    — 4 normalized SpMV iterations (composition
  check + the quickstart's "do something real" demo).
* ``spmv_flops_graph``    — SpMV plus the Gflops bookkeeping reduction
  (dot-products count) used by the benchmark harness to cross-check the
  rust-side flop accounting.
"""

import jax
import jax.numpy as jnp

from .kernels.ell_spmm import ell_spmm
from .kernels.ell_spmv import ell_spmv
from .kernels.seg_spmv import seg_spmv


def ell_spmv_graph(cols, data, x):
    """y = A @ x with A in padded ELL form. Returns a 1-tuple."""
    return (ell_spmv(cols, data, x),)


def ell_spmm_graph(cols, data, x):
    """Y = A @ X (multi-vector SpMV). Returns a 1-tuple."""
    return (ell_spmm(cols, data, x),)


def seg_spmv_graph(cols, rows, data, x, *, m):
    """y = A @ x with A as a flat nonzero stream. Returns a 1-tuple."""
    return (seg_spmv(cols, rows, data, x, m=m),)


def power_iter_graph(cols, data, x0, *, iters=4):
    """iters steps of v <- normalize(A v); returns (v, rayleigh).

    The Rayleigh quotient v'Av gives the dominant-eigenvalue estimate —
    a realistic consumer of SpMV (the paper motivates SpMV via iterative
    scientific kernels of exactly this shape).
    """

    def step(_, v):
        y = ell_spmv(cols, data, v)
        n = jnp.sqrt(jnp.sum(y * y)) + 1e-12
        return y / n

    v = jax.lax.fori_loop(0, iters, step, x0)
    av = ell_spmv(cols, data, v)
    rayleigh = jnp.sum(v * av)
    return (v, rayleigh)


def spmv_flops_graph(cols, data, x):
    """(y, useful_flops) — flops = 2 * count(data != 0) as f32.

    The harness divides by simulated seconds to report Gflops the same
    way the paper does (2*nnz flops per SpMV).
    """
    y = ell_spmv(cols, data, x)
    nnz = jnp.sum(jnp.where(data != 0.0, 1.0, 0.0))
    return (y, 2.0 * nnz)
