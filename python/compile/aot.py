"""AOT export: lower the L2 graphs to HLO *text* artifacts.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``). The HLO text
parser on the rust side reassigns ids, so text round-trips cleanly.
See /opt/xla-example/README.md.

Every artifact is a fixed-shape *bucket*; ``manifest.json`` records the
bucket table so ``runtime::registry`` on the rust side can select and
pad without re-parsing HLO. Run as::

    python -m compile.aot --out-dir ../artifacts

(idempotent: skips writing when the manifest matches).
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32
I32 = jnp.int32


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (ids reassigned)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# Bucket tables. Square matrices (the corpus is square, like SuiteSparse's
# square subset the paper uses). M = N for every bucket.
#
# ELL buckets: (rows, K). K is the padded row width; rust picks the first
# bucket with rows >= m and K >= nnz_max (or falls back to seg buckets for
# pathological rows).
ELL_BUCKETS = [
    (1024, 8),
    (1024, 32),
    (4096, 8),
    (4096, 32),
    (16384, 16),
]
# SEG buckets: (nnz_padded, rows). Load-balanced path; used when ELL
# padding would explode (nnz_max >> nnz_avg, the exdata_1 pathology).
SEG_BUCKETS = [
    (16384, 4096),
    (65536, 16384),
    (262144, 16384),
]
# Power-iteration buckets (rows, K): the composed-graph artifact.
POWER_BUCKETS = [(4096, 16)]
# SpMM buckets (rows, K, V): block solvers' multi-vector SpMV.
SPMM_BUCKETS = [(4096, 16, 8)]

BLOCK_ROWS = 256  # ELL row-tile; all bucket row counts are multiples.


def build_jobs():
    """Yield (name, lowered) for every artifact."""
    for m, k in ELL_BUCKETS:
        name = f"ell_spmv_m{m}_k{k}"
        fn = jax.jit(model.ell_spmv_graph)
        lowered = fn.lower(
            _spec((m, k), I32), _spec((m, k), F32), _spec((m,), F32)
        )
        yield name, lowered, {
            "kind": "ell",
            "rows": m,
            "k": k,
            "n": m,
            "params": ["cols i32[m,k]", "data f32[m,k]", "x f32[n]"],
            "returns": ["y f32[m]"],
        }
    for nnz, m in SEG_BUCKETS:
        name = f"seg_spmv_nnz{nnz}_m{m}"
        fn = jax.jit(functools.partial(model.seg_spmv_graph, m=m))
        lowered = fn.lower(
            _spec((nnz,), I32),
            _spec((nnz,), I32),
            _spec((nnz,), F32),
            _spec((m,), F32),
        )
        yield name, lowered, {
            "kind": "seg",
            "rows": m,
            "nnz": nnz,
            "n": m,
            "params": [
                "cols i32[nnz]",
                "rows i32[nnz]",
                "data f32[nnz]",
                "x f32[n]",
            ],
            "returns": ["y f32[m]"],
        }
    for m, k, v in SPMM_BUCKETS:
        name = f"ell_spmm_m{m}_k{k}_v{v}"
        fn = jax.jit(model.ell_spmm_graph)
        lowered = fn.lower(
            _spec((m, k), I32), _spec((m, k), F32), _spec((m, v), F32)
        )
        yield name, lowered, {
            "kind": "spmm",
            "rows": m,
            "k": k,
            "n": m,
            "v": v,
            "params": ["cols i32[m,k]", "data f32[m,k]", "x f32[n,v]"],
            "returns": ["y f32[m,v]"],
        }
    for m, k in POWER_BUCKETS:
        name = f"power_iter_m{m}_k{k}"
        fn = jax.jit(functools.partial(model.power_iter_graph, iters=4))
        lowered = fn.lower(
            _spec((m, k), I32), _spec((m, k), F32), _spec((m,), F32)
        )
        yield name, lowered, {
            "kind": "power",
            "rows": m,
            "k": k,
            "n": m,
            "iters": 4,
            "params": ["cols i32[m,k]", "data f32[m,k]", "x0 f32[n]"],
            "returns": ["v f32[m]", "rayleigh f32[]"],
        }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest_path = os.path.join(args.out_dir, "manifest.json")
    manifest = {"format": "hlo-text", "artifacts": []}

    for name, lowered, meta in build_jobs():
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        meta = dict(meta, name=name, file=os.path.basename(path))
        manifest["artifacts"].append(meta)
        print(f"wrote {path} ({len(text)} chars)")

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {manifest_path} ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
