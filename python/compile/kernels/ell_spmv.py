"""Pallas ELL SpMV kernel (row-tiled), the TPU re-expression of the
paper's row-parallel CSR SpMV.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's OpenMP
static row partition becomes a BlockSpec row tiling — each grid step
owns a ``(TM, K)`` slab of the padded nonzero matrix in VMEM, while the
dense vector ``x`` stays resident in VMEM across all row tiles. That
residency is the TPU analogue of the shared-L2 reuse of ``x`` that the
paper identifies as the key scalability factor on FT-2000+.

The per-row dot product is a vectorized multiply + lane reduction on the
VPU (there is no MXU-shaped matmul in SpMV; the kernel is gather-bound,
exactly like the CPU version is memory-bound).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO. Real-TPU viability
is assessed from the VMEM footprint of the chosen BlockSpec (see
DESIGN.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ell_kernel(cols_ref, data_ref, x_ref, y_ref):
    """One row tile: y[TM] = sum_k data[TM, K] * x[cols[TM, K]]."""
    cols = cols_ref[...]  # i32[TM, K]
    data = data_ref[...]  # f32[TM, K]
    x = x_ref[...]  # f32[N] — full vector, VMEM resident
    gathered = x[cols]  # gather, VPU
    y_ref[...] = jnp.sum(data * gathered, axis=1)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def ell_spmv(cols, data, x, *, block_rows=256):
    """ELL SpMV via pallas_call with a row-tiled grid.

    Args:
      cols: i32[M, K] padded column indices (padding -> 0).
      data: f32[M, K] padded values (padding -> 0.0).
      x:    f32[N] dense vector.
      block_rows: rows per grid step; must divide M. Automatically
        clamped to M for small matrices (M < block_rows).

    Returns:
      f32[M] = A @ x.
    """
    m, k = data.shape
    (n,) = x.shape
    if block_rows > m:
        block_rows = m
    if m % block_rows != 0:
        raise ValueError(f"M={m} not divisible by block_rows={block_rows}")
    grid = (m // block_rows,)
    return pl.pallas_call(
        _ell_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),  # x: same full block every step
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), data.dtype),
        interpret=True,
    )(cols, data, x)


def vmem_bytes(m, k, n, block_rows=256, dtype_bytes=4):
    """Estimated VMEM working set per grid step for this BlockSpec.

    data tile + cols tile + x + y tile. Used by the §Perf analysis to
    check the schedule fits the ~16 MiB/core VMEM of a modern TPU.
    """
    tile = block_rows * k * dtype_bytes  # data
    tile += block_rows * k * 4  # cols (i32)
    tile += n * dtype_bytes  # x resident
    tile += block_rows * dtype_bytes  # y
    return tile
