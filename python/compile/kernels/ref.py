"""Pure-jnp reference oracles for the SpMV kernels.

These are the correctness ground truth for the Pallas kernels in this
package (checked by pytest at build time) and define the exact semantics
the rust runtime relies on:

* ``ell_spmv_ref``  — ELL-padded SpMV. Padding slots carry ``data == 0``
  and ``col == 0``; they contribute nothing to the row dot product.
* ``seg_spmv_ref``  — flat (CSR5-style) segmented SpMV over an nnz
  stream. Padding slots carry ``data == 0`` and ``row == 0``.
* ``power_iter_ell_ref`` — a small composed graph (repeated normalized
  SpMV) used to validate that the L2 model composes kernels correctly.
"""

import jax
import jax.numpy as jnp


def ell_spmv_ref(data, cols, x):
    """ELL SpMV: y[i] = sum_k data[i, k] * x[cols[i, k]].

    Args:
      data: f32[M, K] nonzero values, zero-padded per row.
      cols: i32[M, K] column indices, padding slots must be 0.
      x:    f32[N] dense input vector.

    Returns:
      f32[M] product vector.
    """
    gathered = x[cols]  # [M, K]
    return jnp.sum(data * gathered, axis=1)


def seg_spmv_ref(data, cols, rows, x, m):
    """Segmented (flat-nnz) SpMV: y = segment_sum(data * x[cols], rows).

    This is the CSR5-shaped computation: the nonzero stream is processed
    as a flat array regardless of row boundaries, so work is balanced by
    construction. Padding slots must have ``data == 0`` (their row id is
    irrelevant but kept in-range, conventionally 0).

    Args:
      data: f32[NNZ] nonzero values (zero-padded tail).
      cols: i32[NNZ] column index per nonzero.
      rows: i32[NNZ] row id (segment id) per nonzero, non-decreasing.
      x:    f32[N] dense input vector.
      m:    static output length (number of rows).

    Returns:
      f32[m] product vector.
    """
    prod = data * x[cols]
    return jax.ops.segment_sum(prod, rows, num_segments=m)


def power_iter_ell_ref(data, cols, x0, iters=4):
    """``iters`` steps of y <- normalize(A @ y) starting from x0.

    Square-matrix (M == N) composed graph used by the L2 model tests and
    the quickstart example. Normalization uses the L2 norm with an
    epsilon so the all-zero matrix is safe.
    """

    def step(_, v):
        y = ell_spmv_ref(data, cols, v)
        n = jnp.sqrt(jnp.sum(y * y)) + 1e-12
        return y / n

    return jax.lax.fori_loop(0, iters, step, x0)


def csr_to_ell(ptr, indices, values, m, k):
    """Host-side helper: convert CSR arrays to zero-padded ELL (numpy).

    Used only by tests/tools; the production conversion lives in rust
    (``sparse::ell``). Rows with more than ``k`` nonzeros are an error.
    """
    import numpy as np

    data = np.zeros((m, k), dtype=np.float32)
    cols = np.zeros((m, k), dtype=np.int32)
    for i in range(m):
        row = values[ptr[i]:ptr[i + 1]]
        idx = indices[ptr[i]:ptr[i + 1]]
        if len(row) > k:
            raise ValueError(f"row {i} has {len(row)} nnz > K={k}")
        data[i, : len(row)] = row
        cols[i, : len(idx)] = idx
    return data, cols
