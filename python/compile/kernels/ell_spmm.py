"""Pallas ELL SpMM kernel: Y = A @ X for a block of dense vectors.

Multi-vector SpMV (SpMM) is the natural extension iterative block
solvers use; on TPU it is strictly more MXU-friendly than SpMV because
the per-row gather amortizes over the vector block: each gathered
x-row of shape [V] participates in a rank-1 update, turning the lane
reduction into a small matmul-like contraction.

Same padding convention as ell_spmv: data == 0 / col == 0 on padding.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ell_spmm_kernel(cols_ref, data_ref, x_ref, y_ref):
    """One row tile: Y[TM, V] = sum_k data[TM, K] * X[cols[TM, K], V]."""
    cols = cols_ref[...]  # i32[TM, K]
    data = data_ref[...]  # f32[TM, K]
    x = x_ref[...]  # f32[N, V]
    gathered = x[cols]  # f32[TM, K, V]
    y_ref[...] = jnp.einsum("mk,mkv->mv", data, gathered)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def ell_spmm(cols, data, x, *, block_rows=128):
    """ELL SpMM via pallas_call with a row-tiled grid.

    Args:
      cols: i32[M, K] padded column indices.
      data: f32[M, K] padded values.
      x:    f32[N, V] dense vector block.
      block_rows: rows per grid step (clamped to M).

    Returns:
      f32[M, V] = A @ X.
    """
    m, k = data.shape
    n, v = x.shape
    if block_rows > m:
        block_rows = m
    if m % block_rows != 0:
        raise ValueError(f"M={m} not divisible by block_rows={block_rows}")
    grid = (m // block_rows,)
    return pl.pallas_call(
        _ell_spmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
            pl.BlockSpec((n, v), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, v), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, v), data.dtype),
        interpret=True,
    )(cols, data, x)


def ell_spmm_ref(data, cols, x):
    """Pure-jnp oracle: Y[i, :] = sum_k data[i, k] * X[cols[i, k], :]."""
    gathered = x[cols]  # [M, K, V]
    return jnp.einsum("mk,mkv->mv", data, gathered)


def vmem_bytes(m, k, n, v, block_rows=128, dtype_bytes=4):
    """VMEM working set per grid step."""
    return (
        block_rows * k * (dtype_bytes + 4)
        + n * v * dtype_bytes
        + block_rows * v * dtype_bytes
        + block_rows * k * v * dtype_bytes  # gathered intermediate
    )
