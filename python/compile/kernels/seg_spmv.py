"""Pallas segmented-sum SpMV kernel — the CSR5-shaped computation.

CSR5's insight (Liu & Vinter, ICS'15) is that partitioning the *nonzero
stream* into fixed-size 2-D tiles, instead of partitioning rows, gives
perfect load balance regardless of the row-length distribution. The
paper (§5.2.1) uses CSR5 to rescue matrices whose CSR scalability is
killed by ``job_var >= 0.45``.

TPU re-expression (DESIGN.md §Hardware-Adaptation): the nnz stream is
reshaped into ``(T, S)`` tiles (CSR5's t×σ); each tile's products
``data * x[cols]`` are computed vectorized, then a segmented reduction
keyed by the per-nonzero row id folds products into rows. The
cross-tile carry that CSR5 handles with ``seg_off``/``y_off``
descriptors is here subsumed by the scatter-add segment reduction,
which XLA lowers to a single fused scatter.

Padding: tail slots carry ``data == 0`` and ``row == 0`` so they fold
harmlessly into row 0.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _seg_kernel(m, cols_ref, rows_ref, data_ref, x_ref, y_ref):
    """Whole-stream segmented SpMV in one program.

    interpret-mode note: the scatter-add races that would make a
    multi-program scatter unsafe on real hardware do not arise here —
    the segment reduction is expressed as one scatter over the full
    stream, which is also the form XLA fuses best on CPU.
    """
    cols = cols_ref[...]
    rows = rows_ref[...]
    data = data_ref[...]
    x = x_ref[...]
    tiles, width = data.shape
    prod = (data * x[cols]).reshape(tiles * width)
    seg = rows.reshape(tiles * width)
    y = jnp.zeros((m,), dtype=data.dtype).at[seg].add(prod)
    y_ref[...] = y


@functools.partial(jax.jit, static_argnames=("m", "tile_width"))
def seg_spmv(cols, rows, data, x, *, m, tile_width=256):
    """Segmented (CSR5-style) SpMV via pallas_call.

    Args:
      cols: i32[NNZ] column index per nonzero (padding -> 0).
      rows: i32[NNZ] row (segment) id per nonzero (padding -> 0).
      data: f32[NNZ] values (padding -> 0.0).
      x:    f32[N] dense vector.
      m:    static number of rows.
      tile_width: CSR5 sigma; NNZ must be divisible by it.

    Returns:
      f32[m] = A @ x.
    """
    (nnz,) = data.shape
    if nnz % tile_width != 0:
        raise ValueError(f"NNZ={nnz} not divisible by tile_width={tile_width}")
    tiles = nnz // tile_width
    shape2d = (tiles, tile_width)
    (n,) = x.shape
    kernel = functools.partial(_seg_kernel, m)
    return pl.pallas_call(
        kernel,
        in_specs=[
            pl.BlockSpec(shape2d, lambda: (0, 0)),
            pl.BlockSpec(shape2d, lambda: (0, 0)),
            pl.BlockSpec(shape2d, lambda: (0, 0)),
            pl.BlockSpec((n,), lambda: (0,)),
        ],
        out_specs=pl.BlockSpec((m,), lambda: (0,)),
        out_shape=jax.ShapeDtypeStruct((m,), data.dtype),
        interpret=True,
    )(
        cols.reshape(shape2d),
        rows.reshape(shape2d),
        data.reshape(shape2d),
        x,
    )


def vmem_bytes(nnz, m, n, dtype_bytes=4):
    """Estimated VMEM working set (whole-stream schedule)."""
    return nnz * (dtype_bytes + 4 + 4) + n * dtype_bytes + m * dtype_bytes
