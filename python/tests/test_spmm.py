"""SpMM kernel vs oracle + consistency with per-vector SpMV."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.ell_spmm import ell_spmm, ell_spmm_ref, vmem_bytes
from compile.kernels.ell_spmv import ell_spmv

from .conftest import random_ell


def test_spmm_matches_ref(rng):
    m, k, n, v = 256, 8, 256, 4
    data, cols = random_ell(rng, m, k, n)
    x = rng.standard_normal((n, v)).astype(np.float32)
    got = np.asarray(ell_spmm(cols, data, x, block_rows=64))
    want = np.asarray(ell_spmm_ref(data, cols, x))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_spmm_columns_match_spmv(rng):
    """Each SpMM output column equals the SpMV on that x column."""
    m, k, n, v = 128, 6, 128, 3
    data, cols = random_ell(rng, m, k, n)
    x = rng.standard_normal((n, v)).astype(np.float32)
    y = np.asarray(ell_spmm(cols, data, x, block_rows=64))
    for j in range(v):
        yj = np.asarray(ell_spmv(cols, data, x[:, j].copy(), block_rows=64))
        np.testing.assert_allclose(y[:, j], yj, rtol=1e-4, atol=1e-4)


def test_spmm_single_vector_degenerate(rng):
    m, k, n = 64, 4, 64
    data, cols = random_ell(rng, m, k, n)
    x = rng.standard_normal((n, 1)).astype(np.float32)
    y = np.asarray(ell_spmm(cols, data, x, block_rows=64))
    assert y.shape == (m, 1)


def test_vmem_estimate_positive():
    assert vmem_bytes(4096, 16, 4096, 8) > 0


@settings(max_examples=15, deadline=None)
@given(
    m_pow=st.integers(5, 8),
    k=st.integers(1, 8),
    v=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_spmm_hypothesis_sweep(m_pow, k, v, seed):
    m = 2**m_pow
    r = np.random.default_rng(seed)
    data, cols = random_ell(r, m, k, m)
    x = r.standard_normal((m, v)).astype(np.float32)
    got = np.asarray(ell_spmm(cols, data, x, block_rows=32))
    want = np.asarray(ell_spmm_ref(data, cols, x))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
