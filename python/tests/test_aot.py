"""AOT pipeline: every bucket lowers to parseable HLO text and the
manifest is consistent. This is the build-time gate for `make artifacts`.
"""

import json

import pytest

from compile import aot


@pytest.fixture(scope="module")
def jobs():
    # Lowering all buckets is the expensive part; do it once.
    return list(aot.build_jobs())


def test_all_buckets_lower(jobs):
    assert len(jobs) == (
        len(aot.ELL_BUCKETS)
        + len(aot.SEG_BUCKETS)
        + len(aot.POWER_BUCKETS)
        + len(aot.SPMM_BUCKETS)
    )


def test_hlo_text_roundtrippable(jobs):
    for name, lowered, _meta in jobs:
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), name
        assert "ROOT" in text, name


def test_meta_schema(jobs):
    for name, _lowered, meta in jobs:
        assert meta["kind"] in ("ell", "seg", "power", "spmm")
        assert meta["rows"] > 0
        assert isinstance(meta["params"], list) and meta["params"]


def test_ell_bucket_rows_divisible_by_block():
    for m, _k in aot.ELL_BUCKETS:
        assert m % aot.BLOCK_ROWS == 0


def test_manifest_written(tmp_path, monkeypatch, jobs):
    import sys

    monkeypatch.setattr(
        sys, "argv", ["aot", "--out-dir", str(tmp_path)]
    )
    aot.main()
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["format"] == "hlo-text"
    names = {a["name"] for a in manifest["artifacts"]}
    assert len(names) == len(manifest["artifacts"])  # unique
    for a in manifest["artifacts"]:
        assert (tmp_path / a["file"]).exists()
