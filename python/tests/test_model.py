"""L2 correctness: composed graphs (power iteration, flops graph)."""

import numpy as np
import pytest

from compile import model
from compile.kernels import ref

from .conftest import random_ell


def _sym_ell(rng, m, k):
    """A diagonally-dominant symmetric-ish ELL matrix with a known
    dominant direction (for power-iteration convergence checks)."""
    data = np.zeros((m, k), dtype=np.float32)
    cols = np.zeros((m, k), dtype=np.int32)
    data[:, 0] = 2.0 + rng.random(m).astype(np.float32)
    cols[:, 0] = np.arange(m)
    if k > 1:
        data[:, 1] = 0.1
        cols[:, 1] = (np.arange(m) + 1) % m
    return data, cols


def test_power_iter_matches_ref(rng):
    m, k = 256, 4
    data, cols = _sym_ell(rng, m, k)
    x0 = np.ones(m, dtype=np.float32) / np.sqrt(m)
    v, lam = model.power_iter_graph(cols, data, x0, iters=4)
    v_ref = np.asarray(ref.power_iter_ell_ref(data, cols, x0, iters=4))
    np.testing.assert_allclose(np.asarray(v), v_ref, rtol=1e-5, atol=1e-5)
    assert np.isfinite(float(lam))


def test_power_iter_unit_norm(rng):
    m, k = 256, 4
    data, cols = _sym_ell(rng, m, k)
    x0 = np.ones(m, dtype=np.float32) / np.sqrt(m)
    v, _ = model.power_iter_graph(cols, data, x0, iters=8)
    assert abs(float(np.linalg.norm(np.asarray(v))) - 1.0) < 1e-4


def test_power_iter_rayleigh_in_spectrum(rng):
    """For a diagonal matrix the Rayleigh quotient must lie within
    [min(diag), max(diag)]."""
    m = 128
    diag = (1.0 + np.arange(m) / m).astype(np.float32)
    data = np.zeros((m, 4), dtype=np.float32)
    cols = np.zeros((m, 4), dtype=np.int32)
    data[:, 0] = diag
    cols[:, 0] = np.arange(m)
    x0 = np.ones(m, dtype=np.float32) / np.sqrt(m)
    _, lam = model.power_iter_graph(cols, data, x0, iters=16)
    assert diag.min() - 1e-4 <= float(lam) <= diag.max() + 1e-4


def test_flops_graph_counts_nonzeros(rng):
    m, k, n = 128, 8, 128
    data, cols = random_ell(rng, m, k, n)
    x = rng.standard_normal(n).astype(np.float32)
    y, flops = model.spmv_flops_graph(cols, data, x)
    want_y = np.asarray(ref.ell_spmv_ref(data, cols, x))
    np.testing.assert_allclose(np.asarray(y), want_y, rtol=1e-4, atol=1e-4)
    assert float(flops) == pytest.approx(2.0 * np.count_nonzero(data))
