"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

This is the CORE correctness signal for the whole stack — the rust
runtime executes exactly the HLO these kernels lower to.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ell_spmv import ell_spmv, vmem_bytes
from compile.kernels.seg_spmv import seg_spmv
from compile.kernels import ref

from .conftest import ell_to_seg, pad_seg, random_ell


# ---------------------------------------------------------------------------
# ELL kernel


def test_ell_identity(rng):
    """A = I (in ELL form) => y == x."""
    m = 256
    data = np.zeros((m, 4), dtype=np.float32)
    cols = np.zeros((m, 4), dtype=np.int32)
    data[:, 0] = 1.0
    cols[:, 0] = np.arange(m)
    x = rng.standard_normal(m).astype(np.float32)
    y = np.asarray(ell_spmv(cols, data, x, block_rows=64))
    np.testing.assert_allclose(y, x, rtol=1e-6)


def test_ell_matches_ref(rng):
    m, k, n = 512, 8, 512
    data, cols = random_ell(rng, m, k, n)
    x = rng.standard_normal(n).astype(np.float32)
    got = np.asarray(ell_spmv(cols, data, x, block_rows=128))
    want = np.asarray(ref.ell_spmv_ref(data, cols, x))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_ell_matches_dense(rng):
    """Cross-check against an explicit dense matmul."""
    m, k, n = 128, 4, 128
    data, cols = random_ell(rng, m, k, n)
    x = rng.standard_normal(n).astype(np.float32)
    dense = np.zeros((m, n), dtype=np.float64)
    for i in range(m):
        for j in range(k):
            dense[i, cols[i, j]] += np.float64(data[i, j])
    want = dense @ x.astype(np.float64)
    got = np.asarray(ell_spmv(cols, data, x, block_rows=64))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_ell_block_rows_invariance(rng):
    """Result must not depend on the BlockSpec row tile."""
    m, k, n = 512, 8, 512
    data, cols = random_ell(rng, m, k, n)
    x = rng.standard_normal(n).astype(np.float32)
    y64 = np.asarray(ell_spmv(cols, data, x, block_rows=64))
    y256 = np.asarray(ell_spmv(cols, data, x, block_rows=256))
    y512 = np.asarray(ell_spmv(cols, data, x, block_rows=512))
    np.testing.assert_allclose(y64, y256, rtol=1e-6)
    np.testing.assert_allclose(y64, y512, rtol=1e-6)


def test_ell_rejects_bad_block():
    data = np.zeros((100, 4), dtype=np.float32)
    cols = np.zeros((100, 4), dtype=np.int32)
    x = np.zeros(100, dtype=np.float32)
    with pytest.raises(ValueError, match="not divisible"):
        ell_spmv(cols, data, x, block_rows=64)


def test_ell_zero_matrix():
    m = 128
    data = np.zeros((m, 8), dtype=np.float32)
    cols = np.zeros((m, 8), dtype=np.int32)
    x = np.ones(m, dtype=np.float32)
    y = np.asarray(ell_spmv(cols, data, x, block_rows=64))
    assert np.all(y == 0.0)


def test_vmem_estimate_sane():
    # 16384x16 bucket: ~3.3 MiB — comfortably inside 16 MiB VMEM.
    b = vmem_bytes(16384, 16, 16384, block_rows=256)
    assert b < 16 * 2**20


@settings(max_examples=25, deadline=None)
@given(
    m_pow=st.integers(6, 9),
    k=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_ell_hypothesis_sweep(m_pow, k, seed):
    """Shape/content sweep: kernel == oracle for random ELL matrices."""
    m = 2**m_pow
    r = np.random.default_rng(seed)
    data, cols = random_ell(r, m, k, m)
    x = r.standard_normal(m).astype(np.float32)
    got = np.asarray(ell_spmv(cols, data, x, block_rows=64))
    want = np.asarray(ref.ell_spmv_ref(data, cols, x))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Segmented (CSR5-style) kernel


def test_seg_matches_ref(rng):
    m, k, n = 256, 8, 256
    data, cols = random_ell(rng, m, k, n)
    d, c, r = ell_to_seg(data, cols)
    nnz_padded = 2048
    d, c, r = pad_seg(d, c, r, nnz_padded)
    x = rng.standard_normal(n).astype(np.float32)
    got = np.asarray(seg_spmv(c, r, d, x, m=m, tile_width=256))
    want = np.asarray(ref.seg_spmv_ref(d, c, r, x, m))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_seg_matches_ell(rng):
    """The two kernels must agree: same matrix, different layouts."""
    m, k, n = 256, 8, 256
    data, cols = random_ell(rng, m, k, n)
    x = rng.standard_normal(n).astype(np.float32)
    y_ell = np.asarray(ell_spmv(cols, data, x, block_rows=64))
    d, c, r = pad_seg(*ell_to_seg(data, cols), 2048)
    y_seg = np.asarray(seg_spmv(c, r, d, x, m=m, tile_width=256))
    np.testing.assert_allclose(y_ell, y_seg, rtol=1e-4, atol=1e-4)


def test_seg_single_dense_row(rng):
    """The exdata_1 pathology: all nonzeros in one row. ELL cannot hold
    it without K=m; the seg kernel handles it natively."""
    m, nnz = 64, 1024
    d = rng.standard_normal(nnz).astype(np.float32)
    c = rng.integers(0, m, nnz).astype(np.int32)
    r = np.full(nnz, 7, dtype=np.int32)
    x = rng.standard_normal(m).astype(np.float32)
    got = np.asarray(seg_spmv(c, r, d, x, m=m, tile_width=256))
    want = np.zeros(m, dtype=np.float64)
    for j in range(nnz):
        want[7] += np.float64(d[j]) * np.float64(x[c[j]])
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_seg_rejects_bad_tile():
    d = np.zeros(100, dtype=np.float32)
    c = np.zeros(100, dtype=np.int32)
    r = np.zeros(100, dtype=np.int32)
    x = np.zeros(10, dtype=np.float32)
    with pytest.raises(ValueError, match="not divisible"):
        seg_spmv(c, r, d, x, m=10, tile_width=64)


def test_seg_tile_width_invariance(rng):
    """Result must not depend on the CSR5 tile width (sigma)."""
    m, k, n = 128, 6, 128
    data, cols = random_ell(rng, m, k, n)
    d, c, r = pad_seg(*ell_to_seg(data, cols), 1024)
    x = rng.standard_normal(n).astype(np.float32)
    outs = [
        np.asarray(seg_spmv(c, r, d, x, m=m, tile_width=w))
        for w in (64, 128, 256, 512, 1024)
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=1e-6)


def test_seg_all_padding():
    """A fully-padded (empty) stream yields zero output."""
    d = np.zeros(256, dtype=np.float32)
    c = np.zeros(256, dtype=np.int32)
    r = np.zeros(256, dtype=np.int32)
    x = np.ones(32, dtype=np.float32)
    y = np.asarray(seg_spmv(c, r, d, x, m=32, tile_width=256))
    assert np.all(y == 0.0)


def test_seg_duplicate_coordinates_accumulate(rng):
    """Multiple stream entries with the same (row, col) must sum."""
    d = np.array([1.0, 2.0, 3.0] + [0.0] * 253, dtype=np.float32)
    c = np.array([5, 5, 5] + [0] * 253, dtype=np.int32)
    r = np.array([2, 2, 2] + [0] * 253, dtype=np.int32)
    x = np.arange(16, dtype=np.float32)
    y = np.asarray(seg_spmv(c, r, d, x, m=16, tile_width=256))
    assert y[2] == pytest.approx(6.0 * 5.0)


def test_ell_duplicate_columns_accumulate():
    """ELL rows may repeat a column; contributions must sum."""
    data = np.array([[1.0, 2.0]], dtype=np.float32)
    cols = np.array([[3, 3]], dtype=np.int32)
    x = np.zeros(8, dtype=np.float32)
    x[3] = 10.0
    y = np.asarray(ell_spmv(cols, data, x, block_rows=1))
    assert y[0] == pytest.approx(30.0)


def test_kernels_float32_accumulation_order(rng):
    """Both kernels stay within float32 tolerance of a float64 oracle
    on ill-conditioned inputs (large cancellations)."""
    m, k, n = 64, 8, 64
    data, cols = random_ell(rng, m, k, n)
    data *= 1e4  # amplify cancellation error
    x = (rng.standard_normal(n) * 1e3).astype(np.float32)
    want = np.zeros(m, dtype=np.float64)
    for i in range(m):
        for j in range(k):
            want[i] += np.float64(data[i, j]) * np.float64(x[cols[i, j]])
    got = np.asarray(ell_spmv(cols, data, x, block_rows=64))
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=1e-1)


@settings(max_examples=20, deadline=None)
@given(
    m_pow=st.integers(5, 9),
    k=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_seg_hypothesis_sweep(m_pow, k, seed):
    m = 2**m_pow
    r_ = np.random.default_rng(seed)
    data, cols = random_ell(r_, m, k, m)
    x = r_.standard_normal(m).astype(np.float32)
    d, c, r = ell_to_seg(data, cols)
    nnz_padded = max(256, int(2 ** np.ceil(np.log2(max(len(d), 1) + 1))))
    nnz_padded = ((nnz_padded + 255) // 256) * 256
    d, c, r = pad_seg(d, c, r, nnz_padded)
    got = np.asarray(seg_spmv(c, r, d, x, m=m, tile_width=256))
    want = np.asarray(ref.seg_spmv_ref(d, c, r, x, m))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
