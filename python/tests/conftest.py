"""Shared fixtures: random sparse matrices in ELL / flat-seg form."""

import numpy as np
import pytest


def random_ell(rng, m, k, n, density=0.6):
    """Random zero-padded ELL arrays. Padding: data=0, col=0."""
    data = np.zeros((m, k), dtype=np.float32)
    cols = np.zeros((m, k), dtype=np.int32)
    for i in range(m):
        nnz = int(rng.integers(0, k + 1) * density) if k else 0
        nnz = min(nnz, k)
        data[i, :nnz] = rng.standard_normal(nnz).astype(np.float32)
        cols[i, :nnz] = rng.integers(0, n, nnz).astype(np.int32)
    return data, cols


def ell_to_seg(data, cols):
    """Flatten ELL arrays to the seg kernel's (data, cols, rows) stream,
    dropping padding then re-padding the tail with row id 0 / data 0."""
    m, k = data.shape
    mask = data != 0.0
    rows2d = np.broadcast_to(np.arange(m, dtype=np.int32)[:, None], (m, k))
    d = data[mask]
    c = cols[mask]
    r = rows2d[mask]
    return d, c, r


def pad_seg(d, c, r, nnz_padded):
    out_d = np.zeros(nnz_padded, dtype=np.float32)
    out_c = np.zeros(nnz_padded, dtype=np.int32)
    out_r = np.zeros(nnz_padded, dtype=np.int32)
    out_d[: len(d)] = d
    out_c[: len(c)] = c
    out_r[: len(r)] = r
    return out_d, out_c, out_r


@pytest.fixture
def rng():
    return np.random.default_rng(0xF7_2000)
