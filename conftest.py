"""Repo-root pytest shim: make `pytest python/tests/` work from the
repository root (the test modules import the `compile` package relative
to `python/`)."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent / "python"))
