//! Happens-before detector validation (requires `--features hbcheck`).
//!
//! Two halves:
//!
//! * **Racy fixtures** — known race classes (store-store, store-load,
//!   broken release) driven through the instrumented cells on real
//!   threads; `check::hb::analyze` must flag every one of them, and
//!   must *stop* flagging once the protocol is repaired. This proves
//!   the detector's teeth before its clean passes are trusted.
//! * **Real core** — the full `check::hb::run` sweep (ExecPool +
//!   TraceRecorder + MetricsRegistry + shard admission) across ≥1000
//!   seeded interleavings must come back with zero findings and zero
//!   ordering-waste advisories.

#![cfg(feature = "hbcheck")]

use std::sync::atomic::Ordering;

use ft2000_spmv::check::hb::{self, HbConfig};
use ft2000_spmv::util::ordatomic::{capture, OrdAtomicUsize};

fn analyze_capture(f: impl FnOnce()) -> hb::HbAnalysis {
    let ((), events) = capture::capture(f);
    hb::analyze(&events)
}

fn race_on(a: &hb::HbAnalysis, site: &str) -> bool {
    a.races.iter().any(|r| r.site == site)
}

#[test]
fn store_store_race_is_flagged() {
    let cell = OrdAtomicUsize::named(0, "fixture.ss");
    let a = analyze_capture(|| {
        std::thread::scope(|s| {
            s.spawn(|| cell.store(1, Ordering::Relaxed));
            s.spawn(|| cell.store(2, Ordering::Relaxed));
        });
    });
    assert!(
        race_on(&a, "fixture.ss"),
        "two unordered plain stores must race: {:?}",
        a.races
    );
}

#[test]
fn store_load_race_is_flagged() {
    let cell = OrdAtomicUsize::named(0, "fixture.sl");
    let a = analyze_capture(|| {
        std::thread::scope(|s| {
            s.spawn(|| cell.store(1, Ordering::Relaxed));
            s.spawn(|| {
                let _ = cell.load(Ordering::Relaxed);
            });
        });
    });
    assert!(
        race_on(&a, "fixture.sl"),
        "unordered plain store vs plain load must race: {:?}",
        a.races
    );
}

/// The broken-release signature: data published before a *Relaxed*
/// flag store. The reader's Acquire spin derives no edge (nothing was
/// released), so the data handoff is a race — and the flag cell
/// itself shows the tell-tale Relaxed-store/Acquire-load conflict.
#[test]
fn broken_release_publication_is_flagged() {
    let data = OrdAtomicUsize::named(0, "fixture.br.data");
    let flag = OrdAtomicUsize::named(0, "fixture.br.flag");
    let a = analyze_capture(|| {
        std::thread::scope(|s| {
            s.spawn(|| {
                data.store(42, Ordering::Relaxed);
                // Broken on purpose: publication needs Release.
                flag.store(1, Ordering::Relaxed);
            });
            s.spawn(|| {
                while flag.load(Ordering::Acquire) == 0 {
                    std::thread::yield_now();
                }
                let _ = data.load(Ordering::Relaxed);
            });
        });
    });
    assert!(
        race_on(&a, "fixture.br.data"),
        "data handoff over a relaxed flag must race: {:?}",
        a.races
    );
    assert!(
        race_on(&a, "fixture.br.flag"),
        "the relaxed flag store vs acquire spin is the broken-release \
         tell: {:?}",
        a.races
    );
}

/// Same protocol with the Release restored: the flag edge orders the
/// data accesses and every finding disappears.
#[test]
fn repaired_release_publication_is_clean() {
    let data = OrdAtomicUsize::named(0, "fixture.ok.data");
    let flag = OrdAtomicUsize::named(0, "fixture.ok.flag");
    let a = analyze_capture(|| {
        std::thread::scope(|s| {
            s.spawn(|| {
                data.store(42, Ordering::Relaxed);
                flag.store(1, Ordering::Release);
            });
            s.spawn(|| {
                while flag.load(Ordering::Acquire) == 0 {
                    std::thread::yield_now();
                }
                assert_eq!(data.load(Ordering::Relaxed), 42);
            });
        });
    });
    assert!(
        a.races.is_empty(),
        "release/acquire publication is race-free: {:?}",
        a.races
    );
    assert!(a.edges >= 1, "the flag handoff must derive an edge");
}

/// The real lock-free core, full sweep: ≥1000 seeded schedules over
/// the pool/trace/metrics scenario plus the shard admission scenario.
/// Zero findings (no races, no protocol violations) and zero
/// ordering-waste advisories — every ordering in the core is both
/// sufficient and necessary.
#[test]
fn real_core_is_race_free_across_seeded_interleavings() {
    // Scaled down under Miri (interpreted spins); the CI sanitizer
    // job runs this natively at full depth.
    let cfg = if cfg!(miri) {
        HbConfig::quick(0x48B_2000)
    } else {
        HbConfig::full(0x48B_2000)
    };
    let run = hb::run(&cfg);
    assert!(
        run.report.is_clean(),
        "hb findings on the real core:\n{}",
        run.report
    );
    if !cfg!(miri) {
        assert!(
            run.schedules >= 1000,
            "acceptance floor: ≥1000 seeded interleavings, got {}",
            run.schedules
        );
    }
    assert!(
        run.advice.is_empty(),
        "ordering-strength waste on the real core: {:?}",
        run.advice
    );
    assert!(run.events > 0 && run.edges > 0);
}

/// Determinism: same seed, same verdict and same coverage counters —
/// the analyzer's output is a pure function of the captured logs, and
/// the capture schedules are seeded.
#[test]
fn hb_run_is_deterministic_per_seed() {
    let a = hb::run(&HbConfig::quick(97));
    let b = hb::run(&HbConfig::quick(97));
    assert_eq!(a.report.is_clean(), b.report.is_clean());
    assert_eq!(a.report.checked, b.report.checked);
    assert_eq!(a.schedules, b.schedules);
}
