//! Cross-module property tests (deterministic seeds via
//! `util::testkit::check`).

use ft2000_spmv::autotune;
use ft2000_spmv::coordinator::{simulate_point, ProfileConfig};
use ft2000_spmv::corpus::generators::MatrixClass;
use ft2000_spmv::exec;
use ft2000_spmv::prop_assert;
use ft2000_spmv::reorder::locality_reorder;
use ft2000_spmv::sched::{partition, Partition, Schedule};
use ft2000_spmv::service;
use ft2000_spmv::sim::topology::Placement;
use ft2000_spmv::sparse::{Coo, Csr, Csr5, Ell, Hyb, MatrixFeatures};
use ft2000_spmv::util::rng::Pcg32;
use ft2000_spmv::util::testkit::check;

fn random_csr(rng: &mut Pcg32) -> Csr {
    let n = 8 + rng.gen_range(300);
    let mut coo = Coo::new(n, n);
    let nnz = 1 + rng.gen_range(n * 6);
    for _ in 0..nnz {
        coo.push(rng.gen_range(n), rng.gen_range(n), rng.gen_f64() - 0.5);
    }
    coo.to_csr()
}

fn random_schedule(rng: &mut Pcg32) -> Schedule {
    match rng.gen_range(5) {
        0 => Schedule::CsrRowStatic,
        1 => Schedule::CsrRowBalanced,
        2 => Schedule::Csr5Tiles { tile_nnz: 1 + rng.gen_range(128) },
        3 => Schedule::SellChunks {
            c: 1 + rng.gen_range(64),
            sigma: 1 + rng.gen_range(256),
        },
        _ => Schedule::CsrDynamic { chunk: 1 + rng.gen_range(32) },
    }
}

#[test]
fn partitions_conserve_nonzeros() {
    check("partition-conserves-nnz", 40, |rng| {
        let csr = random_csr(rng);
        let sched = random_schedule(rng);
        let nt = 1 + rng.gen_range(8);
        let p = partition(&csr, sched, nt);
        if let Err(e) = p.validate(&csr) {
            return Err(format!("{sched:?} nt={nt}: {e}"));
        }
        let total: usize = p.thread_nnz(&csr).iter().sum();
        prop_assert!(
            total == csr.nnz(),
            "{sched:?} nt={nt}: {total} != {}",
            csr.nnz()
        );
        Ok(())
    });
}

#[test]
fn all_formats_agree_on_spmv() {
    check("formats-agree", 30, |rng| {
        let csr = random_csr(rng);
        let n = csr.n_rows;
        let x: Vec<f64> = (0..n).map(|_| rng.gen_f64()).collect();
        let mut want = vec![0.0; n];
        csr.spmv(&x, &mut want);
        let close = |got: &[f64], what: &str| -> Result<(), String> {
            for (i, (a, b)) in want.iter().zip(got).enumerate() {
                if (a - b).abs() > 1e-9 * (1.0 + a.abs()) {
                    return Err(format!("{what} row {i}: {a} vs {b}"));
                }
            }
            Ok(())
        };
        let c5 = Csr5::from_csr(&csr, 1 + rng.gen_range(64));
        let mut y = vec![0.0; n];
        c5.spmv(&x, &mut y);
        close(&y, "csr5")?;
        let ell = Ell::from_csr(&csr, None).map_err(|e| e.to_string())?;
        ell.spmv(&x, &mut y);
        close(&y, "ell")?;
        let h = Hyb::from_csr(&csr, Hyb::auto_k(&csr));
        h.spmv(&x, &mut y);
        close(&y, "hyb")?;
        Ok(())
    });
}

#[test]
fn simulation_counters_sane_across_configs() {
    check("sim-counter-invariants", 15, |rng| {
        let class = MatrixClass::ALL[rng.gen_range(MatrixClass::ALL.len())];
        let csr = class.generate(
            64 + rng.gen_range(1500),
            500 + rng.gen_range(8000),
            rng.next_u64(),
        );
        let cfg = ProfileConfig {
            schedule: random_schedule(rng),
            placement: if rng.gen_range(2) == 0 {
                Placement::CoreGroupFirst
            } else {
                Placement::PrivateL2
            },
            ..Default::default()
        };
        let nt = 1 + rng.gen_range(8);
        let (res, thread_nnz) = simulate_point(&csr, &cfg, nt);
        prop_assert!(res.per_thread.len() == nt);
        prop_assert!(thread_nnz.len() == nt);
        for (t, c) in res.per_thread.iter().enumerate() {
            prop_assert!(c.l1_dcm <= c.l1_dca, "t{t}: l1_dcm > l1_dca");
            prop_assert!(c.l2_dca == c.l1_dcm, "t{t}: l2_dca != l1_dcm");
            prop_assert!(c.l2_dcm <= c.l2_dca, "t{t}: l2_dcm > l2_dca");
            prop_assert!(
                c.fr_ins <= c.tot_ins,
                "t{t}: fp ins exceed total"
            );
        }
        prop_assert!(res.timing.wall_seconds > 0.0);
        let slowest = res
            .timing
            .per_thread_cycles
            .iter()
            .cloned()
            .fold(0.0, f64::max);
        prop_assert!(
            res.timing.wall_cycles >= slowest,
            "wall below slowest thread"
        );
        Ok(())
    });
}

#[test]
fn reorder_preserves_spmv_semantics() {
    check("reorder-preserves-spmv", 25, |rng| {
        let csr = random_csr(rng);
        let n = csr.n_rows;
        let plan = locality_reorder(&csr, 1 + rng.gen_range(64));
        let permuted = plan.apply(&csr);
        prop_assert!(permuted.nnz() == csr.nnz());
        prop_assert!(permuted.validate().is_ok());
        let x: Vec<f64> = (0..n).map(|_| rng.gen_f64()).collect();
        let mut y0 = vec![0.0; n];
        let mut y1 = vec![0.0; n];
        csr.spmv(&x, &mut y0);
        permuted.spmv(&x, &mut y1);
        let inv = plan.inverse();
        for r in 0..n {
            prop_assert!(
                (y0[r] - y1[inv[r]]).abs() < 1e-9,
                "row {r} mismatch after reorder"
            );
        }
        Ok(())
    });
}

#[test]
fn threaded_exec_matches_reference_everywhere() {
    check("exec-matches-ref", 20, |rng| {
        let class = MatrixClass::ALL[rng.gen_range(MatrixClass::ALL.len())];
        let csr = class.generate(
            32 + rng.gen_range(400),
            100 + rng.gen_range(3000),
            rng.next_u64(),
        );
        let x: Vec<f64> =
            (0..csr.n_cols).map(|_| rng.gen_f64() - 0.5).collect();
        let want = exec::spmv_sequential(&csr, &x).y;
        let got = exec::spmv_threaded(
            &csr,
            &x,
            random_schedule(rng),
            1 + rng.gen_range(6),
        );
        for (i, (a, b)) in want.iter().zip(&got.y).enumerate() {
            prop_assert!(
                (a - b).abs() < 1e-9 * (1.0 + a.abs()),
                "row {i}: {a} vs {b}"
            );
        }
        Ok(())
    });
}

#[test]
fn unrolled_and_sell_kernels_bitwise_match_sequential() {
    // The PR-5 kernel contract: every row-space schedule (the
    // 4x-unrolled fmadd CSR kernel) and every SELL-C-σ geometry (the
    // chunk-vectorized kernel, whose padding slots are exact no-ops)
    // reproduce `spmv_sequential` bit for bit, at any thread count.
    // CSR5 is the one executor allowed to re-associate (boundary-row
    // carries) and keeps its tolerance bound elsewhere.
    check("unrolled+sell==sequential-bitwise", 25, |rng| {
        let csr = random_csr(rng);
        let n = csr.n_rows;
        let x: Vec<f64> = (0..n).map(|_| rng.gen_f64() - 0.5).collect();
        let want = exec::spmv_sequential(&csr, &x).y;
        let sched = match rng.gen_range(4) {
            0 => Schedule::CsrRowStatic,
            1 => Schedule::CsrRowBalanced,
            2 => Schedule::CsrDynamic { chunk: 1 + rng.gen_range(32) },
            _ => Schedule::SellChunks {
                c: 1 + rng.gen_range(64),
                sigma: 1 + rng.gen_range(512),
            },
        };
        let nt = 1 + rng.gen_range(8);
        let got = exec::spmv_threaded(&csr, &x, sched, nt);
        for (i, (a, b)) in want.iter().zip(&got.y).enumerate() {
            prop_assert!(
                a.to_bits() == b.to_bits(),
                "{sched:?} nt={nt} row {i}: {a} vs {b} (bitwise)"
            );
        }
        // And the SELL format's own sequential kernel agrees bitwise
        // with the CSR reference (padding no-ops, shared fmadd
        // discipline).
        if let Schedule::SellChunks { c, sigma } = sched {
            let sell =
                ft2000_spmv::sparse::SellCSigma::from_csr(&csr, c, sigma);
            let mut y = vec![0.0; n];
            sell.spmv(&x, &mut y);
            for (i, (a, b)) in want.iter().zip(&y).enumerate() {
                prop_assert!(
                    a.to_bits() == b.to_bits(),
                    "SellCSigma::spmv row {i}: {a} vs {b} (bitwise)"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn spmm_matches_sequential_per_column() {
    check("spmm-matches-per-column", 20, |rng| {
        let csr = random_csr(rng);
        let batch = 1 + rng.gen_range(12);
        let vectors: Vec<Vec<f64>> = (0..batch)
            .map(|_| {
                (0..csr.n_cols).map(|_| rng.gen_f64() - 0.5).collect()
            })
            .collect();
        let xs = exec::pack_vectors(&vectors);
        let got = exec::spmm_threaded(
            &csr,
            &xs,
            batch,
            random_schedule(rng),
            1 + rng.gen_range(6),
        );
        for (j, x) in vectors.iter().enumerate() {
            let want = exec::spmv_sequential(&csr, x).y;
            let col = got.column(j);
            for (i, (a, b)) in want.iter().zip(&col).enumerate() {
                prop_assert!(
                    (a - b).abs() < 1e-9 * (1.0 + a.abs()),
                    "col {j} row {i}: {a} vs {b}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn pooled_executors_match_scoped_and_sequential() {
    // The executor-pool property: for any schedule and thread count,
    // running on a persistent pool and running on per-call scoped
    // threads produce the same answer as the sequential reference —
    // for SpMV and for batch sizes straddling SPMM_COL_BLOCK.
    let pool = exec::ExecPool::new(4);
    check("pooled==scoped==sequential", 15, |rng| {
        let csr = random_csr(rng);
        let sched = random_schedule(rng);
        let nt = 1 + rng.gen_range(8);
        let x: Vec<f64> =
            (0..csr.n_cols).map(|_| rng.gen_f64() - 0.5).collect();
        let want = exec::spmv_sequential(&csr, &x).y;
        let pooled = exec::spmv_threaded_on(Some(&pool), &csr, &x, sched, nt);
        let scoped = exec::spmv_threaded(&csr, &x, sched, nt);
        prop_assert!(
            pooled.threads == scoped.threads,
            "effective threads diverge: pooled {} vs scoped {} \
             ({sched:?} nt={nt})",
            pooled.threads,
            scoped.threads
        );
        for (i, (p, q)) in pooled.y.iter().zip(&want).enumerate() {
            prop_assert!(
                (p - q).abs() < 1e-9 * (1.0 + p.abs()),
                "pooled row {i}: {p} vs {q} under {sched:?} nt={nt}"
            );
        }
        for (i, (p, q)) in scoped.y.iter().zip(&pooled.y).enumerate() {
            prop_assert!(
                p.to_bits() == q.to_bits(),
                "scoped row {i} diverges bitwise from pooled: {p} vs {q}"
            );
        }
        // Batched path straddling the column block width.
        let batch = exec::SPMM_COL_BLOCK - 1 + rng.gen_range(3);
        let vectors: Vec<Vec<f64>> = (0..batch)
            .map(|_| {
                (0..csr.n_cols).map(|_| rng.gen_f64() - 0.5).collect()
            })
            .collect();
        let xs = exec::pack_vectors(&vectors);
        let pooled =
            exec::spmm_threaded_on(Some(&pool), &csr, &xs, batch, sched, nt);
        let scoped = exec::spmm_threaded(&csr, &xs, batch, sched, nt);
        prop_assert!(
            pooled.schedule == scoped.schedule
                && pooled.threads == scoped.threads,
            "spmm metadata diverges under {sched:?} nt={nt}"
        );
        for (j, x) in vectors.iter().enumerate() {
            let want = exec::spmv_sequential(&csr, x).y;
            let col = pooled.column(j);
            for (i, (a, b)) in want.iter().zip(&col).enumerate() {
                prop_assert!(
                    (a - b).abs() < 1e-9 * (1.0 + a.abs()),
                    "pooled spmm col {j} row {i}: {a} vs {b}"
                );
            }
        }
        for (i, (p, q)) in scoped.y.iter().zip(&pooled.y).enumerate() {
            prop_assert!(
                p.to_bits() == q.to_bits(),
                "spmm element {i} diverges bitwise: {p} vs {q}"
            );
        }
        Ok(())
    });
    assert_eq!(pool.n_workers(), 4, "the pool never grows");
}

#[test]
fn pooled_executors_skip_empty_slots_when_threads_exceed_rows() {
    // Thread counts far beyond the row count: surplus slots are
    // empty; both dispatch modes must skip them and report the same
    // effective parallelism.
    let pool = exec::ExecPool::new(8);
    for n in [1usize, 2, 3, 5] {
        let csr = Csr::identity(n);
        let x = vec![2.0; n];
        for sched in [
            Schedule::CsrRowStatic,
            Schedule::CsrRowBalanced,
            Schedule::CsrDynamic { chunk: 1 },
            Schedule::Csr5Tiles { tile_nnz: 2 },
        ] {
            for nt in [n + 1, 16] {
                let pooled =
                    exec::spmv_threaded_on(Some(&pool), &csr, &x, sched, nt);
                let scoped = exec::spmv_threaded(&csr, &x, sched, nt);
                assert_eq!(pooled.y, vec![2.0; n], "{sched:?} nt={nt}");
                assert_eq!(pooled.y, scoped.y);
                assert_eq!(pooled.threads, scoped.threads);
                assert!(
                    pooled.threads <= n.max(1),
                    "{sched:?} nt={nt}: {} effective workers for {n} rows",
                    pooled.threads
                );
            }
        }
    }
}

#[test]
fn pool_reuse_stress_many_small_requests() {
    // The reuse contract: hundreds of small dispatches on one pool,
    // zero thread growth, every job accounted for.
    let pool = exec::ExecPool::new(4);
    let mut rng = Pcg32::new(0x5700);
    let csr = random_csr(&mut rng);
    let x: Vec<f64> = (0..csr.n_cols).map(|_| rng.gen_f64()).collect();
    let want = exec::spmv_sequential(&csr, &x).y;
    let jobs_before = pool.jobs_dispatched();
    let iters = 300usize;
    for i in 0..iters {
        let sched = random_schedule(&mut rng);
        let got = exec::spmv_threaded_on(Some(&pool), &csr, &x, sched, 4);
        assert_eq!(got.y.len(), want.len(), "iter {i}");
        for (r, (p, q)) in got.y.iter().zip(&want).enumerate() {
            assert!(
                (p - q).abs() < 1e-9 * (1.0 + p.abs()),
                "iter {i} row {r}: {p} vs {q} under {sched:?}"
            );
        }
    }
    assert_eq!(pool.n_workers(), 4, "no thread-count growth");
    assert_eq!(
        pool.jobs_dispatched() - jobs_before,
        iters as u64,
        "one pool job per request"
    );
}

#[test]
fn tuner_candidate_plans_match_the_reference() {
    // Plan-variant equivalence: every candidate the autotuner may
    // promote must compute the same answer as the sequential
    // reference — numerically everywhere, and *bitwise* wherever the
    // executed kernel is row-space (row-partitioned SpMV sums each
    // row in index order, exactly like the reference; batched SpMM is
    // always row-space). CSR5 tile variants may associate a
    // boundary-spanning row's partial sums differently, so they get
    // the 1e-9 bound plus a bitwise *determinism* check (the same
    // variant must never produce two different answers).
    check("tuner-variants==reference", 12, |rng| {
        let csr = random_csr(rng);
        let cfg = service::PlanConfig::default();
        let static_plan =
            service::build_plan(&service::Planner::Heuristic, &cfg, &csr);
        let variants = autotune::candidates(
            static_plan.schedule,
            cfg.csr5_tile_nnz,
            static_plan.n_threads,
            16,
        );
        prop_assert!(
            variants.len() > 1,
            "the ladder must hold real alternatives"
        );
        let x: Vec<f64> =
            (0..csr.n_cols).map(|_| rng.gen_f64() - 0.5).collect();
        let want = exec::spmv_sequential(&csr, &x).y;
        let xs = exec::pack_vectors(&[&x, &x, &x]);
        for v in &variants {
            let plan = service::build_plan_with(
                &cfg,
                &csr,
                v.schedule,
                v.n_threads,
                static_plan.features.clone(),
            );
            let got = plan.execute(&csr, &x);
            for (i, (a, b)) in want.iter().zip(&got.y).enumerate() {
                prop_assert!(
                    (a - b).abs() < 1e-9 * (1.0 + a.abs()),
                    "row {i}: {a} vs {b} under {v:?}"
                );
            }
            if matches!(plan.partition, Partition::Rows { .. }) {
                for (i, (a, b)) in want.iter().zip(&got.y).enumerate() {
                    prop_assert!(
                        a.to_bits() == b.to_bits(),
                        "row-space variant {v:?} diverges bitwise at \
                         row {i}: {a} vs {b}"
                    );
                }
            }
            // Re-executing the same variant is bitwise deterministic.
            let again = plan.execute(&csr, &x);
            for (i, (a, b)) in got.y.iter().zip(&again.y).enumerate() {
                prop_assert!(
                    a.to_bits() == b.to_bits(),
                    "variant {v:?} not deterministic at row {i}"
                );
            }
            // The batched path is always row-space: bitwise identical
            // to the sequential reference, column by column.
            let batch = plan.execute_batch(&csr, &xs, 3);
            for j in 0..3 {
                for (i, (a, b)) in
                    want.iter().zip(&batch.column(j)).enumerate()
                {
                    prop_assert!(
                        a.to_bits() == b.to_bits(),
                        "batch col {j} row {i} diverges bitwise under \
                         {v:?}: {a} vs {b}"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn plan_is_deterministic_per_fingerprint() {
    check("plan-deterministic", 10, |rng| {
        let csr = random_csr(rng);
        let fp = service::fingerprint(&csr);
        prop_assert!(
            service::fingerprint(&csr.clone()) == fp,
            "fingerprint must be content-addressed"
        );
        // Two independent caches (two fresh processes) must build the
        // identical plan for the same fingerprint.
        let fresh = || {
            service::PlanCache::new(
                service::Planner::Heuristic,
                service::PlanConfig::default(),
            )
        };
        let (a, b) = (fresh(), fresh());
        let (pa, first_hit) = a.plan_for(fp, &csr);
        let (pb, _) = b.plan_for(fp, &csr);
        prop_assert!(!first_hit, "first request cannot hit");
        prop_assert!(
            pa.schedule == pb.schedule,
            "{:?} vs {:?}",
            pa.schedule,
            pb.schedule
        );
        prop_assert!(pa.n_threads == pb.n_threads);
        // A repeat against the same cache hits and returns the very
        // same plan object.
        let (pa2, hit) = a.plan_for(fp, &csr);
        prop_assert!(hit);
        prop_assert!(std::sync::Arc::ptr_eq(&pa, &pa2));
        Ok(())
    });
}

#[test]
fn features_are_finite_and_consistent() {
    check("features-finite", 30, |rng| {
        let csr = random_csr(rng);
        let f = MatrixFeatures::extract(&csr);
        prop_assert!(f.nnz == csr.nnz());
        prop_assert!(f.nnz_max <= f.nnz.max(1));
        prop_assert!(f.nnz_avg.is_finite() && f.nnz_avg >= 0.0);
        prop_assert!(f.nnz_var.is_finite() && f.nnz_var >= 0.0);
        prop_assert!(
            (f.nnz_avg * f.n_rows as f64 - f.nnz as f64).abs() < 1e-6,
            "avg inconsistent"
        );
        Ok(())
    });
}
