//! Observability acceptance: a traced virtual-time replay must export
//! a parseable Chrome trace-event document covering all seven serve
//! stages, and the engine's unified metrics snapshot must carry every
//! stats surface under one schema.

use std::sync::Arc;

use ft2000_spmv::autotune::AutotuneConfig;
use ft2000_spmv::corpus::suite::SuiteSpec;
use ft2000_spmv::obs::{ClockMode, Stage, TraceConfig, TraceRecorder};
use ft2000_spmv::service::{
    replay, Arrivals, CostModel, MatrixRegistry, PlanConfig, Planner,
    Popularity, ReplayConfig, ServeEngine, WorkloadSpec,
};
use ft2000_spmv::util::json::{parse, Json};

#[test]
fn traced_replay_exports_chrome_trace_and_unified_metrics() {
    let mut reg = MatrixRegistry::new();
    let ids = reg.register_suite(&SuiteSpec::tiny(), Some(6));
    let engine =
        ServeEngine::new(reg, Planner::Heuristic, PlanConfig::default());
    // A virtual-clock tuner makes the `autotune_observe` stage fire;
    // the other six come from the replay harness + model dispatcher.
    let engine = engine.with_tuner(AutotuneConfig {
        wall_clock: false,
        ..AutotuneConfig::default()
    });
    let engine = engine.with_trace(Arc::new(TraceRecorder::new(
        TraceConfig::on(),
        ClockMode::Virtual,
        1,
    )));
    let spec = WorkloadSpec {
        requests: 400,
        popularity: Popularity::Zipf { s: 1.2 },
        arrivals: Arrivals::Closed { clients: 1 },
        seed: 0x0B5,
    };
    let cfg = ReplayConfig { execute: false, ..ReplayConfig::default() };
    let report = replay(&engine, &ids, &spec, &cfg).unwrap();
    assert_eq!(report.stats.requests, 400);

    // The exported document round-trips through the JSON parser and
    // names every serve stage.
    let rec = engine.trace().expect("recorder attached");
    let text = rec.export_chrome().to_string();
    let doc = parse(&text).expect("chrome document parses");
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents array");
    assert!(!events.is_empty(), "a traced replay must record spans");
    for stage in Stage::all() {
        assert!(
            events.iter().any(|e| e.get("name").and_then(Json::as_str)
                == Some(stage.name())),
            "stage {} missing from the exported trace",
            stage.name()
        );
    }

    // One snapshot, every surface, one schema.
    let text = engine.metrics_snapshot().to_string();
    let snap = parse(&text).expect("metrics snapshot parses");
    assert_eq!(
        snap.get("schema").and_then(Json::as_str),
        Some("ft2000.metrics.v1")
    );
    for key in ["serve", "plan_cache", "autotune", "registry"] {
        assert!(snap.get(key).is_some(), "snapshot missing {key}");
    }
    // Queue wait is reported separately from service time.
    let qw = snap
        .get("serve")
        .and_then(|s| s.get("queue_wait_ms"))
        .expect("queue-wait block in the serve report");
    assert!(qw.get("p95").is_some(), "queue-wait p95 missing");
}

/// The exact key set of a JSON object, for golden-schema pins.
fn keys(doc: &Json) -> Vec<&str> {
    doc.as_obj()
        .expect("object node")
        .keys()
        .map(String::as_str)
        .collect()
}

fn model_replay_engine(requests: usize, cost: CostModel) -> ServeEngine {
    let mut reg = MatrixRegistry::new();
    let ids = reg.register_suite(&SuiteSpec::tiny(), Some(6));
    let engine =
        ServeEngine::new(reg, Planner::Heuristic, PlanConfig::default());
    let spec = WorkloadSpec {
        requests,
        popularity: Popularity::Zipf { s: 1.2 },
        arrivals: Arrivals::Closed { clients: 2 },
        seed: 0x5CA1,
    };
    let cfg = ReplayConfig { execute: false, cost, ..ReplayConfig::default() };
    replay(&engine, &ids, &spec, &cfg).unwrap();
    engine
}

/// Golden schema: `ft2000.metrics.v1` carries exactly the documented
/// key sets at every level dashboards are told to read. A key
/// appearing or vanishing here is a consumer-visible schema change
/// and must bump the version string instead.
#[test]
fn metrics_snapshot_golden_keys() {
    let engine = model_replay_engine(120, CostModel::default());
    let snap = parse(&engine.metrics_snapshot().to_string()).unwrap();
    assert_eq!(
        keys(&snap),
        ["autotune", "plan_cache", "pool", "registry", "schema", "serve"]
    );
    assert_eq!(
        keys(snap.get("serve").unwrap()),
        [
            "batch_hist",
            "batches",
            "cache_hits",
            "cache_misses",
            "duration_s",
            "errors",
            "executed_gflops",
            "latency_ms",
            "mean_batch",
            "per_schedule",
            "queue_wait_ms",
            "rejected",
            "requests",
            "shed",
            "throughput_rps",
        ]
    );
    assert_eq!(
        keys(snap.get("serve").unwrap().get("queue_wait_ms").unwrap()),
        ["count", "mean", "p50", "p95"]
    );
    assert_eq!(
        keys(snap.get("plan_cache").unwrap()),
        [
            "capacity",
            "evictions",
            "hit_rate",
            "hits",
            "len",
            "misses",
            "replacements",
        ]
    );
}

/// Golden schema: `ft2000.scaling.v1` — the document `obs-report`
/// diffs — emits exactly the documented keys for the roll-up, the
/// per-matrix attribution, and every efficiency-curve point.
#[test]
fn scaling_snapshot_golden_keys() {
    let engine = model_replay_engine(120, CostModel::default());
    let snap = parse(&engine.scaling_snapshot().to_string()).unwrap();
    assert_eq!(
        snap.get("schema").and_then(Json::as_str),
        Some("ft2000.scaling.v1")
    );
    assert_eq!(
        keys(&snap),
        ["batches", "gap", "matrices", "queue_wait_ms", "schema"]
    );
    let gap_keys = [
        "batches",
        "gap_s",
        "ideal_s",
        "imbalance_s",
        "imbalance_share",
        "kernel_s",
        "observed_s",
        "overhead_s",
        "overhead_share",
        "requests",
        "residual_s",
        "residual_share",
        "work_s",
    ];
    assert_eq!(keys(snap.get("gap").unwrap()), gap_keys);
    assert_eq!(
        keys(snap.get("queue_wait_ms").unwrap()),
        ["count", "mean_ms", "p50_ms", "p95_ms"]
    );
    let mats = snap.get("matrices").and_then(Json::as_arr).unwrap();
    assert!(!mats.is_empty(), "replay must populate per-matrix curves");
    for m in mats {
        assert_eq!(
            keys(m),
            ["efficiency", "fingerprint", "gap", "knee_threads"]
        );
        assert_eq!(keys(m.get("gap").unwrap()), gap_keys);
        let curve = m.get("efficiency").and_then(Json::as_arr).unwrap();
        assert!(!curve.is_empty());
        for cell in curve {
            assert_eq!(
                keys(cell),
                ["batches", "efficiency", "speedup", "threads"]
            );
        }
    }
}

/// Acceptance pin: on a deterministic model replay the per-batch
/// gap-to-linear components must sum to the observed gap (the
/// attribution never invents or loses time), the decomposition must
/// be reproducible bit-for-bit across runs, and a cost model
/// saturating below the plan width must surface a positive
/// memory-bound residual.
#[test]
fn model_replay_components_sum_to_observed_gap() {
    // Panels saturate at 2 threads while plans run 4 wide: the model
    // predicts a memory-bandwidth residual `T1 * (1/2 - 1/4) > 0`.
    let cost = CostModel { sat_threads: 2, ..CostModel::default() };
    let engine = model_replay_engine(200, cost);
    let t = engine.scaling().totals();
    assert!(t.batches > 0 && t.requests >= t.batches);
    assert!(t.work_s > 0.0 && t.kernel_s > 0.0);

    // Identity 1: gap is exactly observed minus ideal.
    assert!(
        (t.observed_s - t.ideal_s - t.gap_s).abs() <= 1e-12 * t.observed_s,
        "gap {} != observed {} - ideal {}",
        t.gap_s,
        t.observed_s,
        t.ideal_s
    );
    // Identity 2: the gap decomposes without remainder.
    let parts = t.imbalance_s + t.overhead_s + t.residual_s;
    assert!(
        (t.gap_s - parts).abs() <= 1e-9 * t.gap_s.max(1e-12),
        "components {} do not sum to gap {}",
        parts,
        t.gap_s
    );
    // Dispatch + fork/join cost every batch; saturation past 2 of 4
    // threads leaves bandwidth-bound time on the table.
    assert!(t.overhead_s > 0.0, "dispatch/sync overhead must be counted");
    assert!(t.residual_s > 0.0, "memory-bound residual must be counted");

    // Same seed, same model: the totals replay bit-for-bit.
    let again = model_replay_engine(200, cost).scaling().totals();
    assert_eq!(t.batches, again.batches);
    assert_eq!(t.gap_s.to_bits(), again.gap_s.to_bits());
    assert_eq!(t.residual_s.to_bits(), again.residual_s.to_bits());

    // Every efficiency-curve point reflects the saturation ceiling:
    // the modeled kernel speedup is exactly min(threads, sat_threads).
    let snap = engine.scaling_snapshot();
    for m in snap.get("matrices").and_then(Json::as_arr).unwrap() {
        for cell in m.get("efficiency").and_then(Json::as_arr).unwrap() {
            let th = cell.get("threads").and_then(Json::as_usize).unwrap();
            let sp = cell.get("speedup").and_then(Json::as_f64).unwrap();
            let want = th.min(2) as f64;
            assert!(
                (sp - want).abs() < 1e-9,
                "speedup {sp} at {th} threads, expected {want}"
            );
        }
    }
}
