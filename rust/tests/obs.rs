//! Observability acceptance: a traced virtual-time replay must export
//! a parseable Chrome trace-event document covering all seven serve
//! stages, and the engine's unified metrics snapshot must carry every
//! stats surface under one schema.

use std::sync::Arc;

use ft2000_spmv::autotune::AutotuneConfig;
use ft2000_spmv::corpus::suite::SuiteSpec;
use ft2000_spmv::obs::{ClockMode, Stage, TraceConfig, TraceRecorder};
use ft2000_spmv::service::{
    replay, Arrivals, MatrixRegistry, PlanConfig, Planner, Popularity,
    ReplayConfig, ServeEngine, WorkloadSpec,
};
use ft2000_spmv::util::json::{parse, Json};

#[test]
fn traced_replay_exports_chrome_trace_and_unified_metrics() {
    let mut reg = MatrixRegistry::new();
    let ids = reg.register_suite(&SuiteSpec::tiny(), Some(6));
    let engine =
        ServeEngine::new(reg, Planner::Heuristic, PlanConfig::default());
    // A virtual-clock tuner makes the `autotune_observe` stage fire;
    // the other six come from the replay harness + model dispatcher.
    let engine = engine.with_tuner(AutotuneConfig {
        wall_clock: false,
        ..AutotuneConfig::default()
    });
    let engine = engine.with_trace(Arc::new(TraceRecorder::new(
        TraceConfig::on(),
        ClockMode::Virtual,
        1,
    )));
    let spec = WorkloadSpec {
        requests: 400,
        popularity: Popularity::Zipf { s: 1.2 },
        arrivals: Arrivals::Closed { clients: 1 },
        seed: 0x0B5,
    };
    let cfg = ReplayConfig { execute: false, ..ReplayConfig::default() };
    let report = replay(&engine, &ids, &spec, &cfg).unwrap();
    assert_eq!(report.stats.requests, 400);

    // The exported document round-trips through the JSON parser and
    // names every serve stage.
    let rec = engine.trace().expect("recorder attached");
    let text = rec.export_chrome().to_string();
    let doc = parse(&text).expect("chrome document parses");
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents array");
    assert!(!events.is_empty(), "a traced replay must record spans");
    for stage in Stage::all() {
        assert!(
            events.iter().any(|e| e.get("name").and_then(Json::as_str)
                == Some(stage.name())),
            "stage {} missing from the exported trace",
            stage.name()
        );
    }

    // One snapshot, every surface, one schema.
    let text = engine.metrics_snapshot().to_string();
    let snap = parse(&text).expect("metrics snapshot parses");
    assert_eq!(
        snap.get("schema").and_then(Json::as_str),
        Some("ft2000.metrics.v1")
    );
    for key in ["serve", "plan_cache", "autotune", "registry"] {
        assert!(snap.get(key).is_some(), "snapshot missing {key}");
    }
    // Queue wait is reported separately from service time.
    let qw = snap
        .get("serve")
        .and_then(|s| s.get("queue_wait_ms"))
        .expect("queue-wait block in the serve report");
    assert!(qw.get("p95").is_some(), "queue-wait p95 missing");
}
