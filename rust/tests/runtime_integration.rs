//! Integration: PJRT runtime vs native executor over the AOT
//! artifacts. Requires `make artifacts` (skips with a message when the
//! directory is absent, so `cargo test` works in a fresh checkout).

use ft2000_spmv::corpus::generators;
use ft2000_spmv::runtime::Runtime;
use ft2000_spmv::sparse::{Csr, Ell};
use ft2000_spmv::util::rng::Pcg32;

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping runtime integration: run `make artifacts` first");
        return None;
    }
    Some(Runtime::new("artifacts").expect("runtime init"))
}

fn check_spmv(rt: &Runtime, csr: &Csr, rng: &mut Pcg32, what: &str) {
    let x: Vec<f64> = (0..csr.n_cols).map(|_| rng.gen_f64() - 0.5).collect();
    let mut want = vec![0.0; csr.n_rows];
    csr.spmv(&x, &mut want);
    let got = rt.spmv(csr, &x).expect("pjrt spmv");
    assert_eq!(got.len(), csr.n_rows);
    for (i, (a, b)) in want.iter().zip(&got).enumerate() {
        assert!(
            (a - b).abs() / (1.0 + a.abs()) < 1e-4,
            "{what} row {i}: native {a} vs pjrt {b}"
        );
    }
}

#[test]
fn ell_kernel_matches_native() {
    let Some(rt) = runtime() else { return };
    let mut rng = Pcg32::new(1);
    let csr = generators::banded(1000, 7, &mut rng);
    check_spmv(&rt, &csr, &mut rng, "banded");
}

#[test]
fn seg_kernel_handles_wide_rows() {
    let Some(rt) = runtime() else { return };
    let mut rng = Pcg32::new(2);
    // One giant row: ELL would need K = 1500; the seg bucket takes it.
    let csr = generators::dense_row_block(1500, 12_000, &mut rng);
    assert!(csr.max_row_nnz() > 64);
    check_spmv(&rt, &csr, &mut rng, "dense-row-block");
}

#[test]
fn kernel_routing_covers_classes() {
    let Some(rt) = runtime() else { return };
    let mut rng = Pcg32::new(3);
    for (name, csr) in [
        ("random", generators::random_uniform(2000, 12, &mut rng)),
        ("stencil", generators::stencil(1024, 5)),
        ("road", generators::road_network(4000, &mut rng)),
        ("powerlaw", generators::power_law(1500, 6.0, 1.6, &mut rng)),
    ] {
        check_spmv(&rt, &csr, &mut rng, name);
    }
}

#[test]
fn power_iteration_graph_runs() {
    let Some(rt) = runtime() else { return };
    let mut rng = Pcg32::new(4);
    let csr = generators::banded(2048, 5, &mut rng);
    let ell = Ell::from_csr(&csr, None).unwrap();
    let x0 = vec![1.0 / (2048.0f64).sqrt(); 2048];
    let (v, rayleigh) = rt.power_iter(&ell, &x0).expect("power iter");
    assert_eq!(v.len(), 2048);
    let norm: f64 = v.iter().map(|a| a * a).sum::<f64>().sqrt();
    assert!((norm - 1.0).abs() < 1e-3, "normalized output: {norm}");
    assert!(rayleigh.is_finite());
}

#[test]
fn empty_and_identity_edge_cases() {
    let Some(rt) = runtime() else { return };
    let mut rng = Pcg32::new(5);
    let identity = Csr::identity(512);
    check_spmv(&rt, &identity, &mut rng, "identity");
    // All-zero matrix through the seg path.
    let zero = Csr::zero(512, 512);
    let x = vec![1.0; 512];
    let got = rt.spmv_seg(&zero, &x).expect("zero spmv");
    assert!(got.iter().all(|&v| v == 0.0));
}

#[test]
fn spmm_matches_per_vector_spmv() {
    let Some(rt) = runtime() else { return };
    let mut rng = Pcg32::new(6);
    let csr = generators::banded(2000, 9, &mut rng);
    let ell = Ell::from_csr(&csr, None).unwrap();
    let vectors: Vec<Vec<f64>> = (0..5)
        .map(|_| (0..csr.n_cols).map(|_| rng.gen_f64() - 0.5).collect())
        .collect();
    let block = rt.spmm_ell(&ell, &vectors).expect("spmm");
    assert_eq!(block.len(), 5);
    for (j, x) in vectors.iter().enumerate() {
        let single = rt.spmv_ell(&ell, x).expect("spmv");
        for (r, (a, b)) in single.iter().zip(&block[j]).enumerate() {
            assert!(
                (a - b).abs() < 1e-4 * (1.0 + a.abs()),
                "vector {j} row {r}: {a} vs {b}"
            );
        }
    }
}

#[test]
fn rejects_oversized_matrices() {
    let Some(rt) = runtime() else { return };
    // Larger than any bucket: must error, not crash.
    let big = Csr::identity(1_000_000);
    let x = vec![0.0; 1_000_000];
    assert!(rt.spmv(&big, &x).is_err());
}
