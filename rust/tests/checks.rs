//! Property tests of the structural invariant verifier: every
//! corruption class the `check` subsystem claims to catch is caught,
//! and the clean corpus sails through. Deterministic seeds via
//! `util::testkit::check`.

use ft2000_spmv::check::{self, interleave};
use ft2000_spmv::corpus::suite::SuiteSpec;
use ft2000_spmv::prop_assert;
use ft2000_spmv::sched::{Partition, Schedule};
use ft2000_spmv::service::{
    build_plan, build_plan_with, MatrixRegistry, PlanConfig, Planner,
};
use ft2000_spmv::sparse::{Coo, Csr, Csr5, SellCSigma};
use ft2000_spmv::util::rng::Pcg32;
use ft2000_spmv::util::testkit::check as prop_check;

fn random_csr(rng: &mut Pcg32) -> Csr {
    let n = 8 + rng.gen_range(200);
    let mut coo = Coo::new(n, n);
    let nnz = n + rng.gen_range(n * 4);
    for _ in 0..nnz {
        coo.push(rng.gen_range(n), rng.gen_range(n), rng.gen_f64() - 0.5);
    }
    coo.to_csr()
}

#[test]
fn corpus_passes_clean_through_the_verifier() {
    let cfg = PlanConfig::default();
    let mut reg = MatrixRegistry::new();
    let ids = reg.register_suite(&SuiteSpec::tiny(), Some(8));
    for id in ids {
        let e = reg.entry(id);
        let r = check::check_csr(&e.name, &e.csr);
        assert!(r.is_clean(), "{}: {r}", e.name);
        let plan = build_plan(&Planner::Heuristic, &cfg, &e.csr);
        let r = check::check_plan(&e.name, &plan, &e.csr);
        assert!(r.is_clean(), "{} plan: {r}", e.name);
    }
}

#[test]
fn mutated_row_ptr_is_caught() {
    prop_check("row-ptr-mutation-caught", 25, |rng| {
        let mut csr = random_csr(rng);
        // Push an interior pointer past the end: guaranteed to break
        // monotonicity (its successor is <= nnz).
        let i = 1 + rng.gen_range(csr.n_rows - 1);
        let beyond = csr.nnz() + 1;
        csr.ptr[i] = beyond;
        let r = check::check_csr("mutated", &csr);
        prop_assert!(!r.is_clean(), "mutation at ptr[{i}] not caught");
        prop_assert!(
            r.findings.iter().any(|f| f.invariant == "ptr-monotone"
                || f.invariant == "ptr-end"),
            "wrong invariant: {r}"
        );
        Ok(())
    });
}

#[test]
fn oob_column_index_is_caught() {
    prop_check("oob-column-caught", 25, |rng| {
        let mut csr = random_csr(rng);
        let k = rng.gen_range(csr.nnz());
        csr.indices[k] = csr.n_cols as u32 + rng.gen_range(5) as u32;
        let r = check::check_csr("oob", &csr);
        prop_assert!(!r.is_clean(), "oob col at {k} not caught");
        prop_assert!(
            r.findings.iter().any(|f| f.invariant == "col-bounds"),
            "wrong invariant: {r}"
        );
        Ok(())
    });
}

#[test]
fn non_permutation_sell_perm_is_caught() {
    prop_check("sell-perm-mutation-caught", 20, |rng| {
        let csr = random_csr(rng);
        let c = 1 + rng.gen_range(16);
        let sigma = 1 + rng.gen_range(128);
        let mut s = SellCSigma::from_csr(&csr, c, sigma);
        // Duplicate one permutation entry: no longer a bijection.
        s.perm[0] = s.perm[1];
        let r = check::check_sell("dup-perm", &s);
        prop_assert!(!r.is_clean(), "duplicated perm entry not caught");
        prop_assert!(
            r.findings.iter().any(|f| f.invariant == "perm-permutation"),
            "wrong invariant: {r}"
        );
        Ok(())
    });
}

#[test]
fn overlapping_and_gapped_row_slots_are_caught() {
    prop_check("bad-row-partition-caught", 20, |rng| {
        let csr = random_csr(rng);
        let n = csr.n_rows;
        // Overlap: two threads both own row 0.
        let overlap = Partition::Rows {
            per_thread: vec![vec![(0, n)], vec![(0, 1)]],
        };
        let r = check::check_partition("overlap", &overlap, &csr);
        prop_assert!(!r.is_clean(), "overlapping slots not caught");
        // Gap: the last row is covered by nobody.
        let gap = Partition::Rows {
            per_thread: vec![vec![(0, n - 1)], vec![]],
        };
        let r = check::check_partition("gap", &gap, &csr);
        prop_assert!(!r.is_clean(), "coverage gap not caught");
        Ok(())
    });
}

#[test]
fn corrupt_csr5_tile_descriptors_are_caught() {
    prop_check("csr5-tile-mutation-caught", 20, |rng| {
        let csr = random_csr(rng);
        let tile_nnz = 1 + rng.gen_range(64);
        let mut c5 = Csr5::from_csr(&csr, tile_nnz);
        // A tile's starting row beyond the matrix breaks the
        // descriptor/row-pointer consistency.
        let t = rng.gen_range(c5.tile_ptr.len());
        c5.tile_ptr[t] = csr.n_rows as u32 + 7;
        let r = check::check_csr5_vs_csr("bad-tile", &c5, &csr);
        prop_assert!(!r.is_clean(), "corrupt tile_ptr[{t}] not caught");
        Ok(())
    });
}

#[test]
fn quick_plan_check_matches_plan_to_matrix() {
    let cfg = PlanConfig::default();
    let mut rng = Pcg32::new(0xC8EC);
    let a = random_csr(&mut rng);
    for sched in [
        Schedule::CsrRowStatic,
        Schedule::Csr5Tiles { tile_nnz: 64 },
        Schedule::SellChunks { c: 8, sigma: 64 },
    ] {
        let plan =
            build_plan_with(&cfg, &a, sched, cfg.n_threads, Vec::new());
        assert!(
            check::quick_plan_check(&plan, &a).is_ok(),
            "{sched:?}: clean plan rejected"
        );
        // The same plan against a differently-sized matrix must be
        // refused before a kernel can run off the end of it.
        let mut rng2 = Pcg32::new(0x0DD);
        let b = loop {
            let b = random_csr(&mut rng2);
            if b.n_rows != a.n_rows {
                break b;
            }
        };
        assert!(
            check::quick_plan_check(&plan, &b).is_err(),
            "{sched:?}: mismatched matrix accepted"
        );
    }
}

#[test]
fn registry_counts_rejections_without_panicking() {
    let mut rng = Pcg32::new(0xBAD);
    let good = random_csr(&mut rng);
    let mut bad = good.clone();
    bad.data[0] = f64::NAN;
    let mut reg = MatrixRegistry::new();
    assert!(reg.try_register("nan", bad).is_err());
    assert_eq!(reg.rejected(), 1);
    assert!(reg.try_register("good", good).is_ok());
    assert_eq!(reg.len(), 1);
}

#[test]
fn interleave_quick_mode_runs_clean() {
    for seed in [1u64, 0xF00D] {
        let r = interleave::run(&interleave::InterleaveConfig::quick(seed));
        assert!(r.is_clean(), "seed {seed:#x}: {r}");
        assert!(r.checked > 0);
    }
}
