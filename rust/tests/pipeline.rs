//! Integration: coordinator pipeline pieces working together —
//! campaign → dataset → model → advisor → reports.

use ft2000_spmv::coordinator::advisor::{diagnose, Advice};
use ft2000_spmv::coordinator::{
    build_dataset, profile_matrix, report, Campaign, ProfileConfig,
    FEATURE_NAMES,
};
use ft2000_spmv::corpus::suite::SuiteSpec;
use ft2000_spmv::corpus::NamedMatrix;
use ft2000_spmv::mlmodel::{Forest, ForestParams, Tree, TreeParams};
use ft2000_spmv::sched::Schedule;

fn tiny_profiles() -> Vec<ft2000_spmv::coordinator::MatrixProfile> {
    Campaign::new(SuiteSpec::tiny(), ProfileConfig::default()).run()
}

#[test]
fn campaign_to_model_roundtrip() {
    let profiles = tiny_profiles();
    let data = build_dataset(&profiles);
    assert_eq!(data.n_features(), FEATURE_NAMES.len());
    assert_eq!(data.len(), profiles.len());
    // Both model types train and predict finite values.
    let tree = Tree::fit(&data, TreeParams::default());
    let forest = Forest::fit(
        &data,
        ForestParams { n_trees: 5, ..Default::default() },
    );
    for row in &data.x {
        assert!(tree.predict(row).is_finite());
        assert!(forest.predict(row).is_finite());
    }
    // Rendering is non-empty and mentions a real feature.
    let txt = tree.render();
    assert!(txt.contains("speedup ="), "{txt}");
}

#[test]
fn reports_cover_all_matrices() {
    let profiles = tiny_profiles();
    let mut csv = Vec::new();
    report::write_csv(&mut csv, &profiles).unwrap();
    let text = String::from_utf8(csv).unwrap();
    assert_eq!(text.lines().count(), profiles.len() + 1);
    for p in &profiles {
        assert!(text.contains(&p.name), "missing {} in csv", p.name);
    }
    assert!(!report::table2_average_speedups(&profiles).is_empty());
    assert!(!report::fig4_distribution(&profiles).is_empty());
}

#[test]
fn advisor_end_to_end_improves_flagged_matrices() {
    // Every matrix the advisor flags for CSR5 must actually improve
    // under CSR5 in the simulator (the §5.2.1 loop, closed).
    let profiles = tiny_profiles();
    let suite = SuiteSpec::tiny();
    let entries = suite.entries();
    let mut checked = 0;
    for (i, p) in profiles.iter().enumerate() {
        if p.derived.job_var < 0.45 {
            continue;
        }
        let m = suite.materialize(&entries[i]);
        let advice = diagnose(&m.csr, p);
        assert!(
            advice.contains(&Advice::UseCsr5),
            "{}: job_var {} must trigger CSR5 advice",
            p.name,
            p.derived.job_var
        );
        let after = profile_matrix(
            &m.csr,
            &m.name,
            &ProfileConfig {
                schedule: Schedule::Csr5Tiles { tile_nnz: 256 },
                ..Default::default()
            },
        );
        assert!(
            after.max_speedup() > p.max_speedup() * 0.95,
            "{}: CSR5 should not regress ({} -> {})",
            p.name,
            p.max_speedup(),
            after.max_speedup()
        );
        checked += 1;
    }
    assert!(checked > 0, "tiny corpus must contain imbalance cases");
}

#[test]
fn named_matrices_have_distinct_diagnoses() {
    let cfg = ProfileConfig::default();
    let mut kinds = std::collections::HashSet::new();
    for m in NamedMatrix::ALL {
        let csr = m.generate();
        let p = profile_matrix(&csr, m.name(), &cfg);
        for a in diagnose(&csr, &p) {
            kinds.insert(format!("{a:?}"));
        }
    }
    // The six case studies must span at least three advice kinds.
    assert!(kinds.len() >= 3, "diagnoses too uniform: {kinds:?}");
}

#[test]
fn campaign_deterministic() {
    let a = tiny_profiles();
    let b = tiny_profiles();
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.name, y.name);
        assert_eq!(x.speedups, y.speedups);
        assert_eq!(x.counters_1t, y.counters_1t);
    }
}
