//! Integration: the paper's headline shapes must hold end-to-end.
//! (Each test runs the full simulate→profile pipeline; corpus-level
//! checks use the tiny suite to stay fast.)

use ft2000_spmv::coordinator::{
    build_dataset, profile_matrix, Campaign, ProfileConfig,
};
use ft2000_spmv::corpus::suite::SuiteSpec;
use ft2000_spmv::corpus::NamedMatrix;
use ft2000_spmv::mlmodel::{Forest, ForestParams};
use ft2000_spmv::reorder::locality_reorder;
use ft2000_spmv::sched::Schedule;
use ft2000_spmv::sim::topology::Topology;
use ft2000_spmv::util::rng::Pcg32;

/// Table 4 ordering: exdata_1 flat < conf5/appu (gather-limited) <
/// debr (streams) < asia_osm; and exdata_1 ~1.0x.
#[test]
fn table4_ordering() {
    let cfg = ProfileConfig::default();
    let sp = |m: NamedMatrix| {
        profile_matrix(&m.generate(), m.name(), &cfg).max_speedup()
    };
    let exdata = sp(NamedMatrix::Exdata1);
    let conf5 = sp(NamedMatrix::Conf5_4_8x8_20);
    let appu = sp(NamedMatrix::Appu);
    let debr = sp(NamedMatrix::Debr);
    let asia = sp(NamedMatrix::AsiaOsm);
    assert!((0.9..1.15).contains(&exdata), "exdata_1 ~1.0x: {exdata}");
    assert!(exdata < conf5 && exdata < appu, "imbalance worst");
    assert!(conf5 < debr, "gather-limited below streaming: {conf5} vs {debr}");
    assert!(appu < debr, "gather-limited below streaming: {appu} vs {debr}");
    assert!(debr < asia + 0.8, "asia in the same band or above: {debr} vs {asia}");
}

/// Fig 2: Xeon saturates by 4 threads; FT-2000+ keeps climbing to 16.
#[test]
fn fig2_shapes() {
    let csr = NamedMatrix::Bone010.generate();
    let threads = vec![1, 2, 4, 8, 16];
    let xeon = profile_matrix(
        &csr,
        "bone010",
        &ProfileConfig {
            topo: Topology::xeon_e5_2692(),
            threads: threads.clone(),
            ..Default::default()
        },
    );
    let ft = profile_matrix(
        &csr,
        "bone010",
        &ProfileConfig { threads, ..Default::default() },
    );
    // Xeon: 4 -> 16 threads gains little.
    let xeon_gain = xeon.gflops[4] / xeon.gflops[2];
    assert!(xeon_gain < 1.35, "xeon must flatten after 4: {xeon_gain}");
    // FT: 4 -> 16 threads gains a lot (new core-groups).
    let ft_gain = ft.gflops[4] / ft.gflops[2];
    assert!(ft_gain > 2.0, "ft must keep scaling: {ft_gain}");
    // FT overtakes Xeon by 16 threads.
    assert!(ft.gflops[4] > xeon.gflops[4]);
}

/// Fig 7: CSR5 rescues exdata_1.
#[test]
fn fig7_csr5_rescue() {
    let csr = NamedMatrix::Exdata1.generate();
    let base =
        profile_matrix(&csr, "x", &ProfileConfig::default()).max_speedup();
    let csr5 = profile_matrix(
        &csr,
        "x",
        &ProfileConfig {
            schedule: Schedule::Csr5Tiles { tile_nnz: 256 },
            ..Default::default()
        },
    )
    .max_speedup();
    assert!(csr5 > base * 1.3, "CSR5 {csr5} must rescue CSR {base}");
}

/// Fig 8: private L2 beats one core-group broadly; conf5 reaches ~3.6x.
#[test]
fn fig8_private_l2() {
    let conf5 = NamedMatrix::Conf5_4_8x8_20.generate();
    let g = profile_matrix(&conf5, "c", &ProfileConfig::default())
        .max_speedup();
    let p = profile_matrix(&conf5, "c", &ProfileConfig::private_l2())
        .max_speedup();
    assert!(p > 3.0, "private-L2 conf5: {p}");
    assert!(p > g + 1.0, "gap: {g} -> {p}");
}

/// Table 5: locality reorder lifts 64-thread throughput substantially.
#[test]
fn table5_locality_reorder() {
    let mut rng = Pcg32::new(0x10CA11);
    let n = 64 * 1600; // smaller than the bench but same structure
    let synth =
        ft2000_spmv::corpus::generators::poor_locality(n, 4, 64, &mut rng);
    let plan = locality_reorder(&synth, 64);
    let fixed = plan.apply(&synth);
    let cfg = ProfileConfig { threads: vec![1, 64], ..Default::default() };
    let a = profile_matrix(&synth, "synth", &cfg);
    let b = profile_matrix(&fixed, "fixed", &cfg);
    assert!(
        b.gflops[1] > 1.4 * a.gflops[1],
        "64-thread Gflops must improve >40%: {} -> {}",
        a.gflops[1],
        b.gflops[1]
    );
    assert!(b.gflops[0] > a.gflops[0], "single-thread improves too");
}

/// §4.2: the trained model ranks job_var as the dominant factor.
#[test]
fn model_finds_imbalance_factor() {
    let profiles =
        Campaign::new(SuiteSpec::tiny(), ProfileConfig::default()).run();
    let data = build_dataset(&profiles);
    let forest = Forest::fit(
        &data,
        ForestParams { n_trees: 10, ..Default::default() },
    );
    let ranked = forest.ranked_features();
    let top3: Vec<&str> =
        ranked.iter().take(3).map(|(n, _)| n.as_str()).collect();
    assert!(
        top3.contains(&"job_var"),
        "job_var must rank top-3: {ranked:?}"
    );
}

/// Table 2 band: tiny-corpus 4-thread average lands in a sane range
/// (sub-linear, clearly above 1).
#[test]
fn table2_band() {
    let profiles =
        Campaign::new(SuiteSpec::tiny(), ProfileConfig::default()).run();
    let avg = ft2000_spmv::util::stats::mean(
        &profiles.iter().map(|p| p.max_speedup()).collect::<Vec<_>>(),
    );
    assert!(
        (0.9..3.0).contains(&avg),
        "tiny-corpus average 4t speedup out of band: {avg}"
    );
}
