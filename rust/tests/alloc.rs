//! Zero-allocation regression test for the pooled steady-state serve
//! path (the PR-5 tentpole contract).
//!
//! A counting global allocator (`util::allocprobe`) is installed for
//! this test binary only. After warmup — plan caches populated,
//! scratch arenas grown to the corpus's largest request, telemetry
//! maps holding every key they will ever hold — repeated
//! `ServeEngine::serve_batch` dispatches must not allocate at all,
//! across all three plan families (row-partitioned CSR, CSR5 tiles,
//! SELL-C-σ chunks) and both the singleton and the coalesced SpMM
//! path.
//!
//! Stage tracing (PR 6) is enabled on the engine under test: span
//! recording rides the hot path through pre-sized atomic ring
//! buffers, so the zero-allocation contract must hold with the
//! recorder attached, not just with observability off.
//!
//! The scalability profiler (PR 9) is always on — every dispatch in
//! the measured window records gap components through
//! `ScalingProfiler::record`, whose per-fingerprint aggregates were
//! allocated during warmup. The zero-alloc pin therefore covers the
//! profiler's steady state too; the batch-count assertion at the end
//! proves it really observed the window.
//!
//! So is the health ledger (PR 10): every dispatch consults the
//! degraded-mode ladder and feeds the slow-lane EWMA detector, whose
//! per-lane vector was grown during warmup. The dwell assertion at
//! the end proves the tracker was live inside the measured window —
//! the resilience seams ride the hot path allocation-free too.
//!
//! Kept as a single `#[test]` on purpose: the counter is
//! process-global, and libtest runs sibling tests on concurrent
//! threads whose allocations would pollute the reading.

use std::sync::Arc;

use ft2000_spmv::corpus::{generators, NamedMatrix};
use ft2000_spmv::obs::{ClockMode, TraceConfig, TraceRecorder};
use ft2000_spmv::service::{
    MatrixRegistry, PlanConfig, Planner, ServeEngine,
};
use ft2000_spmv::sparse::Coo;
use ft2000_spmv::util::allocprobe::{total_allocs, CountingAllocator};
use ft2000_spmv::util::rng::Pcg32;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

/// 4-thread static split [64, 64, 64, 128] -> job_var 0.4: lands in
/// the heuristic's SELL-C-σ band.
fn sell_band_matrix() -> ft2000_spmv::sparse::Csr {
    let mut coo = Coo::new(256, 256);
    for r in 0..256 {
        coo.push(r, r, 1.0);
        if r >= 192 {
            coo.push(r, (r + 1) % 256, 1.0);
        }
    }
    coo.to_csr()
}

#[test]
fn pooled_steady_state_serving_allocates_nothing() {
    // Probe sanity: the counting allocator is really installed.
    let before = total_allocs();
    let probe: Vec<u8> = Vec::with_capacity(4096);
    assert!(
        total_allocs() > before,
        "counting allocator not installed — the test would be vacuous"
    );
    drop(probe);

    let mut rng = Pcg32::new(0xA110C);
    let mut reg = MatrixRegistry::new();
    // One matrix per plan family.
    let row_id = reg.register("rows", generators::stencil(512, 5));
    let tile_id = reg.register("tiles", NamedMatrix::Exdata1.generate());
    let sell_id = reg.register("sell", sell_band_matrix());
    let engine =
        ServeEngine::pooled(reg, Planner::Heuristic, PlanConfig::default());
    // Tracing on, at full sampling: spans must land in the pre-sized
    // rings without touching the heap.
    let n_lanes = engine.pool().map(|p| p.n_workers() + 1).unwrap_or(1);
    let engine = engine.with_trace(Arc::new(TraceRecorder::new(
        TraceConfig::on(),
        ClockMode::Wall,
        n_lanes,
    )));

    // The three plan families really are exercised (guards the test
    // against a future heuristic change silently narrowing coverage).
    use ft2000_spmv::sched::Schedule;
    let kinds: Vec<Schedule> = [row_id, tile_id, sell_id]
        .iter()
        .map(|&id| {
            let e = engine.registry.entry(id);
            engine.plans.plan_for(e.fingerprint, &e.csr).0.schedule
        })
        .collect();
    assert!(matches!(kinds[0], Schedule::CsrRowStatic), "{kinds:?}");
    assert!(matches!(kinds[1], Schedule::Csr5Tiles { .. }), "{kinds:?}");
    assert!(matches!(kinds[2], Schedule::SellChunks { .. }), "{kinds:?}");

    // Per-matrix request vectors, allocated up front (request payloads
    // are the caller's; the contract under test is the engine's).
    let inputs: Vec<(usize, Vec<f64>)> = [row_id, tile_id, sell_id]
        .iter()
        .map(|&id| {
            let n = engine.registry.entry(id).csr.n_cols;
            (id, (0..n).map(|_| rng.gen_f64() - 0.5).collect())
        })
        .collect();

    let serve_round = |engine: &ServeEngine| {
        for (id, x) in &inputs {
            // Singleton dispatch and a coalesced 4-wide SpMM dispatch.
            engine.serve_batch(*id, &[x.as_slice()]).expect("singleton");
            engine
                .serve_batch(
                    *id,
                    &[x.as_slice(), x.as_slice(), x.as_slice(), x.as_slice()],
                )
                .expect("coalesced");
        }
    };

    // Warmup: grow every buffer to its steady-state size — scratch
    // arenas (output, packed-x, carries), the engine's scratch pool,
    // telemetry's histogram/per-matrix/per-schedule keys.
    for _ in 0..8 {
        serve_round(&engine);
    }

    // Steady state: not one heap allocation across 40 more rounds
    // (240 dispatches, 600 served requests) — with tracing enabled.
    let allocs_before = total_allocs();
    let spans_before =
        engine.trace().map(|r| r.spans_recorded()).unwrap_or(0);
    for _ in 0..40 {
        serve_round(&engine);
    }
    let delta = total_allocs() - allocs_before;
    assert_eq!(
        delta, 0,
        "pooled steady-state serving (tracing on) must be \
         allocation-free, observed {delta} allocations over 240 \
         dispatches"
    );
    let spans = engine
        .trace()
        .map(|r| r.spans_recorded())
        .unwrap_or(0)
        .saturating_sub(spans_before);
    assert!(
        spans >= 240,
        "the recorder must have been live during the measured window \
         (saw {spans} new spans), else the zero-alloc claim is vacuous"
    );

    // The telemetry still recorded everything while allocation-free.
    let stats = engine.telemetry.snapshot();
    assert_eq!(stats.requests, 48 * 3 * 5);
    assert_eq!(stats.batches, 48 * 3 * 2);

    // So did the scalability profiler: every dispatch attributed its
    // gap-to-linear components without leaving the zero-alloc budget,
    // and the accounting stayed internally consistent.
    let totals = engine.scaling().totals();
    assert_eq!(
        totals.batches,
        (48 * 3 * 2) as u64,
        "the scaling profiler must observe every steady-state dispatch"
    );
    assert!(
        (totals.gap_s
            - (totals.imbalance_s + totals.overhead_s + totals.residual_s))
            .abs()
            <= 1e-9 * totals.gap_s.abs().max(1e-12),
        "gap components must sum to the observed gap"
    );

    // And so did the health ledger, without leaving the budget: every
    // dispatch charged the Full rung of the degraded-mode ladder, and
    // the slow-lane EWMA detector observed the pool's lanes (the
    // snapshot itself allocates — which is why it is read only here,
    // outside the measured window).
    use ft2000_spmv::util::json::Json;
    let health = engine.health_snapshot();
    assert_eq!(
        health.get("schema").and_then(Json::as_str),
        Some("ft2000.health.v1")
    );
    assert_eq!(
        health
            .get("mode")
            .and_then(|m| m.get("current"))
            .and_then(Json::as_str),
        Some("full"),
        "a healthy run must end on the Full rung"
    );
    let dwell_full = health
        .get("mode")
        .and_then(|m| m.get("dwell"))
        .and_then(|d| d.get("full"))
        .and_then(Json::as_f64)
        .unwrap_or(0.0);
    assert_eq!(
        dwell_full as u64, totals.batches,
        "the ladder must be consulted on every dispatch"
    );
    assert!(
        !health
            .get("lanes")
            .and_then(Json::as_arr)
            .map(|l| l.is_empty())
            .unwrap_or(true),
        "the slow-lane detector must have observed the pool's lanes"
    );
}
