//! Integration tests of the serving subsystem: registry + plan cache
//! + batched executor + replay harness, end to end.

use std::sync::Arc;

use ft2000_spmv::autotune::{AutotuneConfig, Policy};
use ft2000_spmv::corpus::suite::SuiteSpec;
use ft2000_spmv::corpus::NamedMatrix;
use ft2000_spmv::service::{
    build_plan, replay, replay_sharded, Arrivals, MatrixRegistry,
    PlacementPolicy, PlanConfig, Planner, Popularity, ReplayConfig, Request,
    ServeEngine, ShardConfig, ShardedServer, WorkloadSpec,
};
use ft2000_spmv::sparse::mm;
use ft2000_spmv::util::json;

fn tiny_engine(planner: Planner) -> (ServeEngine, Vec<usize>) {
    let mut reg = MatrixRegistry::new();
    let ids = reg.register_suite(&SuiteSpec::tiny(), Some(9));
    (ServeEngine::new(reg, planner, PlanConfig::default()), ids)
}

#[test]
fn replay_zipf_open_loop_end_to_end() {
    let (engine, ids) = tiny_engine(Planner::Heuristic);
    let spec = WorkloadSpec {
        requests: 500,
        popularity: Popularity::Zipf { s: 1.2 },
        arrivals: Arrivals::Open { rate: 10_000.0 },
        seed: 0x5EED_2019,
    };
    let report =
        replay(&engine, &ids, &spec, &ReplayConfig::default()).unwrap();
    assert_eq!(report.stats.requests, 500);
    assert_eq!(report.stats.latencies_ms.len(), 500);
    assert!(
        report.hit_rate() > 0.0,
        "repeated matrices must hit the plan cache"
    );
    assert!(
        report.cache_misses as usize <= ids.len(),
        "at most one plan build per matrix"
    );
    assert!(report.throughput_rps() > 0.0);
    let (p50, p99) = (
        report.stats.latency_percentile(50.0),
        report.stats.latency_percentile(99.0),
    );
    assert!(p50 > 0.0 && p99 >= p50, "p50={p50} p99={p99}");
    assert!(report.stats.executed_gflops() > 0.0, "kernels must really run");

    // The JSON report parses with our own parser and round-trips the
    // headline numbers.
    let text = report.to_json().to_string();
    let parsed = json::parse(&text).unwrap();
    assert_eq!(parsed.get("requests").unwrap().as_usize(), Some(500));
    assert!(parsed.get("latency_ms").unwrap().get("p99").is_some());
    assert_eq!(
        parsed.get("cache_misses").unwrap().as_usize(),
        Some(report.cache_misses as usize)
    );
}

#[test]
fn replay_bursty_coalesces() {
    let (engine, ids) = tiny_engine(Planner::Heuristic);
    let spec = WorkloadSpec {
        requests: 400,
        popularity: Popularity::Zipf { s: 1.5 },
        arrivals: Arrivals::Bursty {
            rate: 5_000.0,
            burst: 10.0,
            period_s: 0.05,
            duty: 0.3,
        },
        seed: 0xB0B0,
    };
    let report =
        replay(&engine, &ids, &spec, &ReplayConfig::default()).unwrap();
    assert_eq!(report.stats.requests, 400);
    assert!(
        report.stats.mean_batch() > 1.1,
        "bursts against a busy server must coalesce: {}",
        report.stats.mean_batch()
    );
    assert!(!report.stats.batch_hist.is_empty());
}

#[test]
fn learned_planner_is_deterministic_and_correct() {
    let spec = SuiteSpec::tiny();
    let a = Planner::train(&spec);
    let b = Planner::train(&spec);
    for m in NamedMatrix::ALL {
        let csr = m.generate();
        let pa = build_plan(&a, &PlanConfig::default(), &csr);
        let pb = build_plan(&b, &PlanConfig::default(), &csr);
        assert_eq!(
            pa.schedule,
            pb.schedule,
            "training must be deterministic ({})",
            m.name()
        );
    }
    // A learned plan must still compute the right answer on the
    // imbalance pathology.
    let csr = NamedMatrix::Exdata1.generate();
    let plan = build_plan(&a, &PlanConfig::default(), &csr);
    let x: Vec<f64> = (0..csr.n_cols).map(|i| (i % 7) as f64).collect();
    let mut want = vec![0.0; csr.n_rows];
    csr.spmv(&x, &mut want);
    let got = plan.execute(&csr, &x);
    for (i, (p, q)) in want.iter().zip(&got.y).enumerate() {
        assert!(
            (p - q).abs() < 1e-9 * (1.0 + p.abs()),
            "row {i}: {p} vs {q} under {:?}",
            plan.schedule
        );
    }
}

#[test]
fn registry_serves_matrixmarket_files() {
    let dir = std::env::temp_dir().join("ft2000_service_mtx_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("m.mtx");
    let csr = NamedMatrix::Debr.generate();
    {
        let mut f =
            std::io::BufWriter::new(std::fs::File::create(&path).unwrap());
        mm::write_csr(&mut f, &csr).unwrap();
    }
    let mut reg = MatrixRegistry::new();
    let id = reg.register_mtx(path.to_str().unwrap()).unwrap();
    assert_eq!(reg.entry(id).csr.nnz(), csr.nnz());
    // Same content registered from memory deduplicates onto the same
    // fingerprint entry.
    let id2 = reg.register("debr-in-memory", csr.clone());
    assert_eq!(id, id2);

    let engine =
        ServeEngine::new(reg, Planner::Heuristic, PlanConfig::default());
    let x = vec![1.0; csr.n_cols];
    let out = engine
        .execute_batch(id, &[x.as_slice(), x.as_slice()])
        .unwrap();
    let mut want = vec![0.0; csr.n_rows];
    csr.spmv(&x, &mut want);
    for y in &out.ys {
        for (i, (p, q)) in want.iter().zip(y).enumerate() {
            assert!(
                (p - q).abs() < 1e-9 * (1.0 + p.abs()),
                "row {i}: {p} vs {q}"
            );
        }
    }
    assert!(reg_missing_errors());
}

fn reg_missing_errors() -> bool {
    MatrixRegistry::new()
        .register_mtx("/nonexistent/path/m.mtx")
        .is_err()
}

#[test]
fn tuned_replay_converges_on_the_quick_corpus() {
    // The PR's acceptance path: `replay --tune` over the Zipf quick
    // corpus. Closed-loop with one client keeps every dispatch a
    // singleton, so arm observations measure the cost model's thread
    // knee exactly and the whole run is deterministic.
    let spec = WorkloadSpec {
        requests: 1200,
        popularity: Popularity::Zipf { s: 1.2 },
        arrivals: Arrivals::Closed { clients: 1 },
        seed: 0x7E57_5EED,
    };
    let cfg = ReplayConfig { execute: false, ..ReplayConfig::default() };
    let tune = AutotuneConfig {
        policy: Policy::EpsilonGreedy { epsilon: 0.05 },
        wall_clock: false,
        ..AutotuneConfig::default()
    };

    let (static_engine, ids) = tiny_engine(Planner::Heuristic);
    let static_report = replay(&static_engine, &ids, &spec, &cfg).unwrap();
    assert!(static_report.autotune.is_none(), "untuned runs don't report");

    let (engine, ids) = tiny_engine(Planner::Heuristic);
    let engine = engine.with_tuner(tune);
    let report = replay(&engine, &ids, &spec, &cfg).unwrap();
    assert_eq!(report.stats.requests, 1200);

    let summaries = report.autotune.as_ref().expect("tuned runs report");
    assert!(!summaries.is_empty());
    // Convergence: for at least one matrix the tuner's chosen thread
    // count differs from the static planner's pick...
    let diverged: Vec<_> = summaries
        .iter()
        .filter(|s| {
            s.chosen_variant.n_threads != s.static_variant.n_threads
        })
        .collect();
    assert!(
        !diverged.is_empty(),
        "no matrix tuned away from the static width: {summaries:?}"
    );
    // ...and its measured mean latency is no worse than the static
    // plan's (promotion demands a strict gain, so this is strict).
    for s in &diverged {
        assert!(
            s.chosen_mean_ms <= s.static_mean_ms,
            "{}: tuned {} ms vs static {} ms",
            s.name,
            s.chosen_mean_ms,
            s.static_mean_ms
        );
    }
    let promotions: u64 = summaries.iter().map(|s| s.promotions).sum();
    assert!(promotions >= 1, "at least one promotion must occur");
    // Promotions really landed in the serving plan cache (versioned
    // replace), so untuned lookups now serve the winner too.
    assert!(
        engine.plans.replacements() >= 1,
        "promotion must install into the plan cache"
    );
    // End to end, tuning must not lose to the static baseline (small
    // exploration tax allowed, converged gain should dominate).
    assert!(
        report.throughput_rps() >= 0.98 * static_report.throughput_rps(),
        "tuned {} req/s vs static {} req/s",
        report.throughput_rps(),
        static_report.throughput_rps()
    );
    // Observations accumulated for offline-planner retraining.
    let tuner = engine.tuner().unwrap();
    assert_eq!(tuner.dataset().len(), report.stats.batches as usize);
    // And the run is reproducible end to end.
    let (engine2, ids2) = tiny_engine(Planner::Heuristic);
    let engine2 = engine2.with_tuner(tune);
    let report2 = replay(&engine2, &ids2, &spec, &cfg).unwrap();
    assert_eq!(
        report.duration_s.to_bits(),
        report2.duration_s.to_bits(),
        "tuned replay must be bit-reproducible"
    );
}

#[test]
fn sharded_server_survives_poison_and_reports_per_shard() {
    // The serve-bench acceptance path end to end: suite corpus, 8
    // shards, Zipf traffic with one poison request (unregistered id)
    // mixed in. The run must finish, count the poison as an error,
    // and produce per-shard streaming-percentile telemetry.
    let mut reg = MatrixRegistry::new();
    let ids = reg.register_suite(&SuiteSpec::tiny(), Some(9));
    let registry = Arc::new(reg);
    let wl = WorkloadSpec {
        requests: 300,
        popularity: Popularity::Zipf { s: 1.2 },
        arrivals: Arrivals::Closed { clients: 4 },
        seed: 0xFEED,
    };
    let seq = wl.generate(ids.len());
    let weights = wl.popularity.placement_weights(&ids, registry.len());
    let server = ShardedServer::with_weights(
        registry.clone(),
        Planner::Heuristic,
        PlanConfig::default(),
        ShardConfig {
            shards: 8,
            queue_cap: 0,
            workers_per_shard: 1,
            max_batch: 16,
            deadline_ms: 0.0,
            policy: PlacementPolicy::HotReplicate { hot: 2 },
            pooled: true,
            tune: None,
            trace: None,
        },
        &weights,
    );
    let served = std::thread::scope(|s| {
        s.spawn(|| {
            for (i, r) in seq.iter().enumerate() {
                if i == 150 {
                    server.submit(Request::new(usize::MAX, vec![1.0; 4]));
                }
                let id = ids[r.matrix_idx];
                let n = registry.entry(id).csr.n_cols;
                server.submit(Request::new(id, vec![1.0; n]));
            }
            server.close();
        });
        server.serve()
    });
    assert_eq!(served, 300, "all valid requests served");
    // Pooled by default: every shard carries a persistent executor
    // pool pinned to its panel, and all kernel work ran on it.
    for shard in &server.shards {
        let pool = shard.engine.pool().expect("shards are pooled");
        assert_eq!(pool.cores(), Some(shard.cores));
    }
    let jobs: u64 = server
        .shards
        .iter()
        .map(|s| s.engine.pool().unwrap().jobs_dispatched())
        .sum();
    assert!(jobs > 0, "dispatches must run on the shard pools");
    let merged = server.merged_stats();
    assert_eq!(merged.requests, 300);
    assert!(
        !merged.per_schedule.is_empty(),
        "effective executed schedules must be recorded"
    );
    assert_eq!(merged.errors, 1, "poison counted, not fatal");
    assert_eq!(merged.rejected, 0, "unbounded queues reject nothing");
    assert_eq!(merged.digest.count, 300);
    assert!(merged.latency_percentile(99.0) >= merged.latency_percentile(50.0));
    // The hot head is replicated; at least half the shards served it.
    let hot_id = ids[0];
    assert!(server.placement.is_replicated(hot_id));
    let snaps = server.snapshots(1.0);
    assert_eq!(snaps.len(), 8);
    let shards_with_head = snaps
        .iter()
        .filter(|s| s.stats.per_matrix.contains_key(&hot_id))
        .count();
    assert!(shards_with_head >= 4, "head on {shards_with_head}/8 shards");
    // Each shard owns one modeled panel of 8 cores.
    for s in &snaps {
        assert_eq!(s.cores.1 - s.cores.0, 8);
    }
    // Per-shard plan caches build at most one plan per matrix.
    let (_, misses) = server.cache_totals();
    assert!(misses <= (ids.len() * 8) as u64);
    // The shard table renders without NaN.
    let md = ft2000_spmv::service::telemetry::shard_table(&snaps)
        .to_markdown();
    assert!(!md.contains("NaN"), "{md}");
}

#[test]
fn sharded_replay_matches_global_request_totals() {
    // A/B harness invariant: the same workload replayed through one
    // global virtual server and through 8 virtual panels serves the
    // same request population (routing must lose nothing).
    let spec = WorkloadSpec {
        requests: 600,
        popularity: Popularity::Zipf { s: 1.2 },
        arrivals: Arrivals::Open { rate: 20_000.0 },
        seed: 0x5EED_2019,
    };
    let cfg = ReplayConfig { execute: false, ..ReplayConfig::default() };

    let (engine, ids) = tiny_engine(Planner::Heuristic);
    let global = replay(&engine, &ids, &spec, &cfg).unwrap();
    assert_eq!(global.stats.requests, 600);

    let mut reg = MatrixRegistry::new();
    let ids = reg.register_suite(&SuiteSpec::tiny(), Some(9));
    let sharded = replay_sharded(
        Arc::new(reg),
        &Planner::Heuristic,
        &PlanConfig::default(),
        &ids,
        &spec,
        &cfg,
        8,
        PlacementPolicy::HotReplicate { hot: 2 },
    )
    .unwrap();
    let merged = sharded.merged();
    assert_eq!(merged.stats.requests, 600);
    assert_eq!(merged.stats.rejected, 0);
    assert!(sharded.duration_s > 0.0 && global.duration_s > 0.0);
    // Every shard's own timeline ends no later than the fleet
    // makespan, and the fleet served the same population the global
    // server did — the A/B compares like with like.
    for r in &sharded.shards {
        assert!(r.duration_s <= sharded.duration_s);
    }
    assert_eq!(merged.stats.requests, global.stats.requests);
    // JSON report carries per-shard entries.
    let j = sharded.to_json();
    assert_eq!(j.get("shards").unwrap().as_arr().map(|a| a.len()), Some(8));
    assert_eq!(j.get("requests").unwrap().as_usize(), Some(600));
}
