//! Resilience acceptance (the PR-10 tentpole contract): the seeded
//! chaos sweep replays bit-identically through the public API and
//! leaves the fleet healthy; shard failover re-homes traffic onto
//! survivors as counted graceful outcomes; bounded re-admission
//! spends exactly its budget; corrupt MatrixMarket payloads — seeded
//! mutations of a valid file — are counted rejections, never panics;
//! and the `ft2000.health.v1` document carries exactly its documented
//! key set (a golden-schema pin like `ft2000.metrics.v1`'s).

use std::sync::Arc;

use ft2000_spmv::corpus::suite::SuiteSpec;
use ft2000_spmv::resil::{chaos, ChaosConfig, DegradedMode, HEALTH_SCHEMA};
use ft2000_spmv::service::{
    MatrixRegistry, PlacementPolicy, PlanConfig, Planner, Request,
    ShardConfig, ShardedServer,
};
use ft2000_spmv::util::json::{parse, Json};
use ft2000_spmv::util::rng::Pcg32;

fn small_chaos() -> ChaosConfig {
    ChaosConfig {
        scenarios: 2,
        requests: 28,
        matrices: 3,
        shards: 2,
        faults: 3,
        ..ChaosConfig::default()
    }
}

/// Same seed, same fault schedule, same health evidence — the chaos
/// sweep is an experiment, so its output must be a pure function of
/// its configuration; and a clean sweep means every injected fault
/// ended as a counted graceful outcome.
#[test]
fn chaos_sweep_is_clean_and_replays_bit_identically() {
    if cfg!(miri) {
        return;
    }
    let cfg = small_chaos();
    let a = chaos::run(&cfg);
    assert!(
        a.report.is_clean(),
        "chaos sweep must pass: {:?}",
        a.report.findings
    );
    assert!(a.submitted > 0, "the sweep must drive traffic");
    let b = chaos::run(&cfg);
    assert_eq!(
        a.health.to_string(),
        b.health.to_string(),
        "same seed must replay to byte-identical health evidence"
    );
    assert_eq!(a.submitted, b.submitted);
}

/// `--canary` drops one deliberate shed from the ledger: the sweep
/// must catch its own instrumentation lying (proves the gate can
/// fail, so a green run means something).
#[test]
fn chaos_canary_is_caught() {
    if cfg!(miri) {
        return;
    }
    let cfg = ChaosConfig { canary: true, scenarios: 1, ..small_chaos() };
    let out = chaos::run(&cfg);
    assert!(!out.report.is_clean(), "the canary must be detected");
    assert!(
        out.report.findings.iter().any(|f| f.invariant == "request-ledger"),
        "the dropped shed must surface as a ledger finding: {:?}",
        out.report.findings
    );
}

/// The exact key set of a JSON object, for golden-schema pins.
fn keys(doc: &Json) -> Vec<&str> {
    doc.as_obj()
        .expect("object node")
        .keys()
        .map(String::as_str)
        .collect()
}

/// Golden schema: `ft2000.health.v1` — the document `obs-report
/// --health-baseline/--health-current` diffs — carries exactly the
/// documented keys at every level. A key appearing or vanishing here
/// is a consumer-visible schema change and must bump the version
/// string instead.
#[test]
fn health_snapshot_golden_keys() {
    if cfg!(miri) {
        return;
    }
    // A chaos scenario exercises every counter the snapshot reports.
    let out = chaos::run(&ChaosConfig { scenarios: 1, ..small_chaos() });
    let snap = parse(&out.health.to_string()).expect("snapshot parses");
    assert_eq!(
        snap.get("schema").and_then(Json::as_str),
        Some(HEALTH_SCHEMA)
    );
    assert_eq!(
        keys(&snap),
        ["injected", "lanes", "mode", "outcomes", "recovery_ms", "schema"]
    );
    assert_eq!(
        keys(snap.get("injected").unwrap()),
        [
            "corrupt_payload",
            "lane_slow",
            "lane_stall",
            "queue_spike",
            "shard_flap",
            "shard_outage",
            "worker_panic",
        ]
    );
    assert_eq!(
        keys(snap.get("outcomes").unwrap()),
        [
            "degraded_dispatches",
            "failed_over",
            "panics_contained",
            "rejected",
            "rejected_corrupt",
            "retried",
            "sequential_dispatches",
            "served_ok",
            "shed",
            "slow_lane_marks",
            "tuner_suppressed",
        ]
    );
    assert_eq!(keys(snap.get("mode").unwrap()), ["current", "dwell"]);
    assert_eq!(
        keys(snap.get("mode").unwrap().get("dwell").unwrap()),
        ["full", "reduced_lanes", "sequential"]
    );
    assert_eq!(
        keys(snap.get("recovery_ms").unwrap()),
        ["count", "max_ms", "mean_ms", "p50_ms", "p95_ms"]
    );
    let lanes = snap.get("lanes").and_then(Json::as_arr).unwrap();
    assert!(!lanes.is_empty(), "chaos must feed the slow-lane EWMA");
    for lane in lanes {
        assert_eq!(keys(lane), ["ewma_share", "lane"]);
    }
    // The sweep injected every fault kind at least once (scenario 0
    // is scripted to cover the full matrix).
    for k in keys(snap.get("injected").unwrap()) {
        let n = snap
            .get("injected")
            .and_then(|i| i.get(k))
            .and_then(Json::as_f64)
            .unwrap();
        assert!(n >= 1.0, "fault kind {k} never injected");
    }
}

fn sharded(shards: usize, queue_cap: usize) -> ShardedServer {
    let mut reg = MatrixRegistry::new();
    reg.register_suite(&SuiteSpec::tiny(), Some(4));
    ShardedServer::new(
        Arc::new(reg),
        Planner::Heuristic,
        PlanConfig::default(),
        ShardConfig {
            shards,
            queue_cap,
            workers_per_shard: 1,
            pooled: false,
            // Every matrix homed: failover counts are deterministic.
            policy: PlacementPolicy::Home,
            ..ShardConfig::default()
        },
    )
}

/// A dark shard's traffic re-homes onto survivors (counted
/// failovers, ladder escalated); an all-dark fleet rejects instead of
/// wedging; recovery sends traffic home and returns the ladder to
/// `Full`.
#[test]
fn shard_outage_fails_over_and_recovers() {
    if cfg!(miri) {
        return;
    }
    let server = sharded(2, 64);
    let n_cols: Vec<usize> = (0..4)
        .map(|id| server.registry().entry(id).csr.n_cols)
        .collect();

    // Healthy: every admission lands on its home shard.
    for id in 0..4 {
        let admitted =
            server.submit(Request::new(id, vec![1.0; n_cols[id]]));
        assert!(!admitted.is_rejected());
    }
    assert_eq!(server.health().totals().failed_over, 0);
    assert_eq!(server.health().mode(), DegradedMode::Full);

    // Shard 0 goes dark: its matrices re-home (one counted failover
    // each), admissions only land on shard 1, the ladder escalates.
    server.set_shard_down(0, true);
    assert!(server.is_shard_down(0));
    let planned = server.health().totals().failed_over;
    assert!(planned > 0, "a dark shard must re-home its matrices");
    assert_eq!(server.health().mode(), DegradedMode::ReducedLanes);
    for id in 0..4 {
        match server.submit(Request::new(id, vec![1.0; n_cols[id]])) {
            ft2000_spmv::service::Admitted::Shard(s) => {
                assert_eq!(s, 1, "matrix {id} routed to the dark shard")
            }
            other => panic!("matrix {id} not admitted: {other:?}"),
        }
    }
    // Dark-period admissions of re-homed matrices count as failovers
    // too; healthy admissions after recovery must not.
    let during = server.health().totals().failed_over;
    assert!(during >= planned);

    // The whole fleet dark: counted rejections, not a hang or panic.
    server.set_shard_down(1, true);
    let admitted = server.submit(Request::new(0, vec![1.0; n_cols[0]]));
    assert!(admitted.is_rejected(), "all-dark must reject");
    assert!(server.health().totals().rejected >= 1);

    // Recovery: overrides clear, traffic goes home, ladder recovers.
    server.set_shard_down(0, false);
    server.set_shard_down(1, false);
    assert_eq!(server.health().mode(), DegradedMode::Full);
    for id in 0..4 {
        let admitted =
            server.submit(Request::new(id, vec![1.0; n_cols[id]]));
        assert!(!admitted.is_rejected());
    }
    assert_eq!(
        server.health().totals().failed_over,
        during,
        "healthy admissions must not count failovers"
    );

    // Everything admitted drains and serves after the episode.
    server.close();
    let served = server.serve();
    assert_eq!(served, 12, "all admitted requests must be served");

    // The fleet roll-up is a valid health document.
    let snap = server.health_snapshot();
    assert_eq!(
        snap.get("schema").and_then(Json::as_str),
        Some(HEALTH_SCHEMA)
    );
}

/// `submit_with_retry` spends exactly its budget against a full
/// queue — every attempt a counted retry, overload still winning.
#[test]
fn retry_budget_is_bounded_and_counted() {
    if cfg!(miri) {
        return;
    }
    let server = sharded(1, 1);
    let n = server.registry().entry(0).csr.n_cols;
    // Fill the single admission slot; no workers are draining.
    assert!(!server.submit(Request::new(0, vec![1.0; n])).is_rejected());

    let admitted =
        server.submit_with_retry(Request::new(0, vec![1.0; n]), 3);
    assert!(admitted.is_rejected(), "overload must win past the budget");
    assert_eq!(
        server.health().totals().retried,
        3,
        "every re-admission attempt must be counted"
    );

    // Zero budget means plain submit: no retries counted.
    let admitted =
        server.submit_with_retry(Request::new(0, vec![1.0; n]), 0);
    assert!(admitted.is_rejected());
    assert_eq!(server.health().totals().retried, 3);

    server.close();
    assert_eq!(server.serve(), 1);
}

/// A valid MatrixMarket payload for mutation: 4x4, 5 entries.
const VALID_MTX: &str = "%%MatrixMarket matrix coordinate real general\n\
     4 4 5\n\
     1 1 2.0\n\
     2 3 -1.5\n\
     3 1 4.0\n\
     3 3 1.0\n\
     4 2 0.5\n";

/// Seeded corpus mutations through the admission seam: every corrupt
/// payload is a counted rejection (`MatrixRegistry::rejected`), the
/// registry never grows from one, and nothing panics. Covers the
/// structured failure modes explicitly plus seeded random
/// truncations/splices for the long tail.
#[test]
fn corrupt_mtx_payloads_are_counted_rejections() {
    let mut reg = MatrixRegistry::new();
    let ok = reg.register_mtx_reader("valid", VALID_MTX.as_bytes());
    assert!(ok.is_ok(), "the unmutated payload must admit");
    assert_eq!(reg.rejected(), 0);
    let len_before = reg.len();

    // Structured mutations: one per parser defense.
    let structured = [
        // Non-finite value.
        VALID_MTX.replace("-1.5", "NaN"),
        // Out-of-range (1-based) coordinate.
        VALID_MTX.replace("4 2 0.5", "5 2 0.5"),
        // Zero (0-based) coordinate.
        VALID_MTX.replace("1 1 2.0", "0 1 2.0"),
        // Duplicate coordinate.
        VALID_MTX.replace("3 3 1.0", "1 1 1.0"),
        // Truncated: fewer entries than declared.
        VALID_MTX.replace("4 2 0.5\n", ""),
        // Oversized declaration: nnz past the matrix capacity.
        VALID_MTX.replace("4 4 5", "4 4 99"),
        // Dimension overflow.
        VALID_MTX
            .replace("4 4 5", "18446744073709551615 18446744073709551615 1"),
        // Wrong header.
        VALID_MTX.replace("coordinate", "array"),
        // Unsupported field type.
        VALID_MTX.replace(" real ", " complex "),
        // Garbage value token.
        VALID_MTX.replace("2.0", "2.O"),
        // Empty payload.
        String::new(),
    ];
    for (i, bad) in structured.iter().enumerate() {
        let res = reg.register_mtx_reader("mutant", bad.as_bytes());
        assert!(res.is_err(), "structured mutation {i} must be rejected");
        assert_eq!(
            reg.rejected(),
            i + 1,
            "mutation {i} must be a *counted* rejection"
        );
    }

    // Seeded random mutations: truncate at an arbitrary byte, or
    // splice a garbage byte in. Some splices still parse (e.g. a
    // digit replacing a digit) — the contract under test is "Err or
    // Ok, never a panic; every Err counted".
    let mut rng = Pcg32::new(0x5EED_F00D);
    let mut rejected = reg.rejected();
    let mut admitted_fuzz = 0;
    for _ in 0..64 {
        let mut bytes = VALID_MTX.as_bytes().to_vec();
        let cut = 1 + rng.gen_range(bytes.len() - 1);
        if rng.gen_range(2) == 0 {
            bytes.truncate(cut);
        } else {
            bytes[cut] = (rng.next_u64() % 256) as u8;
        }
        match reg.register_mtx_reader("fuzz", &bytes[..]) {
            Ok(_) => admitted_fuzz += 1,
            Err(_) => {
                rejected += 1;
                assert_eq!(reg.rejected(), rejected);
            }
        }
    }
    assert_eq!(
        reg.len(),
        len_before + admitted_fuzz,
        "rejected payloads must never register"
    );
    assert!(
        reg.rejected() >= structured.len(),
        "the structured mutations alone must all be counted"
    );
}
