//! Fig 8 + §5.2.2 — shared vs private L2 placement.
//!
//! Paper: conf5_4-8x8-20 improves 1.35x -> 3.61x with private L2
//! (L2 miss rate 30% -> 25%); asia_osm barely improves (3.170x ->
//! 3.254x, +2.6%) because nnz_avg < 3; the corpus average improves
//! 1.93x -> 3.40x.

mod common;

use ft2000_spmv::coordinator::{profile_matrix, Campaign, ProfileConfig};
use ft2000_spmv::corpus::NamedMatrix;
use ft2000_spmv::util::stats;
use ft2000_spmv::util::table::Table;

fn main() {
    common::banner("Fig 8", "SpMV scalability with shared vs private L2 caches");
    let group = ProfileConfig::default();
    let private = ProfileConfig::private_l2();

    let mut t = Table::new(
        "Fig 8 — 4-thread speedup: one core-group vs private L2",
        &["matrix", "shared L2", "private L2", "paper"],
    );
    for (named, paper) in [
        (NamedMatrix::Conf5_4_8x8_20, "1.35x -> 3.61x"),
        (NamedMatrix::AsiaOsm, "3.170x -> 3.254x"),
        (NamedMatrix::Debr, "(not reported)"),
    ] {
        let csr = named.generate();
        let g = profile_matrix(&csr, named.name(), &group);
        let p = profile_matrix(&csr, named.name(), &private);
        t.row(vec![
            named.name().to_string(),
            format!("{:.3}x", g.max_speedup()),
            format!("{:.3}x", p.max_speedup()),
            paper.to_string(),
        ]);
    }
    t.print();

    let suite = common::suite_from_env();
    eprintln!("corpus averages over {} matrices...", suite.total());
    let g_avg = stats::mean(
        &Campaign::new(suite.clone(), group)
            .run()
            .iter()
            .map(|p| p.max_speedup())
            .collect::<Vec<_>>(),
    );
    let p_avg = stats::mean(
        &Campaign::new(suite, private)
            .run()
            .iter()
            .map(|p| p.max_speedup())
            .collect::<Vec<_>>(),
    );
    println!(
        "\ncorpus average 4-thread speedup: {g_avg:.3}x (shared) -> {p_avg:.3}x (private)   (paper: 1.93x -> 3.40x)"
    );
}
