//! Format/schedule shoot-out + learned selector evaluation.
//!
//! For every structural class: which schedule wins on the simulated
//! FT-2000+ core-group, and how close does the static-feature
//! classifier (the paper's future-work "decide whether to apply these
//! optimizations" tool) get to the oracle?

mod common;

use std::collections::HashMap;

use ft2000_spmv::coordinator::format_select::{
    candidates, label_matrix, FormatSelector,
};
use ft2000_spmv::util::table::Table;

fn main() {
    let suite = common::suite_from_env();
    common::banner(
        "Format shoot-out",
        "per-class schedule winners + learned selector (future work, §5.2.3)",
    );
    eprintln!("labeling {} matrices (3 schedules each)...", suite.total());
    let entries = suite.entries();
    let samples: Vec<_> = entries
        .iter()
        .map(|e| {
            let m = suite.materialize(e);
            (e.class, label_matrix(&m.csr, &e.name))
        })
        .collect();

    // Per-class winner counts.
    let mut per_class: HashMap<&str, Vec<usize>> = HashMap::new();
    for (class, s) in &samples {
        per_class
            .entry(class.name())
            .or_insert_with(|| vec![0; candidates().len()])
            [s.best] += 1;
    }
    let mut t = Table::new(
        "Winning schedule by structural class (4 threads, one core-group)",
        &["class", "csr-static", "csr-balanced", "csr5-t256"],
    );
    let mut classes: Vec<_> = per_class.iter().collect();
    classes.sort_by_key(|(name, _)| *name);
    for (name, wins) in classes {
        t.row(vec![
            name.to_string(),
            wins[0].to_string(),
            wins[1].to_string(),
            wins[2].to_string(),
        ]);
    }
    t.print();

    // Train/test split for the selector.
    let n = samples.len();
    let cut = n * 8 / 10;
    let train: Vec<_> =
        samples[..cut].iter().map(|(_, s)| s.clone()).collect();
    let test: Vec<_> = samples[cut..].iter().map(|(_, s)| s.clone()).collect();
    let sel = FormatSelector::train(&train);
    let (acc_tr, ratio_tr) = sel.evaluate(&train);
    let (acc_te, ratio_te) = sel.evaluate(&test);
    let static_ratio = |xs: &[ft2000_spmv::coordinator::format_select::LabeledMatrix]| {
        xs.iter().map(|s| s.seconds[s.best] / s.seconds[0]).sum::<f64>()
            / xs.len().max(1) as f64
    };
    let mut t = Table::new(
        "Learned selector (static pre-run features only)",
        &["metric", "train", "held-out"],
    );
    t.row(vec![
        "label accuracy".into(),
        format!("{:.1}%", acc_tr * 100.0),
        format!("{:.1}%", acc_te * 100.0),
    ]);
    t.row(vec![
        "achieved/oracle perf".into(),
        format!("{:.1}%", ratio_tr * 100.0),
        format!("{:.1}%", ratio_te * 100.0),
    ]);
    t.row(vec![
        "always-CSR-static baseline".into(),
        format!("{:.1}%", static_ratio(&train) * 100.0),
        format!("{:.1}%", static_ratio(&test) * 100.0),
    ]);
    t.print();
}
