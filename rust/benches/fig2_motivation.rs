//! Fig 2 — motivation: bone010 CSR SpMV, 1–16 threads, Intel Xeon
//! E5-2692 vs Phytium FT-2000+.
//!
//! Paper shape: Xeon rises ~linearly to 4 threads then flattens
//! (memory bus saturates); FT-2000+ starts lower, rises only slightly
//! inside the first core-group, then climbs quasi-linearly to 16
//! threads as more core-groups (each with its own L2 + DCU share)
//! come online.

mod common;

use ft2000_spmv::coordinator::{profile_matrix, ProfileConfig};
use ft2000_spmv::corpus::NamedMatrix;
use ft2000_spmv::sim::topology::{Placement, Topology};
use ft2000_spmv::util::table::{series, Table};

fn main() {
    common::banner(
        "Fig 2",
        "SpMV performance (Gflops) of bone010, 1-16 threads, Xeon vs FT-2000+",
    );
    let csr = NamedMatrix::Bone010.generate();
    let threads: Vec<usize> = vec![1, 2, 4, 8, 12, 16];
    let mut table = Table::new(
        "Fig 2 — bone010 SpMV Gflops by thread count",
        &["threads", "Xeon E5-2692", "FT-2000+"],
    );
    let mut xeon_pts = Vec::new();
    let mut ft_pts = Vec::new();
    let xeon_cfg = ProfileConfig {
        topo: Topology::xeon_e5_2692(),
        threads: threads.clone(),
        ..Default::default()
    };
    let ft_cfg = ProfileConfig {
        topo: Topology::ft2000plus(),
        placement: Placement::CoreGroupFirst,
        threads: threads.clone(),
        ..Default::default()
    };
    let xeon = profile_matrix(&csr, "bone010", &xeon_cfg);
    let ft = profile_matrix(&csr, "bone010", &ft_cfg);
    for (i, nt) in threads.iter().enumerate() {
        table.row(vec![
            nt.to_string(),
            format!("{:.3}", xeon.gflops[i]),
            format!("{:.3}", ft.gflops[i]),
        ]);
        xeon_pts.push((*nt as f64, xeon.gflops[i]));
        ft_pts.push((*nt as f64, ft.gflops[i]));
    }
    table.print();
    println!("{}", series("xeon", &xeon_pts));
    println!("{}", series("ft2000+", &ft_pts));

    // Shape assertions the paper's narrative makes:
    let x4 = xeon.gflops[2];
    let x16 = xeon.gflops[5];
    println!(
        "\nXeon 4->16 thread gain: {:.1}% (paper: 'very slight')",
        100.0 * (x16 - x4) / x4
    );
    let f4 = ft.gflops[2];
    let f16 = ft.gflops[5];
    println!(
        "FT-2000+ 4->16 thread gain: {:.1}% (paper: 'quasi-linear speedup')",
        100.0 * (f16 - f4) / f4
    );
    println!(
        "single-thread ratio Xeon/FT: {:.2}x (paper: Xeon clearly faster per core)",
        xeon.gflops[0] / ft.gflops[0]
    );
}
