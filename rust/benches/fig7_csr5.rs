//! Fig 7 — CSR vs CSR5 on exdata_1: job_var and speedup by thread
//! count, plus the corpus-level §5.2.1 check (CSR5 on every matrix
//! with job_var >= 0.45).
//!
//! Paper: on exdata_1 CSR5 drops job_var 0.992 -> 0.298 and lifts the
//! 4-thread speedup 1.018x -> 1.468x; over all imbalance-flagged
//! matrices the average improves 1.632x -> 2.023x.

mod common;

use ft2000_spmv::coordinator::{profile_matrix, Campaign, ProfileConfig};
use ft2000_spmv::sched::{partition, Schedule};
use ft2000_spmv::sparse::features::job_var;
use ft2000_spmv::corpus::NamedMatrix;
use ft2000_spmv::util::stats;
use ft2000_spmv::util::table::Table;

fn main() {
    common::banner("Fig 7", "job_var and speedup of exdata_1 in CSR vs CSR5");
    let csr = NamedMatrix::Exdata1.generate();
    let csr5_sched = Schedule::Csr5Tiles { tile_nnz: 256 };

    let jv_csr =
        job_var(&partition(&csr, Schedule::CsrRowStatic, 4).thread_nnz(&csr));
    let jv_csr5 = job_var(&partition(&csr, csr5_sched, 4).thread_nnz(&csr));

    let p_csr = profile_matrix(&csr, "exdata_1", &ProfileConfig::default());
    let p_csr5 = profile_matrix(
        &csr,
        "exdata_1",
        &ProfileConfig { schedule: csr5_sched, ..Default::default() },
    );

    let mut t = Table::new(
        "Fig 7 — exdata_1: CSR vs CSR5 (paper: job_var 0.992->0.298, speedup 1.018x->1.468x)",
        &["metric", "CSR", "CSR5"],
    );
    t.row(vec![
        "job_var (4t)".into(),
        format!("{jv_csr:.3}"),
        format!("{jv_csr5:.3}"),
    ]);
    for (i, nt) in p_csr.thread_counts.iter().enumerate() {
        t.row(vec![
            format!("speedup {nt}t"),
            format!("{:.3}x", p_csr.speedups[i]),
            format!("{:.3}x", p_csr5.speedups[i]),
        ]);
    }
    t.print();

    // Corpus-level: CSR5 on all imbalance-flagged matrices.
    let suite = common::suite_from_env();
    eprintln!("sweeping {} matrices for the flagged-set check...", suite.total());
    let base = Campaign::new(suite.clone(), ProfileConfig::default()).run();
    let entries = suite.entries();
    let mut before = Vec::new();
    let mut after = Vec::new();
    for (i, p) in base.iter().enumerate() {
        if p.derived.job_var >= 0.45 {
            let m = suite.materialize(&entries[i]);
            before.push(p.max_speedup());
            after.push(
                profile_matrix(
                    &m.csr,
                    &m.name,
                    &ProfileConfig { schedule: csr5_sched, ..Default::default() },
                )
                .max_speedup(),
            );
        }
    }
    println!(
        "\nCSR5 on the {} matrices with job_var >= 0.45:\n  average 4t speedup {:.3}x -> {:.3}x   (paper: 1.632x -> 2.023x)",
        before.len(),
        stats::mean(&before),
        stats::mean(&after)
    );
}
