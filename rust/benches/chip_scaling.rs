//! Chip-wide strong scaling: 1..64 threads over the whole FT-2000+
//! (the regime of Table 5's 64-thread runs and the tail of Fig 2).
//!
//! Expected shape: in-group flattening at 2-4 threads, a fresh slope
//! whenever a new core-group (every 4) or panel (every 8) comes
//! online, approaching the chip's aggregate-bandwidth roofline.

mod common;

use ft2000_spmv::coordinator::{profile_matrix, ProfileConfig};
use ft2000_spmv::corpus::NamedMatrix;
use ft2000_spmv::util::table::{series, Table};

fn main() {
    common::banner(
        "Chip scaling",
        "strong scaling to 64 threads (core-group-first placement)",
    );
    let threads = vec![1, 2, 4, 8, 16, 32, 64];
    let cfg = ProfileConfig { threads: threads.clone(), ..Default::default() };
    let mut t = Table::new(
        "Speedup by thread count (whole chip)",
        &["matrix", "4t", "8t", "16t", "32t", "64t"],
    );
    for named in [
        NamedMatrix::Bone010,
        NamedMatrix::Debr,
        NamedMatrix::Conf5_4_8x8_20,
        NamedMatrix::AsiaOsm,
    ] {
        let csr = named.generate();
        let p = profile_matrix(&csr, named.name(), &cfg);
        t.row(vec![
            named.name().to_string(),
            format!("{:.2}x", p.speedups[2]),
            format!("{:.2}x", p.speedups[3]),
            format!("{:.2}x", p.speedups[4]),
            format!("{:.2}x", p.speedups[5]),
            format!("{:.2}x", p.speedups[6]),
        ]);
        let pts: Vec<(f64, f64)> = threads
            .iter()
            .zip(&p.gflops)
            .map(|(&nt, &g)| (nt as f64, g))
            .collect();
        println!("{}", series(named.name(), &pts));
    }
    println!();
    t.print();
    println!(
        "(paper context: Table 5's synthesized workload reaches 37.96x at 64 \
         threads; asia_osm reaches ~46x-equivalent throughput after reordering)"
    );
}
