//! Table 4 — the four representative matrices: job_var,
//! L2_DCMR_change, nnz_var, and 4-thread speedup.
//!
//! Paper values:
//!   exdata_1        job_var 0.992, change  0.000, nnz_var 649.6, 1.018x
//!   conf5_4-8x8-20  job_var 0.250, change  0.056, nnz_var   0.0, 1.351x
//!   debr            job_var 0.250, change -0.001, nnz_var 0.003, 2.241x
//!   appu            job_var 0.252, change -0.001, nnz_var  36.5, 1.479x

mod common;

use ft2000_spmv::coordinator::{profile_matrix, ProfileConfig};
use ft2000_spmv::corpus::NamedMatrix;
use ft2000_spmv::util::table::Table;

fn main() {
    common::banner("Table 4", "concise description of four representative matrices");
    let paper: [(&str, f64, f64, f64, f64); 4] = [
        ("exdata_1", 0.992, 0.000, 649.627, 1.018),
        ("conf5_4-8x8-20", 0.250, 0.056, 0.000, 1.351),
        ("debr", 0.250, -0.001, 0.003, 2.241),
        ("appu", 0.252, -0.001, 36.494, 1.479),
    ];
    let mut t = Table::new(
        "Table 4 — representative matrices (ours vs paper)",
        &[
            "matrix",
            "job_var",
            "L2_DCMR_change",
            "nnz_var",
            "speedup",
            "paper speedup",
        ],
    );
    for (name, p_jv, _p_ch, p_nv, p_sp) in paper {
        let named = NamedMatrix::ALL
            .into_iter()
            .find(|m| m.name() == name)
            .expect("known name");
        let csr = named.generate();
        let prof = profile_matrix(&csr, name, &ProfileConfig::default());
        t.row(vec![
            name.to_string(),
            format!("{:.3} (paper {p_jv:.3})", prof.derived.job_var),
            format!("{:+.4}", prof.derived.l2_dcmr_change),
            format!("{:.3} (paper {p_nv:.3})", prof.features.nnz_var),
            format!("{:.3}x", prof.max_speedup()),
            format!("{p_sp:.3}x"),
        ]);
    }
    t.print();
    println!(
        "shape check: exdata_1 flat (imbalance), conf5/appu limited by shared-L2 \
         gather pressure, debr scales best of the four."
    );
}
