//! Fig 9 + Table 5 — the locality-aware storage format experiment.
//!
//! The synthesized matrix has 64*6400 rows with 4 nonzeros per row,
//! drawn from interleaved distant column clusters (Fig 9 left: worst
//! possible x reuse). The locality-aware reorder groups rows with
//! similar column signatures (Fig 9 right).
//!
//! Paper (Table 5): single-thread 0.419 -> 0.585 Gflops; 64-thread
//! 15.907 -> 27.306 Gflops (+71.7%); scalability 37.96x -> 46.68x.

mod common;

use ft2000_spmv::coordinator::{profile_matrix, ProfileConfig};
use ft2000_spmv::corpus::generators::poor_locality;
use ft2000_spmv::reorder::{locality_reorder, locality_score};
use ft2000_spmv::util::rng::Pcg32;
use ft2000_spmv::util::table::Table;

fn main() {
    common::banner(
        "Table 5",
        "performance and scalability of SpMV by exploiting the locality of x",
    );
    // Paper geometry: rows = 64*6400, avg nonzeros per row = 4.
    let n = 64 * 6400;
    let mut rng = Pcg32::new(0x10CA11);
    let synth = poor_locality(n, 4, 64, &mut rng);
    let plan = locality_reorder(&synth, 64);
    let transformed = plan.apply(&synth);
    println!(
        "locality score (block overlap of adjacent rows): {:.3} -> {:.3}\n",
        locality_score(&synth, 64),
        locality_score(&transformed, 64)
    );

    // 1 thread and 64 threads across the whole chip (core-group-first
    // covers all 16 groups / 8 panels at 64 threads).
    let cfg = ProfileConfig {
        threads: vec![1, 4, 16, 64],
        ..Default::default()
    };
    let p_synth = profile_matrix(&synth, "synthesized", &cfg);
    let p_trans = profile_matrix(&transformed, "transformed", &cfg);

    let mut t = Table::new(
        "Table 5 — synthesized vs transformed (locality-aware) matrix",
        &["metric", "synthesized", "transformed", "paper"],
    );
    t.row(vec![
        "single-thread Perf.".into(),
        format!("{:.3} Gflops", p_synth.gflops[0]),
        format!("{:.3} Gflops", p_trans.gflops[0]),
        "0.419 -> 0.585 Gflops".into(),
    ]);
    let last = cfg.threads.len() - 1;
    t.row(vec![
        "64-thread Perf.".into(),
        format!("{:.3} Gflops", p_synth.gflops[last]),
        format!("{:.3} Gflops", p_trans.gflops[last]),
        "15.907 -> 27.306 Gflops".into(),
    ]);
    t.row(vec![
        "speedup".into(),
        format!("{:.2}x", p_synth.speedups[last]),
        format!("{:.2}x", p_trans.speedups[last]),
        "37.96x -> 46.68x".into(),
    ]);
    t.print();

    let gain = 100.0 * (p_trans.gflops[last] - p_synth.gflops[last])
        / p_synth.gflops[last];
    println!("64-thread improvement: {gain:+.1}% (paper: +71.7%)");
    println!(
        "intermediate: 4t {:.2}x -> {:.2}x, 16t {:.2}x -> {:.2}x",
        p_synth.speedups[1],
        p_trans.speedups[1],
        p_synth.speedups[2],
        p_trans.speedups[2]
    );
}
