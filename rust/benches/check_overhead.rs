//! §Check — cost of the serve-path structural validation seam.
//!
//! The dispatcher runs `check::quick_plan_check` on every request
//! when `PlanConfig::validate` is on (the debug-build default). This
//! bench A/Bs serving with validation on vs off across the three
//! plan families (CSR rows, CSR5 tiles, SELL-C-sigma chunks) so the
//! per-dispatch tax is a measured number, not folklore, and also
//! prices the full offline verifier (`check_csr` + `check_plan`) for
//! the `ft2000-spmv check` sweep.
//!
//! Scale with `FT2000_SUITE=tiny|fast|full` (default fast); set
//! `FT2000_QUICK=1` for the CI smoke mode.

mod common;

use std::sync::atomic::{AtomicU64, Ordering};

use ft2000_spmv::check;
use ft2000_spmv::sched::Schedule;
use ft2000_spmv::service::{
    build_plan_with, MatrixRegistry, PlanConfig, Planner, ServeEngine,
};
use ft2000_spmv::util::bench::{bench, black_box, BenchConfig};
use ft2000_spmv::util::ordatomic::OrdAtomicU64;
use ft2000_spmv::util::table::Table;

fn main() {
    common::banner(
        "§Check",
        "serve-path validation overhead (quick_plan_check per dispatch)",
    );
    let quick = common::quick_from_env();
    let suite = common::suite_from_env();
    let bench_cfg = if quick {
        BenchConfig {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 20,
            target_rel_ci: 0.2,
            max_seconds: 0.5,
        }
    } else {
        BenchConfig {
            warmup_iters: 2,
            min_iters: 5,
            max_iters: 60,
            target_rel_ci: 0.1,
            max_seconds: 2.0,
        }
    };
    let matrices = if quick { 3 } else { 6 };

    // --- per-dispatch A/B: validate on vs off ------------------------
    // Same corpus sample, same planner, same pooled dispatch; the only
    // delta is the `quick_plan_check` call inside `dispatch_into`.
    let schedules: &[(&str, Schedule)] = &[
        ("csr", Schedule::CsrRowStatic),
        ("csr5", Schedule::Csr5Tiles { tile_nnz: 256 }),
        ("sell", Schedule::SellChunks { c: 8, sigma: 64 }),
    ];
    let mut t = Table::new(
        "Serve-path validation tax (validate on vs off, pooled dispatch)",
        &["matrix", "nnz", "off us/req", "on us/req", "tax"],
    );
    let mut worst_tax = 0.0f64;
    let ids = {
        let mut reg = MatrixRegistry::new();
        reg.register_suite(&suite, Some(matrices))
    };
    let build = |validate: bool| {
        let mut reg = MatrixRegistry::new();
        reg.register_suite(&suite, Some(matrices));
        ServeEngine::pooled(
            reg,
            Planner::Heuristic,
            PlanConfig { validate, ..PlanConfig::default() },
        )
    };
    let engine_off = build(false);
    let engine_on = build(true);
    for &id in &ids {
        let entry = engine_off.registry.entry(id);
        let x = vec![1.0f64; entry.csr.n_cols];
        // Warm both plan caches outside the timed region.
        let _ = engine_off.serve_batch(id, &[x.as_slice()]);
        let _ = engine_on.serve_batch(id, &[x.as_slice()]);
        let off = bench("off", &bench_cfg, || {
            black_box(engine_off.serve_batch(id, &[x.as_slice()]).unwrap());
        });
        let on = bench("on", &bench_cfg, || {
            black_box(engine_on.serve_batch(id, &[x.as_slice()]).unwrap());
        });
        let tax = on.mean_s / off.mean_s - 1.0;
        worst_tax = worst_tax.max(tax);
        t.row(vec![
            entry.name.clone(),
            entry.csr.nnz().to_string(),
            format!("{:.2}", off.mean_s * 1e6),
            format!("{:.2}", on.mean_s * 1e6),
            format!("{:+.1}%", tax * 100.0),
        ]);
    }
    t.print();
    println!(
        "worst per-dispatch validation tax: {:+.1}% (O(slots) pointer \
         walk, no allocation)",
        worst_tax * 100.0
    );

    // --- offline verifier cost ---------------------------------------
    // What the `ft2000-spmv check` sweep pays per matrix: the full
    // format verifier plus a plan build + plan verifier, per schedule
    // family.
    let mut t = Table::new(
        "Offline verifier cost per matrix (check_csr + check_plan)",
        &["matrix", "nnz", "check_csr us", "plan family", "check_plan us"],
    );
    for &id in ids.iter().take(if quick { 2 } else { 3 }) {
        let entry = engine_off.registry.entry(id);
        let csr = &entry.csr;
        let rc = bench("check_csr", &bench_cfg, || {
            black_box(check::check_csr(&entry.name, csr));
        });
        for (fname, sched) in schedules {
            let cfg = PlanConfig::default();
            let plan = build_plan_with(
                &cfg,
                csr,
                *sched,
                cfg.n_threads,
                Vec::new(),
            );
            let rp = bench("check_plan", &bench_cfg, || {
                black_box(check::check_plan(&entry.name, &plan, csr));
            });
            t.row(vec![
                entry.name.clone(),
                csr.nnz().to_string(),
                format!("{:.2}", rc.mean_s * 1e6),
                fname.to_string(),
                format!("{:.2}", rp.mean_s * 1e6),
            ]);
        }
    }
    t.print();

    // --- ordatomic passthrough A/B -----------------------------------
    // With `hbcheck` off (every release build, tier-1 tests, this
    // bench), `OrdAtomicU64` must compile to the bare std atomic — the
    // whole concurrency-soundness layer rides on that being free. A/B
    // a hot RMW+load loop on a raw `AtomicU64` vs the instrumented
    // cell and gate on the ratio in quick (CI) mode.
    let iters: u64 = if quick { 200_000 } else { 1_000_000 };
    let raw = AtomicU64::new(0);
    let wrapped = OrdAtomicU64::named(0, "bench.passthrough");
    let spin = |add: &dyn Fn() -> u64, load: &dyn Fn() -> u64| {
        let mut acc = 0u64;
        for _ in 0..iters {
            black_box(add());
            acc = acc.wrapping_add(black_box(load()));
        }
        acc
    };
    let r_raw = bench("raw", &bench_cfg, || {
        black_box(spin(
            &|| raw.fetch_add(1, Ordering::Relaxed),
            &|| raw.load(Ordering::Relaxed),
        ));
    });
    let r_ord = bench("ordatomic", &bench_cfg, || {
        black_box(spin(
            &|| wrapped.fetch_add(1, Ordering::Relaxed),
            &|| wrapped.load(Ordering::Relaxed),
        ));
    });
    let ratio = r_ord.mean_s / r_raw.mean_s;
    let mut t = Table::new(
        "OrdAtomic passthrough (hbcheck off): raw vs instrumented cell",
        &["variant", "ns/op", "ratio"],
    );
    let per_op = 1e9 / (2.0 * iters as f64);
    t.row(vec![
        "AtomicU64".into(),
        format!("{:.2}", r_raw.mean_s * per_op),
        "1.00x".into(),
    ]);
    t.row(vec![
        "OrdAtomicU64".into(),
        format!("{:.2}", r_ord.mean_s * per_op),
        format!("{ratio:.2}x"),
    ]);
    t.print();
    println!(
        "ordatomic passthrough ratio: {ratio:.3}x (must be ~1.0 — the \
         wrapper is #[inline(always)] delegation)"
    );
    // Gate only in quick/CI mode; threshold is generous because at
    // ~1 ns/op the measurement jitter dwarfs any real delta.
    if quick {
        assert!(
            ratio < 1.25,
            "ordatomic passthrough regressed: {ratio:.3}x slower than \
             the raw atomic"
        );
    }
}
