//! §Perf — wall-clock benchmarks of the hot paths on THIS machine
//! (criterion is unavailable offline; `util::bench` implements the
//! 95%-CI measurement protocol).
//!
//! Targets:
//! * cache probe micro-benchmark (the simulator's innermost loop);
//! * trace-driven simulation throughput (accesses/second);
//! * native CSR/CSR5 SpMV executor (Gflops on the host);
//! * end-to-end matrix profile (the campaign unit of work).

mod common;

use ft2000_spmv::coordinator::{profile_matrix, ProfileConfig};
use ft2000_spmv::corpus::generators;
use ft2000_spmv::exec;
use ft2000_spmv::sched::Schedule;
use ft2000_spmv::sim::cache::{Cache, Replacement};
use ft2000_spmv::sim::engine::{simulate, ThreadSpec};
use ft2000_spmv::sim::topology::Topology;
use ft2000_spmv::trace::CsrTrace;
use ft2000_spmv::util::bench::{bench, black_box, BenchConfig};
use ft2000_spmv::util::rng::Pcg32;

fn main() {
    common::banner("§Perf", "host wall-clock of the simulator/executor hot paths");
    let cfg = BenchConfig::default();
    let mut rng = Pcg32::new(0xBE7C);

    // --- cache probe micro ---------------------------------------------
    let addrs: Vec<u64> =
        (0..1_000_000).map(|_| (rng.gen_range(1 << 22) as u64) << 3).collect();
    for (name, policy) in
        [("lru", Replacement::Lru), ("random", Replacement::Random)]
    {
        let mut cache = Cache::with_policy(2 * 1024 * 1024, 16, policy);
        let r = bench(&format!("cache_probe_{name}_1M"), &cfg, || {
            for &a in &addrs {
                black_box(cache.access(a));
            }
        });
        println!(
            "{}  ({:.1} M probes/s)",
            r.summary(),
            1.0 / r.mean_s
        );
    }

    // --- simulation throughput ------------------------------------------
    let csr = generators::random_uniform(16_384, 16, &mut rng);
    let accesses = (2 * csr.n_rows + 3 * csr.nnz()) as f64;
    let topo = Topology::ft2000plus();
    let r = bench("simulate_4t_random16k", &cfg, || {
        let threads: Vec<ThreadSpec<CsrTrace>> = (0..4)
            .map(|t| ThreadSpec {
                gen: CsrTrace::new(
                    &csr,
                    csr.n_rows * t / 4,
                    csr.n_rows * (t + 1) / 4,
                ),
                core: t,
            })
            .collect();
        black_box(simulate(&topo, threads));
    });
    println!(
        "{}  ({:.1} M accesses/s)",
        r.summary(),
        accesses / r.mean_s / 1e6
    );

    // --- native SpMV executors ------------------------------------------
    let x: Vec<f64> = (0..csr.n_cols).map(|_| rng.gen_f64()).collect();
    for (name, sched) in [
        ("csr_seq", None),
        ("csr_4t", Some(Schedule::CsrRowStatic)),
        ("csr5_4t", Some(Schedule::Csr5Tiles { tile_nnz: 256 })),
    ] {
        let r = bench(&format!("spmv_{name}"), &cfg, || match sched {
            None => {
                black_box(exec::spmv_sequential(&csr, &x));
            }
            Some(s) => {
                black_box(exec::spmv_threaded(&csr, &x, s, 4));
            }
        });
        println!(
            "{}  ({:.3} Gflops host)",
            r.summary(),
            2.0 * csr.nnz() as f64 / r.mean_s / 1e9
        );
    }

    // --- campaign unit of work ------------------------------------------
    let small = generators::banded(4096, 8, &mut rng);
    let r = bench("profile_matrix_banded4k", &cfg, || {
        black_box(profile_matrix(&small, "b", &ProfileConfig::default()));
    });
    println!("{}", r.summary());
}
