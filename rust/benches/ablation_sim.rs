//! Ablations of the simulator's design choices (DESIGN.md §6):
//!
//! 1. **L2 replacement policy** — pseudo-random (the FT-2000+ reality,
//!    and the mechanism behind x-eviction contention) vs LRU (which
//!    pins the hot x lines and hides the effect);
//! 2. **queueing model** — the shared-L2 probe path on/off (capacity
//!    -> infinity), isolating how much of conf5/appu's flat scaling it
//!    explains;
//! 3. **bandwidth roofline floor** on/off via the DCU path, isolating
//!    the streaming (debr/bone010) limiter.

mod common;

use ft2000_spmv::coordinator::{profile_matrix, ProfileConfig};
use ft2000_spmv::corpus::NamedMatrix;
use ft2000_spmv::sim::cache::Replacement;
use ft2000_spmv::sim::topology::Topology;
use ft2000_spmv::util::table::Table;

fn speedup_with(topo: Topology, m: NamedMatrix) -> f64 {
    let cfg = ProfileConfig { topo, ..Default::default() };
    profile_matrix(&m.generate(), m.name(), &cfg).max_speedup()
}

fn main() {
    common::banner(
        "Ablations",
        "simulator design choices vs the paper's observed behaviours",
    );

    let cases =
        [NamedMatrix::Conf5_4_8x8_20, NamedMatrix::Debr, NamedMatrix::AsiaOsm];

    // 1. L2 replacement policy.
    let mut t = Table::new(
        "Ablation 1 — L2 replacement policy (4-thread speedup)",
        &["matrix", "random (default)", "LRU"],
    );
    for m in cases {
        let mut lru = Topology::ft2000plus();
        lru.l2.policy = Replacement::Lru;
        t.row(vec![
            m.name().to_string(),
            format!("{:.3}x", speedup_with(Topology::ft2000plus(), m)),
            format!("{:.3}x", speedup_with(lru.clone(), m)),
        ]);
    }
    t.print();

    // 2. Shared-L2 probe queueing.
    let mut t = Table::new(
        "Ablation 2 — shared-L2 probe queueing (4-thread speedup)",
        &["matrix", "modeled (default)", "disabled"],
    );
    for m in cases {
        let mut off = Topology::ft2000plus();
        off.l2_acc_per_cycle = 1e9;
        t.row(vec![
            m.name().to_string(),
            format!("{:.3}x", speedup_with(Topology::ft2000plus(), m)),
            format!("{:.3}x", speedup_with(off.clone(), m)),
        ]);
    }
    t.print();

    // 3. DCU / group-port bandwidth limits.
    let mut t = Table::new(
        "Ablation 3 — DRAM bandwidth limits (4-thread speedup)",
        &["matrix", "modeled (default)", "unlimited BW"],
    );
    for m in cases {
        let mut off = Topology::ft2000plus();
        off.bw_l2_port_gbs = 1e9;
        off.bw_domain_gbs = 1e9;
        t.row(vec![
            m.name().to_string(),
            format!("{:.3}x", speedup_with(Topology::ft2000plus(), m)),
            format!("{:.3}x", speedup_with(off.clone(), m)),
        ]);
    }
    t.print();

    println!(
        "expected: ablation 2 explains conf5's flat in-group scaling; \
         ablation 3 explains debr's (streaming) cap; asia_osm sits between."
    );
}
