//! Shared helpers for the figure/table bench harnesses.
//!
//! Each bench target regenerates one table or figure of the paper,
//! printing the same rows/series the paper reports. Scale defaults to
//! the fast corpus; set `FT2000_SUITE=full` for the paper-scale 1008
//! matrices (or `tiny` for smoke runs).

use ft2000_spmv::corpus::suite::SuiteSpec;

pub fn suite_from_env() -> SuiteSpec {
    match std::env::var("FT2000_SUITE").as_deref() {
        Ok("full") => SuiteSpec::full(),
        Ok("tiny") => SuiteSpec::tiny(),
        _ => SuiteSpec::fast(),
    }
}

/// Quick-mode toggle for CI smoke runs: set `FT2000_QUICK=1` to
/// shrink request counts and iteration budgets so a bench target
/// finishes in seconds while still exercising its full code path.
#[allow(dead_code)] // not every bench target has a quick mode
pub fn quick_from_env() -> bool {
    matches!(
        std::env::var("FT2000_QUICK").as_deref(),
        Ok("1") | Ok("true") | Ok("yes")
    )
}

/// Optional section filter: `FT2000_SECTION=<name>` runs only the
/// matching section of a multi-section bench target;
/// `FT2000_SECTION=-<name>` runs everything *except* it (CI smoke
/// splits a bench across steps without running any section twice).
#[allow(dead_code)] // not every bench target is sectioned
pub fn section_from_env() -> Option<String> {
    std::env::var("FT2000_SECTION").ok().filter(|s| !s.is_empty())
}

/// Should the section named `name` run under the current filter?
#[allow(dead_code)]
pub fn section_enabled(name: &str) -> bool {
    match section_from_env() {
        Some(filter) => match filter.strip_prefix('-') {
            Some(excluded) => excluded != name,
            None => filter == name,
        },
        None => true,
    }
}

pub fn banner(id: &str, paper: &str) {
    println!("\n=== {id} ===");
    println!("paper reference: {paper}\n");
}
