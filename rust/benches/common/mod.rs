//! Shared helpers for the figure/table bench harnesses.
//!
//! Each bench target regenerates one table or figure of the paper,
//! printing the same rows/series the paper reports. Scale defaults to
//! the fast corpus; set `FT2000_SUITE=full` for the paper-scale 1008
//! matrices (or `tiny` for smoke runs).

use ft2000_spmv::corpus::suite::SuiteSpec;

pub fn suite_from_env() -> SuiteSpec {
    match std::env::var("FT2000_SUITE").as_deref() {
        Ok("full") => SuiteSpec::full(),
        Ok("tiny") => SuiteSpec::tiny(),
        _ => SuiteSpec::fast(),
    }
}

pub fn banner(id: &str, paper: &str) {
    println!("\n=== {id} ===");
    println!("paper reference: {paper}\n");
}
