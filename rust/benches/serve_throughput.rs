//! §Serve — wall-clock throughput of the batched serving path.
//!
//! Three questions the serving layer must answer affirmatively on the
//! host:
//!
//! 1. Does coalescing `b` concurrent requests into one
//!    `exec::spmm_threaded` launch beat `b` back-to-back SpMV calls?
//!    (It should: one dispatch, A streamed once per column block.)
//! 2. What does the end-to-end engine sustain under Zipf traffic,
//!    open- and closed-loop?
//! 3. Does the persistent executor pool beat per-request thread
//!    spawning? (It should: small/medium SpMV kernels are dominated
//!    by parallel-runtime overhead, which the pool pays once.)
//! 4. Does online autotuning beat the static planner on the same
//!    traffic? (Deterministic virtual-time A/B — see section 5.)
//! 5. Do the PR-5 kernels pay off? Section `kernels` microbenches the
//!    scalar vs 4x-unrolled CSR row kernel and the packed formats
//!    (SELL-C-σ, CSR5) per matrix, and snapshots the numbers to
//!    `BENCH_kernels.json` for the perf trajectory. Section `arena`
//!    A/Bs the zero-allocation scratch serve path against the
//!    allocating path (quick mode asserts the arena is no slower).
//! 6. Is stage tracing cheap enough to leave on? Section `obs` A/Bs
//!    the serve path with the span recorder detached vs attached at
//!    full sampling, interleaved so drift cancels, and snapshots the
//!    tax to `BENCH_obs.json` (quick mode gates it at <= 2%). The
//!    same section A/Bs the always-on scalability profiler against
//!    `without_scaling` under the identical gate.
//!
//! Scale with `FT2000_SUITE=tiny|fast|full` (default fast); set
//! `FT2000_QUICK=1` for the CI smoke mode (tiny request counts, full
//! code paths, convergence assertions in section 5). Run a single
//! section with
//! `FT2000_SECTION=batch|traffic|pool|shard|autotune|kernels|arena|obs`,
//! or everything but one with `FT2000_SECTION=-<name>`.

mod common;

use std::sync::Arc;

use ft2000_spmv::autotune::{autotune_table, AutotuneConfig};
use ft2000_spmv::exec;
use ft2000_spmv::service;
use ft2000_spmv::service::{
    replay, serve_queue, Arrivals, MatrixRegistry, PlacementPolicy,
    PlanConfig, Planner, Popularity, ReplayConfig, Request, RequestQueue,
    ServeEngine, ShardConfig, ShardedServer, WorkloadSpec,
};
use ft2000_spmv::util::bench::{bench, black_box, BenchConfig};
use ft2000_spmv::util::json::Json;
use ft2000_spmv::util::table::Table;

fn main() {
    common::banner(
        "§Serve",
        "batched SpMM vs repeated SpMV; engine throughput under Zipf \
         traffic; pooled vs spawn dispatch; static vs tuned plans; \
         kernel microbench; arena vs allocating serve path",
    );
    let suite = common::suite_from_env();
    let quick = common::quick_from_env();

    // --- 1: batching win ------------------------------------------------
    if common::section_enabled("batch") {
        let mut reg = MatrixRegistry::new();
        let ids = reg.register_suite(&suite, Some(12));
        let engine =
            ServeEngine::new(reg, Planner::Heuristic, PlanConfig::default());
        let cfg = BenchConfig {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: if quick { 5 } else { 30 },
            target_rel_ci: 0.1,
            max_seconds: if quick { 0.25 } else { 2.0 },
        };
        let mut chosen = ids.clone();
        chosen.sort_by_key(|&id| {
            std::cmp::Reverse(engine.registry.entry(id).csr.nnz())
        });
        chosen.dedup();
        chosen.truncate(if quick { 1 } else { 3 });
        let batch_sizes: &[usize] =
            if quick { &[1, 8] } else { &[1, 2, 4, 8, 16, 32] };
        let mut t = Table::new(
            "Batched SpMM vs N sequential SpMV calls (cached plan, 4 \
             threads)",
            &["matrix", "nnz", "batch", "spmm Gflops", "Nx spmv Gflops", "win"],
        );
        for &id in &chosen {
            let entry = engine.registry.entry(id);
            let (plan, _) =
                engine.plans.plan_for(entry.fingerprint, &entry.csr);
            let nnz = entry.csr.nnz();
            let x = vec![1.0f64; entry.csr.n_cols];
            for &b in batch_sizes {
                let xs_refs: Vec<&[f64]> =
                    (0..b).map(|_| x.as_slice()).collect();
                let packed = exec::pack_vectors(&xs_refs);
                let spmm = bench("spmm", &cfg, || {
                    black_box(plan.execute_batch(&entry.csr, &packed, b));
                });
                let spmv = bench("spmv", &cfg, || {
                    for _ in 0..b {
                        black_box(plan.execute(&entry.csr, &x));
                    }
                });
                let flops = 2.0 * nnz as f64 * b as f64;
                t.row(vec![
                    entry.name.clone(),
                    nnz.to_string(),
                    b.to_string(),
                    format!("{:.3}", flops / spmm.mean_s / 1e9),
                    format!("{:.3}", flops / spmv.mean_s / 1e9),
                    format!("{:.2}x", spmv.mean_s / spmm.mean_s),
                ]);
            }
        }
        t.print();
    }

    // --- 2: end-to-end engine under traffic -----------------------------
    if common::section_enabled("traffic") {
        section_traffic(&suite, quick);
    }

    // --- 3: pooled vs spawn dispatch, wall clock A/B ---------------------
    if common::section_enabled("pool") {
        section_pool(&suite, quick);
    }

    // --- 4: sharded vs global serving, wall clock A/B -------------------
    if common::section_enabled("shard") {
        section_shard(&suite, quick);
    }

    // --- 5: static vs tuned plans, virtual-time A/B ----------------------
    if common::section_enabled("autotune") {
        section_autotune(&suite, quick);
    }

    // --- 6: kernel microbench (scalar vs unrolled vs packed formats) -----
    if common::section_enabled("kernels") {
        section_kernels(&suite, quick);
    }

    // --- 7: arena (zero-alloc) vs allocating serve path, wall clock ------
    if common::section_enabled("arena") {
        section_arena(&suite, quick);
    }

    // --- 8: tracing overhead A/B (span recorder off vs on) ---------------
    if common::section_enabled("obs") {
        section_obs(&suite, quick);
    }
}

// Tracing overhead A/B: the same pooled `serve_batch` stream measured
// with the span recorder detached and attached (full sampling, Wall
// clock). Two identically-built engines; rounds are interleaved with
// alternating order so clock drift and thermal state hit both sides
// equally, and the gated number is the *median* per-round ratio —
// robust to a stray slow round on shared CI hardware. Emits
// `BENCH_obs.json` for the perf trajectory; quick mode asserts the
// tracing tax stays within the 2% observability budget. A second A/B
// with the same methodology gates the always-on scalability
// profiler's tax (attribution enabled vs `without_scaling`).
fn section_obs(suite: &ft2000_spmv::corpus::suite::SuiteSpec, quick: bool) {
    use ft2000_spmv::obs::{ClockMode, TraceConfig, TraceRecorder};

    println!();
    println!("tracing overhead A/B (serve_batch wall clock):");
    let build = || {
        let mut reg = MatrixRegistry::new();
        let ids = reg.register_suite(suite, Some(6));
        let engine = ServeEngine::pooled(
            reg,
            Planner::Heuristic,
            PlanConfig::default(),
        );
        (engine, ids)
    };
    let (plain, ids) = build();
    let (traced, _) = build();
    let n_lanes = traced.pool().map(|p| p.n_workers() + 1).unwrap_or(1);
    let traced = traced.with_trace(Arc::new(TraceRecorder::new(
        TraceConfig::on(),
        ClockMode::Wall,
        n_lanes,
    )));
    // Median-sized matrix, same selection rule as section `arena`.
    let mut by_nnz = ids.clone();
    by_nnz.sort_by_key(|&id| plain.registry.entry(id).csr.nnz());
    let id = by_nnz[by_nnz.len() / 2];
    let x = vec![1.0f64; plain.registry.entry(id).csr.n_cols];
    let xs1 = [x.as_slice()];
    let xs8 = [x.as_slice(); 8];
    let round = |engine: &ServeEngine| {
        let t0 = std::time::Instant::now();
        for _ in 0..8 {
            engine.serve_batch(id, &xs1).expect("serve");
            engine.serve_batch(id, &xs8).expect("serve");
        }
        t0.elapsed().as_secs_f64()
    };
    // Warm plan caches and scratch arenas on both engines.
    for _ in 0..6 {
        round(&plain);
        round(&traced);
    }
    let rounds = if quick { 40 } else { 150 };
    let (mut total_off, mut total_on) = (0.0f64, 0.0f64);
    let mut ratios = Vec::with_capacity(rounds);
    for i in 0..rounds {
        let (off, on) = if i % 2 == 0 {
            let off = round(&plain);
            (off, round(&traced))
        } else {
            let on = round(&traced);
            (round(&plain), on)
        };
        total_off += off;
        total_on += on;
        ratios.push(on / off);
    }
    ratios.sort_by(f64::total_cmp);
    let median = ratios[ratios.len() / 2];
    let total_ratio = total_on / total_off;
    let spans = traced.trace().map(|r| r.spans_recorded()).unwrap_or(0);
    println!(
        "untraced {:.3} ms  traced {:.3} ms  total ratio \
         {total_ratio:.4}x  median round ratio {median:.4}x  ({spans} \
         spans recorded)",
        total_off * 1e3,
        total_on * 1e3,
    );
    if let Some(rec) = traced.trace() {
        rec.flame_table().print();
    }
    // Scalability-profiler tax, same interleaved-median methodology:
    // both engines untraced, one with attribution disabled. The
    // profiler is always on in deployments, so its cost shares the
    // tracing section's observability budget.
    println!();
    println!("scaling profiler A/B (serve_batch wall clock):");
    let (scaling_off, _) = build();
    let scaling_off = scaling_off.without_scaling();
    let (scaling_on, _) = build();
    for _ in 0..6 {
        round(&scaling_off);
        round(&scaling_on);
    }
    let (mut sc_total_off, mut sc_total_on) = (0.0f64, 0.0f64);
    let mut sc_ratios = Vec::with_capacity(rounds);
    for i in 0..rounds {
        let (off, on) = if i % 2 == 0 {
            let off = round(&scaling_off);
            (off, round(&scaling_on))
        } else {
            let on = round(&scaling_on);
            (round(&scaling_off), on)
        };
        sc_total_off += off;
        sc_total_on += on;
        sc_ratios.push(on / off);
    }
    sc_ratios.sort_by(f64::total_cmp);
    let sc_median = sc_ratios[sc_ratios.len() / 2];
    let sc_total_ratio = sc_total_on / sc_total_off;
    let sc_batches = scaling_on.scaling().batches();
    println!(
        "profiler off {:.3} ms  on {:.3} ms  total ratio \
         {sc_total_ratio:.4}x  median round ratio {sc_median:.4}x  \
         ({sc_batches} batches attributed)",
        sc_total_off * 1e3,
        sc_total_on * 1e3,
    );
    scaling_on.scaling().table().print();
    let snapshot = Json::Obj(
        [
            ("section".to_string(), Json::Str("obs".to_string())),
            (
                "quick".to_string(),
                Json::Num(if quick { 1.0 } else { 0.0 }),
            ),
            ("rounds".to_string(), Json::Num(rounds as f64)),
            ("untraced_s".to_string(), Json::Num(total_off)),
            ("traced_s".to_string(), Json::Num(total_on)),
            ("total_ratio".to_string(), Json::Num(total_ratio)),
            ("median_round_ratio".to_string(), Json::Num(median)),
            ("spans_recorded".to_string(), Json::Num(spans as f64)),
            ("scaling_off_s".to_string(), Json::Num(sc_total_off)),
            ("scaling_on_s".to_string(), Json::Num(sc_total_on)),
            (
                "scaling_total_ratio".to_string(),
                Json::Num(sc_total_ratio),
            ),
            (
                "scaling_median_ratio".to_string(),
                Json::Num(sc_median),
            ),
            (
                "scaling_batches".to_string(),
                Json::Num(sc_batches as f64),
            ),
        ]
        .into_iter()
        .collect(),
    );
    let path = std::env::var("FT2000_BENCH_DIR")
        .map(|d| format!("{d}/BENCH_obs.json"))
        .unwrap_or_else(|_| "BENCH_obs.json".to_string());
    match std::fs::write(&path, snapshot.to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
    if quick {
        assert!(
            median <= 1.02,
            "obs smoke: tracing tax exceeded the 2% budget (median \
             round ratio {median:.4}x over {rounds} interleaved rounds)"
        );
        assert!(
            sc_median <= 1.02,
            "obs smoke: scaling-profiler tax exceeded the 2% budget \
             (median round ratio {sc_median:.4}x over {rounds} \
             interleaved rounds)"
        );
    }
}

// Per-format kernel microbench: the scalar single-accumulator CSR row
// kernel (the pre-PR-5 baseline) vs the 4x-unrolled fmadd kernel, the
// SELL-C-σ chunk-vectorized kernel, and CSR5 — sequential, so the
// numbers isolate the inner loop from dispatch/partitioning. Emits a
// `BENCH_kernels.json` snapshot for the perf trajectory.
fn section_kernels(suite: &ft2000_spmv::corpus::suite::SuiteSpec, quick: bool) {
    use ft2000_spmv::sparse::{row_dot_scalar, Csr5, SellCSigma};

    println!();
    let cfg = BenchConfig {
        warmup_iters: 1,
        min_iters: 3,
        max_iters: if quick { 8 } else { 40 },
        target_rel_ci: 0.1,
        max_seconds: if quick { 0.2 } else { 1.5 },
    };
    let mut reg = MatrixRegistry::new();
    let ids = reg.register_suite(suite, Some(if quick { 3 } else { 6 }));
    let mut chosen = ids.clone();
    chosen.sort_by_key(|&id| std::cmp::Reverse(reg.entry(id).csr.nnz()));
    chosen.dedup();
    chosen.truncate(if quick { 2 } else { 4 });
    let mut t = Table::new(
        "Kernel microbench (sequential, Gflops; higher is better)",
        &[
            "matrix",
            "nnz",
            "csr scalar",
            "csr unrolled",
            "sell-c8-s64",
            "csr5-t256",
        ],
    );
    let mut rows: Vec<Json> = Vec::new();
    for &id in &chosen {
        let entry = reg.entry(id);
        let csr = &entry.csr;
        let n = csr.n_rows;
        let nnz = csr.nnz();
        let flops = 2.0 * nnz as f64;
        let x = vec![1.0f64; csr.n_cols];
        let mut y = vec![0.0f64; n];
        let scalar = bench("csr-scalar", &cfg, || {
            for r in 0..n {
                let (cols, vals) = csr.row(r);
                y[r] = row_dot_scalar(cols, vals, &x);
            }
            black_box(&y);
        });
        let mut y = vec![0.0f64; n];
        let unrolled = bench("csr-unrolled", &cfg, || {
            csr.spmv(&x, &mut y);
            black_box(&y);
        });
        let sell = SellCSigma::from_csr(csr, 8, 64);
        let mut y = vec![0.0f64; n];
        let sell_run = bench("sell", &cfg, || {
            sell.spmv(&x, &mut y);
            black_box(&y);
        });
        let csr5 = Csr5::from_csr(csr, 256);
        let mut y = vec![0.0f64; n];
        let csr5_run = bench("csr5", &cfg, || {
            csr5.spmv(&x, &mut y);
            black_box(&y);
        });
        let gf = |mean_s: f64| flops / mean_s / 1e9;
        t.row(vec![
            entry.name.clone(),
            nnz.to_string(),
            format!("{:.3}", gf(scalar.mean_s)),
            format!("{:.3}", gf(unrolled.mean_s)),
            format!("{:.3}", gf(sell_run.mean_s)),
            format!("{:.3}", gf(csr5_run.mean_s)),
        ]);
        for (kernel, mean_s) in [
            ("csr-scalar", scalar.mean_s),
            ("csr-unrolled", unrolled.mean_s),
            ("sell-c8-s64", sell_run.mean_s),
            ("csr5-t256", csr5_run.mean_s),
        ] {
            rows.push(Json::Obj(
                [
                    ("matrix".to_string(), Json::Str(entry.name.clone())),
                    ("nnz".to_string(), Json::Num(nnz as f64)),
                    ("kernel".to_string(), Json::Str(kernel.to_string())),
                    ("mean_s".to_string(), Json::Num(mean_s)),
                    ("gflops".to_string(), Json::Num(gf(mean_s))),
                ]
                .into_iter()
                .collect(),
            ));
        }
    }
    t.print();
    let snapshot = Json::Obj(
        [
            ("section".to_string(), Json::Str("kernels".to_string())),
            (
                "quick".to_string(),
                Json::Num(if quick { 1.0 } else { 0.0 }),
            ),
            ("rows".to_string(), Json::Arr(rows)),
        ]
        .into_iter()
        .collect(),
    );
    let path = std::env::var("FT2000_BENCH_DIR")
        .map(|d| format!("{d}/BENCH_kernels.json"))
        .unwrap_or_else(|_| "BENCH_kernels.json".to_string());
    match std::fs::write(&path, snapshot.to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

// Arena (zero-alloc scratch) vs allocating serve path: same cached
// plan, same pooled engine, same inputs. Three rungs:
//
// * `plan direct` — bare `plan.execute_on`, a fresh scratch + output
//   per call (the pre-PR-5 allocation profile, no engine bookkeeping;
//   informational only — it skips the registry/plan-cache/telemetry
//   work the engine paths share);
// * `engine alloc` — `execute_batch`, the materializing engine path
//   (arena execution + one output clone per request);
// * `engine arena` — `serve_batch`, the zero-allocation serve path.
//
// The quick-mode CI gate compares the two *engine* rungs — identical
// bookkeeping, so the ratio isolates exactly the per-request output
// materialization the arena removes and cannot be skewed by lock
// overhead differences.
fn section_arena(suite: &ft2000_spmv::corpus::suite::SuiteSpec, quick: bool) {
    println!();
    println!("arena (zero-alloc) vs allocating serve path (wall clock):");
    let cfg = BenchConfig {
        warmup_iters: 2,
        min_iters: 5,
        max_iters: if quick { 60 } else { 200 },
        target_rel_ci: 0.05,
        max_seconds: if quick { 0.6 } else { 2.0 },
    };
    let mut reg = MatrixRegistry::new();
    let ids = reg.register_suite(suite, Some(6));
    let engine =
        ServeEngine::pooled(reg, Planner::Heuristic, PlanConfig::default());
    // Median-sized matrix: big enough to be a real kernel, small
    // enough that per-request overhead is visible.
    let mut by_nnz = ids.clone();
    by_nnz.sort_by_key(|&id| engine.registry.entry(id).csr.nnz());
    let id = by_nnz[by_nnz.len() / 2];
    let entry = engine.registry.entry(id);
    let (plan, _) = engine.plans.plan_for(entry.fingerprint, &entry.csr);
    let x = vec![1.0f64; entry.csr.n_cols];
    let xs1 = [x.as_slice()];
    let xs8 = [x.as_slice(); 8];
    // Warm the arena before timing it.
    for _ in 0..4 {
        engine.serve_batch(id, &xs1).expect("warmup");
        engine.serve_batch(id, &xs8).expect("warmup");
    }
    let mut report = Vec::new();
    for (label, batch) in [("batch 1", 1usize), ("batch 8", 8)] {
        let direct = bench("plan-direct", &cfg, || {
            if batch == 1 {
                black_box(plan.execute_on(&entry.csr, &x, engine.pool()));
            } else {
                let packed = exec::pack_vectors(&xs8);
                black_box(plan.execute_batch_on(
                    &entry.csr,
                    &packed,
                    8,
                    engine.pool(),
                ));
            }
        });
        let alloc = bench("engine-alloc", &cfg, || {
            let xs: &[&[f64]] = if batch == 1 { &xs1 } else { &xs8 };
            black_box(engine.execute_batch(id, xs).expect("serve"));
        });
        let arena = bench("engine-arena", &cfg, || {
            let xs: &[&[f64]] = if batch == 1 { &xs1 } else { &xs8 };
            black_box(engine.serve_batch(id, xs).expect("serve"));
        });
        let ratio = arena.mean_s / alloc.mean_s;
        println!(
            "{} ({label:<7}): plan direct {:>9.3} us  engine alloc \
             {:>9.3} us  engine arena {:>9.3} us  arena/alloc {ratio:.3}x",
            entry.name,
            direct.mean_s * 1e6,
            alloc.mean_s * 1e6,
            arena.mean_s * 1e6,
        );
        report.push((label, ratio));
    }
    if quick {
        for (label, ratio) in report {
            assert!(
                ratio <= 1.10,
                "arena smoke: the zero-alloc serve path must be no \
                 slower than the materializing path ({label}: {ratio:.3}x)"
            );
        }
    }
}

fn section_traffic(suite: &ft2000_spmv::corpus::suite::SuiteSpec, quick: bool) {
    for (label, arrivals) in [
        ("open-loop 4k req/s", Arrivals::Open { rate: 4000.0 }),
        ("closed-loop 16 clients", Arrivals::Closed { clients: 16 }),
    ] {
        let mut reg = MatrixRegistry::new();
        let ids = reg.register_suite(&suite, Some(12));
        let engine = ServeEngine::pooled(
            reg,
            Planner::Heuristic,
            PlanConfig::default(),
        );
        let spec = WorkloadSpec {
            requests: if quick { 200 } else { 1500 },
            popularity: Popularity::Zipf { s: 1.2 },
            arrivals,
            seed: 0x5EED_2019,
        };
        let report =
            replay(&engine, &ids, &spec, &ReplayConfig::default())
                .expect("replay");
        println!(
            "{label:<24} {:>9.1} req/s  p50 {:>8.3} ms  p99 {:>8.3} ms  \
             mean batch {:>5.2}  hit rate {:>5.1}%  ({:.2} Gflops measured)",
            report.throughput_rps(),
            report.stats.latency_percentile(50.0),
            report.stats.latency_percentile(99.0),
            report.stats.mean_batch(),
            100.0 * report.hit_rate(),
            report.stats.executed_gflops(),
        );
    }
}

// Pooled vs spawn dispatch, wall clock A/B. The tax PR 3 removed:
// same Zipf closed-loop stream, same coalescing drain loop; (a)
// per-request scoped threads — the old hot path — and (b) the
// persistent executor pool. The corpus is dominated by small/medium
// matrices, so dispatch overhead (not kernel work) decides the gap.
fn section_pool(suite: &ft2000_spmv::corpus::suite::SuiteSpec, quick: bool) {
    println!();
    println!("pooled vs spawn dispatch (same traffic, wall clock):");
    let n_req = if quick { 256 } else { 2048 };
    let wl = WorkloadSpec {
        requests: n_req,
        popularity: Popularity::Zipf { s: 1.2 },
        arrivals: Arrivals::Closed { clients: 4 },
        seed: 0x900D,
    };
    let mut rps = Vec::new();
    for pooled in [false, true] {
        let mut reg = MatrixRegistry::new();
        let ids = reg.register_suite(&suite, Some(12));
        let seq = wl.generate(ids.len());
        let registry = Arc::new(reg);
        let inputs: std::collections::HashMap<usize, Arc<Vec<f64>>> = ids
            .iter()
            .map(|&id| {
                let n = registry.entry(id).csr.n_cols;
                (id, Arc::new(vec![1.0f64; n]))
            })
            .collect();
        let engine = ServeEngine::shared_with_mode(
            pooled,
            registry.clone(),
            Planner::Heuristic,
            PlanConfig::default(),
        );
        let queue = RequestQueue::new();
        let t0 = std::time::Instant::now();
        let served = std::thread::scope(|s| {
            s.spawn(|| {
                for r in &seq {
                    let id = ids[r.matrix_idx];
                    queue.push(Request::new(id, inputs[&id].clone()));
                }
                queue.close();
            });
            serve_queue(&engine, &queue, 4, 16)
        });
        let wall = t0.elapsed().as_secs_f64();
        let label = if pooled { "pool dispatch" } else { "spawn dispatch" };
        let throughput = served as f64 / wall;
        println!(
            "{label:<24} {throughput:>9.1} req/s  ({served} served in \
             {wall:.3}s)",
        );
        rps.push(throughput);
    }
    println!("pooled/spawn throughput ratio: {:.2}x", rps[1] / rps[0]);
}

// Sharded vs global serving, wall clock A/B. Same Zipf request
// sequence pushed through (a) one global queue with one
// undifferentiated pool — the topology-blind baseline — and (b) the
// panel-sharded server (hot matrices replicated, cold homed,
// per-shard plan caches + panel-pinned executor pools).
// Streaming-percentile telemetry in both.
fn section_shard(suite: &ft2000_spmv::corpus::suite::SuiteSpec, quick: bool) {
    println!();
    println!("sharded vs global serving (same traffic, wall clock):");
    let n_req = if quick { 256usize } else { 1024 };
    let wl = WorkloadSpec {
        requests: n_req,
        popularity: Popularity::Zipf { s: 1.2 },
        arrivals: Arrivals::Closed { clients: 8 },
        seed: 0x5EED_2019,
    };
    for shards in [1usize, 8] {
        let mut reg = MatrixRegistry::new();
        let ids = reg.register_suite(&suite, Some(12));
        let seq = wl.generate(ids.len());
        let registry = Arc::new(reg);
        let inputs: std::collections::HashMap<usize, Arc<Vec<f64>>> = ids
            .iter()
            .map(|&id| {
                let n = registry.entry(id).csr.n_cols;
                (id, Arc::new(vec![1.0f64; n]))
            })
            .collect();
        let t0 = std::time::Instant::now();
        let (served, merged) = if shards == 1 {
            let engine = ServeEngine::shared_pooled(
                registry.clone(),
                Planner::Heuristic,
                PlanConfig::default(),
            );
            let queue = RequestQueue::new();
            let served = std::thread::scope(|s| {
                s.spawn(|| {
                    for r in &seq {
                        let id = ids[r.matrix_idx];
                        queue.push(Request::new(id, inputs[&id].clone()));
                    }
                    queue.close();
                });
                serve_queue(&engine, &queue, 8, 16)
            });
            (served, engine.telemetry.snapshot())
        } else {
            let weights =
                wl.popularity.placement_weights(&ids, registry.len());
            let server = ShardedServer::with_weights(
                registry.clone(),
                Planner::Heuristic,
                PlanConfig::default(),
                ShardConfig {
                    shards,
                    queue_cap: 0,
                    workers_per_shard: 1,
                    max_batch: 16,
                    deadline_ms: 0.0,
                    policy: PlacementPolicy::HotReplicate { hot: 2 },
                    pooled: true,
                    tune: None,
                    trace: None,
                },
                &weights,
            );
            let served = std::thread::scope(|s| {
                s.spawn(|| {
                    for r in &seq {
                        let id = ids[r.matrix_idx];
                        server.submit(Request::new(id, inputs[&id].clone()));
                    }
                    server.close();
                });
                server.serve()
            });
            service::telemetry::shard_table(
                &server.snapshots(t0.elapsed().as_secs_f64()),
            )
            .print();
            (served, server.merged_stats())
        };
        let wall = t0.elapsed().as_secs_f64();
        let label = if shards == 1 {
            "global queue, 8 workers"
        } else {
            "8 shards x 1 worker"
        };
        println!(
            "{label:<24} {:>9.1} req/s  p50 {:>8.3} ms  p99 {:>8.3} ms  \
             mean batch {:>5.2}  ({served} served)",
            n_req as f64 / wall,
            merged.latency_percentile(50.0),
            merged.latency_percentile(99.0),
            merged.mean_batch(),
        );
    }
}

// Static vs tuned plans, A/B over the *virtual-time* replay: the same
// closed-loop Zipf stream served once with frozen static plans and
// once with the online autotuner exploring the (schedule x thread)
// ladder on the deterministic cost model. One client keeps every
// dispatch a singleton, so the A/B isolates the plan choice — and the
// whole comparison is bit-reproducible, which lets quick mode assert
// convergence (the CI autotune smoke step).
fn section_autotune(
    suite: &ft2000_spmv::corpus::suite::SuiteSpec,
    quick: bool,
) {
    println!();
    println!("static vs tuned plan serving (virtual-time replay A/B):");
    let spec = WorkloadSpec {
        requests: if quick { 1200 } else { 4000 },
        popularity: Popularity::Zipf { s: 1.2 },
        arrivals: Arrivals::Closed { clients: 1 },
        seed: 0x7E57_5EED,
    };
    let rcfg = ReplayConfig { execute: false, ..ReplayConfig::default() };
    let mut t = Table::new(
        "Static vs tuned plan serving (same Zipf stream, virtual time)",
        &["mode", "req/s", "p50 ms", "p99 ms", "mean ms", "promotions"],
    );
    let mut reports = Vec::new();
    for tuned in [false, true] {
        let mut reg = MatrixRegistry::new();
        let ids = reg.register_suite(suite, Some(8));
        let engine = ServeEngine::new(
            reg,
            Planner::Heuristic,
            PlanConfig::default(),
        );
        let engine = if tuned {
            engine.with_tuner(AutotuneConfig {
                wall_clock: false,
                ..AutotuneConfig::default()
            })
        } else {
            engine
        };
        let report = replay(&engine, &ids, &spec, &rcfg).expect("replay");
        let promotions: u64 = report
            .autotune
            .as_ref()
            .map(|s| s.iter().map(|x| x.promotions).sum())
            .unwrap_or(0);
        t.row(vec![
            if tuned { "tuned".into() } else { "static".to_string() },
            format!("{:.1}", report.throughput_rps()),
            format!("{:.4}", report.stats.latency_percentile(50.0)),
            format!("{:.4}", report.stats.latency_percentile(99.0)),
            format!("{:.4}", report.stats.latency_mean()),
            promotions.to_string(),
        ]);
        if tuned {
            if let Some(summaries) = &report.autotune {
                autotune_table(summaries).print();
            }
        }
        reports.push((report, promotions));
    }
    t.print();
    let static_rps = reports[0].0.throughput_rps();
    let tuned_rps = reports[1].0.throughput_rps();
    let promotions = reports[1].1;
    println!(
        "tuned/static throughput ratio: {:.3}x ({promotions} promotions)",
        tuned_rps / static_rps
    );
    if quick {
        // The CI smoke contract: on the quick corpus the tuner must
        // find at least one better-than-static variant and must not
        // lose throughput to the static baseline overall (exploration
        // cost included).
        assert!(
            promotions >= 1,
            "autotune smoke: no promotion on the quick corpus"
        );
        assert!(
            tuned_rps >= static_rps,
            "autotune smoke: tuned serving lost to static \
             ({tuned_rps:.1} vs {static_rps:.1} req/s)"
        );
    }
}
