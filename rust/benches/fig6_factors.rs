//! Fig 6 — scatter plots + binned averages of the three identified
//! factors against 4-thread speedup.
//!
//! Paper shape: speedup declines as job_var grows past ~0.45 (b), as
//! L2_DCMR_change grows (d), and as normalized nnz_var grows (f).

mod common;

use ft2000_spmv::coordinator::{report, Campaign, ProfileConfig};
use ft2000_spmv::util::stats;
use ft2000_spmv::util::table::ascii_scatter;

fn main() {
    let suite = common::suite_from_env();
    common::banner(
        "Fig 6",
        "correspondence between the three factors and SpMV speedup",
    );
    eprintln!("sweeping {} matrices...", suite.total());
    let profiles = Campaign::new(suite, ProfileConfig::default()).run();
    let speedups: Vec<f64> =
        profiles.iter().map(|p| p.max_speedup()).collect();

    for (name, xs, normalize) in [
        (
            "job_var",
            profiles.iter().map(|p| p.derived.job_var).collect::<Vec<_>>(),
            false,
        ),
        (
            "L2_DCMR_change",
            profiles
                .iter()
                .map(|p| p.derived.l2_dcmr_change)
                .collect::<Vec<_>>(),
            false,
        ),
        (
            "nnz_var",
            profiles.iter().map(|p| p.features.nnz_var).collect::<Vec<_>>(),
            true,
        ),
    ] {
        let xs = if normalize {
            stats::minmax_normalize(&xs)
        } else {
            xs
        };
        println!(
            "Fig 6 ({name}) — scatter (x: {name}{}, y: 4t speedup):",
            if normalize { ", normalized" } else { "" }
        );
        println!("{}", ascii_scatter(&xs, &speedups, 64, 10));
        report::fig6_binned(&profiles, name, 6).print();
        println!(
            "pearson r({name}, speedup) = {:+.3}\n",
            stats::pearson(&xs, &speedups)
        );
    }
}
