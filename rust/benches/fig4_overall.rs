//! Fig 4 + Table 2 — overall speedup of the corpus, 1–4 threads on a
//! core-group.
//!
//! Paper shape: most 4-thread speedups lie between 1x and 2x; a small
//! tail is hyper-linear; averages 1.0 / 1.50 / 1.77 / 1.93.

mod common;

use ft2000_spmv::coordinator::{report, Campaign, ProfileConfig};
use ft2000_spmv::util::table::ascii_scatter;

fn main() {
    let suite = common::suite_from_env();
    common::banner(
        "Fig 4 + Table 2",
        "overall speedup of SpMV in 1-4 threads on FT-2000+ (one core-group)",
    );
    eprintln!("sweeping {} matrices...", suite.total());
    let profiles = Campaign::new(suite, ProfileConfig::default()).run();

    report::table2_average_speedups(&profiles).print();
    report::fig4_distribution(&profiles).print();

    // Fig 4 as an ascii scatter: matrix index vs 4-thread speedup.
    let xs: Vec<f64> = (0..profiles.len()).map(|i| i as f64).collect();
    let ys: Vec<f64> = profiles.iter().map(|p| p.max_speedup()).collect();
    println!("Fig 4 — speedup per matrix (x: matrix, y: 4t speedup):");
    println!("{}", ascii_scatter(&xs, &ys, 72, 12));
}
