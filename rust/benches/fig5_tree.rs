//! Fig 5 — a tree picked from the regression forests, plus the
//! feature-importance ranking of §4.2.3.
//!
//! Paper result: the top factors are the nonzero allocation
//! (`job_var`), the shared L2 cache (`L2_DCMR`/`L2_DCMR_change`), and
//! the nnz variance across rows (`nnz_var`).

mod common;

use ft2000_spmv::coordinator::{build_dataset, Campaign, ProfileConfig};
use ft2000_spmv::mlmodel::{Forest, ForestParams};
use ft2000_spmv::util::table::Table;

fn main() {
    let suite = common::suite_from_env();
    common::banner(
        "Fig 5",
        "regression-tree model of 4-thread speedup; top-3 factor check",
    );
    eprintln!("profiling {} matrices...", suite.total());
    let profiles = Campaign::new(suite, ProfileConfig::default()).run();
    let data = build_dataset(&profiles);
    let (train, test) = data.split(0.9, 0x5EED);
    let forest = Forest::fit(&train, ForestParams::default());

    let ranked = forest.ranked_features();
    let mut t = Table::new(
        "Feature importances (forest, normalized impurity decrease)",
        &["rank", "feature", "importance"],
    );
    for (i, (name, v)) in ranked.iter().enumerate() {
        t.row(vec![(i + 1).to_string(), name.clone(), format!("{v:.4}")]);
    }
    t.print();
    println!(
        "model: train mse {:.4}, held-out mse {:.4} ({}/{} split)\n",
        forest.mse(&train),
        forest.mse(&test),
        train.len(),
        test.len()
    );

    let top3: Vec<&str> =
        ranked.iter().take(3).map(|(n, _)| n.as_str()).collect();
    // The paper names its top factors as "the nonzero allocation, the
    // shared L2 cache, and the nnz variance across rows" — the L2
    // factor shows up as either L2_DCMR or L2_DCMR_change depending on
    // which projection of the contention the tree picks.
    let imbalance = top3.contains(&"job_var");
    let l2 = top3.contains(&"L2_DCMR") || top3.contains(&"L2_DCMR_change");
    let structure = top3.contains(&"nnz_var")
        || top3.contains(&"nnz_max")
        || top3.contains(&"nnz_avg");
    println!(
        "paper's factor families in our top-3 {top3:?}:\n  nonzero allocation (job_var): {imbalance}\n  shared L2 cache (L2_DCMR*):   {l2}\n  row structure (nnz_*):        {structure}\n"
    );

    println!("Fig 5 — a tree picked from the regression forest:\n");
    println!("{}", forest.representative_tree(&train).render());
}
