//! Learned schedule/format selection — the paper's closing future-work
//! item realized: "we will extract a detailed profile of a given
//! sparse matrix before performing the SpMV computation ... based on
//! this information, we can decide whether to apply these
//! optimizations or not".
//!
//! Pipeline: for each corpus matrix, simulate every candidate
//! schedule at 4 threads, label with the fastest; train a
//! classification tree on **static, pre-run features only** (matrix
//! structure + locality score — no hardware counters, so the decision
//! costs one pass over the matrix); report accuracy and the achieved
//! fraction of the oracle's speedup.

use crate::analysis::reuse::x_reuse_profile;
use crate::mlmodel::classify::{ClassTree, ClassTreeParams};
use crate::mlmodel::Dataset;
use crate::reorder::locality_score;
use crate::sched::{partition, Schedule};
use crate::sparse::features::job_var;
use crate::sparse::{Csr, MatrixFeatures};

use super::{simulate_point, ProfileConfig};

/// The candidate schedules the selector chooses among.
pub fn candidates() -> Vec<Schedule> {
    vec![
        Schedule::CsrRowStatic,
        Schedule::CsrRowBalanced,
        Schedule::Csr5Tiles { tile_nnz: 256 },
    ]
}

pub const SELECT_FEATURES: [&str; 7] = [
    "n_rows",
    "nnz_avg",
    "nnz_var",
    "nnz_max_ratio",
    "job_var_static",
    "locality_score",
    "x_miss_l1",
];

/// Static (pre-run) feature vector for schedule selection.
pub fn static_features(csr: &Csr) -> Vec<f64> {
    let f = MatrixFeatures::extract(csr);
    let jv =
        job_var(&partition(csr, Schedule::CsrRowStatic, 4).thread_nnz(csr));
    let reuse = x_reuse_profile(csr);
    vec![
        f.n_rows as f64,
        f.nnz_avg,
        f.nnz_var,
        f.nnz_max as f64 / f.nnz_avg.max(1e-9),
        jv,
        locality_score(csr, 64),
        reuse.miss_rate_at(512), // 32 KB L1 in 64 B lines
    ]
}

/// SpMV invocations a format conversion is amortized over (an
/// iterative solver runs tens-to-hundreds of SpMVs per matrix; the
/// paper's §5.2.3 caveat — "there is an overhead for format
/// conversion" — is what keeps CSR competitive on regular matrices).
pub const AMORTIZATION_SPMVS: f64 = 50.0;
/// CSR→CSR5 conversion costs ~this many streaming passes over the
/// nonzeros (tile descriptors + bit flags).
pub const CSR5_CONVERT_PASSES: f64 = 2.0;

/// One labeled training sample.
#[derive(Clone, Debug)]
pub struct LabeledMatrix {
    pub name: String,
    pub features: Vec<f64>,
    /// Simulated 4-thread wall seconds per candidate, including the
    /// amortized conversion cost.
    pub seconds: Vec<f64>,
    pub best: usize,
}

/// Simulate all candidates for one matrix and label it.
pub fn label_matrix(csr: &Csr, name: &str) -> LabeledMatrix {
    // Conversion baseline: one single-thread streaming pass ~= the
    // 1-thread CSR SpMV time.
    let (res_1t, _) =
        simulate_point(csr, &ProfileConfig::default(), 1);
    let pass = res_1t.wall_seconds();
    let mut seconds = Vec::new();
    for sched in candidates() {
        let cfg = ProfileConfig { schedule: sched, ..Default::default() };
        let (res, _) = simulate_point(csr, &cfg, 4);
        let convert = match sched {
            Schedule::Csr5Tiles { .. } => {
                CSR5_CONVERT_PASSES * pass / AMORTIZATION_SPMVS
            }
            _ => 0.0,
        };
        seconds.push(res.wall_seconds() + convert);
    }
    let best = seconds
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0);
    LabeledMatrix {
        name: name.to_string(),
        features: static_features(csr),
        seconds,
        best,
    }
}

/// The trained selector.
#[derive(Clone)]
pub struct FormatSelector {
    pub tree: ClassTree,
}

impl FormatSelector {
    pub fn train(samples: &[LabeledMatrix]) -> FormatSelector {
        let mut d = Dataset::new(
            SELECT_FEATURES.iter().map(|s| s.to_string()).collect(),
        );
        for s in samples {
            d.push(s.features.clone(), s.best as f64);
        }
        let tree =
            ClassTree::fit(&d, candidates().len(), ClassTreeParams::default());
        FormatSelector { tree }
    }

    pub fn select(&self, csr: &Csr) -> Schedule {
        let k = self.tree.predict(&static_features(csr));
        candidates()[k.min(candidates().len() - 1)]
    }

    /// Evaluation: (accuracy, achieved/oracle performance ratio).
    ///
    /// The performance ratio is the honest metric: picking a
    /// near-tied schedule barely costs anything even when the label
    /// disagrees.
    pub fn evaluate(&self, samples: &[LabeledMatrix]) -> (f64, f64) {
        if samples.is_empty() {
            return (0.0, 0.0);
        }
        let mut hits = 0usize;
        let mut ratio_sum = 0.0;
        for s in samples {
            let pick = self.tree.predict(&s.features);
            if pick == s.best {
                hits += 1;
            }
            ratio_sum += s.seconds[s.best] / s.seconds[pick].max(1e-300);
        }
        (
            hits as f64 / samples.len() as f64,
            ratio_sum / samples.len() as f64,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::suite::SuiteSpec;
    use crate::corpus::NamedMatrix;

    fn labeled_corpus() -> Vec<LabeledMatrix> {
        let spec = SuiteSpec::tiny();
        spec.entries()
            .iter()
            .map(|e| {
                let m = spec.materialize(e);
                label_matrix(&m.csr, &e.name)
            })
            .collect()
    }

    #[test]
    fn labels_pick_fastest() {
        let samples = labeled_corpus();
        for s in &samples {
            let min = s
                .seconds
                .iter()
                .cloned()
                .fold(f64::INFINITY, f64::min);
            assert_eq!(s.seconds[s.best], min, "{}", s.name);
        }
    }

    #[test]
    fn exdata1_labeled_balanced_or_csr5() {
        let s = label_matrix(&NamedMatrix::Exdata1.generate(), "exdata_1");
        // Static CSR is the imbalance pathology; anything else wins.
        assert_ne!(
            candidates()[s.best],
            Schedule::CsrRowStatic,
            "seconds: {:?}",
            s.seconds
        );
    }

    #[test]
    fn selector_beats_static_default() {
        let samples = labeled_corpus();
        let sel = FormatSelector::train(&samples);
        let (acc, ratio) = sel.evaluate(&samples);
        assert!(acc > 0.5, "training accuracy too low: {acc}");
        assert!(ratio > 0.9, "achieved/oracle: {ratio}");
        // Compare against always-static: the selector must achieve a
        // higher fraction of oracle performance.
        let static_ratio = samples
            .iter()
            .map(|s| s.seconds[s.best] / s.seconds[0])
            .sum::<f64>()
            / samples.len() as f64;
        assert!(
            ratio >= static_ratio,
            "selector {ratio} vs always-static {static_ratio}"
        );
    }

    #[test]
    fn static_features_are_finite() {
        for m in NamedMatrix::ALL {
            let f = static_features(&m.generate());
            assert_eq!(f.len(), SELECT_FEATURES.len());
            assert!(f.iter().all(|v| v.is_finite()), "{}: {f:?}", m.name());
        }
    }
}
