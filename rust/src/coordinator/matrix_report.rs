//! Full markdown characterization report for one matrix — the
//! "performance profiling tool" the paper's abstract promises
//! ("a performance profiling tool to guide the optimization of SpMV").
//!
//! Combines: structure (features, spy plot, degree histogram,
//! bandwidth), x-reuse (stack distances), the simulated FT-2000+
//! scalability sweep with per-thread counters, the advisor's
//! diagnosis, and the learned schedule selection.

use std::fmt::Write as _;

use crate::analysis::reuse::x_reuse_profile;
use crate::analysis::spy;
use crate::reorder::locality_score;
use crate::sparse::Csr;

use super::advisor;
use super::format_select;
use super::{profile_matrix, ProfileConfig};

/// Render the report (markdown).
pub fn matrix_report(csr: &Csr, name: &str) -> String {
    let mut out = String::new();
    let profile = profile_matrix(csr, name, &ProfileConfig::default());
    let f = &profile.features;
    let _ = writeln!(out, "# SpMV characterization: {name}\n");

    // --- structure ------------------------------------------------------
    let _ = writeln!(out, "## Structure\n");
    let _ = writeln!(
        out,
        "| rows | cols | nnz | nnz_avg | nnz_max | nnz_var | bandwidth (max/mean) |"
    );
    let _ = writeln!(out, "|---|---|---|---|---|---|---|");
    let (bw_max, bw_mean) = spy::bandwidth(csr);
    let _ = writeln!(
        out,
        "| {} | {} | {} | {:.2} | {} | {:.2} | {bw_max} / {bw_mean:.1} |",
        f.n_rows, f.n_cols, f.nnz, f.nnz_avg, f.nnz_max, f.nnz_var
    );
    let _ = writeln!(out, "\n```\n{}```\n", spy::spy(csr, 12, 48));
    let _ = writeln!(out, "Row-degree histogram:\n");
    for (label, count) in spy::degree_histogram(csr) {
        let _ = writeln!(out, "* {label}: {count} rows");
    }

    // --- locality ---------------------------------------------------------
    let reuse = x_reuse_profile(csr);
    let _ = writeln!(out, "\n## x-vector locality\n");
    let _ = writeln!(
        out,
        "* adjacent-row block overlap: {:.3}",
        locality_score(csr, 64)
    );
    let _ = writeln!(
        out,
        "* x stack-distance median: {} lines; cold share {:.1}%",
        reuse.median_distance(),
        100.0 * reuse.cold as f64 / reuse.total.max(1) as f64
    );
    for (label, lines) in
        [("32 KB L1", 512usize), ("2 MB L2", 32_768), ("8 MB", 131_072)]
    {
        let _ = writeln!(
            out,
            "* est. x miss rate @ {label}: {:.1}%",
            100.0 * reuse.miss_rate_at(lines)
        );
    }

    // --- simulated scalability -------------------------------------------
    let _ = writeln!(out, "\n## Simulated FT-2000+ scalability (CSR static, one core-group)\n");
    let _ = writeln!(out, "| threads | speedup | Gflops | L2_DCMR (slowest) |");
    let _ = writeln!(out, "|---|---|---|---|");
    for (i, nt) in profile.thread_counts.iter().enumerate() {
        let dcmr = if i == profile.thread_counts.len() - 1 {
            format!("{:.3}", profile.derived.l2_dcmr_mt_slowest)
        } else if i == 0 {
            format!("{:.3}", profile.derived.l2_dcmr_1t)
        } else {
            "-".into()
        };
        let _ = writeln!(
            out,
            "| {nt} | {:.3}x | {:.3} | {dcmr} |",
            profile.speedups[i], profile.gflops[i]
        );
    }
    let _ = writeln!(
        out,
        "\njob_var = {:.3}, L2_DCMR_change = {:+.4}, IPC(1t) = {:.3}",
        profile.derived.job_var,
        profile.derived.l2_dcmr_change,
        profile.derived.ipc_1t
    );

    // --- diagnosis ---------------------------------------------------------
    let _ = writeln!(out, "\n## Diagnosis & recommendations\n");
    for line in advisor::advise(csr, &profile) {
        let _ = writeln!(out, "* {line}");
    }
    let label = format_select::label_matrix(csr, name);
    let picked = format_select::candidates()[label.best];
    let _ = writeln!(
        out,
        "* fastest schedule among candidates (simulated, conversion-amortized): **{}**",
        picked.name()
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::NamedMatrix;

    #[test]
    fn report_contains_all_sections() {
        let csr = NamedMatrix::Exdata1.generate();
        let r = matrix_report(&csr, "exdata_1");
        for section in [
            "# SpMV characterization: exdata_1",
            "## Structure",
            "## x-vector locality",
            "## Simulated FT-2000+ scalability",
            "## Diagnosis & recommendations",
            "fastest schedule",
        ] {
            assert!(r.contains(section), "missing '{section}'");
        }
        // exdata_1 must be diagnosed as imbalanced.
        assert!(r.contains("load imbalance"), "{r}");
    }

    #[test]
    fn report_on_tiny_matrix() {
        let r = matrix_report(&crate::sparse::Csr::identity(16), "eye");
        assert!(r.contains("| 16 | 16 | 16 |"));
    }
}
