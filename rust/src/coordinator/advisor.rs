//! Scalability advisor — the paper's closing claim made concrete:
//! "extract a detailed profile of a given sparse matrix before
//! performing the SpMV computation ... based on this information, we
//! can decide whether to apply these optimizations or not" (§5.2.3).
//!
//! Diagnoses the dominant bottleneck from the Table-3 features and
//! recommends the matching §5.2 optimization:
//!
//! * `job_var >= 0.45`            → switch to CSR5 (§5.2.1);
//! * rising `L2_DCMR_change` with high `nnz_avg` → private-L2
//!   placement (§5.2.2) — skipped when `nnz_avg < 3` (the asia_osm
//!   case where the shared L2 already suffices);
//! * poor `x` locality (low block-overlap score) with balanced rows
//!   → locality-aware reordering (§5.2.3);
//! * small working set → expect hyper-linear scaling, leave alone.

use crate::reorder::{locality_score, DEFAULT_BLOCKS};
use crate::sparse::Csr;

use super::MatrixProfile;

/// The paper's imbalance threshold (Fig 6b).
pub const JOB_VAR_THRESHOLD: f64 = 0.45;
/// L2 miss-rate growth that signals cache contention (Fig 6d).
pub const L2_CHANGE_THRESHOLD: f64 = 0.02;
/// Shared-L2 probe intensity (L2_DCA / TOT_INS) above which the
/// core-group's L2 queues under 4 gather-heavy threads.
pub const L2_PROBE_THRESHOLD: f64 = 0.08;
/// Degree below which private L2 is not worth it (asia_osm, §5.2.2).
pub const LOW_DEGREE: f64 = 3.0;
/// Block-overlap score under which reordering is recommended.
pub const LOCALITY_THRESHOLD: f64 = 0.35;

/// One diagnosis with its recommended action.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Advice {
    UseCsr5,
    UsePrivateL2,
    UseLocalityReorder,
    FitsInCache,
    NoActionNeeded,
}

impl Advice {
    pub fn describe(&self) -> &'static str {
        match self {
            Advice::UseCsr5 => {
                "load imbalance (job_var >= 0.45): switch to CSR5 tiles \
                 (§5.2.1 — paper improved avg speedup 1.632x -> 2.023x)"
            }
            Advice::UsePrivateL2 => {
                "shared-L2 contention (L2_DCMR rising, high nnz_avg): pin \
                 threads to separate core-groups (§5.2.2 — paper: 1.93x -> \
                 3.40x corpus average)"
            }
            Advice::UseLocalityReorder => {
                "poor x-vector locality across adjacent rows: apply the \
                 locality-aware row reorder (§5.2.3 — paper: +71.7% at 64 \
                 threads on the synthesized workload)"
            }
            Advice::FitsInCache => {
                "working set fits the shared L2: expect hyper-linear \
                 scaling; no optimization needed"
            }
            Advice::NoActionNeeded => {
                "no dominant bottleneck detected; CSR static scheduling is \
                 adequate"
            }
        }
    }
}

/// Rank the applicable optimizations for this matrix.
pub fn diagnose(csr: &Csr, profile: &MatrixProfile) -> Vec<Advice> {
    let mut out = Vec::new();
    let d = &profile.derived;
    let f = &profile.features;
    if d.job_var >= JOB_VAR_THRESHOLD {
        out.push(Advice::UseCsr5);
    }
    let l2_pressure = d.l2_dcmr_change > L2_CHANGE_THRESHOLD
        || d.l2_probe_rate_1t > L2_PROBE_THRESHOLD;
    if l2_pressure && f.nnz_avg >= LOW_DEGREE {
        out.push(Advice::UsePrivateL2);
    }
    let loc = locality_score(csr, DEFAULT_BLOCKS);
    if loc < LOCALITY_THRESHOLD && d.job_var < JOB_VAR_THRESHOLD {
        out.push(Advice::UseLocalityReorder);
    }
    if out.is_empty() {
        // 2 MB shared L2 on the FT-2000+ core-group.
        if csr.working_set_bytes() <= 2 * 1024 * 1024 {
            out.push(Advice::FitsInCache);
        } else {
            out.push(Advice::NoActionNeeded);
        }
    }
    out
}

/// Human-readable advice lines.
pub fn advise(csr: &Csr, profile: &MatrixProfile) -> Vec<String> {
    diagnose(csr, profile)
        .into_iter()
        .map(|a| a.describe().to_string())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{profile_matrix, ProfileConfig};
    use crate::corpus::generators;
    use crate::corpus::NamedMatrix;
    use crate::util::rng::Pcg32;

    fn profile(csr: &Csr) -> MatrixProfile {
        profile_matrix(csr, "t", &ProfileConfig::default())
    }

    #[test]
    fn exdata1_gets_csr5_advice() {
        let csr = NamedMatrix::Exdata1.generate();
        let p = profile(&csr);
        assert!(diagnose(&csr, &p).contains(&Advice::UseCsr5));
    }

    #[test]
    fn conf5_gets_private_l2_advice() {
        let csr = NamedMatrix::Conf5_4_8x8_20.generate();
        let p = profile(&csr);
        let advice = diagnose(&csr, &p);
        assert!(
            advice.contains(&Advice::UsePrivateL2),
            "conf5 should be flagged for contention: {advice:?} \
             (l2_change={:.4}, nnz_avg={:.1})",
            p.derived.l2_dcmr_change,
            p.features.nnz_avg
        );
    }

    #[test]
    fn asia_osm_not_private_l2() {
        // nnz_avg < 3: the paper found private L2 gains only 2.6%.
        let csr = NamedMatrix::AsiaOsm.generate();
        let p = profile(&csr);
        assert!(!diagnose(&csr, &p).contains(&Advice::UsePrivateL2));
    }

    #[test]
    fn poor_locality_gets_reorder_advice() {
        let mut rng = Pcg32::new(3);
        let csr = generators::poor_locality(4096, 4, 64, &mut rng);
        let p = profile(&csr);
        assert!(
            diagnose(&csr, &p).contains(&Advice::UseLocalityReorder),
            "{:?}",
            diagnose(&csr, &p)
        );
    }

    #[test]
    fn small_banded_fits_cache() {
        let mut rng = Pcg32::new(4);
        let csr = generators::banded(2048, 4, &mut rng);
        let p = profile(&csr);
        let d = diagnose(&csr, &p);
        assert!(
            d.contains(&Advice::FitsInCache)
                || d.contains(&Advice::NoActionNeeded),
            "{d:?}"
        );
    }

    #[test]
    fn every_advice_has_description() {
        for a in [
            Advice::UseCsr5,
            Advice::UsePrivateL2,
            Advice::UseLocalityReorder,
            Advice::FitsInCache,
            Advice::NoActionNeeded,
        ] {
            assert!(!a.describe().is_empty());
        }
    }
}
