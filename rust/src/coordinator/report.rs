//! Report writers: CSV dumps and markdown summaries of campaign
//! results (what the benches print, what EXPERIMENTS.md records).

use std::io::Write;

use crate::util::stats;
use crate::util::table::Table;

use super::{MatrixProfile, FEATURE_NAMES};

/// CSV of all profiles: features + per-thread-count speedups.
pub fn write_csv<W: Write>(
    w: &mut W,
    profiles: &[MatrixProfile],
) -> std::io::Result<()> {
    write!(w, "name")?;
    for f in FEATURE_NAMES {
        write!(w, ",{f}")?;
    }
    if let Some(p) = profiles.first() {
        for nt in &p.thread_counts {
            write!(w, ",speedup_{nt}t")?;
        }
        for nt in &p.thread_counts {
            write!(w, ",gflops_{nt}t")?;
        }
    }
    writeln!(w)?;
    for p in profiles {
        write!(w, "{}", p.name)?;
        for v in super::feature_vector(p) {
            write!(w, ",{v}")?;
        }
        for s in &p.speedups {
            write!(w, ",{s:.4}")?;
        }
        for g in &p.gflops {
            write!(w, ",{g:.4}")?;
        }
        writeln!(w)?;
    }
    Ok(())
}

/// Table 2: average speedup per thread count.
pub fn table2_average_speedups(profiles: &[MatrixProfile]) -> Table {
    let mut t = Table::new(
        "Table 2 — average speedup of SpMV with multi-threads over a single thread",
        &["#threads", "speedup"],
    );
    if profiles.is_empty() {
        return t;
    }
    let counts = &profiles[0].thread_counts;
    for (i, nt) in counts.iter().enumerate() {
        let avg = stats::mean(
            &profiles.iter().map(|p| p.speedups[i]).collect::<Vec<_>>(),
        );
        t.row(vec![nt.to_string(), format!("{avg:.2}x")]);
    }
    t
}

/// Fig 4 summary: distribution of max-thread speedups.
pub fn fig4_distribution(profiles: &[MatrixProfile]) -> Table {
    let speedups: Vec<f64> =
        profiles.iter().map(|p| p.max_speedup()).collect();
    let mut t = Table::new(
        "Fig 4 — distribution of 4-thread speedups over the corpus",
        &["stat", "value"],
    );
    t.row(vec!["matrices".into(), speedups.len().to_string()]);
    t.row(vec!["mean".into(), format!("{:.3}x", stats::mean(&speedups))]);
    t.row(vec![
        "p10".into(),
        format!("{:.3}x", stats::percentile(&speedups, 10.0)),
    ]);
    t.row(vec![
        "median".into(),
        format!("{:.3}x", stats::percentile(&speedups, 50.0)),
    ]);
    t.row(vec![
        "p90".into(),
        format!("{:.3}x", stats::percentile(&speedups, 90.0)),
    ]);
    t.row(vec![
        "max".into(),
        format!(
            "{:.3}x",
            speedups.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
        ),
    ]);
    let hyper = speedups.iter().filter(|&&s| s > 4.0).count();
    t.row(vec!["hyper-linear (>4x)".into(), hyper.to_string()]);
    let sub2 = speedups.iter().filter(|&&s| s < 2.0).count();
    t.row(vec![
        "below 2x".into(),
        format!(
            "{} ({:.0}%)",
            sub2,
            100.0 * sub2 as f64 / speedups.len().max(1) as f64
        ),
    ]);
    t
}

/// Fig 6 binned-average rows for one factor.
pub fn fig6_binned(
    profiles: &[MatrixProfile],
    factor: &str,
    bins: usize,
) -> Table {
    let xs: Vec<f64> = profiles
        .iter()
        .map(|p| match factor {
            "job_var" => p.derived.job_var,
            "L2_DCMR_change" => p.derived.l2_dcmr_change,
            "nnz_var" => p.features.nnz_var,
            other => panic!("unknown factor {other}"),
        })
        .collect();
    let xs = if factor == "nnz_var" {
        stats::minmax_normalize(&xs) // the paper normalizes nnz_var
    } else {
        xs
    };
    let ys: Vec<f64> = profiles.iter().map(|p| p.max_speedup()).collect();
    let mut t = Table::new(
        format!("Fig 6 — binned average speedup vs {factor}"),
        &[factor, "avg speedup", "n"],
    );
    for (center, mean, count) in stats::binned_mean(&xs, &ys, bins) {
        t.row(vec![
            format!("{center:.3}"),
            format!("{mean:.3}x"),
            count.to_string(),
        ]);
    }
    t
}

/// Correlation summary of the three Fig 6 factors against speedup.
pub fn factor_correlations(profiles: &[MatrixProfile]) -> Table {
    let ys: Vec<f64> = profiles.iter().map(|p| p.max_speedup()).collect();
    let mut t = Table::new(
        "Factor correlations with 4-thread speedup",
        &["factor", "pearson r"],
    );
    for (name, xs) in [
        (
            "job_var",
            profiles.iter().map(|p| p.derived.job_var).collect::<Vec<_>>(),
        ),
        (
            "L2_DCMR_change",
            profiles
                .iter()
                .map(|p| p.derived.l2_dcmr_change)
                .collect::<Vec<_>>(),
        ),
        (
            "nnz_var",
            profiles.iter().map(|p| p.features.nnz_var).collect::<Vec<_>>(),
        ),
    ] {
        t.row(vec![name.into(), format!("{:+.3}", stats::pearson(&xs, &ys))]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{profile_matrix, ProfileConfig};
    use crate::corpus::generators::banded;
    use crate::util::rng::Pcg32;

    fn profiles() -> Vec<MatrixProfile> {
        let mut rng = Pcg32::new(2);
        (0..3)
            .map(|i| {
                let csr = banded(512 + i * 256, 6, &mut rng);
                profile_matrix(
                    &csr,
                    &format!("m{i}"),
                    &ProfileConfig::default(),
                )
            })
            .collect()
    }

    #[test]
    fn csv_well_formed() {
        let ps = profiles();
        let mut buf = Vec::new();
        write_csv(&mut buf, &ps).unwrap();
        let text = String::from_utf8(buf).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 1 + ps.len());
        let header_cols = lines[0].split(',').count();
        for l in &lines[1..] {
            assert_eq!(l.split(',').count(), header_cols);
        }
        assert!(lines[0].contains("job_var"));
    }

    #[test]
    fn tables_render() {
        let ps = profiles();
        assert!(table2_average_speedups(&ps).to_markdown().contains("1"));
        assert!(fig4_distribution(&ps).to_markdown().contains("median"));
        assert!(fig6_binned(&ps, "job_var", 4).to_markdown().contains("Fig 6"));
        assert!(factor_correlations(&ps).to_markdown().contains("pearson"));
    }

    #[test]
    #[should_panic(expected = "unknown factor")]
    fn fig6_rejects_bad_factor() {
        fig6_binned(&profiles(), "bogus", 4);
    }
}
