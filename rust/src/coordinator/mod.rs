//! Campaign orchestration — the L3 coordination layer.
//!
//! A *profile* runs one matrix through the simulator at each thread
//! count (the paper's 1–4 on a core-group, up to 64 chip-wide),
//! collecting PAPI counters, speedups, and the Table-3 derived
//! features. A *campaign* sweeps a corpus in parallel worker threads
//! and assembles the regression dataset of §4.2.1.

pub mod advisor;
pub mod format_select;
pub mod matrix_report;
pub mod report;

use std::sync::atomic::Ordering;
use std::sync::Mutex;

use crate::corpus::suite::SuiteSpec;
use crate::counters::{Counters, Derived};
use crate::mlmodel::Dataset;
use crate::sched::{csr5_for, partition, Partition, Schedule};
use crate::sim::engine::{simulate, SimResult, ThreadSpec};
use crate::sim::topology::{Placement, Topology};
use crate::sparse::{Csr, MatrixFeatures};
use crate::trace::{AccessGen, Csr5Trace, CsrMultiTrace};
use crate::util::ordatomic::OrdAtomicUsize;

/// Experiment configuration for one profiling run.
#[derive(Clone, Debug)]
pub struct ProfileConfig {
    pub topo: Topology,
    pub schedule: Schedule,
    pub placement: Placement,
    /// Thread counts to sweep; must start with 1 (speedup baseline).
    pub threads: Vec<usize>,
}

impl Default for ProfileConfig {
    fn default() -> Self {
        ProfileConfig {
            topo: Topology::ft2000plus(),
            schedule: Schedule::CsrRowStatic,
            placement: Placement::CoreGroupFirst,
            threads: vec![1, 2, 3, 4],
        }
    }
}

impl ProfileConfig {
    /// §5.2.2's private-L2 mode.
    pub fn private_l2() -> Self {
        ProfileConfig { placement: Placement::PrivateL2, ..Default::default() }
    }
}

/// Everything measured for one matrix under one config.
#[derive(Clone, Debug)]
pub struct MatrixProfile {
    pub name: String,
    pub features: MatrixFeatures,
    pub thread_counts: Vec<usize>,
    pub wall_seconds: Vec<f64>,
    /// Normalized to the 1-thread run (paper convention).
    pub speedups: Vec<f64>,
    pub gflops: Vec<f64>,
    pub derived: Derived,
    pub counters_1t: Counters,
    /// Per-thread counters of the max-thread run.
    pub counters_mt: Vec<Counters>,
}

impl MatrixProfile {
    /// Speedup at the highest thread count.
    pub fn max_speedup(&self) -> f64 {
        *self.speedups.last().unwrap_or(&1.0)
    }
}

/// Simulate one (matrix, thread-count) point; returns the sim result
/// plus the nonzero allocation of the partition.
pub fn simulate_point(
    csr: &Csr,
    cfg: &ProfileConfig,
    n_threads: usize,
) -> (SimResult, Vec<usize>) {
    let part = partition(csr, cfg.schedule, n_threads);
    let thread_nnz = part.thread_nnz(csr);
    let csr5 = csr5_for(csr, cfg.schedule);
    let mut threads: Vec<ThreadSpec<Box<dyn AccessGen + '_>>> = Vec::new();
    match &part {
        Partition::Rows { per_thread } => {
            for (t, ranges) in per_thread.iter().enumerate() {
                threads.push(ThreadSpec {
                    gen: Box::new(CsrMultiTrace::new(csr, ranges.clone())),
                    core: cfg.placement.core_of(t, &cfg.topo),
                });
            }
        }
        Partition::Tiles { per_thread, .. } => {
            let csr5 = csr5.as_ref().expect("tile schedule implies csr5");
            for (t, &(t0, t1)) in per_thread.iter().enumerate() {
                threads.push(ThreadSpec {
                    gen: Box::new(Csr5Trace::new(csr5, t0, t1)),
                    core: cfg.placement.core_of(t, &cfg.topo),
                });
            }
        }
        Partition::SellChunks { c, sigma, per_thread } => {
            // Modeled as per-row CSR accesses over each slot's
            // permuted rows: the memory traffic (A streamed once, x
            // gathered per nonzero) matches; the intra-chunk SIMD
            // shuffle is elided, consistent with the CSR5 trace's
            // simplification.
            let perm = crate::sparse::sell::sell_perm(csr, *c, *sigma);
            for (t, &(k0, k1)) in per_thread.iter().enumerate() {
                let lo = (k0 * c).min(csr.n_rows);
                let hi = (k1 * c).min(csr.n_rows);
                let rows: Vec<(usize, usize)> = perm[lo..hi]
                    .iter()
                    .map(|&r| (r as usize, r as usize + 1))
                    .collect();
                threads.push(ThreadSpec {
                    gen: Box::new(CsrMultiTrace::new(csr, rows)),
                    core: cfg.placement.core_of(t, &cfg.topo),
                });
            }
        }
    }
    (simulate(&cfg.topo, threads), thread_nnz)
}

/// Profile a matrix across the configured thread counts.
pub fn profile_matrix(
    csr: &Csr,
    name: &str,
    cfg: &ProfileConfig,
) -> MatrixProfile {
    assert_eq!(cfg.threads.first(), Some(&1), "first sweep point must be 1");
    let features = MatrixFeatures::extract(csr);
    let flops = 2.0 * csr.nnz() as f64;
    let mut wall = Vec::new();
    let mut gflops = Vec::new();
    let mut counters_1t = Counters::default();
    let mut counters_mt = Vec::new();
    let mut last_thread_nnz = vec![csr.nnz()];
    for &nt in &cfg.threads {
        let (res, thread_nnz) = simulate_point(csr, cfg, nt);
        wall.push(res.wall_seconds());
        gflops.push(res.gflops(flops));
        if nt == 1 {
            counters_1t = res.per_thread[0];
        }
        if nt == *cfg.threads.last().unwrap() {
            counters_mt = res.per_thread.clone();
            last_thread_nnz = thread_nnz;
        }
    }
    let speedups: Vec<f64> = wall.iter().map(|&t| wall[0] / t).collect();
    let derived = Derived::from_profiles(
        &counters_1t,
        if counters_mt.is_empty() {
            std::slice::from_ref(&counters_1t)
        } else {
            &counters_mt
        },
        &last_thread_nnz,
    );
    MatrixProfile {
        name: name.to_string(),
        features,
        thread_counts: cfg.threads.clone(),
        wall_seconds: wall,
        speedups,
        gflops,
        derived,
        counters_1t,
        counters_mt,
    }
}

/// A corpus-wide sweep.
#[derive(Clone, Debug)]
pub struct Campaign {
    pub spec: SuiteSpec,
    pub cfg: ProfileConfig,
    pub workers: usize,
}

impl Campaign {
    pub fn new(spec: SuiteSpec, cfg: ProfileConfig) -> Self {
        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        Campaign { spec, cfg, workers }
    }

    /// Run the sweep across worker threads. Results keep entry order.
    pub fn run(&self) -> Vec<MatrixProfile> {
        let entries = self.spec.entries();
        let n = entries.len();
        let next = OrdAtomicUsize::named(0, "campaign.next");
        let results: Mutex<Vec<Option<MatrixProfile>>> =
            Mutex::new((0..n).map(|_| None).collect());
        std::thread::scope(|s| {
            for _ in 0..self.workers.max(1) {
                s.spawn(|| loop {
                    // ord: Relaxed RMW — work-stealing ticket; each
                    // index is claimed exactly once by atomicity
                    // alone, results rendezvous through the Mutex.
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let e = &entries[i];
                    let m = self.spec.materialize(e);
                    let p = profile_matrix(&m.csr, &e.name, &self.cfg);
                    results.lock().unwrap()[i] = Some(p);
                });
            }
        });
        results
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|p| p.expect("worker completed"))
            .collect()
    }
}

/// Table-3 feature order used throughout the model/report code.
pub const FEATURE_NAMES: [&str; 9] = [
    "n_rows",
    "nnz_max",
    "nnz_avg",
    "nnz_var",
    "L1_DCMR",
    "L2_DCMR",
    "IPC",
    "L2_DCMR_change",
    "job_var",
];

/// Feature vector of one profile (Table 3 order).
pub fn feature_vector(p: &MatrixProfile) -> Vec<f64> {
    vec![
        p.features.n_rows as f64,
        p.features.nnz_max as f64,
        p.features.nnz_avg,
        p.features.nnz_var,
        p.derived.l1_dcmr_1t,
        p.derived.l2_dcmr_1t,
        p.derived.ipc_1t,
        p.derived.l2_dcmr_change,
        p.derived.job_var,
    ]
}

/// Assemble the regression dataset: features -> max-thread speedup.
pub fn build_dataset(profiles: &[MatrixProfile]) -> Dataset {
    let mut d = Dataset::new(
        FEATURE_NAMES.iter().map(|s| s.to_string()).collect(),
    );
    for p in profiles {
        d.push(feature_vector(p), p.max_speedup());
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::NamedMatrix;

    #[test]
    fn profile_shapes() {
        let csr = crate::corpus::generators::banded(
            2048,
            8,
            &mut crate::util::rng::Pcg32::new(1),
        );
        let p = profile_matrix(&csr, "banded", &ProfileConfig::default());
        assert_eq!(p.speedups.len(), 4);
        assert!((p.speedups[0] - 1.0).abs() < 1e-12);
        assert!(p.speedups.iter().all(|&s| s > 0.0));
        assert_eq!(p.counters_mt.len(), 4);
        assert!(p.gflops[0] > 0.0);
    }

    #[test]
    fn speedup_non_trivial_on_named() {
        // debr-like: balanced, good locality -> should scale decently.
        let csr = NamedMatrix::Debr.generate();
        let p = profile_matrix(&csr, "debr", &ProfileConfig::default());
        assert!(
            p.max_speedup() > 1.3,
            "debr replica should scale: {:?}",
            p.speedups
        );
    }

    #[test]
    fn exdata1_flat_speedup() {
        // The paper's imbalance pathology: speedup ~1.02x at 4 threads.
        let csr = NamedMatrix::Exdata1.generate();
        let p = profile_matrix(&csr, "exdata_1", &ProfileConfig::default());
        assert!(
            p.max_speedup() < 1.3,
            "exdata_1 must be imbalance-limited: {:?}",
            p.speedups
        );
        assert!(p.derived.job_var > 0.9);
    }

    #[test]
    fn csr5_rescues_exdata1() {
        let csr = NamedMatrix::Exdata1.generate();
        let csr_cfg = ProfileConfig::default();
        let csr5_cfg = ProfileConfig {
            schedule: Schedule::Csr5Tiles { tile_nnz: 256 },
            ..Default::default()
        };
        let a = profile_matrix(&csr, "exdata_1", &csr_cfg);
        let b = profile_matrix(&csr, "exdata_1", &csr5_cfg);
        assert!(
            b.max_speedup() > a.max_speedup() + 0.2,
            "CSR5 {:.3} should beat CSR {:.3} (Fig 7)",
            b.max_speedup(),
            a.max_speedup()
        );
        assert!(b.derived.job_var < 0.35);
    }

    #[test]
    fn campaign_tiny_runs() {
        let c = Campaign::new(SuiteSpec::tiny(), ProfileConfig::default());
        let profiles = c.run();
        assert_eq!(profiles.len(), SuiteSpec::tiny().total());
        let d = build_dataset(&profiles);
        assert_eq!(d.len(), profiles.len());
        assert_eq!(d.n_features(), FEATURE_NAMES.len());
    }

    #[test]
    fn dataset_targets_are_speedups() {
        let c = Campaign::new(SuiteSpec::tiny(), ProfileConfig::default());
        let profiles = c.run();
        let d = build_dataset(&profiles);
        for (&y, p) in d.y.iter().zip(&profiles) {
            assert_eq!(y, p.max_speedup());
            assert!(y > 0.1 && y < 16.0, "speedup out of range: {y}");
        }
    }
}
