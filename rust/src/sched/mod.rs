//! Work partitioning (schedules) and thread placement.
//!
//! The paper's default is the OpenMP *static* row schedule over CSR —
//! whose nonzero allocation is entirely at the mercy of the matrix
//! structure (the `job_var` factor). CSR5's tile schedule balances by
//! construction (§5.2.1). Row-balanced and dynamic-chunk schedules are
//! included as baselines the paper mentions ("the overhead of thread
//! communication with dynamic scheduling is nonnegligible").

use crate::sim::topology::Topology;
use crate::sparse::sell::sell_perm;
use crate::sparse::{Csr, Csr5};

/// A work schedule for multi-threaded SpMV.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    /// OpenMP `schedule(static)` over rows: equal row *counts*.
    CsrRowStatic,
    /// Rows split so per-thread nonzero counts are balanced (prefix
    /// bisection) — the cheap software fix for imbalance.
    CsrRowBalanced,
    /// CSR5 tiles split evenly (`tile_nnz` nonzeros per tile).
    Csr5Tiles { tile_nnz: usize },
    /// OpenMP `schedule(dynamic, chunk)` over rows: round-robin chunks
    /// (modeled deterministically; the runtime overhead is charged by
    /// the timing model per chunk).
    CsrDynamic { chunk: usize },
    /// SELL-C-σ chunks (σ-window sorted, C-row padded, vectorizable
    /// column-major layout), split by prefix bisection on chunk
    /// nonzero counts — the SIMD-friendly load-balance format the
    /// paper's related work recommends cross-platform.
    SellChunks { c: usize, sigma: usize },
}

impl Schedule {
    pub fn name(&self) -> String {
        match self {
            Schedule::CsrRowStatic => "csr-static".into(),
            Schedule::CsrRowBalanced => "csr-balanced".into(),
            Schedule::Csr5Tiles { tile_nnz } => format!("csr5-t{tile_nnz}"),
            Schedule::CsrDynamic { chunk } => format!("csr-dyn{chunk}"),
            Schedule::SellChunks { c, sigma } => format!("sell-c{c}-s{sigma}"),
        }
    }
}

/// The materialized assignment of work to threads.
#[derive(Clone, Debug)]
pub enum Partition {
    /// Per thread: a list of row ranges `[r0, r1)`.
    Rows { per_thread: Vec<Vec<(usize, usize)>> },
    /// Per thread: one tile range `[t0, t1)` over a CSR5 tiling.
    Tiles { tile_nnz: usize, per_thread: Vec<(usize, usize)> },
    /// Per thread: one chunk range `[k0, k1)` over a SELL-C-σ packing
    /// (`c`/`sigma` as handed to `SellCSigma::from_csr`; chunk `k`
    /// owns the rows `sell_perm(csr, c, sigma)[k*c .. (k+1)*c]`).
    SellChunks {
        c: usize,
        sigma: usize,
        per_thread: Vec<(usize, usize)>,
    },
}

impl Partition {
    /// Nonzeros assigned to each thread (the `job_var` input).
    pub fn thread_nnz(&self, csr: &Csr) -> Vec<usize> {
        match self {
            Partition::Rows { per_thread } => per_thread
                .iter()
                .map(|ranges| {
                    ranges
                        .iter()
                        .map(|&(r0, r1)| csr.ptr[r1] - csr.ptr[r0])
                        .sum()
                })
                .collect(),
            Partition::Tiles { tile_nnz, per_thread } => {
                let nnz = csr.nnz();
                per_thread
                    .iter()
                    .map(|&(t0, t1)| {
                        (t1 * tile_nnz).min(nnz) - (t0 * tile_nnz).min(nnz)
                    })
                    .collect()
            }
            Partition::SellChunks { c, sigma, per_thread } => {
                let perm = sell_perm(csr, *c, *sigma);
                per_thread
                    .iter()
                    .map(|&(k0, k1)| {
                        let lo = (k0 * c).min(csr.n_rows);
                        let hi = (k1 * c).min(csr.n_rows);
                        perm[lo..hi]
                            .iter()
                            .map(|&r| csr.row_nnz(r as usize))
                            .sum()
                    })
                    .collect()
            }
        }
    }

    pub fn n_threads(&self) -> usize {
        match self {
            Partition::Rows { per_thread } => per_thread.len(),
            Partition::Tiles { per_thread, .. } => per_thread.len(),
            Partition::SellChunks { per_thread, .. } => per_thread.len(),
        }
    }

    /// Every row/tile covered exactly once?
    pub fn validate(&self, csr: &Csr) -> Result<(), String> {
        match self {
            Partition::Rows { per_thread } => {
                let mut covered = vec![false; csr.n_rows];
                for ranges in per_thread {
                    for &(r0, r1) in ranges {
                        if r1 > csr.n_rows || r0 > r1 {
                            return Err(format!("bad range ({r0},{r1})"));
                        }
                        for r in r0..r1 {
                            if covered[r] {
                                return Err(format!("row {r} covered twice"));
                            }
                            covered[r] = true;
                        }
                    }
                }
                if let Some(r) = covered.iter().position(|&c| !c) {
                    return Err(format!("row {r} uncovered"));
                }
                Ok(())
            }
            Partition::Tiles { tile_nnz, per_thread } => {
                let n_tiles = csr.nnz().div_ceil(*tile_nnz).max(1);
                let mut expect = 0usize;
                for &(t0, t1) in per_thread {
                    if t0 != expect || t1 < t0 {
                        return Err(format!(
                            "tile ranges not contiguous at ({t0},{t1})"
                        ));
                    }
                    expect = t1;
                }
                if expect != n_tiles {
                    return Err(format!("covered {expect} of {n_tiles} tiles"));
                }
                Ok(())
            }
            Partition::SellChunks { c, per_thread, .. } => {
                let n_chunks = csr.n_rows.div_ceil((*c).max(1));
                let mut expect = 0usize;
                for &(k0, k1) in per_thread {
                    if k0 != expect || k1 < k0 {
                        return Err(format!(
                            "chunk ranges not contiguous at ({k0},{k1})"
                        ));
                    }
                    expect = k1;
                }
                if expect != n_chunks {
                    return Err(format!(
                        "covered {expect} of {n_chunks} chunks"
                    ));
                }
                Ok(())
            }
        }
    }
}

thread_local! {
    /// Per-thread count of partition materializations — the
    /// regression probe for "a served request never re-partitions":
    /// plans memoize their [`Partition`] at build time, so repeated
    /// plan executions must leave this counter untouched on the
    /// serving thread (pinned by `service::plan` tests).
    static PARTITION_CALLS: std::cell::Cell<u64> =
        const { std::cell::Cell::new(0) };
}

/// Number of [`partition`] calls made by the *current thread* so far.
/// Monotone; compare two readings to assert a code path did (or did
/// not) re-partition.
pub fn partition_calls() -> u64 {
    PARTITION_CALLS.with(|c| c.get())
}

/// Build the partition of `csr` for `n_threads` under `schedule`.
pub fn partition(csr: &Csr, schedule: Schedule, n_threads: usize) -> Partition {
    assert!(n_threads > 0);
    PARTITION_CALLS.with(|c| c.set(c.get() + 1));
    match schedule {
        Schedule::CsrRowStatic => {
            let n = csr.n_rows;
            Partition::Rows {
                per_thread: (0..n_threads)
                    .map(|t| vec![(n * t / n_threads, n * (t + 1) / n_threads)])
                    .collect(),
            }
        }
        Schedule::CsrRowBalanced => {
            let total = csr.nnz();
            let mut per_thread = Vec::with_capacity(n_threads);
            let mut r = 0usize;
            for t in 0..n_threads {
                let target = total * (t + 1) / n_threads;
                let r0 = r;
                while r < csr.n_rows && csr.ptr[r + 1] <= target {
                    r += 1;
                }
                // Take at least one row if any remain (avoid starving
                // later threads of progress on pathological prefixes).
                if r == r0 && r < csr.n_rows && t < n_threads - 1 {
                    r += 1;
                }
                if t == n_threads - 1 {
                    r = csr.n_rows;
                }
                per_thread.push(vec![(r0, r)]);
            }
            Partition::Rows { per_thread }
        }
        Schedule::Csr5Tiles { tile_nnz } => {
            let n_tiles = csr.nnz().div_ceil(tile_nnz).max(1);
            Partition::Tiles {
                tile_nnz,
                per_thread: (0..n_threads)
                    .map(|t| {
                        (n_tiles * t / n_threads, n_tiles * (t + 1) / n_threads)
                    })
                    .collect(),
            }
        }
        Schedule::CsrDynamic { chunk } => {
            // Deterministic model of dynamic scheduling: greedy
            // longest-processing-time assignment of row chunks by
            // nonzero count — what a work-stealing runtime converges
            // to for SpMV.
            let chunk = chunk.max(1);
            let mut chunks: Vec<(usize, usize, usize)> = Vec::new();
            let mut r = 0;
            while r < csr.n_rows {
                let r1 = (r + chunk).min(csr.n_rows);
                chunks.push((csr.ptr[r1] - csr.ptr[r], r, r1));
                r = r1;
            }
            chunks.sort_by(|a, b| b.0.cmp(&a.0));
            let mut per_thread: Vec<Vec<(usize, usize)>> =
                vec![Vec::new(); n_threads];
            let mut load = vec![0usize; n_threads];
            for (nnz, r0, r1) in chunks {
                let t = (0..n_threads).min_by_key(|&t| load[t]).unwrap();
                load[t] += nnz;
                per_thread[t].push((r0, r1));
            }
            for ranges in &mut per_thread {
                ranges.sort_unstable();
            }
            Partition::Rows { per_thread }
        }
        Schedule::SellChunks { c, sigma } => {
            // Contiguous chunk ranges balanced by chunk nonzero count
            // (prefix bisection, like CsrRowBalanced over rows). The
            // chunk -> row map is the σ-window permutation, shared
            // with `SellCSigma::from_csr` via `sell_perm`.
            let c = c.clamp(1, 64);
            let perm = sell_perm(csr, c, sigma);
            let n_chunks = csr.n_rows.div_ceil(c);
            let mut cum = Vec::with_capacity(n_chunks + 1);
            cum.push(0usize);
            for k in 0..n_chunks {
                let hi = ((k + 1) * c).min(csr.n_rows);
                let nnz_k: usize = perm[k * c..hi]
                    .iter()
                    .map(|&r| csr.row_nnz(r as usize))
                    .sum();
                cum.push(cum[k] + nnz_k);
            }
            let total = *cum.last().unwrap();
            let mut per_thread = Vec::with_capacity(n_threads);
            let mut k = 0usize;
            for t in 0..n_threads {
                let target = total * (t + 1) / n_threads;
                let k0 = k;
                while k < n_chunks && cum[k + 1] <= target {
                    k += 1;
                }
                // Keep every leading thread fed when prefixes are
                // pathological (one huge chunk), like CsrRowBalanced.
                if k == k0 && k < n_chunks && t < n_threads - 1 {
                    k += 1;
                }
                if t == n_threads - 1 {
                    k = n_chunks;
                }
                per_thread.push((k0, k));
            }
            Partition::SellChunks { c, sigma, per_thread }
        }
    }
}

/// Core range `[c0, c1)` of the modeled NUMA panel(s) that serving
/// shard `shard` of `n_shards` pins its workers to.
///
/// The paper's Fig 1/Fig 3 point: SpMV stops scaling once threads
/// cross a panel (memory-domain) boundary, so the serving layer maps
/// one shard per panel. With as many shards as panels (FT-2000+: 8x8)
/// each shard owns exactly one panel; more shards than panels wrap
/// round-robin; fewer shards split the panels into contiguous blocks
/// so every core stays owned by exactly one shard.
pub fn panel_core_range(
    topo: &Topology,
    shard: usize,
    n_shards: usize,
) -> (usize, usize) {
    let span = topo.cores_per_mem_domain.max(1);
    let panels = (topo.cores / span).max(1);
    let n_shards = n_shards.max(1);
    if n_shards >= panels {
        let panel = shard % panels;
        (panel * span, (panel + 1) * span)
    } else {
        let per = panels / n_shards;
        let extra = panels % n_shards;
        let s = shard.min(n_shards - 1);
        let p0 = s * per + s.min(extra);
        let p1 = p0 + per + usize::from(s < extra);
        (p0 * span, p1 * span)
    }
}

/// Convenience: build the CSR5 structure matching a tile schedule.
pub fn csr5_for(csr: &Csr, schedule: Schedule) -> Option<Csr5> {
    match schedule {
        Schedule::Csr5Tiles { tile_nnz } => Some(Csr5::from_csr(csr, tile_nnz)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::features::job_var;
    use crate::sparse::Coo;

    fn skewed_matrix(n: usize) -> Csr {
        // All mass in rows n/4..n/4+4 (thread 2 of 4 under static).
        let mut coo = Coo::new(n, n);
        for i in 0..4 {
            for c in 0..n {
                coo.push(n / 4 + i, c, 1.0);
            }
        }
        for r in 0..n {
            coo.push(r, r, 1.0);
        }
        coo.to_csr()
    }

    #[test]
    fn static_partition_covers() {
        let csr = skewed_matrix(64);
        for nt in [1, 2, 3, 4, 7] {
            let p = partition(&csr, Schedule::CsrRowStatic, nt);
            assert!(p.validate(&csr).is_ok(), "nt={nt}");
            assert_eq!(p.n_threads(), nt);
            let total: usize = p.thread_nnz(&csr).iter().sum();
            assert_eq!(total, csr.nnz());
        }
    }

    #[test]
    fn static_is_imbalanced_on_skew() {
        let csr = skewed_matrix(64);
        let p = partition(&csr, Schedule::CsrRowStatic, 4);
        let jv = job_var(&p.thread_nnz(&csr));
        assert!(jv > 0.7, "static should be imbalanced: {jv}");
    }

    #[test]
    fn balanced_fixes_imbalance() {
        let csr = skewed_matrix(64);
        let p = partition(&csr, Schedule::CsrRowBalanced, 4);
        assert!(p.validate(&csr).is_ok());
        let jv = job_var(&p.thread_nnz(&csr));
        assert!(jv < 0.5, "balanced should reduce job_var: {jv}");
    }

    #[test]
    fn csr5_tiles_balanced() {
        let csr = skewed_matrix(64);
        let p = partition(&csr, Schedule::Csr5Tiles { tile_nnz: 8 }, 4);
        assert!(p.validate(&csr).is_ok());
        let jv = job_var(&p.thread_nnz(&csr));
        assert!(jv < 0.35, "csr5 tiles must balance: {jv}");
    }

    #[test]
    fn dynamic_balances_chunks() {
        // chunk=1 lets LPT spread the four dense rows across threads;
        // coarser chunks cannot split a chunk (tested below).
        let csr = skewed_matrix(256);
        let p = partition(&csr, Schedule::CsrDynamic { chunk: 1 }, 4);
        assert!(p.validate(&csr).is_ok());
        let jv = job_var(&p.thread_nnz(&csr));
        assert!(jv < 0.35, "dynamic chunk=1 should spread rows: {jv}");
    }

    #[test]
    fn dynamic_coarse_chunk_limited_by_granularity() {
        // The dense block fits one chunk of 4 rows: no schedule can
        // split it, so job_var stays high — the "dynamic scheduling is
        // not free" caveat of §5.2.1.
        let csr = skewed_matrix(256);
        let p = partition(&csr, Schedule::CsrDynamic { chunk: 4 }, 4);
        assert!(p.validate(&csr).is_ok());
        let jv = job_var(&p.thread_nnz(&csr));
        assert!(jv > 0.6, "coarse chunk cannot split the block: {jv}");
    }

    #[test]
    fn more_threads_than_rows() {
        let csr = Csr::identity(3);
        for sched in [
            Schedule::CsrRowStatic,
            Schedule::CsrRowBalanced,
            Schedule::CsrDynamic { chunk: 1 },
        ] {
            let p = partition(&csr, sched, 8);
            assert!(p.validate(&csr).is_ok(), "{sched:?}");
        }
    }

    #[test]
    fn empty_matrix_partitions() {
        let csr = Csr::zero(0, 0);
        let p = partition(&csr, Schedule::CsrRowStatic, 4);
        assert!(p.validate(&csr).is_ok());
        assert_eq!(p.thread_nnz(&csr), vec![0, 0, 0, 0]);
    }

    #[test]
    fn csr5_for_matches() {
        let csr = skewed_matrix(32);
        assert!(csr5_for(&csr, Schedule::CsrRowStatic).is_none());
        let c5 = csr5_for(&csr, Schedule::Csr5Tiles { tile_nnz: 16 }).unwrap();
        assert_eq!(c5.tile_nnz, 16);
    }

    #[test]
    fn balanced_theoretical_optimum_uniform() {
        let csr = Csr::identity(100);
        let p = partition(&csr, Schedule::CsrRowBalanced, 4);
        let jv = job_var(&p.thread_nnz(&csr));
        assert!((jv - 0.25).abs() < 0.02, "uniform should hit 0.25: {jv}");
    }

    #[test]
    fn panel_ranges_partition_the_chip() {
        let topo = Topology::ft2000plus();
        // One shard per panel: shard i owns panel i's 8 cores.
        for s in 0..8 {
            assert_eq!(panel_core_range(&topo, s, 8), (8 * s, 8 * s + 8));
        }
        // More shards than panels wrap round-robin.
        assert_eq!(panel_core_range(&topo, 9, 16), (8, 16));
        // Fewer shards than panels: contiguous panel blocks covering
        // every core exactly once.
        for n_shards in [1usize, 2, 3, 5, 7] {
            let mut next = 0;
            for s in 0..n_shards {
                let (c0, c1) = panel_core_range(&topo, s, n_shards);
                assert_eq!(c0, next, "shard {s} of {n_shards}");
                assert!(c1 > c0);
                assert_eq!(c0 % 8, 0);
                assert_eq!(c1 % 8, 0);
                next = c1;
            }
            assert_eq!(next, topo.cores, "{n_shards} shards");
        }
    }

    #[test]
    fn schedule_names() {
        assert_eq!(Schedule::CsrRowStatic.name(), "csr-static");
        assert_eq!(Schedule::Csr5Tiles { tile_nnz: 64 }.name(), "csr5-t64");
        assert_eq!(
            Schedule::SellChunks { c: 8, sigma: 64 }.name(),
            "sell-c8-s64"
        );
    }

    #[test]
    fn sell_chunks_partition_covers_and_balances() {
        let csr = skewed_matrix(256);
        for nt in [1usize, 2, 4, 7] {
            let p =
                partition(&csr, Schedule::SellChunks { c: 8, sigma: 64 }, nt);
            assert!(p.validate(&csr).is_ok(), "nt={nt}");
            assert_eq!(p.n_threads(), nt);
            let nnz = p.thread_nnz(&csr);
            assert_eq!(nnz.iter().sum::<usize>(), csr.nnz());
        }
        // Chunk-nnz bisection beats the static row split on the
        // skewed matrix (the dense block is one chunk, but the other
        // threads still get even shares of the rest).
        let p = partition(&csr, Schedule::SellChunks { c: 4, sigma: 256 }, 4);
        let jv = job_var(&p.thread_nnz(&csr));
        let pstat = partition(&csr, Schedule::CsrRowStatic, 4);
        assert!(
            jv <= job_var(&pstat.thread_nnz(&csr)),
            "sell chunks must not be worse than static: {jv}"
        );
    }

    #[test]
    fn sell_chunks_edge_geometry() {
        // More threads than chunks, empty matrices, pathological σ.
        let tiny = Csr::identity(3);
        let p =
            partition(&tiny, Schedule::SellChunks { c: 8, sigma: 8 }, 6);
        assert!(p.validate(&tiny).is_ok());
        let empty = Csr::zero(0, 0);
        let p = partition(
            &empty,
            Schedule::SellChunks { c: 8, sigma: usize::MAX },
            4,
        );
        assert!(p.validate(&empty).is_ok());
        assert_eq!(p.thread_nnz(&empty), vec![0, 0, 0, 0]);
        let zeros = Csr::zero(10, 10);
        let p = partition(&zeros, Schedule::SellChunks { c: 4, sigma: 4 }, 3);
        assert!(p.validate(&zeros).is_ok());
        assert_eq!(p.thread_nnz(&zeros).iter().sum::<usize>(), 0);
    }

}
