//! Bagged regression forest ("regression forests", Fig 5).
//!
//! Bootstrap-sampled trees with per-tree feature subsampling;
//! prediction is the tree average, importance is the tree average of
//! normalized impurity decreases (sklearn's RandomForestRegressor
//! convention).

use crate::util::rng::Pcg32;

use super::dataset::Dataset;
use super::tree::{Tree, TreeParams};

#[derive(Clone, Debug)]
pub struct ForestParams {
    pub n_trees: usize,
    pub tree: TreeParams,
    /// Features considered per tree (0 = all).
    pub max_features: usize,
    pub seed: u64,
}

impl Default for ForestParams {
    fn default() -> Self {
        ForestParams {
            n_trees: 20,
            tree: TreeParams::default(),
            max_features: 0,
            seed: 0xF02E57,
        }
    }
}

pub struct Forest {
    pub trees: Vec<(Tree, Vec<usize>)>, // (tree, feature subset)
    pub feature_names: Vec<String>,
}

impl Forest {
    pub fn fit(data: &Dataset, params: ForestParams) -> Forest {
        assert!(!data.is_empty());
        let mut rng = Pcg32::new(params.seed);
        let nf = data.n_features();
        let mf = if params.max_features == 0 {
            nf
        } else {
            params.max_features.min(nf)
        };
        let mut trees = Vec::with_capacity(params.n_trees);
        for t in 0..params.n_trees {
            let mut trng = rng.fork(t as u64);
            // Bootstrap rows.
            let n = data.len();
            let mut boot = Dataset::new(Vec::new());
            // Feature subset for this tree.
            let feats = trng.sample_distinct(nf, mf);
            boot.feature_names =
                feats.iter().map(|&f| data.feature_names[f].clone()).collect();
            for _ in 0..n {
                let i = trng.gen_range(n);
                let row: Vec<f64> =
                    feats.iter().map(|&f| data.x[i][f]).collect();
                boot.push(row, data.y[i]);
            }
            let tree = Tree::fit(&boot, params.tree.clone());
            trees.push((tree, feats));
        }
        Forest { trees, feature_names: data.feature_names.clone() }
    }

    pub fn predict(&self, features: &[f64]) -> f64 {
        let sum: f64 = self
            .trees
            .iter()
            .map(|(t, feats)| {
                let row: Vec<f64> =
                    feats.iter().map(|&f| features[f]).collect();
                t.predict(&row)
            })
            .sum();
        sum / self.trees.len() as f64
    }

    pub fn mse(&self, data: &Dataset) -> f64 {
        if data.is_empty() {
            return 0.0;
        }
        data.x
            .iter()
            .zip(&data.y)
            .map(|(x, &y)| {
                let d = self.predict(x) - y;
                d * d
            })
            .sum::<f64>()
            / data.len() as f64
    }

    /// Average of per-tree normalized importances, mapped back to the
    /// full feature space.
    pub fn feature_importances(&self) -> Vec<f64> {
        let nf = self.feature_names.len();
        let mut imp = vec![0.0; nf];
        for (tree, feats) in &self.trees {
            for (local, &global) in feats.iter().enumerate() {
                imp[global] += tree.feature_importances()[local];
            }
        }
        let total: f64 = imp.iter().sum();
        if total > 0.0 {
            for v in &mut imp {
                *v /= total;
            }
        }
        imp
    }

    pub fn ranked_features(&self) -> Vec<(String, f64)> {
        let mut ranked: Vec<(String, f64)> = self
            .feature_names
            .iter()
            .cloned()
            .zip(self.feature_importances())
            .collect();
        ranked.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        ranked
    }

    /// "A tree picked from the regression forests" (Fig 5): the tree
    /// with the lowest training error — rendered as text.
    pub fn representative_tree(&self, data: &Dataset) -> &Tree {
        self.trees
            .iter()
            .min_by(|(a, fa), (b, fb)| {
                let da = project(data, fa);
                let db = project(data, fb);
                a.mse(&da).partial_cmp(&b.mse(&db)).unwrap()
            })
            .map(|(t, _)| t)
            .expect("non-empty forest")
    }
}

fn project(data: &Dataset, feats: &[usize]) -> Dataset {
    let mut out = Dataset::new(
        feats.iter().map(|&f| data.feature_names[f].clone()).collect(),
    );
    for (row, &y) in data.x.iter().zip(&data.y) {
        out.push(feats.iter().map(|&f| row[f]).collect(), y);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synthetic(n: usize, seed: u64) -> Dataset {
        let mut rng = Pcg32::new(seed);
        let mut d =
            Dataset::new(vec!["strong".into(), "weak".into(), "noise".into()]);
        for _ in 0..n {
            let a = rng.gen_f64();
            let b = rng.gen_f64();
            let c = rng.gen_f64();
            let y = if a > 0.5 { 3.0 } else { 1.0 } + 0.3 * b;
            d.push(vec![a, b, c], y);
        }
        d
    }

    #[test]
    fn forest_fits_and_ranks() {
        let d = synthetic(300, 1);
        let f = Forest::fit(&d, ForestParams::default());
        assert!(f.mse(&d) < 0.05, "mse={}", f.mse(&d));
        assert_eq!(f.ranked_features()[0].0, "strong");
        let imp = f.feature_importances();
        assert!((imp.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn feature_subsampling_still_covers() {
        let d = synthetic(300, 2);
        let f = Forest::fit(
            &d,
            ForestParams { max_features: 2, n_trees: 30, ..Default::default() },
        );
        // With 2-of-3 features per tree the strong feature still
        // dominates on average.
        assert_eq!(f.ranked_features()[0].0, "strong");
    }

    #[test]
    fn representative_tree_renders() {
        let d = synthetic(200, 3);
        let f = Forest::fit(&d, ForestParams::default());
        let t = f.representative_tree(&d);
        assert!(t.render().contains("speedup ="));
    }

    #[test]
    fn deterministic() {
        let d = synthetic(100, 4);
        let a = Forest::fit(&d, ForestParams::default());
        let b = Forest::fit(&d, ForestParams::default());
        assert_eq!(a.predict(&[0.3, 0.5, 0.5]), b.predict(&[0.3, 0.5, 0.5]));
    }
}
