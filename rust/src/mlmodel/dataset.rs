//! Feature-matrix container for the regression model.

use crate::util::rng::Pcg32;

/// A supervised dataset: `x[i]` is a feature row, `y[i]` the target
/// (the 4-thread speedup).
#[derive(Clone, Debug, Default)]
pub struct Dataset {
    pub feature_names: Vec<String>,
    pub x: Vec<Vec<f64>>,
    pub y: Vec<f64>,
}

impl Dataset {
    pub fn new(feature_names: Vec<String>) -> Self {
        Dataset { feature_names, x: vec![], y: vec![] }
    }

    pub fn push(&mut self, features: Vec<f64>, target: f64) {
        assert_eq!(features.len(), self.feature_names.len());
        self.x.push(features);
        self.y.push(target);
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn n_features(&self) -> usize {
        self.feature_names.len()
    }

    /// Deterministic shuffled split: first `frac` for training, rest
    /// for testing (the paper trains on 90%).
    pub fn split(&self, frac: f64, seed: u64) -> (Dataset, Dataset) {
        let mut idx: Vec<usize> = (0..self.len()).collect();
        Pcg32::new(seed).shuffle(&mut idx);
        let cut = ((self.len() as f64) * frac).round() as usize;
        let mut train = Dataset::new(self.feature_names.clone());
        let mut test = Dataset::new(self.feature_names.clone());
        for (k, &i) in idx.iter().enumerate() {
            if k < cut {
                train.push(self.x[i].clone(), self.y[i]);
            } else {
                test.push(self.x[i].clone(), self.y[i]);
            }
        }
        (train, test)
    }

    /// Column view.
    pub fn column(&self, f: usize) -> Vec<f64> {
        self.x.iter().map(|row| row[f]).collect()
    }

    /// Append every row of `other` (same schema required) — how the
    /// per-shard autotune observation logs merge into one retraining
    /// set for the offline planner.
    pub fn extend(&mut self, other: &Dataset) {
        assert_eq!(
            self.feature_names, other.feature_names,
            "datasets must share a feature schema to merge"
        );
        self.x.extend(other.x.iter().cloned());
        self.y.extend(other.y.iter().copied());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        let mut d = Dataset::new(vec!["a".into(), "b".into()]);
        for i in 0..10 {
            d.push(vec![i as f64, (10 - i) as f64], i as f64 * 2.0);
        }
        d
    }

    #[test]
    fn push_and_len() {
        let d = toy();
        assert_eq!(d.len(), 10);
        assert_eq!(d.n_features(), 2);
        assert!(!d.is_empty());
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut d = Dataset::new(vec!["a".into()]);
        d.push(vec![1.0, 2.0], 0.0);
    }

    #[test]
    fn split_fractions() {
        let d = toy();
        let (train, test) = d.split(0.9, 42);
        assert_eq!(train.len(), 9);
        assert_eq!(test.len(), 1);
        // Deterministic.
        let (t2, _) = d.split(0.9, 42);
        assert_eq!(train.x, t2.x);
    }

    #[test]
    fn column_extraction() {
        let d = toy();
        let c = d.column(1);
        assert_eq!(c[0], 10.0);
        assert_eq!(c[9], 1.0);
    }

    #[test]
    fn extend_merges_rows() {
        let mut a = toy();
        let b = toy();
        a.extend(&b);
        assert_eq!(a.len(), 20);
        assert_eq!(a.x[10], b.x[0]);
        assert_eq!(a.y[19], b.y[9]);
    }

    #[test]
    #[should_panic(expected = "feature schema")]
    fn extend_rejects_schema_mismatch() {
        let mut a = toy();
        let b = Dataset::new(vec!["other".into()]);
        a.extend(&b);
    }
}
