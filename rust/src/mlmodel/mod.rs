//! Regression-tree scalability model (§4.2) — the paper's
//! scikit-learn analysis re-implemented from scratch.
//!
//! * [`dataset`] — feature matrix assembly (Table 3 feature order);
//! * [`tree`] — CART regression tree (variance-reduction splits,
//!   identical criterion to sklearn's default) + impurity-based
//!   feature importance + the Fig 5 text rendering;
//! * [`forest`] — bagged regression forest ("a tree picked from the
//!   regression forests", Fig 5) with averaged importances.
//!
//! The model is used the way the paper uses it: as an *analysis tool*
//! (trained on 90% of the data, §4.2) whose feature importances rank
//! the factors limiting SpMV scalability.

pub mod classify;
pub mod dataset;
pub mod forest;
pub mod tree;

pub use dataset::Dataset;
pub use forest::{Forest, ForestParams};
pub use tree::{Tree, TreeParams};
