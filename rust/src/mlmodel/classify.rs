//! Classification tree (gini impurity) — used by the format selector
//! (`coordinator::format_select`), the paper's future-work claim:
//! choose the SpMV format/schedule from a cheap pre-run profile.

use super::dataset::Dataset;

#[derive(Clone, Debug)]
pub struct ClassTreeParams {
    pub max_depth: usize,
    pub min_samples_split: usize,
    pub min_samples_leaf: usize,
    pub max_thresholds: usize,
}

impl Default for ClassTreeParams {
    fn default() -> Self {
        ClassTreeParams {
            max_depth: 6,
            min_samples_split: 8,
            min_samples_leaf: 3,
            max_thresholds: 32,
        }
    }
}

#[derive(Clone, Debug)]
enum Node {
    Leaf { class: usize, n: usize },
    Split { feature: usize, threshold: f64, left: Box<Node>, right: Box<Node> },
}

/// A multi-class decision tree over a [`Dataset`] whose targets are
/// class ids encoded as f64 (0.0, 1.0, ...).
#[derive(Clone, Debug)]
pub struct ClassTree {
    root: Node,
    pub feature_names: Vec<String>,
    pub n_classes: usize,
}

fn gini(counts: &[usize]) -> f64 {
    let n: usize = counts.iter().sum();
    if n == 0 {
        return 0.0;
    }
    let nf = n as f64;
    1.0 - counts
        .iter()
        .map(|&c| {
            let p = c as f64 / nf;
            p * p
        })
        .sum::<f64>()
}

fn majority(counts: &[usize]) -> usize {
    counts
        .iter()
        .enumerate()
        .max_by_key(|&(_, &c)| c)
        .map(|(i, _)| i)
        .unwrap_or(0)
}

impl ClassTree {
    pub fn fit(data: &Dataset, n_classes: usize, params: ClassTreeParams) -> ClassTree {
        assert!(!data.is_empty());
        let idx: Vec<usize> = (0..data.len()).collect();
        let root = build(data, &idx, n_classes, &params, 0);
        ClassTree {
            root,
            feature_names: data.feature_names.clone(),
            n_classes,
        }
    }

    pub fn predict(&self, features: &[f64]) -> usize {
        let mut node = &self.root;
        loop {
            match node {
                Node::Leaf { class, .. } => return *class,
                Node::Split { feature, threshold, left, right } => {
                    node = if features[*feature] <= *threshold {
                        left
                    } else {
                        right
                    };
                }
            }
        }
    }

    pub fn accuracy(&self, data: &Dataset) -> f64 {
        if data.is_empty() {
            return 0.0;
        }
        let hits = data
            .x
            .iter()
            .zip(&data.y)
            .filter(|(x, &y)| self.predict(x) == y as usize)
            .count();
        hits as f64 / data.len() as f64
    }
}

fn class_counts(data: &Dataset, idx: &[usize], k: usize) -> Vec<usize> {
    let mut counts = vec![0usize; k];
    for &i in idx {
        counts[data.y[i] as usize] += 1;
    }
    counts
}

fn build(
    data: &Dataset,
    idx: &[usize],
    k: usize,
    params: &ClassTreeParams,
    depth: usize,
) -> Node {
    let counts = class_counts(data, idx, k);
    let leaf = || Node::Leaf { class: majority(&counts), n: idx.len() };
    if depth >= params.max_depth
        || idx.len() < params.min_samples_split
        || gini(&counts) < 1e-12
    {
        return leaf();
    }
    let parent_gini = gini(&counts);
    let mut best: Option<(usize, f64, f64)> = None;
    for f in 0..data.n_features() {
        let mut vals: Vec<f64> = idx.iter().map(|&i| data.x[i][f]).collect();
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        vals.dedup();
        if vals.len() < 2 {
            continue;
        }
        let step = ((vals.len() - 1) as f64
            / params.max_thresholds.min(vals.len() - 1) as f64)
            .max(1.0);
        let mut t = 0.0f64;
        while (t as usize) < vals.len() - 1 {
            let i = t as usize;
            let thr = 0.5 * (vals[i] + vals[i + 1]);
            let mut lc = vec![0usize; k];
            let mut rc = vec![0usize; k];
            for &j in idx {
                if data.x[j][f] <= thr {
                    lc[data.y[j] as usize] += 1;
                } else {
                    rc[data.y[j] as usize] += 1;
                }
            }
            let nl: usize = lc.iter().sum();
            let nr: usize = rc.iter().sum();
            if nl >= params.min_samples_leaf && nr >= params.min_samples_leaf {
                let w = idx.len() as f64;
                let g = parent_gini
                    - (nl as f64 / w) * gini(&lc)
                    - (nr as f64 / w) * gini(&rc);
                if g > 1e-12 && best.map_or(true, |(_, _, bg)| g > bg) {
                    best = Some((f, thr, g));
                }
            }
            t += step;
        }
    }
    match best {
        None => leaf(),
        Some((feature, threshold, _)) => {
            let (li, ri): (Vec<usize>, Vec<usize>) =
                idx.iter().partition(|&&i| data.x[i][feature] <= threshold);
            Node::Split {
                feature,
                threshold,
                left: Box::new(build(data, &li, k, params, depth + 1)),
                right: Box::new(build(data, &ri, k, params, depth + 1)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn blobs(n: usize, seed: u64) -> Dataset {
        // Three separable classes in 2-D.
        let mut rng = Pcg32::new(seed);
        let mut d = Dataset::new(vec!["a".into(), "b".into()]);
        for _ in 0..n {
            let k = rng.gen_range(3);
            let (cx, cy) = [(0.0, 0.0), (5.0, 0.0), (0.0, 5.0)][k];
            d.push(
                vec![cx + rng.gen_normal() * 0.4, cy + rng.gen_normal() * 0.4],
                k as f64,
            );
        }
        d
    }

    #[test]
    fn separable_blobs_high_accuracy() {
        let d = blobs(300, 1);
        let t = ClassTree::fit(&d, 3, ClassTreeParams::default());
        assert!(t.accuracy(&d) > 0.97, "{}", t.accuracy(&d));
        assert_eq!(t.predict(&[5.0, 0.0]), 1);
        assert_eq!(t.predict(&[0.0, 5.0]), 2);
    }

    #[test]
    fn generalizes() {
        let d = blobs(400, 2);
        let (train, test) = d.split(0.8, 3);
        let t = ClassTree::fit(&train, 3, ClassTreeParams::default());
        assert!(t.accuracy(&test) > 0.9, "{}", t.accuracy(&test));
    }

    #[test]
    fn single_class_is_leaf() {
        let mut d = Dataset::new(vec!["a".into()]);
        for i in 0..20 {
            d.push(vec![i as f64], 1.0);
        }
        let t = ClassTree::fit(&d, 3, ClassTreeParams::default());
        assert_eq!(t.predict(&[100.0]), 1);
        assert_eq!(t.accuracy(&d), 1.0);
    }

    #[test]
    fn gini_properties() {
        assert_eq!(gini(&[10, 0, 0]), 0.0);
        let g = gini(&[5, 5]);
        assert!((g - 0.5).abs() < 1e-12);
        assert_eq!(gini(&[]), 0.0);
    }
}
