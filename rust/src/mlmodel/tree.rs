//! CART regression tree with variance-reduction splits.
//!
//! Matches sklearn's `DecisionTreeRegressor` defaults in the respects
//! the paper relies on: squared-error impurity, best-split search over
//! all features, and feature importance as the normalized total
//! impurity decrease each feature contributes (`feature_importances_`).

use super::dataset::Dataset;

#[derive(Clone, Debug)]
pub struct TreeParams {
    pub max_depth: usize,
    pub min_samples_split: usize,
    pub min_samples_leaf: usize,
    /// Candidate thresholds per feature (quantile subsampling keeps
    /// training O(n·f·q) instead of O(n²·f)).
    pub max_thresholds: usize,
}

impl Default for TreeParams {
    fn default() -> Self {
        TreeParams {
            max_depth: 6,
            min_samples_split: 8,
            min_samples_leaf: 4,
            max_thresholds: 32,
        }
    }
}

#[derive(Clone, Debug)]
pub enum Node {
    Leaf {
        value: f64,
        n: usize,
    },
    Split {
        feature: usize,
        threshold: f64,
        /// Weighted impurity decrease this split achieved (for
        /// feature importance).
        gain: f64,
        n: usize,
        left: Box<Node>,
        right: Box<Node>,
    },
}

#[derive(Clone, Debug)]
pub struct Tree {
    pub root: Node,
    pub feature_names: Vec<String>,
    pub params: TreeParams,
}

struct Slice<'a> {
    data: &'a Dataset,
    idx: Vec<usize>,
}

impl Slice<'_> {
    fn mean(&self) -> f64 {
        if self.idx.is_empty() {
            return 0.0;
        }
        self.idx.iter().map(|&i| self.data.y[i]).sum::<f64>()
            / self.idx.len() as f64
    }

    /// Sum of squared error around the mean (n * variance).
    fn sse(&self) -> f64 {
        let m = self.mean();
        self.idx
            .iter()
            .map(|&i| {
                let d = self.data.y[i] - m;
                d * d
            })
            .sum()
    }
}

impl Tree {
    /// Fit on the full dataset.
    pub fn fit(data: &Dataset, params: TreeParams) -> Tree {
        assert!(!data.is_empty(), "cannot fit on empty dataset");
        let slice = Slice { data, idx: (0..data.len()).collect() };
        let root = build(&slice, &params, 0);
        Tree { root, feature_names: data.feature_names.clone(), params }
    }

    pub fn predict(&self, features: &[f64]) -> f64 {
        let mut node = &self.root;
        loop {
            match node {
                Node::Leaf { value, .. } => return *value,
                Node::Split { feature, threshold, left, right, .. } => {
                    node = if features[*feature] <= *threshold {
                        left
                    } else {
                        right
                    };
                }
            }
        }
    }

    /// Mean squared error on a dataset.
    pub fn mse(&self, data: &Dataset) -> f64 {
        if data.is_empty() {
            return 0.0;
        }
        data.x
            .iter()
            .zip(&data.y)
            .map(|(x, &y)| {
                let d = self.predict(x) - y;
                d * d
            })
            .sum::<f64>()
            / data.len() as f64
    }

    /// Normalized impurity-decrease feature importances
    /// (sklearn's `feature_importances_`).
    pub fn feature_importances(&self) -> Vec<f64> {
        let mut imp = vec![0.0; self.feature_names.len()];
        accumulate_importance(&self.root, &mut imp);
        let total: f64 = imp.iter().sum();
        if total > 0.0 {
            for v in &mut imp {
                *v /= total;
            }
        }
        imp
    }

    /// Features ranked by importance (descending), with scores.
    pub fn ranked_features(&self) -> Vec<(String, f64)> {
        let imp = self.feature_importances();
        let mut ranked: Vec<(String, f64)> = self
            .feature_names
            .iter()
            .cloned()
            .zip(imp)
            .collect();
        ranked.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        ranked
    }

    /// Render the tree as indented text — the Fig 5 visualization.
    pub fn render(&self) -> String {
        let mut out = String::new();
        render_node(&self.root, &self.feature_names, 0, &mut out);
        out
    }

    pub fn depth(&self) -> usize {
        fn d(n: &Node) -> usize {
            match n {
                Node::Leaf { .. } => 0,
                Node::Split { left, right, .. } => 1 + d(left).max(d(right)),
            }
        }
        d(&self.root)
    }
}

fn accumulate_importance(node: &Node, imp: &mut [f64]) {
    if let Node::Split { feature, gain, left, right, .. } = node {
        imp[*feature] += *gain;
        accumulate_importance(left, imp);
        accumulate_importance(right, imp);
    }
}

fn render_node(node: &Node, names: &[String], depth: usize, out: &mut String) {
    let pad = "  ".repeat(depth);
    match node {
        Node::Leaf { value, n } => {
            out.push_str(&format!("{pad}-> speedup = {value:.3} (n={n})\n"));
        }
        Node::Split { feature, threshold, n, left, right, .. } => {
            out.push_str(&format!(
                "{pad}if {} <= {threshold:.4} (n={n})\n",
                names[*feature]
            ));
            render_node(left, names, depth + 1, out);
            out.push_str(&format!("{pad}else  # {} > {threshold:.4}\n", names[*feature]));
            render_node(right, names, depth + 1, out);
        }
    }
}

fn build(slice: &Slice, params: &TreeParams, depth: usize) -> Node {
    let n = slice.idx.len();
    let leaf = || Node::Leaf { value: slice.mean(), n };
    if depth >= params.max_depth || n < params.min_samples_split {
        return leaf();
    }
    let parent_sse = slice.sse();
    if parent_sse <= 1e-12 {
        return leaf();
    }
    let mut best: Option<(usize, f64, f64)> = None; // (feature, threshold, gain)
    for f in 0..slice.data.n_features() {
        let mut vals: Vec<f64> =
            slice.idx.iter().map(|&i| slice.data.x[i][f]).collect();
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        vals.dedup();
        if vals.len() < 2 {
            continue;
        }
        // Quantile-subsampled candidate thresholds (midpoints).
        let step = ((vals.len() - 1) as f64
            / params.max_thresholds.min(vals.len() - 1) as f64)
            .max(1.0);
        let mut k = 0.0;
        while (k as usize) < vals.len() - 1 {
            let i = k as usize;
            let thr = 0.5 * (vals[i] + vals[i + 1]);
            if let Some(gain) = split_gain(slice, f, thr, parent_sse, params)
            {
                if best.map_or(true, |(_, _, g)| gain > g) {
                    best = Some((f, thr, gain));
                }
            }
            k += step;
        }
    }
    match best {
        None => leaf(),
        Some((feature, threshold, gain)) => {
            let (li, ri): (Vec<usize>, Vec<usize>) = slice
                .idx
                .iter()
                .partition(|&&i| slice.data.x[i][feature] <= threshold);
            let left = Slice { data: slice.data, idx: li };
            let right = Slice { data: slice.data, idx: ri };
            Node::Split {
                feature,
                threshold,
                gain,
                n,
                left: Box::new(build(&left, params, depth + 1)),
                right: Box::new(build(&right, params, depth + 1)),
            }
        }
    }
}

fn split_gain(
    slice: &Slice,
    feature: usize,
    threshold: f64,
    parent_sse: f64,
    params: &TreeParams,
) -> Option<f64> {
    let mut nl = 0usize;
    let mut sl = 0.0;
    let mut sl2 = 0.0;
    let mut nr = 0usize;
    let mut sr = 0.0;
    let mut sr2 = 0.0;
    for &i in &slice.idx {
        let y = slice.data.y[i];
        if slice.data.x[i][feature] <= threshold {
            nl += 1;
            sl += y;
            sl2 += y * y;
        } else {
            nr += 1;
            sr += y;
            sr2 += y * y;
        }
    }
    if nl < params.min_samples_leaf || nr < params.min_samples_leaf {
        return None;
    }
    let sse_l = sl2 - sl * sl / nl as f64;
    let sse_r = sr2 - sr * sr / nr as f64;
    let gain = parent_sse - sse_l - sse_r;
    if gain > 1e-12 {
        Some(gain)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    /// y depends strongly on feature 0, weakly on 1, not at all on 2.
    fn synthetic(n: usize, seed: u64) -> Dataset {
        let mut rng = Pcg32::new(seed);
        let mut d = Dataset::new(vec![
            "strong".into(),
            "weak".into(),
            "noise".into(),
        ]);
        for _ in 0..n {
            let a = rng.gen_f64();
            let b = rng.gen_f64();
            let c = rng.gen_f64();
            let y = if a > 0.5 { 3.0 } else { 1.0 }
                + 0.3 * b
                + 0.02 * (rng.gen_f64() - 0.5);
            d.push(vec![a, b, c], y);
        }
        d
    }

    #[test]
    fn fits_step_function() {
        let d = synthetic(400, 1);
        let t = Tree::fit(&d, TreeParams::default());
        assert!(t.mse(&d) < 0.05, "mse={}", t.mse(&d));
        assert!(t.predict(&[0.9, 0.5, 0.5]) > 2.5);
        assert!(t.predict(&[0.1, 0.5, 0.5]) < 1.8);
    }

    #[test]
    fn importance_ranks_strong_first() {
        let d = synthetic(400, 2);
        let t = Tree::fit(&d, TreeParams::default());
        let ranked = t.ranked_features();
        assert_eq!(ranked[0].0, "strong");
        let imp = t.feature_importances();
        assert!((imp.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(imp[0] > 0.7, "strong importance: {}", imp[0]);
        assert!(imp[2] < 0.1, "noise importance: {}", imp[2]);
    }

    #[test]
    fn respects_max_depth() {
        let d = synthetic(400, 3);
        let t = Tree::fit(
            &d,
            TreeParams { max_depth: 2, ..Default::default() },
        );
        assert!(t.depth() <= 2);
    }

    #[test]
    fn min_samples_leaf_respected() {
        let d = synthetic(50, 4);
        let t = Tree::fit(
            &d,
            TreeParams { min_samples_leaf: 10, ..Default::default() },
        );
        fn check(n: &Node) {
            match n {
                Node::Leaf { n, .. } => assert!(*n >= 10),
                Node::Split { left, right, .. } => {
                    check(left);
                    check(right);
                }
            }
        }
        check(&t.root);
    }

    #[test]
    fn constant_target_is_single_leaf() {
        let mut d = Dataset::new(vec!["a".into()]);
        for i in 0..20 {
            d.push(vec![i as f64], 5.0);
        }
        let t = Tree::fit(&d, TreeParams::default());
        assert_eq!(t.depth(), 0);
        assert_eq!(t.predict(&[3.0]), 5.0);
    }

    #[test]
    fn render_mentions_split_feature() {
        let d = synthetic(200, 5);
        let t = Tree::fit(&d, TreeParams::default());
        let r = t.render();
        assert!(r.contains("strong"), "render:\n{r}");
        assert!(r.contains("speedup ="));
    }

    #[test]
    fn generalizes_to_test_split() {
        let d = synthetic(600, 6);
        let (train, test) = d.split(0.9, 7);
        let t = Tree::fit(&train, TreeParams::default());
        // The 0.3*b continuous term bounds what a depth-6 tree can
        // capture; the step structure must generalize well though.
        assert!(t.mse(&test) < 0.2, "test mse={}", t.mse(&test));
    }
}
