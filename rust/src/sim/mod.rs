//! Trace-driven many-core cache/memory/timing simulator.
//!
//! This is the substitute for the physical FT-2000+ (DESIGN.md
//! §Substitutions): every scalability effect the paper analyzes —
//! shared-L2 interference and positive reuse of `x`, load imbalance
//! (slowest-thread time), DCU bandwidth saturation — is a cache or
//! bandwidth phenomenon this simulator reproduces, while emitting the
//! same PAPI-named counter set the paper collects.
//!
//! Fidelity notes are in DESIGN.md §6. The simulator is *not*
//! cycle-accurate; it is calibrated to reproduce the paper's shapes
//! (Table 2 averages, Fig 2 curves, Fig 8 placement effects).

pub mod cache;
pub mod engine;
pub mod memory;
pub mod timing;
pub mod topology;

pub use cache::Cache;
pub use engine::{simulate, SimResult};
pub use topology::{Placement, Topology};
