//! Shared-resource queueing model.
//!
//! Two shared paths shape FT-2000+ SpMV scaling inside a core-group:
//!
//! 1. the **DCU/DRAM path** (bandwidth `bw_*_gbs`): holds the
//!    line-fill traffic of every thread behind it;
//! 2. the **shared L2 access path** (`l2_acc_per_cycle`): every L1
//!    miss probes the group's L2.
//!
//! The crucial asymmetry (what makes conf5 scale at 1.35x while debr
//! scales at 2.24x on the *same* hardware): **sequential** (stream)
//! misses are covered by prefetchers — they consume bandwidth but
//! hide latency, so they only suffer when the path is over-committed
//! (rho > 1) — while **random** (x-gather) misses and L2 probes expose
//! the full queueing latency, which grows like the M/M/1 factor
//! 1/(1-rho) as utilization approaches saturation. Four gather-heavy
//! threads push rho to ~0.9 and see ~10x latency amplification even
//! though the path still nominally has headroom.
//!
//! Utilization is computed over the window of the slowest thread on
//! the path (threads that finish early leave the window to the
//! stragglers — an exdata_1-style lone heavy thread runs at
//! single-thread speed).

/// Per-thread stall decomposition fed to the solver.
#[derive(Clone, Copy, Debug, Default)]
pub struct StallInputs {
    /// Compute + anything not subject to contention (cycles).
    pub base: f64,
    /// Latency-exposed stalls on L2 hits (cycles).
    pub l2_hit: f64,
    /// Prefetch-covered DRAM stalls (cycles).
    pub mem_seq: f64,
    /// Latency-exposed DRAM stalls (cycles).
    pub mem_rand: f64,
    /// Line-fill traffic (bytes) charged to the DRAM paths.
    pub mem_bytes: f64,
    /// Probes charged to the shared L2 path.
    pub l2_accesses: f64,
}

/// One shared path: capacity per cycle + the threads drawing on it.
#[derive(Clone, Debug)]
pub struct SharedPath {
    pub kind: PathKind,
    /// Bytes/cycle for DRAM paths; accesses/cycle for L2 paths.
    pub capacity: f64,
    pub threads: Vec<usize>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PathKind {
    Dram,
    L2Access,
}

/// Queueing amplification; capped (MSHR/queue depths bound the real
/// amplification well before the M/M/1 asymptote).
#[inline]
pub fn queue_factor(rho: f64) -> f64 {
    1.0 / (1.0 - rho.clamp(0.0, 0.84))
}

/// Apply the shared-path amplifications; returns per-thread cycles.
///
/// Utilization is computed **open-loop** from the unloaded runtimes:
/// an out-of-order core with prefetchers keeps issuing requests at the
/// MLP-pinned rate of its instruction stream even as latency grows, so
/// the offered load on a shared path does not relax when the path
/// queues (no closed-loop fixed point — that would let the system
/// self-limit into comfortable equilibria real hardware never finds).
pub fn solve_contention(
    inputs: &[StallInputs],
    paths: &[SharedPath],
) -> Vec<f64> {
    let n = inputs.len();
    let unloaded: Vec<f64> = inputs
        .iter()
        .map(|s| s.base + s.l2_hit + s.mem_seq + s.mem_rand)
        .collect();
    let mut q_l2 = vec![1.0f64; n];
    let mut q_rand = vec![1.0f64; n];
    let mut q_seq = vec![1.0f64; n];
    for p in paths {
        if p.threads.is_empty() || p.capacity <= 0.0 {
            continue;
        }
        // All traffic behind the path is offered within the window of
        // the path's slowest thread (threads that finish early leave
        // the window to the stragglers — an exdata_1-style lone heavy
        // thread runs at single-thread speed).
        let window = p
            .threads
            .iter()
            .map(|&t| unloaded[t])
            .fold(0.0f64, f64::max)
            .max(1.0);
        let demand: f64 = p
            .threads
            .iter()
            .map(|&t| match p.kind {
                PathKind::Dram => inputs[t].mem_bytes,
                PathKind::L2Access => inputs[t].l2_accesses,
            })
            .sum::<f64>()
            / window;
        let rho = demand / p.capacity;
        match p.kind {
            PathKind::Dram => {
                // DRAM stalls inflate by the overload ratio once the
                // path is over-committed; the bandwidth-roofline floor
                // below handles deep saturation. (M/M/1 amplification
                // is reserved for the shared-L2 path — DRAM demand
                // misses on SpMV are too sparse to queue on each
                // other.)
                if rho > 1.0 {
                    for &t in &p.threads {
                        q_seq[t] = q_seq[t].max(rho);
                        q_rand[t] = q_rand[t].max(rho);
                    }
                }
            }
            PathKind::L2Access => {
                let q = queue_factor(rho);
                for &t in &p.threads {
                    q_l2[t] = q_l2[t].max(q);
                }
            }
        }
    }
    let mut total: Vec<f64> = (0..n)
        .map(|t| {
            let s = &inputs[t];
            s.base
                + s.l2_hit * q_l2[t]
                + s.mem_seq * q_seq[t]
                + s.mem_rand * q_rand[t]
        })
        .collect();
    // Bandwidth roofline: a saturated DRAM path cannot serve its
    // aggregate traffic faster than capacity allows, whatever the
    // latency picture says.
    for p in paths {
        if p.kind != PathKind::Dram
            || p.threads.is_empty()
            || p.capacity <= 0.0
        {
            continue;
        }
        let bytes: f64 =
            p.threads.iter().map(|&t| inputs[t].mem_bytes).sum();
        let floor = bytes / p.capacity;
        let bytes_max = p
            .threads
            .iter()
            .map(|&t| inputs[t].mem_bytes)
            .fold(0.0f64, f64::max);
        if bytes_max <= 0.0 {
            continue;
        }
        // Each thread is floored in proportion to its share of the
        // path's traffic (the heaviest consumer carries the full
        // service time; light threads finish early).
        for &t in &p.threads {
            total[t] =
                total[t].max(floor * inputs[t].mem_bytes / bytes_max);
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    fn streaming(base: f64, seq: f64, bytes: f64) -> StallInputs {
        StallInputs {
            base,
            mem_seq: seq,
            mem_bytes: bytes,
            ..Default::default()
        }
    }

    #[test]
    fn unloaded_is_sum() {
        let t = solve_contention(
            &[streaming(100.0, 50.0, 64.0)],
            &[SharedPath {
                kind: PathKind::Dram,
                capacity: 10.0,
                threads: vec![0],
            }],
        );
        // rho tiny -> q ~= 1.
        assert!((t[0] - 150.0).abs() < 2.0, "{t:?}");
    }

    #[test]
    fn stream_overload_scales_to_roofline() {
        // 4 streaming threads each demanding 2 B/cyc on a 4 B/cyc
        // path: saturated -> wall ~= total bytes / capacity.
        let inp: Vec<StallInputs> =
            (0..4).map(|_| streaming(100.0, 100.0, 400.0)).collect();
        let paths = [SharedPath {
            kind: PathKind::Dram,
            capacity: 4.0,
            threads: (0..4).collect(),
        }];
        let t = solve_contention(&inp, &paths);
        let window = t.iter().cloned().fold(0.0, f64::max);
        let rate = 1600.0 / window;
        assert!(rate < 4.4, "rate={rate}");
    }

    #[test]
    fn dram_overload_bounds_gather_threads() {
        // 4 gather threads over-committing a DRAM path: both the
        // overload inflation and the roofline floor must keep the
        // aggregate rate at/below capacity.
        let gather = StallInputs {
            base: 100.0,
            mem_rand: 100.0,
            mem_bytes: 160.0, // 0.8 B/cyc each unloaded
            ..Default::default()
        };
        let paths = |k: usize| {
            vec![SharedPath {
                kind: PathKind::Dram,
                capacity: 2.4,
                threads: (0..k).collect(),
            }]
        };
        let t1 = solve_contention(&[gather], &paths(1));
        let t4 = solve_contention(&[gather; 4], &paths(4));
        let window = t4.iter().cloned().fold(0.0, f64::max);
        let rate = 4.0 * 160.0 / window;
        assert!(rate <= 2.5, "rate={rate}");
        let speedup = t1[0] / window;
        assert!(speedup < 1.5, "gather scaling must be poor: {speedup}");
    }

    #[test]
    fn l2_path_amplifies_hits() {
        let probe = StallInputs {
            base: 100.0,
            l2_hit: 100.0,
            l2_accesses: 30.0, // 0.15/cyc unloaded
            ..Default::default()
        };
        let path = |k: usize| {
            vec![SharedPath {
                kind: PathKind::L2Access,
                capacity: 0.5,
                threads: (0..k).collect(),
            }]
        };
        let t1 = solve_contention(&[probe], &path(1))[0];
        let t4 = solve_contention(&[probe; 4], &path(4))
            .iter()
            .cloned()
            .fold(0.0, f64::max);
        assert!(t4 > 1.3 * t1, "shared L2 probes must queue: {t1} vs {t4}");
    }

    #[test]
    fn slow_thread_window_shields_light_threads() {
        // One heavy streaming thread + 3 idle-ish threads: the heavy
        // thread must not be inflated (its window is the whole run).
        let mut inp = vec![streaming(10.0, 5.0, 8.0); 4];
        inp[0] = streaming(10_000.0, 10_000.0, 40_000.0); // 2 B/cyc
        let paths = [SharedPath {
            kind: PathKind::Dram,
            capacity: 4.0,
            threads: (0..4).collect(),
        }];
        let t = solve_contention(&inp, &paths);
        assert!(
            (t[0] - 20_000.0).abs() < 2_000.0,
            "heavy thread should run near-unloaded: {}",
            t[0]
        );
    }

    #[test]
    fn queue_factor_shape() {
        assert!((queue_factor(0.0) - 1.0).abs() < 1e-12);
        assert!(queue_factor(0.5) > 1.9 && queue_factor(0.5) < 2.1);
        // Capped at the MSHR/queue-depth bound (rho clamped to 0.84).
        assert!((queue_factor(0.9) - 6.25).abs() < 0.01);
        assert_eq!(queue_factor(0.9), queue_factor(2.0));
        assert!(queue_factor(2.0).is_finite());
    }

    #[test]
    fn empty_paths_ok() {
        let t = solve_contention(&[streaming(10.0, 5.0, 64.0)], &[]);
        assert_eq!(t, vec![15.0]);
    }
}
