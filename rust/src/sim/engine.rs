//! The simulation engine: replays interleaved per-thread access
//! streams through the cache hierarchy, then applies the timing model.
//!
//! Interleaving is round-robin with a fixed quantum of accesses per
//! turn — cheap, deterministic, and sufficient to produce the
//! shared-L2 interference effects (both the positive reuse of `x`
//! between core-group siblings and the capacity contention) that the
//! paper's analysis revolves around.

use crate::counters::Counters;
use crate::trace::{AccessGen, ADDR_MASK, SEQ_BIT};

use super::cache::{Cache, LINE_SHIFT};
use super::timing::{time_threads, ThreadProfile, TimingResult};
use super::topology::Topology;

/// Accesses each thread advances per round-robin turn.
const QUANTUM: usize = 64;
/// Refill chunk size per thread.
const CHUNK: usize = 4096;

/// One thread to simulate: its access stream and core pinning.
pub struct ThreadSpec<G: AccessGen> {
    pub gen: G,
    pub core: usize,
}

/// Complete result of one simulated kernel invocation.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// PAPI-style counters per thread (TOT_CYC filled from timing).
    pub per_thread: Vec<Counters>,
    /// Stall decomposition per thread (seq/rand miss split etc.) —
    /// useful for bottleneck attribution in reports.
    pub profiles: Vec<ThreadProfile>,
    pub timing: TimingResult,
}

impl SimResult {
    pub fn wall_seconds(&self) -> f64 {
        self.timing.wall_seconds
    }

    pub fn gflops(&self, flops: f64) -> f64 {
        flops / self.timing.wall_seconds / 1e9
    }

    /// Aggregate counters over threads.
    pub fn aggregate(&self) -> Counters {
        let mut agg = Counters::default();
        for c in &self.per_thread {
            agg.add(c);
        }
        agg
    }
}

/// Run the cache simulation + timing model over a set of threads.
pub fn simulate<G: AccessGen>(
    topo: &Topology,
    mut threads: Vec<ThreadSpec<G>>,
) -> SimResult {
    let n = threads.len();
    assert!(n > 0, "need at least one thread");
    for t in &threads {
        assert!(t.core < topo.cores, "core {} out of range", t.core);
    }
    // Snapshot instruction estimates before the replay drains the
    // generators (the trait reports the *remaining* stream).
    let estimates: Vec<(u64, u64)> =
        threads.iter().map(|s| s.gen.instruction_estimate()).collect();

    // Cache instances: private L1 per thread; shared L2 per group in
    // use; shared L3 per L3 group in use (Xeon).
    let mut l1: Vec<Cache> = (0..n)
        .map(|_| Cache::with_policy(topo.l1.size_bytes, topo.l1.ways, topo.l1.policy))
        .collect();
    let mut l2_of_thread = vec![0usize; n];
    let mut l2: Vec<Cache> = Vec::new();
    {
        let mut group_slot: std::collections::HashMap<usize, usize> =
            std::collections::HashMap::new();
        for (t, spec) in threads.iter().enumerate() {
            let g = topo.l2_group_of(spec.core);
            let slot = *group_slot.entry(g).or_insert_with(|| {
                l2.push(Cache::with_policy(topo.l2.size_bytes, topo.l2.ways, topo.l2.policy));
                l2.len() - 1
            });
            l2_of_thread[t] = slot;
        }
    }
    let mut l3_of_thread = vec![usize::MAX; n];
    let mut l3: Vec<Cache> = Vec::new();
    if let Some(p) = topo.l3 {
        let mut group_slot: std::collections::HashMap<usize, usize> =
            std::collections::HashMap::new();
        for (t, spec) in threads.iter().enumerate() {
            let g = topo.l3_group_of(spec.core);
            let slot = *group_slot.entry(g).or_insert_with(|| {
                l3.push(Cache::with_policy(p.size_bytes, p.ways, p.policy));
                l3.len() - 1
            });
            l3_of_thread[t] = slot;
        }
    }

    let mut counters = vec![Counters::default(); n];
    let mut profiles: Vec<ThreadProfile> = threads
        .iter()
        .map(|s| ThreadProfile { core: s.core, ..Default::default() })
        .collect();
    // Per-thread stream detectors for unmarked (x-gather) DRAM misses:
    // hardware prefetchers catch gathers that advance near-sequentially
    // (banded matrices walk x alongside the rows), so such misses are
    // latency-hidden like the marked streams. 4 tracked stream heads,
    // +-2-line adjacency, LRU allocation.
    let mut xstream: Vec<[u64; 4]> = vec![[u64::MAX; 4]; n];
    let mut xstream_next: Vec<usize> = vec![0; n];

    // Per-thread refillable chunk buffers.
    let mut bufs: Vec<Vec<u64>> = vec![Vec::with_capacity(CHUNK); n];
    let mut cursor = vec![0usize; n];
    let mut done = vec![false; n];
    let mut live = n;

    while live > 0 {
        for t in 0..n {
            if done[t] {
                continue;
            }
            let mut budget = QUANTUM;
            while budget > 0 {
                if cursor[t] == bufs[t].len() {
                    bufs[t].clear();
                    cursor[t] = 0;
                    if threads[t].gen.fill(&mut bufs[t], CHUNK) == 0 {
                        done[t] = true;
                        live -= 1;
                        break;
                    }
                }
                let take = budget.min(bufs[t].len() - cursor[t]);
                let slice = &bufs[t][cursor[t]..cursor[t] + take];
                let c = &mut counters[t];
                let p = &mut profiles[t];
                let l1c = &mut l1[t];
                let l2c = &mut l2[l2_of_thread[t]];
                // Every slice entry is an L1 access (bulk count; the
                // loop only bookkeeps the miss path).
                c.l1_dca += take as u64;
                for &word in slice {
                    let line = (word & ADDR_MASK) >> LINE_SHIFT;
                    if l1c.access_line(line) {
                        continue;
                    }
                    let seq = word & SEQ_BIT != 0;
                    c.l1_dcm += 1;
                    c.l2_dca += 1;
                    p.l2_probes += 1;
                    if l2c.access_line(line) {
                        p.l2_hits += 1;
                        continue;
                    }
                    c.l2_dcm += 1;
                    if l3_of_thread[t] != usize::MAX {
                        if l3[l3_of_thread[t]].access_line(line) {
                            p.l3_hits += 1;
                            continue;
                        }
                    }
                    if seq {
                        p.mem_seq += 1;
                    } else {
                        // x-gather miss: consult the stream detector.
                        let heads = &mut xstream[t];
                        let mut hit = false;
                        for h in heads.iter_mut() {
                            if *h != u64::MAX
                                && line.wrapping_sub(*h) <= 2
                                && line != *h
                            {
                                *h = line;
                                hit = true;
                                break;
                            }
                        }
                        if hit {
                            p.mem_seq += 1;
                        } else {
                            p.mem_rand += 1;
                            heads[xstream_next[t]] = line;
                            xstream_next[t] = (xstream_next[t] + 1) % 4;
                        }
                    }
                }
                cursor[t] += take;
                budget -= take;
            }
        }
    }

    for (t, (ins, fp)) in estimates.into_iter().enumerate() {
        counters[t].tot_ins = ins;
        counters[t].fr_ins = fp;
        profiles[t].tot_ins = ins;
    }

    finish(topo, counters, profiles)
}

fn finish(
    topo: &Topology,
    counters: Vec<Counters>,
    profiles: Vec<ThreadProfile>,
) -> SimResult {
    let timing = time_threads(topo, &profiles);
    let mut per_thread = counters;
    for (t, c) in per_thread.iter_mut().enumerate() {
        c.tot_cyc = timing.per_thread_cycles[t] as u64;
    }
    SimResult { per_thread, profiles, timing }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{Coo, Csr};
    use crate::trace::CsrTrace;
    use crate::util::rng::Pcg32;

    fn random_csr(n: usize, deg: usize, seed: u64) -> Csr {
        let mut rng = Pcg32::new(seed);
        let mut coo = Coo::new(n, n);
        for r in 0..n {
            for c in rng.sample_distinct(n, deg.min(n)) {
                coo.push(r, c, 1.0);
            }
        }
        coo.to_csr()
    }

    fn run(
        csr: &Csr,
        topo: &Topology,
        cores: &[usize],
    ) -> SimResult {
        let n = cores.len();
        let rows = csr.n_rows;
        let mut threads = Vec::new();
        let mut est = Vec::new();
        for (t, &core) in cores.iter().enumerate() {
            let r0 = rows * t / n;
            let r1 = rows * (t + 1) / n;
            let tr = CsrTrace::new(csr, r0, r1);
            est.push(tr.instruction_estimate());
            threads.push(ThreadSpec { gen: tr, core });
        }
        { let _ = &est; simulate(topo, threads) }
    }

    #[test]
    fn counters_are_consistent() {
        let csr = random_csr(1024, 8, 1);
        let topo = Topology::ft2000plus();
        let r = run(&csr, &topo, &[0]);
        let c = &r.per_thread[0];
        // Access count: 2 per row + 3 per nnz.
        assert_eq!(c.l1_dca, (2 * 1024 + 3 * csr.nnz()) as u64);
        assert!(c.l1_dcm <= c.l1_dca);
        assert_eq!(c.l2_dca, c.l1_dcm);
        assert!(c.l2_dcm <= c.l2_dca);
        assert!(c.tot_cyc > 0);
        assert!(c.tot_ins > 0 && c.fr_ins > 0);
    }

    #[test]
    fn small_matrix_mostly_hits() {
        // Working set ~24 KB < 32 KB L1: second... even first pass is
        // sequential so misses are ~1/8 of data touches. L2 misses
        // after warm L2 are near-cold-only.
        let csr = random_csr(256, 4, 2);
        let topo = Topology::ft2000plus();
        let r = run(&csr, &topo, &[0]);
        let c = &r.per_thread[0];
        assert!(
            c.l1_dcmr() < 0.25,
            "sequential streams should keep L1 DCMR low: {}",
            c.l1_dcmr()
        );
    }

    #[test]
    fn shared_l2_positive_interference_on_x() {
        // A matrix whose x working set fits in L2: with 4 in-group
        // threads the siblings share x lines, so total L2 misses stay
        // near the single-thread count rather than 4x.
        let csr = random_csr(8192, 16, 3); // x = 64 KB
        let topo = Topology::ft2000plus();
        let single = run(&csr, &topo, &[0]);
        let quad = run(&csr, &topo, &[0, 1, 2, 3]);
        let m1: u64 = single.per_thread.iter().map(|c| c.l2_dcm).sum();
        let m4: u64 = quad.per_thread.iter().map(|c| c.l2_dcm).sum();
        assert!(
            (m4 as f64) < 2.0 * m1 as f64,
            "x sharing should cap total L2 misses: {m1} -> {m4}"
        );
    }

    #[test]
    fn private_l2_splits_counters() {
        let csr = random_csr(4096, 8, 4);
        let topo = Topology::ft2000plus();
        // Spread threads across 4 distinct groups.
        let r = run(&csr, &topo, &[0, 4, 8, 12]);
        assert_eq!(r.per_thread.len(), 4);
        for c in &r.per_thread {
            assert!(c.l1_dca > 0);
        }
    }

    #[test]
    fn xeon_l3_absorbs_misses() {
        let csr = random_csr(16384, 8, 5); // x = 128 KB > L2, < L3
        let topo = Topology::xeon_e5_2692();
        let r = run(&csr, &topo, &[0]);
        let c = &r.per_thread[0];
        // L3 must absorb a meaningful share of L2 misses (x fits).
        assert!(c.l2_dcm > 0);
    }

    #[test]
    fn deterministic() {
        let csr = random_csr(2048, 8, 6);
        let topo = Topology::ft2000plus();
        let a = run(&csr, &topo, &[0, 1]);
        let b = run(&csr, &topo, &[0, 1]);
        assert_eq!(a.per_thread, b.per_thread);
    }

    #[test]
    fn gflops_accounting() {
        let csr = random_csr(1024, 8, 7);
        let topo = Topology::ft2000plus();
        let r = run(&csr, &topo, &[0]);
        let flops = 2.0 * csr.nnz() as f64;
        let g = r.gflops(flops);
        assert!(g > 0.01 && g < 50.0, "gflops={g}");
    }
}
