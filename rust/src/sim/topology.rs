//! Hardware topologies: FT-2000+ (the paper's platform, Fig 3) and an
//! Intel Xeon E5-2692 config for the Fig 2 motivation comparison.

use super::cache::Replacement;

/// Geometry of one cache level.
#[derive(Clone, Copy, Debug)]
pub struct CacheParams {
    pub size_bytes: usize,
    pub ways: usize,
    pub policy: Replacement,
}

/// A many-core chip model.
#[derive(Clone, Debug)]
pub struct Topology {
    pub name: &'static str,
    pub cores: usize,
    pub freq_ghz: f64,
    /// Private L1d per core.
    pub l1: CacheParams,
    /// L2, shared among `l2_group_cores` cores ("core-group" on FT).
    pub l2: CacheParams,
    pub l2_group_cores: usize,
    /// Optional L3 shared among `l3_group_cores` (Xeon).
    pub l3: Option<CacheParams>,
    pub l3_group_cores: usize,
    /// Unloaded access latencies (cycles).
    pub l2_lat: f64,
    pub l3_lat: f64,
    pub mem_lat: f64,
    /// Sustained issue rate for the SpMV instruction mix (ins/cycle).
    pub issue_width: f64,
    /// Fraction of a miss's latency the core cannot hide (MLP model).
    pub l2_overlap: f64,
    pub mem_overlap: f64,
    /// DRAM bandwidth per memory domain (GB/s) and its core span
    /// (FT-2000+: one panel of 8 cores shares a DCU path).
    pub bw_domain_gbs: f64,
    pub cores_per_mem_domain: usize,
    /// L2 fill-port bandwidth shared by one L2 group (GB/s) — the
    /// in-group bottleneck behind the paper's flat 1→4-thread scaling.
    pub bw_l2_port_gbs: f64,
    /// Shared-L2 access service rate (probes/cycle per group): L1
    /// misses from all group cores queue on the L2's banks/MSHRs.
    pub l2_acc_per_cycle: f64,
    /// Parallel-region fork/join cost (cycles, per invocation).
    pub fork_join_cycles: f64,
}

impl Topology {
    /// Phytium FT-2000+ ("Mars II"): 64 ARMv8 Xiaomi cores @2.3 GHz,
    /// 8 panels x 8 cores, 32 KB private L1d, 2 MB L2 shared per
    /// 4-core group, panels connected through DCUs (paper §3, Fig 3).
    ///
    /// Latency/bandwidth values follow published FT-2000+
    /// characterizations (memory latency ~130 ns-equivalent, modest
    /// per-panel sustained bandwidth — the microarchitectural reason
    /// the paper observes flat in-group scaling).
    pub fn ft2000plus() -> Topology {
        Topology {
            name: "FT-2000+",
            cores: 64,
            freq_ghz: 2.3,
            l1: CacheParams {
                size_bytes: 32 * 1024,
                ways: 4,
                policy: Replacement::Lru,
            },
            // ARM L2s replace pseudo-randomly — the mechanism behind
            // the paper's x-eviction contention (see sim::cache docs).
            l2: CacheParams {
                size_bytes: 2 * 1024 * 1024,
                ways: 16,
                policy: Replacement::Random,
            },
            l2_group_cores: 4,
            l3: None,
            l3_group_cores: 0,
            l2_lat: 21.0,
            l3_lat: 0.0,
            mem_lat: 300.0,
            issue_width: 2.2,
            l2_overlap: 0.30,
            mem_overlap: 0.33,
            bw_domain_gbs: 19.2,
            cores_per_mem_domain: 8,
            bw_l2_port_gbs: 8.8,
            l2_acc_per_cycle: 0.25,
            fork_join_cycles: 18_000.0,
        }
    }

    /// Intel Xeon E5-2692 v2 (Ivy Bridge, 12C @2.2 GHz): 32 KB L1d,
    /// 256 KB private L2, 30 MB shared L3, strong cores but a memory
    /// bus that saturates at ~4 SpMV threads (the Fig 2 Xeon curve).
    pub fn xeon_e5_2692() -> Topology {
        Topology {
            name: "Xeon E5-2692",
            cores: 16,
            freq_ghz: 2.2,
            l1: CacheParams {
                size_bytes: 32 * 1024,
                ways: 8,
                policy: Replacement::Lru,
            },
            l2: CacheParams {
                size_bytes: 256 * 1024,
                ways: 8,
                policy: Replacement::Lru,
            },
            l2_group_cores: 1, // private L2
            l3: Some(CacheParams {
                size_bytes: 32 * 1024 * 1024,
                ways: 16,
                policy: Replacement::Lru,
            }),
            l3_group_cores: 16,
            l2_lat: 12.0,
            l3_lat: 36.0,
            mem_lat: 220.0,
            issue_width: 3.2,
            l2_overlap: 0.30,
            mem_overlap: 0.42,
            bw_domain_gbs: 22.0,
            cores_per_mem_domain: 16,
            // Private L2 per core: neither the fill port nor the
            // access path is a shared bottleneck on Xeon.
            bw_l2_port_gbs: 64.0,
            l2_acc_per_cycle: 2.0,
            fork_join_cycles: 9_000.0,
        }
    }

    pub fn l2_group_of(&self, core: usize) -> usize {
        core / self.l2_group_cores
    }

    pub fn l3_group_of(&self, core: usize) -> usize {
        if self.l3_group_cores == 0 {
            0
        } else {
            core / self.l3_group_cores
        }
    }

    pub fn mem_domain_of(&self, core: usize) -> usize {
        core / self.cores_per_mem_domain
    }

    /// Bytes/cycle available to one memory domain.
    pub fn bw_bytes_per_cycle(&self) -> f64 {
        self.bw_domain_gbs * 1e9 / (self.freq_ghz * 1e9)
    }
}

/// Thread-to-core placement policies (paper §5.2.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// Fill one core-group first (threads 0..4 share one L2) then the
    /// next — the paper's default pinning for §4 and Table 2.
    CoreGroupFirst,
    /// One thread per core-group ("private-L2 mode", §5.2.2): thread t
    /// on the first core of group t, spreading across panels/DCUs.
    PrivateL2,
}

impl Placement {
    /// Map thread index -> core id under this policy.
    pub fn core_of(&self, thread: usize, topo: &Topology) -> usize {
        match self {
            Placement::CoreGroupFirst => thread % topo.cores,
            Placement::PrivateL2 => {
                let groups = topo.cores / topo.l2_group_cores;
                let g = thread % groups;
                let wrap = thread / groups; // >64-thread safety
                // Spread consecutive threads across panels first so
                // they also get separate DCU bandwidth domains.
                let per_panel = topo.cores_per_mem_domain
                    / topo.l2_group_cores; // groups per panel
                let panel = g % (groups / per_panel).max(1);
                let slot = g / (groups / per_panel).max(1);
                let core = panel * topo.cores_per_mem_domain
                    + slot * topo.l2_group_cores
                    + wrap % topo.l2_group_cores;
                core % topo.cores
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ft_geometry_matches_paper() {
        let t = Topology::ft2000plus();
        assert_eq!(t.cores, 64);
        assert_eq!(t.l2_group_cores, 4);
        assert_eq!(t.l1.size_bytes, 32 * 1024);
        assert_eq!(t.l2.size_bytes, 2 * 1024 * 1024);
        assert_eq!(t.cores / t.cores_per_mem_domain, 8); // 8 panels
    }

    #[test]
    fn group_mapping() {
        let t = Topology::ft2000plus();
        assert_eq!(t.l2_group_of(0), 0);
        assert_eq!(t.l2_group_of(3), 0);
        assert_eq!(t.l2_group_of(4), 1);
        assert_eq!(t.mem_domain_of(7), 0);
        assert_eq!(t.mem_domain_of(8), 1);
    }

    #[test]
    fn core_group_first_shares_l2() {
        let t = Topology::ft2000plus();
        let p = Placement::CoreGroupFirst;
        let groups: Vec<usize> = (0..4)
            .map(|th| t.l2_group_of(p.core_of(th, &t)))
            .collect();
        assert!(groups.iter().all(|&g| g == groups[0]));
    }

    #[test]
    fn private_l2_separates_groups() {
        let t = Topology::ft2000plus();
        let p = Placement::PrivateL2;
        let groups: Vec<usize> = (0..4)
            .map(|th| t.l2_group_of(p.core_of(th, &t)))
            .collect();
        let set: std::collections::HashSet<_> = groups.iter().collect();
        assert_eq!(set.len(), 4, "4 threads must get 4 distinct L2s: {groups:?}");
    }

    #[test]
    fn private_l2_spreads_mem_domains() {
        let t = Topology::ft2000plus();
        let p = Placement::PrivateL2;
        let domains: Vec<usize> = (0..4)
            .map(|th| t.mem_domain_of(p.core_of(th, &t)))
            .collect();
        let set: std::collections::HashSet<_> = domains.iter().collect();
        assert!(set.len() >= 2, "threads should span DCUs: {domains:?}");
    }

    #[test]
    fn placement_covers_64_threads() {
        let t = Topology::ft2000plus();
        for placement in [Placement::CoreGroupFirst, Placement::PrivateL2] {
            let cores: std::collections::HashSet<usize> = (0..64)
                .map(|th| placement.core_of(th, &t))
                .collect();
            assert_eq!(cores.len(), 64, "{placement:?} must cover all cores");
        }
    }

    #[test]
    fn bw_translation() {
        let t = Topology::ft2000plus();
        let bpc = t.bw_bytes_per_cycle();
        assert!(bpc > 1.0 && bpc < 64.0, "bytes/cycle={bpc}");
    }
}
