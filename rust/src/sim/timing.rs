//! Cycle model: turns per-thread counters into per-thread cycles.
//!
//! cycles(thread) = TOT_INS / issue_width                  (compute)
//!                + L2_hits · l2_lat · ovl · q(rho_L2)     (L2 probes)
//!                + L3_hits · l3_lat · ovl                 (Xeon only)
//!                + mem_seq · mem_lat · ovl · PF · [rho>1] (streams)
//!                + mem_rand · mem_lat · ovl · q(rho_mem)  (gathers)
//!
//! where `q` is the M/M/1-style queue factor of
//! [`super::memory::queue_factor`] over the group/domain shared paths.
//! SpMV wall time = slowest thread + fork/join overhead (the paper:
//! "the SpMV performance is determined by the slowest thread").

use super::memory::{solve_contention, PathKind, SharedPath, StallInputs};
use super::topology::Topology;

/// Fraction of the DRAM latency a prefetched sequential miss still
/// exposes (calibrated so single-core streaming SpMV lands at the
/// paper's ~0.4–0.6 Gflops).
pub const PREFETCH_FACTOR: f64 = 0.20;

/// Per-thread cache/instruction profile handed to the timing model.
#[derive(Clone, Copy, Debug, Default)]
pub struct ThreadProfile {
    pub tot_ins: u64,
    /// L1 misses (== L2 probes).
    pub l2_probes: u64,
    /// L1 misses that hit in L2.
    pub l2_hits: u64,
    /// L2 misses that hit in L3 (Xeon path; 0 on FT).
    pub l3_hits: u64,
    /// Misses to DRAM, split by stream kind.
    pub mem_seq: u64,
    pub mem_rand: u64,
    /// Core this thread is pinned to.
    pub core: usize,
}

impl ThreadProfile {
    pub fn mem_lines(&self) -> u64 {
        self.mem_seq + self.mem_rand
    }
}

/// Timing result for one simulated kernel invocation.
#[derive(Clone, Debug)]
pub struct TimingResult {
    pub per_thread_cycles: Vec<f64>,
    /// Wall cycles: slowest thread + fork/join (if >1 thread).
    pub wall_cycles: f64,
    pub wall_seconds: f64,
}

/// Compute per-thread and wall cycles under the topology's shared-path
/// constraints.
pub fn time_threads(
    topo: &Topology,
    profiles: &[ThreadProfile],
) -> TimingResult {
    let n = profiles.len();
    let ghz = topo.freq_ghz;
    let inputs: Vec<StallInputs> = profiles
        .iter()
        .map(|p| StallInputs {
            base: p.tot_ins as f64 / topo.issue_width
                + p.l3_hits as f64 * topo.l3_lat * topo.l2_overlap,
            l2_hit: p.l2_hits as f64 * topo.l2_lat * topo.l2_overlap,
            mem_seq: p.mem_seq as f64
                * topo.mem_lat
                * topo.mem_overlap
                * PREFETCH_FACTOR,
            mem_rand: p.mem_rand as f64 * topo.mem_lat * topo.mem_overlap,
            mem_bytes: p.mem_lines() as f64 * 64.0,
            l2_accesses: p.l2_probes as f64,
        })
        .collect();
    // Shared paths from the placement: one L2-access path + one DRAM
    // port per L2 group in use, one DRAM path per memory domain.
    let mut groups: std::collections::HashMap<usize, Vec<usize>> =
        std::collections::HashMap::new();
    let mut domains: std::collections::HashMap<usize, Vec<usize>> =
        std::collections::HashMap::new();
    for (t, p) in profiles.iter().enumerate() {
        groups.entry(topo.l2_group_of(p.core)).or_default().push(t);
        domains.entry(topo.mem_domain_of(p.core)).or_default().push(t);
    }
    let mut paths: Vec<SharedPath> = Vec::new();
    for (_, threads) in groups {
        paths.push(SharedPath {
            kind: PathKind::L2Access,
            capacity: topo.l2_acc_per_cycle,
            threads: threads.clone(),
        });
        paths.push(SharedPath {
            kind: PathKind::Dram,
            capacity: topo.bw_l2_port_gbs / ghz,
            threads,
        });
    }
    for (_, threads) in domains {
        paths.push(SharedPath {
            kind: PathKind::Dram,
            capacity: topo.bw_domain_gbs / ghz,
            threads,
        });
    }
    let per_thread = solve_contention(&inputs, &paths);
    let slowest = per_thread.iter().cloned().fold(0.0, f64::max);
    let fork = if n > 1 { topo.fork_join_cycles } else { 0.0 };
    let wall = slowest + fork;
    TimingResult {
        per_thread_cycles: per_thread,
        wall_cycles: wall,
        wall_seconds: wall / (ghz * 1e9),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A memory-streaming SpMV-like profile (debr/bone010 shape).
    fn streaming_profile(core: usize, scale: u64) -> ThreadProfile {
        ThreadProfile {
            tot_ins: 1_600_000 * scale,
            l2_probes: 70_000 * scale,
            l2_hits: 5_000 * scale,
            l3_hits: 0,
            mem_seq: 62_000 * scale,
            mem_rand: 3_000 * scale,
            core,
        }
    }

    /// A gather-heavy profile (conf5 shape): many L2 probes, a solid
    /// random-miss tail.
    fn gather_profile(core: usize, scale: u64) -> ThreadProfile {
        ThreadProfile {
            tot_ins: 3_000_000 * scale,
            l2_probes: 550_000 * scale,
            l2_hits: 430_000 * scale,
            l3_hits: 0,
            mem_seq: 95_000 * scale,
            mem_rand: 25_000 * scale,
            core,
        }
    }

    #[test]
    fn single_thread_baseline() {
        let topo = Topology::ft2000plus();
        let r = time_threads(&topo, &[streaming_profile(0, 1)]);
        assert_eq!(r.per_thread_cycles.len(), 1);
        assert!(r.wall_cycles > 0.0);
        assert!((r.wall_seconds - r.wall_cycles / 2.3e9).abs() < 1e-12);
    }

    fn speedup_4t(topo: &Topology, mk: fn(usize, u64) -> ThreadProfile, cores: [usize; 4]) -> f64 {
        let single = time_threads(topo, &[mk(0, 4)]);
        let quad: Vec<ThreadProfile> =
            cores.iter().map(|&c| mk(c, 1)).collect();
        let multi = time_threads(topo, &quad);
        single.wall_cycles / multi.wall_cycles
    }

    #[test]
    fn in_group_streaming_scales_partially() {
        // debr-like: paper gets ~2.2x in a core-group.
        let topo = Topology::ft2000plus();
        let s = speedup_4t(&topo, streaming_profile, [0, 1, 2, 3]);
        assert!(s > 1.6 && s < 3.2, "streaming in-group speedup: {s}");
    }

    #[test]
    fn in_group_gather_scales_poorly() {
        // conf5-like: paper gets ~1.35x in a core-group.
        let topo = Topology::ft2000plus();
        let s = speedup_4t(&topo, gather_profile, [0, 1, 2, 3]);
        assert!(s < 2.0, "gather in-group speedup should be flat: {s}");
    }

    #[test]
    fn private_l2_rescues_gather() {
        // conf5-like with threads on 4 different panels: ~3.6x.
        let topo = Topology::ft2000plus();
        let in_group = speedup_4t(&topo, gather_profile, [0, 1, 2, 3]);
        let private = speedup_4t(&topo, gather_profile, [0, 8, 16, 24]);
        assert!(
            private > in_group + 1.0,
            "private-L2 {private} must beat in-group {in_group}"
        );
        assert!(private > 3.0, "private-L2 gather speedup: {private}");
    }

    #[test]
    fn slowest_thread_dominates() {
        let topo = Topology::ft2000plus();
        let mut threads =
            vec![streaming_profile(0, 1), streaming_profile(1, 1)];
        threads[1].tot_ins *= 20; // imbalanced
        let r = time_threads(&topo, &threads);
        assert!(r.per_thread_cycles[1] > r.per_thread_cycles[0] * 3.0);
        assert!(r.wall_cycles >= r.per_thread_cycles[1]);
    }

    #[test]
    fn random_misses_cost_more_than_seq() {
        let topo = Topology::ft2000plus();
        let seq = ThreadProfile {
            tot_ins: 1000,
            mem_seq: 10_000,
            ..Default::default()
        };
        let rand = ThreadProfile {
            tot_ins: 1000,
            mem_rand: 10_000,
            ..Default::default()
        };
        let t_seq = time_threads(&topo, &[seq]).wall_cycles;
        let t_rand = time_threads(&topo, &[rand]).wall_cycles;
        assert!(t_rand > 3.0 * t_seq, "{t_rand} vs {t_seq}");
    }

    #[test]
    fn xeon_faster_single_core() {
        // Fig 2: Xeon's single-thread SpMV clearly beats FT-2000+'s.
        let ft = time_threads(
            &Topology::ft2000plus(),
            &[streaming_profile(0, 1)],
        );
        let xeon = time_threads(
            &Topology::xeon_e5_2692(),
            &[streaming_profile(0, 1)],
        );
        // Cycle counts: xeon runs fewer cycles AND at similar clock.
        assert!(xeon.wall_cycles < ft.wall_cycles);
    }
}
