//! Set-associative LRU cache model.
//!
//! Miss counting only (no dirty/writeback modelling): the paper's
//! analysis consumes PAPI miss *rates*, which a write-allocate LRU
//! model reproduces. This is the simulator's innermost loop — keep it
//! allocation-free and branch-light (§Perf optimizes here).

/// Replacement policy.
///
/// FT-2000+'s ARM caches use pseudo-random replacement — which is not
/// a modeling shortcut but the mechanism behind the paper's central
/// observation: streaming SpMV traffic continuously evicts the shared
/// `x` vector from the L2 even when `x` would fit, and four threads'
/// combined streams quadruple the eviction pressure (the
/// `L2_DCMR_change` factor). LRU would keep the frequently-touched
/// `x` lines pinned and hide the effect.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Replacement {
    Lru,
    /// Pseudo-random victim way (xorshift, deterministic).
    Random,
}

/// One set-associative cache level.
///
/// Ways are kept in recency order (move-to-front): `tags[set*ways]`
/// is the MRU line, the last way is the LRU victim. This is exact LRU
/// without stamp bookkeeping, and makes the common case — a hit on
/// the most recent line of a sequential stream — a single compare
/// (§Perf: the probe loop is the simulator's innermost loop).
#[derive(Clone, Debug)]
pub struct Cache {
    sets: usize,
    ways: usize,
    set_mask: u64,
    /// tags\[set * ways + way\] in MRU→LRU order; `u64::MAX` = invalid.
    tags: Vec<u64>,
    policy: Replacement,
    /// xorshift state for Random replacement (deterministic).
    prng: u64,
    pub accesses: u64,
    pub misses: u64,
}

pub const LINE_BYTES: u64 = 64;
pub const LINE_SHIFT: u32 = 6;

impl Cache {
    /// `size_bytes` must give a power-of-two set count for the chosen
    /// associativity and 64-byte lines.
    pub fn new(size_bytes: usize, ways: usize) -> Self {
        Self::with_policy(size_bytes, ways, Replacement::Lru)
    }

    pub fn with_policy(
        size_bytes: usize,
        ways: usize,
        policy: Replacement,
    ) -> Self {
        assert!(ways > 0);
        let lines = size_bytes / LINE_BYTES as usize;
        assert!(lines >= ways, "cache smaller than one set");
        let sets = lines / ways;
        assert!(
            sets.is_power_of_two(),
            "set count {sets} must be a power of two (size {size_bytes}, ways {ways})"
        );
        Cache {
            sets,
            ways,
            set_mask: (sets - 1) as u64,
            tags: vec![u64::MAX; sets * ways],
            policy,
            prng: 0x2545_F491_4F6C_DD1D,
            accesses: 0,
            misses: 0,
        }
    }

    pub fn size_bytes(&self) -> usize {
        self.sets * self.ways * LINE_BYTES as usize
    }

    pub fn reset_stats(&mut self) {
        self.accesses = 0;
        self.misses = 0;
    }

    /// Probe + fill one cache line (identified by `line = addr >> 6`).
    /// Returns `true` on hit. On miss a victim way is replaced per the
    /// policy (invalid ways, which accumulate at the LRU end, are
    /// always preferred).
    #[inline]
    pub fn access_line(&mut self, line: u64) -> bool {
        self.accesses += 1;
        let set = (line & self.set_mask) as usize;
        let base = set * self.ways;
        let ways = &mut self.tags[base..base + self.ways];
        // Fast path: MRU hit (sequential streams live here).
        if ways[0] == line {
            return true;
        }
        for w in 1..ways.len() {
            if ways[w] == line {
                // Move to front (exact LRU recency update).
                ways[..=w].rotate_right(1);
                ways[0] = line;
                return true;
            }
        }
        // Miss: pick the victim position.
        self.misses += 1;
        let last = ways.len() - 1;
        let victim = if ways[last] == u64::MAX {
            // Cold set: invalids sink to the LRU end; consume them.
            last
        } else {
            match self.policy {
                Replacement::Lru => last,
                Replacement::Random => {
                    // xorshift64*
                    self.prng ^= self.prng >> 12;
                    self.prng ^= self.prng << 25;
                    self.prng ^= self.prng >> 27;
                    (self.prng.wrapping_mul(0x2545_F491_4F6C_DD1D) >> 33)
                        as usize
                        % self.ways
                }
            }
        };
        // Insert the new line at the MRU position.
        ways[..=victim].rotate_right(1);
        ways[0] = line;
        false
    }

    /// Byte-address convenience wrapper.
    #[inline]
    pub fn access(&mut self, addr: u64) -> bool {
        self.access_line(addr >> LINE_SHIFT)
    }

    pub fn miss_rate(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.misses as f64 / self.accesses as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry() {
        let c = Cache::new(32 * 1024, 4); // FT-2000+ L1d
        assert_eq!(c.size_bytes(), 32 * 1024);
        let c = Cache::new(2 * 1024 * 1024, 16); // FT-2000+ shared L2
        assert_eq!(c.size_bytes(), 2 * 1024 * 1024);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn rejects_non_pow2_sets() {
        Cache::new(48 * 1024, 4);
    }

    #[test]
    fn cold_miss_then_hit() {
        let mut c = Cache::new(4096, 4);
        assert!(!c.access(0x1000));
        assert!(c.access(0x1000));
        assert!(c.access(0x1008)); // same line
        assert!(!c.access(0x1040)); // next line
        assert_eq!(c.accesses, 4);
        assert_eq!(c.misses, 2);
    }

    #[test]
    fn lru_eviction_order() {
        // 2-way, force a set conflict: lines mapping to the same set
        // differ by sets*LINE_BYTES.
        let mut c = Cache::new(2 * 64 * 4, 2); // 4 sets, 2 ways
        let stride = 4 * 64; // same-set stride
        let a = 0u64;
        let b = a + stride;
        let d = a + 2 * stride;
        assert!(!c.access(a));
        assert!(!c.access(b));
        assert!(c.access(a)); // refresh a; b is now LRU
        assert!(!c.access(d)); // evicts b
        assert!(c.access(a)); // a survives
        assert!(!c.access(b)); // b was evicted
    }

    #[test]
    fn working_set_fits() {
        // Streaming a working set smaller than the cache twice: second
        // pass must be all hits.
        let mut c = Cache::new(32 * 1024, 4);
        for addr in (0..16 * 1024u64).step_by(64) {
            c.access(addr);
        }
        let misses_cold = c.misses;
        for addr in (0..16 * 1024u64).step_by(64) {
            assert!(c.access(addr));
        }
        assert_eq!(c.misses, misses_cold);
    }

    #[test]
    fn working_set_thrashes() {
        // A working set 2x the cache streamed repeatedly with LRU: ~0
        // reuse (the classic LRU streaming pathology).
        let mut c = Cache::new(4096, 4);
        let span = 8192u64;
        for _ in 0..3 {
            for addr in (0..span).step_by(64) {
                c.access(addr);
            }
        }
        assert!(c.miss_rate() > 0.99, "rate={}", c.miss_rate());
    }

    #[test]
    fn miss_rate_empty() {
        let c = Cache::new(4096, 4);
        assert_eq!(c.miss_rate(), 0.0);
    }
}
