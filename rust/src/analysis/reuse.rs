//! LRU stack-distance (reuse-distance) analysis.
//!
//! For a reference stream, the *stack distance* of an access is the
//! number of distinct lines touched since the previous access to the
//! same line (∞ for first touches). The miss count of a fully
//! associative LRU cache of capacity `S` lines is exactly the number
//! of accesses with distance ≥ S — so one pass yields the
//! miss-vs-cache-size curve for every size at once (Mattson et al.
//! 1970). Applied to the x-gather stream of a matrix's row order, it
//! quantifies how cacheable `x` is — the factor behind the paper's
//! nnz_var/locality analysis and the §5.2.3 reorder.
//!
//! Implementation: O(N log N) with an order-statistics (Fenwick) tree
//! over access timestamps + a last-touch map.

use std::collections::HashMap;

use crate::sparse::Csr;

/// Fenwick tree (binary indexed tree) for prefix sums.
struct Fenwick {
    tree: Vec<u32>,
}

impl Fenwick {
    fn new(n: usize) -> Self {
        Fenwick { tree: vec![0; n + 1] }
    }

    fn add(&mut self, mut i: usize, delta: i32) {
        i += 1;
        while i < self.tree.len() {
            self.tree[i] = (self.tree[i] as i32 + delta) as u32;
            i += i & i.wrapping_neg();
        }
    }

    /// Sum of [0, i].
    fn prefix(&self, mut i: usize) -> u32 {
        i += 1;
        let mut s = 0;
        while i > 0 {
            s += self.tree[i];
            i -= i & i.wrapping_neg();
        }
        s
    }
}

/// Histogram of LRU stack distances.
#[derive(Clone, Debug)]
pub struct ReuseProfile {
    /// `hist[b]` = accesses with distance in `[2^b, 2^(b+1))`
    /// (b = 0 covers distance 0..2).
    pub hist: Vec<u64>,
    /// First touches (cold / infinite distance).
    pub cold: u64,
    pub total: u64,
}

impl ReuseProfile {
    /// Misses of a fully associative LRU cache holding `lines` lines
    /// (distance >= lines => miss). Conservative: a bucket straddling
    /// the boundary is counted entirely (so `misses_at(S)` >= exact
    /// and `misses_at(2S)` <= exact — see the brute-force test).
    pub fn misses_at(&self, lines: usize) -> u64 {
        let mut misses = self.cold;
        for (b, &count) in self.hist.iter().enumerate() {
            // Bucket b holds distances in [2^(b-1), 2^b) (b = 0: {0}).
            let hi_exclusive = 1u64 << b;
            if hi_exclusive > lines as u64 {
                misses += count;
            }
        }
        misses
    }

    pub fn miss_rate_at(&self, lines: usize) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.misses_at(lines) as f64 / self.total as f64
        }
    }

    /// Median stack distance (of finite reuses), as a locality score.
    pub fn median_distance(&self) -> u64 {
        let finite: u64 = self.hist.iter().sum();
        if finite == 0 {
            return u64::MAX;
        }
        let mut acc = 0;
        for (b, &count) in self.hist.iter().enumerate() {
            acc += count;
            if acc * 2 >= finite {
                return 1 << b;
            }
        }
        u64::MAX
    }
}

/// Stack-distance profile of an arbitrary reference stream.
pub fn profile_stream<I: IntoIterator<Item = u64>>(stream: I) -> ReuseProfile {
    let mut last_touch: HashMap<u64, usize> = HashMap::new();
    let mut hist = vec![0u64; 40];
    let mut cold = 0u64;
    let mut total = 0u64;
    // Collect to know N for the Fenwick tree.
    let refs: Vec<u64> = stream.into_iter().collect();
    let mut fen = Fenwick::new(refs.len());
    for (t, &line) in refs.iter().enumerate() {
        total += 1;
        match last_touch.insert(line, t) {
            None => cold += 1,
            Some(prev) => {
                // Distinct lines touched in (prev, t) = number of
                // "live" last-touch marks in that window.
                let distinct =
                    fen.prefix(t) - fen.prefix(prev);
                let b = (64 - u64::from(distinct).leading_zeros())
                    .min(hist.len() as u32 - 1)
                    as usize;
                hist[b] += 1;
                // prev is no longer a last touch.
                fen.add(prev, -1);
            }
        }
        fen.add(t, 1);
    }
    ReuseProfile { hist, cold, total }
}

/// Profile of the x-gather line stream for a CSR matrix in row order
/// (8 f64 per 64-byte line).
pub fn x_reuse_profile(csr: &Csr) -> ReuseProfile {
    profile_stream(
        csr.indices.iter().map(|&c| c as u64 / 8),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::generators;
    use crate::util::rng::Pcg32;

    #[test]
    fn repeated_line_distance_zero() {
        let p = profile_stream(vec![5, 5, 5, 5]);
        assert_eq!(p.cold, 1);
        assert_eq!(p.hist[0], 3); // distance 0 -> bucket 0
        assert_eq!(p.misses_at(1), 1);
    }

    #[test]
    fn cyclic_stream_distance_equals_working_set() {
        // 0,1,2,3,0,1,2,3,...: every reuse has distance 3.
        let stream: Vec<u64> =
            (0..40).map(|i| (i % 4) as u64).collect();
        let p = profile_stream(stream);
        assert_eq!(p.cold, 4);
        // distance 3 lands in bucket [2,4) = b=2.
        assert_eq!(p.hist[2], 36);
        // A 4-line cache holds the loop; a 2-line cache misses it all.
        assert_eq!(p.misses_at(4), 4);
        assert_eq!(p.misses_at(2), 40);
    }

    #[test]
    fn matches_brute_force_lru() {
        // Cross-check misses_at against a simulated fully associative
        // LRU for random streams.
        let mut rng = Pcg32::new(0xD157);
        for _ in 0..5 {
            let stream: Vec<u64> =
                (0..400).map(|_| rng.gen_range(30) as u64).collect();
            let p = profile_stream(stream.clone());
            for cap in [1usize, 2, 4, 8, 16, 32] {
                let mut lru: Vec<u64> = Vec::new();
                let mut misses = 0u64;
                for &l in &stream {
                    if let Some(pos) = lru.iter().position(|&x| x == l) {
                        lru.remove(pos);
                    } else {
                        misses += 1;
                        if lru.len() == cap {
                            lru.remove(0);
                        }
                    }
                    lru.push(l);
                }
                // Bucketing makes misses_at conservative (>= exact)
                // but never more than one power-of-two bucket off.
                let approx = p.misses_at(cap);
                assert!(
                    approx >= misses,
                    "cap {cap}: approx {approx} < exact {misses}"
                );
                let loose = p.misses_at(cap * 2);
                assert!(
                    loose <= misses,
                    "cap {cap}: 2x-cap bound {loose} > exact {misses}"
                );
            }
        }
    }

    #[test]
    fn banded_x_is_highly_local() {
        let mut rng = Pcg32::new(1);
        let banded = generators::banded(2048, 5, &mut rng);
        let p = x_reuse_profile(&banded);
        assert!(p.median_distance() <= 4, "{}", p.median_distance());
        // A tiny cache captures almost all reuse.
        assert!(p.miss_rate_at(64) < 0.2);
    }

    #[test]
    fn poor_locality_x_is_distant() {
        let mut rng = Pcg32::new(2);
        let bad = generators::poor_locality(2048, 4, 64, &mut rng);
        let good = {
            let plan = crate::reorder::locality_reorder(&bad, 64);
            plan.apply(&bad)
        };
        let p_bad = x_reuse_profile(&bad);
        let p_good = x_reuse_profile(&good);
        // (Within-row contiguity makes the *median* distance small for
        // both; the cross-row reuse tail is where they differ.)
        assert!(
            p_good.median_distance() <= p_bad.median_distance(),
            "reorder must not lengthen reuse: {} -> {}",
            p_bad.median_distance(),
            p_good.median_distance()
        );
        // At a small-cache capacity (which is also where set conflicts
        // bite on real hardware) the reordered stream misses far less.
        let cap = 64;
        assert!(
            p_good.miss_rate_at(cap) < 0.5 * p_bad.miss_rate_at(cap),
            "{} vs {}",
            p_good.miss_rate_at(cap),
            p_bad.miss_rate_at(cap)
        );
    }

    #[test]
    fn empty_matrix() {
        let p = x_reuse_profile(&crate::sparse::Csr::zero(8, 8));
        assert_eq!(p.total, 0);
        assert_eq!(p.miss_rate_at(100), 0.0);
    }
}
