//! ASCII spy plots and structural profiles — the visual half of the
//! Table-4 "sparsity structure" column and the report generator.

use crate::sparse::Csr;

/// Density spy plot: `rows x cols` character grid; darker glyphs mark
/// denser blocks.
pub fn spy(csr: &Csr, rows: usize, cols: usize) -> String {
    let rows = rows.max(1);
    let cols = cols.max(1);
    let mut grid = vec![0u32; rows * cols];
    if csr.n_rows == 0 || csr.n_cols == 0 {
        return String::new();
    }
    for r in 0..csr.n_rows {
        let gr = r * rows / csr.n_rows;
        let (rc, _) = csr.row(r);
        for &c in rc {
            let gc = (c as usize) * cols / csr.n_cols;
            grid[gr * cols + gc] += 1;
        }
    }
    let max = *grid.iter().max().unwrap_or(&1);
    let glyphs = [' ', '.', ':', '+', '*', '#', '@'];
    let mut out = String::with_capacity(rows * (cols + 3));
    for gr in 0..rows {
        out.push('|');
        for gc in 0..cols {
            let v = grid[gr * cols + gc];
            let g = if v == 0 {
                0
            } else {
                1 + (v as usize * (glyphs.len() - 2)) / max as usize
            };
            out.push(glyphs[g.min(glyphs.len() - 1)]);
        }
        out.push_str("|\n");
    }
    out
}

/// Row-degree histogram over log2 buckets: (bucket_label, count).
pub fn degree_histogram(csr: &Csr) -> Vec<(String, usize)> {
    let mut buckets = vec![0usize; 24];
    for r in 0..csr.n_rows {
        let d = csr.row_nnz(r);
        let b = if d == 0 {
            0
        } else {
            (usize::BITS - d.leading_zeros()) as usize
        };
        buckets[b.min(23)] += 1;
    }
    buckets
        .iter()
        .enumerate()
        .filter(|&(_, &c)| c > 0)
        .map(|(b, &c)| {
            let label = if b == 0 {
                "0".to_string()
            } else {
                format!("{}..{}", 1usize << (b - 1), (1usize << b) - 1)
            };
            (label, c)
        })
        .collect()
}

/// Matrix bandwidth profile: (max |col-row|, mean |col-row|).
pub fn bandwidth(csr: &Csr) -> (usize, f64) {
    let mut max = 0usize;
    let mut sum = 0f64;
    let mut n = 0u64;
    for r in 0..csr.n_rows {
        let (cols, _) = csr.row(r);
        for &c in cols {
            let d = (c as i64 - r as i64).unsigned_abs() as usize;
            max = max.max(d);
            sum += d as f64;
            n += 1;
        }
    }
    (max, if n == 0 { 0.0 } else { sum / n as f64 })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::generators;
    use crate::util::rng::Pcg32;

    #[test]
    fn spy_shapes() {
        let mut rng = Pcg32::new(1);
        let csr = generators::banded(256, 5, &mut rng);
        let s = spy(&csr, 8, 16);
        assert_eq!(s.lines().count(), 8);
        // A banded matrix lights the diagonal cells.
        let first = s.lines().next().unwrap();
        assert_ne!(first.chars().nth(1), Some(' '));
    }

    #[test]
    fn spy_empty() {
        assert!(spy(&Csr::zero(0, 0), 4, 4).is_empty());
        let blank = spy(&Csr::zero(4, 4), 2, 2);
        assert!(blank.chars().all(|c| c == ' ' || c == '|' || c == '\n'));
    }

    #[test]
    fn degree_histogram_sums_to_rows() {
        let mut rng = Pcg32::new(2);
        let csr = generators::power_law(512, 6.0, 1.6, &mut rng);
        let h = degree_histogram(&csr);
        let total: usize = h.iter().map(|(_, c)| c).sum();
        assert_eq!(total, 512);
    }

    #[test]
    fn bandwidth_of_banded() {
        let mut rng = Pcg32::new(3);
        let csr = generators::banded(128, 7, &mut rng);
        let (max, mean) = bandwidth(&csr);
        assert!(max <= 4, "band halfwidth: {max}");
        assert!(mean <= 4.0);
    }

    #[test]
    fn bandwidth_of_identity() {
        assert_eq!(bandwidth(&Csr::identity(9)), (0, 0.0));
    }
}
