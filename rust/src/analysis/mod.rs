//! Offline matrix/trace analysis tools.
//!
//! * [`reuse`] — Mattson stack-distance (LRU reuse-distance) analysis
//!   of the `x`-vector gather stream: the quantitative version of the
//!   paper's §5.1 locality argument ("how the dense vector x will be
//!   reused"), and the input the advisor uses to justify the §5.2.3
//!   reordering.
//! * [`spy`] — ASCII spy plots and structural profiles (row-degree
//!   histogram, bandwidth profile) for reports and examples.

pub mod reuse;
pub mod spy;
