//! # ft2000-spmv
//!
// Every `unsafe` operation must sit in an explicit `unsafe { }` block
// even inside `unsafe fn`, and every such block carries a `// SAFETY:`
// comment (warned here, promoted to an error by `-D warnings` in CI;
// `ft2000-lint` enforces the comment rule without a toolchain).
#![deny(unsafe_op_in_unsafe_fn)]
#![warn(clippy::undocumented_unsafe_blocks)]
//!
//! Reproduction of *"Characterizing Scalability of Sparse Matrix-Vector
//! Multiplications on Phytium FT-2000+ Many-cores"* (Chen, Fang, Xu,
//! Chen, Wang — IJPP 2019).
//!
//! The library provides, from the bottom up:
//!
//! * [`sparse`] — CSR / CSR5 / ELL / HYB / COO formats + Table-3
//!   matrix features;
//! * [`corpus`] — a deterministic synthetic stand-in for the paper's
//!   1008 SuiteSparse matrices, plus replicas of its case studies;
//! * [`sim`] — a trace-driven FT-2000+ many-core cache/memory/timing
//!   simulator (and a Xeon config for the Fig 2 comparison);
//! * [`trace`] — per-thread SpMV address-stream generators;
//! * [`counters`] — PAPI-style events and the derived features
//!   (L1_DCMR, L2_DCMR, IPC, L2_DCMR_change, job_var);
//! * [`exec`] — native threaded SpMV executors (functional path);
//! * [`sched`] — nonzero partitioners and core placements;
//! * [`reorder`] — the locality-aware row reordering of §5.2.3;
//! * [`mlmodel`] — CART regression trees / forests + feature
//!   importance (the paper's scikit-learn analysis, from scratch);
//! * [`coordinator`] — campaign orchestration: sweeps, datasets,
//!   reports;
//! * [`runtime`] — PJRT execution of the AOT-compiled Pallas SpMV
//!   kernels in `artifacts/` (python never runs at request time;
//!   native f32 fallback without the `pjrt` feature);
//! * [`service`] — the serving layer: matrix registry, per-matrix
//!   plan cache, batched request executor (same-matrix coalescing
//!   into multi-vector SpMM), NUMA-panel-sharded serving with
//!   placement policies and admission control, deterministic traffic
//!   replay, and serving telemetry with streaming percentiles;
//! * [`autotune`] — online closed-loop plan tuning: per-matrix
//!   explore/exploit over plan variants (epsilon-greedy / UCB1) fed
//!   by measured serving latency, knee-hunting thread-count
//!   hill-climb, promotion into the versioned plan cache, drift-based
//!   demotion, JSON snapshots, and observation datasets for
//!   retraining the offline planner;
//! * [`obs`] — serve-path observability: a lock-free stage-span
//!   recorder (Chrome `trace_event` export, per-stage/per-schedule
//!   flame table, wall or virtual clock) and a unified metrics
//!   registry (counters, gauges, log-bucketed histograms) whose
//!   snapshot schema absorbs the serving/shard/pool/plan-cache/
//!   autotune surfaces;
//! * [`check`] — the static-analysis/correctness layer: structural
//!   invariant verifiers for every sparse format and for
//!   partitions/plans/plan-cache versions (`CheckReport` findings,
//!   wired into registry admission, dispatch validation, and the
//!   `ft2000-spmv check` CLI), a deterministic interleaving harness
//!   for the lock-free pool + trace rings, and a vector-clock
//!   happens-before race detector (`check::hb`, `check --hb`) over
//!   the event logs captured by [`util::ordatomic`]'s instrumented
//!   atomics (`--features hbcheck`; zero-cost passthrough otherwise)
//!   — reporting both unordered conflicting accesses and
//!   ordering-strength waste;
//! * [`resil`] — deterministic fault injection and graceful
//!   degradation: seeded virtual-clock fault plans (lane stalls,
//!   worker panics, shard outages, queue spikes, corrupt payloads),
//!   the health tracker / degraded-mode ladder the serve path
//!   consults on every dispatch, shard failover and bounded-retry
//!   backoff, the versioned `ft2000.health.v1` snapshot, and the
//!   `ft2000-spmv chaos` replayable fault-matrix sweep.

pub mod analysis;
pub mod autotune;
pub mod check;
pub mod cli;
pub mod coordinator;
pub mod corpus;
pub mod counters;
pub mod exec;
pub mod mlmodel;
pub mod obs;
pub mod reorder;
pub mod resil;
pub mod runtime;
pub mod sched;
pub mod service;
pub mod sim;
pub mod solver;
pub mod sparse;
pub mod trace;
pub mod util;
