//! The synthetic 1008-matrix suite — stand-in for the paper's
//! SuiteSparse sweep (§3 Datasets).
//!
//! 1008 = 9 structural classes × 112 parameter points. Sizes are
//! log-uniform; nnz spans ~2K–2M so the corpus crosses the
//! L2-resident → memory-bound boundary of the simulated 2 MB shared L2
//! the same way the paper's 100K–200M-nnz corpus crosses the real one.

use crate::util::rng::Pcg32;

use super::generators::MatrixClass;
use super::CorpusMatrix;

/// Parameters of a suite sweep.
#[derive(Clone, Debug)]
pub struct SuiteSpec {
    /// Matrices per class.
    pub per_class: usize,
    /// Log-uniform row-count range.
    pub n_range: (usize, usize),
    /// Target average row degree range (log-uniform).
    pub deg_range: (f64, f64),
    /// Master seed.
    pub seed: u64,
}

impl SuiteSpec {
    /// The full paper-scale suite: 9 × 112 = 1008 matrices.
    ///
    /// `n` spans past the shared-L2 boundary (x up to 2 MB) so the
    /// `L2_DCMR_change` feature is exercised the way the paper's
    /// 100K–200M-nnz corpus exercises the real 2 MB L2.
    pub fn full() -> Self {
        SuiteSpec {
            per_class: 112,
            n_range: (1_024, 262_144),
            deg_range: (2.0, 80.0),
            seed: 0x5347_2019,
        }
    }

    /// A fast subset (~126 matrices) for smoke runs and CI.
    pub fn fast() -> Self {
        SuiteSpec { per_class: 14, ..Self::full() }
    }

    /// A tiny subset for unit tests.
    pub fn tiny() -> Self {
        SuiteSpec {
            per_class: 2,
            n_range: (256, 2_048),
            deg_range: (2.0, 16.0),
            seed: 0x5347_2019,
        }
    }

    pub fn total(&self) -> usize {
        self.per_class * MatrixClass::ALL.len()
    }

    /// Enumerate the suite's entries (parameters only — cheap).
    pub fn entries(&self) -> Vec<SuiteEntry> {
        let mut rng = Pcg32::new(self.seed);
        let mut out = Vec::with_capacity(self.total());
        for class in MatrixClass::ALL {
            for i in 0..self.per_class {
                let n = log_uniform(
                    &mut rng,
                    self.n_range.0 as f64,
                    self.n_range.1 as f64,
                ) as usize;
                let deg = log_uniform(
                    &mut rng,
                    self.deg_range.0,
                    self.deg_range.1,
                );
                let target_nnz =
                    ((n as f64 * deg) as usize).max(n).min(4_000_000);
                let seed = rng.next_u64();
                out.push(SuiteEntry {
                    name: format!("{}_{i:03}", class.name()),
                    class,
                    n,
                    target_nnz,
                    seed,
                });
            }
        }
        out
    }

    /// Generate a matrix from one entry.
    pub fn materialize(&self, e: &SuiteEntry) -> CorpusMatrix {
        CorpusMatrix {
            name: e.name.clone(),
            class: e.class,
            seed: e.seed,
            csr: e.class.generate(e.n, e.target_nnz, e.seed),
        }
    }
}

/// One matrix's generation parameters.
#[derive(Clone, Debug)]
pub struct SuiteEntry {
    pub name: String,
    pub class: MatrixClass,
    pub n: usize,
    pub target_nnz: usize,
    pub seed: u64,
}

fn log_uniform(rng: &mut Pcg32, lo: f64, hi: f64) -> f64 {
    (rng.gen_f64_range(lo.ln(), hi.ln())).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_is_1008() {
        assert_eq!(SuiteSpec::full().total(), 1008);
        assert_eq!(SuiteSpec::full().entries().len(), 1008);
    }

    #[test]
    fn entries_deterministic() {
        let a = SuiteSpec::fast().entries();
        let b = SuiteSpec::fast().entries();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.seed, y.seed);
            assert_eq!(x.n, y.n);
        }
    }

    #[test]
    fn names_unique() {
        let entries = SuiteSpec::fast().entries();
        let set: std::collections::HashSet<&str> =
            entries.iter().map(|e| e.name.as_str()).collect();
        assert_eq!(set.len(), entries.len());
    }

    #[test]
    fn sizes_in_range() {
        let spec = SuiteSpec::tiny();
        for e in spec.entries() {
            assert!(e.n >= spec.n_range.0 && e.n <= spec.n_range.1);
            let m = spec.materialize(&e);
            assert!(m.csr.validate().is_ok(), "{}", e.name);
        }
    }

    #[test]
    fn covers_all_classes() {
        let entries = SuiteSpec::tiny().entries();
        let classes: std::collections::HashSet<_> =
            entries.iter().map(|e| e.class).collect();
        assert_eq!(classes.len(), MatrixClass::ALL.len());
    }
}
