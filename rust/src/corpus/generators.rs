//! Structural matrix generators, one per SuiteSparse domain family.

use crate::sparse::{Coo, Csr};
use crate::util::rng::Pcg32;

/// Structural families found in the SuiteSparse collection, matched to
/// the scalability behaviours the paper analyzes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MatrixClass {
    /// Banded FEM/stencil matrices (regular, good x-locality) —
    /// the `debr` behaviour class.
    Banded,
    /// 5-point 2-D grid Laplacian (very regular, nnz_var = 0).
    Stencil5,
    /// 9-point 2-D grid Laplacian.
    Stencil9,
    /// Uniform random pattern (poor locality) — the `appu` class.
    RandomUniform,
    /// Power-law / social-network degrees (skewed rows).
    PowerLaw,
    /// Dense row-block outliers concentrating the nonzeros — the
    /// `exdata_1` pathology class.
    DenseRowBlock,
    /// Fixed row degree with wide random spread (regular but
    /// contention-heavy) — the `conf5_4-8x8-20` (QCD lattice) class.
    RegularWide,
    /// Road-network-like: tiny degree, near-1-D locality — the
    /// `asia_osm` class.
    RoadNetwork,
    /// Fig 9's synthesized poor-locality matrix: balanced rows whose
    /// column clusters are interleaved so consecutive rows touch
    /// distant parts of x.
    PoorLocality,
}

impl MatrixClass {
    pub const ALL: [MatrixClass; 9] = [
        MatrixClass::Banded,
        MatrixClass::Stencil5,
        MatrixClass::Stencil9,
        MatrixClass::RandomUniform,
        MatrixClass::PowerLaw,
        MatrixClass::DenseRowBlock,
        MatrixClass::RegularWide,
        MatrixClass::RoadNetwork,
        MatrixClass::PoorLocality,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            MatrixClass::Banded => "banded",
            MatrixClass::Stencil5 => "stencil5",
            MatrixClass::Stencil9 => "stencil9",
            MatrixClass::RandomUniform => "random_uniform",
            MatrixClass::PowerLaw => "power_law",
            MatrixClass::DenseRowBlock => "dense_row_block",
            MatrixClass::RegularWide => "regular_wide",
            MatrixClass::RoadNetwork => "road_network",
            MatrixClass::PoorLocality => "poor_locality",
        }
    }

    /// Generate an `n x n` matrix with roughly `target_nnz` nonzeros.
    pub fn generate(&self, n: usize, target_nnz: usize, seed: u64) -> Csr {
        let mut rng = Pcg32::new(seed);
        let deg = (target_nnz as f64 / n.max(1) as f64).max(1.0);
        match self {
            MatrixClass::Banded => banded(n, deg.round() as usize, &mut rng),
            MatrixClass::Stencil5 => stencil(n, 5),
            MatrixClass::Stencil9 => stencil(n, 9),
            MatrixClass::RandomUniform => {
                random_uniform(n, (deg.round() as usize).max(1), &mut rng)
            }
            MatrixClass::PowerLaw => power_law(n, deg, 1.6, &mut rng),
            MatrixClass::DenseRowBlock => {
                dense_row_block(n, target_nnz, &mut rng)
            }
            MatrixClass::RegularWide => {
                regular_wide(n, (deg.round() as usize).max(2), &mut rng)
            }
            MatrixClass::RoadNetwork => road_network(n, &mut rng),
            MatrixClass::PoorLocality => {
                poor_locality(n, (deg.round() as usize).max(2), 64, &mut rng)
            }
        }
    }
}

fn val(rng: &mut Pcg32) -> f64 {
    // Nonzero magnitudes around 1.0; never exactly zero.
    0.1 + rng.gen_f64()
}

/// Banded matrix: `band` diagonals clustered around the main diagonal.
pub fn banded(n: usize, band: usize, rng: &mut Pcg32) -> Csr {
    let band = band.clamp(1, n.max(1));
    let mut coo = Coo::with_capacity(n, n, n * band);
    let half = (band / 2) as isize;
    for r in 0..n as isize {
        for d in -half..=(band as isize - half - 1) {
            let c = r + d;
            if c >= 0 && c < n as isize {
                coo.push(r as usize, c as usize, val(rng));
            }
        }
    }
    coo.to_csr()
}

/// 2-D grid Laplacian stencil (5- or 9-point) on a ~sqrt(n) x sqrt(n)
/// grid; n is rounded down to a perfect square.
pub fn stencil(n: usize, points: usize) -> Csr {
    let side = (n as f64).sqrt().floor() as usize;
    let side = side.max(1);
    let n = side * side;
    let mut coo = Coo::with_capacity(n, n, n * points);
    let idx = |i: usize, j: usize| i * side + j;
    for i in 0..side {
        for j in 0..side {
            let r = idx(i, j);
            coo.push(r, r, 4.0);
            let mut neigh: Vec<(isize, isize)> =
                vec![(-1, 0), (1, 0), (0, -1), (0, 1)];
            if points == 9 {
                neigh.extend_from_slice(&[(-1, -1), (-1, 1), (1, -1), (1, 1)]);
            }
            for (di, dj) in neigh {
                let (ni, nj) = (i as isize + di, j as isize + dj);
                if ni >= 0 && ni < side as isize && nj >= 0 && nj < side as isize
                {
                    coo.push(r, idx(ni as usize, nj as usize), -1.0);
                }
            }
        }
    }
    coo.to_csr()
}

/// Uniform random pattern with exactly `deg` distinct columns per row.
pub fn random_uniform(n: usize, deg: usize, rng: &mut Pcg32) -> Csr {
    let deg = deg.min(n);
    let mut coo = Coo::with_capacity(n, n, n * deg);
    for r in 0..n {
        for c in rng.sample_distinct(n, deg) {
            coo.push(r, c, val(rng));
        }
    }
    coo.to_csr()
}

/// Power-law row degrees (zipf over rows) with uniform columns — the
/// social-network family.
pub fn power_law(n: usize, avg_deg: f64, alpha: f64, rng: &mut Pcg32) -> Csr {
    let total = (n as f64 * avg_deg) as usize;
    let mut coo = Coo::with_capacity(n, n, total);
    // Hub rows get zipf-rank-proportional degree; assign by sampling
    // a row via zipf then a uniform column.
    let mut row_of_rank: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut row_of_rank);
    for _ in 0..total {
        let r = row_of_rank[rng.gen_zipf(n, alpha)];
        let c = rng.gen_range(n);
        coo.push(r, c, val(rng));
    }
    coo.to_csr()
}

/// A contiguous block of dense rows holds ~`frac` of all nonzeros —
/// the exdata_1 pathology. The block sits in the second quarter of the
/// rows so a 4-thread static row partition lands it on thread 2,
/// matching the paper's "the second thread will consume more than 99%
/// of the nonzeros".
pub fn dense_row_block(n: usize, target_nnz: usize, rng: &mut Pcg32) -> Csr {
    let frac = 0.99;
    let dense_nnz = (target_nnz as f64 * frac) as usize;
    let sparse_nnz = target_nnz - dense_nnz;
    // Concentrate the dense nonzeros in ~n/16 rows so nnz_max dwarfs
    // nnz_avg (exdata_1: a block of very wide rows).
    let width = (dense_nnz / (n / 16).max(1)).clamp(1, n);
    let dense_rows = (dense_nnz / width).max(1);
    let start = n / 4; // second quarter
    let mut coo = Coo::with_capacity(n, n, target_nnz);
    for i in 0..dense_rows {
        let r = (start + i).min(n - 1);
        for c in rng.sample_distinct(n, width) {
            coo.push(r, c, val(rng));
        }
    }
    // Background: diagonal + sprinkle.
    for r in 0..n {
        coo.push(r, r, val(rng));
    }
    for _ in 0..sparse_nnz.saturating_sub(n) {
        coo.push(rng.gen_range(n), rng.gen_range(n), val(rng));
    }
    coo.to_csr()
}

/// Every row has exactly `deg` nonzeros spread over the whole column
/// space (QCD-lattice-like: perfectly balanced but each row's gather
/// spans far across x, stressing the shared L2).
pub fn regular_wide(n: usize, deg: usize, rng: &mut Pcg32) -> Csr {
    let deg = deg.min(n);
    let mut coo = Coo::with_capacity(n, n, n * deg);
    let stride = (n / deg.max(1)).max(1);
    for r in 0..n {
        // Evenly-strided columns with a random phase: fixed degree,
        // zero row variance, whole-x span.
        let phase = rng.gen_range(stride);
        for j in 0..deg {
            let c = (phase + j * stride + r / 64) % n;
            coo.push(r, c, val(rng));
        }
    }
    let csr = coo.to_csr();
    // Strided construction can collide columns (dedup merges them);
    // top up rows that lost entries to keep variance ~0.
    top_up_rows(csr, deg, rng)
}

fn top_up_rows(csr: Csr, deg: usize, rng: &mut Pcg32) -> Csr {
    let n = csr.n_rows;
    let mut coo = Coo::with_capacity(n, n, n * deg);
    for r in 0..n {
        let (cols, vals) = csr.row(r);
        let mut have: Vec<u32> = cols.to_vec();
        for (c, v) in cols.iter().zip(vals) {
            coo.push(r, *c as usize, *v);
        }
        let mut guard = 0;
        while have.len() < deg && guard < deg * 20 {
            let c = rng.gen_range(n) as u32;
            if !have.contains(&c) {
                have.push(c);
                coo.push(r, c as usize, val(rng));
            }
            guard += 1;
        }
    }
    coo.to_csr()
}

/// Road-network-like: a 1-D chain plus sparse shortcut edges; average
/// degree ~2.5, excellent x-locality (the asia_osm behaviour: private
/// L2 barely helps because the shared L2 already suffices).
pub fn road_network(n: usize, rng: &mut Pcg32) -> Csr {
    let mut coo = Coo::with_capacity(n, n, n * 3);
    for r in 0..n {
        if r + 1 < n {
            coo.push(r, r + 1, val(rng));
            coo.push(r + 1, r, val(rng));
        }
        // A small fraction of nodes get a shortcut edge. Geographic
        // node ordering (how SuiteSparse road networks are stored)
        // keeps almost all edges near-diagonal, so x access is
        // overwhelmingly prefetchable.
        if rng.gen_f64() < 0.08 {
            let off = 2 + rng.gen_range(1022);
            let c = (r + off) % n;
            coo.push(r, c, val(rng));
        }
    }
    coo.to_csr()
}

/// Fig 9's synthesized poor-locality matrix: rows have identical
/// degree, but consecutive rows draw their columns from clusters far
/// apart, so the sequential row order reuses x as badly as possible.
/// `clusters` controls how many distant column groups interleave.
pub fn poor_locality(
    n: usize,
    deg: usize,
    clusters: usize,
    rng: &mut Pcg32,
) -> Csr {
    let clusters = clusters.clamp(1, n.max(1));
    let cluster_w = (n / clusters).max(deg.max(1));
    let mut coo = Coo::with_capacity(n, n, n * deg);
    for r in 0..n {
        // Row r uses cluster (r mod clusters): adjacent rows touch
        // maximally distant x regions. Within a row the nonzeros are
        // contiguous (Fig 9's block structure): the pathology is the
        // lack of cross-row reuse, not within-row scatter.
        let cl = r % clusters;
        let base = (cl * cluster_w) % n;
        let off = rng.gen_range(cluster_w.saturating_sub(deg).max(1));
        for j in 0..deg {
            let c = (base + off + j) % n;
            coo.push(r, c, val(rng));
        }
    }
    coo.to_csr()
}

/// The locality-friendly counterpart of [`poor_locality`] — what the
/// ideal reordering of Fig 9 (right) produces. Used as ground truth in
/// reorder tests.
pub fn good_locality(
    n: usize,
    deg: usize,
    clusters: usize,
    rng: &mut Pcg32,
) -> Csr {
    let csr = poor_locality(n, deg, clusters, rng);
    // Sort rows by cluster id == stable sort by (r % clusters).
    let clusters = clusters.clamp(1, n.max(1));
    let mut perm: Vec<usize> = (0..n).collect();
    perm.sort_by_key(|&r| r % clusters);
    csr.permute_rows(&perm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::MatrixFeatures;

    fn rng() -> Pcg32 {
        Pcg32::new(0xC0FFEE)
    }

    #[test]
    fn all_classes_generate_valid() {
        for class in MatrixClass::ALL {
            let csr = class.generate(512, 4096, 42);
            assert!(csr.validate().is_ok(), "{class:?}");
            assert!(csr.nnz() > 0, "{class:?} generated empty matrix");
            assert!(csr.n_rows > 0);
        }
    }

    #[test]
    fn deterministic_by_seed() {
        for class in MatrixClass::ALL {
            let a = class.generate(256, 2048, 7);
            let b = class.generate(256, 2048, 7);
            assert_eq!(a, b, "{class:?} not deterministic");
        }
    }

    #[test]
    fn different_seeds_differ() {
        let a = MatrixClass::RandomUniform.generate(256, 2048, 1);
        let b = MatrixClass::RandomUniform.generate(256, 2048, 2);
        assert_ne!(a, b);
    }

    #[test]
    fn stencil5_regular() {
        let csr = stencil(1024, 5);
        let f = MatrixFeatures::extract(&csr);
        // Interior rows have 5 nonzeros; borders fewer.
        assert_eq!(f.nnz_max, 5);
        assert!(f.nnz_var < 1.0);
    }

    #[test]
    fn regular_wide_zero_variance() {
        let csr = regular_wide(512, 16, &mut rng());
        let f = MatrixFeatures::extract(&csr);
        assert!(
            f.nnz_var < 0.5,
            "regular_wide should have ~0 row variance, got {}",
            f.nnz_var
        );
        assert!((f.nnz_avg - 16.0).abs() < 1.0);
    }

    #[test]
    fn dense_row_block_is_skewed() {
        let csr = dense_row_block(1024, 40_000, &mut rng());
        let f = MatrixFeatures::extract(&csr);
        // Nearly all nonzeros in few rows -> huge max, small avg.
        assert!(f.nnz_max as f64 > 10.0 * f.nnz_avg);
        // And they sit in the second quarter of rows.
        let q = csr.n_rows / 4;
        let block_nnz: usize =
            (q..2 * q).map(|r| csr.row_nnz(r)).sum();
        assert!(block_nnz as f64 > 0.8 * csr.nnz() as f64);
    }

    #[test]
    fn power_law_skewed_rows() {
        let csr = power_law(2048, 8.0, 1.6, &mut rng());
        let f = MatrixFeatures::extract(&csr);
        assert!(f.nnz_var > f.nnz_avg, "power law should be overdispersed");
    }

    #[test]
    fn road_network_low_degree() {
        let csr = road_network(4096, &mut rng());
        let f = MatrixFeatures::extract(&csr);
        assert!(f.nnz_avg < 3.0, "asia_osm-like degree, got {}", f.nnz_avg);
    }

    #[test]
    fn poor_locality_balanced_but_scattered() {
        let csr = poor_locality(1024, 4, 64, &mut rng());
        let f = MatrixFeatures::extract(&csr);
        assert!(f.nnz_var < 2.0, "rows balanced");
        // Adjacent rows should overlap in columns rarely.
        let mut overlaps = 0usize;
        for r in 0..csr.n_rows - 1 {
            let (a, _) = csr.row(r);
            let (b, _) = csr.row(r + 1);
            if a.iter().any(|c| b.contains(c)) {
                overlaps += 1;
            }
        }
        assert!(
            (overlaps as f64) < 0.05 * csr.n_rows as f64,
            "adjacent rows share columns too often: {overlaps}"
        );
    }

    #[test]
    fn good_locality_is_row_permutation() {
        let mut r1 = rng();
        let mut r2 = rng();
        let bad = poor_locality(256, 4, 16, &mut r1);
        let good = good_locality(256, 4, 16, &mut r2);
        assert_eq!(bad.nnz(), good.nnz());
        // Same multiset of row degree values.
        let mut d1: Vec<usize> = (0..256).map(|r| bad.row_nnz(r)).collect();
        let mut d2: Vec<usize> = (0..256).map(|r| good.row_nnz(r)).collect();
        d1.sort_unstable();
        d2.sort_unstable();
        assert_eq!(d1, d2);
    }

    #[test]
    fn banded_degree_matches() {
        let csr = banded(512, 9, &mut rng());
        let f = MatrixFeatures::extract(&csr);
        assert!((f.nnz_avg - 9.0).abs() < 0.5);
    }
}
