//! Replicas of the paper's six case-study matrices, generated from
//! their published structure, scaled so the L2-resident/memory-bound
//! boundary relative to the simulated 2 MB shared L2 matches the
//! original (DESIGN.md §Substitutions).

use crate::sparse::Csr;
use crate::util::rng::Pcg32;

use super::generators;

/// The case-study matrices of Fig 2, Table 4, Fig 7, Fig 8.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NamedMatrix {
    /// 3-D trabecular bone FEM (Fig 2 motivation): large, ~48 nnz/row,
    /// banded — memory-bandwidth bound at scale.
    Bone010,
    /// Table 4 row 1: one dense row block holds >99% of nonzeros;
    /// job_var 0.992, speedup 1.018x.
    Exdata1,
    /// Table 4 row 2: QCD lattice, exactly 39 nnz/row, nnz_var 0,
    /// whole-x gather span; speedup 1.351x shared-L2 / 3.61x private.
    Conf5_4_8x8_20,
    /// Table 4 row 3: balanced 4 nnz/row with tight locality;
    /// speedup 2.241x (positive L2 sharing).
    Debr,
    /// Table 4 row 4: random pattern, nnz_var 36.5; speedup 1.479x.
    Appu,
    /// §5.2.2: road network, nnz_avg < 3; private L2 gains only 2.6%.
    AsiaOsm,
}

impl NamedMatrix {
    pub const ALL: [NamedMatrix; 6] = [
        NamedMatrix::Bone010,
        NamedMatrix::Exdata1,
        NamedMatrix::Conf5_4_8x8_20,
        NamedMatrix::Debr,
        NamedMatrix::Appu,
        NamedMatrix::AsiaOsm,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            NamedMatrix::Bone010 => "bone010",
            NamedMatrix::Exdata1 => "exdata_1",
            NamedMatrix::Conf5_4_8x8_20 => "conf5_4-8x8-20",
            NamedMatrix::Debr => "debr",
            NamedMatrix::Appu => "appu",
            NamedMatrix::AsiaOsm => "asia_osm",
        }
    }

    /// Generate the scaled replica. Deterministic per matrix.
    pub fn generate(&self) -> Csr {
        let mut rng = Pcg32::new(0xBADC0DE ^ (*self as u64) << 8);
        match self {
            // bone010: 986,703 rows, 47.8M nnz, ~48/row, FEM band.
            // Scaled: 32k rows, 48/row -> ~1.5M nnz, ~19 MB working
            // set: firmly memory-bound vs the 2 MB L2 (as the original
            // 600 MB is vs the real 2 MB L2).
            NamedMatrix::Bone010 => {
                generators::banded(32_768, 48, &mut rng)
            }
            // exdata_1: 6,001 rows, 2.27M nnz, one dense block.
            // Scaled: 6,016 rows, ~280k nnz, >99% in the second
            // quarter of rows (thread 2 of 4).
            NamedMatrix::Exdata1 => {
                generators::dense_row_block(6_016, 280_000, &mut rng)
            }
            // conf5_4-8x8-20: kept at its REAL size (49,152 rows,
            // 39/row -> 1.9M nnz). The shared-L2 pathology the paper
            // analyzes depends on the absolute ratio of the x reuse
            // distance to the 2 MB L2 (x = 384 KB; the per-thread
            // gather window x4 threads overflows the L2 at 4 threads
            // but not at 1) — scaling n down would erase it.
            NamedMatrix::Conf5_4_8x8_20 => {
                generators::regular_wide(49_152, 39, &mut rng)
            }
            // debr: 1,048,576 rows, 4.2M nnz, 4/row, tight band.
            // Scaled: 65,536 rows, 4/row (~3.7 MB: x fits in L2 when
            // shared, per-thread slices fit when split).
            NamedMatrix::Debr => generators::banded(65_536, 4, &mut rng),
            // appu: kept at its REAL size (14,336 rows, ~130/row ->
            // 1.86M nnz, random graph). Like conf5, its behaviour is
            // governed by the x(112 KB)-vs-L1(32 KB) gather ratio —
            // scaling n down would let x sit in L1 and erase the
            // shared-L2 probe pressure.
            NamedMatrix::Appu => {
                let base =
                    generators::random_uniform(14_336, 130, &mut rng);
                perturb_degrees(base, 6.0, &mut rng)
            }
            // asia_osm: 12M rows, 2.1 nnz/row road network.
            // Scaled: 65,536 rows, same degree structure.
            NamedMatrix::AsiaOsm => {
                generators::road_network(65_536, &mut rng)
            }
        }
    }
}

/// Add row-degree jitter (appu's nnz_var ≈ 36.5 is nonzero unlike the
/// QCD lattice): randomly add extra entries to ~half the rows.
fn perturb_degrees(csr: Csr, sd: f64, rng: &mut Pcg32) -> Csr {
    use crate::sparse::Coo;
    let n = csr.n_rows;
    let mut coo = Coo::with_capacity(n, n, csr.nnz() + n * 4);
    for r in 0..n {
        let (cols, vals) = csr.row(r);
        for (c, v) in cols.iter().zip(vals) {
            coo.push(r, *c as usize, *v);
        }
        let extra = (rng.gen_normal().abs() * sd) as usize;
        for _ in 0..extra {
            coo.push(r, rng.gen_range(n), 0.1 + rng.gen_f64());
        }
    }
    coo.to_csr()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::MatrixFeatures;
    use crate::sparse::features::job_var;

    #[test]
    fn all_named_generate_valid() {
        for m in NamedMatrix::ALL {
            let csr = m.generate();
            assert!(csr.validate().is_ok(), "{}", m.name());
            assert!(csr.nnz() > 1000, "{}", m.name());
        }
    }

    #[test]
    fn exdata1_job_var_matches_paper() {
        // Paper Table 4: job_var = 0.992 under a 4-thread static row
        // partition.
        let csr = NamedMatrix::Exdata1.generate();
        let n = csr.n_rows;
        let per: Vec<usize> = (0..4)
            .map(|t| {
                let r0 = n * t / 4;
                let r1 = n * (t + 1) / 4;
                (r0..r1).map(|r| csr.row_nnz(r)).sum()
            })
            .collect();
        let jv = job_var(&per);
        assert!(jv > 0.95, "exdata_1 replica job_var = {jv}, want ~0.99");
        // And the heavy thread is thread 2 (index 1), as in the paper.
        let imax = per
            .iter()
            .enumerate()
            .max_by_key(|(_, &v)| v)
            .unwrap()
            .0;
        assert_eq!(imax, 1, "dense block should land on thread 2");
    }

    #[test]
    fn conf5_regular() {
        let csr = NamedMatrix::Conf5_4_8x8_20.generate();
        let f = MatrixFeatures::extract(&csr);
        assert!((f.nnz_avg - 39.0).abs() < 1.0, "nnz_avg={}", f.nnz_avg);
        assert!(f.nnz_var < 1.0, "nnz_var={}", f.nnz_var);
    }

    #[test]
    fn debr_low_variance_low_degree() {
        let csr = NamedMatrix::Debr.generate();
        let f = MatrixFeatures::extract(&csr);
        assert!((f.nnz_avg - 4.0).abs() < 0.5);
        assert!(f.nnz_var < 1.0);
    }

    #[test]
    fn appu_has_variance() {
        let csr = NamedMatrix::Appu.generate();
        let f = MatrixFeatures::extract(&csr);
        assert!(f.nnz_avg > 100.0);
        assert!(f.nnz_var > 5.0, "appu needs row jitter: {}", f.nnz_var);
    }

    #[test]
    fn asia_osm_tiny_degree() {
        let csr = NamedMatrix::AsiaOsm.generate();
        let f = MatrixFeatures::extract(&csr);
        assert!(f.nnz_avg < 3.0);
    }

    #[test]
    fn bone010_memory_bound_size() {
        let csr = NamedMatrix::Bone010.generate();
        // Working set must dwarf the 2 MB shared L2.
        assert!(csr.working_set_bytes() > 8 * (1 << 20));
    }
}
