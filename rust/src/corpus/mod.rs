//! Synthetic matrix corpus — the substitute for the paper's 1008
//! SuiteSparse matrices (DESIGN.md §Substitutions).
//!
//! The paper's dataset spans "regular and irregular matrices, covering
//! domains from scientific computing to social networks". Each
//! [`MatrixClass`] here generates one of those structural families
//! deterministically from a seed; [`suite`] assembles the full
//! 1008-matrix sweep, and [`named`] replicates the six case-study
//! matrices (bone010, exdata_1, conf5_4-8x8-20, debr, appu, asia_osm)
//! from their published structure.

pub mod generators;
pub mod named;
pub mod suite;

pub use generators::MatrixClass;
pub use named::NamedMatrix;
pub use suite::{SuiteSpec, SuiteEntry};

use crate::sparse::Csr;

/// A corpus entry: a generated matrix plus its provenance.
#[derive(Clone, Debug)]
pub struct CorpusMatrix {
    pub name: String,
    pub class: MatrixClass,
    pub seed: u64,
    pub csr: Csr,
}
