//! PAPI-style hardware event counters and the derived features of the
//! paper's Table 3.
//!
//! The raw set matches what the paper collects through PAPI on
//! FT-2000+ (L2_DCM, L2_DCA, L1_DCM, L1_DCA, FR_INS, TOT_INS, TOT_CYC);
//! the derived set adds L1_DCMR, L2_DCMR, IPC, and the two customized
//! features `L2_DCMR_change` and `job_var`.

/// Raw per-thread counters (Table 3, "raw hardware counters").
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Counters {
    /// L1 data cache accesses.
    pub l1_dca: u64,
    /// L1 data cache misses.
    pub l1_dcm: u64,
    /// L2 data cache accesses (== L1 misses in this hierarchy).
    pub l2_dca: u64,
    /// L2 data cache misses.
    pub l2_dcm: u64,
    /// Floating point instructions executed.
    pub fr_ins: u64,
    /// Total instructions executed.
    pub tot_ins: u64,
    /// Total cycles (filled by the timing model).
    pub tot_cyc: u64,
}

impl Counters {
    pub fn l1_dcmr(&self) -> f64 {
        ratio(self.l1_dcm, self.l1_dca)
    }

    pub fn l2_dcmr(&self) -> f64 {
        ratio(self.l2_dcm, self.l2_dca)
    }

    pub fn ipc(&self) -> f64 {
        if self.tot_cyc == 0 {
            0.0
        } else {
            self.tot_ins as f64 / self.tot_cyc as f64
        }
    }

    pub fn add(&mut self, other: &Counters) {
        self.l1_dca += other.l1_dca;
        self.l1_dcm += other.l1_dcm;
        self.l2_dca += other.l2_dca;
        self.l2_dcm += other.l2_dcm;
        self.fr_ins += other.fr_ins;
        self.tot_ins += other.tot_ins;
        self.tot_cyc = self.tot_cyc.max(other.tot_cyc);
    }
}

fn ratio(num: u64, den: u64) -> f64 {
    if den == 0 {
        0.0
    } else {
        num as f64 / den as f64
    }
}

/// Derived features for one (matrix, schedule) pair, combining the
/// 1-thread and 4-thread profiles the way §4.2.1 describes:
/// `l2_dcmr_change` uses the *slowest* thread's L2_DCMR at 4 threads
/// minus the single-thread L2_DCMR.
#[derive(Clone, Copy, Debug, Default)]
pub struct Derived {
    pub l1_dcmr_1t: f64,
    pub l2_dcmr_1t: f64,
    pub ipc_1t: f64,
    pub l1_dcmr_mt: f64,
    /// L2 miss rate of the slowest thread in the multi-thread run.
    pub l2_dcmr_mt_slowest: f64,
    pub ipc_mt: f64,
    /// `L2_DCMR_change` (Table 3).
    pub l2_dcmr_change: f64,
    /// `job_var` (Table 3): max per-thread nnz share.
    pub job_var: f64,
    /// Shared-L2 probe intensity: L2_DCA / TOT_INS of the single-thread
    /// run. High values (gather-heavy kernels whose x overflows the
    /// L1) mark the matrices that queue on the shared L2 — the conf5 /
    /// appu signature.
    pub l2_probe_rate_1t: f64,
}

impl Derived {
    /// Combine profiles. `single` is the 1-thread counter set;
    /// `multi` the per-thread counters of the n-thread run;
    /// `thread_nnz` the nonzero allocation behind `job_var`.
    pub fn from_profiles(
        single: &Counters,
        multi: &[Counters],
        thread_nnz: &[usize],
    ) -> Derived {
        assert!(!multi.is_empty());
        let slowest = multi
            .iter()
            .max_by_key(|c| c.tot_cyc)
            .expect("non-empty");
        let mut agg = Counters::default();
        for c in multi {
            agg.add(c);
        }
        Derived {
            l1_dcmr_1t: single.l1_dcmr(),
            l2_dcmr_1t: single.l2_dcmr(),
            ipc_1t: single.ipc(),
            l1_dcmr_mt: agg.l1_dcmr(),
            l2_dcmr_mt_slowest: slowest.l2_dcmr(),
            ipc_mt: agg.ipc(),
            l2_dcmr_change: slowest.l2_dcmr() - single.l2_dcmr(),
            job_var: crate::sparse::features::job_var(thread_nnz),
            l2_probe_rate_1t: if single.tot_ins == 0 {
                0.0
            } else {
                single.l2_dca as f64 / single.tot_ins as f64
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn c(l1a: u64, l1m: u64, l2m: u64, ins: u64, cyc: u64) -> Counters {
        Counters {
            l1_dca: l1a,
            l1_dcm: l1m,
            l2_dca: l1m,
            l2_dcm: l2m,
            fr_ins: ins / 2,
            tot_ins: ins,
            tot_cyc: cyc,
        }
    }

    #[test]
    fn rates() {
        let x = c(1000, 100, 50, 5000, 2500);
        assert!((x.l1_dcmr() - 0.1).abs() < 1e-12);
        assert!((x.l2_dcmr() - 0.5).abs() < 1e-12);
        assert!((x.ipc() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn zero_denominators() {
        let z = Counters::default();
        assert_eq!(z.l1_dcmr(), 0.0);
        assert_eq!(z.l2_dcmr(), 0.0);
        assert_eq!(z.ipc(), 0.0);
    }

    #[test]
    fn derived_uses_slowest_thread() {
        let single = c(1000, 100, 20, 4000, 2000);
        // Thread 1 is slowest (more cycles) and has higher L2 DCMR.
        let multi = vec![
            c(500, 50, 5, 2000, 1000),
            c(500, 50, 40, 2000, 9000),
        ];
        let d = Derived::from_profiles(&single, &multi, &[500, 500]);
        assert!((d.l2_dcmr_mt_slowest - 0.8).abs() < 1e-12);
        assert!((d.l2_dcmr_change - (0.8 - 0.2)).abs() < 1e-12);
        assert!((d.job_var - 0.5).abs() < 1e-12);
    }

    #[test]
    fn add_merges() {
        let mut a = c(10, 5, 2, 100, 50);
        a.add(&c(20, 5, 4, 100, 80));
        assert_eq!(a.l1_dca, 30);
        assert_eq!(a.l1_dcm, 10);
        assert_eq!(a.tot_ins, 200);
        assert_eq!(a.tot_cyc, 80); // max, not sum
    }
}
