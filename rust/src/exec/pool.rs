//! Persistent panel-pinned executor pool.
//!
//! The paper's point about parallel-runtime overheads ("the overhead
//! of thread communication ... is nonnegligible") cuts both ways: the
//! serving hot path used to pay it on *every* request by spawning
//! fresh OS threads through `std::thread::scope`. For the
//! small/medium matrices a request-serving engine mostly sees, the
//! spawn+join tax rivals the kernel itself. An [`ExecPool`] pays it
//! once: workers are created at pool construction, (modeled) pinned
//! to a panel's core range, and reused across requests via a
//! Condvar-latch handoff — a dispatch is one lock, one wake, one
//! join-latch wait, no thread creation.
//!
//! Work items are *slots* (partition indices). A job publishes a
//! slot-indexed closure plus a slot count; the dispatching thread and
//! every resident worker pull slot indices under the pool mutex until
//! none remain, so a pool narrower than the partition still covers
//! every slot, and a partition narrower than the pool leaves the
//! excess workers parked. The dispatcher participates in the work and
//! only returns once every slot has completed, which is what makes
//! handing non-`'static` borrows to the resident workers sound (the
//! same contract as `std::thread::scope`, without the spawn).
//!
//! Concurrent dispatches from different threads (e.g. two queue
//! workers sharing one shard's pool) serialize on an internal lock:
//! one panel's cores can only run one kernel at a time anyway, and
//! serializing keeps the job slot single-owner.

use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::obs::{Stage, TraceRecorder};
use crate::util::ordatomic::OrdAtomicU64;

/// Type-erased, lifetime-erased slot closure. Only ever dereferenced
/// while the dispatching `run` call is blocked on the job's
/// completion latch, which keeps the borrow alive.
#[derive(Clone, Copy)]
struct RawWork(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (shared calls from many threads are
// fine) and `run` guarantees it outlives every use (see module docs).
unsafe impl Send for RawWork {}

/// One published job: a slot closure, how many slots it has, and the
/// claim/completion cursors of the latch.
struct Job {
    work: RawWork,
    n_slots: usize,
    /// Next unclaimed slot index.
    next: usize,
    /// Slots whose closure has returned (or unwound).
    completed: usize,
    /// A slot closure panicked; `run` re-raises after the latch.
    panicked: bool,
}

struct State {
    /// Bumped once per published job so parked workers can tell a new
    /// job from the one they already drained.
    epoch: u64,
    job: Option<Job>,
    shutdown: bool,
    /// Fault-injection stall mask: bit `lane` set means worker lane
    /// `lane` (1-based, the tally index) must not claim new slots. An
    /// in-flight slot always finishes — the mask gates *claims*, so a
    /// stalled lane parks and the dispatcher plus the healthy lanes
    /// cover the job (a straggler degrades the dispatch, it never
    /// wedges it). Lanes >= 64 are never maskable.
    stalled: u64,
    /// When set, the dispatcher's completion-latch wait wakes every
    /// `latch_timeout` to count the overdue join instead of blocking
    /// forever. It must keep waiting — abandoning claimed slots would
    /// free the borrowed closure under a running worker — but the
    /// counted timeout is the health signal degradation policies key
    /// off.
    latch_timeout: Option<Duration>,
    /// Latch waits that exceeded `latch_timeout` (monotone).
    latch_timeouts: u64,
    /// Jobs published while at least one lane was stalled (monotone).
    degraded_dispatches: u64,
}

/// Per-lane busy accounting (lane 0 = the dispatching thread, lane
/// `i + 1` = resident worker `i`). Cheap enough to keep always-on:
/// two relaxed atomic adds per executed slot.
struct WorkerTally {
    /// Slots this lane has executed.
    slots: OrdAtomicU64,
    /// Total time this lane spent inside slot closures, ns.
    busy_ns: OrdAtomicU64,
}

impl WorkerTally {
    fn new() -> WorkerTally {
        WorkerTally {
            slots: OrdAtomicU64::named(0, "pool.tally.slots"),
            busy_ns: OrdAtomicU64::named(0, "pool.tally.busy_ns"),
        }
    }
}

struct Shared {
    state: Mutex<State>,
    /// Workers park here between jobs.
    work_cv: Condvar,
    /// The dispatcher parks here until `completed == n_slots`.
    done_cv: Condvar,
    /// One tally per lane: `[dispatcher, worker 0, worker 1, ...]`.
    tallies: Box<[WorkerTally]>,
    /// Optional span recorder (set once when tracing is enabled);
    /// absent, the hot path pays a single relaxed load.
    trace: OnceLock<Arc<TraceRecorder>>,
}

impl Shared {
    /// Lock the pool state, recovering from poisoning. The guarded
    /// sections are pure field updates that cannot themselves panic;
    /// recovery is defense in depth so an unforeseen poisoning (e.g.
    /// a panicking panic-hook) degrades gracefully instead of
    /// cascading `unwrap` failures through every worker.
    fn lock(&self) -> std::sync::MutexGuard<'_, State> {
        self.state
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Claim the next unclaimed slot of the current job, if any.
    fn claim(st: &mut State) -> Option<(RawWork, usize)> {
        let job = st.job.as_mut()?;
        if job.next >= job.n_slots {
            return None;
        }
        let slot = job.next;
        job.next += 1;
        Some((job.work, slot))
    }

    /// Record one executed slot against `lane`: busy tally always,
    /// a per-worker kernel span when a recorder is attached.
    fn note_done(&self, lane: usize, elapsed: Duration) {
        let tally = &self.tallies[lane.min(self.tallies.len() - 1)];
        // ord: Relaxed RMW — monotone per-lane counters; readers only
        // snapshot (telemetry), and the latch orders end-of-job reads.
        tally.slots.fetch_add(1, Ordering::Relaxed);
        // ord: Relaxed RMW — same contract as `slots` above.
        tally
            .busy_ns
            .fetch_add(elapsed.as_nanos() as u64, Ordering::Relaxed);
        if let Some(rec) = self.trace.get() {
            rec.record_elapsed(
                lane,
                Stage::Kernel,
                rec.kernel_ctx(),
                elapsed.as_secs_f64() * 1e6,
            );
        }
    }

    /// Run one claimed slot outside the lock, then record completion.
    fn complete(&self, lane: usize, raw: RawWork, slot: usize) {
        // SAFETY: `run` holds the dispatch lock and blocks on the
        // completion latch until this increment lands, so the
        // borrowed closure is still alive here.
        let work = unsafe { &*raw.0 };
        let t0 = Instant::now();
        let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || work(slot),
        ))
        .is_ok();
        self.note_done(lane, t0.elapsed());
        let mut st = self.lock();
        if let Some(job) = st.job.as_mut() {
            job.completed += 1;
            if !ok {
                job.panicked = true;
            }
            if job.completed == job.n_slots {
                self.done_cv.notify_all();
            }
        }
    }
}

/// A persistent worker pool for the threaded SpMV/SpMM executors.
///
/// Construction spawns the workers once; [`ExecPool::run`] reuses
/// them for every subsequent dispatch. Dropping the pool shuts the
/// workers down and joins them.
pub struct ExecPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    /// Serializes dispatches: the job slot is single-owner.
    dispatch: Mutex<()>,
    /// Modeled core range `[c0, c1)` the workers are pinned to (the
    /// same modeling convention as `service::shard` — std exposes no
    /// affinity API; what matters is the sizing and the disjointness
    /// across pools).
    cores: Option<(usize, usize)>,
    jobs: OrdAtomicU64,
    /// Construction time, the denominator of busy-share gauges.
    started: Instant,
}

impl ExecPool {
    /// Pool with `n_workers` resident workers, unpinned.
    pub fn new(n_workers: usize) -> Self {
        Self::build(n_workers.max(1), None)
    }

    /// Pool whose workers are (modeled) pinned to the core range
    /// `[c0, c1)` — one worker per core, the per-shard sizing rule
    /// (`sched::panel_core_range` hands each shard its panel block).
    pub fn pinned(cores: (usize, usize)) -> Self {
        let width = cores.1.saturating_sub(cores.0).max(1);
        Self::build(width, Some(cores))
    }

    fn build(n_workers: usize, cores: Option<(usize, usize)>) -> Self {
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                epoch: 0,
                job: None,
                shutdown: false,
                stalled: 0,
                latch_timeout: None,
                latch_timeouts: 0,
                degraded_dispatches: 0,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            tallies: (0..n_workers + 1).map(|_| WorkerTally::new()).collect(),
            trace: OnceLock::new(),
        });
        let handles = (0..n_workers)
            .map(|i| {
                let shared = shared.clone();
                std::thread::spawn(move || worker_loop(i + 1, &shared))
            })
            .collect();
        ExecPool {
            shared,
            handles,
            dispatch: Mutex::new(()),
            cores,
            jobs: OrdAtomicU64::named(0, "pool.jobs"),
            started: Instant::now(),
        }
    }

    /// Number of resident workers. Constant for the pool's lifetime —
    /// the reuse stress test pins this.
    pub fn n_workers(&self) -> usize {
        self.handles.len()
    }

    /// The modeled core range the workers are pinned to, if any.
    pub fn cores(&self) -> Option<(usize, usize)> {
        self.cores
    }

    /// Jobs dispatched so far (monotone; telemetry/tests).
    pub fn jobs_dispatched(&self) -> u64 {
        // ord: Relaxed load — monotone counter snapshot; exactness at
        // a moment in time is not part of the contract.
        self.jobs.load(Ordering::Relaxed)
    }

    /// Attach a span recorder: subsequent slot executions also emit
    /// per-lane kernel spans. First caller wins (set-once).
    pub fn set_trace(&self, rec: Arc<TraceRecorder>) {
        let _ = self.shared.trace.set(rec);
    }

    /// Per-lane `(slots_executed, busy_seconds)` tallies. Index 0 is
    /// the dispatching thread, index `i + 1` resident worker `i`.
    pub fn worker_tallies(&self) -> Vec<(u64, f64)> {
        self.shared
            .tallies
            .iter()
            .map(|t| {
                (
                    // ord: Relaxed loads — monotone counter snapshots
                    // for telemetry; tests read them latch-ordered.
                    t.slots.load(Ordering::Relaxed),
                    t.busy_ns.load(Ordering::Relaxed) as f64 / 1e9,
                )
            })
            .collect()
    }

    /// Write each lane's cumulative busy nanoseconds into the head of
    /// `out`, returning how many lanes were written
    /// (`min(lanes, out.len())`). Alloc-free on purpose — the scaling
    /// profiler snapshots this into a stack buffer around every
    /// dispatch to derive per-batch lane deltas, where
    /// [`ExecPool::worker_tallies`]'s `Vec` would break the zero-alloc
    /// steady-state contract.
    pub fn fill_busy_ns(&self, out: &mut [u64]) -> usize {
        let n = self.shared.tallies.len().min(out.len());
        for (slot, t) in out[..n].iter_mut().zip(self.shared.tallies.iter()) {
            // ord: Relaxed load — monotone tally snapshot; the
            // dispatcher reads its own job's contribution after the
            // latch join, which already orders the workers' adds.
            *slot = t.busy_ns.load(Ordering::Relaxed);
        }
        n
    }

    /// Seconds since the pool was built (busy-share denominator).
    pub fn uptime_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Mark worker lane `lane` (1-based tally index, `1..=n_workers`)
    /// stalled or healthy. A stalled lane stops claiming slots — its
    /// in-flight slot, if any, still completes — so injected
    /// stragglers degrade dispatches to the remaining lanes instead
    /// of wedging the latch. Clearing a stall wakes the pool so a
    /// revived lane can claim pending work. Lanes >= 64 are ignored.
    pub fn set_lane_stalled(&self, lane: usize, stalled: bool) {
        if lane == 0 || lane >= 64 {
            return;
        }
        let bit = 1u64 << lane;
        let mut st = self.shared.lock();
        if stalled {
            st.stalled |= bit;
        } else {
            st.stalled &= !bit;
            drop(st);
            self.shared.work_cv.notify_all();
        }
    }

    /// The current stall mask (bit `lane` = worker lane `lane`).
    pub fn stalled_lanes(&self) -> u64 {
        self.shared.lock().stalled
    }

    /// Bound the dispatcher's completion-latch wait: overdue joins
    /// are counted in [`ExecPool::latch_timeouts`] every `timeout`
    /// instead of blocking silently. `None` restores the unbounded
    /// wait. Soundness note: the latch still waits out every claimed
    /// slot — the timeout is a *counted health signal*, not an
    /// abandonment (the borrowed closure must outlive every worker).
    pub fn set_latch_timeout(&self, timeout: Option<Duration>) {
        self.shared.lock().latch_timeout = timeout;
    }

    /// Completion-latch waits that exceeded the configured timeout.
    pub fn latch_timeouts(&self) -> u64 {
        self.shared.lock().latch_timeouts
    }

    /// Jobs published while at least one lane was stalled — each one
    /// ran degraded on the dispatcher plus the healthy lanes.
    pub fn degraded_dispatches(&self) -> u64 {
        self.shared.lock().degraded_dispatches
    }

    /// Execute `work(slot)` for every `slot in 0..n_slots` across the
    /// resident workers plus the calling thread, returning once every
    /// slot has completed. Slots must be safe to run concurrently
    /// (the executors hand each slot disjoint output rows).
    ///
    /// Panics if any slot closure panicked (after the latch, so the
    /// pool stays consistent and reusable).
    pub fn run(&self, n_slots: usize, work: &(dyn Fn(usize) + Sync)) {
        if n_slots == 0 {
            return;
        }
        let _dispatch = self
            .dispatch
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        // Under hbcheck, model this dispatch's scope semantics for the
        // analyzer: everything the dispatcher did so far happens-before
        // every slot (fork), and every slot happens-before the return
        // (join, below) — exactly what the Condvar latch enforces.
        #[cfg(feature = "hbcheck")]
        crate::util::ordatomic::hb_fork();
        // ord: Relaxed RMW — monotone dispatch counter, snapshot-read.
        self.jobs.fetch_add(1, Ordering::Relaxed);
        if n_slots == 1 {
            // Single-slot fast path: run inline on the dispatcher —
            // no job publication, no worker wakeups. Tiny matrices
            // (the common serving case) pay one lock, zero context
            // switches.
            let t0 = Instant::now();
            work(0);
            self.shared.note_done(0, t0.elapsed());
            #[cfg(feature = "hbcheck")]
            crate::util::ordatomic::hb_join();
            return;
        }
        let raw = erase(work);
        {
            let mut st = self.shared.lock();
            st.epoch += 1;
            if st.stalled != 0 {
                st.degraded_dispatches += 1;
            }
            st.job = Some(Job {
                work: raw,
                n_slots,
                next: 0,
                completed: 0,
                panicked: false,
            });
        }
        // The dispatcher claims one slot itself, so n_slots - 1
        // helpers suffice; waking the whole pool for a narrow job
        // would just stampede the state mutex. A worker that misses a
        // notification (busy finishing the previous job) still finds
        // the new epoch when it re-locks, so targeted wakeups cannot
        // strand work.
        if n_slots - 1 >= self.handles.len() {
            self.shared.work_cv.notify_all();
        } else {
            for _ in 0..n_slots - 1 {
                self.shared.work_cv.notify_one();
            }
        }
        // Participate: claim slots alongside the workers, then wait
        // out the latch. With zero live workers the dispatcher alone
        // still drains every slot — `run` can never deadlock.
        let panicked = loop {
            let mut st = self.shared.lock();
            if let Some((w, slot)) = Shared::claim(&mut st) {
                drop(st);
                self.shared.complete(0, w, slot);
                continue;
            }
            let done = loop {
                // Invariant: `job` is Some from publish until the
                // dispatcher (here) takes it after the completion
                // latch below — no other thread clears it.
                // lint:allow(no-unwrap)
                let job = st.job.as_ref().expect("job owned by dispatcher");
                if job.completed == job.n_slots {
                    break job.panicked;
                }
                let latch_timeout = st.latch_timeout;
                st = match latch_timeout {
                    Some(d) => {
                        let (mut g, wait) = self
                            .shared
                            .done_cv
                            .wait_timeout(st, d)
                            .unwrap_or_else(
                                std::sync::PoisonError::into_inner,
                            );
                        if wait.timed_out() {
                            // Overdue join: count it and keep waiting
                            // — claimed slots borrow the closure, so
                            // the latch may never be abandoned.
                            g.latch_timeouts += 1;
                        }
                        g
                    }
                    None => self
                        .shared
                        .done_cv
                        .wait(st)
                        .unwrap_or_else(std::sync::PoisonError::into_inner),
                };
            };
            st.job = None;
            break done;
        };
        #[cfg(feature = "hbcheck")]
        crate::util::ordatomic::hb_join();
        if panicked {
            panic!("ExecPool: a slot closure panicked during dispatch");
        }
    }
}

impl Drop for ExecPool {
    fn drop(&mut self) {
        self.shared.lock().shutdown = true;
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Erase the borrow lifetime of a slot closure so it can sit in the
/// pool's (`'static`) job slot.
///
/// SAFETY contract (upheld by [`ExecPool::run`]): the caller must not
/// return until every use of the erased pointer has completed — the
/// completion latch is what enforces it, exactly like
/// `std::thread::scope`'s implicit join.
fn erase<'a>(work: &'a (dyn Fn(usize) + Sync + 'a)) -> RawWork {
    let short: *const (dyn Fn(usize) + Sync + 'a) = work;
    // SAFETY: layout-identical fat pointers; only the lifetime bound
    // on the trait object changes.
    let long: *const (dyn Fn(usize) + Sync + 'static) =
        unsafe { std::mem::transmute(short) };
    RawWork(long)
}

fn worker_loop(lane: usize, shared: &Shared) {
    let mut seen_epoch = 0u64;
    let stall_bit = if lane < 64 { 1u64 << lane } else { 0 };
    loop {
        let mut st = shared.lock();
        loop {
            if st.shutdown {
                return;
            }
            if st.stalled & stall_bit == 0
                && st.epoch != seen_epoch
                && st.job.is_some()
            {
                break;
            }
            st = shared
                .work_cv
                .wait(st)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
        }
        seen_epoch = st.epoch;
        // The stall mask gates *claims* only: a lane stalled mid-job
        // finishes its in-flight slot (in `complete`, outside the
        // lock) and simply stops taking more.
        while st.stalled & stall_bit == 0 {
            match Shared::claim(&mut st) {
                Some((w, slot)) => {
                    drop(st);
                    shared.complete(lane, w, slot);
                    st = shared.lock();
                }
                None => break,
            }
        }
        drop(st);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn covers_every_slot_once() {
        let pool = ExecPool::new(4);
        for n_slots in [0usize, 1, 3, 4, 7, 64] {
            let hits: Vec<AtomicUsize> =
                (0..n_slots).map(|_| AtomicUsize::new(0)).collect();
            pool.run(n_slots, &|s| {
                hits[s].fetch_add(1, Ordering::Relaxed);
            });
            for (s, h) in hits.iter().enumerate() {
                assert_eq!(
                    h.load(Ordering::Relaxed),
                    1,
                    "slot {s} of {n_slots}"
                );
            }
        }
        assert_eq!(pool.jobs_dispatched(), 5, "n_slots == 0 is a no-op");
    }

    #[test]
    fn reuses_the_same_workers_across_many_jobs() {
        let pool = ExecPool::new(3);
        assert_eq!(pool.n_workers(), 3);
        // Miri runs threads ~100x slower; a scaled-down job count
        // exercises the same reuse contract.
        let jobs: u64 = if cfg!(miri) { 25 } else { 500 };
        let total = AtomicUsize::new(0);
        for _ in 0..jobs {
            pool.run(5, &|_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed) as u64, 5 * jobs);
        assert_eq!(pool.n_workers(), 3, "worker set must not grow");
        assert_eq!(pool.jobs_dispatched(), jobs);
    }

    #[test]
    fn borrows_local_state_like_scoped_threads() {
        let pool = ExecPool::new(2);
        let mut out = vec![0usize; 16];
        {
            struct SendPtr(*mut usize);
            // SAFETY: slots write disjoint elements of `out`, which
            // outlives the (latched) `run` call.
            unsafe impl Send for SendPtr {}
            // SAFETY: only the raw pointer value is shared; every
            // dereference targets a slot-owned element.
            unsafe impl Sync for SendPtr {}
            let ptr = SendPtr(out.as_mut_ptr());
            pool.run(16, &|s| {
                // SAFETY: each slot writes its own element.
                unsafe { *ptr.0.add(s) = s * s };
            });
        }
        for (s, v) in out.iter().enumerate() {
            assert_eq!(*v, s * s);
        }
    }

    #[test]
    fn concurrent_dispatchers_serialize_safely() {
        let pool = ExecPool::new(2);
        let per_thread = if cfg!(miri) { 5 } else { 50 };
        let total = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..per_thread {
                        pool.run(3, &|_| {
                            total.fetch_add(1, Ordering::Relaxed);
                        });
                    }
                });
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 4 * per_thread * 3);
    }

    #[test]
    fn pinned_pool_sizes_from_core_range() {
        let pool = ExecPool::pinned((8, 16));
        assert_eq!(pool.n_workers(), 8);
        assert_eq!(pool.cores(), Some((8, 16)));
        let hits = AtomicUsize::new(0);
        pool.run(4, &|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn tallies_and_trace_spans_cover_executed_slots() {
        use crate::obs::{ClockMode, TraceConfig};
        let pool = ExecPool::new(2);
        let rec = Arc::new(TraceRecorder::new(
            TraceConfig::on(),
            ClockMode::Wall,
            pool.n_workers() + 1,
        ));
        pool.set_trace(rec.clone());
        rec.set_kernel_ctx(3);
        let jobs: u64 = if cfg!(miri) { 4 } else { 20 };
        pool.run(1, &|_| {});
        for _ in 0..jobs {
            pool.run(6, &|_| std::thread::yield_now());
        }
        let want = 1 + jobs * 6;
        let tallies = pool.worker_tallies();
        assert_eq!(tallies.len(), 3, "dispatcher lane + 2 worker lanes");
        let slots: u64 = tallies.iter().map(|(s, _)| s).sum();
        assert_eq!(slots, want, "every executed slot is tallied");
        assert!(
            tallies[0].0 >= 1,
            "the single-slot fast path runs on the dispatcher lane"
        );
        assert!(pool.uptime_s() >= 0.0);
        // sample = 1: every executed slot also produced a kernel span,
        // attributed to the schedule context set before dispatch.
        assert_eq!(rec.spans_recorded() as u64, want);
        let cells = rec.flame_cells();
        assert_eq!(cells[&(Stage::Kernel.index(), 3)].0 as u64, want);
    }

    #[test]
    fn panicking_slot_does_not_wedge_the_pool() {
        let pool = ExecPool::new(2);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(4, &|s| {
                if s == 2 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "slot panic must propagate to the dispatcher");
        // The pool is still serviceable afterwards.
        let hits = AtomicUsize::new(0);
        pool.run(6, &|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 6);
    }

    #[test]
    fn stalled_lane_dispatch_degrades_but_completes() {
        let pool = ExecPool::new(2);
        // Stall worker lane 1 permanently: it must stop claiming, the
        // dispatcher plus lane 2 must still cover every slot, and the
        // job must be counted as a degraded dispatch — not a hang.
        pool.set_lane_stalled(1, true);
        assert_eq!(pool.stalled_lanes(), 1 << 1);
        let hits = AtomicUsize::new(0);
        pool.run(8, &|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 8);
        assert!(
            pool.degraded_dispatches() >= 1,
            "a dispatch under a stalled lane must be counted degraded"
        );
        assert_eq!(
            pool.worker_tallies()[1].0,
            0,
            "a stalled lane must not claim slots while stalled"
        );
        // Revive the lane: the pool returns to full-width service.
        pool.set_lane_stalled(1, false);
        assert_eq!(pool.stalled_lanes(), 0);
        let before = pool.degraded_dispatches();
        let hits = AtomicUsize::new(0);
        pool.run(8, &|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 8);
        assert_eq!(
            pool.degraded_dispatches(),
            before,
            "a healthy dispatch must not be counted degraded"
        );
        // Lane 0 (the dispatcher) and out-of-range lanes are never
        // maskable: the dispatcher always participates, so `run` can
        // never deadlock even with every worker stalled.
        pool.set_lane_stalled(0, true);
        pool.set_lane_stalled(64, true);
        assert_eq!(pool.stalled_lanes(), 0);
    }

    #[test]
    fn latch_timeout_counts_overdue_joins_without_abandoning() {
        // Timing-sensitive (real sleeps); Miri's serial scheduler
        // would make the margins meaningless.
        if cfg!(miri) {
            return;
        }
        let pool = ExecPool::new(1);
        pool.set_latch_timeout(Some(Duration::from_millis(20)));
        // Choreography: worker lane 1 starts stalled, so the
        // dispatcher deterministically claims slot 0. Slot 0 revives
        // the lane and spins until the worker has entered slot 1,
        // then returns — the dispatcher reaches the completion latch
        // while the worker is still sleeping, so the bounded wait
        // must time out (counted) and then still join normally.
        pool.set_lane_stalled(1, true);
        let worker_in_slot = AtomicUsize::new(0);
        pool.run(2, &|s| {
            if s == 0 {
                pool.set_lane_stalled(1, false);
                let t0 = Instant::now();
                while worker_in_slot.load(Ordering::Acquire) == 0 {
                    assert!(
                        t0.elapsed() < Duration::from_secs(5),
                        "worker never claimed the remaining slot"
                    );
                    std::thread::yield_now();
                }
            } else {
                worker_in_slot.store(1, Ordering::Release);
                std::thread::sleep(Duration::from_millis(120));
            }
        });
        assert!(
            pool.latch_timeouts() >= 1,
            "an overdue completion latch must be a counted timeout"
        );
        // The latch still joined: both slots completed exactly once
        // and the pool stays serviceable with the timeout armed.
        let hits = AtomicUsize::new(0);
        pool.run(4, &|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 4);
        pool.set_latch_timeout(None);
    }
}
