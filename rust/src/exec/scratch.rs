//! Reusable execution buffers — the zero-allocation serving arena.
//!
//! Every `*_into` executor entry point writes its outputs (and keeps
//! its bookkeeping) in a [`Scratch`] instead of allocating fresh
//! vectors per request. A serving engine owns a small pool of these
//! (one is checked out per dispatch), so once traffic has warmed the
//! buffers to the corpus's maximum sizes, the steady-state serve path
//! performs **zero heap allocations per request** — the regression
//! test in `tests/alloc.rs` pins this with a counting allocator.
//!
//! The "take-or-borrow" story: after an `*_into` call the caller can
//! either *borrow* the output ([`Scratch::y`] / [`Scratch::y_batch`],
//! the hot serving path — nothing is copied) or *take* it
//! ([`Scratch::take_y`] / [`Scratch::take_y_batch`], the one-shot
//! paths that must return an owning `ExecResult`; the scratch simply
//! re-grows on its next use).

use crate::sparse::csr5::TileCarry;

/// Reusable buffers for one in-flight dispatch. All fields retain
/// their capacity across requests.
#[derive(Default)]
pub struct Scratch {
    /// Single-vector output of the last `spmv_*_into`.
    pub(crate) y: Vec<f64>,
    /// Interleaved packed input block of the last `spmm_into`
    /// (`xs[i * batch + j]`).
    pub(crate) packed: Vec<f64>,
    /// Batched output of the last `spmm_into` (`y[r * batch + j]`).
    pub(crate) yb: Vec<f64>,
    /// Indices of partition slots that carry work in the current
    /// dispatch (the executors' empty-slot filter, without the
    /// per-request `Vec` it used to allocate).
    pub(crate) active: Vec<usize>,
    /// Per-slot CSR5 carry buffers; outer length grows to the widest
    /// tile partition seen, inner vectors are cleared and reused.
    pub(crate) carries: Vec<Vec<TileCarry>>,
}

impl Scratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Borrow the single-vector output of the last `spmv_*_into`.
    pub fn y(&self) -> &[f64] {
        &self.y
    }

    /// Borrow the batched output of the last `spmm_into`
    /// (vector-interleaved: element `(r, j)` at `r * batch + j`).
    pub fn y_batch(&self) -> &[f64] {
        &self.yb
    }

    /// Take ownership of the single-vector output (leaves an empty
    /// buffer behind; the scratch re-grows on next use).
    pub fn take_y(&mut self) -> Vec<f64> {
        std::mem::take(&mut self.y)
    }

    /// Take ownership of the batched output.
    pub fn take_y_batch(&mut self) -> Vec<f64> {
        std::mem::take(&mut self.yb)
    }

    /// Heap capacity currently retained by this scratch, in bytes —
    /// feeds the scratch-arena gauge of the engine's metrics
    /// snapshot. Grows as traffic warms the buffers, then plateaus
    /// (the zero-alloc steady state).
    pub fn footprint_bytes(&self) -> usize {
        use std::mem::size_of;
        (self.y.capacity() + self.packed.capacity() + self.yb.capacity())
            * size_of::<f64>()
            + self.active.capacity() * size_of::<usize>()
            + self.carries.capacity() * size_of::<Vec<TileCarry>>()
            + self
                .carries
                .iter()
                .map(|c| c.capacity() * size_of::<TileCarry>())
                .sum::<usize>()
    }

    /// Extract output vector `j` of the last `spmm_into` as an owned
    /// column (the compatibility path for callers that need
    /// per-request vectors; the serving path borrows instead).
    pub fn batch_column(
        &self,
        n_rows: usize,
        batch: usize,
        j: usize,
    ) -> Vec<f64> {
        assert!(j < batch);
        (0..n_rows).map(|r| self.yb[r * batch + j]).collect()
    }
}
