//! Native threaded SpMV executors — the functional compute path for
//! arbitrary shapes (the PJRT artifacts cover the bucketed shapes; see
//! `runtime`). Also used to wall-clock the host in the §Perf benches.
//!
//! Threads write disjoint row ranges of `y`; the only cross-thread
//! rows are CSR5 range-boundary carries, which are merged by the
//! calling thread after the join (exactly the CSR5 algorithm's
//! cross-thread reduction step).

use std::time::Instant;

use crate::sched::{partition, Partition, Schedule};
use crate::sparse::csr5::TileCarry;
use crate::sparse::{Csr, Csr5};

/// Result of one threaded SpMV execution.
#[derive(Clone, Debug)]
pub struct ExecResult {
    pub y: Vec<f64>,
    pub wall_seconds: f64,
    pub threads: usize,
}

impl ExecResult {
    pub fn gflops(&self, nnz: usize) -> f64 {
        2.0 * nnz as f64 / self.wall_seconds / 1e9
    }
}

/// Disjoint-range mutable view of `y` for scoped threads.
///
/// SAFETY: callers must hand each thread ranges that do not overlap
/// with any other thread's ranges — guaranteed by
/// `Partition::validate`, which rejects double-covered rows.
struct SendPtr(*mut f64);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Multi-threaded CSR SpMV under any row partition.
pub fn spmv_threaded(
    csr: &Csr,
    x: &[f64],
    schedule: Schedule,
    n_threads: usize,
) -> ExecResult {
    assert_eq!(x.len(), csr.n_cols);
    let part = partition(csr, schedule, n_threads);
    debug_assert!(part.validate(csr).is_ok());
    match part {
        Partition::Rows { per_thread } => {
            spmv_rows_threaded(csr, x, &per_thread)
        }
        Partition::Tiles { tile_nnz, per_thread } => {
            let csr5 = Csr5::from_csr(csr, tile_nnz);
            spmv_csr5_threaded(&csr5, x, &per_thread)
        }
    }
}

fn spmv_rows_threaded(
    csr: &Csr,
    x: &[f64],
    per_thread: &[Vec<(usize, usize)>],
) -> ExecResult {
    let mut y = vec![0.0f64; csr.n_rows];
    let ptr = SendPtr(y.as_mut_ptr());
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for ranges in per_thread {
            let ptr = &ptr;
            s.spawn(move || {
                // SAFETY: ranges are disjoint across threads
                // (Partition::validate) — each y[r] is written by
                // exactly one thread.
                let yslice = unsafe {
                    std::slice::from_raw_parts_mut(ptr.0, csr.n_rows)
                };
                for &(r0, r1) in ranges {
                    csr.spmv_rows(r0, r1, x, yslice);
                }
            });
        }
    });
    ExecResult {
        y,
        wall_seconds: t0.elapsed().as_secs_f64(),
        threads: per_thread.len(),
    }
}

/// Multi-threaded CSR5 SpMV over tile ranges, with post-join carry
/// merge.
pub fn spmv_csr5_threaded(
    csr5: &Csr5,
    x: &[f64],
    per_thread: &[(usize, usize)],
) -> ExecResult {
    let mut y = vec![0.0f64; csr5.n_rows];
    let ptr = SendPtr(y.as_mut_ptr());
    let t0 = Instant::now();
    let carries: Vec<Vec<TileCarry>> = std::thread::scope(|s| {
        let handles: Vec<_> = per_thread
            .iter()
            .map(|&(a, b)| {
                let ptr = &ptr;
                s.spawn(move || {
                    // SAFETY: spmv_tiles writes only rows fully
                    // contained in its tile range; boundary rows are
                    // returned as carries, not written.
                    let yslice = unsafe {
                        std::slice::from_raw_parts_mut(ptr.0, csr5.n_rows)
                    };
                    csr5.spmv_tiles(a, b, x, yslice)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for cs in carries {
        for c in cs {
            y[c.row] += c.value;
        }
    }
    ExecResult {
        y,
        wall_seconds: t0.elapsed().as_secs_f64(),
        threads: per_thread.len(),
    }
}

/// Sequential reference execution (wrapped for timing symmetry).
pub fn spmv_sequential(csr: &Csr, x: &[f64]) -> ExecResult {
    let mut y = vec![0.0f64; csr.n_rows];
    let t0 = Instant::now();
    csr.spmv(x, &mut y);
    ExecResult { y, wall_seconds: t0.elapsed().as_secs_f64(), threads: 1 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use crate::util::testkit::check;
    use crate::{prop_assert, sparse::Coo};

    fn random_csr(rng: &mut Pcg32, n: usize, per_row: usize) -> Csr {
        let mut coo = Coo::new(n, n);
        for r in 0..n {
            let deg = rng.gen_range(per_row * 2 + 1);
            for c in rng.sample_distinct(n, deg.min(n)) {
                coo.push(r, c, rng.gen_f64() - 0.5);
            }
        }
        coo.to_csr()
    }

    fn assert_close(a: &[f64], b: &[f64]) {
        assert_eq!(a.len(), b.len());
        for (i, (p, q)) in a.iter().zip(b).enumerate() {
            assert!(
                (p - q).abs() < 1e-9 * (1.0 + p.abs()),
                "row {i}: {p} vs {q}"
            );
        }
    }

    #[test]
    fn all_schedules_match_sequential() {
        let mut rng = Pcg32::new(0xE8EC);
        let csr = random_csr(&mut rng, 500, 6);
        let x: Vec<f64> = (0..500).map(|_| rng.gen_f64()).collect();
        let want = spmv_sequential(&csr, &x).y;
        for sched in [
            Schedule::CsrRowStatic,
            Schedule::CsrRowBalanced,
            Schedule::Csr5Tiles { tile_nnz: 32 },
            Schedule::CsrDynamic { chunk: 16 },
        ] {
            for nt in [1, 2, 3, 4, 8] {
                let got = spmv_threaded(&csr, &x, sched, nt);
                assert_close(&got.y, &want);
                assert_eq!(got.threads, nt);
            }
        }
    }

    #[test]
    fn csr5_boundary_rows_merge() {
        // One long row spanning multiple threads' tile ranges: every
        // thread contributes a carry to the same row.
        let n = 64;
        let mut coo = Coo::new(n, n);
        for c in 0..n {
            coo.push(0, c, 1.0);
        }
        let csr = coo.to_csr();
        let x = vec![1.0; n];
        let got = spmv_threaded(
            &csr,
            &x,
            Schedule::Csr5Tiles { tile_nnz: 4 },
            4,
        );
        assert_eq!(got.y[0], n as f64);
        assert!(got.y[1..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn property_threaded_matches_sequential() {
        check("threaded==sequential", 25, |rng| {
            let n = 16 + rng.gen_range(200);
            let csr = random_csr(rng, n, 4);
            let x: Vec<f64> = (0..n).map(|_| rng.gen_f64()).collect();
            let want = spmv_sequential(&csr, &x).y;
            let nt = 1 + rng.gen_range(8);
            let sched = match rng.gen_range(4) {
                0 => Schedule::CsrRowStatic,
                1 => Schedule::CsrRowBalanced,
                2 => Schedule::Csr5Tiles { tile_nnz: 1 + rng.gen_range(64) },
                _ => Schedule::CsrDynamic { chunk: 1 + rng.gen_range(32) },
            };
            let got = spmv_threaded(&csr, &x, sched, nt);
            for (i, (p, q)) in got.y.iter().zip(&want).enumerate() {
                prop_assert!(
                    (p - q).abs() < 1e-9 * (1.0 + p.abs()),
                    "row {i}: {p} vs {q} under {sched:?} nt={nt}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn empty_matrix() {
        let csr = Csr::zero(10, 10);
        let x = vec![1.0; 10];
        let r = spmv_threaded(&csr, &x, Schedule::CsrRowStatic, 4);
        assert!(r.y.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn gflops_positive() {
        let mut rng = Pcg32::new(1);
        let csr = random_csr(&mut rng, 256, 8);
        let x = vec![1.0; 256];
        let r = spmv_threaded(&csr, &x, Schedule::CsrRowStatic, 2);
        assert!(r.gflops(csr.nnz()) > 0.0);
    }
}
