//! Native threaded SpMV executors — the functional compute path for
//! arbitrary shapes (the PJRT artifacts cover the bucketed shapes; see
//! `runtime`). Also used to wall-clock the host in the §Perf benches.
//!
//! Threads write disjoint row ranges of `y`; the only cross-thread
//! rows are CSR5 range-boundary carries, which are merged by the
//! calling thread after the join (exactly the CSR5 algorithm's
//! cross-thread reduction step). SELL-C-σ slots own whole chunks,
//! whose permuted rows are disjoint across slots by construction.
//!
//! Every executor comes in two dispatch modes behind one entry point:
//! handed a [`pool::ExecPool`] it runs on the pool's resident workers
//! (the serving hot path — no per-request thread spawn); handed
//! `None` it falls back to `std::thread::scope` (one-shot CLI and
//! bench paths where a resident pool has nothing to amortize).
//! Partition slots with no rows are skipped in both modes, and the
//! result reports the *effective* worker count, so scalability curves
//! at `n_threads > n_rows` aren't skewed by idle spawns.
//!
//! And every executor comes in two *allocation* modes: the `*_into`
//! entry points write outputs into a caller-provided [`Scratch`]
//! arena (the zero-allocation serving path — buffers are reused
//! across requests), while the classic entry points allocate a fresh
//! result per call (one-shot paths) by running the same kernels over
//! a throwaway scratch and taking its buffers.

pub mod pool;
pub mod scratch;

pub use pool::ExecPool;
pub use scratch::Scratch;

use std::time::Instant;

use crate::sched::{partition, Partition, Schedule};
use crate::sparse::csr::fmadd;
use crate::sparse::sell::SellCSigma;
use crate::sparse::{Csr, Csr5};

/// Result of one threaded SpMV execution (owning).
#[derive(Clone, Debug)]
pub struct ExecResult {
    pub y: Vec<f64>,
    pub wall_seconds: f64,
    /// Effective parallelism: workers that had nonempty row/tile
    /// ranges (not the configured thread count).
    pub threads: usize,
}

impl ExecResult {
    /// Achieved Gflops; 0 when the timer resolved to zero (tiny
    /// kernels on coarse clocks must not report `inf`).
    pub fn gflops(&self, nnz: usize) -> f64 {
        if self.wall_seconds > 0.0 {
            2.0 * nnz as f64 / self.wall_seconds / 1e9
        } else {
            0.0
        }
    }

    /// Measured latency of the one request this execution served, in
    /// milliseconds — the autotuner's observation unit.
    pub fn per_request_ms(&self) -> f64 {
        self.wall_seconds * 1e3
    }
}

/// Result of one `spmv_*_into` execution: the timing/parallelism
/// metadata, with the output left in the caller's [`Scratch`]
/// (borrow via [`Scratch::y`], or take via [`ExecStats::into_result`]).
#[derive(Clone, Copy, Debug)]
pub struct ExecStats {
    pub wall_seconds: f64,
    /// Effective parallelism (slots that carried work).
    pub threads: usize,
}

impl ExecStats {
    pub fn per_request_ms(&self) -> f64 {
        self.wall_seconds * 1e3
    }

    /// Materialize an owning [`ExecResult`] by taking the scratch's
    /// output buffer (the "take" half of the take-or-borrow story).
    pub fn into_result(self, scratch: &mut Scratch) -> ExecResult {
        ExecResult {
            y: scratch.take_y(),
            wall_seconds: self.wall_seconds,
            threads: self.threads,
        }
    }
}

/// Disjoint-range mutable view for concurrent slot workers.
///
/// SAFETY: callers must hand each slot writes that do not overlap
/// with any other slot's — guaranteed by `Partition::validate`, which
/// rejects double-covered rows, and by slot-indexed output cells.
struct SendPtr<T>(*mut T);
// SAFETY: see type docs — slots never write overlapping ranges, so
// sending the pointer to worker threads cannot create aliased &mut.
unsafe impl<T> Send for SendPtr<T> {}
// SAFETY: shared references to the wrapper only expose the raw
// pointer value; all dereferences are slot-disjoint (type docs).
unsafe impl<T> Sync for SendPtr<T> {}

/// A row-range list that carries at least one row — the slot filter
/// shared by the executors and by `Plan::effective_threads`, so the
/// replay cost model can never drift from what execution reports.
fn slot_has_rows(ranges: &[(usize, usize)]) -> bool {
    ranges.iter().any(|&(r0, r1)| r1 > r0)
}

/// Effective parallelism of a row partition: slots that carry work,
/// floored at 1 (what `ExecResult.threads`/`SpmmResult.threads`
/// report).
pub fn effective_row_slots(per_thread: &[Vec<(usize, usize)>]) -> usize {
    per_thread
        .iter()
        .filter(|ranges| slot_has_rows(ranges))
        .count()
        .max(1)
}

/// Effective parallelism of a tile/chunk partition, floored at 1.
pub fn effective_tile_slots(per_thread: &[(usize, usize)]) -> usize {
    per_thread.iter().filter(|&&(t0, t1)| t1 > t0).count().max(1)
}

/// Run `work(slot)` for every slot: on the pool's resident workers
/// when one is supplied, otherwise on freshly scoped threads (the
/// one-shot fallback). Returns once every slot completed.
fn dispatch(
    pool: Option<&ExecPool>,
    n_slots: usize,
    work: &(dyn Fn(usize) + Sync),
) {
    match pool {
        Some(p) => p.run(n_slots, work),
        None => match n_slots {
            0 => {}
            1 => work(0),
            _ => {
                std::thread::scope(|s| {
                    for i in 0..n_slots {
                        s.spawn(move || work(i));
                    }
                });
            }
        },
    }
}

/// Pre-converted structures a partitioned execution may reuse instead
/// of rebuilding per call — plans memoize their CSR5/SELL conversion
/// at build time and hand it here, so the non-plan `spmv_partitioned`
/// path stops paying per-request conversion too.
#[derive(Clone, Copy, Default)]
pub struct Prebuilt<'a> {
    pub csr5: Option<&'a Csr5>,
    pub sell: Option<&'a SellCSigma>,
}

/// Multi-threaded CSR SpMV under any row partition (spawn fallback;
/// see [`spmv_threaded_on`] for the pooled serving path).
pub fn spmv_threaded(
    csr: &Csr,
    x: &[f64],
    schedule: Schedule,
    n_threads: usize,
) -> ExecResult {
    spmv_threaded_on(None, csr, x, schedule, n_threads)
}

/// Multi-threaded SpMV: partition under `schedule`, convert once when
/// the schedule needs a packed format, then execute on `pool` (or
/// scoped threads when `None`).
pub fn spmv_threaded_on(
    pool: Option<&ExecPool>,
    csr: &Csr,
    x: &[f64],
    schedule: Schedule,
    n_threads: usize,
) -> ExecResult {
    assert_eq!(x.len(), csr.n_cols);
    let part = partition(csr, schedule, n_threads);
    debug_assert!(part.validate(csr).is_ok());
    spmv_partitioned(pool, csr, x, &part, Prebuilt::default())
}

/// Execute a *pre-materialized* partition — the serving hot path:
/// plans memoize their partition at build time and requests skip the
/// (prefix-bisection / tiling / chunk-packing) partitioning work
/// entirely. `prebuilt` supplies already-converted CSR5/SELL
/// structures (matched on tile size / chunk height before use);
/// absent ones are converted on the fly (one-shot paths only — a
/// serving path should always pass its memoized conversion).
pub fn spmv_partitioned(
    pool: Option<&ExecPool>,
    csr: &Csr,
    x: &[f64],
    part: &Partition,
    prebuilt: Prebuilt<'_>,
) -> ExecResult {
    match part {
        Partition::Rows { per_thread } => {
            spmv_rows_on(pool, csr, x, per_thread)
        }
        Partition::Tiles { tile_nnz, per_thread } => match prebuilt.csr5 {
            Some(c5) if c5.tile_nnz == *tile_nnz => {
                spmv_csr5_on(pool, c5, x, per_thread)
            }
            _ => {
                let csr5 = Csr5::from_csr(csr, *tile_nnz);
                spmv_csr5_on(pool, &csr5, x, per_thread)
            }
        },
        Partition::SellChunks { c, sigma, per_thread } => {
            // The prebuilt must match on σ too — a different window
            // means a different row permutation, and the chunk ranges
            // of this partition would address the wrong rows.
            let want_sigma = crate::sparse::sell::normalize_sigma(
                (*c).max(1),
                *sigma,
                csr.n_rows,
            );
            match prebuilt.sell {
                Some(s) if s.c == *c && s.sigma == want_sigma => {
                    spmv_sell_on(pool, s, x, per_thread)
                }
                _ => {
                    // No clamping here: a hand-built partition with an
                    // out-of-domain c must hit `from_csr`'s assert
                    // loudly, not silently convert under a different
                    // chunking than the ranges were computed for.
                    let sell = SellCSigma::from_csr(csr, *c, *sigma);
                    spmv_sell_on(pool, &sell, x, per_thread)
                }
            }
        }
    }
}

/// CSR SpMV over explicit per-slot row ranges. Slots with no rows are
/// skipped; `threads` reports the effective worker count. Writes into
/// the caller's scratch (`scratch.y()`), allocation-free once the
/// scratch is warm.
pub fn spmv_rows_into(
    pool: Option<&ExecPool>,
    csr: &Csr,
    x: &[f64],
    per_thread: &[Vec<(usize, usize)>],
    scratch: &mut Scratch,
) -> ExecStats {
    assert_eq!(x.len(), csr.n_cols);
    let Scratch { y, active, .. } = scratch;
    active.clear();
    for (i, ranges) in per_thread.iter().enumerate() {
        if slot_has_rows(ranges) {
            active.push(i);
        }
    }
    let active: &[usize] = active;
    y.resize(csr.n_rows, 0.0);
    let ptr = SendPtr(y.as_mut_ptr());
    let t0 = Instant::now();
    let work = |slot: usize| {
        // SAFETY: ranges are disjoint across slots
        // (Partition::validate) — each y[r] is written by exactly
        // one worker.
        let yslice =
            unsafe { std::slice::from_raw_parts_mut(ptr.0, csr.n_rows) };
        for &(r0, r1) in &per_thread[active[slot]] {
            csr.spmv_rows(r0, r1, x, yslice);
        }
    };
    dispatch(pool, active.len(), &work);
    ExecStats {
        wall_seconds: t0.elapsed().as_secs_f64(),
        threads: active.len().max(1),
    }
}

/// Allocating wrapper over [`spmv_rows_into`] (one-shot paths).
pub fn spmv_rows_on(
    pool: Option<&ExecPool>,
    csr: &Csr,
    x: &[f64],
    per_thread: &[Vec<(usize, usize)>],
) -> ExecResult {
    let mut scratch = Scratch::new();
    spmv_rows_into(pool, csr, x, per_thread, &mut scratch)
        .into_result(&mut scratch)
}

/// Multi-threaded CSR5 SpMV over tile ranges, with post-join carry
/// merge (spawn fallback; see [`spmv_csr5_on`]).
pub fn spmv_csr5_threaded(
    csr5: &Csr5,
    x: &[f64],
    per_thread: &[(usize, usize)],
) -> ExecResult {
    spmv_csr5_on(None, csr5, x, per_thread)
}

/// CSR5 SpMV over tile ranges into the caller's scratch. Empty tile
/// ranges are skipped; boundary-row carries land in reused per-slot
/// buffers and are merged by the calling thread after the latch (the
/// CSR5 cross-thread reduction step).
pub fn spmv_csr5_into(
    pool: Option<&ExecPool>,
    csr5: &Csr5,
    x: &[f64],
    per_thread: &[(usize, usize)],
    scratch: &mut Scratch,
) -> ExecStats {
    let Scratch { y, active, carries, .. } = scratch;
    active.clear();
    for (i, &(t0, t1)) in per_thread.iter().enumerate() {
        if t1 > t0 {
            active.push(i);
        }
    }
    let active: &[usize] = active;
    y.resize(csr5.n_rows, 0.0);
    // Carries add into y, and rows with no nonzeros are never written
    // by a tile — the output must start clean.
    y.fill(0.0);
    if carries.len() < active.len() {
        // One-time scratch growth to the slot count; steady-state
        // serving re-enters with capacity already in place (pinned by
        // tests/alloc.rs). lint:allow(hot-alloc)
        carries.resize_with(active.len(), Vec::new);
    }
    let yptr = SendPtr(y.as_mut_ptr());
    let cptr = SendPtr(carries.as_mut_ptr());
    let t0 = Instant::now();
    let work = |slot: usize| {
        // SAFETY: spmv_tiles_into writes only rows fully contained in
        // its tile range; boundary rows come back as carries. Each
        // slot writes its own carries cell.
        let yslice =
            unsafe { std::slice::from_raw_parts_mut(yptr.0, csr5.n_rows) };
        let (a, b) = per_thread[active[slot]];
        // SAFETY: `slot < active.len() <= carries.len()` and each
        // slot dereferences only its own carries cell — no aliasing.
        let cs = unsafe { &mut *cptr.0.add(slot) };
        csr5.spmv_tiles_into(a, b, x, yslice, cs);
    };
    dispatch(pool, active.len(), &work);
    for cs in &carries[..active.len()] {
        for c in cs {
            y[c.row] += c.value;
        }
    }
    ExecStats {
        wall_seconds: t0.elapsed().as_secs_f64(),
        threads: active.len().max(1),
    }
}

/// Allocating wrapper over [`spmv_csr5_into`].
pub fn spmv_csr5_on(
    pool: Option<&ExecPool>,
    csr5: &Csr5,
    x: &[f64],
    per_thread: &[(usize, usize)],
) -> ExecResult {
    let mut scratch = Scratch::new();
    spmv_csr5_into(pool, csr5, x, per_thread, &mut scratch)
        .into_result(&mut scratch)
}

/// SELL-C-σ SpMV over chunk ranges into the caller's scratch. Each
/// slot sweeps its chunks column-major (the vectorizable SELL access
/// pattern) and scatters per-row sums through `perm` into `y`; chunk
/// ranges own disjoint permuted rows, so slots never collide.
pub fn spmv_sell_into(
    pool: Option<&ExecPool>,
    sell: &SellCSigma,
    x: &[f64],
    per_thread: &[(usize, usize)],
    scratch: &mut Scratch,
) -> ExecStats {
    assert_eq!(x.len(), sell.n_cols);
    let Scratch { y, active, .. } = scratch;
    active.clear();
    for (i, &(k0, k1)) in per_thread.iter().enumerate() {
        if k1 > k0 {
            active.push(i);
        }
    }
    let active: &[usize] = active;
    y.resize(sell.n_rows, 0.0);
    let ptr = SendPtr(y.as_mut_ptr());
    let t0 = Instant::now();
    let work = |slot: usize| {
        // SAFETY: chunk ranges are disjoint across slots and each
        // chunk owns `c` distinct rows of the permutation — every
        // y[perm[slot_row]] is written by exactly one worker.
        let yslice =
            unsafe { std::slice::from_raw_parts_mut(ptr.0, sell.n_rows) };
        let (k0, k1) = per_thread[active[slot]];
        sell.spmv_chunks(k0, k1, x, yslice);
    };
    dispatch(pool, active.len(), &work);
    ExecStats {
        wall_seconds: t0.elapsed().as_secs_f64(),
        threads: active.len().max(1),
    }
}

/// Allocating wrapper over [`spmv_sell_into`].
pub fn spmv_sell_on(
    pool: Option<&ExecPool>,
    sell: &SellCSigma,
    x: &[f64],
    per_thread: &[(usize, usize)],
) -> ExecResult {
    let mut scratch = Scratch::new();
    spmv_sell_into(pool, sell, x, per_thread, &mut scratch)
        .into_result(&mut scratch)
}

/// Sequential reference execution (wrapped for timing symmetry).
pub fn spmv_sequential(csr: &Csr, x: &[f64]) -> ExecResult {
    let mut y = vec![0.0f64; csr.n_rows];
    let t0 = Instant::now();
    csr.spmv(x, &mut y);
    ExecResult { y, wall_seconds: t0.elapsed().as_secs_f64(), threads: 1 }
}

/// Width of one column block of the batched-vector SpMM kernel: the
/// accumulator tile lives in registers, and every nonzero of `A` is
/// loaded once per block instead of once per vector.
pub const SPMM_COL_BLOCK: usize = 8;

/// Result of one batched (multi-vector) SpMM execution:
/// `Y = A X` for a block of `batch` dense vectors.
#[derive(Clone, Debug)]
pub struct SpmmResult {
    /// Vector-interleaved outputs: `y[r * batch + j]` is row `r` of
    /// output vector `j` (same layout as the `xs` input).
    pub y: Vec<f64>,
    pub n_rows: usize,
    pub batch: usize,
    pub wall_seconds: f64,
    /// Effective parallelism (workers with nonempty row ranges).
    pub threads: usize,
    /// The schedule that actually executed. Tile (CSR5) and SELL
    /// chunk plans remap to [`Schedule::CsrRowBalanced`] for
    /// multi-vector batches — telemetry reports this field, not the
    /// plan's nominal schedule, so replay tables stop attributing
    /// SpMM throughput to formats that never ran it.
    pub schedule: Schedule,
}

impl SpmmResult {
    /// Extract output vector `j` as a contiguous `Vec`.
    pub fn column(&self, j: usize) -> Vec<f64> {
        assert!(j < self.batch);
        (0..self.n_rows).map(|r| self.y[r * self.batch + j]).collect()
    }

    /// Achieved Gflops; 0 when the timer resolved to zero.
    pub fn gflops(&self, nnz: usize) -> f64 {
        if self.wall_seconds > 0.0 {
            2.0 * nnz as f64 * self.batch as f64 / self.wall_seconds / 1e9
        } else {
            0.0
        }
    }

    /// Measured per-request share of this coalesced dispatch, in
    /// milliseconds — what the autotuner records per served vector so
    /// batched and singleton observations stay comparable.
    pub fn per_request_ms(&self) -> f64 {
        self.wall_seconds * 1e3 / self.batch.max(1) as f64
    }
}

/// Metadata of one `spmm_into` execution; the outputs stay in the
/// caller's [`Scratch`] (`scratch.y_batch()`).
#[derive(Clone, Copy, Debug)]
pub struct SpmmStats {
    pub n_rows: usize,
    pub batch: usize,
    pub wall_seconds: f64,
    pub threads: usize,
    /// Effective executed schedule (see [`SpmmResult::schedule`]).
    pub schedule: Schedule,
}

impl SpmmStats {
    /// Per-request share of the coalesced dispatch, in milliseconds.
    pub fn per_request_ms(&self) -> f64 {
        self.wall_seconds * 1e3 / self.batch.max(1) as f64
    }

    /// Materialize an owning [`SpmmResult`] by taking the scratch's
    /// batched output buffer.
    pub fn into_result(self, scratch: &mut Scratch) -> SpmmResult {
        SpmmResult {
            y: scratch.take_y_batch(),
            n_rows: self.n_rows,
            batch: self.batch,
            wall_seconds: self.wall_seconds,
            threads: self.threads,
            schedule: self.schedule,
        }
    }
}

/// Interleave a slice of equal-length vectors into the
/// `xs[i * batch + j]` layout the SpMM kernels consume, reusing the
/// caller's buffer (allocation-free once warm). Panics on ragged
/// input lengths ("vector length mismatch") — the serving engine
/// validates lengths before packing, so this is a programmer-error
/// guard, not a traffic-error path.
pub fn pack_vectors_into<T: AsRef<[f64]>>(vectors: &[T], xs: &mut Vec<f64>) {
    let batch = vectors.len();
    assert!(batch > 0, "need at least one vector");
    let n = vectors[0].as_ref().len();
    // No clear(): resize alone grows/shrinks, and the loops below
    // overwrite every element — a warm buffer pays no memset.
    xs.resize(n * batch, 0.0);
    for (j, v) in vectors.iter().enumerate() {
        let v = v.as_ref();
        assert_eq!(v.len(), n, "vector length mismatch");
        for (i, &val) in v.iter().enumerate() {
            xs[i * batch + j] = val;
        }
    }
}

/// Allocating wrapper over [`pack_vectors_into`].
pub fn pack_vectors<T: AsRef<[f64]>>(vectors: &[T]) -> Vec<f64> {
    let mut xs = Vec::new();
    pack_vectors_into(vectors, &mut xs);
    xs
}

/// The column-blocked SpMM inner kernel over a row range: for each
/// block of `SPMM_COL_BLOCK` vectors, each nonzero `A[r,c]` is read
/// once and multiplied against the block's contiguous slice of `x`
/// row `c`. Row elements follow the crate-wide accumulation
/// discipline (element `k` -> accumulator `k % 4`, reduced
/// `(a0+a1)+(a2+a3)` — see [`crate::sparse::csr::row_dot`]), so every
/// output column is bitwise identical to the single-vector CSR
/// reference.
fn spmm_rows_blocked(
    csr: &Csr,
    xs: &[f64],
    batch: usize,
    r0: usize,
    r1: usize,
    y: &mut [f64],
) {
    let mut jb = 0;
    while jb < batch {
        let bw = (batch - jb).min(SPMM_COL_BLOCK);
        let mut acc = [[0.0f64; SPMM_COL_BLOCK]; 4];
        for r in r0..r1 {
            for lane in acc.iter_mut() {
                lane[..bw].fill(0.0);
            }
            let (lo, hi) = (csr.ptr[r], csr.ptr[r + 1]);
            let main = lo + ((hi - lo) & !3);
            let mut k = lo;
            while k < main {
                for (e, lane) in acc.iter_mut().enumerate() {
                    let a = csr.data[k + e];
                    let xoff = csr.indices[k + e] as usize * batch + jb;
                    for (t, slot) in lane[..bw].iter_mut().enumerate() {
                        *slot = fmadd(a, xs[xoff + t], *slot);
                    }
                }
                k += 4;
            }
            let mut e = 0;
            while k < hi {
                let a = csr.data[k];
                let xoff = csr.indices[k] as usize * batch + jb;
                for (t, slot) in acc[e][..bw].iter_mut().enumerate() {
                    *slot = fmadd(a, xs[xoff + t], *slot);
                }
                e += 1;
                k += 1;
            }
            let yoff = r * batch + jb;
            for (t, out) in y[yoff..yoff + bw].iter_mut().enumerate() {
                *out = (acc[0][t] + acc[1][t]) + (acc[2][t] + acc[3][t]);
            }
        }
        jb += bw;
    }
}

/// The row-space schedule a batched SpMM actually runs under. Packed
/// formats (CSR5 tiles, SELL chunks) have no multi-vector kernel;
/// they remap to `CsrRowBalanced`, the row-space schedule with the
/// same load-balancing intent.
pub fn effective_spmm_schedule(schedule: Schedule) -> Schedule {
    match schedule {
        Schedule::Csr5Tiles { .. } | Schedule::SellChunks { .. } => {
            Schedule::CsrRowBalanced
        }
        s => s,
    }
}

/// Multi-threaded batched SpMM: `Y = A X` for `batch` interleaved
/// vectors (`xs[i * batch + j]`), threads over row partitions (spawn
/// fallback; see [`spmm_threaded_on`]).
pub fn spmm_threaded(
    csr: &Csr,
    xs: &[f64],
    batch: usize,
    schedule: Schedule,
    n_threads: usize,
) -> SpmmResult {
    spmm_threaded_on(None, csr, xs, batch, schedule, n_threads)
}

/// Batched SpMM on an optional pool: partition under the effective
/// (row-space) schedule, then execute.
pub fn spmm_threaded_on(
    pool: Option<&ExecPool>,
    csr: &Csr,
    xs: &[f64],
    batch: usize,
    schedule: Schedule,
    n_threads: usize,
) -> SpmmResult {
    let schedule = effective_spmm_schedule(schedule);
    let part = partition(csr, schedule, n_threads);
    debug_assert!(part.validate(csr).is_ok());
    let per_thread = match part {
        Partition::Rows { per_thread } => per_thread,
        _ => unreachable!("packed-format schedules remapped"),
    };
    spmm_partitioned(pool, csr, xs, batch, &per_thread, schedule)
}

/// Shared SpMM slot runner: filtered `active` slot indices, kernel
/// dispatch, wall-clock. Output rows are owned per slot.
fn spmm_run(
    pool: Option<&ExecPool>,
    csr: &Csr,
    xs: &[f64],
    batch: usize,
    per_thread: &[Vec<(usize, usize)>],
    active: &[usize],
    y: &mut [f64],
) -> f64 {
    let ptr = SendPtr(y.as_mut_ptr());
    let t0 = Instant::now();
    let work = |slot: usize| {
        // SAFETY: row ranges are disjoint across slots
        // (Partition::validate), and row r owns the disjoint slice
        // y[r*batch .. (r+1)*batch].
        let yslice = unsafe {
            std::slice::from_raw_parts_mut(ptr.0, csr.n_rows * batch)
        };
        for &(r0, r1) in &per_thread[active[slot]] {
            spmm_rows_blocked(csr, xs, batch, r0, r1, yslice);
        }
    };
    dispatch(pool, active.len(), &work);
    t0.elapsed().as_secs_f64()
}

/// Batched SpMM over a *pre-materialized* row partition — the serving
/// hot path (plans memoize `per_thread` at build time). `schedule` is
/// recorded on the result as the effective executed schedule.
pub fn spmm_partitioned(
    pool: Option<&ExecPool>,
    csr: &Csr,
    xs: &[f64],
    batch: usize,
    per_thread: &[Vec<(usize, usize)>],
    schedule: Schedule,
) -> SpmmResult {
    assert!(batch > 0, "batch must be >= 1");
    assert_eq!(xs.len(), csr.n_cols * batch, "xs length != n_cols * batch");
    let active: Vec<usize> = (0..per_thread.len())
        .filter(|&i| slot_has_rows(&per_thread[i]))
        .collect();
    let mut y = vec![0.0f64; csr.n_rows * batch];
    let wall_seconds =
        spmm_run(pool, csr, xs, batch, per_thread, &active, &mut y);
    SpmmResult {
        y,
        n_rows: csr.n_rows,
        batch,
        wall_seconds,
        threads: active.len().max(1),
        schedule,
    }
}

/// Batched SpMM into the caller's scratch: packs the input vectors
/// into the reused interleave buffer and writes outputs into the
/// reused batched output buffer — the zero-allocation serving path
/// for coalesced dispatches. `vectors` must be equal-length (the
/// engine validates before calling).
pub fn spmm_into(
    pool: Option<&ExecPool>,
    csr: &Csr,
    vectors: &[&[f64]],
    per_thread: &[Vec<(usize, usize)>],
    schedule: Schedule,
    scratch: &mut Scratch,
) -> SpmmStats {
    let batch = vectors.len();
    assert!(batch > 0, "batch must be >= 1");
    let Scratch { packed, yb, active, .. } = scratch;
    pack_vectors_into(vectors, packed);
    assert_eq!(packed.len(), csr.n_cols * batch, "xs length != n_cols * batch");
    active.clear();
    for (i, ranges) in per_thread.iter().enumerate() {
        if slot_has_rows(ranges) {
            active.push(i);
        }
    }
    let active: &[usize] = active;
    yb.resize(csr.n_rows * batch, 0.0);
    let wall_seconds =
        spmm_run(pool, csr, packed, batch, per_thread, active, yb);
    SpmmStats {
        n_rows: csr.n_rows,
        batch,
        wall_seconds,
        threads: active.len().max(1),
        schedule,
    }
}

/// Sequential batched SpMM reference (timing symmetry with
/// [`spmv_sequential`]).
pub fn spmm_sequential(csr: &Csr, xs: &[f64], batch: usize) -> SpmmResult {
    assert!(batch > 0, "batch must be >= 1");
    assert_eq!(xs.len(), csr.n_cols * batch, "xs length != n_cols * batch");
    let mut y = vec![0.0f64; csr.n_rows * batch];
    let t0 = Instant::now();
    spmm_rows_blocked(csr, xs, batch, 0, csr.n_rows, &mut y);
    SpmmResult {
        y,
        n_rows: csr.n_rows,
        batch,
        wall_seconds: t0.elapsed().as_secs_f64(),
        threads: 1,
        schedule: Schedule::CsrRowStatic,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use crate::util::testkit::check;
    use crate::{prop_assert, sparse::Coo};

    fn random_csr(rng: &mut Pcg32, n: usize, per_row: usize) -> Csr {
        let mut coo = Coo::new(n, n);
        for r in 0..n {
            let deg = rng.gen_range(per_row * 2 + 1);
            for c in rng.sample_distinct(n, deg.min(n)) {
                coo.push(r, c, rng.gen_f64() - 0.5);
            }
        }
        coo.to_csr()
    }

    fn assert_close(a: &[f64], b: &[f64]) {
        assert_eq!(a.len(), b.len());
        for (i, (p, q)) in a.iter().zip(b).enumerate() {
            assert!(
                (p - q).abs() < 1e-9 * (1.0 + p.abs()),
                "row {i}: {p} vs {q}"
            );
        }
    }

    #[test]
    fn all_schedules_match_sequential() {
        let mut rng = Pcg32::new(0xE8EC);
        let csr = random_csr(&mut rng, 500, 6);
        let x: Vec<f64> = (0..500).map(|_| rng.gen_f64()).collect();
        let want = spmv_sequential(&csr, &x).y;
        for sched in [
            Schedule::CsrRowStatic,
            Schedule::CsrRowBalanced,
            Schedule::Csr5Tiles { tile_nnz: 32 },
            Schedule::CsrDynamic { chunk: 16 },
            Schedule::SellChunks { c: 8, sigma: 64 },
        ] {
            for nt in [1, 2, 3, 4, 8] {
                let got = spmv_threaded(&csr, &x, sched, nt);
                assert_close(&got.y, &want);
                assert_eq!(got.threads, nt, "{sched:?}");
            }
        }
    }

    #[test]
    fn row_space_and_sell_schedules_match_sequential_bitwise() {
        // The PR-5 equivalence pin: every kernel that reduces rows in
        // element order (all row-space schedules, and SELL-C-σ whose
        // padding is an exact no-op) reproduces the sequential
        // reference bit for bit. CSR5 may associate boundary-row
        // partials differently and is excluded (tolerance-tested
        // above and in tests/properties.rs).
        let mut rng = Pcg32::new(0xB175);
        for n in [37usize, 256, 401] {
            let csr = random_csr(&mut rng, n, 7);
            let x: Vec<f64> =
                (0..n).map(|_| rng.gen_f64() - 0.5).collect();
            let want = spmv_sequential(&csr, &x).y;
            for sched in [
                Schedule::CsrRowStatic,
                Schedule::CsrRowBalanced,
                Schedule::CsrDynamic { chunk: 8 },
                Schedule::SellChunks { c: 4, sigma: 16 },
                Schedule::SellChunks { c: 8, sigma: 64 },
                Schedule::SellChunks { c: 16, sigma: 16 },
                Schedule::SellChunks { c: 32, sigma: 4096 },
            ] {
                for nt in [1usize, 3, 8] {
                    let got = spmv_threaded(&csr, &x, sched, nt);
                    for (i, (a, b)) in want.iter().zip(&got.y).enumerate() {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "{sched:?} nt={nt} row {i}: {a} vs {b}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn scratch_reuse_matches_allocating_path_bitwise() {
        // One scratch serving many matrices/partitions in sequence
        // produces exactly what the allocating path produces — stale
        // buffer contents must never leak into an output.
        let mut rng = Pcg32::new(0x5C4A);
        let pool = ExecPool::new(3);
        let mut scratch = Scratch::new();
        for round in 0..12 {
            let n = 16 + rng.gen_range(300);
            let csr = random_csr(&mut rng, n, 5);
            let x: Vec<f64> = (0..n).map(|_| rng.gen_f64()).collect();
            let sched = match round % 3 {
                0 => Schedule::CsrRowBalanced,
                1 => Schedule::Csr5Tiles { tile_nnz: 32 },
                _ => Schedule::SellChunks { c: 8, sigma: 32 },
            };
            let part = partition(&csr, sched, 4);
            let stats = match &part {
                Partition::Rows { per_thread } => spmv_rows_into(
                    Some(&pool),
                    &csr,
                    &x,
                    per_thread,
                    &mut scratch,
                ),
                Partition::Tiles { tile_nnz, per_thread } => {
                    let c5 = Csr5::from_csr(&csr, *tile_nnz);
                    spmv_csr5_into(
                        Some(&pool),
                        &c5,
                        &x,
                        per_thread,
                        &mut scratch,
                    )
                }
                Partition::SellChunks { c, sigma, per_thread } => {
                    let s = SellCSigma::from_csr(&csr, *c, *sigma);
                    spmv_sell_into(
                        Some(&pool),
                        &s,
                        &x,
                        per_thread,
                        &mut scratch,
                    )
                }
            };
            let alloc =
                spmv_partitioned(None, &csr, &x, &part, Prebuilt::default());
            assert_eq!(stats.threads, alloc.threads, "round {round}");
            assert_eq!(scratch.y().len(), alloc.y.len());
            for (i, (a, b)) in alloc.y.iter().zip(scratch.y()).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "round {round} ({sched:?}) row {i}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn spmv_partitioned_reuses_prebuilt_structures() {
        // The satellite fix: a repeatedly-executed tile/chunk
        // partition no longer converts per call when the caller hands
        // its memoized structure — and a *mismatched* prebuilt (wrong
        // tile size / chunk height) is ignored, not trusted.
        let mut rng = Pcg32::new(0x9B17);
        let csr = random_csr(&mut rng, 300, 6);
        let x: Vec<f64> = (0..300).map(|_| rng.gen_f64()).collect();
        let want = spmv_sequential(&csr, &x).y;
        let part = partition(&csr, Schedule::Csr5Tiles { tile_nnz: 32 }, 4);
        let good = Csr5::from_csr(&csr, 32);
        let wrong = Csr5::from_csr(&csr, 64);
        for prebuilt in [
            Prebuilt::default(),
            Prebuilt { csr5: Some(&good), sell: None },
            Prebuilt { csr5: Some(&wrong), sell: None },
        ] {
            let got = spmv_partitioned(None, &csr, &x, &part, prebuilt);
            assert_close(&got.y, &want);
        }
        let part =
            partition(&csr, Schedule::SellChunks { c: 8, sigma: 32 }, 4);
        let good = SellCSigma::from_csr(&csr, 8, 32);
        let wrong = SellCSigma::from_csr(&csr, 4, 32);
        for prebuilt in [
            Prebuilt::default(),
            Prebuilt { csr5: None, sell: Some(&good) },
            Prebuilt { csr5: None, sell: Some(&wrong) },
        ] {
            let got = spmv_partitioned(None, &csr, &x, &part, prebuilt);
            assert_close(&got.y, &want);
        }
    }

    #[test]
    fn pooled_matches_spawn_and_sequential() {
        let mut rng = Pcg32::new(0xB001);
        let csr = random_csr(&mut rng, 400, 5);
        let x: Vec<f64> = (0..400).map(|_| rng.gen_f64()).collect();
        let want = spmv_sequential(&csr, &x).y;
        let pool = ExecPool::new(4);
        for sched in [
            Schedule::CsrRowStatic,
            Schedule::CsrRowBalanced,
            Schedule::Csr5Tiles { tile_nnz: 32 },
            Schedule::CsrDynamic { chunk: 16 },
            Schedule::SellChunks { c: 8, sigma: 64 },
        ] {
            for nt in [1, 3, 8] {
                let pooled =
                    spmv_threaded_on(Some(&pool), &csr, &x, sched, nt);
                let spawned = spmv_threaded(&csr, &x, sched, nt);
                assert_close(&pooled.y, &want);
                assert_close(&pooled.y, &spawned.y);
                assert_eq!(pooled.threads, spawned.threads, "{sched:?}");
            }
        }
        assert_eq!(pool.n_workers(), 4, "pool must not grow");
    }

    #[test]
    fn empty_partition_slots_are_skipped() {
        // More threads than rows: the surplus slots have no rows and
        // must neither spawn nor count toward effective parallelism.
        let csr = Csr::identity(3);
        let x = vec![1.0; 3];
        for sched in [
            Schedule::CsrRowStatic,
            Schedule::CsrRowBalanced,
            Schedule::CsrDynamic { chunk: 1 },
            Schedule::SellChunks { c: 1, sigma: 1 },
        ] {
            let r = spmv_threaded(&csr, &x, sched, 8);
            assert_eq!(r.y, vec![1.0; 3], "{sched:?}");
            assert!(
                r.threads <= 3,
                "{sched:?}: {} effective workers for 3 rows",
                r.threads
            );
        }
        let s = spmm_threaded(&csr, &x, 1, Schedule::CsrRowStatic, 8);
        assert!(s.threads <= 3, "spmm: {} workers for 3 rows", s.threads);
        assert_close(&s.y, &x);
    }

    #[test]
    fn csr5_boundary_rows_merge() {
        // One long row spanning multiple threads' tile ranges: every
        // thread contributes a carry to the same row.
        let n = 64;
        let mut coo = Coo::new(n, n);
        for c in 0..n {
            coo.push(0, c, 1.0);
        }
        let csr = coo.to_csr();
        let x = vec![1.0; n];
        let got = spmv_threaded(
            &csr,
            &x,
            Schedule::Csr5Tiles { tile_nnz: 4 },
            4,
        );
        assert_eq!(got.y[0], n as f64);
        assert!(got.y[1..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn property_threaded_matches_sequential() {
        check("threaded==sequential", 25, |rng| {
            let n = 16 + rng.gen_range(200);
            let csr = random_csr(rng, n, 4);
            let x: Vec<f64> = (0..n).map(|_| rng.gen_f64()).collect();
            let want = spmv_sequential(&csr, &x).y;
            let nt = 1 + rng.gen_range(8);
            let sched = match rng.gen_range(5) {
                0 => Schedule::CsrRowStatic,
                1 => Schedule::CsrRowBalanced,
                2 => Schedule::Csr5Tiles { tile_nnz: 1 + rng.gen_range(64) },
                3 => Schedule::SellChunks {
                    c: 1 + rng.gen_range(32),
                    sigma: 1 + rng.gen_range(128),
                },
                _ => Schedule::CsrDynamic { chunk: 1 + rng.gen_range(32) },
            };
            let got = spmv_threaded(&csr, &x, sched, nt);
            for (i, (p, q)) in got.y.iter().zip(&want).enumerate() {
                prop_assert!(
                    (p - q).abs() < 1e-9 * (1.0 + p.abs()),
                    "row {i}: {p} vs {q} under {sched:?} nt={nt}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn empty_matrix() {
        let csr = Csr::zero(10, 10);
        let x = vec![1.0; 10];
        let r = spmv_threaded(&csr, &x, Schedule::CsrRowStatic, 4);
        assert!(r.y.iter().all(|&v| v == 0.0));
        let r = spmv_threaded(
            &csr,
            &x,
            Schedule::SellChunks { c: 4, sigma: 8 },
            4,
        );
        assert!(r.y.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn gflops_positive() {
        let mut rng = Pcg32::new(1);
        let csr = random_csr(&mut rng, 256, 8);
        let x = vec![1.0; 256];
        let r = spmv_threaded(&csr, &x, Schedule::CsrRowStatic, 2);
        assert!(r.gflops(csr.nnz()) > 0.0);
    }

    #[test]
    fn per_request_ms_normalizes_by_batch() {
        let r = ExecResult { y: vec![], wall_seconds: 0.002, threads: 1 };
        assert!((r.per_request_ms() - 2.0).abs() < 1e-12);
        let s = SpmmResult {
            y: vec![],
            n_rows: 0,
            batch: 4,
            wall_seconds: 0.002,
            threads: 2,
            schedule: Schedule::CsrRowStatic,
        };
        assert!((s.per_request_ms() - 0.5).abs() < 1e-12);
        let st = ExecStats { wall_seconds: 0.002, threads: 1 };
        assert!((st.per_request_ms() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn per_request_ms_guards_batch_zero() {
        // A hand-built batch-0 result (nothing was served) must not
        // divide by zero — the satellite's unspecified-behavior pin.
        let s = SpmmResult {
            y: vec![],
            n_rows: 0,
            batch: 0,
            wall_seconds: 0.002,
            threads: 1,
            schedule: Schedule::CsrRowStatic,
        };
        assert!((s.per_request_ms() - 2.0).abs() < 1e-12);
        assert!(s.per_request_ms().is_finite());
        let st = SpmmStats {
            n_rows: 0,
            batch: 0,
            wall_seconds: 0.002,
            threads: 1,
            schedule: Schedule::CsrRowStatic,
        };
        assert!(st.per_request_ms().is_finite());
    }

    #[test]
    fn gflops_guard_zero_wall_time() {
        let r = ExecResult { y: vec![], wall_seconds: 0.0, threads: 1 };
        assert_eq!(r.gflops(1_000_000), 0.0);
        let s = SpmmResult {
            y: vec![],
            n_rows: 0,
            batch: 4,
            wall_seconds: 0.0,
            threads: 1,
            schedule: Schedule::CsrRowStatic,
        };
        assert_eq!(s.gflops(1_000_000), 0.0);
        assert!(s.gflops(1_000_000).is_finite());
    }

    fn random_vectors(rng: &mut Pcg32, n: usize, batch: usize) -> Vec<Vec<f64>> {
        (0..batch)
            .map(|_| (0..n).map(|_| rng.gen_f64() - 0.5).collect())
            .collect()
    }

    #[test]
    fn spmm_matches_per_vector_spmv() {
        let mut rng = Pcg32::new(0x5B33);
        let csr = random_csr(&mut rng, 300, 5);
        // Batch sizes straddling the column block width.
        for batch in [1usize, 2, 7, 8, 9, 16] {
            let vectors = random_vectors(&mut rng, 300, batch);
            let xs = pack_vectors(&vectors);
            for sched in [
                Schedule::CsrRowStatic,
                Schedule::CsrRowBalanced,
                Schedule::CsrDynamic { chunk: 16 },
                Schedule::Csr5Tiles { tile_nnz: 32 }, // remapped to rows
                Schedule::SellChunks { c: 8, sigma: 32 }, // remapped too
            ] {
                for nt in [1, 3, 4] {
                    let got = spmm_threaded(&csr, &xs, batch, sched, nt);
                    assert_eq!(got.batch, batch);
                    for (j, x) in vectors.iter().enumerate() {
                        let want = spmv_sequential(&csr, x).y;
                        // Shared accumulation discipline: the batched
                        // kernel reproduces the reference bitwise.
                        let col = got.column(j);
                        for (i, (a, b)) in want.iter().zip(&col).enumerate()
                        {
                            assert_eq!(
                                a.to_bits(),
                                b.to_bits(),
                                "{sched:?} b{batch} nt{nt} col {j} \
                                 row {i}: {a} vs {b}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn spmm_into_matches_allocating_path() {
        let mut rng = Pcg32::new(0x5B37);
        let pool = ExecPool::new(3);
        let mut scratch = Scratch::new();
        for batch in [1usize, 3, 8, 11] {
            let csr = random_csr(&mut rng, 200, 5);
            let vectors = random_vectors(&mut rng, 200, batch);
            let refs: Vec<&[f64]> =
                vectors.iter().map(|v| v.as_slice()).collect();
            let xs = pack_vectors(&vectors);
            let part =
                partition(&csr, Schedule::CsrRowBalanced, 4);
            let per_thread = match part {
                Partition::Rows { per_thread } => per_thread,
                _ => unreachable!(),
            };
            let alloc = spmm_partitioned(
                Some(&pool),
                &csr,
                &xs,
                batch,
                &per_thread,
                Schedule::CsrRowBalanced,
            );
            let stats = spmm_into(
                Some(&pool),
                &csr,
                &refs,
                &per_thread,
                Schedule::CsrRowBalanced,
                &mut scratch,
            );
            assert_eq!(stats.threads, alloc.threads);
            assert_eq!(stats.batch, alloc.batch);
            assert_eq!(stats.schedule, alloc.schedule);
            assert_eq!(scratch.y_batch().len(), alloc.y.len());
            for (i, (a, b)) in
                alloc.y.iter().zip(scratch.y_batch()).enumerate()
            {
                assert_eq!(a.to_bits(), b.to_bits(), "element {i}");
            }
            for j in 0..batch {
                assert_eq!(
                    scratch.batch_column(200, batch, j),
                    alloc.column(j)
                );
            }
        }
    }

    #[test]
    fn spmm_records_effective_schedule() {
        let mut rng = Pcg32::new(0x5B35);
        let csr = random_csr(&mut rng, 128, 4);
        let xs = vec![1.0; 128 * 2];
        let tiled = spmm_threaded(
            &csr,
            &xs,
            2,
            Schedule::Csr5Tiles { tile_nnz: 32 },
            4,
        );
        assert_eq!(
            tiled.schedule,
            Schedule::CsrRowBalanced,
            "tile plans remap to the balanced row schedule for SpMM"
        );
        let rows = spmm_threaded(&csr, &xs, 2, Schedule::CsrRowStatic, 4);
        assert_eq!(rows.schedule, Schedule::CsrRowStatic);
        assert_eq!(
            effective_spmm_schedule(Schedule::Csr5Tiles { tile_nnz: 7 }),
            Schedule::CsrRowBalanced
        );
        assert_eq!(
            effective_spmm_schedule(Schedule::SellChunks { c: 8, sigma: 64 }),
            Schedule::CsrRowBalanced,
            "SELL chunk plans remap for SpMM too"
        );
        assert_eq!(
            effective_spmm_schedule(Schedule::CsrDynamic { chunk: 4 }),
            Schedule::CsrDynamic { chunk: 4 }
        );
    }

    #[test]
    fn spmm_pooled_matches_spawn() {
        let mut rng = Pcg32::new(0x5B36);
        let csr = random_csr(&mut rng, 200, 5);
        let pool = ExecPool::new(3);
        for batch in [1usize, 7, 8, 9] {
            let vectors = random_vectors(&mut rng, 200, batch);
            let xs = pack_vectors(&vectors);
            let pooled = spmm_threaded_on(
                Some(&pool),
                &csr,
                &xs,
                batch,
                Schedule::CsrRowBalanced,
                4,
            );
            let spawned = spmm_threaded(
                &csr,
                &xs,
                batch,
                Schedule::CsrRowBalanced,
                4,
            );
            assert_close(&pooled.y, &spawned.y);
            assert_eq!(pooled.threads, spawned.threads);
            assert_eq!(pooled.schedule, spawned.schedule);
        }
    }

    #[test]
    fn spmm_sequential_matches_threaded() {
        let mut rng = Pcg32::new(0x5B34);
        let csr = random_csr(&mut rng, 200, 6);
        let vectors = random_vectors(&mut rng, 200, 5);
        let xs = pack_vectors(&vectors);
        let seq = spmm_sequential(&csr, &xs, 5);
        let par = spmm_threaded(&csr, &xs, 5, Schedule::CsrRowBalanced, 4);
        assert_close(&seq.y, &par.y);
        assert_eq!(seq.threads, 1);
        assert!(seq.gflops(csr.nnz()) > 0.0);
    }

    #[test]
    fn spmm_empty_matrix() {
        let csr = Csr::zero(10, 10);
        let xs = vec![1.0; 10 * 3];
        let r = spmm_threaded(&csr, &xs, 3, Schedule::CsrRowStatic, 4);
        assert!(r.y.iter().all(|&v| v == 0.0));
        assert_eq!(r.y.len(), 30);
    }

    #[test]
    fn pack_vectors_interleaves() {
        let xs = pack_vectors(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        // x[i * batch + j]: element i of vector j.
        assert_eq!(xs, vec![1.0, 3.0, 2.0, 4.0]);
        // The reusing variant overwrites whatever the buffer held.
        let mut buf = vec![9.0; 17];
        pack_vectors_into(&[vec![5.0], vec![6.0], vec![7.0]], &mut buf);
        assert_eq!(buf, vec![5.0, 6.0, 7.0]);
    }

    #[test]
    #[should_panic(expected = "vector length mismatch")]
    fn pack_vectors_panics_on_ragged_lengths() {
        // The satellite pin: ragged inputs are a programmer error and
        // must fail loudly (the serving engine validates lengths
        // before packing, so traffic can never reach this).
        let _ = pack_vectors(&[vec![1.0, 2.0], vec![3.0]]);
    }

    #[test]
    #[should_panic(expected = "need at least one vector")]
    fn pack_vectors_panics_on_empty_batch() {
        let _ = pack_vectors::<Vec<f64>>(&[]);
    }
}
