//! Native threaded SpMV executors — the functional compute path for
//! arbitrary shapes (the PJRT artifacts cover the bucketed shapes; see
//! `runtime`). Also used to wall-clock the host in the §Perf benches.
//!
//! Threads write disjoint row ranges of `y`; the only cross-thread
//! rows are CSR5 range-boundary carries, which are merged by the
//! calling thread after the join (exactly the CSR5 algorithm's
//! cross-thread reduction step).
//!
//! Every executor comes in two dispatch modes behind one entry point:
//! handed a [`pool::ExecPool`] it runs on the pool's resident workers
//! (the serving hot path — no per-request thread spawn); handed
//! `None` it falls back to `std::thread::scope` (one-shot CLI and
//! bench paths where a resident pool has nothing to amortize).
//! Partition slots with no rows are skipped in both modes, and the
//! result reports the *effective* worker count, so scalability curves
//! at `n_threads > n_rows` aren't skewed by idle spawns.

pub mod pool;

pub use pool::ExecPool;

use std::time::Instant;

use crate::sched::{partition, Partition, Schedule};
use crate::sparse::csr5::TileCarry;
use crate::sparse::{Csr, Csr5};

/// Result of one threaded SpMV execution.
#[derive(Clone, Debug)]
pub struct ExecResult {
    pub y: Vec<f64>,
    pub wall_seconds: f64,
    /// Effective parallelism: workers that had nonempty row/tile
    /// ranges (not the configured thread count).
    pub threads: usize,
}

impl ExecResult {
    /// Achieved Gflops; 0 when the timer resolved to zero (tiny
    /// kernels on coarse clocks must not report `inf`).
    pub fn gflops(&self, nnz: usize) -> f64 {
        if self.wall_seconds > 0.0 {
            2.0 * nnz as f64 / self.wall_seconds / 1e9
        } else {
            0.0
        }
    }

    /// Measured latency of the one request this execution served, in
    /// milliseconds — the autotuner's observation unit.
    pub fn per_request_ms(&self) -> f64 {
        self.wall_seconds * 1e3
    }
}

/// Disjoint-range mutable view for concurrent slot workers.
///
/// SAFETY: callers must hand each slot writes that do not overlap
/// with any other slot's — guaranteed by `Partition::validate`, which
/// rejects double-covered rows, and by slot-indexed output cells.
struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// A row-range list that carries at least one row — the slot filter
/// shared by the executors and by `Plan::effective_threads`, so the
/// replay cost model can never drift from what execution reports.
fn slot_has_rows(ranges: &[(usize, usize)]) -> bool {
    ranges.iter().any(|&(r0, r1)| r1 > r0)
}

/// Effective parallelism of a row partition: slots that carry work,
/// floored at 1 (what `ExecResult.threads`/`SpmmResult.threads`
/// report).
pub fn effective_row_slots(per_thread: &[Vec<(usize, usize)>]) -> usize {
    per_thread
        .iter()
        .filter(|ranges| slot_has_rows(ranges))
        .count()
        .max(1)
}

/// Effective parallelism of a tile partition, floored at 1.
pub fn effective_tile_slots(per_thread: &[(usize, usize)]) -> usize {
    per_thread.iter().filter(|&&(t0, t1)| t1 > t0).count().max(1)
}

/// Run `work(slot)` for every slot: on the pool's resident workers
/// when one is supplied, otherwise on freshly scoped threads (the
/// one-shot fallback). Returns once every slot completed.
fn dispatch(
    pool: Option<&ExecPool>,
    n_slots: usize,
    work: &(dyn Fn(usize) + Sync),
) {
    match pool {
        Some(p) => p.run(n_slots, work),
        None => match n_slots {
            0 => {}
            1 => work(0),
            _ => {
                std::thread::scope(|s| {
                    for i in 0..n_slots {
                        s.spawn(move || work(i));
                    }
                });
            }
        },
    }
}

/// Multi-threaded CSR SpMV under any row partition (spawn fallback;
/// see [`spmv_threaded_on`] for the pooled serving path).
pub fn spmv_threaded(
    csr: &Csr,
    x: &[f64],
    schedule: Schedule,
    n_threads: usize,
) -> ExecResult {
    spmv_threaded_on(None, csr, x, schedule, n_threads)
}

/// Multi-threaded CSR SpMV: partition under `schedule`, then execute
/// on `pool` (or scoped threads when `None`).
pub fn spmv_threaded_on(
    pool: Option<&ExecPool>,
    csr: &Csr,
    x: &[f64],
    schedule: Schedule,
    n_threads: usize,
) -> ExecResult {
    assert_eq!(x.len(), csr.n_cols);
    let part = partition(csr, schedule, n_threads);
    debug_assert!(part.validate(csr).is_ok());
    spmv_partitioned(pool, csr, x, &part)
}

/// Execute a *pre-materialized* partition — the serving hot path:
/// plans memoize their partition at build time and requests skip the
/// (prefix-bisection / tiling) partitioning work entirely.
pub fn spmv_partitioned(
    pool: Option<&ExecPool>,
    csr: &Csr,
    x: &[f64],
    part: &Partition,
) -> ExecResult {
    match part {
        Partition::Rows { per_thread } => {
            spmv_rows_on(pool, csr, x, per_thread)
        }
        Partition::Tiles { tile_nnz, per_thread } => {
            let csr5 = Csr5::from_csr(csr, *tile_nnz);
            spmv_csr5_on(pool, &csr5, x, per_thread)
        }
    }
}

/// CSR SpMV over explicit per-slot row ranges. Slots with no rows are
/// skipped; `threads` reports the effective worker count.
pub fn spmv_rows_on(
    pool: Option<&ExecPool>,
    csr: &Csr,
    x: &[f64],
    per_thread: &[Vec<(usize, usize)>],
) -> ExecResult {
    assert_eq!(x.len(), csr.n_cols);
    let active: Vec<&[(usize, usize)]> = per_thread
        .iter()
        .map(|ranges| ranges.as_slice())
        .filter(|ranges| slot_has_rows(ranges))
        .collect();
    let mut y = vec![0.0f64; csr.n_rows];
    let ptr = SendPtr(y.as_mut_ptr());
    let t0 = Instant::now();
    let work = |slot: usize| {
        // SAFETY: ranges are disjoint across slots
        // (Partition::validate) — each y[r] is written by exactly
        // one worker.
        let yslice =
            unsafe { std::slice::from_raw_parts_mut(ptr.0, csr.n_rows) };
        for &(r0, r1) in active[slot] {
            csr.spmv_rows(r0, r1, x, yslice);
        }
    };
    dispatch(pool, active.len(), &work);
    ExecResult {
        y,
        wall_seconds: t0.elapsed().as_secs_f64(),
        threads: active.len().max(1),
    }
}

/// Multi-threaded CSR5 SpMV over tile ranges, with post-join carry
/// merge (spawn fallback; see [`spmv_csr5_on`]).
pub fn spmv_csr5_threaded(
    csr5: &Csr5,
    x: &[f64],
    per_thread: &[(usize, usize)],
) -> ExecResult {
    spmv_csr5_on(None, csr5, x, per_thread)
}

/// CSR5 SpMV over tile ranges on an optional pool. Empty tile ranges
/// are skipped; boundary-row carries are merged by the calling thread
/// after the latch (the CSR5 cross-thread reduction step).
pub fn spmv_csr5_on(
    pool: Option<&ExecPool>,
    csr5: &Csr5,
    x: &[f64],
    per_thread: &[(usize, usize)],
) -> ExecResult {
    let active: Vec<(usize, usize)> = per_thread
        .iter()
        .copied()
        .filter(|&(t0, t1)| t1 > t0)
        .collect();
    let mut y = vec![0.0f64; csr5.n_rows];
    let mut carries: Vec<Vec<TileCarry>> = vec![Vec::new(); active.len()];
    let yptr = SendPtr(y.as_mut_ptr());
    let cptr = SendPtr(carries.as_mut_ptr());
    let t0 = Instant::now();
    let work = |slot: usize| {
        // SAFETY: spmv_tiles writes only rows fully contained in its
        // tile range; boundary rows come back as carries. Each slot
        // writes its own carries cell.
        let yslice =
            unsafe { std::slice::from_raw_parts_mut(yptr.0, csr5.n_rows) };
        let (a, b) = active[slot];
        let got = csr5.spmv_tiles(a, b, x, yslice);
        unsafe { *cptr.0.add(slot) = got };
    };
    dispatch(pool, active.len(), &work);
    for cs in &carries {
        for c in cs {
            y[c.row] += c.value;
        }
    }
    ExecResult {
        y,
        wall_seconds: t0.elapsed().as_secs_f64(),
        threads: active.len().max(1),
    }
}

/// Sequential reference execution (wrapped for timing symmetry).
pub fn spmv_sequential(csr: &Csr, x: &[f64]) -> ExecResult {
    let mut y = vec![0.0f64; csr.n_rows];
    let t0 = Instant::now();
    csr.spmv(x, &mut y);
    ExecResult { y, wall_seconds: t0.elapsed().as_secs_f64(), threads: 1 }
}

/// Width of one column block of the batched-vector SpMM kernel: the
/// accumulator tile lives in registers, and every nonzero of `A` is
/// loaded once per block instead of once per vector.
pub const SPMM_COL_BLOCK: usize = 8;

/// Result of one batched (multi-vector) SpMM execution:
/// `Y = A X` for a block of `batch` dense vectors.
#[derive(Clone, Debug)]
pub struct SpmmResult {
    /// Vector-interleaved outputs: `y[r * batch + j]` is row `r` of
    /// output vector `j` (same layout as the `xs` input).
    pub y: Vec<f64>,
    pub n_rows: usize,
    pub batch: usize,
    pub wall_seconds: f64,
    /// Effective parallelism (workers with nonempty row ranges).
    pub threads: usize,
    /// The schedule that actually executed. Tile (CSR5) plans remap
    /// to [`Schedule::CsrRowBalanced`] for multi-vector batches —
    /// telemetry reports this field, not the plan's nominal schedule,
    /// so replay tables stop attributing SpMM throughput to CSR5.
    pub schedule: Schedule,
}

impl SpmmResult {
    /// Extract output vector `j` as a contiguous `Vec`.
    pub fn column(&self, j: usize) -> Vec<f64> {
        assert!(j < self.batch);
        (0..self.n_rows).map(|r| self.y[r * self.batch + j]).collect()
    }

    /// Achieved Gflops; 0 when the timer resolved to zero.
    pub fn gflops(&self, nnz: usize) -> f64 {
        if self.wall_seconds > 0.0 {
            2.0 * nnz as f64 * self.batch as f64 / self.wall_seconds / 1e9
        } else {
            0.0
        }
    }

    /// Measured per-request share of this coalesced dispatch, in
    /// milliseconds — what the autotuner records per served vector so
    /// batched and singleton observations stay comparable.
    pub fn per_request_ms(&self) -> f64 {
        self.wall_seconds * 1e3 / self.batch.max(1) as f64
    }
}

/// Interleave a slice of equal-length vectors into the
/// `xs[i * batch + j]` layout the SpMM kernels consume.
pub fn pack_vectors<T: AsRef<[f64]>>(vectors: &[T]) -> Vec<f64> {
    let batch = vectors.len();
    assert!(batch > 0, "need at least one vector");
    let n = vectors[0].as_ref().len();
    let mut xs = vec![0.0f64; n * batch];
    for (j, v) in vectors.iter().enumerate() {
        let v = v.as_ref();
        assert_eq!(v.len(), n, "vector length mismatch");
        for (i, &val) in v.iter().enumerate() {
            xs[i * batch + j] = val;
        }
    }
    xs
}

/// The column-blocked SpMM inner kernel over a row range: for each
/// block of `SPMM_COL_BLOCK` vectors, each nonzero `A[r,c]` is read
/// once and multiplied against the block's contiguous slice of `x`
/// row `c` — the batched-serving analog of the CSR row kernel.
fn spmm_rows_blocked(
    csr: &Csr,
    xs: &[f64],
    batch: usize,
    r0: usize,
    r1: usize,
    y: &mut [f64],
) {
    let mut jb = 0;
    while jb < batch {
        let bw = (batch - jb).min(SPMM_COL_BLOCK);
        let mut acc = [0.0f64; SPMM_COL_BLOCK];
        for r in r0..r1 {
            acc[..bw].fill(0.0);
            for i in csr.ptr[r]..csr.ptr[r + 1] {
                let a = csr.data[i];
                let xoff = csr.indices[i] as usize * batch + jb;
                for (t, slot) in acc[..bw].iter_mut().enumerate() {
                    *slot += a * xs[xoff + t];
                }
            }
            let yoff = r * batch + jb;
            y[yoff..yoff + bw].copy_from_slice(&acc[..bw]);
        }
        jb += bw;
    }
}

/// The row-space schedule a batched SpMM actually runs under. Tile
/// (CSR5) schedules have no multi-vector kernel; they remap to
/// `CsrRowBalanced`, the row-space schedule with the same
/// load-balancing intent.
pub fn effective_spmm_schedule(schedule: Schedule) -> Schedule {
    match schedule {
        Schedule::Csr5Tiles { .. } => Schedule::CsrRowBalanced,
        s => s,
    }
}

/// Multi-threaded batched SpMM: `Y = A X` for `batch` interleaved
/// vectors (`xs[i * batch + j]`), threads over row partitions (spawn
/// fallback; see [`spmm_threaded_on`]).
pub fn spmm_threaded(
    csr: &Csr,
    xs: &[f64],
    batch: usize,
    schedule: Schedule,
    n_threads: usize,
) -> SpmmResult {
    spmm_threaded_on(None, csr, xs, batch, schedule, n_threads)
}

/// Batched SpMM on an optional pool: partition under the effective
/// (row-space) schedule, then execute.
pub fn spmm_threaded_on(
    pool: Option<&ExecPool>,
    csr: &Csr,
    xs: &[f64],
    batch: usize,
    schedule: Schedule,
    n_threads: usize,
) -> SpmmResult {
    let schedule = effective_spmm_schedule(schedule);
    let part = partition(csr, schedule, n_threads);
    debug_assert!(part.validate(csr).is_ok());
    let per_thread = match part {
        Partition::Rows { per_thread } => per_thread,
        Partition::Tiles { .. } => unreachable!("tile schedules remapped"),
    };
    spmm_partitioned(pool, csr, xs, batch, &per_thread, schedule)
}

/// Batched SpMM over a *pre-materialized* row partition — the serving
/// hot path (plans memoize `per_thread` at build time). `schedule` is
/// recorded on the result as the effective executed schedule.
pub fn spmm_partitioned(
    pool: Option<&ExecPool>,
    csr: &Csr,
    xs: &[f64],
    batch: usize,
    per_thread: &[Vec<(usize, usize)>],
    schedule: Schedule,
) -> SpmmResult {
    assert!(batch > 0, "batch must be >= 1");
    assert_eq!(xs.len(), csr.n_cols * batch, "xs length != n_cols * batch");
    let active: Vec<&[(usize, usize)]> = per_thread
        .iter()
        .map(|ranges| ranges.as_slice())
        .filter(|ranges| slot_has_rows(ranges))
        .collect();
    let mut y = vec![0.0f64; csr.n_rows * batch];
    let ptr = SendPtr(y.as_mut_ptr());
    let t0 = Instant::now();
    let work = |slot: usize| {
        // SAFETY: row ranges are disjoint across slots
        // (Partition::validate), and row r owns the disjoint slice
        // y[r*batch .. (r+1)*batch].
        let yslice = unsafe {
            std::slice::from_raw_parts_mut(ptr.0, csr.n_rows * batch)
        };
        for &(r0, r1) in active[slot] {
            spmm_rows_blocked(csr, xs, batch, r0, r1, yslice);
        }
    };
    dispatch(pool, active.len(), &work);
    SpmmResult {
        y,
        n_rows: csr.n_rows,
        batch,
        wall_seconds: t0.elapsed().as_secs_f64(),
        threads: active.len().max(1),
        schedule,
    }
}

/// Sequential batched SpMM reference (timing symmetry with
/// [`spmv_sequential`]).
pub fn spmm_sequential(csr: &Csr, xs: &[f64], batch: usize) -> SpmmResult {
    assert!(batch > 0, "batch must be >= 1");
    assert_eq!(xs.len(), csr.n_cols * batch, "xs length != n_cols * batch");
    let mut y = vec![0.0f64; csr.n_rows * batch];
    let t0 = Instant::now();
    spmm_rows_blocked(csr, xs, batch, 0, csr.n_rows, &mut y);
    SpmmResult {
        y,
        n_rows: csr.n_rows,
        batch,
        wall_seconds: t0.elapsed().as_secs_f64(),
        threads: 1,
        schedule: Schedule::CsrRowStatic,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use crate::util::testkit::check;
    use crate::{prop_assert, sparse::Coo};

    fn random_csr(rng: &mut Pcg32, n: usize, per_row: usize) -> Csr {
        let mut coo = Coo::new(n, n);
        for r in 0..n {
            let deg = rng.gen_range(per_row * 2 + 1);
            for c in rng.sample_distinct(n, deg.min(n)) {
                coo.push(r, c, rng.gen_f64() - 0.5);
            }
        }
        coo.to_csr()
    }

    fn assert_close(a: &[f64], b: &[f64]) {
        assert_eq!(a.len(), b.len());
        for (i, (p, q)) in a.iter().zip(b).enumerate() {
            assert!(
                (p - q).abs() < 1e-9 * (1.0 + p.abs()),
                "row {i}: {p} vs {q}"
            );
        }
    }

    #[test]
    fn all_schedules_match_sequential() {
        let mut rng = Pcg32::new(0xE8EC);
        let csr = random_csr(&mut rng, 500, 6);
        let x: Vec<f64> = (0..500).map(|_| rng.gen_f64()).collect();
        let want = spmv_sequential(&csr, &x).y;
        for sched in [
            Schedule::CsrRowStatic,
            Schedule::CsrRowBalanced,
            Schedule::Csr5Tiles { tile_nnz: 32 },
            Schedule::CsrDynamic { chunk: 16 },
        ] {
            for nt in [1, 2, 3, 4, 8] {
                let got = spmv_threaded(&csr, &x, sched, nt);
                assert_close(&got.y, &want);
                assert_eq!(got.threads, nt);
            }
        }
    }

    #[test]
    fn pooled_matches_spawn_and_sequential() {
        let mut rng = Pcg32::new(0xB001);
        let csr = random_csr(&mut rng, 400, 5);
        let x: Vec<f64> = (0..400).map(|_| rng.gen_f64()).collect();
        let want = spmv_sequential(&csr, &x).y;
        let pool = ExecPool::new(4);
        for sched in [
            Schedule::CsrRowStatic,
            Schedule::CsrRowBalanced,
            Schedule::Csr5Tiles { tile_nnz: 32 },
            Schedule::CsrDynamic { chunk: 16 },
        ] {
            for nt in [1, 3, 8] {
                let pooled =
                    spmv_threaded_on(Some(&pool), &csr, &x, sched, nt);
                let spawned = spmv_threaded(&csr, &x, sched, nt);
                assert_close(&pooled.y, &want);
                assert_close(&pooled.y, &spawned.y);
                assert_eq!(pooled.threads, spawned.threads, "{sched:?}");
            }
        }
        assert_eq!(pool.n_workers(), 4, "pool must not grow");
    }

    #[test]
    fn empty_partition_slots_are_skipped() {
        // More threads than rows: the surplus slots have no rows and
        // must neither spawn nor count toward effective parallelism.
        let csr = Csr::identity(3);
        let x = vec![1.0; 3];
        for sched in [
            Schedule::CsrRowStatic,
            Schedule::CsrRowBalanced,
            Schedule::CsrDynamic { chunk: 1 },
        ] {
            let r = spmv_threaded(&csr, &x, sched, 8);
            assert_eq!(r.y, vec![1.0; 3], "{sched:?}");
            assert!(
                r.threads <= 3,
                "{sched:?}: {} effective workers for 3 rows",
                r.threads
            );
        }
        let s = spmm_threaded(&csr, &x, 1, Schedule::CsrRowStatic, 8);
        assert!(s.threads <= 3, "spmm: {} workers for 3 rows", s.threads);
        assert_close(&s.y, &x);
    }

    #[test]
    fn csr5_boundary_rows_merge() {
        // One long row spanning multiple threads' tile ranges: every
        // thread contributes a carry to the same row.
        let n = 64;
        let mut coo = Coo::new(n, n);
        for c in 0..n {
            coo.push(0, c, 1.0);
        }
        let csr = coo.to_csr();
        let x = vec![1.0; n];
        let got = spmv_threaded(
            &csr,
            &x,
            Schedule::Csr5Tiles { tile_nnz: 4 },
            4,
        );
        assert_eq!(got.y[0], n as f64);
        assert!(got.y[1..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn property_threaded_matches_sequential() {
        check("threaded==sequential", 25, |rng| {
            let n = 16 + rng.gen_range(200);
            let csr = random_csr(rng, n, 4);
            let x: Vec<f64> = (0..n).map(|_| rng.gen_f64()).collect();
            let want = spmv_sequential(&csr, &x).y;
            let nt = 1 + rng.gen_range(8);
            let sched = match rng.gen_range(4) {
                0 => Schedule::CsrRowStatic,
                1 => Schedule::CsrRowBalanced,
                2 => Schedule::Csr5Tiles { tile_nnz: 1 + rng.gen_range(64) },
                _ => Schedule::CsrDynamic { chunk: 1 + rng.gen_range(32) },
            };
            let got = spmv_threaded(&csr, &x, sched, nt);
            for (i, (p, q)) in got.y.iter().zip(&want).enumerate() {
                prop_assert!(
                    (p - q).abs() < 1e-9 * (1.0 + p.abs()),
                    "row {i}: {p} vs {q} under {sched:?} nt={nt}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn empty_matrix() {
        let csr = Csr::zero(10, 10);
        let x = vec![1.0; 10];
        let r = spmv_threaded(&csr, &x, Schedule::CsrRowStatic, 4);
        assert!(r.y.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn gflops_positive() {
        let mut rng = Pcg32::new(1);
        let csr = random_csr(&mut rng, 256, 8);
        let x = vec![1.0; 256];
        let r = spmv_threaded(&csr, &x, Schedule::CsrRowStatic, 2);
        assert!(r.gflops(csr.nnz()) > 0.0);
    }

    #[test]
    fn per_request_ms_normalizes_by_batch() {
        let r = ExecResult { y: vec![], wall_seconds: 0.002, threads: 1 };
        assert!((r.per_request_ms() - 2.0).abs() < 1e-12);
        let s = SpmmResult {
            y: vec![],
            n_rows: 0,
            batch: 4,
            wall_seconds: 0.002,
            threads: 2,
            schedule: Schedule::CsrRowStatic,
        };
        assert!((s.per_request_ms() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn gflops_guard_zero_wall_time() {
        let r = ExecResult { y: vec![], wall_seconds: 0.0, threads: 1 };
        assert_eq!(r.gflops(1_000_000), 0.0);
        let s = SpmmResult {
            y: vec![],
            n_rows: 0,
            batch: 4,
            wall_seconds: 0.0,
            threads: 1,
            schedule: Schedule::CsrRowStatic,
        };
        assert_eq!(s.gflops(1_000_000), 0.0);
        assert!(s.gflops(1_000_000).is_finite());
    }

    fn random_vectors(rng: &mut Pcg32, n: usize, batch: usize) -> Vec<Vec<f64>> {
        (0..batch)
            .map(|_| (0..n).map(|_| rng.gen_f64() - 0.5).collect())
            .collect()
    }

    #[test]
    fn spmm_matches_per_vector_spmv() {
        let mut rng = Pcg32::new(0x5B33);
        let csr = random_csr(&mut rng, 300, 5);
        // Batch sizes straddling the column block width.
        for batch in [1usize, 2, 7, 8, 9, 16] {
            let vectors = random_vectors(&mut rng, 300, batch);
            let xs = pack_vectors(&vectors);
            for sched in [
                Schedule::CsrRowStatic,
                Schedule::CsrRowBalanced,
                Schedule::CsrDynamic { chunk: 16 },
                Schedule::Csr5Tiles { tile_nnz: 32 }, // remapped to rows
            ] {
                for nt in [1, 3, 4] {
                    let got = spmm_threaded(&csr, &xs, batch, sched, nt);
                    assert_eq!(got.batch, batch);
                    for (j, x) in vectors.iter().enumerate() {
                        let want = spmv_sequential(&csr, x).y;
                        assert_close(&got.column(j), &want);
                    }
                }
            }
        }
    }

    #[test]
    fn spmm_records_effective_schedule() {
        let mut rng = Pcg32::new(0x5B35);
        let csr = random_csr(&mut rng, 128, 4);
        let xs = vec![1.0; 128 * 2];
        let tiled = spmm_threaded(
            &csr,
            &xs,
            2,
            Schedule::Csr5Tiles { tile_nnz: 32 },
            4,
        );
        assert_eq!(
            tiled.schedule,
            Schedule::CsrRowBalanced,
            "tile plans remap to the balanced row schedule for SpMM"
        );
        let rows = spmm_threaded(&csr, &xs, 2, Schedule::CsrRowStatic, 4);
        assert_eq!(rows.schedule, Schedule::CsrRowStatic);
        assert_eq!(
            effective_spmm_schedule(Schedule::Csr5Tiles { tile_nnz: 7 }),
            Schedule::CsrRowBalanced
        );
        assert_eq!(
            effective_spmm_schedule(Schedule::CsrDynamic { chunk: 4 }),
            Schedule::CsrDynamic { chunk: 4 }
        );
    }

    #[test]
    fn spmm_pooled_matches_spawn() {
        let mut rng = Pcg32::new(0x5B36);
        let csr = random_csr(&mut rng, 200, 5);
        let pool = ExecPool::new(3);
        for batch in [1usize, 7, 8, 9] {
            let vectors = random_vectors(&mut rng, 200, batch);
            let xs = pack_vectors(&vectors);
            let pooled = spmm_threaded_on(
                Some(&pool),
                &csr,
                &xs,
                batch,
                Schedule::CsrRowBalanced,
                4,
            );
            let spawned = spmm_threaded(
                &csr,
                &xs,
                batch,
                Schedule::CsrRowBalanced,
                4,
            );
            assert_close(&pooled.y, &spawned.y);
            assert_eq!(pooled.threads, spawned.threads);
            assert_eq!(pooled.schedule, spawned.schedule);
        }
    }

    #[test]
    fn spmm_sequential_matches_threaded() {
        let mut rng = Pcg32::new(0x5B34);
        let csr = random_csr(&mut rng, 200, 6);
        let vectors = random_vectors(&mut rng, 200, 5);
        let xs = pack_vectors(&vectors);
        let seq = spmm_sequential(&csr, &xs, 5);
        let par = spmm_threaded(&csr, &xs, 5, Schedule::CsrRowBalanced, 4);
        assert_close(&seq.y, &par.y);
        assert_eq!(seq.threads, 1);
        assert!(seq.gflops(csr.nnz()) > 0.0);
    }

    #[test]
    fn spmm_empty_matrix() {
        let csr = Csr::zero(10, 10);
        let xs = vec![1.0; 10 * 3];
        let r = spmm_threaded(&csr, &xs, 3, Schedule::CsrRowStatic, 4);
        assert!(r.y.iter().all(|&v| v == 0.0));
        assert_eq!(r.y.len(), 30);
    }

    #[test]
    fn pack_vectors_interleaves() {
        let xs = pack_vectors(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        // x[i * batch + j]: element i of vector j.
        assert_eq!(xs, vec![1.0, 3.0, 2.0, 4.0]);
    }
}
