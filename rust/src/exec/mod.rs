//! Native threaded SpMV executors — the functional compute path for
//! arbitrary shapes (the PJRT artifacts cover the bucketed shapes; see
//! `runtime`). Also used to wall-clock the host in the §Perf benches.
//!
//! Threads write disjoint row ranges of `y`; the only cross-thread
//! rows are CSR5 range-boundary carries, which are merged by the
//! calling thread after the join (exactly the CSR5 algorithm's
//! cross-thread reduction step).

use std::time::Instant;

use crate::sched::{partition, Partition, Schedule};
use crate::sparse::csr5::TileCarry;
use crate::sparse::{Csr, Csr5};

/// Result of one threaded SpMV execution.
#[derive(Clone, Debug)]
pub struct ExecResult {
    pub y: Vec<f64>,
    pub wall_seconds: f64,
    pub threads: usize,
}

impl ExecResult {
    /// Achieved Gflops; 0 when the timer resolved to zero (tiny
    /// kernels on coarse clocks must not report `inf`).
    pub fn gflops(&self, nnz: usize) -> f64 {
        if self.wall_seconds > 0.0 {
            2.0 * nnz as f64 / self.wall_seconds / 1e9
        } else {
            0.0
        }
    }
}

/// Disjoint-range mutable view of `y` for scoped threads.
///
/// SAFETY: callers must hand each thread ranges that do not overlap
/// with any other thread's ranges — guaranteed by
/// `Partition::validate`, which rejects double-covered rows.
struct SendPtr(*mut f64);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Multi-threaded CSR SpMV under any row partition.
pub fn spmv_threaded(
    csr: &Csr,
    x: &[f64],
    schedule: Schedule,
    n_threads: usize,
) -> ExecResult {
    assert_eq!(x.len(), csr.n_cols);
    let part = partition(csr, schedule, n_threads);
    debug_assert!(part.validate(csr).is_ok());
    match part {
        Partition::Rows { per_thread } => {
            spmv_rows_threaded(csr, x, &per_thread)
        }
        Partition::Tiles { tile_nnz, per_thread } => {
            let csr5 = Csr5::from_csr(csr, tile_nnz);
            spmv_csr5_threaded(&csr5, x, &per_thread)
        }
    }
}

fn spmv_rows_threaded(
    csr: &Csr,
    x: &[f64],
    per_thread: &[Vec<(usize, usize)>],
) -> ExecResult {
    let mut y = vec![0.0f64; csr.n_rows];
    let ptr = SendPtr(y.as_mut_ptr());
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for ranges in per_thread {
            let ptr = &ptr;
            s.spawn(move || {
                // SAFETY: ranges are disjoint across threads
                // (Partition::validate) — each y[r] is written by
                // exactly one thread.
                let yslice = unsafe {
                    std::slice::from_raw_parts_mut(ptr.0, csr.n_rows)
                };
                for &(r0, r1) in ranges {
                    csr.spmv_rows(r0, r1, x, yslice);
                }
            });
        }
    });
    ExecResult {
        y,
        wall_seconds: t0.elapsed().as_secs_f64(),
        threads: per_thread.len(),
    }
}

/// Multi-threaded CSR5 SpMV over tile ranges, with post-join carry
/// merge.
pub fn spmv_csr5_threaded(
    csr5: &Csr5,
    x: &[f64],
    per_thread: &[(usize, usize)],
) -> ExecResult {
    let mut y = vec![0.0f64; csr5.n_rows];
    let ptr = SendPtr(y.as_mut_ptr());
    let t0 = Instant::now();
    let carries: Vec<Vec<TileCarry>> = std::thread::scope(|s| {
        let handles: Vec<_> = per_thread
            .iter()
            .map(|&(a, b)| {
                let ptr = &ptr;
                s.spawn(move || {
                    // SAFETY: spmv_tiles writes only rows fully
                    // contained in its tile range; boundary rows are
                    // returned as carries, not written.
                    let yslice = unsafe {
                        std::slice::from_raw_parts_mut(ptr.0, csr5.n_rows)
                    };
                    csr5.spmv_tiles(a, b, x, yslice)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for cs in carries {
        for c in cs {
            y[c.row] += c.value;
        }
    }
    ExecResult {
        y,
        wall_seconds: t0.elapsed().as_secs_f64(),
        threads: per_thread.len(),
    }
}

/// Sequential reference execution (wrapped for timing symmetry).
pub fn spmv_sequential(csr: &Csr, x: &[f64]) -> ExecResult {
    let mut y = vec![0.0f64; csr.n_rows];
    let t0 = Instant::now();
    csr.spmv(x, &mut y);
    ExecResult { y, wall_seconds: t0.elapsed().as_secs_f64(), threads: 1 }
}

/// Width of one column block of the batched-vector SpMM kernel: the
/// accumulator tile lives in registers, and every nonzero of `A` is
/// loaded once per block instead of once per vector.
pub const SPMM_COL_BLOCK: usize = 8;

/// Result of one batched (multi-vector) SpMM execution:
/// `Y = A X` for a block of `batch` dense vectors.
#[derive(Clone, Debug)]
pub struct SpmmResult {
    /// Vector-interleaved outputs: `y[r * batch + j]` is row `r` of
    /// output vector `j` (same layout as the `xs` input).
    pub y: Vec<f64>,
    pub n_rows: usize,
    pub batch: usize,
    pub wall_seconds: f64,
    pub threads: usize,
}

impl SpmmResult {
    /// Extract output vector `j` as a contiguous `Vec`.
    pub fn column(&self, j: usize) -> Vec<f64> {
        assert!(j < self.batch);
        (0..self.n_rows).map(|r| self.y[r * self.batch + j]).collect()
    }

    /// Achieved Gflops; 0 when the timer resolved to zero.
    pub fn gflops(&self, nnz: usize) -> f64 {
        if self.wall_seconds > 0.0 {
            2.0 * nnz as f64 * self.batch as f64 / self.wall_seconds / 1e9
        } else {
            0.0
        }
    }
}

/// Interleave a slice of equal-length vectors into the
/// `xs[i * batch + j]` layout the SpMM kernels consume.
pub fn pack_vectors<T: AsRef<[f64]>>(vectors: &[T]) -> Vec<f64> {
    let batch = vectors.len();
    assert!(batch > 0, "need at least one vector");
    let n = vectors[0].as_ref().len();
    let mut xs = vec![0.0f64; n * batch];
    for (j, v) in vectors.iter().enumerate() {
        let v = v.as_ref();
        assert_eq!(v.len(), n, "vector length mismatch");
        for (i, &val) in v.iter().enumerate() {
            xs[i * batch + j] = val;
        }
    }
    xs
}

/// The column-blocked SpMM inner kernel over a row range: for each
/// block of `SPMM_COL_BLOCK` vectors, each nonzero `A[r,c]` is read
/// once and multiplied against the block's contiguous slice of `x`
/// row `c` — the batched-serving analog of the CSR row kernel.
fn spmm_rows_blocked(
    csr: &Csr,
    xs: &[f64],
    batch: usize,
    r0: usize,
    r1: usize,
    y: &mut [f64],
) {
    let mut jb = 0;
    while jb < batch {
        let bw = (batch - jb).min(SPMM_COL_BLOCK);
        let mut acc = [0.0f64; SPMM_COL_BLOCK];
        for r in r0..r1 {
            acc[..bw].fill(0.0);
            for i in csr.ptr[r]..csr.ptr[r + 1] {
                let a = csr.data[i];
                let xoff = csr.indices[i] as usize * batch + jb;
                for (t, slot) in acc[..bw].iter_mut().enumerate() {
                    *slot += a * xs[xoff + t];
                }
            }
            let yoff = r * batch + jb;
            y[yoff..yoff + bw].copy_from_slice(&acc[..bw]);
        }
        jb += bw;
    }
}

/// Multi-threaded batched SpMM: `Y = A X` for `batch` interleaved
/// vectors (`xs[i * batch + j]`), threads over row partitions.
///
/// Tile (CSR5) schedules have no multi-vector kernel; they are
/// remapped to `CsrRowBalanced`, the row-space schedule with the same
/// load-balancing intent, so a cached tile plan still serves batches.
pub fn spmm_threaded(
    csr: &Csr,
    xs: &[f64],
    batch: usize,
    schedule: Schedule,
    n_threads: usize,
) -> SpmmResult {
    assert!(batch > 0, "batch must be >= 1");
    assert_eq!(xs.len(), csr.n_cols * batch, "xs length != n_cols * batch");
    let schedule = match schedule {
        Schedule::Csr5Tiles { .. } => Schedule::CsrRowBalanced,
        s => s,
    };
    let part = partition(csr, schedule, n_threads);
    debug_assert!(part.validate(csr).is_ok());
    let per_thread = match part {
        Partition::Rows { per_thread } => per_thread,
        Partition::Tiles { .. } => unreachable!("tile schedules remapped"),
    };
    let mut y = vec![0.0f64; csr.n_rows * batch];
    let ptr = SendPtr(y.as_mut_ptr());
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for ranges in &per_thread {
            let ptr = &ptr;
            s.spawn(move || {
                // SAFETY: row ranges are disjoint across threads
                // (Partition::validate), and row r owns the disjoint
                // slice y[r*batch .. (r+1)*batch].
                let yslice = unsafe {
                    std::slice::from_raw_parts_mut(ptr.0, csr.n_rows * batch)
                };
                for &(r0, r1) in ranges {
                    spmm_rows_blocked(csr, xs, batch, r0, r1, yslice);
                }
            });
        }
    });
    SpmmResult {
        y,
        n_rows: csr.n_rows,
        batch,
        wall_seconds: t0.elapsed().as_secs_f64(),
        threads: per_thread.len(),
    }
}

/// Sequential batched SpMM reference (timing symmetry with
/// [`spmv_sequential`]).
pub fn spmm_sequential(csr: &Csr, xs: &[f64], batch: usize) -> SpmmResult {
    assert!(batch > 0, "batch must be >= 1");
    assert_eq!(xs.len(), csr.n_cols * batch, "xs length != n_cols * batch");
    let mut y = vec![0.0f64; csr.n_rows * batch];
    let t0 = Instant::now();
    spmm_rows_blocked(csr, xs, batch, 0, csr.n_rows, &mut y);
    SpmmResult {
        y,
        n_rows: csr.n_rows,
        batch,
        wall_seconds: t0.elapsed().as_secs_f64(),
        threads: 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use crate::util::testkit::check;
    use crate::{prop_assert, sparse::Coo};

    fn random_csr(rng: &mut Pcg32, n: usize, per_row: usize) -> Csr {
        let mut coo = Coo::new(n, n);
        for r in 0..n {
            let deg = rng.gen_range(per_row * 2 + 1);
            for c in rng.sample_distinct(n, deg.min(n)) {
                coo.push(r, c, rng.gen_f64() - 0.5);
            }
        }
        coo.to_csr()
    }

    fn assert_close(a: &[f64], b: &[f64]) {
        assert_eq!(a.len(), b.len());
        for (i, (p, q)) in a.iter().zip(b).enumerate() {
            assert!(
                (p - q).abs() < 1e-9 * (1.0 + p.abs()),
                "row {i}: {p} vs {q}"
            );
        }
    }

    #[test]
    fn all_schedules_match_sequential() {
        let mut rng = Pcg32::new(0xE8EC);
        let csr = random_csr(&mut rng, 500, 6);
        let x: Vec<f64> = (0..500).map(|_| rng.gen_f64()).collect();
        let want = spmv_sequential(&csr, &x).y;
        for sched in [
            Schedule::CsrRowStatic,
            Schedule::CsrRowBalanced,
            Schedule::Csr5Tiles { tile_nnz: 32 },
            Schedule::CsrDynamic { chunk: 16 },
        ] {
            for nt in [1, 2, 3, 4, 8] {
                let got = spmv_threaded(&csr, &x, sched, nt);
                assert_close(&got.y, &want);
                assert_eq!(got.threads, nt);
            }
        }
    }

    #[test]
    fn csr5_boundary_rows_merge() {
        // One long row spanning multiple threads' tile ranges: every
        // thread contributes a carry to the same row.
        let n = 64;
        let mut coo = Coo::new(n, n);
        for c in 0..n {
            coo.push(0, c, 1.0);
        }
        let csr = coo.to_csr();
        let x = vec![1.0; n];
        let got = spmv_threaded(
            &csr,
            &x,
            Schedule::Csr5Tiles { tile_nnz: 4 },
            4,
        );
        assert_eq!(got.y[0], n as f64);
        assert!(got.y[1..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn property_threaded_matches_sequential() {
        check("threaded==sequential", 25, |rng| {
            let n = 16 + rng.gen_range(200);
            let csr = random_csr(rng, n, 4);
            let x: Vec<f64> = (0..n).map(|_| rng.gen_f64()).collect();
            let want = spmv_sequential(&csr, &x).y;
            let nt = 1 + rng.gen_range(8);
            let sched = match rng.gen_range(4) {
                0 => Schedule::CsrRowStatic,
                1 => Schedule::CsrRowBalanced,
                2 => Schedule::Csr5Tiles { tile_nnz: 1 + rng.gen_range(64) },
                _ => Schedule::CsrDynamic { chunk: 1 + rng.gen_range(32) },
            };
            let got = spmv_threaded(&csr, &x, sched, nt);
            for (i, (p, q)) in got.y.iter().zip(&want).enumerate() {
                prop_assert!(
                    (p - q).abs() < 1e-9 * (1.0 + p.abs()),
                    "row {i}: {p} vs {q} under {sched:?} nt={nt}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn empty_matrix() {
        let csr = Csr::zero(10, 10);
        let x = vec![1.0; 10];
        let r = spmv_threaded(&csr, &x, Schedule::CsrRowStatic, 4);
        assert!(r.y.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn gflops_positive() {
        let mut rng = Pcg32::new(1);
        let csr = random_csr(&mut rng, 256, 8);
        let x = vec![1.0; 256];
        let r = spmv_threaded(&csr, &x, Schedule::CsrRowStatic, 2);
        assert!(r.gflops(csr.nnz()) > 0.0);
    }

    #[test]
    fn gflops_guard_zero_wall_time() {
        let r = ExecResult { y: vec![], wall_seconds: 0.0, threads: 1 };
        assert_eq!(r.gflops(1_000_000), 0.0);
        let s = SpmmResult {
            y: vec![],
            n_rows: 0,
            batch: 4,
            wall_seconds: 0.0,
            threads: 1,
        };
        assert_eq!(s.gflops(1_000_000), 0.0);
        assert!(s.gflops(1_000_000).is_finite());
    }

    fn random_vectors(rng: &mut Pcg32, n: usize, batch: usize) -> Vec<Vec<f64>> {
        (0..batch)
            .map(|_| (0..n).map(|_| rng.gen_f64() - 0.5).collect())
            .collect()
    }

    #[test]
    fn spmm_matches_per_vector_spmv() {
        let mut rng = Pcg32::new(0x5B33);
        let csr = random_csr(&mut rng, 300, 5);
        // Batch sizes straddling the column block width.
        for batch in [1usize, 2, 7, 8, 9, 16] {
            let vectors = random_vectors(&mut rng, 300, batch);
            let xs = pack_vectors(&vectors);
            for sched in [
                Schedule::CsrRowStatic,
                Schedule::CsrRowBalanced,
                Schedule::CsrDynamic { chunk: 16 },
                Schedule::Csr5Tiles { tile_nnz: 32 }, // remapped to rows
            ] {
                for nt in [1, 3, 4] {
                    let got = spmm_threaded(&csr, &xs, batch, sched, nt);
                    assert_eq!(got.batch, batch);
                    for (j, x) in vectors.iter().enumerate() {
                        let want = spmv_sequential(&csr, x).y;
                        assert_close(&got.column(j), &want);
                    }
                }
            }
        }
    }

    #[test]
    fn spmm_sequential_matches_threaded() {
        let mut rng = Pcg32::new(0x5B34);
        let csr = random_csr(&mut rng, 200, 6);
        let vectors = random_vectors(&mut rng, 200, 5);
        let xs = pack_vectors(&vectors);
        let seq = spmm_sequential(&csr, &xs, 5);
        let par = spmm_threaded(&csr, &xs, 5, Schedule::CsrRowBalanced, 4);
        assert_close(&seq.y, &par.y);
        assert_eq!(seq.threads, 1);
        assert!(seq.gflops(csr.nnz()) > 0.0);
    }

    #[test]
    fn spmm_empty_matrix() {
        let csr = Csr::zero(10, 10);
        let xs = vec![1.0; 10 * 3];
        let r = spmm_threaded(&csr, &xs, 3, Schedule::CsrRowStatic, 4);
        assert!(r.y.iter().all(|&v| v == 0.0));
        assert_eq!(r.y.len(), 30);
    }

    #[test]
    fn pack_vectors_interleaves() {
        let xs = pack_vectors(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        // x[i * batch + j]: element i of vector j.
        assert_eq!(xs, vec![1.0, 3.0, 2.0, 4.0]);
    }
}
