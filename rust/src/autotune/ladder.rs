//! Candidate-variant construction: the (format × schedule ×
//! thread-count) ladder a [`super::Tuner`] explores around the static
//! planner's pick.
//!
//! The paper's scalability result shapes the ladder: speedup plateaus
//! well before all FT-2000+ cores are used, and *where* it plateaus is
//! matrix-dependent. So the thread dimension is a geometric ladder
//! around the static pick (bounded by the serving shard's panel core
//! range), and [`knee_index`] implements the plateau hunt — among
//! statistically comparable arms, prefer the one using the fewest
//! cores, because cores past the knee add cost and nothing else.

use crate::sched::Schedule;

/// One candidate execution configuration: a schedule (which implies
/// the storage format — CSR5 tiles pre-convert) and a kernel width.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Variant {
    pub schedule: Schedule,
    pub n_threads: usize,
}

impl Variant {
    pub fn name(&self) -> String {
        format!("{}@{}t", self.schedule.name(), self.n_threads)
    }
}

/// Geometric thread ladder around `static_threads`: `{1, s/2, s, 2s,
/// 4s}` clamped to `[1, max_threads]`, sorted and deduplicated. The
/// static width is always present.
pub fn thread_ladder(static_threads: usize, max_threads: usize) -> Vec<usize> {
    let s = static_threads.max(1);
    let max = max_threads.max(1);
    let mut ladder = vec![1, s / 2, s, s * 2, s * 4];
    ladder.retain(|&t| t >= 1);
    for t in &mut ladder {
        *t = (*t).min(max);
    }
    ladder.sort_unstable();
    ladder.dedup();
    ladder
}

/// The candidate schedules explored around `static_schedule`. The two
/// row-space schedules are always present (they are free — no format
/// conversion); the packed formats (CSR5 tiles, SELL-C-σ chunks) are
/// kept as candidates only when the static planner already picked
/// them, so exploration never pays a per-variant format conversion
/// the planner's prior voted against. (A packed static pick keeps its
/// whole thread ladder — `static_schedule` is always the first
/// schedule here, and [`candidates`] crosses every schedule with the
/// ladder — so the ladder's conversion is shared across those arms.)
pub fn schedule_candidates(
    static_schedule: Schedule,
    tile_nnz: usize,
) -> Vec<Schedule> {
    let mut out = vec![static_schedule];
    for s in [
        Schedule::CsrRowStatic,
        Schedule::CsrRowBalanced,
        Schedule::Csr5Tiles { tile_nnz },
    ] {
        let keep = match s {
            Schedule::Csr5Tiles { .. } => {
                matches!(static_schedule, Schedule::Csr5Tiles { .. })
            }
            _ => true,
        };
        if keep && !out.contains(&s) {
            out.push(s);
        }
    }
    out
}

/// The full candidate set: schedules × thread ladder, with the static
/// (schedule, width) pair guaranteed at index 0 — the arm every
/// promotion decision is measured against.
pub fn candidates(
    static_schedule: Schedule,
    tile_nnz: usize,
    static_threads: usize,
    max_threads: usize,
) -> Vec<Variant> {
    let static_threads = static_threads.max(1);
    let static_variant =
        Variant { schedule: static_schedule, n_threads: static_threads };
    let mut out = vec![static_variant];
    for schedule in schedule_candidates(static_schedule, tile_nnz) {
        for &n_threads in &thread_ladder(static_threads, max_threads) {
            let v = Variant { schedule, n_threads };
            if !out.contains(&v) {
                out.push(v);
            }
        }
    }
    out
}

/// The plateau knee: among arms with a measured mean (`None` = not
/// yet warmed up), find the best mean, then return the index of the
/// *fewest-thread* arm whose mean is within `tol` of it (ties break
/// to the lowest index). `None` when no arm is warmed up.
pub fn knee_index(
    variants: &[Variant],
    means: &[Option<f64>],
    tol: f64,
) -> Option<usize> {
    assert_eq!(variants.len(), means.len());
    let best = means
        .iter()
        .flatten()
        .copied()
        .fold(f64::INFINITY, f64::min);
    if !best.is_finite() {
        return None;
    }
    let cutoff = best * (1.0 + tol.max(0.0));
    let mut pick: Option<usize> = None;
    for (i, m) in means.iter().enumerate() {
        let Some(m) = m else { continue };
        if *m <= cutoff {
            let better = match pick {
                None => true,
                Some(p) => variants[i].n_threads < variants[p].n_threads,
            };
            if better {
                pick = Some(i);
            }
        }
    }
    pick
}

/// Numeric schedule code for the observation dataset (a tree split on
/// "which schedule ran" needs an ordinal, not a string).
pub fn schedule_code(s: Schedule) -> f64 {
    match s {
        Schedule::CsrRowStatic => 0.0,
        Schedule::CsrRowBalanced => 1.0,
        Schedule::Csr5Tiles { .. } => 2.0,
        Schedule::CsrDynamic { .. } => 3.0,
        Schedule::SellChunks { .. } => 4.0,
    }
}

/// Inverse of [`Schedule::name`] for snapshot warm starts
/// ("csr-static", "csr-balanced", "csr5-t256", "csr-dyn64",
/// "sell-c8-s64").
pub fn schedule_from_name(name: &str) -> Option<Schedule> {
    match name {
        "csr-static" => Some(Schedule::CsrRowStatic),
        "csr-balanced" => Some(Schedule::CsrRowBalanced),
        _ => {
            if let Some(t) = name.strip_prefix("csr5-t") {
                t.parse().ok().map(|tile_nnz| Schedule::Csr5Tiles { tile_nnz })
            } else if let Some(c) = name.strip_prefix("csr-dyn") {
                c.parse().ok().map(|chunk| Schedule::CsrDynamic { chunk })
            } else if let Some(rest) = name.strip_prefix("sell-c") {
                let (c, sigma) = rest.split_once("-s")?;
                match (c.parse().ok(), sigma.parse().ok()) {
                    (Some(c), Some(sigma)) => {
                        Some(Schedule::SellChunks { c, sigma })
                    }
                    _ => None,
                }
            } else {
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_brackets_the_static_width() {
        assert_eq!(thread_ladder(4, 16), vec![1, 2, 4, 8, 16]);
        assert_eq!(thread_ladder(4, 8), vec![1, 2, 4, 8]);
        assert_eq!(thread_ladder(1, 4), vec![1, 2, 4]);
        assert_eq!(thread_ladder(8, 8), vec![1, 4, 8]);
        assert_eq!(thread_ladder(0, 0), vec![1], "degenerate bounds clamp");
    }

    #[test]
    fn candidates_start_with_the_static_pick() {
        let tile = Schedule::Csr5Tiles { tile_nnz: 256 };
        let cands = candidates(tile, 256, 4, 16);
        assert_eq!(
            cands[0],
            Variant { schedule: tile, n_threads: 4 },
            "static pick must be arm 0"
        );
        // Tile static pick keeps the CSR5 format in the ladder.
        assert!(cands
            .iter()
            .any(|v| matches!(v.schedule, Schedule::Csr5Tiles { .. })
                && v.n_threads == 16));
        // No duplicates.
        for (i, a) in cands.iter().enumerate() {
            assert!(!cands[i + 1..].contains(a), "duplicate {a:?}");
        }
    }

    #[test]
    fn row_static_pick_skips_tile_conversion() {
        let cands = candidates(Schedule::CsrRowStatic, 256, 4, 8);
        assert!(
            cands
                .iter()
                .all(|v| !matches!(v.schedule, Schedule::Csr5Tiles { .. })),
            "no speculative CSR5 conversion: {cands:?}"
        );
        assert!(cands
            .iter()
            .any(|v| v.schedule == Schedule::CsrRowBalanced));
    }

    #[test]
    fn knee_prefers_fewest_threads_within_tolerance() {
        let variants: Vec<Variant> = [1usize, 2, 4, 8]
            .iter()
            .map(|&t| Variant {
                schedule: Schedule::CsrRowStatic,
                n_threads: t,
            })
            .collect();
        // 4 threads is fastest, but 2 threads is within 3%: the knee
        // stops paying for the extra cores.
        let means =
            vec![Some(2.0), Some(1.02), Some(1.0), Some(1.4)];
        assert_eq!(knee_index(&variants, &means, 0.03), Some(1));
        // Tighter tolerance keeps the true minimum.
        assert_eq!(knee_index(&variants, &means, 0.001), Some(2));
        // Unwarmed arms are ignored; all-unwarmed has no knee.
        let partial = vec![None, None, Some(1.0), None];
        assert_eq!(knee_index(&variants, &partial, 0.1), Some(2));
        assert_eq!(knee_index(&variants, &[None, None, None, None], 0.1), None);
    }

    #[test]
    fn schedule_names_roundtrip() {
        for s in [
            Schedule::CsrRowStatic,
            Schedule::CsrRowBalanced,
            Schedule::Csr5Tiles { tile_nnz: 128 },
            Schedule::CsrDynamic { chunk: 32 },
            Schedule::SellChunks { c: 8, sigma: 64 },
            Schedule::SellChunks { c: 32, sigma: 4096 },
        ] {
            assert_eq!(schedule_from_name(&s.name()), Some(s));
        }
        assert_eq!(schedule_from_name("bogus"), None);
        assert_eq!(schedule_from_name("sell-c8"), None);
        assert_eq!(schedule_from_name("sell-cx-sy"), None);
    }

    #[test]
    fn sell_static_pick_keeps_its_ladder_without_tiles() {
        // A SELL static pick explores the SELL thread ladder (shared
        // conversion) plus the free row-space schedules — but never a
        // speculative CSR5 conversion.
        let sell = Schedule::SellChunks { c: 8, sigma: 64 };
        let cands = candidates(sell, 256, 4, 16);
        assert_eq!(cands[0], Variant { schedule: sell, n_threads: 4 });
        assert!(
            cands.iter().filter(|v| v.schedule == sell).count() >= 3,
            "the SELL arm family must span the thread ladder: {cands:?}"
        );
        assert!(
            cands
                .iter()
                .all(|v| !matches!(v.schedule, Schedule::Csr5Tiles { .. })),
            "no speculative CSR5 conversion from a SELL pick: {cands:?}"
        );
        assert!(cands.iter().any(|v| v.schedule == Schedule::CsrRowStatic));
        assert_eq!(schedule_code(sell), 4.0);
    }
}
