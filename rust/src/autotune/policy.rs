//! Explore/exploit policies over plan-variant arms.
//!
//! Each registered matrix's [`super::Tuner`] holds one arm per
//! candidate plan variant; a policy picks which arm the next dispatch
//! runs. Both policies are deterministic given the tuner's seeded RNG
//! and the observation sequence, which is what keeps a tuned
//! virtual-time replay bit-reproducible.

use crate::util::rng::Pcg32;

/// Streaming latency statistics of one plan-variant arm (Welford's
/// online mean/variance — constant memory at any pull count).
#[derive(Clone, Debug, Default)]
pub struct ArmStats {
    pub pulls: u64,
    pub mean_ms: f64,
    m2: f64,
}

impl ArmStats {
    /// Restore an arm from snapshot fields (JSON warm start).
    pub fn restored(pulls: u64, mean_ms: f64, m2: f64) -> ArmStats {
        ArmStats { pulls, mean_ms, m2: m2.max(0.0) }
    }

    pub fn observe(&mut self, ms: f64) {
        self.pulls += 1;
        let delta = ms - self.mean_ms;
        self.mean_ms += delta / self.pulls as f64;
        self.m2 += delta * (ms - self.mean_ms);
    }

    /// Sample variance of the observed latencies (0 below 2 pulls).
    pub fn variance(&self) -> f64 {
        if self.pulls < 2 {
            0.0
        } else {
            self.m2 / (self.pulls - 1) as f64
        }
    }

    /// Internal Welford accumulator (snapshot serialization).
    pub fn m2(&self) -> f64 {
        self.m2
    }

    /// Halve the evidence weight — demotion re-opens exploration
    /// without forgetting everything the arm has learned.
    pub fn decay(&mut self) {
        self.pulls /= 2;
        self.m2 /= 2.0;
    }
}

/// Arm-selection policy. Latencies are *costs*: both policies
/// minimize.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Policy {
    /// With probability `epsilon` pick a uniform random arm, else the
    /// lowest observed mean (ties to the lowest index).
    EpsilonGreedy { epsilon: f64 },
    /// UCB1 adapted to minimization: pick the arm minimizing
    /// `mean - c * scale * sqrt(2 ln N / n)`, where `scale` is the
    /// mean of the arm means (latencies are not in [0, 1], so the
    /// confidence radius is normalized to the problem's latency
    /// scale).
    Ucb1 { c: f64 },
}

impl Policy {
    pub fn name(&self) -> String {
        match self {
            Policy::EpsilonGreedy { epsilon } => {
                format!("epsilon-greedy({epsilon:.2})")
            }
            Policy::Ucb1 { c } => format!("ucb1({c:.2})"),
        }
    }

    /// Pick the next arm to pull. Arms with zero pulls are swept first
    /// in index order (the deterministic warmup pass both policies
    /// share).
    pub fn select(&self, arms: &[ArmStats], rng: &mut Pcg32) -> usize {
        assert!(!arms.is_empty(), "policy needs at least one arm");
        if let Some(i) = arms.iter().position(|a| a.pulls == 0) {
            return i;
        }
        match self {
            Policy::EpsilonGreedy { epsilon } => {
                if rng.gen_f64() < *epsilon {
                    rng.gen_range(arms.len())
                } else {
                    argmin_mean(arms)
                }
            }
            Policy::Ucb1 { c } => {
                let total: u64 = arms.iter().map(|a| a.pulls).sum();
                let scale = arms.iter().map(|a| a.mean_ms).sum::<f64>()
                    / arms.len() as f64;
                let ln_total = (total.max(1) as f64).ln();
                let mut best = 0usize;
                let mut best_score = f64::INFINITY;
                for (i, a) in arms.iter().enumerate() {
                    let bonus = c
                        * scale
                        * (2.0 * ln_total / a.pulls as f64).sqrt();
                    let score = a.mean_ms - bonus;
                    if score < best_score {
                        best_score = score;
                        best = i;
                    }
                }
                best
            }
        }
    }
}

fn argmin_mean(arms: &[ArmStats]) -> usize {
    let mut best = 0usize;
    for (i, a) in arms.iter().enumerate().skip(1) {
        if a.mean_ms < arms[best].mean_ms {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arms_with_means(means: &[f64], pulls: u64) -> Vec<ArmStats> {
        means
            .iter()
            .map(|&m| ArmStats::restored(pulls, m, 0.0))
            .collect()
    }

    #[test]
    fn welford_mean_and_variance() {
        let mut a = ArmStats::default();
        for ms in [1.0, 2.0, 3.0, 4.0] {
            a.observe(ms);
        }
        assert_eq!(a.pulls, 4);
        assert!((a.mean_ms - 2.5).abs() < 1e-12);
        assert!((a.variance() - 5.0 / 3.0).abs() < 1e-12);
        a.decay();
        assert_eq!(a.pulls, 2);
        assert!((a.mean_ms - 2.5).abs() < 1e-12, "decay keeps the mean");
    }

    #[test]
    fn unpulled_arms_are_swept_first() {
        let mut rng = Pcg32::new(1);
        let mut arms = arms_with_means(&[5.0, 1.0, 3.0], 2);
        arms[2] = ArmStats::default();
        for policy in [
            Policy::EpsilonGreedy { epsilon: 0.5 },
            Policy::Ucb1 { c: 1.0 },
        ] {
            assert_eq!(policy.select(&arms, &mut rng), 2, "{policy:?}");
        }
    }

    #[test]
    fn greedy_exploits_the_best_mean() {
        let mut rng = Pcg32::new(2);
        let arms = arms_with_means(&[5.0, 1.0, 3.0], 4);
        let policy = Policy::EpsilonGreedy { epsilon: 0.0 };
        for _ in 0..10 {
            assert_eq!(policy.select(&arms, &mut rng), 1);
        }
    }

    #[test]
    fn epsilon_explores_sometimes() {
        let mut rng = Pcg32::new(3);
        let arms = arms_with_means(&[5.0, 1.0, 3.0], 4);
        let policy = Policy::EpsilonGreedy { epsilon: 0.5 };
        let picks: Vec<usize> =
            (0..200).map(|_| policy.select(&arms, &mut rng)).collect();
        assert!(picks.iter().any(|&i| i != 1), "must explore");
        let best = picks.iter().filter(|&&i| i == 1).count();
        assert!(best > 100, "must still mostly exploit: {best}/200");
    }

    #[test]
    fn ucb_revisits_underexplored_arms() {
        let mut rng = Pcg32::new(4);
        // Arm 0 is slightly worse but barely pulled: the confidence
        // bonus must send UCB back to it.
        let mut arms = arms_with_means(&[1.2, 1.0], 1);
        arms[1].pulls = 1000;
        let policy = Policy::Ucb1 { c: 1.0 };
        assert_eq!(policy.select(&arms, &mut rng), 0);
        // Once evidence accumulates, the better mean wins.
        arms[0].pulls = 1000;
        assert_eq!(policy.select(&arms, &mut rng), 1);
    }

    #[test]
    fn selection_is_deterministic_for_a_seed() {
        let arms = arms_with_means(&[2.0, 1.0, 1.5], 3);
        let run = |seed: u64| -> Vec<usize> {
            let mut rng = Pcg32::new(seed);
            let policy = Policy::EpsilonGreedy { epsilon: 0.3 };
            (0..50).map(|_| policy.select(&arms, &mut rng)).collect()
        };
        assert_eq!(run(7), run(7));
    }
}
