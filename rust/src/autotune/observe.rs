//! Observation plumbing: every measured dispatch becomes (a) an arm
//! update in the tuner and (b) a supervised sample for retraining the
//! offline planner.
//!
//! The paper's regression tree is trained on simulated labels; a
//! serving deployment sees the real thing. [`ObservationLog`]
//! accumulates `(static features, n_threads, batch, schedule) ->
//! per-request latency` rows into an [`mlmodel::Dataset`] so the
//! `coordinator::format_select` tree can periodically be refit from
//! production measurements — the offline model becomes the prior, not
//! the verdict. [`BatchDrift`] watches the traffic's batch-width EWMA
//! and flags when it moves far from where a promotion was decided
//! (batched dispatches execute a different effective schedule than
//! singletons — see `per_schedule` telemetry — so a promotion decided
//! under one batching regime may not survive another).

use crate::mlmodel::Dataset;

use super::ladder::{schedule_code, Variant};

/// Length of the `coordinator::format_select::static_features` vector
/// the observation rows lead with (zero-padded for degenerate
/// matrices whose plans carry no features).
pub const BASE_FEATURES: usize = 7;

/// Rows retained before the log stops growing (bounds memory on
/// million-request runs; the tuner's arm statistics keep streaming).
pub const DATASET_CAP: usize = 65_536;

/// Feature schema of the observation dataset. The trailing stage
/// columns come from the span recorder's per-dispatch breakdown
/// (zero when the dispatch was not staged — e.g. modeled replay).
pub fn feature_names() -> Vec<String> {
    vec![
        "n_rows".into(),
        "nnz_avg".into(),
        "nnz_var".into(),
        "nnz_max_ratio".into(),
        "job_var".into(),
        "locality".into(),
        "x_miss_l1".into(),
        "n_threads".into(),
        "batch".into(),
        "schedule".into(),
        "plan_lookup_ms".into(),
        "kernel_ms".into(),
        "reduce_ms".into(),
        "imbalance_ms".into(),
        "overhead_ms".into(),
        "residual_ms".into(),
    ]
}

/// Stage columns appended after the base features + (threads, batch,
/// schedule) triple: three measured stage timings and the scaling
/// profiler's three gap-attribution components.
pub const STAGE_COLUMNS: usize = 6;

/// Per-dispatch stage breakdown attached to an observation — the
/// tracing subsystem's measured decomposition of where a dispatch's
/// time went, folded into the retraining dataset as extra columns.
#[derive(Clone, Copy, Debug, Default)]
pub struct StageObs {
    /// Plan-cache lookup (+ tuner arm selection), ms.
    pub plan_lookup_ms: f64,
    /// Kernel execution, ms.
    pub kernel_ms: f64,
    /// Post-kernel reduction + telemetry accounting, ms.
    pub reduce_ms: f64,
    /// Scaling-profiler attribution: busiest-lane minus mean-lane
    /// kernel time (`obs::scaling`), ms.
    pub imbalance_ms: f64,
    /// Dispatch/sync overhead (lookup + partition + reduce + latch
    /// tail), ms.
    pub overhead_ms: f64,
    /// Unattributed gap remainder (model replay: the bandwidth-
    /// saturation loss), ms.
    pub residual_ms: f64,
}

/// Bounded accumulator of supervised observations.
#[derive(Clone, Debug)]
pub struct ObservationLog {
    data: Dataset,
    dropped: u64,
}

impl Default for ObservationLog {
    fn default() -> Self {
        ObservationLog { data: Dataset::new(feature_names()), dropped: 0 }
    }
}

impl ObservationLog {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append one measured dispatch. `features` is the plan's static
    /// feature vector (may be empty; padded to [`BASE_FEATURES`]);
    /// `stages` the dispatch's measured stage breakdown
    /// ([`StageObs::default`] when none was captured).
    pub fn record(
        &mut self,
        features: &[f64],
        variant: &Variant,
        batch: usize,
        per_request_ms: f64,
        stages: &StageObs,
    ) {
        if self.data.len() >= DATASET_CAP {
            self.dropped += 1;
            return;
        }
        let mut row = Vec::with_capacity(BASE_FEATURES + 3 + STAGE_COLUMNS);
        row.extend(features.iter().copied().take(BASE_FEATURES));
        while row.len() < BASE_FEATURES {
            row.push(0.0);
        }
        row.push(variant.n_threads as f64);
        row.push(batch as f64);
        row.push(schedule_code(variant.schedule));
        row.push(stages.plan_lookup_ms.max(0.0));
        row.push(stages.kernel_ms.max(0.0));
        row.push(stages.reduce_ms.max(0.0));
        row.push(stages.imbalance_ms.max(0.0));
        row.push(stages.overhead_ms.max(0.0));
        row.push(stages.residual_ms.max(0.0));
        self.data.push(row, per_request_ms);
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Observations discarded after the cap was reached.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Clone-out of the accumulated dataset (retraining input).
    pub fn snapshot(&self) -> Dataset {
        self.data.clone()
    }
}

/// EWMA batch-width drift detector: anchored at promotion time,
/// trips when the traffic's coalescing behavior moves `ratio` away
/// from the anchor.
#[derive(Clone, Copy, Debug)]
pub struct BatchDrift {
    alpha: f64,
    ratio: f64,
    ewma: f64,
    anchor: f64,
    seen: bool,
}

impl BatchDrift {
    pub fn new(alpha: f64, ratio: f64) -> Self {
        BatchDrift {
            alpha: alpha.clamp(0.0, 1.0),
            ratio: ratio.max(0.0),
            ewma: 0.0,
            anchor: 0.0,
            seen: false,
        }
    }

    /// Fold one dispatch's batch width in; returns `true` when the
    /// EWMA has drifted past the anchored reference (only while
    /// anchored).
    pub fn observe(&mut self, batch: usize) -> bool {
        let b = batch.max(1) as f64;
        if !self.seen {
            self.ewma = b;
            self.seen = true;
        } else {
            self.ewma = (1.0 - self.alpha) * self.ewma + self.alpha * b;
        }
        self.anchor > 0.0
            && (self.ewma - self.anchor).abs() / self.anchor > self.ratio
    }

    /// Freeze the current EWMA as the reference regime (called at
    /// promotion time).
    pub fn anchor(&mut self) {
        self.anchor = self.ewma.max(1.0);
    }

    /// Drop the reference (called at demotion).
    pub fn release(&mut self) {
        self.anchor = 0.0;
    }

    pub fn ewma(&self) -> f64 {
        self.ewma
    }

    pub fn anchored(&self) -> f64 {
        self.anchor
    }

    /// Restore from snapshot fields.
    pub fn restored(alpha: f64, ratio: f64, ewma: f64, anchor: f64) -> Self {
        BatchDrift {
            alpha: alpha.clamp(0.0, 1.0),
            ratio: ratio.max(0.0),
            ewma,
            anchor,
            seen: ewma > 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::Schedule;

    #[test]
    fn log_pads_and_schemas_rows() {
        let mut log = ObservationLog::new();
        let v = Variant { schedule: Schedule::CsrRowBalanced, n_threads: 2 };
        let none = StageObs::default();
        // Degenerate: empty features pad, no stage breakdown.
        log.record(&[], &v, 4, 0.5, &none);
        let staged = StageObs {
            plan_lookup_ms: 0.01,
            kernel_ms: 0.2,
            reduce_ms: 0.04,
            imbalance_ms: 0.03,
            overhead_ms: 0.05,
            residual_ms: 0.02,
        };
        log.record(
            &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0],
            &v,
            1,
            0.25,
            &staged,
        );
        let d = log.snapshot();
        assert_eq!(d.len(), 2);
        assert_eq!(d.n_features(), BASE_FEATURES + 3 + STAGE_COLUMNS);
        assert_eq!(d.n_features(), feature_names().len());
        assert_eq!(d.x[0][..BASE_FEATURES], [0.0; BASE_FEATURES]);
        assert_eq!(d.x[1][0], 1.0);
        assert_eq!(d.x[0][BASE_FEATURES], 2.0); // n_threads
        assert_eq!(d.x[0][BASE_FEATURES + 1], 4.0); // batch
        assert_eq!(d.x[0][BASE_FEATURES + 2], 1.0); // csr-balanced
        assert_eq!(
            d.x[0][BASE_FEATURES + 3..],
            [0.0, 0.0, 0.0, 0.0, 0.0, 0.0]
        );
        assert_eq!(
            d.x[1][BASE_FEATURES + 3..],
            [0.01, 0.2, 0.04, 0.03, 0.05, 0.02]
        );
        assert_eq!(d.y, vec![0.5, 0.25]);
    }

    #[test]
    fn log_caps_and_counts_drops() {
        let mut log = ObservationLog::new();
        let v = Variant { schedule: Schedule::CsrRowStatic, n_threads: 1 };
        for _ in 0..DATASET_CAP + 10 {
            log.record(
                &[0.0; BASE_FEATURES],
                &v,
                1,
                1.0,
                &StageObs::default(),
            );
        }
        assert_eq!(log.len(), DATASET_CAP);
        assert_eq!(log.dropped(), 10);
    }

    #[test]
    fn drift_trips_only_when_anchored_and_moved() {
        let mut d = BatchDrift::new(0.5, 0.5);
        for _ in 0..10 {
            assert!(!d.observe(4), "unanchored drift must not trip");
        }
        d.anchor();
        assert!((d.anchored() - 4.0).abs() < 1e-9);
        assert!(!d.observe(4), "stable traffic stays anchored");
        // Batch width collapses to singletons: EWMA halves fast at
        // alpha 0.5 and crosses the 50% ratio.
        let mut tripped = false;
        for _ in 0..10 {
            tripped |= d.observe(1);
        }
        assert!(tripped, "regime change must trip the detector");
        d.release();
        assert!(!d.observe(1), "released detector never trips");
    }
}
