//! Online plan autotuning — closed-loop refinement of per-matrix
//! execution plans from measured serving latency.
//!
//! The paper's central result is that the best (format, schedule,
//! thread count) for SpMV is matrix-dependent and that speedup
//! plateaus well before all FT-2000+ cores are used. The static
//! planner in [`crate::service::plan`] encodes that result as a
//! *prior* — a heuristic or a learned tree over static features — but
//! it decides once and never looks at what actually happened at
//! runtime. This module treats every registered matrix's plan as a
//! live hypothesis instead:
//!
//! * a [`Tuner`] per matrix fingerprint holds a candidate ladder of
//!   plan variants ([`ladder`]: schedule × thread-count around the
//!   static pick, bounded by the serving shard's panel core range);
//! * an explore/exploit [`policy`] (epsilon-greedy or UCB1) picks
//!   which variant each dispatch runs, fed by measured per-request
//!   latencies (wall-clock in live serving, the deterministic cost
//!   model in virtual-time replay);
//! * promotion hunts the paper's speedup-plateau knee
//!   ([`ladder::knee_index`]): among statistically comparable arms
//!   the fewest-thread one wins, so the fleet stops paying for cores
//!   past the plateau. Winners are installed into the serving
//!   [`PlanCache`](crate::service::PlanCache) via its versioned
//!   `replace` API;
//! * demotion re-opens exploration when traffic shifts regime
//!   ([`observe::BatchDrift`] on the batch-width EWMA — coalescing
//!   changes the *effective executed* schedule, so a promotion from
//!   one regime may not survive another);
//! * every observation also lands in an [`observe::ObservationLog`]
//!   ([`crate::mlmodel::Dataset`]) so the offline regression-tree
//!   planner can be retrained from production measurements;
//! * the whole tuning state snapshots to JSON ([`Autotuner::to_json`])
//!   and warm-starts a later run ([`Autotuner::warm_start`]).

pub mod ladder;
pub mod observe;
pub mod policy;

pub use ladder::{candidates, knee_index, schedule_from_name, Variant};
pub use observe::{BatchDrift, ObservationLog, StageObs};
pub use policy::{ArmStats, Policy};

use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Mutex};

use crate::mlmodel::Dataset;
use crate::service::plan::{
    build_plan_shared, Plan, PlanConfig, SharedFormats,
};
use crate::sparse::Csr;
use crate::util::json::Json;
use crate::util::rng::Pcg32;
use crate::util::table::Table;

/// Tuning knobs shared by every per-matrix tuner of an engine.
#[derive(Clone, Copy, Debug)]
pub struct AutotuneConfig {
    pub policy: Policy,
    /// Minimum pulls before an arm's mean can win a promotion.
    pub warmup: u64,
    /// Fractional latency improvement a challenger needs over the
    /// currently chosen arm to be promoted (damping against noise).
    pub min_gain: f64,
    /// Arms within this fraction of the best mean are "at the
    /// plateau"; the fewest-thread one is preferred (the knee hunt).
    pub knee_tol: f64,
    /// EWMA smoothing of the observed batch width.
    pub drift_alpha: f64,
    /// Relative batch-width drift from the promotion-time anchor that
    /// demotes the chosen variant and re-opens exploration.
    pub drift_ratio: f64,
    /// Thread-ladder upper bound — a sharded engine passes its panel
    /// core-range width so tuning never plans past its panel.
    pub max_threads: usize,
    /// Hard cap on arms per tuner (hill-climb extension bound).
    pub max_arms: usize,
    /// `true`: the engine self-observes kernel wall time (live
    /// serving). `false`: an external caller feeds observations (the
    /// deterministic virtual-time replay).
    pub wall_clock: bool,
    /// Seed of the per-tuner exploration RNG (xored with the matrix
    /// fingerprint, so tuners explore independently but
    /// reproducibly).
    pub seed: u64,
}

impl Default for AutotuneConfig {
    fn default() -> Self {
        AutotuneConfig {
            policy: Policy::EpsilonGreedy { epsilon: 0.1 },
            warmup: 2,
            min_gain: 0.02,
            knee_tol: 0.05,
            drift_alpha: 0.2,
            drift_ratio: 0.5,
            max_threads: 16,
            max_arms: 24,
            wall_clock: true,
            seed: 0x7E57_7E57,
        }
    }
}

impl AutotuneConfig {
    /// Clamp the thread-ladder bound to a panel core range `[c0, c1)`
    /// — shared by the live sharded server and the replay harness so
    /// a shard's tuner can never plan past its own panel.
    pub fn bounded_to_cores(mut self, cores: (usize, usize)) -> Self {
        let span = cores.1.saturating_sub(cores.0).max(1);
        self.max_threads = self.max_threads.min(span).max(1);
        self
    }
}

/// Warm-start state for one fingerprint, parsed from a JSON snapshot.
#[derive(Clone, Debug, Default)]
pub struct TunerSnapshot {
    pub name: String,
    /// (schedule name, threads) of the arm that was chosen.
    pub chosen: Option<(String, usize)>,
    pub promotions: u64,
    pub demotions: u64,
    pub batch_ewma: f64,
    pub batch_anchor: f64,
    /// (schedule name, threads, pulls, mean_ms, m2) per arm.
    pub arms: Vec<(String, usize, u64, f64, f64)>,
}

/// One matrix's live tuning state.
pub struct Tuner {
    fingerprint: u64,
    name: String,
    variants: Vec<Variant>,
    arms: Vec<ArmStats>,
    /// Lazily built plan per variant (arm 0 = the static plan).
    plans: Vec<Option<Arc<Plan>>>,
    /// Static feature vector carried over from the static plan (the
    /// observation rows lead with it).
    features: Vec<f64>,
    static_idx: usize,
    chosen: usize,
    /// Set by a warm start that restored a non-static `chosen`: the
    /// serving cache does not yet hold that variant, so the next
    /// observation after the variant's plan is (re)built hands it
    /// back for a `PlanCache::replace` — the promotion survives the
    /// restart.
    pending_install: bool,
    promotions: u64,
    demotions: u64,
    drift: BatchDrift,
    rng: Pcg32,
}

impl Tuner {
    fn new(
        fingerprint: u64,
        name: &str,
        static_plan: &Arc<Plan>,
        cfg: &AutotuneConfig,
        plan_cfg: &PlanConfig,
        warm: Option<&TunerSnapshot>,
    ) -> Tuner {
        let tile_nnz = match static_plan.schedule {
            crate::sched::Schedule::Csr5Tiles { tile_nnz } => tile_nnz,
            _ => plan_cfg.csr5_tile_nnz,
        };
        let variants = candidates(
            static_plan.schedule,
            tile_nnz,
            static_plan.n_threads,
            cfg.max_threads.max(1),
        );
        let n = variants.len();
        let mut tuner = Tuner {
            fingerprint,
            name: name.to_string(),
            variants,
            arms: vec![ArmStats::default(); n],
            plans: vec![None; n],
            features: static_plan.features.clone(),
            static_idx: 0,
            chosen: 0,
            pending_install: false,
            promotions: 0,
            demotions: 0,
            drift: BatchDrift::new(cfg.drift_alpha, cfg.drift_ratio),
            rng: Pcg32::new(cfg.seed ^ fingerprint),
        };
        tuner.plans[0] = Some(static_plan.clone());
        if let Some(w) = warm {
            tuner.apply_snapshot(w, cfg);
        }
        tuner
    }

    fn find_variant(&self, schedule_name: &str, threads: usize) -> Option<usize> {
        self.variants.iter().position(|v| {
            v.n_threads == threads && v.schedule.name() == schedule_name
        })
    }

    fn apply_snapshot(&mut self, w: &TunerSnapshot, cfg: &AutotuneConfig) {
        // Packed-format arms (CSR5 tiles, SELL chunks) may only
        // re-enter a ladder that already carries that format (the
        // static pick chose it) — a snapshot from a different planner
        // must not smuggle speculative conversions back in.
        let ladder_has_tiles = self
            .variants
            .iter()
            .any(|v| matches!(v.schedule, crate::sched::Schedule::Csr5Tiles { .. }));
        let ladder_has_sell = self
            .variants
            .iter()
            .any(|v| matches!(v.schedule, crate::sched::Schedule::SellChunks { .. }));
        for (sched, threads, pulls, mean, m2) in &w.arms {
            let idx = match self.find_variant(sched, *threads) {
                Some(i) => Some(i),
                None => match schedule_from_name(sched) {
                    // A hill-climb-discovered variant from the earlier
                    // run: re-adopt it if it still fits the bounds.
                    Some(schedule)
                        if *threads <= cfg.max_threads.max(1)
                            && self.variants.len() < cfg.max_arms
                            && (ladder_has_tiles
                                || !matches!(
                                    schedule,
                                    crate::sched::Schedule::Csr5Tiles { .. }
                                ))
                            && (ladder_has_sell
                                || !matches!(
                                    schedule,
                                    crate::sched::Schedule::SellChunks { .. }
                                )) =>
                    {
                        self.variants
                            .push(Variant { schedule, n_threads: *threads });
                        self.arms.push(ArmStats::default());
                        self.plans.push(None);
                        Some(self.variants.len() - 1)
                    }
                    _ => None,
                },
            };
            if let Some(i) = idx {
                self.arms[i] = ArmStats::restored(*pulls, *mean, *m2);
            }
        }
        if let Some((sched, threads)) = &w.chosen {
            if let Some(i) = self.find_variant(sched, *threads) {
                self.chosen = i;
                // The restored winner must be re-installed into the
                // (fresh) serving plan cache once its plan is rebuilt.
                self.pending_install = i != self.static_idx;
            }
        }
        self.promotions = w.promotions;
        self.demotions = w.demotions;
        self.drift = BatchDrift::restored(
            cfg.drift_alpha,
            cfg.drift_ratio,
            w.batch_ewma,
            w.batch_anchor,
        );
    }

    /// Pick the arm the next dispatch runs (explore/exploit).
    fn select(&mut self, policy: &Policy) -> usize {
        policy.select(&self.arms, &mut self.rng)
    }

    /// Fold one measured dispatch in; returns the plan that should
    /// now be served from the cache when the chosen arm changed
    /// (promotion or demotion) or a warm-started winner finished
    /// rebuilding, `None` otherwise.
    fn observe(
        &mut self,
        arm: usize,
        per_request_ms: f64,
        batch: usize,
        cfg: &AutotuneConfig,
    ) -> Option<Arc<Plan>> {
        self.arms[arm].observe(per_request_ms);
        // Regime shift: the batch-width EWMA left the promotion-time
        // anchor — demote to the static plan and re-open exploration
        // with decayed evidence.
        if self.drift.observe(batch) && self.chosen != self.static_idx {
            self.chosen = self.static_idx;
            self.pending_install = false;
            self.demotions += 1;
            self.drift.release();
            for a in &mut self.arms {
                a.decay();
            }
            return self.plans[self.static_idx].clone();
        }
        // Warm start restored a winner the fresh cache doesn't hold:
        // hand it over as soon as its plan exists again.
        if self.pending_install {
            if let Some(p) = self.plans[self.chosen].clone() {
                self.pending_install = false;
                return Some(p);
            }
        }
        self.maybe_extend_ladder(cfg);
        self.maybe_switch(cfg)
    }

    /// Hill-climb: when the best warmed arm sits at the top of its
    /// schedule's thread ladder, extend the ladder one doubling (the
    /// plateau has not been found yet).
    fn maybe_extend_ladder(&mut self, cfg: &AutotuneConfig) {
        if self.variants.len() >= cfg.max_arms {
            return;
        }
        let mut best: Option<usize> = None;
        for (i, a) in self.arms.iter().enumerate() {
            if a.pulls < cfg.warmup {
                continue;
            }
            let better = match best {
                None => true,
                Some(b) => a.mean_ms < self.arms[b].mean_ms,
            };
            if better {
                best = Some(i);
            }
        }
        let Some(b) = best else { return };
        let v = self.variants[b];
        let next = v.n_threads.saturating_mul(2);
        if next > cfg.max_threads.max(1) {
            return;
        }
        let topped = !self
            .variants
            .iter()
            .any(|o| o.schedule == v.schedule && o.n_threads > v.n_threads);
        if topped {
            let candidate =
                Variant { schedule: v.schedule, n_threads: next };
            if !self.variants.contains(&candidate) {
                self.variants.push(candidate);
                self.arms.push(ArmStats::default());
                self.plans.push(None);
            }
        }
    }

    /// Promotion/demotion decision: knee-adjusted best warmed arm vs
    /// the currently chosen one.
    fn maybe_switch(&mut self, cfg: &AutotuneConfig) -> Option<Arc<Plan>> {
        // The baseline needs at least the one pull the initial sweep
        // guarantees it (challengers still need `warmup` pulls, and
        // `min_gain` damps a noisy single-pull baseline).
        if self.arms[self.static_idx].pulls == 0 {
            return None;
        }
        let means: Vec<Option<f64>> = self
            .arms
            .iter()
            .map(|a| (a.pulls >= cfg.warmup).then_some(a.mean_ms))
            .collect();
        let knee = knee_index(&self.variants, &means, cfg.knee_tol)?;
        if knee == self.chosen {
            return None;
        }
        let current = if self.arms[self.chosen].pulls > 0 {
            self.arms[self.chosen].mean_ms
        } else {
            f64::INFINITY
        };
        let challenger = means[knee]?;
        if challenger >= current * (1.0 - cfg.min_gain) {
            return None;
        }
        // A warm-started arm may have statistics but no plan yet; the
        // switch waits until the arm is selected (and built) again.
        let plan = self.plans[knee].clone()?;
        self.chosen = knee;
        self.pending_install = false;
        if knee == self.static_idx {
            self.demotions += 1;
            self.drift.release();
        } else {
            self.promotions += 1;
            self.drift.anchor();
        }
        Some(plan)
    }

    fn summary(&self) -> TunerSummary {
        TunerSummary {
            fingerprint: self.fingerprint,
            name: self.name.clone(),
            static_variant: self.variants[self.static_idx],
            chosen_variant: self.variants[self.chosen],
            static_mean_ms: self.arms[self.static_idx].mean_ms,
            chosen_mean_ms: self.arms[self.chosen].mean_ms,
            observations: self.arms.iter().map(|a| a.pulls).sum(),
            arms: self.variants.len(),
            promotions: self.promotions,
            demotions: self.demotions,
        }
    }

    fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert(
            "fingerprint".into(),
            Json::Str(format!("{:#x}", self.fingerprint)),
        );
        obj.insert("name".into(), Json::Str(self.name.clone()));
        let chosen = self.variants[self.chosen];
        obj.insert(
            "chosen_schedule".into(),
            Json::Str(chosen.schedule.name()),
        );
        obj.insert(
            "chosen_threads".into(),
            Json::Num(chosen.n_threads as f64),
        );
        obj.insert("promotions".into(), Json::Num(self.promotions as f64));
        obj.insert("demotions".into(), Json::Num(self.demotions as f64));
        obj.insert("batch_ewma".into(), Json::Num(self.drift.ewma()));
        obj.insert("batch_anchor".into(), Json::Num(self.drift.anchored()));
        obj.insert(
            "arms".into(),
            Json::Arr(
                self.variants
                    .iter()
                    .zip(&self.arms)
                    .map(|(v, a)| {
                        Json::Obj(
                            [
                                (
                                    "schedule".to_string(),
                                    Json::Str(v.schedule.name()),
                                ),
                                (
                                    "threads".to_string(),
                                    Json::Num(v.n_threads as f64),
                                ),
                                (
                                    "pulls".to_string(),
                                    Json::Num(a.pulls as f64),
                                ),
                                (
                                    "mean_ms".to_string(),
                                    Json::Num(a.mean_ms),
                                ),
                                ("m2".to_string(), Json::Num(a.m2())),
                            ]
                            .into_iter()
                            .collect(),
                        )
                    })
                    .collect(),
            ),
        );
        Json::Obj(obj)
    }
}

/// One tuner's headline state, for reports and acceptance checks.
#[derive(Clone, Debug)]
pub struct TunerSummary {
    pub fingerprint: u64,
    pub name: String,
    pub static_variant: Variant,
    pub chosen_variant: Variant,
    pub static_mean_ms: f64,
    pub chosen_mean_ms: f64,
    pub observations: u64,
    pub arms: usize,
    pub promotions: u64,
    pub demotions: u64,
}

impl TunerSummary {
    /// Did tuning land somewhere other than the static pick?
    pub fn diverged(&self) -> bool {
        self.chosen_variant != self.static_variant
    }
}

/// Thread-safe registry of per-matrix tuners — one per serving
/// engine, shared across its workers.
pub struct Autotuner {
    cfg: AutotuneConfig,
    plan_cfg: PlanConfig,
    inner: Mutex<HashMap<u64, Tuner>>,
    log: Mutex<ObservationLog>,
    warm: HashMap<u64, TunerSnapshot>,
}

impl Autotuner {
    pub fn new(cfg: AutotuneConfig, plan_cfg: PlanConfig) -> Self {
        Autotuner {
            cfg,
            plan_cfg,
            inner: Mutex::new(HashMap::new()),
            log: Mutex::new(ObservationLog::new()),
            warm: HashMap::new(),
        }
    }

    /// Seed tuners from a previous run's [`Autotuner::to_json`]
    /// snapshot: arm statistics, chosen variants, and hill-climb
    /// ladder extensions are restored lazily as matrices reappear.
    pub fn warm_start(mut self, snapshot: &Json) -> Self {
        let Some(tuners) = snapshot.get("tuners").and_then(Json::as_arr)
        else {
            return self;
        };
        for t in tuners {
            let Some(fp) = t
                .get("fingerprint")
                .and_then(Json::as_str)
                .and_then(parse_fingerprint)
            else {
                continue;
            };
            let mut snap = TunerSnapshot {
                name: t
                    .get("name")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string(),
                promotions: t
                    .get("promotions")
                    .and_then(Json::as_f64)
                    .unwrap_or(0.0) as u64,
                demotions: t
                    .get("demotions")
                    .and_then(Json::as_f64)
                    .unwrap_or(0.0) as u64,
                batch_ewma: t
                    .get("batch_ewma")
                    .and_then(Json::as_f64)
                    .unwrap_or(0.0),
                batch_anchor: t
                    .get("batch_anchor")
                    .and_then(Json::as_f64)
                    .unwrap_or(0.0),
                ..TunerSnapshot::default()
            };
            if let (Some(s), Some(th)) = (
                t.get("chosen_schedule").and_then(Json::as_str),
                t.get("chosen_threads").and_then(Json::as_usize),
            ) {
                snap.chosen = Some((s.to_string(), th));
            }
            if let Some(arms) = t.get("arms").and_then(Json::as_arr) {
                for a in arms {
                    let (Some(s), Some(th)) = (
                        a.get("schedule").and_then(Json::as_str),
                        a.get("threads").and_then(Json::as_usize),
                    ) else {
                        continue;
                    };
                    snap.arms.push((
                        s.to_string(),
                        th,
                        a.get("pulls").and_then(Json::as_f64).unwrap_or(0.0)
                            as u64,
                        a.get("mean_ms")
                            .and_then(Json::as_f64)
                            .unwrap_or(0.0),
                        a.get("m2").and_then(Json::as_f64).unwrap_or(0.0),
                    ));
                }
            }
            self.warm.insert(fp, snap);
        }
        self
    }

    pub fn config(&self) -> &AutotuneConfig {
        &self.cfg
    }

    /// Does the owning engine self-observe kernel wall time?
    pub fn wall_clock(&self) -> bool {
        self.cfg.wall_clock
    }

    /// Select the plan variant the next dispatch against `fp` runs.
    /// Creates the tuner (ladder seeded around `static_plan`) on first
    /// sight. Returns the variant's plan and the arm index to feed
    /// back to [`Autotuner::observe`].
    ///
    /// The expensive part — building a not-yet-materialized variant
    /// plan (partitioning, and for CSR5 arms the tile structure,
    /// shared from the static plan's conversion) — runs *outside* the
    /// tuner mutex, the same discipline as `PlanCache::plan_for`; a
    /// concurrent identical build is a benign race (first insert
    /// wins). Arm indices are stable (arms are only ever appended),
    /// so the re-locked insert targets the same slot.
    pub fn plan_for(
        &self,
        fp: u64,
        name: &str,
        static_plan: &Arc<Plan>,
        csr: &Csr,
    ) -> (Arc<Plan>, usize) {
        let (arm, build_ctx) = {
            let mut inner = self.inner.lock().unwrap();
            let tuner = inner.entry(fp).or_insert_with(|| {
                Tuner::new(
                    fp,
                    name,
                    static_plan,
                    &self.cfg,
                    &self.plan_cfg,
                    self.warm.get(&fp),
                )
            });
            let arm = tuner.select(&self.cfg.policy);
            match &tuner.plans[arm] {
                // Post-warmup fast path: no clones beyond the Arc.
                Some(p) => return (p.clone(), arm),
                None => (
                    arm,
                    (
                        tuner.variants[arm],
                        tuner.features.clone(),
                        tuner.plans[tuner.static_idx].clone(),
                    ),
                ),
            }
        };
        let (variant, features, tuner_static) = build_ctx;
        // Packed-format arms (CSR5 tiles, SELL chunks) reuse the
        // static plan's conversion — the ladder only carries a packed
        // format when the static pick did, so one conversion serves
        // the whole arm family.
        let shared = tuner_static
            .as_deref()
            .map(SharedFormats::of)
            .unwrap_or_default();
        let built = Arc::new(build_plan_shared(
            &self.plan_cfg,
            csr,
            variant.schedule,
            variant.n_threads,
            features,
            shared,
        ));
        let mut inner = self.inner.lock().unwrap();
        let tuner = inner.get_mut(&fp).expect("tuner created above");
        let plan = match &tuner.plans[arm] {
            Some(p) => p.clone(),
            None => {
                tuner.plans[arm] = Some(built.clone());
                built
            }
        };
        (plan, arm)
    }

    /// Feed one measured dispatch back: `per_request_ms` is the
    /// per-request share of the dispatch latency, `batch` its
    /// coalesced width. Returns the plan the serving cache should now
    /// install (via [`crate::service::PlanCache::replace`]) when the
    /// observation triggered a promotion or demotion.
    pub fn observe(
        &self,
        fp: u64,
        arm: usize,
        per_request_ms: f64,
        batch: usize,
    ) -> Option<Arc<Plan>> {
        self.observe_staged(
            fp,
            arm,
            per_request_ms,
            batch,
            &StageObs::default(),
        )
    }

    /// [`Autotuner::observe`] carrying the dispatch's measured stage
    /// breakdown (from the span recorder) into the observation
    /// dataset's extra columns — the arm update itself is identical.
    pub fn observe_staged(
        &self,
        fp: u64,
        arm: usize,
        per_request_ms: f64,
        batch: usize,
        stages: &StageObs,
    ) -> Option<Arc<Plan>> {
        if !per_request_ms.is_finite() || per_request_ms < 0.0 {
            return None;
        }
        let mut inner = self.inner.lock().unwrap();
        let tuner = inner.get_mut(&fp)?;
        if arm >= tuner.arms.len() {
            return None;
        }
        self.log.lock().unwrap().record(
            &tuner.features,
            &tuner.variants[arm],
            batch,
            per_request_ms,
            stages,
        );
        tuner.observe(arm, per_request_ms, batch, &self.cfg)
    }

    /// The tuner's currently chosen plan for `fp`, when it differs
    /// from the static arm and has been built — what a plan-cache
    /// rebuild (LRU eviction) must re-install so the promoted winner
    /// survives eviction instead of silently reverting to the static
    /// plan.
    pub fn chosen_plan(&self, fp: u64) -> Option<Arc<Plan>> {
        let inner = self.inner.lock().unwrap();
        let t = inner.get(&fp)?;
        if t.chosen == t.static_idx {
            return None;
        }
        t.plans[t.chosen].clone()
    }

    /// Number of matrices under tuning.
    pub fn tuner_count(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    /// (total promotions, total demotions) across all tuners.
    pub fn totals(&self) -> (u64, u64) {
        let inner = self.inner.lock().unwrap();
        inner.values().fold((0, 0), |(p, d), t| {
            (p + t.promotions, d + t.demotions)
        })
    }

    /// Per-matrix summaries, sorted by matrix name then fingerprint
    /// (stable report order).
    pub fn summaries(&self) -> Vec<TunerSummary> {
        let inner = self.inner.lock().unwrap();
        let mut out: Vec<TunerSummary> =
            inner.values().map(Tuner::summary).collect();
        out.sort_by(|a, b| {
            a.name.cmp(&b.name).then(a.fingerprint.cmp(&b.fingerprint))
        });
        out
    }

    /// Clone-out of the accumulated observation dataset (the
    /// retraining input for the offline planner).
    pub fn dataset(&self) -> Dataset {
        self.log.lock().unwrap().snapshot()
    }

    /// Rows in the observation log — O(1), unlike
    /// [`Autotuner::dataset`], which clones the rows out.
    pub fn dataset_len(&self) -> usize {
        self.log.lock().unwrap().len()
    }

    /// Full tuning state as JSON (see [`Autotuner::warm_start`]).
    pub fn to_json(&self) -> Json {
        let inner = self.inner.lock().unwrap();
        let mut tuners: Vec<&Tuner> = inner.values().collect();
        tuners.sort_by_key(|t| t.fingerprint);
        let mut obj = BTreeMap::new();
        obj.insert("policy".into(), Json::Str(self.cfg.policy.name()));
        obj.insert(
            "tuners".into(),
            Json::Arr(tuners.iter().map(|t| t.to_json()).collect()),
        );
        Json::Obj(obj)
    }
}

fn parse_fingerprint(s: &str) -> Option<u64> {
    let hex = s.strip_prefix("0x").unwrap_or(s);
    u64::from_str_radix(hex, 16).ok()
}

/// The autotune report table (the CLI's `autotune-report` output and
/// the tuned replay's extra block).
pub fn autotune_table(summaries: &[TunerSummary]) -> Table {
    let mut t = Table::new(
        "Autotune report (per-matrix plan tuning)",
        &[
            "matrix", "static plan", "static ms", "tuned plan", "tuned ms",
            "obs", "arms", "promo", "demo",
        ],
    );
    for s in summaries {
        t.row(vec![
            s.name.clone(),
            s.static_variant.name(),
            format!("{:.4}", s.static_mean_ms),
            if s.diverged() {
                s.chosen_variant.name()
            } else {
                format!("{} (=static)", s.chosen_variant.name())
            },
            format!("{:.4}", s.chosen_mean_ms),
            s.observations.to_string(),
            s.arms.to_string(),
            s.promotions.to_string(),
            s.demotions.to_string(),
        ]);
    }
    t
}

/// JSON form of the summaries (rides inside the replay report).
pub fn autotune_json(summaries: &[TunerSummary]) -> Json {
    Json::Arr(
        summaries
            .iter()
            .map(|s| {
                Json::Obj(
                    [
                        (
                            "fingerprint".to_string(),
                            Json::Str(format!("{:#x}", s.fingerprint)),
                        ),
                        ("name".to_string(), Json::Str(s.name.clone())),
                        (
                            "static_plan".to_string(),
                            Json::Str(s.static_variant.name()),
                        ),
                        (
                            "tuned_plan".to_string(),
                            Json::Str(s.chosen_variant.name()),
                        ),
                        (
                            "static_mean_ms".to_string(),
                            Json::Num(s.static_mean_ms),
                        ),
                        (
                            "tuned_mean_ms".to_string(),
                            Json::Num(s.chosen_mean_ms),
                        ),
                        (
                            "observations".to_string(),
                            Json::Num(s.observations as f64),
                        ),
                        (
                            "promotions".to_string(),
                            Json::Num(s.promotions as f64),
                        ),
                        (
                            "demotions".to_string(),
                            Json::Num(s.demotions as f64),
                        ),
                    ]
                    .into_iter()
                    .collect(),
                )
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::generators;
    use crate::service::plan::{build_plan, Planner};
    use crate::util::rng::Pcg32 as TestRng;

    fn setup() -> (Csr, Arc<Plan>, u64) {
        let mut rng = TestRng::new(0xA7A7);
        let csr = generators::random_uniform(400, 6, &mut rng);
        let plan = Arc::new(build_plan(
            &Planner::Heuristic,
            &PlanConfig::default(),
            &csr,
        ));
        let fp = crate::service::registry::fingerprint(&csr);
        (csr, plan, fp)
    }

    /// Synthetic latency model with a knee: per-thread sync cost plus
    /// work that stops scaling at 4 threads — 2 threads is optimal for
    /// small work, 4 for large.
    fn modeled_ms(threads: usize, work_ms: f64) -> f64 {
        let eff = threads.min(4).max(1) as f64;
        0.03 + 0.002 * (threads as f64 - 1.0) + work_ms / eff
    }

    fn drive(
        tuner: &Autotuner,
        csr: &Csr,
        plan: &Arc<Plan>,
        fp: u64,
        rounds: usize,
        work_ms: f64,
        batch: usize,
    ) -> u64 {
        let mut replaced = 0;
        for _ in 0..rounds {
            let (p, arm) = tuner.plan_for(fp, "m", plan, csr);
            let ms = modeled_ms(p.n_threads, work_ms);
            if tuner.observe(fp, arm, ms, batch).is_some() {
                replaced += 1;
            }
        }
        replaced
    }

    #[test]
    fn tuner_finds_the_thread_knee() {
        let (csr, plan, fp) = setup();
        assert_eq!(plan.n_threads, 4, "static default is one core group");
        let tuner =
            Autotuner::new(AutotuneConfig::default(), PlanConfig::default());
        // Small work: the sync term dominates past 1-2 threads, so the
        // knee is *below* the static pick of 4.
        let replaced = drive(&tuner, &csr, &plan, fp, 150, 0.01, 1);
        assert!(replaced >= 1, "a better variant must be promoted");
        let summaries = tuner.summaries();
        let s = &summaries[0];
        assert!(s.diverged(), "tuned pick must leave the static plan");
        assert!(
            s.chosen_variant.n_threads < 4,
            "small work must tune below the static width, got {}",
            s.chosen_variant.n_threads
        );
        assert!(
            s.chosen_mean_ms <= s.static_mean_ms,
            "promotion must not regress: {} vs {}",
            s.chosen_mean_ms,
            s.static_mean_ms
        );
        let (promos, _) = tuner.totals();
        assert!(promos >= 1);
        assert_eq!(tuner.tuner_count(), 1);
        // Observations accumulated for retraining.
        let d = tuner.dataset();
        assert_eq!(d.len(), 150);
        assert_eq!(
            d.n_features(),
            observe::BASE_FEATURES + 3 + observe::STAGE_COLUMNS
        );
    }

    #[test]
    fn staged_observation_lands_in_dataset_columns() {
        let (csr, plan, fp) = setup();
        let tuner =
            Autotuner::new(AutotuneConfig::default(), PlanConfig::default());
        let (_, arm) = tuner.plan_for(fp, "m", &plan, &csr);
        let stages = StageObs {
            plan_lookup_ms: 0.02,
            kernel_ms: 0.5,
            reduce_ms: 0.03,
            imbalance_ms: 0.01,
            overhead_ms: 0.06,
            residual_ms: 0.04,
        };
        tuner.observe_staged(fp, arm, 0.6, 1, &stages);
        let d = tuner.dataset();
        assert_eq!(d.len(), 1);
        let row = &d.x[0];
        assert_eq!(
            row[row.len() - 6..],
            [0.02, 0.5, 0.03, 0.01, 0.06, 0.04]
        );
        // The unstaged path records zeroed stage columns.
        tuner.observe(fp, arm, 0.6, 1);
        let d = tuner.dataset();
        let row = &d.x[1];
        assert_eq!(row[row.len() - 6..], [0.0; 6]);
    }

    #[test]
    fn batch_drift_demotes_and_reopens() {
        let (csr, plan, fp) = setup();
        let cfg = AutotuneConfig {
            drift_ratio: 0.3,
            ..AutotuneConfig::default()
        };
        let tuner = Autotuner::new(cfg, PlanConfig::default());
        drive(&tuner, &csr, &plan, fp, 120, 0.01, 1);
        let before = tuner.summaries();
        assert!(
            before[0].diverged(),
            "setup: promotion must have happened"
        );
        // Traffic regime flips from singletons to wide batches: the
        // EWMA leaves the promotion anchor and the tuner demotes.
        drive(&tuner, &csr, &plan, fp, 50, 0.01, 16);
        let (_, demotions) = tuner.totals();
        assert!(demotions >= 1, "batch-width drift must demote");
    }

    #[test]
    fn snapshot_roundtrip_warm_starts() {
        let (csr, plan, fp) = setup();
        let tuner =
            Autotuner::new(AutotuneConfig::default(), PlanConfig::default());
        drive(&tuner, &csr, &plan, fp, 150, 0.01, 1);
        let snap = tuner.to_json();
        let text = snap.to_string();
        let parsed = crate::util::json::parse(&text).unwrap();
        let warm =
            Autotuner::new(AutotuneConfig::default(), PlanConfig::default())
                .warm_start(&parsed);
        // A few requests re-materialize the tuner with its history
        // (observations match each arm's modeled cost, so arm means —
        // and therefore the chosen variant — are unchanged), and the
        // restored winner must be handed back for a cache re-install
        // as soon as its plan is rebuilt.
        let installs = drive(&warm, &csr, &plan, fp, 20, 0.01, 1);
        assert!(
            installs >= 1,
            "the warm-started winner must be re-installed into the cache"
        );
        let (olds, news) = (tuner.summaries(), warm.summaries());
        let (old, new) = (&olds[0], &news[0]);
        assert_eq!(old.chosen_variant, new.chosen_variant);
        assert_eq!(old.promotions, new.promotions);
        assert!(
            new.observations >= old.observations,
            "warm start must carry the pull history"
        );
    }

    #[test]
    fn tuning_is_deterministic_for_a_seed() {
        let (csr, plan, fp) = setup();
        let run = || {
            let tuner = Autotuner::new(
                AutotuneConfig::default(),
                PlanConfig::default(),
            );
            let mut picks = Vec::new();
            for _ in 0..80 {
                let (p, arm) = tuner.plan_for(fp, "m", &plan, &csr);
                picks.push(arm);
                tuner.observe(fp, arm, modeled_ms(p.n_threads, 0.02), 2);
            }
            (picks, tuner.summaries()[0].chosen_variant)
        };
        let (a, b) = (run(), run());
        assert_eq!(a.0, b.0, "arm sequence must be reproducible");
        assert_eq!(a.1, b.1);
    }

    #[test]
    fn ucb_policy_also_converges() {
        let (csr, plan, fp) = setup();
        let cfg = AutotuneConfig {
            policy: Policy::Ucb1 { c: 0.5 },
            ..AutotuneConfig::default()
        };
        let tuner = Autotuner::new(cfg, PlanConfig::default());
        drive(&tuner, &csr, &plan, fp, 200, 0.01, 1);
        let summaries = tuner.summaries();
        let s = &summaries[0];
        assert!(s.diverged());
        assert!(s.chosen_variant.n_threads < 4);
    }

    #[test]
    fn chosen_plan_survives_cache_eviction_semantics() {
        let (csr, plan, fp) = setup();
        let tuner =
            Autotuner::new(AutotuneConfig::default(), PlanConfig::default());
        // Unknown fingerprints and un-diverged tuners expose nothing.
        assert!(tuner.chosen_plan(0xDEAD).is_none());
        let (_, arm) = tuner.plan_for(fp, "m", &plan, &csr);
        tuner.observe(fp, arm, modeled_ms(plan.n_threads, 0.01), 1);
        assert!(
            tuner.chosen_plan(fp).is_none(),
            "chosen == static must not offer a replacement"
        );
        // After promotion, the winner is available for a cache
        // rebuild (the LRU-eviction re-install path).
        drive(&tuner, &csr, &plan, fp, 150, 0.01, 1);
        let winner = tuner.chosen_plan(fp).expect("promoted winner");
        let summaries = tuner.summaries();
        assert_eq!(winner.n_threads, summaries[0].chosen_variant.n_threads);
    }

    #[test]
    fn sell_arms_share_the_static_conversion() {
        use crate::service::plan::PlannedFormat;
        use crate::sparse::Coo;

        // 4-thread static split [64, 64, 64, 128] -> job_var 0.4: the
        // heuristic's SELL band.
        let mut coo = Coo::new(256, 256);
        for r in 0..256 {
            coo.push(r, r, 1.0);
            if r >= 192 {
                coo.push(r, (r + 1) % 256, 1.0);
            }
        }
        let csr = coo.to_csr();
        let plan = Arc::new(build_plan(
            &Planner::Heuristic,
            &PlanConfig::default(),
            &csr,
        ));
        let PlannedFormat::Sell(s) = &plan.format else {
            panic!("setup: expected a SELL static plan, got {:?}", plan.schedule)
        };
        let fp = crate::service::registry::fingerprint(&csr);
        let tuner =
            Autotuner::new(AutotuneConfig::default(), PlanConfig::default());
        let mut sell_arms_seen = 0usize;
        for _ in 0..80 {
            let (p, arm) = tuner.plan_for(fp, "m", &plan, &csr);
            if let PlannedFormat::Sell(got) = &p.format {
                assert!(
                    Arc::ptr_eq(got, s),
                    "a SELL ladder arm reconverted instead of sharing"
                );
                sell_arms_seen += 1;
            }
            tuner.observe(fp, arm, modeled_ms(p.n_threads, 0.01), 1);
        }
        assert!(sell_arms_seen > 0, "exploration must pull SELL arms");
    }

    #[test]
    fn report_renders_and_observation_guards_hold() {
        let (csr, plan, fp) = setup();
        let tuner =
            Autotuner::new(AutotuneConfig::default(), PlanConfig::default());
        drive(&tuner, &csr, &plan, fp, 30, 0.01, 1);
        let summaries = tuner.summaries();
        let md = autotune_table(&summaries).to_markdown();
        assert!(md.contains("Autotune report"));
        let j = autotune_json(&summaries);
        assert_eq!(j.as_arr().map(|a| a.len()), Some(1));
        // Bad feedback is ignored, never a panic.
        assert!(tuner.observe(0xDEAD, 0, 1.0, 1).is_none());
        assert!(tuner.observe(fp, 9999, 1.0, 1).is_none());
        assert!(tuner.observe(fp, 0, f64::NAN, 1).is_none());
        assert!(tuner.observe(fp, 0, -1.0, 1).is_none());
    }
}
