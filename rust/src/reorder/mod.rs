//! Locality-aware row reordering — §5.2.3's "novel storage format"
//! idea: bring together rows with a similar nonzero distribution so
//! the dense vector `x` is reused while it is still cached.
//!
//! The reorder computes a cheap column *signature* per row (the
//! histogram of column blocks the row touches, reduced to its dominant
//! block and mean column) and stably sorts rows by it. For Fig 9's
//! synthesized matrix — consecutive rows drawing from maximally
//! distant column clusters — this recovers exactly the
//! locality-friendly form on the figure's right side.

use crate::sparse::Csr;

/// How column space is bucketed when fingerprinting rows. Finer blocks
/// separate clusters better but cost more; 64 matches the synthesized
/// workload's cluster count and works well across the corpus.
pub const DEFAULT_BLOCKS: usize = 64;

/// A row-reordering plan: `perm[i]` = source row of output row `i`.
#[derive(Clone, Debug)]
pub struct ReorderPlan {
    pub perm: Vec<usize>,
    pub blocks: usize,
}

impl ReorderPlan {
    /// Identity plan.
    pub fn identity(n: usize) -> Self {
        ReorderPlan { perm: (0..n).collect(), blocks: 0 }
    }

    pub fn apply(&self, csr: &Csr) -> Csr {
        csr.permute_rows(&self.perm)
    }

    /// Inverse permutation (to map permuted `y` back to original row
    /// order after SpMV).
    pub fn inverse(&self) -> Vec<usize> {
        let mut inv = vec![0usize; self.perm.len()];
        for (i, &src) in self.perm.iter().enumerate() {
            inv[src] = i;
        }
        inv
    }
}

/// Compute the locality-aware reordering of `csr`.
pub fn locality_reorder(csr: &Csr, blocks: usize) -> ReorderPlan {
    let n = csr.n_rows;
    let blocks = blocks.clamp(1, csr.n_cols.max(1));
    let block_w = (csr.n_cols.max(1)).div_ceil(blocks);
    // Signature per row: (dominant column block, mean column).
    let mut sig: Vec<(usize, u32, u32)> = Vec::with_capacity(n);
    let mut hist = vec![0u32; blocks];
    for r in 0..n {
        let (cols, _) = csr.row(r);
        if cols.is_empty() {
            // Empty rows go last, keeping relative order.
            sig.push((r, u32::MAX, u32::MAX));
            continue;
        }
        for h in hist.iter_mut() {
            *h = 0;
        }
        let mut sum = 0u64;
        for &c in cols {
            hist[(c as usize / block_w).min(blocks - 1)] += 1;
            sum += c as u64;
        }
        let dominant = hist
            .iter()
            .enumerate()
            .max_by_key(|&(_, &cnt)| cnt)
            .map(|(b, _)| b)
            .unwrap_or(0) as u32;
        let mean = (sum / cols.len() as u64) as u32;
        sig.push((r, dominant, mean));
    }
    // Stable sort by (dominant block, mean column).
    sig.sort_by(|a, b| (a.1, a.2, a.0).cmp(&(b.1, b.2, b.0)));
    ReorderPlan { perm: sig.into_iter().map(|(r, _, _)| r).collect(), blocks }
}

/// Locality score: average column-block overlap between consecutive
/// rows (0 = no reuse, 1 = identical block sets). Used to decide
/// whether reordering is worth the conversion overhead (the paper's
/// "not one-fit-all" caveat).
pub fn locality_score(csr: &Csr, blocks: usize) -> f64 {
    let n = csr.n_rows;
    if n < 2 {
        return 1.0;
    }
    let blocks = blocks.clamp(1, csr.n_cols.max(1));
    let block_w = (csr.n_cols.max(1)).div_ceil(blocks);
    let block_set = |r: usize| -> u64 {
        // Bitmask over up to 64 blocks.
        let (cols, _) = csr.row(r);
        let mut m = 0u64;
        for &c in cols {
            m |= 1u64 << ((c as usize / block_w).min(63));
        }
        m
    };
    let mut score = 0.0;
    let mut prev = block_set(0);
    for r in 1..n {
        let cur = block_set(r);
        let inter = (prev & cur).count_ones() as f64;
        let uni = (prev | cur).count_ones() as f64;
        if uni > 0.0 {
            score += inter / uni;
        } else {
            score += 1.0;
        }
        prev = cur;
    }
    score / (n - 1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::generators::{good_locality, poor_locality};
    use crate::util::rng::Pcg32;

    #[test]
    fn identity_on_already_local() {
        let mut rng = Pcg32::new(5);
        let csr = crate::corpus::generators::banded(256, 5, &mut rng);
        let plan = locality_reorder(&csr, 64);
        let before = locality_score(&csr, 64);
        let after = locality_score(&plan.apply(&csr), 64);
        assert!(
            after >= before - 0.05,
            "reorder must not hurt a banded matrix: {before} -> {after}"
        );
    }

    #[test]
    fn fixes_fig9_matrix() {
        let mut rng = Pcg32::new(9);
        let bad = poor_locality(1024, 4, 64, &mut rng);
        let before = locality_score(&bad, 64);
        let plan = locality_reorder(&bad, 64);
        let fixed = plan.apply(&bad);
        let after = locality_score(&fixed, 64);
        assert!(
            after > before + 0.3,
            "reorder should strongly improve Fig 9 locality: {before} -> {after}"
        );
        // And approach the ideal form's score.
        let mut rng2 = Pcg32::new(9);
        let ideal = good_locality(1024, 4, 64, &mut rng2);
        let ideal_score = locality_score(&ideal, 64);
        assert!(after > 0.8 * ideal_score, "{after} vs ideal {ideal_score}");
    }

    #[test]
    fn perm_is_permutation() {
        let mut rng = Pcg32::new(11);
        let csr = poor_locality(512, 4, 32, &mut rng);
        let plan = locality_reorder(&csr, 64);
        let mut seen = vec![false; 512];
        for &p in &plan.perm {
            assert!(!seen[p]);
            seen[p] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn inverse_roundtrips() {
        let mut rng = Pcg32::new(13);
        let csr = poor_locality(128, 4, 16, &mut rng);
        let plan = locality_reorder(&csr, 64);
        let inv = plan.inverse();
        for (i, &src) in plan.perm.iter().enumerate() {
            assert_eq!(inv[src], i);
        }
    }

    #[test]
    fn spmv_equivalent_up_to_permutation() {
        let mut rng = Pcg32::new(17);
        let csr = poor_locality(256, 4, 16, &mut rng);
        let plan = locality_reorder(&csr, 64);
        let permuted = plan.apply(&csr);
        let x: Vec<f64> = (0..256).map(|_| rng.gen_f64()).collect();
        let mut y0 = vec![0.0; 256];
        let mut y1 = vec![0.0; 256];
        csr.spmv(&x, &mut y0);
        permuted.spmv(&x, &mut y1);
        let inv = plan.inverse();
        for r in 0..256 {
            assert!((y0[r] - y1[inv[r]]).abs() < 1e-12);
        }
    }

    #[test]
    fn empty_rows_handled() {
        let csr = Csr::zero(16, 16);
        let plan = locality_reorder(&csr, 8);
        assert_eq!(plan.perm.len(), 16);
        assert_eq!(locality_score(&csr, 8), 1.0);
    }

    #[test]
    fn score_bounds() {
        let mut rng = Pcg32::new(23);
        let csr = poor_locality(128, 4, 16, &mut rng);
        let s = locality_score(&csr, 64);
        assert!((0.0..=1.0).contains(&s));
    }
}
