//! Instrumented atomics — the capture layer under `check::hb`.
//!
//! Every lock-free cell in the serve core ([`crate::exec::ExecPool`]
//! tallies, [`crate::obs`] span rings and metrics, shard admission
//! round-robin, the allocation probe) holds one of these newtypes
//! instead of a raw `std::sync::atomic` type:
//!
//! - **Release builds (default):** `#[repr(transparent)]` passthrough
//!   wrappers with `#[inline(always)]` methods — bit-identical to the
//!   raw atomic, zero cost (A/B-gated in the `check_overhead` bench).
//! - **`--features hbcheck`:** each op additionally logs a
//!   `(lane, op, address, ordering, seq)` [`Event`] into a global
//!   capture buffer while a [`capture::capture`] window is open. The
//!   offline vector-clock analyzer (`check::hb`) replays that log to
//!   derive happens-before edges from acquire/release pairings and
//!   flag conflicting accesses no edge orders.
//!
//! Capture correctness hinges on one rule: the real atomic op executes
//! *while holding the log lock*, so the event log is an exact
//! linearization of the captured execution — an acquire load that
//! observed a release store is always logged after that store, and the
//! analyzer never pairs an edge backwards.
//!
//! Constructors carry audit metadata (erased in release builds):
//! [`OrdAtomicU64::named`] labels the cell for findings, and
//! [`OrdAtomicU64::racy_ok`] documents a *benign* race (last-writer-
//! wins cells like the trace kernel context) that the analyzer must
//! not report — the cell still participates in edge derivation.
//!
//! The analyzer-facing vocabulary ([`Event`], [`OpKind`], [`MemOrd`])
//! compiles unconditionally so `check::hb::analyze` is testable with
//! synthetic event streams in the default build; only the capture
//! machinery is feature-gated.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

/// What an instrumented operation did to its cell.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum OpKind {
    /// Atomic read.
    Load,
    /// Atomic write (blind — overwrites regardless of current value).
    Store,
    /// Atomic read-modify-write (`fetch_add`, `swap`, ...). RMWs on
    /// the same cell arbitrate atomically and never race each other.
    Rmw,
    /// Pseudo-event: `ExecPool::run` dispatched a job. Everything the
    /// forking lane did so far happens-before every slot's work.
    Fork,
    /// Pseudo-event: `ExecPool::run`'s completion latch released.
    /// Every slot's work happens-before the join point.
    Join,
}

impl OpKind {
    /// Short label for findings ("store", "load", ...).
    pub fn label(self) -> &'static str {
        match self {
            OpKind::Load => "load",
            OpKind::Store => "store",
            OpKind::Rmw => "rmw",
            OpKind::Fork => "fork",
            OpKind::Join => "join",
        }
    }
}

/// Closed mirror of `std::sync::atomic::Ordering` (which is
/// `#[non_exhaustive]` and so cannot be matched exhaustively or used
/// as a map key by the analyzer).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum MemOrd {
    /// No synchronization — morally a plain access the surrounding
    /// protocol (mutex, latch, fork/join) must order.
    Relaxed,
    /// Read side of a release/acquire edge.
    Acquire,
    /// Write side of a release/acquire edge.
    Release,
    /// Both sides (RMW only).
    AcqRel,
    /// Acquire + release + total order.
    SeqCst,
}

impl MemOrd {
    /// Classify a std `Ordering`.
    pub fn of(ord: Ordering) -> Self {
        match ord {
            Ordering::Relaxed => MemOrd::Relaxed,
            Ordering::Acquire => MemOrd::Acquire,
            Ordering::Release => MemOrd::Release,
            Ordering::AcqRel => MemOrd::AcqRel,
            // `Ordering` is #[non_exhaustive]; map anything new to the
            // strongest class rather than miscategorizing it.
            _ => MemOrd::SeqCst,
        }
    }

    /// Does a read at this strength consume release edges?
    pub fn acquires(self) -> bool {
        matches!(self, MemOrd::Acquire | MemOrd::AcqRel | MemOrd::SeqCst)
    }

    /// Does a write at this strength publish a release edge?
    pub fn releases(self) -> bool {
        matches!(self, MemOrd::Release | MemOrd::AcqRel | MemOrd::SeqCst)
    }

    /// Display label ("Relaxed", "Acquire", ...).
    pub fn label(self) -> &'static str {
        match self {
            MemOrd::Relaxed => "Relaxed",
            MemOrd::Acquire => "Acquire",
            MemOrd::Release => "Release",
            MemOrd::AcqRel => "AcqRel",
            MemOrd::SeqCst => "SeqCst",
        }
    }
}

/// One captured atomic operation, in global linearization order.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// Position in the capture log (== linearization order).
    pub seq: usize,
    /// Capturing-thread id (process-unique, assigned on first op).
    pub lane: usize,
    /// Operation class.
    pub op: OpKind,
    /// Cell address. Ptr-to-int only — an opaque map key for the
    /// analyzer, never cast back to a pointer (Miri-clean).
    pub addr: usize,
    /// Declared memory ordering of the op.
    pub ord: MemOrd,
    /// Audit label from the cell's constructor ("pool.jobs", ...).
    pub site: &'static str,
    /// `Some(why)` for cells declared benign-racy at construction;
    /// the analyzer derives edges from them but never reports them.
    pub racy_ok: Option<&'static str>,
}

macro_rules! ord_atomic {
    ($(#[$meta:meta])* $name:ident, $atomic:ident, $prim:ty) => {
        $(#[$meta])*
        #[cfg(not(feature = "hbcheck"))]
        #[repr(transparent)]
        pub struct $name {
            inner: $atomic,
        }

        $(#[$meta])*
        #[cfg(feature = "hbcheck")]
        pub struct $name {
            inner: $atomic,
            site: &'static str,
            racy: Option<&'static str>,
        }

        #[cfg(not(feature = "hbcheck"))]
        impl $name {
            /// Anonymous cell.
            #[inline(always)]
            pub const fn new(v: $prim) -> Self {
                Self { inner: $atomic::new(v) }
            }

            /// Cell labelled for `check::hb` findings. The label is
            /// erased in this (default) build.
            #[inline(always)]
            pub const fn named(v: $prim, _site: &'static str) -> Self {
                Self { inner: $atomic::new(v) }
            }

            /// Cell with a *documented benign race* (last-writer-wins
            /// by design); `check::hb` will not report conflicts on
            /// it. Metadata erased in this (default) build.
            #[inline(always)]
            pub const fn racy_ok(
                v: $prim,
                _site: &'static str,
                _why: &'static str,
            ) -> Self {
                Self { inner: $atomic::new(v) }
            }

            #[inline(always)]
            pub fn load(&self, ord: Ordering) -> $prim {
                self.inner.load(ord)
            }

            #[inline(always)]
            pub fn store(&self, v: $prim, ord: Ordering) {
                self.inner.store(v, ord)
            }

            #[inline(always)]
            pub fn fetch_add(&self, v: $prim, ord: Ordering) -> $prim {
                self.inner.fetch_add(v, ord)
            }

            /// Consume the cell (sole-ownership read — not an atomic
            /// op, so never logged).
            #[inline(always)]
            pub fn into_inner(self) -> $prim {
                self.inner.into_inner()
            }
        }

        #[cfg(feature = "hbcheck")]
        impl $name {
            pub const fn new(v: $prim) -> Self {
                Self::named(v, "(anon)")
            }

            pub const fn named(v: $prim, site: &'static str) -> Self {
                Self { inner: $atomic::new(v), site, racy: None }
            }

            pub const fn racy_ok(
                v: $prim,
                site: &'static str,
                why: &'static str,
            ) -> Self {
                Self { inner: $atomic::new(v), site, racy: Some(why) }
            }

            pub fn load(&self, ord: Ordering) -> $prim {
                capture::logged(
                    OpKind::Load,
                    self.addr(),
                    MemOrd::of(ord),
                    self.site,
                    self.racy,
                    || self.inner.load(ord),
                )
            }

            pub fn store(&self, v: $prim, ord: Ordering) {
                capture::logged(
                    OpKind::Store,
                    self.addr(),
                    MemOrd::of(ord),
                    self.site,
                    self.racy,
                    || self.inner.store(v, ord),
                )
            }

            pub fn fetch_add(&self, v: $prim, ord: Ordering) -> $prim {
                capture::logged(
                    OpKind::Rmw,
                    self.addr(),
                    MemOrd::of(ord),
                    self.site,
                    self.racy,
                    || self.inner.fetch_add(v, ord),
                )
            }

            /// Consume the cell (sole-ownership read — not an atomic
            /// op, so never logged).
            pub fn into_inner(self) -> $prim {
                self.inner.into_inner()
            }

            fn addr(&self) -> usize {
                &self.inner as *const $atomic as usize
            }
        }

        impl Default for $name {
            fn default() -> Self {
                Self::new(0 as $prim)
            }
        }
    };
}

ord_atomic!(
    /// Instrumented `AtomicU64` (see module docs).
    OrdAtomicU64,
    AtomicU64,
    u64
);
ord_atomic!(
    /// Instrumented `AtomicUsize` (see module docs).
    OrdAtomicUsize,
    AtomicUsize,
    usize
);

/// Instrumented `AtomicBool` (see module docs).
#[cfg(not(feature = "hbcheck"))]
#[repr(transparent)]
pub struct OrdAtomicBool {
    inner: AtomicBool,
}

/// Instrumented `AtomicBool` (see module docs).
#[cfg(feature = "hbcheck")]
pub struct OrdAtomicBool {
    inner: AtomicBool,
    site: &'static str,
    racy: Option<&'static str>,
}

#[cfg(not(feature = "hbcheck"))]
impl OrdAtomicBool {
    /// Anonymous cell.
    #[inline(always)]
    pub const fn new(v: bool) -> Self {
        Self { inner: AtomicBool::new(v) }
    }

    /// Cell labelled for `check::hb` findings.
    #[inline(always)]
    pub const fn named(v: bool, _site: &'static str) -> Self {
        Self { inner: AtomicBool::new(v) }
    }

    /// Cell with a documented benign race.
    #[inline(always)]
    pub const fn racy_ok(
        v: bool,
        _site: &'static str,
        _why: &'static str,
    ) -> Self {
        Self { inner: AtomicBool::new(v) }
    }

    #[inline(always)]
    pub fn load(&self, ord: Ordering) -> bool {
        self.inner.load(ord)
    }

    #[inline(always)]
    pub fn store(&self, v: bool, ord: Ordering) {
        self.inner.store(v, ord)
    }

    #[inline(always)]
    pub fn swap(&self, v: bool, ord: Ordering) -> bool {
        self.inner.swap(v, ord)
    }
}

#[cfg(feature = "hbcheck")]
impl OrdAtomicBool {
    pub const fn new(v: bool) -> Self {
        Self::named(v, "(anon)")
    }

    pub const fn named(v: bool, site: &'static str) -> Self {
        Self { inner: AtomicBool::new(v), site, racy: None }
    }

    pub const fn racy_ok(
        v: bool,
        site: &'static str,
        why: &'static str,
    ) -> Self {
        Self { inner: AtomicBool::new(v), site, racy: Some(why) }
    }

    pub fn load(&self, ord: Ordering) -> bool {
        capture::logged(
            OpKind::Load,
            self.addr(),
            MemOrd::of(ord),
            self.site,
            self.racy,
            || self.inner.load(ord),
        )
    }

    pub fn store(&self, v: bool, ord: Ordering) {
        capture::logged(
            OpKind::Store,
            self.addr(),
            MemOrd::of(ord),
            self.site,
            self.racy,
            || self.inner.store(v, ord),
        )
    }

    pub fn swap(&self, v: bool, ord: Ordering) -> bool {
        capture::logged(
            OpKind::Rmw,
            self.addr(),
            MemOrd::of(ord),
            self.site,
            self.racy,
            || self.inner.swap(v, ord),
        )
    }

    fn addr(&self) -> usize {
        &self.inner as *const AtomicBool as usize
    }
}

impl Default for OrdAtomicBool {
    fn default() -> Self {
        Self::new(false)
    }
}

/// Log a fork pseudo-event: everything the calling lane did so far
/// happens-before any captured op that follows on *any* lane.
/// `ExecPool::run` calls this after taking the dispatch lock — the
/// Condvar latch protocol gives `run` `std::thread::scope` semantics,
/// and the analyzer models that with explicit fork/join events rather
/// than by decoding the latch's mutex traffic.
#[cfg(feature = "hbcheck")]
pub fn hb_fork() {
    capture::sync_event(OpKind::Fork);
}

/// Log a join pseudo-event: every captured op so far (all lanes)
/// happens-before anything the calling lane does next. `ExecPool::run`
/// calls this after its completion latch closes.
#[cfg(feature = "hbcheck")]
pub fn hb_join() {
    capture::sync_event(OpKind::Join);
}

/// Event capture machinery (only under `--features hbcheck`).
#[cfg(feature = "hbcheck")]
pub mod capture {
    use super::{Event, MemOrd, OpKind};
    use std::cell::Cell;
    use std::collections::BTreeMap;
    use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    use std::sync::{Mutex, MutexGuard, PoisonError};

    /// Dedup bookkeeping for one lane's most recent load of a cell.
    struct LoadMark {
        ord: MemOrd,
        /// Seq of the logged load this mark describes.
        seq: usize,
        /// `mod_seq[addr]` at the time the load was logged.
        mod_mark: usize,
    }

    /// Log plus the spin-load dedup state; one mutex so the real
    /// atomic op, the log append, and the dedup decision are a single
    /// linearization point.
    struct LogState {
        events: Vec<Event>,
        /// addr -> seq+1 of the last store/rmw to it (0 = never).
        mod_seq: BTreeMap<usize, usize>,
        /// (lane, addr) -> that lane's last *logged* load of addr.
        last_load: BTreeMap<(usize, usize), LoadMark>,
        /// lane -> seq of the lane's last logged event.
        last_event: BTreeMap<usize, usize>,
    }

    static CAPTURING: AtomicBool = AtomicBool::new(false);
    static LOG: Mutex<LogState> = Mutex::new(LogState {
        events: Vec::new(),
        mod_seq: BTreeMap::new(),
        last_load: BTreeMap::new(),
        last_event: BTreeMap::new(),
    });
    static SESSION: Mutex<()> = Mutex::new(());
    static NEXT_LANE: AtomicUsize = AtomicUsize::new(0);

    thread_local! {
        static LANE: Cell<usize> = const { Cell::new(usize::MAX) };
        static IN_LOG: Cell<bool> = const { Cell::new(false) };
    }

    fn lane_id() -> usize {
        LANE.with(|l| {
            if l.get() == usize::MAX {
                l.set(NEXT_LANE.fetch_add(1, Ordering::Relaxed));
            }
            l.get()
        })
    }

    fn lock_log() -> MutexGuard<'static, LogState> {
        LOG.lock().unwrap_or_else(PoisonError::into_inner)
    }

    impl LogState {
        fn clear(&mut self) {
            self.events.clear();
            self.mod_seq.clear();
            self.last_load.clear();
            self.last_event.clear();
        }

        /// Spin-load dedup: a load may be skipped iff the same lane
        /// already logged an identical load of the cell, has logged
        /// *nothing since* (so its analyzer vector clock is unchanged
        /// and the skipped load is VC-identical to the logged one),
        /// and the cell has not been modified since (so the skipped
        /// load cannot carry a new release/acquire edge). This bounds
        /// a spin-wait loop to one logged load per observed
        /// modification — even with several lanes spinning at once —
        /// without ever dropping an event the analyzer needs.
        fn dup_load(&self, lane: usize, addr: usize, ord: MemOrd) -> bool {
            let Some(m) = self.last_load.get(&(lane, addr)) else {
                return false;
            };
            m.ord == ord
                && self.last_event.get(&lane) == Some(&m.seq)
                && self.mod_seq.get(&addr).copied().unwrap_or(0)
                    == m.mod_mark
        }

        fn push(
            &mut self,
            lane: usize,
            op: OpKind,
            addr: usize,
            ord: MemOrd,
            site: &'static str,
            racy_ok: Option<&'static str>,
        ) {
            let seq = self.events.len();
            self.events.push(Event {
                seq,
                lane,
                op,
                addr,
                ord,
                site,
                racy_ok,
            });
            self.last_event.insert(lane, seq);
            match op {
                OpKind::Load => {
                    let mod_mark =
                        self.mod_seq.get(&addr).copied().unwrap_or(0);
                    self.last_load.insert(
                        (lane, addr),
                        LoadMark { ord, seq, mod_mark },
                    );
                }
                OpKind::Store | OpKind::Rmw => {
                    self.mod_seq.insert(addr, seq + 1);
                }
                OpKind::Fork | OpKind::Join => {}
            }
        }
    }

    /// Perform `do_op`, logging it if a capture window is open.
    ///
    /// The op runs under the log lock so the log is an exact
    /// linearization (see module docs): an acquire load that observed
    /// a release store is always logged after that store. A
    /// thread-local reentrancy flag keeps the bookkeeping safe — the
    /// log structures may allocate → allocator → allocprobe's
    /// *instrumented* counter → back here; the inner op then runs
    /// unlogged instead of self-deadlocking.
    pub(crate) fn logged<T>(
        op: OpKind,
        addr: usize,
        ord: MemOrd,
        site: &'static str,
        racy_ok: Option<&'static str>,
        do_op: impl FnOnce() -> T,
    ) -> T {
        if !CAPTURING.load(Ordering::Acquire) {
            return do_op();
        }
        if IN_LOG.with(Cell::get) {
            return do_op();
        }
        IN_LOG.with(|g| g.set(true));
        let lane = lane_id();
        let out;
        {
            let mut log = lock_log();
            out = do_op();
            if !(op == OpKind::Load && log.dup_load(lane, addr, ord)) {
                log.push(lane, op, addr, ord, site, racy_ok);
            }
        }
        IN_LOG.with(|g| g.set(false));
        out
    }

    /// Log a fork/join pseudo-event for the calling lane.
    pub(crate) fn sync_event(op: OpKind) {
        logged(op, 0, MemOrd::SeqCst, "exec.pool.latch", None, || ());
    }

    /// Run `f` with event capture on; return its result plus the
    /// captured log. Captures serialize process-wide (parallel test
    /// threads would otherwise interleave two captures into one log).
    pub fn capture<R>(f: impl FnOnce() -> R) -> (R, Vec<Event>) {
        let _session =
            SESSION.lock().unwrap_or_else(PoisonError::into_inner);
        struct Off;
        impl Drop for Off {
            fn drop(&mut self) {
                CAPTURING.store(false, Ordering::SeqCst);
            }
        }
        lock_log().clear();
        CAPTURING.store(true, Ordering::SeqCst);
        let off = Off;
        let out = f();
        drop(off);
        let events = std::mem::take(&mut lock_log().events);
        (out, events)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memord_classifies_std_orderings() {
        assert_eq!(MemOrd::of(Ordering::Relaxed), MemOrd::Relaxed);
        assert_eq!(MemOrd::of(Ordering::Acquire), MemOrd::Acquire);
        assert_eq!(MemOrd::of(Ordering::Release), MemOrd::Release);
        assert_eq!(MemOrd::of(Ordering::AcqRel), MemOrd::AcqRel);
        assert_eq!(MemOrd::of(Ordering::SeqCst), MemOrd::SeqCst);
        assert!(MemOrd::Acquire.acquires());
        assert!(!MemOrd::Acquire.releases());
        assert!(MemOrd::Release.releases());
        assert!(!MemOrd::Release.acquires());
        assert!(MemOrd::AcqRel.acquires() && MemOrd::AcqRel.releases());
        assert!(MemOrd::SeqCst.acquires() && MemOrd::SeqCst.releases());
        assert!(!MemOrd::Relaxed.acquires() && !MemOrd::Relaxed.releases());
    }

    #[test]
    fn passthrough_semantics_match_raw_atomics() {
        let u = OrdAtomicU64::named(7, "test.u64");
        assert_eq!(u.load(Ordering::Relaxed), 7);
        assert_eq!(u.fetch_add(3, Ordering::Relaxed), 7);
        u.store(42, Ordering::Release);
        assert_eq!(u.load(Ordering::Acquire), 42);

        let s = OrdAtomicUsize::racy_ok(1, "test.usize", "test cell");
        assert_eq!(s.fetch_add(1, Ordering::Relaxed), 1);
        assert_eq!(s.load(Ordering::Relaxed), 2);

        let b = OrdAtomicBool::named(false, "test.bool");
        assert!(!b.swap(true, Ordering::Relaxed));
        assert!(b.load(Ordering::Relaxed));
        b.store(false, Ordering::Relaxed);
        assert!(!b.load(Ordering::Relaxed));

        assert_eq!(OrdAtomicU64::default().load(Ordering::Relaxed), 0);
        assert!(!OrdAtomicBool::default().load(Ordering::Relaxed));
    }

    #[cfg(feature = "hbcheck")]
    #[test]
    fn capture_logs_ops_in_linearization_order() {
        let cell = OrdAtomicU64::named(0, "test.cap");
        let ((), events) = capture::capture(|| {
            cell.store(1, Ordering::Relaxed);
            cell.fetch_add(1, Ordering::Relaxed);
            let _ = cell.load(Ordering::Acquire);
        });
        let ours: Vec<_> =
            events.iter().filter(|e| e.site == "test.cap").collect();
        assert_eq!(ours.len(), 3);
        assert_eq!(ours[0].op, OpKind::Store);
        assert_eq!(ours[1].op, OpKind::Rmw);
        assert_eq!(ours[2].op, OpKind::Load);
        assert_eq!(ours[2].ord, MemOrd::Acquire);
        assert!(ours[0].seq < ours[1].seq && ours[1].seq < ours[2].seq);
        // Same thread, same cell => same lane and address throughout.
        assert!(ours.iter().all(|e| e.lane == ours[0].lane));
        assert!(ours.iter().all(|e| e.addr == ours[0].addr));
    }

    #[cfg(feature = "hbcheck")]
    #[test]
    fn capture_dedups_spin_loads() {
        let cell = OrdAtomicUsize::named(0, "test.spin");
        let ((), events) = capture::capture(|| {
            for _ in 0..1000 {
                let _ = cell.load(Ordering::Acquire);
            }
            cell.store(1, Ordering::Relaxed);
            let _ = cell.load(Ordering::Acquire);
        });
        let ours: Vec<_> =
            events.iter().filter(|e| e.site == "test.spin").collect();
        // 1000 spins collapse to one load; the store un-dedups the
        // final load.
        assert_eq!(ours.len(), 3);
        assert_eq!(ours[0].op, OpKind::Load);
        assert_eq!(ours[1].op, OpKind::Store);
        assert_eq!(ours[2].op, OpKind::Load);
    }

    #[cfg(feature = "hbcheck")]
    #[test]
    fn capture_off_means_no_logging() {
        let cell = OrdAtomicU64::named(0, "test.off");
        cell.store(5, Ordering::Relaxed);
        let ((), events) = capture::capture(|| ());
        assert!(events.iter().all(|e| e.site != "test.off"));
    }
}
