//! ASCII/markdown table rendering for the bench harness — every bench
//! target prints the same rows/series the paper's tables and figures
//! report.

/// A simple column-aligned table with a title.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        Table {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> =
            self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    /// Render as a GitHub-flavored markdown table.
    pub fn to_markdown(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        out.push_str(&format!("### {}\n\n", self.title));
        let fmt_row = |cells: &[String]| {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<width$} |", c, width = w[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header));
        let mut sep = String::from("|");
        for wi in &w {
            sep.push_str(&format!("{:-<width$}|", "", width = wi + 2));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.to_markdown());
    }
}

/// Format helpers used across the benches.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}
pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}
pub fn fx(x: f64) -> String {
    format!("{x:.2}x")
}
pub fn gflops(x: f64) -> String {
    format!("{x:.3} Gflops")
}
pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

/// A text "series" line for figure-shaped outputs: name followed by
/// (x, y) points, one figure series per line.
pub fn series(name: &str, points: &[(f64, f64)]) -> String {
    let body: Vec<String> =
        points.iter().map(|(x, y)| format!("({x:.3},{y:.3})")).collect();
    format!("series {name}: {}", body.join(" "))
}

/// Sparkline-ish ASCII scatter for quick visual inspection in terminals
/// (rows = value buckets, cols = x buckets).
pub fn ascii_scatter(
    xs: &[f64],
    ys: &[f64],
    cols: usize,
    rows: usize,
) -> String {
    assert_eq!(xs.len(), ys.len());
    if xs.is_empty() {
        return String::new();
    }
    let (xmin, xmax) = xs
        .iter()
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &v| {
            (lo.min(v), hi.max(v))
        });
    let (ymin, ymax) = ys
        .iter()
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &v| {
            (lo.min(v), hi.max(v))
        });
    let mut grid = vec![vec![b' '; cols]; rows];
    for (&x, &y) in xs.iter().zip(ys) {
        let cx = if xmax > xmin {
            (((x - xmin) / (xmax - xmin)) * (cols - 1) as f64) as usize
        } else {
            0
        };
        let cy = if ymax > ymin {
            (((y - ymin) / (ymax - ymin)) * (rows - 1) as f64) as usize
        } else {
            0
        };
        grid[rows - 1 - cy][cx] = b'*';
    }
    let mut out = String::new();
    out.push_str(&format!("  y in [{ymin:.2}, {ymax:.2}]\n"));
    for row in grid {
        out.push_str("  |");
        out.push_str(std::str::from_utf8(&row).unwrap());
        out.push('\n');
    }
    out.push_str("  +");
    out.push_str(&"-".repeat(cols));
    out.push('\n');
    out.push_str(&format!("  x in [{xmin:.3}, {xmax:.3}]\n"));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_markdown() {
        let mut t = Table::new("Table 2", &["#threads", "speedup"]);
        t.row(vec!["1".into(), "1.00x".into()]);
        t.row(vec!["4".into(), "1.93x".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Table 2"));
        assert!(md.contains("| 1.93x"));
        assert_eq!(md.lines().count(), 6);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn rejects_bad_arity() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn scatter_contains_points() {
        let s = ascii_scatter(&[0.0, 1.0], &[0.0, 1.0], 10, 5);
        assert!(s.contains('*'));
    }

    #[test]
    fn series_format() {
        let s = series("ft2000", &[(1.0, 1.0), (2.0, 1.5)]);
        assert!(s.starts_with("series ft2000:"));
        assert!(s.contains("(2.000,1.500)"));
    }
}
