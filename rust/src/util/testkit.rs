//! Property-testing helpers (proptest is not available offline).
//!
//! `check` runs a predicate over N generated cases with deterministic
//! seeds and reports the failing seed on the first counterexample, so a
//! failure is reproducible by construction.

use super::rng::Pcg32;

/// Run `prop` for `cases` deterministic cases. On failure, panics with
/// the case index and seed so the exact input can be regenerated.
pub fn check<F: FnMut(&mut Pcg32) -> Result<(), String>>(
    name: &str,
    cases: usize,
    mut prop: F,
) {
    for case in 0..cases {
        let seed = 0xF72000u64 ^ ((case as u64) << 17) ^ 0x5EED;
        let mut rng = Pcg32::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Assert helper producing `Result<(), String>` for use inside `check`.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("trivial", 50, |rng| {
            let n = rng.gen_range(100) + 1;
            prop_assert!(n >= 1 && n <= 100, "n out of range: {n}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn reports_failures() {
        check("always-fails", 3, |_| Err("nope".into()));
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first: Vec<u64> = vec![];
        check("record", 5, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second: Vec<u64> = vec![];
        check("record", 5, |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
