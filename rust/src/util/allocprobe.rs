//! Allocation-counting probe — the zero-allocation regression
//! instrument (mirroring `sched::partition_calls()` for partitions).
//!
//! [`CountingAllocator`] wraps the system allocator and counts every
//! `alloc`/`realloc` call into a global atomic **and** a per-thread
//! counter. The library only provides the type and the counters; a
//! test binary opts in by installing it:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: ft2000_spmv::util::allocprobe::CountingAllocator =
//!     ft2000_spmv::util::allocprobe::CountingAllocator;
//! ```
//!
//! `tests/alloc.rs` uses it to prove the pooled steady-state serve
//! path performs zero heap allocations per request. Counters are
//! monotone; compare two readings around the code under test.
//! Deallocations are not counted — the property under test is "no
//! new memory is requested", and frees pair with counted allocs.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};

// Deliberately a *raw* atomic, not `util::ordatomic::OrdAtomicU64`:
// this counter is bumped from inside the global allocator, and the
// hbcheck capture path takes a mutex and grows a `Vec` — logging an
// event from within `alloc()` would re-enter the allocator under
// that lock. The probe is observation-only and never synchronizes.
static TOTAL_ALLOCS: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// Heap allocations (alloc + realloc) observed process-wide so far.
/// Always valid to call; stays 0 unless a binary installed
/// [`CountingAllocator`] as its global allocator.
pub fn total_allocs() -> u64 {
    // ord: Relaxed load — monotone counter snapshot; readers compare
    // two readings around a quiesced region, no ordering needed.
    TOTAL_ALLOCS.load(Ordering::Relaxed)
}

/// Heap allocations made by the *current thread* so far (the
/// `partition_calls()`-style probe). Note that pooled executors run
/// kernel slots on resident worker threads — cross-thread effects
/// only show up in [`total_allocs`].
pub fn thread_allocs() -> u64 {
    THREAD_ALLOCS.with(|c| c.get())
}

#[inline]
fn count_one() {
    // ord: Relaxed RMW — monotone counter inside the allocator; must
    // stay lock-free and allocation-free, and carries no ordering.
    TOTAL_ALLOCS.fetch_add(1, Ordering::Relaxed);
    // `try_with`: TLS may already be torn down during thread exit;
    // losing those few counts is fine, panicking in the allocator is
    // not.
    let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
}

/// System allocator wrapper that counts allocation calls. Install
/// with `#[global_allocator]` in a test binary (see module docs).
pub struct CountingAllocator;

// SAFETY: delegates every operation verbatim to `System`; the
// counters are lock-free and allocation-free.
unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        count_one();
        // SAFETY: caller upholds `GlobalAlloc::alloc`'s contract
        // (non-zero-sized `layout`); forwarded verbatim to `System`.
        unsafe { System.alloc(layout) }
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        count_one();
        // SAFETY: same contract as `alloc`, forwarded verbatim.
        unsafe { System.alloc_zeroed(layout) }
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        count_one();
        // SAFETY: caller guarantees `ptr` came from this allocator
        // with `layout` and `new_size` is non-zero; `System` is the
        // allocator every method of this wrapper delegates to.
        unsafe { System.realloc(ptr, layout, new_size) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        // SAFETY: caller guarantees `ptr` was allocated by this
        // allocator (i.e. by `System`) with `layout`.
        unsafe { System.dealloc(ptr, layout) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The allocator is not installed in the library test binary, so
    // these exercise the counters directly — enough for Miri to check
    // the atomic/TLS interplay without a `#[global_allocator]`.
    #[test]
    fn counters_are_monotone_and_thread_local() {
        let g0 = total_allocs();
        let t0 = thread_allocs();
        count_one();
        count_one();
        assert!(total_allocs() >= g0 + 2);
        assert_eq!(thread_allocs(), t0 + 2);
        // Another thread's counts reach the global, not our TLS.
        let t_before = thread_allocs();
        std::thread::spawn(count_one).join().unwrap();
        assert_eq!(thread_allocs(), t_before);
        assert!(total_allocs() >= g0 + 3);
    }
}
