//! Allocation-counting probe — the zero-allocation regression
//! instrument (mirroring `sched::partition_calls()` for partitions).
//!
//! [`CountingAllocator`] wraps the system allocator and counts every
//! `alloc`/`realloc` call into a global atomic **and** a per-thread
//! counter. The library only provides the type and the counters; a
//! test binary opts in by installing it:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: ft2000_spmv::util::allocprobe::CountingAllocator =
//!     ft2000_spmv::util::allocprobe::CountingAllocator;
//! ```
//!
//! `tests/alloc.rs` uses it to prove the pooled steady-state serve
//! path performs zero heap allocations per request. Counters are
//! monotone; compare two readings around the code under test.
//! Deallocations are not counted — the property under test is "no
//! new memory is requested", and frees pair with counted allocs.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};

static TOTAL_ALLOCS: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// Heap allocations (alloc + realloc) observed process-wide so far.
/// Always valid to call; stays 0 unless a binary installed
/// [`CountingAllocator`] as its global allocator.
pub fn total_allocs() -> u64 {
    TOTAL_ALLOCS.load(Ordering::Relaxed)
}

/// Heap allocations made by the *current thread* so far (the
/// `partition_calls()`-style probe). Note that pooled executors run
/// kernel slots on resident worker threads — cross-thread effects
/// only show up in [`total_allocs`].
pub fn thread_allocs() -> u64 {
    THREAD_ALLOCS.with(|c| c.get())
}

#[inline]
fn count_one() {
    TOTAL_ALLOCS.fetch_add(1, Ordering::Relaxed);
    // `try_with`: TLS may already be torn down during thread exit;
    // losing those few counts is fine, panicking in the allocator is
    // not.
    let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
}

/// System allocator wrapper that counts allocation calls. Install
/// with `#[global_allocator]` in a test binary (see module docs).
pub struct CountingAllocator;

// SAFETY: delegates every operation verbatim to `System`; the
// counters are lock-free and allocation-free.
unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        count_one();
        // SAFETY: caller upholds `GlobalAlloc::alloc`'s contract
        // (non-zero-sized `layout`); forwarded verbatim to `System`.
        unsafe { System.alloc(layout) }
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        count_one();
        // SAFETY: same contract as `alloc`, forwarded verbatim.
        unsafe { System.alloc_zeroed(layout) }
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        count_one();
        // SAFETY: caller guarantees `ptr` came from this allocator
        // with `layout` and `new_size` is non-zero; `System` is the
        // allocator every method of this wrapper delegates to.
        unsafe { System.realloc(ptr, layout, new_size) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        // SAFETY: caller guarantees `ptr` was allocated by this
        // allocator (i.e. by `System`) with `layout`.
        unsafe { System.dealloc(ptr, layout) }
    }
}
