//! Deterministic PRNG + distributions.
//!
//! PCG-XSH-RR 64/32 for the stream, SplitMix64 for seeding. All corpus
//! generation is keyed by explicit seeds so every experiment in
//! EXPERIMENTS.md is bit-reproducible.

/// SplitMix64 — used to expand a user seed into PCG state/stream pairs.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG-XSH-RR 64/32: small, fast, statistically solid.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let state = splitmix64(&mut sm);
        let inc = splitmix64(&mut sm) | 1;
        let mut rng = Pcg32 { state: 0, inc };
        rng.state = state.wrapping_add(inc);
        rng.next_u32();
        rng
    }

    /// Derive an independent child stream (for per-matrix seeding).
    pub fn fork(&mut self, tag: u64) -> Pcg32 {
        let s = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Pcg32::new(s)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn gen_f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.gen_f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn gen_normal(&mut self) -> f64 {
        loop {
            let u1 = self.gen_f64();
            if u1 > 1e-12 {
                let u2 = self.gen_f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Zipf-distributed integer in [0, n) with exponent `s` (rejection
    /// sampling; used for power-law / social-network row degrees).
    pub fn gen_zipf(&mut self, n: usize, s: f64) -> usize {
        // Inverse-CDF on a truncated harmonic approximation.
        debug_assert!(n > 0);
        if n == 1 {
            return 0;
        }
        let nf = n as f64;
        loop {
            let u = self.gen_f64();
            // Approximate inverse CDF for P(k) ~ k^-s on [1, n].
            let k = if (s - 1.0).abs() < 1e-9 {
                nf.powf(u)
            } else {
                let t = 1.0 - s;
                ((nf.powf(t) - 1.0) * u + 1.0).powf(1.0 / t)
            };
            let k = k.floor() as usize;
            if k >= 1 && k <= n {
                return k - 1;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct values from [0, n) (partial Fisher–Yates for
    /// dense k, rejection via sort/dedup for sparse k).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct from {n}");
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all
        } else {
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let v = self.gen_range(n);
                if seen.insert(v) {
                    out.push(v);
                }
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Pcg32::new(7);
        for n in [1usize, 2, 3, 10, 1000] {
            for _ in 0..200 {
                assert!(r.gen_range(n) < n);
            }
        }
    }

    #[test]
    fn gen_range_covers() {
        let mut r = Pcg32::new(9);
        let mut seen = [false; 8];
        for _ in 0..500 {
            seen[r.gen_range(8)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Pcg32::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.gen_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn zipf_skewed() {
        let mut r = Pcg32::new(13);
        let n = 1000;
        let mut lo = 0usize;
        for _ in 0..5000 {
            let k = r.gen_zipf(n, 1.5);
            assert!(k < n);
            if k < 10 {
                lo += 1;
            }
        }
        // A zipf(1.5) draw lands in the first 10 ranks far more often
        // than uniform (which would be ~1%).
        assert!(lo > 2000, "lo={lo}");
    }

    #[test]
    fn sample_distinct_properties() {
        let mut r = Pcg32::new(17);
        for (n, k) in [(10, 10), (100, 5), (50, 40), (1, 1), (7, 0)] {
            let s = r.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k, "duplicates for n={n} k={k}");
            assert!(s.iter().all(|&v| v < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(19);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_independent() {
        let mut root = Pcg32::new(23);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
