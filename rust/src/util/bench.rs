//! Wall-clock micro-bench harness (criterion is not available offline).
//!
//! Implements the paper's measurement protocol: repeat until the 95%
//! confidence interval of the mean is within a target fraction (the
//! paper uses 5%) of the mean, with a warm-up phase and iteration caps.

use std::time::Instant;

use super::stats;

#[derive(Clone, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// Stop when ci95 half-width / mean falls below this.
    pub target_rel_ci: f64,
    /// Hard wall-clock cap per benchmark (seconds).
    pub max_seconds: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 2,
            min_iters: 5,
            max_iters: 200,
            target_rel_ci: 0.05,
            max_seconds: 10.0,
        }
    }
}

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub ci95_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchResult {
    pub fn summary(&self) -> String {
        format!(
            "{:<40} {:>12.6}s ±{:>10.6}s  ({} iters, min {:.6}s)",
            self.name, self.mean_s, self.ci95_s, self.iters, self.min_s
        )
    }
}

/// Run `f` under the measurement protocol and return timing stats.
pub fn bench<F: FnMut()>(
    name: &str,
    cfg: &BenchConfig,
    mut f: F,
) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let started = Instant::now();
    let mut samples: Vec<f64> = Vec::with_capacity(cfg.max_iters);
    loop {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        let n = samples.len();
        if n >= cfg.min_iters {
            let m = stats::mean(&samples);
            let hw = stats::ci95_half_width(&samples);
            let rel = if m > 0.0 { hw / m } else { 0.0 };
            if rel <= cfg.target_rel_ci
                || n >= cfg.max_iters
                || started.elapsed().as_secs_f64() > cfg.max_seconds
            {
                break;
            }
        }
    }
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_s: stats::mean(&samples),
        ci95_s: stats::ci95_half_width(&samples),
        min_s: samples.iter().cloned().fold(f64::INFINITY, f64::min),
        max_s: samples.iter().cloned().fold(0.0, f64::max),
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let cfg = BenchConfig {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 10,
            target_rel_ci: 0.5,
            max_seconds: 2.0,
        };
        let mut acc = 0u64;
        let r = bench("spin", &cfg, || {
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
        });
        assert!(r.iters >= 3);
        assert!(r.mean_s > 0.0);
        assert!(r.min_s <= r.mean_s && r.mean_s <= r.max_s + 1e-12);
    }

    #[test]
    fn respects_max_iters() {
        let cfg = BenchConfig {
            warmup_iters: 0,
            min_iters: 2,
            max_iters: 4,
            target_rel_ci: 0.0, // unattainable -> must stop at cap
            max_seconds: 60.0,
        };
        let r = bench("noop", &cfg, || {});
        assert!(r.iters <= 4);
    }
}
