//! Minimal JSON parser + writer (enough for `artifacts/manifest.json`
//! and campaign result files). Offline environment — no serde facade.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, ParseError> {
        Err(ParseError { pos: self.pos, msg: msg.into() })
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(format!("expected '{}'", c as char))
        }
    }

    fn eat_lit(&mut self, lit: &str) -> Result<(), ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(())
        } else {
            self.err(format!("expected '{lit}'"))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.eat_lit("true").map(|_| Json::Bool(true)),
            Some(b'f') => self.eat_lit("false").map(|_| Json::Bool(false)),
            Some(b'n') => self.eat_lit("null").map(|_| Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("unexpected character"),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| ParseError {
                                    pos: self.pos,
                                    msg: "bad \\u escape".into(),
                                })?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| {
                                    ParseError {
                                        pos: self.pos,
                                        msg: "bad \\u escape".into(),
                                    }
                                })?,
                                16,
                            )
                            .map_err(|_| ParseError {
                                pos: self.pos,
                                msg: "bad \\u escape".into(),
                            })?;
                            out.push(
                                char::from_u32(code).unwrap_or('\u{FFFD}'),
                            );
                            self.pos += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.pos += 1;
                }
                Some(c) => {
                    // Pass UTF-8 bytes through verbatim.
                    let start = self.pos;
                    let len = if c < 0x80 {
                        1
                    } else if c >> 5 == 0b110 {
                        2
                    } else if c >> 4 == 0b1110 {
                        3
                    } else {
                        4
                    };
                    let chunk =
                        self.bytes.get(start..start + len).ok_or_else(|| {
                            ParseError { pos: start, msg: "bad utf8".into() }
                        })?;
                    out.push_str(std::str::from_utf8(chunk).map_err(|_| {
                        ParseError { pos: start, msg: "bad utf8".into() }
                    })?);
                    self.pos += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        match s.parse::<f64>() {
            Ok(n) => Ok(Json::Num(n)),
            Err(_) => self.err(format!("bad number '{s}'")),
        }
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, ParseError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => {
                            write!(f, "\\u{:04x}", c as u32)?
                        }
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let doc = r#"{
            "format": "hlo-text",
            "artifacts": [
                {"name": "ell_spmv_m1024_k8", "kind": "ell",
                 "rows": 1024, "k": 8, "n": 1024,
                 "file": "ell_spmv_m1024_k8.hlo.txt"}
            ]
        }"#;
        let j = parse(doc).unwrap();
        assert_eq!(j.get("format").unwrap().as_str(), Some("hlo-text"));
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        assert_eq!(arts[0].get("rows").unwrap().as_usize(), Some(1024));
    }

    #[test]
    fn roundtrip() {
        let doc = r#"{"a":[1,2.5,-3],"b":"x\"y","c":true,"d":null}"#;
        let j = parse(doc).unwrap();
        let j2 = parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn escapes() {
        let j = parse(r#""line\nbreak A""#).unwrap();
        assert_eq!(j.as_str(), Some("line\nbreak A"));
    }

    #[test]
    fn nested() {
        let j = parse(r#"[[[{"k":[{"v":1}]}]]]"#).unwrap();
        let inner = j.as_arr().unwrap()[0].as_arr().unwrap()[0]
            .as_arr()
            .unwrap()[0]
            .get("k")
            .unwrap()
            .as_arr()
            .unwrap()[0]
            .get("v")
            .unwrap()
            .as_f64();
        assert_eq!(inner, Some(1.0));
    }
}
