//! Statistics helpers shared by the harness, the model, and reports.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population variance; 0 for empty input.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Geometric mean of strictly-positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// p-th percentile (0..=100) by linear interpolation on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Half-width of the 95% confidence interval of the mean
/// (normal approximation — the paper's stopping rule for timing runs).
pub fn ci95_half_width(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return f64::INFINITY;
    }
    let sd = {
        let m = mean(xs);
        let s2 = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (xs.len() - 1) as f64;
        s2.sqrt()
    };
    1.96 * sd / (xs.len() as f64).sqrt()
}

/// Pearson correlation coefficient.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    if xs.len() < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        dx += (x - mx) * (x - mx);
        dy += (y - my) * (y - my);
    }
    if dx == 0.0 || dy == 0.0 {
        0.0
    } else {
        num / (dx * dy).sqrt()
    }
}

/// Equal-width binned averages over [min, max] — the paper's Fig 6
/// "integral histogram of the speedup results" (bar charts b/d/f).
///
/// Returns (bin_center, mean_of_ys_in_bin, count) for non-empty bins.
pub fn binned_mean(
    xs: &[f64],
    ys: &[f64],
    bins: usize,
) -> Vec<(f64, f64, usize)> {
    assert_eq!(xs.len(), ys.len());
    if xs.is_empty() || bins == 0 {
        return vec![];
    }
    let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let width = if hi > lo { (hi - lo) / bins as f64 } else { 1.0 };
    let mut sums = vec![0.0; bins];
    let mut counts = vec![0usize; bins];
    for (x, y) in xs.iter().zip(ys) {
        let mut b = ((x - lo) / width) as usize;
        if b >= bins {
            b = bins - 1;
        }
        sums[b] += y;
        counts[b] += 1;
    }
    (0..bins)
        .filter(|&b| counts[b] > 0)
        .map(|b| {
            (
                lo + (b as f64 + 0.5) * width,
                sums[b] / counts[b] as f64,
                counts[b],
            )
        })
        .collect()
}

/// Min-max normalization to [0,1] (the paper normalizes nnz_var for
/// Fig 6 e/f).
pub fn minmax_normalize(xs: &[f64]) -> Vec<f64> {
    let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if !(hi > lo) {
        return vec![0.0; xs.len()];
    }
    xs.iter().map(|x| (x - lo) / (hi - lo)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_var_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(variance(&[]), 0.0);
    }

    #[test]
    fn geomean_of_powers() {
        let xs = [1.0, 4.0];
        assert!((geomean(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interp() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
    }

    #[test]
    fn ci_shrinks_with_n() {
        let a: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let b: Vec<f64> = (0..1000).map(|i| (i % 10) as f64).collect();
        assert!(ci95_half_width(&b) < ci95_half_width(&a));
        assert!(ci95_half_width(&[1.0]).is_infinite());
    }

    #[test]
    fn pearson_signs() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 * x + 1.0).collect();
        let zs: Vec<f64> = xs.iter().map(|x| -x).collect();
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-9);
        assert!((pearson(&xs, &zs) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn binned_mean_partitions() {
        let xs = [0.0, 0.1, 0.9, 1.0];
        let ys = [1.0, 2.0, 3.0, 4.0];
        let bins = binned_mean(&xs, &ys, 2);
        assert_eq!(bins.len(), 2);
        assert_eq!(bins[0].2 + bins[1].2, 4);
        assert!((bins[0].1 - 1.5).abs() < 1e-9);
        assert!((bins[1].1 - 3.5).abs() < 1e-9);
    }

    #[test]
    fn minmax_unit_range() {
        let n = minmax_normalize(&[2.0, 4.0, 6.0]);
        assert_eq!(n, vec![0.0, 0.5, 1.0]);
        assert_eq!(minmax_normalize(&[3.0, 3.0]), vec![0.0, 0.0]);
    }
}
