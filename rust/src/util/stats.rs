//! Statistics helpers shared by the harness, the model, and reports.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population variance; 0 for empty input.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Geometric mean of strictly-positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// p-th percentile (0..=100) by linear interpolation on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Half-width of the 95% confidence interval of the mean
/// (normal approximation — the paper's stopping rule for timing runs).
pub fn ci95_half_width(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return f64::INFINITY;
    }
    let sd = {
        let m = mean(xs);
        let s2 = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (xs.len() - 1) as f64;
        s2.sqrt()
    };
    1.96 * sd / (xs.len() as f64).sqrt()
}

/// Pearson correlation coefficient.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    if xs.len() < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        dx += (x - mx) * (x - mx);
        dy += (y - my) * (y - my);
    }
    if dx == 0.0 || dy == 0.0 {
        0.0
    } else {
        num / (dx * dy).sqrt()
    }
}

/// Equal-width binned averages over [min, max] — the paper's Fig 6
/// "integral histogram of the speedup results" (bar charts b/d/f).
///
/// Returns (bin_center, mean_of_ys_in_bin, count) for non-empty bins.
pub fn binned_mean(
    xs: &[f64],
    ys: &[f64],
    bins: usize,
) -> Vec<(f64, f64, usize)> {
    assert_eq!(xs.len(), ys.len());
    if xs.is_empty() || bins == 0 {
        return vec![];
    }
    let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let width = if hi > lo { (hi - lo) / bins as f64 } else { 1.0 };
    let mut sums = vec![0.0; bins];
    let mut counts = vec![0usize; bins];
    for (x, y) in xs.iter().zip(ys) {
        let mut b = ((x - lo) / width) as usize;
        if b >= bins {
            b = bins - 1;
        }
        sums[b] += y;
        counts[b] += 1;
    }
    (0..bins)
        .filter(|&b| counts[b] > 0)
        .map(|b| {
            (
                lo + (b as f64 + 0.5) * width,
                sums[b] / counts[b] as f64,
                counts[b],
            )
        })
        .collect()
}

/// Streaming quantile estimator — the P² algorithm (Jain & Chlamtac,
/// CACM 1985). Five markers track the running quantile in O(1) memory,
/// so serving telemetry can report p50/p95/p99 over million-request
/// replays without storing every latency sample.
#[derive(Clone, Debug)]
pub struct P2Quantile {
    p: f64,
    n: u64,
    /// Marker heights (quantile estimates at the marker positions).
    q: [f64; 5],
    /// Actual marker positions (1-based ranks).
    pos: [f64; 5],
    /// Desired marker positions.
    des: [f64; 5],
    /// Desired-position increments per observation.
    inc: [f64; 5],
    /// The first five samples, kept verbatim for exact small-n output.
    init: [f64; 5],
}

impl P2Quantile {
    /// Estimator for the `p`-quantile, `p` in [0, 1].
    pub fn new(p: f64) -> Self {
        let p = p.clamp(0.0, 1.0);
        P2Quantile {
            p,
            n: 0,
            q: [0.0; 5],
            pos: [1.0, 2.0, 3.0, 4.0, 5.0],
            des: [1.0, 1.0 + 2.0 * p, 1.0 + 4.0 * p, 3.0 + 2.0 * p, 5.0],
            inc: [0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0],
            init: [0.0; 5],
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    /// The quantile this estimator tracks (in [0, 1]).
    pub fn p(&self) -> f64 {
        self.p
    }

    pub fn observe(&mut self, x: f64) {
        // A non-finite sample would poison the marker heights (and a
        // NaN would defeat the cell search below) — drop it, matching
        // the histogram's observe contract.
        if !x.is_finite() {
            return;
        }
        if self.n < 5 {
            self.init[self.n as usize] = x;
            self.n += 1;
            if self.n == 5 {
                let mut s = self.init;
                s.sort_by(f64::total_cmp);
                self.q = s;
            }
            return;
        }
        // Locate the marker cell containing x, extending the extremes.
        let k = if x < self.q[0] {
            self.q[0] = x;
            0
        } else if x >= self.q[4] {
            self.q[4] = x;
            3
        } else {
            let mut k = 0;
            while k < 3 && x >= self.q[k + 1] {
                k += 1;
            }
            k
        };
        for pos in self.pos.iter_mut().skip(k + 1) {
            *pos += 1.0;
        }
        for (des, inc) in self.des.iter_mut().zip(self.inc) {
            *des += inc;
        }
        // Nudge interior markers toward their desired positions with
        // the piecewise-parabolic (P²) height update.
        for i in 1..4 {
            let d = self.des[i] - self.pos[i];
            if (d >= 1.0 && self.pos[i + 1] - self.pos[i] > 1.0)
                || (d <= -1.0 && self.pos[i - 1] - self.pos[i] < -1.0)
            {
                let s = d.signum();
                let qp = self.parabolic(i, s);
                self.q[i] = if self.q[i - 1] < qp && qp < self.q[i + 1] {
                    qp
                } else {
                    self.linear(i, s)
                };
                self.pos[i] += s;
            }
        }
        self.n += 1;
    }

    fn parabolic(&self, i: usize, s: f64) -> f64 {
        let q = &self.q;
        let np = &self.pos;
        q[i] + s / (np[i + 1] - np[i - 1])
            * ((np[i] - np[i - 1] + s) * (q[i + 1] - q[i])
                / (np[i + 1] - np[i])
                + (np[i + 1] - np[i] - s) * (q[i] - q[i - 1])
                    / (np[i] - np[i - 1]))
    }

    fn linear(&self, i: usize, s: f64) -> f64 {
        let j = if s > 0.0 { i + 1 } else { i - 1 };
        self.q[i] + s * (self.q[j] - self.q[i]) / (self.pos[j] - self.pos[i])
    }

    /// Current estimate. Exact (interpolated) while fewer than five
    /// samples have been observed; 0 for no samples.
    pub fn quantile(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        if self.n < 5 {
            let mut v = self.init[..self.n as usize].to_vec();
            v.sort_by(f64::total_cmp);
            return percentile(&v, self.p * 100.0);
        }
        self.q[2]
    }

    /// Fold another estimator of the same quantile into this one.
    /// Exact when either side is still in its small-n buffer; once
    /// both are warm the marker heights are blended by sample weight —
    /// approximate, and P² self-corrects as more samples arrive.
    pub fn merge(&mut self, other: &P2Quantile) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        if other.n < 5 {
            for &x in &other.init[..other.n as usize] {
                self.observe(x);
            }
            return;
        }
        if self.n < 5 {
            let mut merged = other.clone();
            for &x in &self.init[..self.n as usize] {
                merged.observe(x);
            }
            *self = merged;
            return;
        }
        let (wa, wb) = (self.n as f64, other.n as f64);
        let lo = self.q[0].min(other.q[0]);
        let hi = self.q[4].max(other.q[4]);
        for (qa, qb) in self.q.iter_mut().zip(other.q) {
            *qa = (*qa * wa + qb * wb) / (wa + wb);
        }
        self.q[0] = lo;
        self.q[4] = hi;
        for i in 1..5 {
            if self.q[i] < self.q[i - 1] {
                self.q[i] = self.q[i - 1];
            }
        }
        self.n += other.n;
        // Restart position tracking at the canonical marks for the
        // combined count (strictly increasing for 0 < p < 1).
        let nf = self.n as f64;
        for i in 0..5 {
            self.pos[i] = 1.0 + self.inc[i] * (nf - 1.0);
            self.des[i] = self.pos[i];
        }
    }
}

/// Min-max normalization to [0,1] (the paper normalizes nnz_var for
/// Fig 6 e/f).
pub fn minmax_normalize(xs: &[f64]) -> Vec<f64> {
    let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if !(hi > lo) {
        return vec![0.0; xs.len()];
    }
    xs.iter().map(|x| (x - lo) / (hi - lo)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_var_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(variance(&[]), 0.0);
    }

    #[test]
    fn geomean_of_powers() {
        let xs = [1.0, 4.0];
        assert!((geomean(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interp() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
    }

    #[test]
    fn ci_shrinks_with_n() {
        let a: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let b: Vec<f64> = (0..1000).map(|i| (i % 10) as f64).collect();
        assert!(ci95_half_width(&b) < ci95_half_width(&a));
        assert!(ci95_half_width(&[1.0]).is_infinite());
    }

    #[test]
    fn pearson_signs() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 * x + 1.0).collect();
        let zs: Vec<f64> = xs.iter().map(|x| -x).collect();
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-9);
        assert!((pearson(&xs, &zs) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn binned_mean_partitions() {
        let xs = [0.0, 0.1, 0.9, 1.0];
        let ys = [1.0, 2.0, 3.0, 4.0];
        let bins = binned_mean(&xs, &ys, 2);
        assert_eq!(bins.len(), 2);
        assert_eq!(bins[0].2 + bins[1].2, 4);
        assert!((bins[0].1 - 1.5).abs() < 1e-9);
        assert!((bins[1].1 - 3.5).abs() < 1e-9);
    }

    #[test]
    fn p2_small_n_is_exact() {
        let mut q = P2Quantile::new(0.5);
        assert_eq!(q.quantile(), 0.0);
        for x in [3.0, 1.0, 2.0] {
            q.observe(x);
        }
        assert_eq!(q.count(), 3);
        assert_eq!(q.quantile(), 2.0);
        assert!((q.p() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn p2_edge_populations_never_panic() {
        // Empty and single-sample populations: exact answers, no
        // interpolation panics.
        for p in [0.0, 0.5, 0.95, 1.0] {
            let mut q = P2Quantile::new(p);
            assert_eq!(q.quantile(), 0.0, "empty population answers 0");
            q.observe(7.25);
            assert_eq!(q.count(), 1);
            assert_eq!(
                q.quantile(),
                7.25,
                "single-sample p{p} is the sample itself"
            );
        }
        // Non-finite samples are dropped — in the exact small-n
        // buffer (where a NaN used to poison the sort) and in the
        // warm marker phase alike.
        let mut q = P2Quantile::new(0.5);
        for x in [1.0, f64::NAN, 2.0, f64::INFINITY, 3.0] {
            q.observe(x);
        }
        assert_eq!(q.count(), 3);
        assert_eq!(q.quantile(), 2.0);
        for x in [4.0, 5.0, f64::NAN, 6.0, f64::NEG_INFINITY, 7.0] {
            q.observe(x);
        }
        assert_eq!(q.count(), 7);
        assert!(q.quantile().is_finite());
        // All-identical samples stay degenerate but finite.
        let mut flat = P2Quantile::new(0.95);
        for _ in 0..100 {
            flat.observe(0.0);
        }
        assert_eq!(flat.quantile(), 0.0);
    }

    #[test]
    fn p2_tracks_percentiles_of_a_skewed_stream() {
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::new(0xB2B2);
        let mut q50 = P2Quantile::new(0.50);
        let mut q95 = P2Quantile::new(0.95);
        let mut q99 = P2Quantile::new(0.99);
        let mut all = Vec::with_capacity(20_000);
        for _ in 0..20_000 {
            // Right-skewed, latency-like distribution.
            let u = rng.gen_f64();
            let x = 1.0 + 50.0 * u * u * u;
            all.push(x);
            q50.observe(x);
            q95.observe(x);
            q99.observe(x);
        }
        for (est, p, tol) in
            [(&q50, 50.0, 0.05), (&q95, 95.0, 0.05), (&q99, 99.0, 0.10)]
        {
            let exact = percentile(&all, p);
            let got = est.quantile();
            assert!(
                (got - exact).abs() <= tol * (exact.abs() + 1.0),
                "p{p}: streaming {got} vs exact {exact}"
            );
        }
        assert_eq!(q50.count(), 20_000);
    }

    #[test]
    fn p2_merge_approximates_union() {
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::new(0xC3C3);
        let mut a = P2Quantile::new(0.5);
        let mut b = P2Quantile::new(0.5);
        let mut all = Vec::new();
        for i in 0..10_000 {
            let x = rng.gen_f64() * 100.0;
            all.push(x);
            if i % 2 == 0 {
                a.observe(x);
            } else {
                b.observe(x);
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), 10_000);
        let exact = percentile(&all, 50.0);
        assert!(
            (a.quantile() - exact).abs() <= 0.1 * (exact.abs() + 1.0),
            "merged {} vs exact {exact}",
            a.quantile()
        );
        // Merging into a cold/small estimator stays exact.
        let mut cold = P2Quantile::new(0.5);
        cold.merge(&a);
        assert_eq!(cold.count(), a.count());
        let mut tiny = P2Quantile::new(0.5);
        tiny.observe(1.0);
        tiny.merge(&a);
        assert_eq!(tiny.count(), 10_001);
    }

    #[test]
    fn p2_merge_empty_and_singleton_edges() {
        // empty.merge(empty): still empty, quantile 0.
        let mut e = P2Quantile::new(0.5);
        e.merge(&P2Quantile::new(0.5));
        assert_eq!(e.count(), 0);
        assert_eq!(e.quantile(), 0.0);
        // warm.merge(empty): a no-op.
        let mut warm = P2Quantile::new(0.5);
        for i in 0..100 {
            warm.observe(i as f64);
        }
        let before = warm.quantile();
        warm.merge(&P2Quantile::new(0.5));
        assert_eq!(warm.count(), 100);
        assert_eq!(warm.quantile(), before);
        // empty.merge(warm): adopts the other side exactly.
        let mut e2 = P2Quantile::new(0.5);
        e2.merge(&warm);
        assert_eq!(e2.count(), 100);
        assert_eq!(e2.quantile(), before);
        // singleton.merge(singleton): exact two-sample interpolation.
        let mut a = P2Quantile::new(0.5);
        a.observe(1.0);
        let mut b = P2Quantile::new(0.5);
        b.observe(3.0);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.quantile(), 2.0);
        // warm.merge(singleton): the one sample is replayed exactly.
        let mut w = P2Quantile::new(0.5);
        for i in 0..50 {
            w.observe(i as f64);
        }
        let mut s = P2Quantile::new(0.5);
        s.observe(24.5);
        w.merge(&s);
        assert_eq!(w.count(), 51);
        assert!(w.quantile().is_finite());
    }

    #[test]
    fn p2_merge_disjoint_ranges_stays_bounded() {
        // Two estimators over ranges that do not overlap at all: the
        // merged estimate must land inside the union's hull, and the
        // extreme markers must span both sides.
        let mut lo = P2Quantile::new(0.5);
        let mut hi = P2Quantile::new(0.5);
        let mut all = Vec::new();
        for i in 0..1_000 {
            let x = i as f64 / 100.0; // [0, 10)
            lo.observe(x);
            all.push(x);
            let y = 1_000.0 + i as f64 / 100.0; // [1000, 1010)
            hi.observe(y);
            all.push(y);
        }
        let mut merged = lo.clone();
        merged.merge(&hi);
        assert_eq!(merged.count(), 2_000);
        let q = merged.quantile();
        assert!(
            (0.0..=1_010.0).contains(&q),
            "median {q} escaped the union hull"
        );
        // The true median straddles the gap; weight-blended markers
        // must put the estimate between the two clusters' interiors,
        // not outside the data entirely.
        let exact = percentile(&all, 50.0);
        assert!(
            (exact - 505.0).abs() < 10.0,
            "setup: union median ~505, got {exact}"
        );
        // Merging in the other order is also bounded.
        let mut merged2 = hi.clone();
        merged2.merge(&lo);
        assert!((0.0..=1_010.0).contains(&merged2.quantile()));
    }

    #[test]
    fn p2_quantile_monotone_under_interleaved_merges() {
        use crate::util::rng::Pcg32;
        // Feed identical chunked data to p10/p50/p90 estimators via
        // alternating observe/merge interleavings; the estimates must
        // stay ordered (q10 <= q50 <= q90) and inside the data hull.
        let mut rng = Pcg32::new(0xD15C0);
        let mut q10 = P2Quantile::new(0.10);
        let mut q50 = P2Quantile::new(0.50);
        let mut q90 = P2Quantile::new(0.90);
        for chunk in 0..20 {
            let xs: Vec<f64> =
                (0..200).map(|_| rng.gen_f64() * 50.0).collect();
            if chunk % 2 == 0 {
                // Direct observation.
                for &x in &xs {
                    q10.observe(x);
                    q50.observe(x);
                    q90.observe(x);
                }
            } else {
                // Same samples arriving through a merged sub-digest.
                let mut a10 = P2Quantile::new(0.10);
                let mut a50 = P2Quantile::new(0.50);
                let mut a90 = P2Quantile::new(0.90);
                for &x in &xs {
                    a10.observe(x);
                    a50.observe(x);
                    a90.observe(x);
                }
                q10.merge(&a10);
                q50.merge(&a50);
                q90.merge(&a90);
            }
            if chunk >= 1 {
                let (a, b, c) =
                    (q10.quantile(), q50.quantile(), q90.quantile());
                assert!(
                    a <= b && b <= c,
                    "chunk {chunk}: p10 {a} / p50 {b} / p90 {c} not monotone"
                );
                assert!(
                    (0.0..=50.0).contains(&a) && (0.0..=50.0).contains(&c),
                    "chunk {chunk}: estimates escaped the hull"
                );
            }
        }
        assert_eq!(q50.count(), 20 * 200);
        // After all interleavings the estimates still track the
        // uniform distribution's quantiles loosely.
        assert!((q50.quantile() - 25.0).abs() < 5.0, "{}", q50.quantile());
    }

    #[test]
    fn minmax_unit_range() {
        let n = minmax_normalize(&[2.0, 4.0, 6.0]);
        assert_eq!(n, vec![0.0, 0.5, 1.0]);
        assert_eq!(minmax_normalize(&[3.0, 3.0]), vec![0.0, 0.0]);
    }
}
