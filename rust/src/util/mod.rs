//! Small self-contained utilities.
//!
//! The build environment is fully offline (only the `xla` crate's
//! dependency tree is vendored), so the pieces a crates.io project would
//! pull in — PRNG, JSON, CLI parsing, a bench harness, property-testing
//! helpers — are implemented here from scratch.

pub mod allocprobe;
pub mod bench;
pub mod json;
pub mod ordatomic;
pub mod rng;
pub mod stats;
pub mod table;
pub mod testkit;
