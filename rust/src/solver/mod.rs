//! Iterative solvers on top of the threaded SpMV — the scientific
//! workloads the paper's introduction motivates SpMV with ("one of
//! the most common operations in scientific and HPC applications").
//!
//! Included: Conjugate Gradient (optionally Jacobi-preconditioned)
//! and power iteration. Both drive the *same* SpMV executors the
//! characterization studies, so the simulated per-iteration cost of a
//! solve on FT-2000+ follows directly from a matrix profile
//! (`examples/solver_workload.rs`).

use crate::exec;
use crate::sched::Schedule;
use crate::sparse::Csr;

/// Convergence report of an iterative solve.
#[derive(Clone, Debug)]
pub struct SolveResult {
    pub x: Vec<f64>,
    pub iterations: usize,
    pub residual_norm: f64,
    pub converged: bool,
    /// Total wall time spent inside SpMV (host).
    pub spmv_seconds: f64,
}

/// Options for [`cg`].
#[derive(Clone, Debug)]
pub struct CgOptions {
    pub max_iters: usize,
    pub rel_tol: f64,
    /// Jacobi (diagonal) preconditioning.
    pub jacobi: bool,
    pub schedule: Schedule,
    pub threads: usize,
}

impl Default for CgOptions {
    fn default() -> Self {
        CgOptions {
            max_iters: 500,
            rel_tol: 1e-8,
            jacobi: false,
            schedule: Schedule::CsrRowStatic,
            threads: 1,
        }
    }
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

fn norm(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Conjugate Gradient for SPD systems `A x = b`.
pub fn cg(a: &Csr, b: &[f64], opts: &CgOptions) -> SolveResult {
    assert_eq!(a.n_rows, a.n_cols, "CG needs a square matrix");
    assert_eq!(b.len(), a.n_rows);
    let n = a.n_rows;
    let inv_diag: Option<Vec<f64>> = if opts.jacobi {
        Some(
            (0..n)
                .map(|r| {
                    let (cols, vals) = a.row(r);
                    let d = cols
                        .iter()
                        .zip(vals)
                        .find(|(&c, _)| c as usize == r)
                        .map(|(_, &v)| v)
                        .unwrap_or(1.0);
                    if d.abs() > 1e-300 {
                        1.0 / d
                    } else {
                        1.0
                    }
                })
                .collect(),
        )
    } else {
        None
    };
    let precond = |r: &[f64]| -> Vec<f64> {
        match &inv_diag {
            Some(d) => r.iter().zip(d).map(|(x, m)| x * m).collect(),
            None => r.to_vec(),
        }
    };
    let spmv = |v: &[f64], secs: &mut f64| -> Vec<f64> {
        let res = exec::spmv_threaded(a, v, opts.schedule, opts.threads);
        *secs += res.wall_seconds;
        res.y
    };

    let mut spmv_seconds = 0.0;
    let mut x = vec![0.0; n];
    let mut r = b.to_vec(); // r = b - A*0
    let b_norm = norm(b).max(1e-300);
    let mut z = precond(&r);
    let mut p = z.clone();
    let mut rz = dot(&r, &z);
    for it in 0..opts.max_iters {
        let rn = norm(&r);
        if rn / b_norm <= opts.rel_tol {
            return SolveResult {
                x,
                iterations: it,
                residual_norm: rn,
                converged: true,
                spmv_seconds,
            };
        }
        let ap = spmv(&p, &mut spmv_seconds);
        let pap = dot(&p, &ap);
        if pap.abs() < 1e-300 {
            break; // breakdown (matrix not SPD)
        }
        let alpha = rz / pap;
        axpy(alpha, &p, &mut x);
        axpy(-alpha, &ap, &mut r);
        z = precond(&r);
        let rz_next = dot(&r, &z);
        let beta = rz_next / rz;
        rz = rz_next;
        for (pi, zi) in p.iter_mut().zip(&z) {
            *pi = zi + beta * *pi;
        }
    }
    let rn = norm(&r);
    SolveResult {
        x,
        iterations: opts.max_iters,
        residual_norm: rn,
        converged: rn / b_norm <= opts.rel_tol,
        spmv_seconds,
    }
}

/// Power iteration: dominant eigenvalue + eigenvector estimate.
pub fn power_iteration(
    a: &Csr,
    iters: usize,
    schedule: Schedule,
    threads: usize,
) -> (Vec<f64>, f64) {
    assert_eq!(a.n_rows, a.n_cols);
    let n = a.n_rows;
    let mut v = vec![1.0 / (n as f64).sqrt(); n];
    for _ in 0..iters {
        let y = exec::spmv_threaded(a, &v, schedule, threads).y;
        let nrm = norm(&y).max(1e-300);
        v = y.into_iter().map(|x| x / nrm).collect();
    }
    let av = exec::spmv_threaded(a, &v, schedule, threads).y;
    let lambda = dot(&v, &av);
    (v, lambda)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::generators;
    use crate::sparse::Coo;
    use crate::util::rng::Pcg32;

    /// SPD test matrix: 2-D Laplacian + eps*I.
    fn spd(n: usize) -> Csr {
        let lap = generators::stencil(n, 5);
        let m = lap.n_rows;
        let mut coo = Coo::new(m, m);
        for r in 0..m {
            let (cols, vals) = lap.row(r);
            for (&c, &v) in cols.iter().zip(vals) {
                coo.push(r, c as usize, v);
            }
            coo.push(r, r, 0.5); // diagonal shift -> SPD
        }
        coo.to_csr()
    }

    #[test]
    fn cg_solves_laplacian() {
        let a = spd(400);
        let n = a.n_rows;
        let mut rng = Pcg32::new(1);
        let x_true: Vec<f64> = (0..n).map(|_| rng.gen_f64() - 0.5).collect();
        let mut b = vec![0.0; n];
        a.spmv(&x_true, &mut b);
        let res = cg(&a, &b, &CgOptions::default());
        assert!(res.converged, "residual {}", res.residual_norm);
        for (xs, xt) in res.x.iter().zip(&x_true) {
            assert!((xs - xt).abs() < 1e-5, "{xs} vs {xt}");
        }
        assert!(res.spmv_seconds > 0.0);
    }

    #[test]
    fn jacobi_preconditioning_helps_scaled_system() {
        // Badly scaled SPD diag: Jacobi should reduce iterations.
        let n = 300;
        let mut coo = Coo::new(n, n);
        for r in 0..n {
            coo.push(r, r, 1.0 + (r % 100) as f64 * 10.0);
            if r + 1 < n {
                coo.push(r, r + 1, -0.5);
                coo.push(r + 1, r, -0.5);
            }
        }
        let a = coo.to_csr();
        let b = vec![1.0; n];
        let plain = cg(
            &a,
            &b,
            &CgOptions { rel_tol: 1e-10, ..Default::default() },
        );
        let pre = cg(
            &a,
            &b,
            &CgOptions { rel_tol: 1e-10, jacobi: true, ..Default::default() },
        );
        assert!(plain.converged && pre.converged);
        assert!(
            pre.iterations <= plain.iterations,
            "jacobi {} vs plain {}",
            pre.iterations,
            plain.iterations
        );
    }

    #[test]
    fn cg_with_threads_and_schedules_agrees() {
        let a = spd(225);
        let b = vec![1.0; a.n_rows];
        let base = cg(&a, &b, &CgOptions::default());
        for (threads, sched) in [
            (4, Schedule::CsrRowStatic),
            (2, Schedule::Csr5Tiles { tile_nnz: 64 }),
            (3, Schedule::CsrRowBalanced),
        ] {
            let r = cg(
                &a,
                &b,
                &CgOptions { threads, schedule: sched, ..Default::default() },
            );
            assert!(r.converged);
            for (x1, x2) in base.x.iter().zip(&r.x) {
                assert!((x1 - x2).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn cg_detects_non_convergence() {
        let a = spd(100);
        let b = vec![1.0; a.n_rows];
        let r = cg(
            &a,
            &b,
            &CgOptions { max_iters: 2, rel_tol: 1e-14, ..Default::default() },
        );
        assert!(!r.converged);
        assert_eq!(r.iterations, 2);
    }

    #[test]
    fn power_iteration_diagonal() {
        let n = 64;
        let mut coo = Coo::new(n, n);
        for r in 0..n {
            coo.push(r, r, 1.0 + r as f64);
        }
        let a = coo.to_csr();
        let (v, lambda) =
            power_iteration(&a, 200, Schedule::CsrRowStatic, 2);
        assert!((lambda - n as f64).abs() < 0.5, "lambda={lambda}");
        // Dominant eigenvector concentrates on the last coordinate.
        let maxi = v
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
            .unwrap()
            .0;
        assert_eq!(maxi, n - 1);
    }
}
