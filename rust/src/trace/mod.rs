//! Per-thread SpMV address-stream generators.
//!
//! The simulator is trace-driven: each thread's SpMV work (a CSR row
//! range or a CSR5 tile range) is turned into the exact sequence of
//! data-cache accesses the kernel performs — sequential walks of
//! `ptr`/`indices`/`data`/`y` and the irregular gather of `x` — and
//! the engine replays interleaved streams through the cache model.
//!
//! Access encoding: one `u64` per access;
//! * bit 63 — write (y stores);
//! * bit 62 — sequential/prefetchable stream (ptr/indices/data/y):
//!   hardware prefetchers hide most of the DRAM latency for these, so
//!   the timing model discounts their miss penalty; the `x` gather is
//!   unmarked (random) and pays full latency;
//! * bits 0..48 — byte address.

use crate::sparse::{Csr, Csr5};

pub const WRITE_BIT: u64 = 1 << 63;
pub const SEQ_BIT: u64 = 1 << 62;
pub const ADDR_MASK: u64 = (1 << 48) - 1;

/// Virtual base addresses of the SpMV arrays (disjoint regions).
pub const PTR_BASE: u64 = 0x0100_0000_0000;
pub const IDX_BASE: u64 = 0x0200_0000_0000;
pub const DATA_BASE: u64 = 0x0300_0000_0000;
pub const X_BASE: u64 = 0x0400_0000_0000;
pub const Y_BASE: u64 = 0x0500_0000_0000;

/// Instruction-count estimate for a CSR row-loop executing `rows` rows
/// and `nnz` nonzeros: loads + FMA + index arithmetic + loop control.
/// (Calibrated so the simulated single-core IPC and Gflops land in the
/// range the paper reports for FT-2000+.)
pub const INS_PER_NNZ: u64 = 6;
pub const INS_PER_ROW: u64 = 20;
pub const FP_PER_NNZ: u64 = 2; // mul + add
/// CSR5 segmented sum: slightly higher per-nonzero bookkeeping
/// (bit-flag tests) but cheaper row transitions than the CSR row loop
/// (no loop-exit branch misprediction; descriptors are precomputed).
pub const CSR5_INS_PER_NNZ: u64 = 8;
pub const CSR5_INS_PER_ROWSTART: u64 = 12;

/// A resumable access-stream generator.
pub trait AccessGen {
    /// Append up to `max` accesses to `buf`; returns how many were
    /// appended. 0 means the stream is exhausted.
    fn fill(&mut self, buf: &mut Vec<u64>, max: usize) -> usize;

    /// Analytic (TOT_INS, FR_INS) for the whole stream.
    fn instruction_estimate(&self) -> (u64, u64);
}

impl<G: AccessGen + ?Sized> AccessGen for Box<G> {
    fn fill(&mut self, buf: &mut Vec<u64>, max: usize) -> usize {
        (**self).fill(buf, max)
    }
    fn instruction_estimate(&self) -> (u64, u64) {
        (**self).instruction_estimate()
    }
}

/// CSR SpMV over a row range `[r0, r1)` — the static-schedule thread
/// trace (the paper's default kernel).
pub struct CsrTrace<'a> {
    csr: &'a Csr,
    row: usize,
    row_end: usize,
    /// Next nonzero within the current row (absolute index).
    i: usize,
    emitted_row_header: bool,
    /// Overflow slots when a triple doesn't fit the caller's budget
    /// (fill must always make progress while the stream has work —
    /// `CsrMultiTrace` treats 0 as exhaustion).
    pending: [u64; 3],
    pending_len: u8,
    pending_pos: u8,
}

impl<'a> CsrTrace<'a> {
    pub fn new(csr: &'a Csr, r0: usize, r1: usize) -> Self {
        assert!(r0 <= r1 && r1 <= csr.n_rows);
        CsrTrace {
            csr,
            row: r0,
            row_end: r1,
            i: csr.ptr[r0.min(csr.n_rows)],
            emitted_row_header: false,
            pending: [0; 3],
            pending_len: 0,
            pending_pos: 0,
        }
    }

    pub fn rows(&self) -> usize {
        self.row_end - self.row.min(self.row_end)
    }

    /// Produce the next burst of 1–3 accesses into `self.pending`.
    #[inline]
    fn gen_burst(&mut self) {
        debug_assert!(self.pending_pos == self.pending_len);
        self.pending_pos = 0;
        if !self.emitted_row_header {
            // Load ptr[row] / ptr[row+1] (one touch; they share a
            // line 7 times out of 8).
            self.pending[0] = SEQ_BIT | (PTR_BASE + (self.row as u64) * 8);
            self.pending_len = 1;
            self.emitted_row_header = true;
            self.i = self.csr.ptr[self.row];
        } else if self.i < self.csr.ptr[self.row + 1] {
            self.pending[0] = SEQ_BIT | (IDX_BASE + (self.i as u64) * 4);
            self.pending[1] = SEQ_BIT | (DATA_BASE + (self.i as u64) * 8);
            let col = self.csr.indices[self.i] as u64;
            self.pending[2] = X_BASE + col * 8;
            self.pending_len = 3;
            self.i += 1;
        } else {
            // Store y[row]; advance to next row.
            self.pending[0] =
                WRITE_BIT | SEQ_BIT | (Y_BASE + (self.row as u64) * 8);
            self.pending_len = 1;
            self.row += 1;
            self.emitted_row_header = false;
        }
    }
}

impl AccessGen for CsrTrace<'_> {
    fn fill(&mut self, buf: &mut Vec<u64>, max: usize) -> usize {
        let start = buf.len();
        let target = start + max;
        // Drain any overflow from the previous call.
        while self.pending_pos < self.pending_len && buf.len() < target {
            buf.push(self.pending[self.pending_pos as usize]);
            self.pending_pos += 1;
        }
        if self.pending_pos < self.pending_len {
            return buf.len() - start;
        }
        self.pending_len = 0;
        self.pending_pos = 0;
        // Fast path: emit whole bursts while 3 slots remain (§Perf:
        // this loop feeds the simulator's innermost loop — straight
        // pushes, no per-access state machine).
        while buf.len() + 3 <= target && self.row < self.row_end {
            if !self.emitted_row_header {
                buf.push(SEQ_BIT | (PTR_BASE + (self.row as u64) * 8));
                self.emitted_row_header = true;
                self.i = self.csr.ptr[self.row];
                continue;
            }
            if self.i < self.csr.ptr[self.row + 1] {
                buf.push(SEQ_BIT | (IDX_BASE + (self.i as u64) * 4));
                buf.push(SEQ_BIT | (DATA_BASE + (self.i as u64) * 8));
                let col = self.csr.indices[self.i] as u64;
                buf.push(X_BASE + col * 8);
                self.i += 1;
            } else {
                buf.push(
                    WRITE_BIT | SEQ_BIT | (Y_BASE + (self.row as u64) * 8),
                );
                self.row += 1;
                self.emitted_row_header = false;
            }
        }
        // Tail: guarantee progress for tiny remaining budgets.
        while buf.len() < target && self.row < self.row_end {
            self.gen_burst();
            while self.pending_pos < self.pending_len && buf.len() < target {
                buf.push(self.pending[self.pending_pos as usize]);
                self.pending_pos += 1;
            }
        }
        buf.len() - start
    }

    fn instruction_estimate(&self) -> (u64, u64) {
        let rows = (self.row_end - self.row) as u64;
        let nnz = (self.csr.ptr[self.row_end] - self.csr.ptr[self.row]) as u64;
        (rows * INS_PER_ROW + nnz * INS_PER_NNZ, nnz * FP_PER_NNZ)
    }
}

/// CSR5 segmented SpMV over a tile range — the balanced-schedule
/// thread trace. The nonzero walk is identical to CSR (same arrays,
/// same order); row bookkeeping reads the tile descriptors instead of
/// `ptr`, and `y` is written once per row start in the range.
pub struct Csr5Trace<'a> {
    csr5: &'a Csr5,
    /// Current / end absolute nonzero index.
    i: usize,
    end: usize,
    phase: u8,
    /// Current output row (advanced on bit_flag).
    row: usize,
    started: bool,
    /// Row starts inside [begin, end) — the segmented sum's per-row
    /// work (y scatter + descriptor bookkeeping).
    row_starts: u64,
}

impl<'a> Csr5Trace<'a> {
    pub fn new(csr5: &'a Csr5, t0: usize, t1: usize) -> Self {
        let nnz = csr5.nnz();
        let begin = (t0 * csr5.tile_nnz).min(nnz);
        let end = (t1 * csr5.tile_nnz).min(nnz);
        let row = if t0 < csr5.n_tiles() {
            csr5.tile_ptr[t0] as usize
        } else {
            0
        };
        let row_starts =
            csr5.bit_flag[begin..end].iter().filter(|&&b| b).count() as u64;
        Csr5Trace { csr5, i: begin, end, phase: 0, row, started: false, row_starts }
    }

    pub fn nnz(&self) -> usize {
        self.end - self.i.min(self.end)
    }
}

impl AccessGen for Csr5Trace<'_> {
    fn fill(&mut self, buf: &mut Vec<u64>, max: usize) -> usize {
        let mut n = 0;
        if !self.started && self.i < self.end {
            self.started = true;
        }
        while n < max && self.i < self.end {
            match self.phase {
                0 => {
                    // Tile boundary: read the tile descriptor
                    // (tile_ptr + y_off + seg_off pack into one touch).
                    if self.i % self.csr5.tile_nnz == 0 {
                        buf.push(
                            SEQ_BIT
                                | (PTR_BASE
                                    + (self.i / self.csr5.tile_nnz) as u64
                                        * 16),
                        );
                        self.phase = 4;
                        n += 1;
                        continue;
                    }
                    self.phase = 4;
                }
                4 => {
                    // bit_flag check: row start -> flush the previous
                    // segment's partial sum (read-modify-write of y:
                    // the CSR5 carry/partial update).
                    if self.csr5.bit_flag[self.i] {
                        buf.push(SEQ_BIT | (Y_BASE + (self.row as u64) * 8));
                        buf.push(
                            WRITE_BIT
                                | SEQ_BIT
                                | (Y_BASE + (self.row as u64) * 8),
                        );
                        // Track the row id for x/y addressing.
                        while self.row + 1 < self.csr5.n_rows
                            && self.csr5.ptr[self.row + 1] <= self.i
                        {
                            self.row += 1;
                        }
                        self.phase = 1;
                        n += 2;
                        continue;
                    }
                    self.phase = 1;
                }
                1 => {
                    buf.push(SEQ_BIT | (IDX_BASE + (self.i as u64) * 4));
                    self.phase = 2;
                    n += 1;
                }
                2 => {
                    buf.push(SEQ_BIT | (DATA_BASE + (self.i as u64) * 8));
                    self.phase = 3;
                    n += 1;
                }
                _ => {
                    let col = self.csr5.indices[self.i] as u64;
                    buf.push(X_BASE + col * 8);
                    self.phase = 0;
                    self.i += 1;
                    n += 1;
                }
            }
        }
        n
    }

    fn instruction_estimate(&self) -> (u64, u64) {
        let nnz = (self.end - self.i) as u64;
        (
            nnz * CSR5_INS_PER_NNZ + self.row_starts * CSR5_INS_PER_ROWSTART,
            nnz * FP_PER_NNZ,
        )
    }
}

/// CSR SpMV over a *list* of row ranges — the dynamic-chunk schedule's
/// thread trace (a thread executes its chunks in row order).
pub struct CsrMultiTrace<'a> {
    csr: &'a Csr,
    ranges: Vec<(usize, usize)>,
    cur: usize,
    inner: Option<CsrTrace<'a>>,
}

impl<'a> CsrMultiTrace<'a> {
    pub fn new(csr: &'a Csr, ranges: Vec<(usize, usize)>) -> Self {
        CsrMultiTrace { csr, ranges, cur: 0, inner: None }
    }
}

impl AccessGen for CsrMultiTrace<'_> {
    fn fill(&mut self, buf: &mut Vec<u64>, max: usize) -> usize {
        let mut n = 0;
        while n < max {
            if self.inner.is_none() {
                if self.cur >= self.ranges.len() {
                    break;
                }
                let (r0, r1) = self.ranges[self.cur];
                self.cur += 1;
                self.inner = Some(CsrTrace::new(self.csr, r0, r1));
            }
            let got =
                self.inner.as_mut().unwrap().fill(buf, max - n);
            if got == 0 {
                self.inner = None;
            } else {
                n += got;
            }
        }
        n
    }

    fn instruction_estimate(&self) -> (u64, u64) {
        let mut ins = 0u64;
        let mut fp = 0u64;
        if let Some(inner) = &self.inner {
            let (i, f) = inner.instruction_estimate();
            ins += i;
            fp += f;
        }
        for &(r0, r1) in &self.ranges[self.cur.min(self.ranges.len())..] {
            let rows = (r1 - r0) as u64;
            let nnz = (self.csr.ptr[r1] - self.csr.ptr[r0]) as u64;
            ins += rows * INS_PER_ROW + nnz * INS_PER_NNZ;
            fp += nnz * FP_PER_NNZ;
        }
        (ins, fp)
    }
}

/// Drain a generator fully (test/analysis helper).
pub fn drain(gen: &mut dyn AccessGen) -> Vec<u64> {
    let mut out = Vec::new();
    loop {
        let got = gen.fill(&mut out, 4096);
        if got == 0 {
            break;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;

    fn paper_matrix() -> Csr {
        let mut coo = Coo::new(4, 4);
        for &(r, c, v) in &[
            (0, 1, 5.0),
            (0, 2, 2.0),
            (1, 0, 6.0),
            (1, 2, 8.0),
            (1, 3, 3.0),
            (2, 2, 4.0),
            (3, 1, 7.0),
            (3, 2, 1.0),
        ] {
            coo.push(r, c, v);
        }
        coo.to_csr()
    }

    #[test]
    fn csr_trace_access_count() {
        let csr = paper_matrix();
        let mut t = CsrTrace::new(&csr, 0, 4);
        let accesses = drain(&mut t);
        // Per row: 1 ptr + 1 y; per nnz: idx + data + x.
        assert_eq!(accesses.len(), 4 * 2 + 8 * 3);
    }

    #[test]
    fn csr_trace_x_addresses_follow_columns() {
        let csr = paper_matrix();
        let mut t = CsrTrace::new(&csr, 0, 1);
        let accesses = drain(&mut t);
        let xs: Vec<u64> = accesses
            .iter()
            .filter(|&&a| {
                let addr = a & ADDR_MASK;
                (X_BASE..Y_BASE).contains(&addr)
            })
            .map(|&a| ((a & ADDR_MASK) - X_BASE) / 8)
            .collect();
        assert_eq!(xs, vec![1, 2]); // row 0 columns
    }

    #[test]
    fn csr_trace_writes_are_y() {
        let csr = paper_matrix();
        let mut t = CsrTrace::new(&csr, 0, 4);
        let accesses = drain(&mut t);
        let writes: Vec<u64> = accesses
            .iter()
            .filter(|&&a| a & WRITE_BIT != 0)
            .map(|&a| ((a & ADDR_MASK) - Y_BASE) / 8)
            .collect();
        assert_eq!(writes, vec![0, 1, 2, 3]);
    }

    #[test]
    fn csr_trace_partial_range() {
        let csr = paper_matrix();
        let mut t = CsrTrace::new(&csr, 1, 3);
        let accesses = drain(&mut t);
        // rows 1..3: 2 rows, 4 nnz.
        assert_eq!(accesses.len(), 2 * 2 + 4 * 3);
        let (ins, fp) = CsrTrace::new(&csr, 1, 3).instruction_estimate();
        assert_eq!(fp, 4 * FP_PER_NNZ);
        assert_eq!(ins, 2 * INS_PER_ROW + 4 * INS_PER_NNZ);
    }

    #[test]
    fn csr_trace_respects_max() {
        let csr = paper_matrix();
        let mut t = CsrTrace::new(&csr, 0, 4);
        let mut buf = Vec::new();
        let got = t.fill(&mut buf, 5);
        assert_eq!(got, 5, "fill must use the full budget");
        assert_eq!(buf.len(), 5);
        // Draining the rest completes the stream.
        let rest = drain(&mut t);
        assert_eq!(buf.len() + rest.len(), 32);
    }

    #[test]
    fn csr5_trace_covers_nnz() {
        let csr = paper_matrix();
        let c5 = Csr5::from_csr(&csr, 4);
        let mut t = Csr5Trace::new(&c5, 0, c5.n_tiles());
        let accesses = drain(&mut t);
        let data_touches = accesses
            .iter()
            .filter(|&&a| {
                let addr = a & ADDR_MASK;
                (DATA_BASE..X_BASE).contains(&addr)
            })
            .count();
        assert_eq!(data_touches, 8);
        // One y store per row that starts in range (4 rows).
        let writes = accesses.iter().filter(|&&a| a & WRITE_BIT != 0).count();
        assert_eq!(writes, 4);
    }

    #[test]
    fn csr5_trace_range_split_is_balanced() {
        // 256 nnz in one dense row: CSR gives thread 0 everything;
        // CSR5 tile ranges split the nonzero walk evenly.
        let n = 64;
        let mut coo = Coo::new(n, n);
        for c in 0..n {
            for _ in 0..4 {
                coo.push(7, c, 1.0);
            }
        }
        let csr = coo.to_csr(); // dups merged -> 64 nnz in row 7
        let c5 = Csr5::from_csr(&csr, 8); // 8 tiles
        let mut a = Csr5Trace::new(&c5, 0, 4);
        let mut b = Csr5Trace::new(&c5, 4, 8);
        let (ia, _) = a.instruction_estimate();
        let (ib, _) = b.instruction_estimate();
        // Equal nonzeros per range; row-start bookkeeping may differ
        // by the single dense-row start.
        assert!(
            ia.abs_diff(ib) <= CSR5_INS_PER_ROWSTART,
            "{ia} vs {ib}"
        );
        let da = drain(&mut a).len() as i64;
        let db = drain(&mut b).len() as i64;
        assert!((da - db).abs() <= 2, "{da} vs {db}");
    }

    #[test]
    fn empty_ranges() {
        let csr = paper_matrix();
        let mut t = CsrTrace::new(&csr, 2, 2);
        assert!(drain(&mut t).is_empty());
        let c5 = Csr5::from_csr(&csr, 4);
        let mut t5 = Csr5Trace::new(&c5, 1, 1);
        assert!(drain(&mut t5).is_empty());
    }

    #[test]
    fn multi_trace_equals_concat() {
        let csr = paper_matrix();
        let mut whole = CsrTrace::new(&csr, 0, 4);
        let mut multi =
            CsrMultiTrace::new(&csr, vec![(0, 1), (1, 3), (3, 4)]);
        assert_eq!(drain(&mut whole), drain(&mut multi));
    }

    #[test]
    fn multi_trace_estimate_matches() {
        let csr = paper_matrix();
        let whole = CsrTrace::new(&csr, 0, 4).instruction_estimate();
        let multi = CsrMultiTrace::new(&csr, vec![(0, 2), (2, 4)])
            .instruction_estimate();
        assert_eq!(whole, multi);
    }

    #[test]
    fn boxed_gen_works() {
        let csr = paper_matrix();
        let mut b: Box<dyn AccessGen + '_> =
            Box::new(CsrTrace::new(&csr, 0, 4));
        assert_eq!(drain(&mut b).len(), 32);
    }

    #[test]
    fn seq_bits_partition() {
        let csr = paper_matrix();
        let mut t = CsrTrace::new(&csr, 0, 4);
        for a in drain(&mut t) {
            let addr = a & ADDR_MASK;
            let is_x = (X_BASE..Y_BASE).contains(&addr);
            let seq = a & SEQ_BIT != 0;
            assert_eq!(seq, !is_x, "x must be the only random stream");
        }
    }
}
