//! COO (coordinate) format — generator interchange.

use super::csr::Csr;

/// Coordinate-format sparse matrix. Entries may be unsorted and may
/// contain duplicates (summed on conversion to CSR, matching the
//  MatrixMarket convention).
#[derive(Clone, Debug, Default)]
pub struct Coo {
    pub n_rows: usize,
    pub n_cols: usize,
    pub rows: Vec<u32>,
    pub cols: Vec<u32>,
    pub vals: Vec<f64>,
}

impl Coo {
    pub fn new(n_rows: usize, n_cols: usize) -> Self {
        Coo { n_rows, n_cols, rows: vec![], cols: vec![], vals: vec![] }
    }

    pub fn with_capacity(n_rows: usize, n_cols: usize, cap: usize) -> Self {
        Coo {
            n_rows,
            n_cols,
            rows: Vec::with_capacity(cap),
            cols: Vec::with_capacity(cap),
            vals: Vec::with_capacity(cap),
        }
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Append one entry. Debug-asserts bounds.
    #[inline]
    pub fn push(&mut self, r: usize, c: usize, v: f64) {
        debug_assert!(r < self.n_rows && c < self.n_cols);
        self.rows.push(r as u32);
        self.cols.push(c as u32);
        self.vals.push(v);
    }

    /// Validate all indices are in bounds.
    pub fn validate(&self) -> Result<(), String> {
        if self.rows.len() != self.cols.len()
            || self.rows.len() != self.vals.len()
        {
            return Err("parallel arrays length mismatch".into());
        }
        for (i, (&r, &c)) in self.rows.iter().zip(&self.cols).enumerate() {
            if r as usize >= self.n_rows {
                return Err(format!("entry {i}: row {r} out of bounds"));
            }
            if c as usize >= self.n_cols {
                return Err(format!("entry {i}: col {c} out of bounds"));
            }
        }
        Ok(())
    }

    /// Convert to CSR, sorting by (row, col) and summing duplicates.
    pub fn to_csr(&self) -> Csr {
        let nnz = self.nnz();
        // Counting sort by row (O(nnz + n_rows)).
        let mut row_counts = vec![0usize; self.n_rows + 1];
        for &r in &self.rows {
            row_counts[r as usize + 1] += 1;
        }
        for i in 0..self.n_rows {
            row_counts[i + 1] += row_counts[i];
        }
        let mut order: Vec<u32> = vec![0; nnz];
        {
            let mut next = row_counts.clone();
            for (i, &r) in self.rows.iter().enumerate() {
                order[next[r as usize]] = i as u32;
                next[r as usize] += 1;
            }
        }
        // Sort within each row by column, then merge duplicates.
        let mut ptr = Vec::with_capacity(self.n_rows + 1);
        let mut indices: Vec<u32> = Vec::with_capacity(nnz);
        let mut data: Vec<f64> = Vec::with_capacity(nnz);
        ptr.push(0usize);
        let mut scratch: Vec<(u32, f64)> = Vec::new();
        for r in 0..self.n_rows {
            scratch.clear();
            for &oi in &order[row_counts[r]..row_counts[r + 1]] {
                scratch
                    .push((self.cols[oi as usize], self.vals[oi as usize]));
            }
            scratch.sort_unstable_by_key(|&(c, _)| c);
            let mut i = 0;
            while i < scratch.len() {
                let (c, mut v) = scratch[i];
                let mut j = i + 1;
                while j < scratch.len() && scratch[j].0 == c {
                    v += scratch[j].1;
                    j += 1;
                }
                indices.push(c);
                data.push(v);
                i = j;
            }
            ptr.push(indices.len());
        }
        Csr { n_rows: self.n_rows, n_cols: self.n_cols, ptr, indices, data }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_to_csr() {
        // Figure 1 matrix: 4x4, nnz=8.
        //   row0: (0,1)=5 (0,2)=2
        //   row1: (1,0)=6 (1,2)=8 (1,3)=3
        //   row2: (2,2)=4
        //   row3: (3,1)=7 (3,2)=1
        let mut coo = Coo::new(4, 4);
        for &(r, c, v) in &[
            (0, 1, 5.0),
            (0, 2, 2.0),
            (1, 0, 6.0),
            (1, 2, 8.0),
            (1, 3, 3.0),
            (2, 2, 4.0),
            (3, 1, 7.0),
            (3, 2, 1.0),
        ] {
            coo.push(r, c, v);
        }
        let csr = coo.to_csr();
        // Table 1 values.
        assert_eq!(csr.ptr, vec![0, 2, 5, 6, 8]);
        assert_eq!(csr.indices, vec![1, 2, 0, 2, 3, 2, 1, 2]);
        assert_eq!(
            csr.data,
            vec![5.0, 2.0, 6.0, 8.0, 3.0, 4.0, 7.0, 1.0]
        );
    }

    #[test]
    fn unsorted_input_sorted_output() {
        let mut coo = Coo::new(3, 3);
        coo.push(2, 2, 1.0);
        coo.push(0, 1, 2.0);
        coo.push(2, 0, 3.0);
        coo.push(0, 0, 4.0);
        let csr = coo.to_csr();
        assert_eq!(csr.ptr, vec![0, 2, 2, 4]);
        assert_eq!(csr.indices, vec![0, 1, 0, 2]);
        assert_eq!(csr.data, vec![4.0, 2.0, 3.0, 1.0]);
    }

    #[test]
    fn duplicates_summed() {
        let mut coo = Coo::new(2, 2);
        coo.push(0, 0, 1.0);
        coo.push(0, 0, 2.5);
        coo.push(1, 1, 1.0);
        let csr = coo.to_csr();
        assert_eq!(csr.nnz(), 2);
        assert_eq!(csr.data[0], 3.5);
    }

    #[test]
    fn empty_rows_ok() {
        let coo = Coo::new(5, 5);
        let csr = coo.to_csr();
        assert_eq!(csr.ptr, vec![0; 6]);
        assert_eq!(csr.nnz(), 0);
    }

    #[test]
    fn validate_catches_oob() {
        let mut coo = Coo::new(2, 2);
        coo.rows.push(5);
        coo.cols.push(0);
        coo.vals.push(1.0);
        assert!(coo.validate().is_err());
    }
}
