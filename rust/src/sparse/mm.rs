//! MatrixMarket coordinate I/O — lets users run the harness on real
//! SuiteSparse matrices (the paper's dataset) when they have them; the
//! synthetic corpus is the default substitute (DESIGN.md).

use std::collections::HashSet;
use std::io::{BufRead, BufReader, Read, Write};

use super::coo::Coo;
use super::csr::Csr;

#[derive(Debug, thiserror::Error)]
pub enum MmError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("parse error at line {line}: {msg}")]
    Parse { line: usize, msg: String },
    #[error("unsupported MatrixMarket variant: {0}")]
    Unsupported(String),
}

fn perr(line: usize, msg: impl Into<String>) -> MmError {
    MmError::Parse { line, msg: msg.into() }
}

/// Read a MatrixMarket coordinate file (real/integer/pattern,
/// general/symmetric) into COO.
pub fn read_coo<R: Read>(r: R) -> Result<Coo, MmError> {
    let mut lines = BufReader::new(r).lines().enumerate();
    // Header.
    let (_, header) = lines
        .next()
        .ok_or_else(|| perr(0, "empty file"))?
        .1
        .map(|h| (0, h))
        .map_err(MmError::Io)?;
    // Tolerate CRLF files: `BufRead::lines` strips the `\n` but
    // leaves the `\r`.
    let header_lc = header.trim_end().to_lowercase();
    if !header_lc.starts_with("%%matrixmarket matrix coordinate") {
        return Err(MmError::Unsupported(header));
    }
    let pattern = header_lc.contains(" pattern");
    let symmetric = header_lc.contains(" symmetric");
    if header_lc.contains(" complex") || header_lc.contains(" hermitian") {
        return Err(MmError::Unsupported(header));
    }
    // Size line (skipping comments).
    let mut size: Option<(usize, usize, usize)> = None;
    let mut coo = Coo::default();
    let mut remaining = 0usize;
    // Duplicate coordinates would silently sum in `Coo::to_csr` —
    // reject them at load as counted parse errors instead (a
    // symmetric file repeating a mirrored pair trips this too).
    let mut seen: HashSet<(usize, usize)> = HashSet::new();
    for (ln, line) in lines {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let fields: Vec<&str> = t.split_whitespace().collect();
        if size.is_none() {
            if fields.len() != 3 {
                return Err(perr(ln + 1, "bad size line"));
            }
            let m: usize = fields[0]
                .parse()
                .map_err(|_| perr(ln + 1, "bad rows"))?;
            let n: usize = fields[1]
                .parse()
                .map_err(|_| perr(ln + 1, "bad cols"))?;
            let nnz: usize = fields[2]
                .parse()
                .map_err(|_| perr(ln + 1, "bad nnz"))?;
            // Oversized declarations are rejected before they size a
            // buffer: a corrupt size line must be a counted parse
            // error, never an allocation blow-up downstream.
            let cap = m.checked_mul(n).ok_or_else(|| {
                perr(ln + 1, format!("dimensions {m}x{n} overflow"))
            })?;
            if nnz > cap {
                return Err(perr(
                    ln + 1,
                    format!(
                        "declared nnz {nnz} exceeds the {m}x{n} \
                         matrix capacity {cap}"
                    ),
                ));
            }
            size = Some((m, n, nnz));
            remaining = nnz;
            coo = Coo::with_capacity(
                m,
                n,
                nnz.saturating_mul(if symmetric { 2 } else { 1 }),
            );
            continue;
        }
        if remaining == 0 {
            return Err(perr(ln + 1, "more entries than declared"));
        }
        let want = if pattern { 2 } else { 3 };
        if fields.len() < want {
            return Err(perr(ln + 1, "short entry line"));
        }
        let r: usize =
            fields[0].parse().map_err(|_| perr(ln + 1, "bad row index"))?;
        let c: usize =
            fields[1].parse().map_err(|_| perr(ln + 1, "bad col index"))?;
        let v: f64 = if pattern {
            1.0
        } else {
            fields[2].parse().map_err(|_| perr(ln + 1, "bad value"))?
        };
        // `"NaN".parse::<f64>()` succeeds — catch non-finite values
        // here or they poison every kernel and fingerprint downstream.
        if !v.is_finite() {
            return Err(perr(
                ln + 1,
                format!("non-finite value {v} at ({r},{c})"),
            ));
        }
        if r == 0 || c == 0 || r > coo.n_rows || c > coo.n_cols {
            return Err(perr(
                ln + 1,
                format!(
                    "index ({r},{c}) out of range for {}x{} matrix \
                     (1-based indices expected)",
                    coo.n_rows, coo.n_cols
                ),
            ));
        }
        if !seen.insert((r - 1, c - 1)) {
            return Err(perr(
                ln + 1,
                format!("duplicate entry for coordinate ({r},{c})"),
            ));
        }
        coo.push(r - 1, c - 1, v);
        if symmetric && r != c {
            if !seen.insert((c - 1, r - 1)) {
                return Err(perr(
                    ln + 1,
                    format!(
                        "symmetric mirror of ({r},{c}) duplicates an \
                         explicit entry"
                    ),
                ));
            }
            coo.push(c - 1, r - 1, v);
        }
        remaining -= 1;
    }
    if size.is_none() {
        return Err(perr(0, "missing size line"));
    }
    if remaining != 0 {
        return Err(perr(0, format!("{remaining} entries missing")));
    }
    Ok(coo)
}

/// Read straight to CSR.
pub fn read_csr<R: Read>(r: R) -> Result<Csr, MmError> {
    Ok(read_coo(r)?.to_csr())
}

/// Write a CSR matrix as MatrixMarket coordinate real general.
pub fn write_csr<W: Write>(w: &mut W, csr: &Csr) -> Result<(), MmError> {
    writeln!(w, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(w, "% generated by ft2000-spmv")?;
    writeln!(w, "{} {} {}", csr.n_rows, csr.n_cols, csr.nnz())?;
    for r in 0..csr.n_rows {
        let (cols, vals) = csr.row(r);
        for (c, v) in cols.iter().zip(vals) {
            writeln!(w, "{} {} {:e}", r + 1, *c as usize + 1, v)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    const GENERAL: &str = "%%MatrixMarket matrix coordinate real general\n\
         % comment\n\
         3 3 4\n\
         1 1 2.0\n\
         2 3 -1.5\n\
         3 1 4.0\n\
         3 3 1.0\n";

    #[test]
    fn reads_general() {
        let csr = read_csr(GENERAL.as_bytes()).unwrap();
        assert_eq!(csr.n_rows, 3);
        assert_eq!(csr.nnz(), 4);
        let mut y = vec![0.0; 3];
        csr.spmv(&[1.0, 1.0, 1.0], &mut y);
        assert_eq!(y, vec![2.0, -1.5, 5.0]);
    }

    #[test]
    fn reads_symmetric() {
        let text = "%%MatrixMarket matrix coordinate real symmetric\n\
             2 2 2\n\
             1 1 1.0\n\
             2 1 3.0\n";
        let csr = read_csr(text.as_bytes()).unwrap();
        assert_eq!(csr.nnz(), 3); // mirror of (2,1) added
        let mut y = vec![0.0; 2];
        csr.spmv(&[1.0, 1.0], &mut y);
        assert_eq!(y, vec![4.0, 3.0]);
    }

    #[test]
    fn reads_pattern() {
        let text = "%%MatrixMarket matrix coordinate pattern general\n\
             2 2 2\n\
             1 2\n\
             2 1\n";
        let csr = read_csr(text.as_bytes()).unwrap();
        assert_eq!(csr.data, vec![1.0, 1.0]);
    }

    #[test]
    fn rejects_complex() {
        let text = "%%MatrixMarket matrix coordinate complex general\n2 2 1\n1 1 1 0\n";
        assert!(matches!(
            read_csr(text.as_bytes()),
            Err(MmError::Unsupported(_))
        ));
    }

    #[test]
    fn rejects_oob_and_short() {
        let text = "%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1.0\n";
        assert!(read_csr(text.as_bytes()).is_err());
        let text2 = "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n";
        assert!(read_csr(text2.as_bytes()).is_err());
    }

    #[test]
    fn rejects_duplicate_coordinates() {
        let text = "%%MatrixMarket matrix coordinate real general\n\
             3 3 3\n\
             1 1 1.0\n\
             2 2 2.0\n\
             1 1 4.0\n";
        match read_csr(text.as_bytes()) {
            Err(MmError::Parse { line, msg }) => {
                assert_eq!(line, 5);
                assert!(msg.contains("duplicate"), "unexpected: {msg}");
            }
            other => panic!("expected duplicate error, got {other:?}"),
        }
        // A symmetric file listing both triangles duplicates through
        // the mirror push.
        let text = "%%MatrixMarket matrix coordinate real symmetric\n\
             2 2 2\n\
             2 1 3.0\n\
             1 2 3.0\n";
        match read_csr(text.as_bytes()) {
            Err(MmError::Parse { msg, .. }) => {
                assert!(msg.contains("duplicate"), "unexpected: {msg}");
            }
            other => panic!("expected duplicate error, got {other:?}"),
        }
    }

    #[test]
    fn rejects_non_finite_values() {
        for bad in ["NaN", "nan", "inf", "-inf", "Infinity"] {
            let text = format!(
                "%%MatrixMarket matrix coordinate real general\n\
                 2 2 2\n\
                 1 1 1.0\n\
                 2 2 {bad}\n"
            );
            match read_csr(text.as_bytes()) {
                Err(MmError::Parse { line, msg }) => {
                    assert_eq!(line, 4, "{bad}");
                    assert!(
                        msg.contains("non-finite"),
                        "unexpected message for {bad}: {msg}"
                    );
                }
                other => {
                    panic!("{bad} must be a parse error, got {other:?}")
                }
            }
        }
    }

    #[test]
    fn rejects_oversized_declarations() {
        // Declared nnz past the matrix capacity: rejected at the size
        // line, before any entry buffer is sized from it.
        let text = "%%MatrixMarket matrix coordinate real general\n\
             3 3 100\n\
             1 1 1.0\n";
        match read_csr(text.as_bytes()) {
            Err(MmError::Parse { line, msg }) => {
                assert_eq!(line, 2);
                assert!(msg.contains("capacity"), "unexpected: {msg}");
            }
            other => panic!("expected parse error, got {other:?}"),
        }
        // Dimensions whose product overflows usize.
        let huge = usize::MAX;
        let text = format!(
            "%%MatrixMarket matrix coordinate real general\n\
             {huge} {huge} 1\n\
             1 1 1.0\n"
        );
        match read_csr(text.as_bytes()) {
            Err(MmError::Parse { msg, .. }) => {
                assert!(msg.contains("overflow"), "unexpected: {msg}");
            }
            other => panic!("expected parse error, got {other:?}"),
        }
    }

    #[test]
    fn roundtrip() {
        let csr = read_csr(GENERAL.as_bytes()).unwrap();
        let mut buf = Vec::new();
        write_csr(&mut buf, &csr).unwrap();
        let back = read_csr(buf.as_slice()).unwrap();
        assert_eq!(csr, back);
    }

    #[test]
    fn accepts_crlf_line_endings() {
        let text = GENERAL.replace('\n', "\r\n");
        let csr = read_csr(text.as_bytes()).unwrap();
        assert_eq!(csr, read_csr(GENERAL.as_bytes()).unwrap());
    }

    #[test]
    fn accepts_trailing_blank_lines() {
        let text = format!("{GENERAL}\n\r\n   \n");
        let csr = read_csr(text.as_bytes()).unwrap();
        assert_eq!(csr.nnz(), 4);
        // Blank lines between entries too (some exporters do this).
        let text = "%%MatrixMarket matrix coordinate real general\n\
             2 2 2\n\
             1 1 1.0\n\
             \n\
             2 2 3.0\n";
        assert_eq!(read_csr(text.as_bytes()).unwrap().nnz(), 2);
    }

    #[test]
    fn out_of_range_reports_dims_not_panics() {
        let text = "%%MatrixMarket matrix coordinate real general\n\
             3 3 1\n\
             4 1 1.0\n";
        match read_csr(text.as_bytes()) {
            Err(MmError::Parse { line, msg }) => {
                assert_eq!(line, 3);
                assert!(msg.contains("3x3"), "message lacks dims: {msg}");
            }
            other => panic!("expected parse error, got {other:?}"),
        }
        // Zero (0-based) indices are the classic exporter bug.
        let text = "%%MatrixMarket matrix coordinate real general\n\
             3 3 1\n\
             0 1 1.0\n";
        assert!(matches!(
            read_csr(text.as_bytes()),
            Err(MmError::Parse { .. })
        ));
    }

    #[test]
    fn coo_write_read_roundtrip_including_pattern_symmetric() {
        // COO (with an unsorted duplicate) -> CSR -> write -> read.
        let mut coo = Coo::new(3, 4);
        coo.push(2, 1, 4.0);
        coo.push(0, 3, 1.5);
        coo.push(0, 3, 0.5); // duplicate, summed on conversion
        coo.push(1, 0, -2.0);
        let csr = coo.to_csr();
        let mut buf = Vec::new();
        write_csr(&mut buf, &csr).unwrap();
        let back = read_csr(buf.as_slice()).unwrap();
        assert_eq!(csr, back);

        // Pattern symmetric source: read (mirroring off-diagonals),
        // write as real general, read back — same matrix.
        let text = "%%MatrixMarket matrix coordinate pattern symmetric\r\n\
             3 3 3\r\n\
             1 1\r\n\
             2 1\r\n\
             3 2\r\n\
             \r\n";
        let sym = read_csr(text.as_bytes()).unwrap();
        assert_eq!(sym.nnz(), 5); // 1 diagonal + 2 mirrored pairs
        let mut buf = Vec::new();
        write_csr(&mut buf, &sym).unwrap();
        let back = read_csr(buf.as_slice()).unwrap();
        assert_eq!(sym, back);
        let x = [1.0, 2.0, 3.0];
        let (mut y0, mut y1) = (vec![0.0; 3], vec![0.0; 3]);
        sym.spmv(&x, &mut y0);
        back.spmv(&x, &mut y1);
        assert_eq!(y0, y1);
    }
}
