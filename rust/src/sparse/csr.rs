//! CSR — the paper's primary storage format (§2.2, Table 1).

/// Compressed Sparse Row matrix with f64 values (the paper measures
/// double-precision Gflops on FT-2000+).
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub n_rows: usize,
    pub n_cols: usize,
    /// Row pointers, length `n_rows + 1`; last entry == nnz.
    pub ptr: Vec<usize>,
    /// Column index per nonzero.
    pub indices: Vec<u32>,
    /// Value per nonzero.
    pub data: Vec<f64>,
}

impl Csr {
    /// An empty (all-zero) matrix.
    pub fn zero(n_rows: usize, n_cols: usize) -> Self {
        Csr {
            n_rows,
            n_cols,
            ptr: vec![0; n_rows + 1],
            indices: vec![],
            data: vec![],
        }
    }

    /// Identity matrix (square).
    pub fn identity(n: usize) -> Self {
        Csr {
            n_rows: n,
            n_cols: n,
            ptr: (0..=n).collect(),
            indices: (0..n as u32).collect(),
            data: vec![1.0; n],
        }
    }

    pub fn nnz(&self) -> usize {
        self.data.len()
    }

    /// Number of nonzeros in row `r`.
    #[inline]
    pub fn row_nnz(&self, r: usize) -> usize {
        self.ptr[r + 1] - self.ptr[r]
    }

    /// (columns, values) slices of row `r`.
    #[inline]
    pub fn row(&self, r: usize) -> (&[u32], &[f64]) {
        let (a, b) = (self.ptr[r], self.ptr[r + 1]);
        (&self.indices[a..b], &self.data[a..b])
    }

    /// Maximum nonzeros in any row (Table 3 `nnz_max`).
    pub fn max_row_nnz(&self) -> usize {
        (0..self.n_rows).map(|r| self.row_nnz(r)).max().unwrap_or(0)
    }

    /// Structural validation: monotone ptr, in-bound sorted columns.
    pub fn validate(&self) -> Result<(), String> {
        if self.ptr.len() != self.n_rows + 1 {
            return Err("ptr length != n_rows + 1".into());
        }
        if *self.ptr.last().unwrap() != self.nnz() {
            return Err("ptr[last] != nnz".into());
        }
        if self.indices.len() != self.data.len() {
            return Err("indices/data length mismatch".into());
        }
        for r in 0..self.n_rows {
            if self.ptr[r] > self.ptr[r + 1] {
                return Err(format!("ptr not monotone at row {r}"));
            }
            let (cols, _) = self.row(r);
            for w in cols.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!(
                        "row {r}: columns not strictly increasing"
                    ));
                }
            }
            if let Some(&c) = cols.last() {
                if c as usize >= self.n_cols {
                    return Err(format!("row {r}: column {c} out of bounds"));
                }
            }
        }
        Ok(())
    }

    /// Sequential SpMV: y = A x. The reference semantics for every
    /// other executor in the crate.
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.n_cols);
        assert_eq!(y.len(), self.n_rows);
        for r in 0..self.n_rows {
            let mut acc = 0.0;
            for i in self.ptr[r]..self.ptr[r + 1] {
                acc += self.data[i] * x[self.indices[i] as usize];
            }
            y[r] = acc;
        }
    }

    /// SpMV over a row range [r0, r1) — the unit of work the static
    /// OpenMP schedule assigns to a thread.
    pub fn spmv_rows(&self, r0: usize, r1: usize, x: &[f64], y: &mut [f64]) {
        debug_assert!(r1 <= self.n_rows && y.len() == self.n_rows);
        for r in r0..r1 {
            let mut acc = 0.0;
            for i in self.ptr[r]..self.ptr[r + 1] {
                acc += self.data[i] * x[self.indices[i] as usize];
            }
            y[r] = acc;
        }
    }

    /// Transpose (used by reordering heuristics and generators).
    pub fn transpose(&self) -> Csr {
        let mut counts = vec![0usize; self.n_cols + 1];
        for &c in &self.indices {
            counts[c as usize + 1] += 1;
        }
        for i in 0..self.n_cols {
            counts[i + 1] += counts[i];
        }
        let mut ptr = counts.clone();
        let mut indices = vec![0u32; self.nnz()];
        let mut data = vec![0.0; self.nnz()];
        for r in 0..self.n_rows {
            for i in self.ptr[r]..self.ptr[r + 1] {
                let c = self.indices[i] as usize;
                let dst = ptr[c];
                indices[dst] = r as u32;
                data[dst] = self.data[i];
                ptr[c] += 1;
            }
        }
        Csr {
            n_rows: self.n_cols,
            n_cols: self.n_rows,
            ptr: counts,
            indices,
            data,
        }
    }

    /// Apply a row permutation: out.row[i] = self.row[perm[i]].
    pub fn permute_rows(&self, perm: &[usize]) -> Csr {
        assert_eq!(perm.len(), self.n_rows);
        let mut ptr = Vec::with_capacity(self.n_rows + 1);
        let mut indices = Vec::with_capacity(self.nnz());
        let mut data = Vec::with_capacity(self.nnz());
        ptr.push(0);
        for &src in perm {
            let (cols, vals) = self.row(src);
            indices.extend_from_slice(cols);
            data.extend_from_slice(vals);
            ptr.push(indices.len());
        }
        Csr { n_rows: self.n_rows, n_cols: self.n_cols, ptr, indices, data }
    }

    /// Bytes touched by a full CSR SpMV pass (working-set estimate used
    /// by the analytical roofline in §Perf): ptr + indices + data + x + y.
    pub fn working_set_bytes(&self) -> usize {
        (self.n_rows + 1) * std::mem::size_of::<usize>()
            + self.nnz() * std::mem::size_of::<u32>()
            + self.nnz() * std::mem::size_of::<f64>()
            + self.n_cols * std::mem::size_of::<f64>()
            + self.n_rows * std::mem::size_of::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;

    pub(crate) fn paper_matrix() -> Csr {
        let mut coo = Coo::new(4, 4);
        for &(r, c, v) in &[
            (0, 1, 5.0),
            (0, 2, 2.0),
            (1, 0, 6.0),
            (1, 2, 8.0),
            (1, 3, 3.0),
            (2, 2, 4.0),
            (3, 1, 7.0),
            (3, 2, 1.0),
        ] {
            coo.push(r, c, v);
        }
        coo.to_csr()
    }

    #[test]
    fn figure1_spmv() {
        // Fig 1: A (4x4, nnz=8) times x -> 4x1 vector.
        let a = paper_matrix();
        let x = [1.0, 2.0, 3.0, 4.0];
        let mut y = [0.0; 4];
        a.spmv(&x, &mut y);
        // row0: 5*2 + 2*3 = 16; row1: 6*1 + 8*3 + 3*4 = 42;
        // row2: 4*3 = 12; row3: 7*2 + 1*3 = 17.
        assert_eq!(y, [16.0, 42.0, 12.0, 17.0]);
    }

    #[test]
    fn identity_spmv() {
        let a = Csr::identity(16);
        let x: Vec<f64> = (0..16).map(|i| i as f64).collect();
        let mut y = vec![0.0; 16];
        a.spmv(&x, &mut y);
        assert_eq!(x, y);
    }

    #[test]
    fn spmv_rows_partial() {
        let a = paper_matrix();
        let x = [1.0, 2.0, 3.0, 4.0];
        let mut y = [0.0; 4];
        a.spmv_rows(1, 3, &x, &mut y);
        assert_eq!(y, [0.0, 42.0, 12.0, 0.0]);
    }

    #[test]
    fn validate_accepts_good() {
        assert!(paper_matrix().validate().is_ok());
        assert!(Csr::zero(3, 3).validate().is_ok());
        assert!(Csr::identity(5).validate().is_ok());
    }

    #[test]
    fn validate_rejects_bad() {
        let mut a = paper_matrix();
        a.indices[0] = 9; // out of bounds
        assert!(a.validate().is_err());
        let mut b = paper_matrix();
        b.ptr[2] = 0; // non-monotone
        assert!(b.validate().is_err());
    }

    #[test]
    fn transpose_roundtrip() {
        let a = paper_matrix();
        let att = a.transpose().transpose();
        assert_eq!(a, att);
    }

    #[test]
    fn transpose_spmv_agrees() {
        // (A^T x)[c] == sum_r A[r,c] x[r]
        let a = paper_matrix();
        let at = a.transpose();
        let x = [1.0, -1.0, 0.5, 2.0];
        let mut y = [0.0; 4];
        at.spmv(&x, &mut y);
        let mut want = [0.0; 4];
        for r in 0..4 {
            let (cols, vals) = a.row(r);
            for (c, v) in cols.iter().zip(vals) {
                want[*c as usize] += v * x[r];
            }
        }
        for (a, b) in y.iter().zip(&want) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn permute_rows_identity() {
        let a = paper_matrix();
        let perm: Vec<usize> = (0..4).collect();
        assert_eq!(a.permute_rows(&perm), a);
    }

    #[test]
    fn permute_rows_swap() {
        let a = paper_matrix();
        let b = a.permute_rows(&[3, 2, 1, 0]);
        assert_eq!(b.row_nnz(0), a.row_nnz(3));
        assert_eq!(b.row(0), a.row(3));
        assert_eq!(b.nnz(), a.nnz());
    }

    #[test]
    fn max_row_nnz_paper() {
        assert_eq!(paper_matrix().max_row_nnz(), 3);
        assert_eq!(Csr::zero(4, 4).max_row_nnz(), 0);
    }

    #[test]
    fn working_set_positive() {
        assert!(paper_matrix().working_set_bytes() > 0);
    }
}
