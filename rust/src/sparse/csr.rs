//! CSR — the paper's primary storage format (§2.2, Table 1).

/// Fused (or fused-looking) multiply-add used by every SpMV/SpMM
/// kernel in the crate. On targets with hardware FMA (aarch64, or
/// x86-64 built with `+fma`) this is one `f64::mul_add`; elsewhere it
/// falls back to `acc + a * b` — a software-emulated correctly-rounded
/// fma would be ~50x slower than the kernel it sits in. Either way the
/// choice is uniform across *all* kernels of one build, which is what
/// the bitwise-equivalence property tests pin (they compare kernels
/// against each other, never against a cross-platform constant).
#[inline(always)]
pub fn fmadd(a: f64, b: f64, acc: f64) -> f64 {
    #[cfg(any(target_feature = "fma", target_arch = "aarch64"))]
    {
        a.mul_add(b, acc)
    }
    #[cfg(not(any(target_feature = "fma", target_arch = "aarch64")))]
    {
        acc + a * b
    }
}

/// The shared row-dot accumulation discipline: element `k` of a row
/// lands in accumulator `k % 4`, and the final sum is
/// `(a0 + a1) + (a2 + a3)`. Every row-space kernel (sequential CSR,
/// threaded CSR, SELL-C-σ, batched SpMM) follows this exact order, so
/// their outputs are bitwise identical by construction — zero-padding
/// appended to a row (SELL chunks) contributes exact no-ops
/// (`fmadd(0.0, x, acc) == acc` for finite `x` and the non-negative
/// zero accumulators this chain produces).
#[inline]
pub fn row_dot(cols: &[u32], vals: &[f64], x: &[f64]) -> f64 {
    debug_assert_eq!(cols.len(), vals.len());
    let n = vals.len();
    let mut a = [0.0f64; 4];
    let main = n & !3;
    let mut k = 0;
    while k < main {
        a[0] = fmadd(vals[k], x[cols[k] as usize], a[0]);
        a[1] = fmadd(vals[k + 1], x[cols[k + 1] as usize], a[1]);
        a[2] = fmadd(vals[k + 2], x[cols[k + 2] as usize], a[2]);
        a[3] = fmadd(vals[k + 3], x[cols[k + 3] as usize], a[3]);
        k += 4;
    }
    let mut e = 0;
    while k < n {
        a[e] = fmadd(vals[k], x[cols[k] as usize], a[e]);
        e += 1;
        k += 1;
    }
    (a[0] + a[1]) + (a[2] + a[3])
}

/// The pre-PR-5 scalar row kernel (single accumulator, plain
/// multiply-add), kept as the microbench baseline of the `kernels`
/// bench section. Not bitwise-comparable to [`row_dot`] — different
/// association order.
#[inline]
pub fn row_dot_scalar(cols: &[u32], vals: &[f64], x: &[f64]) -> f64 {
    let mut acc = 0.0;
    for (c, v) in cols.iter().zip(vals) {
        acc += v * x[*c as usize];
    }
    acc
}

/// Compressed Sparse Row matrix with f64 values (the paper measures
/// double-precision Gflops on FT-2000+).
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub n_rows: usize,
    pub n_cols: usize,
    /// Row pointers, length `n_rows + 1`; last entry == nnz.
    pub ptr: Vec<usize>,
    /// Column index per nonzero.
    pub indices: Vec<u32>,
    /// Value per nonzero.
    pub data: Vec<f64>,
}

impl Csr {
    /// An empty (all-zero) matrix.
    pub fn zero(n_rows: usize, n_cols: usize) -> Self {
        Csr {
            n_rows,
            n_cols,
            ptr: vec![0; n_rows + 1],
            indices: vec![],
            data: vec![],
        }
    }

    /// Identity matrix (square).
    pub fn identity(n: usize) -> Self {
        Csr {
            n_rows: n,
            n_cols: n,
            ptr: (0..=n).collect(),
            indices: (0..n as u32).collect(),
            data: vec![1.0; n],
        }
    }

    pub fn nnz(&self) -> usize {
        self.data.len()
    }

    /// Number of nonzeros in row `r`.
    #[inline]
    pub fn row_nnz(&self, r: usize) -> usize {
        self.ptr[r + 1] - self.ptr[r]
    }

    /// (columns, values) slices of row `r`.
    #[inline]
    pub fn row(&self, r: usize) -> (&[u32], &[f64]) {
        let (a, b) = (self.ptr[r], self.ptr[r + 1]);
        (&self.indices[a..b], &self.data[a..b])
    }

    /// Maximum nonzeros in any row (Table 3 `nnz_max`).
    pub fn max_row_nnz(&self) -> usize {
        (0..self.n_rows).map(|r| self.row_nnz(r)).max().unwrap_or(0)
    }

    /// Structural validation: monotone ptr, in-bound sorted columns.
    pub fn validate(&self) -> Result<(), String> {
        if self.ptr.len() != self.n_rows + 1 {
            return Err("ptr length != n_rows + 1".into());
        }
        if *self.ptr.last().unwrap() != self.nnz() {
            return Err("ptr[last] != nnz".into());
        }
        if self.indices.len() != self.data.len() {
            return Err("indices/data length mismatch".into());
        }
        for r in 0..self.n_rows {
            if self.ptr[r] > self.ptr[r + 1] {
                return Err(format!("ptr not monotone at row {r}"));
            }
            let (cols, _) = self.row(r);
            for w in cols.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!(
                        "row {r}: columns not strictly increasing"
                    ));
                }
            }
            if let Some(&c) = cols.last() {
                if c as usize >= self.n_cols {
                    return Err(format!("row {r}: column {c} out of bounds"));
                }
            }
        }
        Ok(())
    }

    /// Sequential SpMV: y = A x. The reference semantics for every
    /// other executor in the crate — each row is reduced by the shared
    /// 4-accumulator [`row_dot`] kernel, so row-space threaded
    /// executions (and SELL-C-σ) reproduce it bit for bit.
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.n_cols);
        assert_eq!(y.len(), self.n_rows);
        self.spmv_rows(0, self.n_rows, x, y);
    }

    /// SpMV over a row range [r0, r1) — the unit of work the static
    /// OpenMP schedule assigns to a thread (4x-unrolled `fmadd` inner
    /// loop; see [`row_dot`]).
    pub fn spmv_rows(&self, r0: usize, r1: usize, x: &[f64], y: &mut [f64]) {
        debug_assert!(r1 <= self.n_rows && y.len() == self.n_rows);
        for r in r0..r1 {
            let (cols, vals) = self.row(r);
            y[r] = row_dot(cols, vals, x);
        }
    }

    /// Transpose (used by reordering heuristics and generators).
    pub fn transpose(&self) -> Csr {
        let mut counts = vec![0usize; self.n_cols + 1];
        for &c in &self.indices {
            counts[c as usize + 1] += 1;
        }
        for i in 0..self.n_cols {
            counts[i + 1] += counts[i];
        }
        let mut ptr = counts.clone();
        let mut indices = vec![0u32; self.nnz()];
        let mut data = vec![0.0; self.nnz()];
        for r in 0..self.n_rows {
            for i in self.ptr[r]..self.ptr[r + 1] {
                let c = self.indices[i] as usize;
                let dst = ptr[c];
                indices[dst] = r as u32;
                data[dst] = self.data[i];
                ptr[c] += 1;
            }
        }
        Csr {
            n_rows: self.n_cols,
            n_cols: self.n_rows,
            ptr: counts,
            indices,
            data,
        }
    }

    /// Apply a row permutation: out.row[i] = self.row[perm[i]].
    pub fn permute_rows(&self, perm: &[usize]) -> Csr {
        assert_eq!(perm.len(), self.n_rows);
        let mut ptr = Vec::with_capacity(self.n_rows + 1);
        let mut indices = Vec::with_capacity(self.nnz());
        let mut data = Vec::with_capacity(self.nnz());
        ptr.push(0);
        for &src in perm {
            let (cols, vals) = self.row(src);
            indices.extend_from_slice(cols);
            data.extend_from_slice(vals);
            ptr.push(indices.len());
        }
        Csr { n_rows: self.n_rows, n_cols: self.n_cols, ptr, indices, data }
    }

    /// Bytes touched by a full CSR SpMV pass (working-set estimate used
    /// by the analytical roofline in §Perf): ptr + indices + data + x + y.
    pub fn working_set_bytes(&self) -> usize {
        (self.n_rows + 1) * std::mem::size_of::<usize>()
            + self.nnz() * std::mem::size_of::<u32>()
            + self.nnz() * std::mem::size_of::<f64>()
            + self.n_cols * std::mem::size_of::<f64>()
            + self.n_rows * std::mem::size_of::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;

    pub(crate) fn paper_matrix() -> Csr {
        let mut coo = Coo::new(4, 4);
        for &(r, c, v) in &[
            (0, 1, 5.0),
            (0, 2, 2.0),
            (1, 0, 6.0),
            (1, 2, 8.0),
            (1, 3, 3.0),
            (2, 2, 4.0),
            (3, 1, 7.0),
            (3, 2, 1.0),
        ] {
            coo.push(r, c, v);
        }
        coo.to_csr()
    }

    #[test]
    fn figure1_spmv() {
        // Fig 1: A (4x4, nnz=8) times x -> 4x1 vector.
        let a = paper_matrix();
        let x = [1.0, 2.0, 3.0, 4.0];
        let mut y = [0.0; 4];
        a.spmv(&x, &mut y);
        // row0: 5*2 + 2*3 = 16; row1: 6*1 + 8*3 + 3*4 = 42;
        // row2: 4*3 = 12; row3: 7*2 + 1*3 = 17.
        assert_eq!(y, [16.0, 42.0, 12.0, 17.0]);
    }

    #[test]
    fn identity_spmv() {
        let a = Csr::identity(16);
        let x: Vec<f64> = (0..16).map(|i| i as f64).collect();
        let mut y = vec![0.0; 16];
        a.spmv(&x, &mut y);
        assert_eq!(x, y);
    }

    #[test]
    fn spmv_rows_partial() {
        let a = paper_matrix();
        let x = [1.0, 2.0, 3.0, 4.0];
        let mut y = [0.0; 4];
        a.spmv_rows(1, 3, &x, &mut y);
        assert_eq!(y, [0.0, 42.0, 12.0, 0.0]);
    }

    #[test]
    fn validate_accepts_good() {
        assert!(paper_matrix().validate().is_ok());
        assert!(Csr::zero(3, 3).validate().is_ok());
        assert!(Csr::identity(5).validate().is_ok());
    }

    #[test]
    fn validate_rejects_bad() {
        let mut a = paper_matrix();
        a.indices[0] = 9; // out of bounds
        assert!(a.validate().is_err());
        let mut b = paper_matrix();
        b.ptr[2] = 0; // non-monotone
        assert!(b.validate().is_err());
    }

    #[test]
    fn transpose_roundtrip() {
        let a = paper_matrix();
        let att = a.transpose().transpose();
        assert_eq!(a, att);
    }

    #[test]
    fn transpose_spmv_agrees() {
        // (A^T x)[c] == sum_r A[r,c] x[r]
        let a = paper_matrix();
        let at = a.transpose();
        let x = [1.0, -1.0, 0.5, 2.0];
        let mut y = [0.0; 4];
        at.spmv(&x, &mut y);
        let mut want = [0.0; 4];
        for r in 0..4 {
            let (cols, vals) = a.row(r);
            for (c, v) in cols.iter().zip(vals) {
                want[*c as usize] += v * x[r];
            }
        }
        for (a, b) in y.iter().zip(&want) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn permute_rows_identity() {
        let a = paper_matrix();
        let perm: Vec<usize> = (0..4).collect();
        assert_eq!(a.permute_rows(&perm), a);
    }

    #[test]
    fn permute_rows_swap() {
        let a = paper_matrix();
        let b = a.permute_rows(&[3, 2, 1, 0]);
        assert_eq!(b.row_nnz(0), a.row_nnz(3));
        assert_eq!(b.row(0), a.row(3));
        assert_eq!(b.nnz(), a.nnz());
    }

    #[test]
    fn max_row_nnz_paper() {
        assert_eq!(paper_matrix().max_row_nnz(), 3);
        assert_eq!(Csr::zero(4, 4).max_row_nnz(), 0);
    }

    #[test]
    fn working_set_positive() {
        assert!(paper_matrix().working_set_bytes() > 0);
    }

    #[test]
    fn row_dot_matches_scalar_and_handles_remainders() {
        // Lengths 0..=9 straddle the 4x unroll boundary in every way.
        let mut rng = crate::util::rng::Pcg32::new(0xD07);
        let x: Vec<f64> = (0..64).map(|_| rng.gen_f64() - 0.5).collect();
        for len in 0..=9usize {
            let cols: Vec<u32> =
                (0..len).map(|_| rng.gen_range(64) as u32).collect();
            let vals: Vec<f64> =
                (0..len).map(|_| rng.gen_f64() - 0.5).collect();
            let unrolled = row_dot(&cols, &vals, &x);
            let scalar = row_dot_scalar(&cols, &vals, &x);
            assert!(
                (unrolled - scalar).abs() < 1e-12 * (1.0 + scalar.abs()),
                "len {len}: {unrolled} vs {scalar}"
            );
        }
        assert_eq!(row_dot(&[], &[], &x), 0.0);
    }

    #[test]
    fn row_dot_ignores_appended_zero_padding_bitwise() {
        // The SELL padding contract: zero-valued tail elements (col 0)
        // must be exact no-ops under the shared accumulation order.
        let mut rng = crate::util::rng::Pcg32::new(0xD08);
        let x: Vec<f64> = (0..32).map(|_| rng.gen_f64() - 0.5).collect();
        for len in 1..=7usize {
            let cols: Vec<u32> =
                (0..len).map(|_| rng.gen_range(32) as u32).collect();
            let vals: Vec<f64> =
                (0..len).map(|_| rng.gen_f64() - 0.5).collect();
            let base = row_dot(&cols, &vals, &x);
            for pad in 1..=5usize {
                let mut pc = cols.clone();
                let mut pv = vals.clone();
                for _ in 0..pad {
                    pc.push(0);
                    pv.push(0.0);
                }
                let padded = row_dot(&pc, &pv, &x);
                assert_eq!(
                    padded.to_bits(),
                    base.to_bits(),
                    "len {len} pad {pad}"
                );
            }
        }
    }

    #[test]
    fn spmv_rows_use_the_shared_row_kernel_bitwise() {
        let a = paper_matrix();
        let x = [0.3, -1.7, 2.9, 0.11];
        let mut y = [0.0; 4];
        a.spmv(&x, &mut y);
        for r in 0..4 {
            let (cols, vals) = a.row(r);
            assert_eq!(y[r].to_bits(), row_dot(cols, vals, &x).to_bits());
        }
    }
}
