//! ELL (ITPACK) format — the padded row-major layout the Pallas/TPU
//! compute path consumes (see `python/compile/kernels/ell_spmv.py` and
//! DESIGN.md §Hardware-Adaptation).
//!
//! Padding convention (must match `ref.py`): padded slots carry
//! `data == 0.0` and `col == 0`, so they contribute nothing.

use super::csr::Csr;

#[derive(Clone, Debug, PartialEq)]
pub struct Ell {
    pub n_rows: usize,
    pub n_cols: usize,
    /// Padded row width (max nonzeros per row, or the bucket's K).
    pub k: usize,
    /// Column indices, row-major `[n_rows][k]`.
    pub cols: Vec<u32>,
    /// Values, row-major `[n_rows][k]`.
    pub data: Vec<f64>,
}

#[derive(Debug, thiserror::Error)]
pub enum EllError {
    #[error("row {row} has {nnz} nonzeros > K={k}")]
    RowTooWide { row: usize, nnz: usize, k: usize },
}

impl Ell {
    /// Convert from CSR. `k` defaults to the max row width; passing an
    /// explicit `k` (a runtime bucket) fails if any row exceeds it.
    pub fn from_csr(csr: &Csr, k: Option<usize>) -> Result<Self, EllError> {
        let width = k.unwrap_or_else(|| csr.max_row_nnz());
        let mut cols = vec![0u32; csr.n_rows * width];
        let mut data = vec![0.0f64; csr.n_rows * width];
        for r in 0..csr.n_rows {
            let (rc, rv) = csr.row(r);
            if rc.len() > width {
                return Err(EllError::RowTooWide {
                    row: r,
                    nnz: rc.len(),
                    k: width,
                });
            }
            let base = r * width;
            cols[base..base + rc.len()].copy_from_slice(rc);
            data[base..base + rv.len()].copy_from_slice(rv);
        }
        Ok(Ell { n_rows: csr.n_rows, n_cols: csr.n_cols, k: width, cols, data })
    }

    pub fn nnz_stored(&self) -> usize {
        self.data.iter().filter(|&&v| v != 0.0).count()
    }

    /// Fraction of padded (wasted) slots — the ELL inefficiency that
    /// mirrors CSR's job_var pathology on skewed matrices.
    pub fn padding_ratio(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        1.0 - self.nnz_stored() as f64 / self.data.len() as f64
    }

    /// Sequential SpMV (reference semantics for the ELL layout).
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.n_cols);
        assert_eq!(y.len(), self.n_rows);
        for r in 0..self.n_rows {
            let base = r * self.k;
            let mut acc = 0.0;
            for j in 0..self.k {
                acc += self.data[base + j] * x[self.cols[base + j] as usize];
            }
            y[r] = acc;
        }
    }

    /// Flattened f32/i32 buffers padded to a runtime bucket
    /// `(bucket_rows, bucket_k)` — the exact argument layout of the
    /// `ell_spmv_m{rows}_k{k}` PJRT artifacts.
    pub fn to_bucket_buffers(
        &self,
        bucket_rows: usize,
        bucket_k: usize,
    ) -> Option<(Vec<i32>, Vec<f32>)> {
        if self.n_rows > bucket_rows || self.k > bucket_k {
            return None;
        }
        let mut cols = vec![0i32; bucket_rows * bucket_k];
        let mut data = vec![0.0f32; bucket_rows * bucket_k];
        for r in 0..self.n_rows {
            let src = r * self.k;
            let dst = r * bucket_k;
            for j in 0..self.k {
                cols[dst + j] = self.cols[src + j] as i32;
                data[dst + j] = self.data[src + j] as f32;
            }
        }
        Some((cols, data))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;

    fn paper_matrix() -> Csr {
        let mut coo = Coo::new(4, 4);
        for &(r, c, v) in &[
            (0, 1, 5.0),
            (0, 2, 2.0),
            (1, 0, 6.0),
            (1, 2, 8.0),
            (1, 3, 3.0),
            (2, 2, 4.0),
            (3, 1, 7.0),
            (3, 2, 1.0),
        ] {
            coo.push(r, c, v);
        }
        coo.to_csr()
    }

    #[test]
    fn natural_width() {
        let e = Ell::from_csr(&paper_matrix(), None).unwrap();
        assert_eq!(e.k, 3);
        assert_eq!(e.nnz_stored(), 8);
        assert!(e.padding_ratio() > 0.0);
    }

    #[test]
    fn spmv_matches_csr() {
        let csr = paper_matrix();
        let e = Ell::from_csr(&csr, None).unwrap();
        let x = [1.0, 2.0, 3.0, 4.0];
        let mut y0 = [0.0; 4];
        let mut y1 = [0.0; 4];
        csr.spmv(&x, &mut y0);
        e.spmv(&x, &mut y1);
        assert_eq!(y0, y1);
    }

    #[test]
    fn explicit_k_too_small() {
        let csr = paper_matrix();
        match Ell::from_csr(&csr, Some(2)) {
            Err(EllError::RowTooWide { row: 1, nnz: 3, k: 2 }) => {}
            other => panic!("expected RowTooWide, got {other:?}"),
        }
    }

    #[test]
    fn bucket_buffers_layout() {
        let csr = paper_matrix();
        let e = Ell::from_csr(&csr, None).unwrap();
        let (cols, data) = e.to_bucket_buffers(8, 4).unwrap();
        assert_eq!(cols.len(), 32);
        assert_eq!(data.len(), 32);
        // row 0 = [(1,5),(2,2),pad,pad]
        assert_eq!(&cols[0..4], &[1, 2, 0, 0]);
        assert_eq!(&data[0..4], &[5.0, 2.0, 0.0, 0.0]);
        // rows beyond n_rows are all padding
        assert!(data[16..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn bucket_too_small_is_none() {
        let e = Ell::from_csr(&paper_matrix(), None).unwrap();
        assert!(e.to_bucket_buffers(2, 4).is_none());
        assert!(e.to_bucket_buffers(8, 2).is_none());
    }

    #[test]
    fn zero_matrix() {
        let e = Ell::from_csr(&Csr::zero(3, 3), None).unwrap();
        assert_eq!(e.k, 0);
        let mut y = [1.0; 3];
        e.spmv(&[1.0; 3], &mut y);
        assert_eq!(y, [0.0; 3]);
    }
}
