//! Sparse matrix storage formats and conversions.
//!
//! The paper targets CSR (§2.2) and its load-balanced successor CSR5
//! (Liu & Vinter, ICS'15; paper §5.2.1). ELL/HYB are included because
//! they are the forms the TPU (Pallas) compute path consumes
//! (DESIGN.md §Hardware-Adaptation), and COO is the interchange format
//! every generator produces first.

pub mod coo;
pub mod csr;
pub mod csr5;
pub mod dia;
pub mod ell;
pub mod features;
pub mod hyb;
pub mod mm;
pub mod sell;

pub use coo::Coo;
pub use csr::{fmadd, row_dot, row_dot_scalar, Csr};
pub use csr5::Csr5;
pub use dia::Dia;
pub use ell::Ell;
pub use features::MatrixFeatures;
pub use hyb::Hyb;
pub use sell::SellCSigma;
