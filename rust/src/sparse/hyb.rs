//! HYB (hybrid ELL + COO) — Bell & Garland's format (paper ref [4]),
//! included as a baseline: the ELL part holds up to `k` nonzeros per
//! row (regular bulk), the COO part holds the overflow (irregular tail).

use super::coo::Coo;
use super::csr::Csr;
use super::ell::Ell;

#[derive(Clone, Debug)]
pub struct Hyb {
    pub ell: Ell,
    pub coo: Coo,
}

impl Hyb {
    /// Split at width `k`: first `k` nonzeros of each row go to ELL,
    /// the rest to COO. `k = ceil(nnz_avg)` is the usual choice.
    pub fn from_csr(csr: &Csr, k: usize) -> Self {
        let mut cols = vec![0u32; csr.n_rows * k];
        let mut data = vec![0.0f64; csr.n_rows * k];
        let mut coo = Coo::new(csr.n_rows, csr.n_cols);
        for r in 0..csr.n_rows {
            let (rc, rv) = csr.row(r);
            let in_ell = rc.len().min(k);
            let base = r * k;
            cols[base..base + in_ell].copy_from_slice(&rc[..in_ell]);
            data[base..base + in_ell].copy_from_slice(&rv[..in_ell]);
            for i in in_ell..rc.len() {
                coo.push(r, rc[i] as usize, rv[i]);
            }
        }
        Hyb {
            ell: Ell {
                n_rows: csr.n_rows,
                n_cols: csr.n_cols,
                k,
                cols,
                data,
            },
            coo,
        }
    }

    /// Default split width: ceil(average nonzeros per row).
    pub fn auto_k(csr: &Csr) -> usize {
        if csr.n_rows == 0 {
            return 0;
        }
        (csr.nnz() as f64 / csr.n_rows as f64).ceil() as usize
    }

    pub fn nnz(&self) -> usize {
        self.ell.nnz_stored() + self.coo.nnz()
    }

    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        self.ell.spmv(x, y);
        for i in 0..self.coo.nnz() {
            y[self.coo.rows[i] as usize] +=
                self.coo.vals[i] * x[self.coo.cols[i] as usize];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn random_csr(rng: &mut Pcg32, n: usize, nnz: usize) -> Csr {
        let mut coo = Coo::new(n, n);
        for _ in 0..nnz {
            coo.push(rng.gen_range(n), rng.gen_range(n), rng.gen_f64() + 0.1);
        }
        coo.to_csr()
    }

    #[test]
    fn matches_csr_various_k() {
        let mut rng = Pcg32::new(31);
        let csr = random_csr(&mut rng, 50, 400);
        let x: Vec<f64> = (0..50).map(|_| rng.gen_f64()).collect();
        let mut want = vec![0.0; 50];
        csr.spmv(&x, &mut want);
        for k in [0, 1, 2, 4, 16, 64] {
            let h = Hyb::from_csr(&csr, k);
            assert_eq!(h.nnz(), csr.nnz(), "k={k}");
            let mut got = vec![0.0; 50];
            h.spmv(&x, &mut got);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-9, "k={k}");
            }
        }
    }

    #[test]
    fn auto_k_reasonable() {
        let mut rng = Pcg32::new(37);
        let csr = random_csr(&mut rng, 100, 500);
        let k = Hyb::auto_k(&csr);
        assert!(k >= 1 && k <= csr.max_row_nnz().max(1));
        assert_eq!(Hyb::auto_k(&Csr::zero(0, 0)), 0);
    }

    #[test]
    fn skewed_row_goes_to_coo() {
        let mut coo = Coo::new(8, 8);
        for c in 0..8 {
            coo.push(0, c, 1.0); // heavy row
        }
        coo.push(5, 5, 2.0);
        let csr = coo.to_csr();
        let h = Hyb::from_csr(&csr, 2);
        assert_eq!(h.coo.nnz(), 6); // 8 - 2 overflow
        let x = vec![1.0; 8];
        let mut y = vec![0.0; 8];
        h.spmv(&x, &mut y);
        assert_eq!(y[0], 8.0);
        assert_eq!(y[5], 2.0);
    }
}
