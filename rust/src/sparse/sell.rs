//! SELL-C-σ (Kreutzer, Hager, Wellein, Fehske, Bishop — SIAM J. Sci.
//! Comput. 2014; the paper's reference [19]).
//!
//! Rows are sorted by length inside windows of σ rows, then packed
//! into chunks of C rows padded to the chunk-local maximum. Compared
//! with ELL, padding waste is bounded by the σ-window's length spread;
//! compared with CSR, the chunk layout is SIMD/vector friendly. The
//! paper's related work positions it as the cross-platform
//! load-balance format; we include it as a baseline the
//! `format_select` pipeline can choose.

use super::csr::Csr;

#[derive(Clone, Debug)]
pub struct SellCSigma {
    pub n_rows: usize,
    pub n_cols: usize,
    /// Chunk height (C) — rows per chunk.
    pub c: usize,
    /// Sorting window (σ) — must be a multiple of C.
    pub sigma: usize,
    /// Width (padded row length) of each chunk.
    pub chunk_len: Vec<u32>,
    /// Start offset of each chunk in `cols`/`vals`
    /// (column-major within the chunk: entry (r, j) of chunk k is at
    /// `chunk_ptr[k] + j * C + r`).
    pub chunk_ptr: Vec<usize>,
    /// Column indices (padding -> 0) and values (padding -> 0.0).
    pub cols: Vec<u32>,
    pub vals: Vec<f64>,
    /// Global row id of each packed slot row: `perm[chunk*C + r]`.
    pub perm: Vec<u32>,
}

impl SellCSigma {
    /// Build from CSR with chunk height `c` and sorting window
    /// `sigma` (rounded up to a multiple of `c`).
    pub fn from_csr(csr: &Csr, c: usize, sigma: usize) -> SellCSigma {
        assert!(c > 0 && c <= 64, "chunk height C must be in 1..=64");
        let sigma = sigma.max(c).div_ceil(c) * c;
        let n = csr.n_rows;
        // Sort rows by descending length within each sigma window.
        let mut perm: Vec<u32> = (0..n as u32).collect();
        for w in perm.chunks_mut(sigma) {
            w.sort_by_key(|&r| std::cmp::Reverse(csr.row_nnz(r as usize)));
        }
        let n_chunks = n.div_ceil(c);
        let mut chunk_len = Vec::with_capacity(n_chunks);
        let mut chunk_ptr = Vec::with_capacity(n_chunks + 1);
        let mut total = 0usize;
        for k in 0..n_chunks {
            let rows = &perm[k * c..((k + 1) * c).min(n)];
            let width = rows
                .iter()
                .map(|&r| csr.row_nnz(r as usize))
                .max()
                .unwrap_or(0) as u32;
            chunk_len.push(width);
            chunk_ptr.push(total);
            total += width as usize * c;
        }
        chunk_ptr.push(total);
        let mut cols = vec![0u32; total];
        let mut vals = vec![0.0f64; total];
        for k in 0..n_chunks {
            let base = chunk_ptr[k];
            let width = chunk_len[k] as usize;
            for r in 0..c {
                let slot = k * c + r;
                if slot >= n {
                    break;
                }
                let (rc, rv) = csr.row(perm[slot] as usize);
                for (j, (&cc, &vv)) in rc.iter().zip(rv).enumerate() {
                    cols[base + j * c + r] = cc;
                    vals[base + j * c + r] = vv;
                }
                let _ = width;
            }
        }
        SellCSigma {
            n_rows: n,
            n_cols: csr.n_cols,
            c,
            sigma,
            chunk_len,
            chunk_ptr,
            cols,
            vals,
            perm,
        }
    }

    pub fn n_chunks(&self) -> usize {
        self.chunk_len.len()
    }

    /// Stored slots (including padding).
    pub fn stored(&self) -> usize {
        self.vals.len()
    }

    /// Padding overhead relative to the true nonzero count.
    pub fn padding_ratio(&self, nnz: usize) -> f64 {
        if self.stored() == 0 {
            return 0.0;
        }
        1.0 - nnz as f64 / self.stored() as f64
    }

    /// SpMV: y (natural row order) = A x.
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.n_cols);
        assert_eq!(y.len(), self.n_rows);
        let c = self.c;
        for k in 0..self.n_chunks() {
            let base = self.chunk_ptr[k];
            let width = self.chunk_len[k] as usize;
            let rows_in_chunk = c.min(self.n_rows - k * c);
            // Column-major walk: the vectorizable SELL access pattern.
            let mut acc = [0.0f64; 64];
            let acc = &mut acc[..rows_in_chunk];
            for j in 0..width {
                let col_base = base + j * c;
                for (r, a) in acc.iter_mut().enumerate() {
                    let idx = col_base + r;
                    *a += self.vals[idx] * x[self.cols[idx] as usize];
                }
            }
            for (r, &a) in acc.iter().enumerate() {
                y[self.perm[k * c + r] as usize] = a;
            }
        }
    }

    /// SpMV over a chunk range (the threaded unit of work).
    pub fn spmv_chunks(
        &self,
        k0: usize,
        k1: usize,
        x: &[f64],
        y: &mut [f64],
    ) {
        let c = self.c;
        for k in k0..k1.min(self.n_chunks()) {
            let base = self.chunk_ptr[k];
            let width = self.chunk_len[k] as usize;
            let rows_in_chunk = c.min(self.n_rows - k * c);
            for r in 0..rows_in_chunk {
                let mut a = 0.0;
                for j in 0..width {
                    let idx = base + j * c + r;
                    a += self.vals[idx] * x[self.cols[idx] as usize];
                }
                y[self.perm[k * c + r] as usize] = a;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;
    use crate::util::rng::Pcg32;

    fn random_csr(rng: &mut Pcg32, n: usize, max_deg: usize) -> Csr {
        let mut coo = Coo::new(n, n);
        for r in 0..n {
            let deg = rng.gen_range(max_deg + 1);
            for c in rng.sample_distinct(n, deg.min(n)) {
                coo.push(r, c, rng.gen_f64() - 0.5);
            }
        }
        coo.to_csr()
    }

    #[test]
    fn matches_csr_various_geometry() {
        let mut rng = Pcg32::new(0x5E11);
        let csr = random_csr(&mut rng, 300, 12);
        let x: Vec<f64> = (0..300).map(|_| rng.gen_f64()).collect();
        let mut want = vec![0.0; 300];
        csr.spmv(&x, &mut want);
        for (c, sigma) in [(4, 4), (8, 32), (16, 64), (32, 300), (64, 64)] {
            let s = SellCSigma::from_csr(&csr, c, sigma);
            let mut got = vec![0.0; 300];
            s.spmv(&x, &mut got);
            for (i, (a, b)) in want.iter().zip(&got).enumerate() {
                assert!(
                    (a - b).abs() < 1e-9,
                    "C={c} sigma={sigma} row {i}: {a} vs {b}"
                );
            }
            // Chunked execution agrees too.
            let mut got2 = vec![0.0; 300];
            let half = s.n_chunks() / 2;
            s.spmv_chunks(0, half, &x, &mut got2);
            s.spmv_chunks(half, s.n_chunks(), &x, &mut got2);
            assert_eq!(got, got2, "C={c} sigma={sigma}");
        }
    }

    #[test]
    fn sigma_sorting_cuts_padding_on_skewed_rows() {
        // Power-law-ish: a few long rows. sigma=1 (no sorting) pads
        // every chunk to its local max; a large sigma groups the long
        // rows together.
        let mut rng = Pcg32::new(0x516A);
        let n = 256;
        let mut coo = Coo::new(n, n);
        for r in 0..n {
            let deg = if r % 37 == 0 { 40 } else { 2 };
            for c in rng.sample_distinct(n, deg) {
                coo.push(r, c, 1.0);
            }
        }
        let csr = coo.to_csr();
        let unsorted = SellCSigma::from_csr(&csr, 8, 8);
        let sorted = SellCSigma::from_csr(&csr, 8, 256);
        assert!(
            sorted.stored() < unsorted.stored(),
            "sigma sorting should cut padding: {} vs {}",
            sorted.stored(),
            unsorted.stored()
        );
        assert!(sorted.padding_ratio(csr.nnz()) < 0.4);
    }

    #[test]
    fn perm_is_permutation_and_window_local() {
        let mut rng = Pcg32::new(3);
        let csr = random_csr(&mut rng, 128, 6);
        let s = SellCSigma::from_csr(&csr, 4, 16);
        let mut seen = vec![false; 128];
        for (slot, &r) in s.perm.iter().enumerate() {
            assert!(!seen[r as usize]);
            seen[r as usize] = true;
            // Row stays within its sigma window.
            assert_eq!(slot / 16, r as usize / 16, "slot {slot} row {r}");
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn ragged_tail_handled() {
        let mut rng = Pcg32::new(5);
        let csr = random_csr(&mut rng, 101, 5); // n not divisible by C
        let s = SellCSigma::from_csr(&csr, 8, 32);
        let x = vec![1.0; 101];
        let mut want = vec![0.0; 101];
        let mut got = vec![0.0; 101];
        csr.spmv(&x, &mut want);
        s.spmv(&x, &mut got);
        for (a, b) in want.iter().zip(&got) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn empty_matrix() {
        let csr = Csr::zero(10, 10);
        let s = SellCSigma::from_csr(&csr, 4, 8);
        let x = vec![1.0; 10];
        let mut y = vec![9.0; 10];
        s.spmv(&x, &mut y);
        assert!(y.iter().all(|&v| v == 0.0));
    }
}
