//! SELL-C-σ (Kreutzer, Hager, Wellein, Fehske, Bishop — SIAM J. Sci.
//! Comput. 2014; the paper's reference [19]).
//!
//! Rows are sorted by length inside windows of σ rows, then packed
//! into chunks of C rows padded to the chunk-local maximum. Compared
//! with ELL, padding waste is bounded by the σ-window's length spread;
//! compared with CSR, the chunk layout is SIMD/vector friendly: the
//! SpMV inner loop walks one *column* of a chunk at a time, touching C
//! consecutive slots — a unit-stride vectorizable sweep.
//!
//! The chunk kernel follows the crate-wide accumulation discipline
//! (`sparse::csr::row_dot`): element `j` of a row lands in accumulator
//! `j % 4`, reduced as `(a0 + a1) + (a2 + a3)`. Padding slots hold
//! value 0.0 against the row's own last column (column 0 for empty
//! rows), so their `fmadd` contribution is an exact no-op for finite
//! inputs and a SELL SpMV is **bitwise identical** to the CSR
//! reference — the property `tests/properties.rs` pins. (Non-finite
//! inputs poison only rows that genuinely read the offending element,
//! matching CSR semantics — except all-empty rows packed into a
//! nonempty chunk, whose padding reads column 0.)

use super::csr::{fmadd, Csr};

/// Round σ to the domain `from_csr` actually sorts over: at least one
/// chunk (`c`), a whole number of chunks, and no larger than the
/// matrix itself — a pathological `σ >> n_rows` (including values near
/// `usize::MAX` that would overflow the naive `div_ceil(σ, c) * c`
/// round-up) clamps to one whole-matrix window.
pub fn normalize_sigma(c: usize, sigma: usize, n_rows: usize) -> usize {
    let c = c.max(1);
    let whole = n_rows.div_ceil(c).max(1).saturating_mul(c);
    sigma.clamp(c, whole).div_ceil(c) * c
}

/// The σ-window row permutation SELL-C-σ packs under: row ids sorted
/// by descending length within each window of `sigma` rows. `sigma`
/// is normalized via [`normalize_sigma`]. Shared by `from_csr` and by
/// `sched::partition`'s chunk balancing, so the two never disagree on
/// which rows a chunk holds.
pub fn sell_perm(csr: &Csr, c: usize, sigma: usize) -> Vec<u32> {
    let sigma = normalize_sigma(c, sigma, csr.n_rows);
    let mut perm: Vec<u32> = (0..csr.n_rows as u32).collect();
    for w in perm.chunks_mut(sigma) {
        w.sort_by_key(|&r| std::cmp::Reverse(csr.row_nnz(r as usize)));
    }
    perm
}

#[derive(Clone, Debug)]
pub struct SellCSigma {
    pub n_rows: usize,
    pub n_cols: usize,
    /// Chunk height (C) — rows per chunk.
    pub c: usize,
    /// Sorting window (σ) — a multiple of C, at most one whole matrix.
    pub sigma: usize,
    /// Width (padded row length) of each chunk.
    pub chunk_len: Vec<u32>,
    /// Start offset of each chunk in `cols`/`vals`
    /// (column-major within the chunk: entry (r, j) of chunk k is at
    /// `chunk_ptr[k] + j * C + r`).
    pub chunk_ptr: Vec<usize>,
    /// Column indices (padding -> 0) and values (padding -> 0.0).
    pub cols: Vec<u32>,
    pub vals: Vec<f64>,
    /// Global row id of each packed slot row: `perm[chunk*C + r]`.
    pub perm: Vec<u32>,
}

impl SellCSigma {
    /// Build from CSR with chunk height `c` and sorting window
    /// `sigma` (normalized: rounded up to a multiple of `c`, clamped
    /// to the matrix height — see [`normalize_sigma`]).
    pub fn from_csr(csr: &Csr, c: usize, sigma: usize) -> SellCSigma {
        assert!(c > 0 && c <= 64, "chunk height C must be in 1..=64");
        let sigma = normalize_sigma(c, sigma, csr.n_rows);
        let n = csr.n_rows;
        let perm = sell_perm(csr, c, sigma);
        let n_chunks = n.div_ceil(c);
        let mut chunk_len = Vec::with_capacity(n_chunks);
        let mut chunk_ptr = Vec::with_capacity(n_chunks + 1);
        let mut total = 0usize;
        for k in 0..n_chunks {
            let rows = &perm[k * c..((k + 1) * c).min(n)];
            let width = rows
                .iter()
                .map(|&r| csr.row_nnz(r as usize))
                .max()
                .unwrap_or(0) as u32;
            chunk_len.push(width);
            chunk_ptr.push(total);
            total += width as usize * c;
        }
        chunk_ptr.push(total);
        let mut cols = vec![0u32; total];
        let mut vals = vec![0.0f64; total];
        for k in 0..n_chunks {
            let base = chunk_ptr[k];
            let width = chunk_len[k] as usize;
            for r in 0..c {
                let slot = k * c + r;
                if slot >= n {
                    break;
                }
                let (rc, rv) = csr.row(perm[slot] as usize);
                for (j, (&cc, &vv)) in rc.iter().zip(rv).enumerate() {
                    cols[base + j * c + r] = cc;
                    vals[base + j * c + r] = vv;
                }
                // Padding slots point at the row's own last column
                // (0 for empty rows): a non-finite x element then
                // can't poison a row that never references it —
                // `fmadd(0.0, x[c], acc)` only goes NaN for an x the
                // row reads anyway. Values stay 0.0, so for finite
                // inputs padding remains an exact no-op.
                let pad_col = rc.last().copied().unwrap_or(0);
                for j in rc.len()..width {
                    cols[base + j * c + r] = pad_col;
                }
            }
        }
        SellCSigma {
            n_rows: n,
            n_cols: csr.n_cols,
            c,
            sigma,
            chunk_len,
            chunk_ptr,
            cols,
            vals,
            perm,
        }
    }

    pub fn n_chunks(&self) -> usize {
        self.chunk_len.len()
    }

    /// Stored slots (including padding).
    pub fn stored(&self) -> usize {
        self.vals.len()
    }

    /// Padding overhead relative to the true nonzero count.
    pub fn padding_ratio(&self, nnz: usize) -> f64 {
        if self.stored() == 0 {
            return 0.0;
        }
        1.0 - nnz as f64 / self.stored() as f64
    }

    /// SpMV: y (natural row order) = A x.
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.n_cols);
        assert_eq!(y.len(), self.n_rows);
        self.spmv_chunks(0, self.n_chunks(), x, y);
    }

    /// SpMV over a chunk range (the threaded unit of work): for each
    /// chunk, four unit-stride accumulator sweeps walk the chunk
    /// column-major (the vectorizable SELL access pattern), then the
    /// per-row sums scatter through `perm` into `y`. Rows covered by
    /// `[k0, k1)` are written exactly once; other rows are untouched,
    /// so disjoint chunk ranges compose across threads.
    pub fn spmv_chunks(
        &self,
        k0: usize,
        k1: usize,
        x: &[f64],
        y: &mut [f64],
    ) {
        let c = self.c;
        // One accumulator block for the whole range; only the
        // `lane[..rows]` prefix each chunk actually uses is re-zeroed
        // (a full 4x64 clear per chunk would rival the fmadd work on
        // sparse rows).
        let mut acc = [[0.0f64; 64]; 4];
        for k in k0..k1.min(self.n_chunks()) {
            let base = self.chunk_ptr[k];
            let width = self.chunk_len[k] as usize;
            let rows = c.min(self.n_rows - k * c);
            for lane in acc.iter_mut() {
                lane[..rows].fill(0.0);
            }
            // Accumulator j % 4, exactly like `row_dot`; padding slots
            // contribute exact no-ops (fmadd(0.0, x[0], acc) == acc).
            let main = width & !3;
            let mut j = 0;
            while j < main {
                for (e, lane) in acc.iter_mut().enumerate() {
                    let col = base + (j + e) * c;
                    for (r, a) in lane[..rows].iter_mut().enumerate() {
                        let i = col + r;
                        *a = fmadd(
                            self.vals[i],
                            x[self.cols[i] as usize],
                            *a,
                        );
                    }
                }
                j += 4;
            }
            let mut e = 0;
            while j < width {
                let col = base + j * c;
                for (r, a) in acc[e][..rows].iter_mut().enumerate() {
                    let i = col + r;
                    *a = fmadd(self.vals[i], x[self.cols[i] as usize], *a);
                }
                e += 1;
                j += 1;
            }
            for r in 0..rows {
                y[self.perm[k * c + r] as usize] =
                    (acc[0][r] + acc[1][r]) + (acc[2][r] + acc[3][r]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;
    use crate::util::rng::Pcg32;

    fn random_csr(rng: &mut Pcg32, n: usize, max_deg: usize) -> Csr {
        let mut coo = Coo::new(n, n);
        for r in 0..n {
            let deg = rng.gen_range(max_deg + 1);
            for c in rng.sample_distinct(n, deg.min(n)) {
                coo.push(r, c, rng.gen_f64() - 0.5);
            }
        }
        coo.to_csr()
    }

    #[test]
    fn matches_csr_various_geometry() {
        let mut rng = Pcg32::new(0x5E11);
        let csr = random_csr(&mut rng, 300, 12);
        let x: Vec<f64> = (0..300).map(|_| rng.gen_f64()).collect();
        let mut want = vec![0.0; 300];
        csr.spmv(&x, &mut want);
        for (c, sigma) in [(4, 4), (8, 32), (16, 64), (32, 300), (64, 64)] {
            let s = SellCSigma::from_csr(&csr, c, sigma);
            let mut got = vec![0.0; 300];
            s.spmv(&x, &mut got);
            for (i, (a, b)) in want.iter().zip(&got).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "C={c} sigma={sigma} row {i}: {a} vs {b}"
                );
            }
            // Chunked execution agrees too.
            let mut got2 = vec![0.0; 300];
            let half = s.n_chunks() / 2;
            s.spmv_chunks(0, half, &x, &mut got2);
            s.spmv_chunks(half, s.n_chunks(), &x, &mut got2);
            assert_eq!(got, got2, "C={c} sigma={sigma}");
        }
    }

    #[test]
    fn sigma_sorting_cuts_padding_on_skewed_rows() {
        // Power-law-ish: a few long rows. sigma=1 (no sorting) pads
        // every chunk to its local max; a large sigma groups the long
        // rows together.
        let mut rng = Pcg32::new(0x516A);
        let n = 256;
        let mut coo = Coo::new(n, n);
        for r in 0..n {
            let deg = if r % 37 == 0 { 40 } else { 2 };
            for c in rng.sample_distinct(n, deg) {
                coo.push(r, c, 1.0);
            }
        }
        let csr = coo.to_csr();
        let unsorted = SellCSigma::from_csr(&csr, 8, 8);
        let sorted = SellCSigma::from_csr(&csr, 8, 256);
        assert!(
            sorted.stored() < unsorted.stored(),
            "sigma sorting should cut padding: {} vs {}",
            sorted.stored(),
            unsorted.stored()
        );
        assert!(sorted.padding_ratio(csr.nnz()) < 0.4);
    }

    #[test]
    fn perm_is_permutation_and_window_local() {
        let mut rng = Pcg32::new(3);
        let csr = random_csr(&mut rng, 128, 6);
        let s = SellCSigma::from_csr(&csr, 4, 16);
        let mut seen = vec![false; 128];
        for (slot, &r) in s.perm.iter().enumerate() {
            assert!(!seen[r as usize]);
            seen[r as usize] = true;
            // Row stays within its sigma window.
            assert_eq!(slot / 16, r as usize / 16, "slot {slot} row {r}");
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn perm_roundtrip_recovers_row_identity() {
        // Scattering through perm then gathering through its inverse
        // is the identity — the property the chunk kernel's
        // `y[perm[slot]] = sum(slot)` write relies on.
        let mut rng = Pcg32::new(0x9E12);
        let csr = random_csr(&mut rng, 97, 7);
        let s = SellCSigma::from_csr(&csr, 8, 24);
        assert_eq!(s.perm, sell_perm(&csr, 8, 24), "from_csr shares sell_perm");
        let mut inv = vec![u32::MAX; 97];
        for (slot, &r) in s.perm.iter().enumerate() {
            inv[r as usize] = slot as u32;
        }
        for (slot, &r) in s.perm.iter().enumerate() {
            assert_eq!(inv[r as usize] as usize, slot);
        }
        // Each slot's packed row really is the CSR row it claims.
        for (slot, &r) in s.perm.iter().enumerate() {
            let (k, p) = (slot / s.c, slot % s.c);
            let (rc, rv) = csr.row(r as usize);
            let base = s.chunk_ptr[k];
            for (j, (&cc, &vv)) in rc.iter().zip(rv).enumerate() {
                assert_eq!(s.cols[base + j * s.c + p], cc);
                assert_eq!(s.vals[base + j * s.c + p], vv);
            }
        }
    }

    #[test]
    fn pathological_sigma_is_clamped() {
        // σ >> n_rows (including near-overflow values) must clamp to
        // one whole-matrix window instead of overflowing the round-up.
        let mut rng = Pcg32::new(7);
        let csr = random_csr(&mut rng, 50, 5);
        let x: Vec<f64> = (0..50).map(|_| rng.gen_f64()).collect();
        let mut want = vec![0.0; 50];
        csr.spmv(&x, &mut want);
        for sigma in [usize::MAX, usize::MAX - 3, 1_000_000, 51, 0] {
            let s = SellCSigma::from_csr(&csr, 8, sigma);
            assert!(
                s.sigma % 8 == 0 && s.sigma <= 56,
                "sigma {} not normalized from {sigma}",
                s.sigma
            );
            let mut got = vec![0.0; 50];
            s.spmv(&x, &mut got);
            assert_eq!(got, want, "sigma {sigma}");
        }
        assert_eq!(normalize_sigma(8, usize::MAX, 50), 56);
        assert_eq!(normalize_sigma(8, 0, 50), 8);
        assert_eq!(normalize_sigma(4, 6, 50), 8, "rounds up to a chunk");
        assert_eq!(normalize_sigma(8, usize::MAX, 0), 8, "empty matrix");
    }

    #[test]
    fn ragged_tail_handled() {
        let mut rng = Pcg32::new(5);
        let csr = random_csr(&mut rng, 101, 5); // n not divisible by C
        let s = SellCSigma::from_csr(&csr, 8, 32);
        let x = vec![1.0; 101];
        let mut want = vec![0.0; 101];
        let mut got = vec![0.0; 101];
        csr.spmv(&x, &mut want);
        s.spmv(&x, &mut got);
        for (a, b) in want.iter().zip(&got) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn empty_matrix_and_empty_chunks() {
        let csr = Csr::zero(10, 10);
        let s = SellCSigma::from_csr(&csr, 4, 8);
        assert_eq!(s.n_chunks(), 3);
        assert_eq!(s.stored(), 0, "all-empty rows store nothing");
        assert!(s.chunk_len.iter().all(|&w| w == 0), "every chunk empty");
        let x = vec![1.0; 10];
        let mut y = vec![9.0; 10];
        s.spmv(&x, &mut y);
        assert!(y.iter().all(|&v| v == 0.0));
        // A zero-row matrix builds and serves without panicking.
        let none = SellCSigma::from_csr(&Csr::zero(0, 4), 8, 64);
        assert_eq!(none.n_chunks(), 0);
        let mut y0: Vec<f64> = vec![];
        none.spmv(&[1.0; 4], &mut y0);
        // A matrix with one empty chunk in the middle (rows 4..8
        // empty) still writes those rows (to 0.0) through the scatter.
        let mut coo = Coo::new(12, 12);
        for r in [0usize, 1, 2, 3, 8, 9] {
            coo.push(r, r, 2.0);
        }
        let sparse = coo.to_csr();
        let s = SellCSigma::from_csr(&sparse, 4, 4);
        let mut y = vec![7.0; 12];
        s.spmv(&[1.0; 12], &mut y);
        let mut want = vec![0.0; 12];
        sparse.spmv(&[1.0; 12], &mut want);
        assert_eq!(y, want, "empty middle chunk must zero its rows");
    }
}
