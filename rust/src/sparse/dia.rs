//! DIA (diagonal) format — the classic layout for banded/stencil
//! matrices (Bell & Garland's taxonomy, paper ref [4]).
//!
//! Stores one dense array per occupied diagonal. Perfectly regular
//! x access (the gather degenerates into shifted streams), but
//! explodes on matrices whose nonzeros do not cluster on diagonals —
//! `from_csr` refuses when the fill ratio is too low, which is itself
//! a useful signal for the format selector.

use super::csr::Csr;

#[derive(Clone, Debug)]
pub struct Dia {
    pub n_rows: usize,
    pub n_cols: usize,
    /// Offsets of the stored diagonals (col - row), ascending.
    pub offsets: Vec<i32>,
    /// Values, one lane of length `n_rows` per diagonal
    /// (`vals[d * n_rows + r]` = A[r][r + offsets[d]] or 0).
    pub vals: Vec<f64>,
}

#[derive(Debug, thiserror::Error)]
pub enum DiaError {
    #[error(
        "matrix is not diagonal-friendly: {diags} diagonals for {nnz} nonzeros \
         (fill {fill:.3} < minimum {min:.3})"
    )]
    TooSparse { diags: usize, nnz: usize, fill: f64, min: f64 },
}

impl Dia {
    /// Convert from CSR. Fails when the stored-slot fill ratio
    /// (nnz / (diagonals * n_rows)) would drop below `min_fill`.
    pub fn from_csr(csr: &Csr, min_fill: f64) -> Result<Dia, DiaError> {
        let n = csr.n_rows;
        let mut present = std::collections::BTreeSet::new();
        for r in 0..n {
            let (cols, _) = csr.row(r);
            for &c in cols {
                present.insert(c as i64 - r as i64);
            }
        }
        let diags = present.len();
        let slots = diags * n;
        let fill = if slots == 0 {
            1.0
        } else {
            csr.nnz() as f64 / slots as f64
        };
        if fill < min_fill {
            return Err(DiaError::TooSparse {
                diags,
                nnz: csr.nnz(),
                fill,
                min: min_fill,
            });
        }
        let offsets: Vec<i32> = present.iter().map(|&d| d as i32).collect();
        let index_of: std::collections::HashMap<i32, usize> = offsets
            .iter()
            .enumerate()
            .map(|(i, &d)| (d, i))
            .collect();
        let mut vals = vec![0.0f64; slots];
        for r in 0..n {
            let (cols, rv) = csr.row(r);
            for (&c, &v) in cols.iter().zip(rv) {
                let d = index_of[&(c as i32 - r as i32)];
                vals[d * n + r] = v;
            }
        }
        Ok(Dia { n_rows: n, n_cols: csr.n_cols, offsets, vals })
    }

    pub fn n_diags(&self) -> usize {
        self.offsets.len()
    }

    /// SpMV: per-diagonal shifted AXPY — fully streaming.
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.n_cols);
        assert_eq!(y.len(), self.n_rows);
        y.iter_mut().for_each(|v| *v = 0.0);
        let n = self.n_rows;
        for (d, &off) in self.offsets.iter().enumerate() {
            let lane = &self.vals[d * n..(d + 1) * n];
            let (r0, r1) = if off >= 0 {
                (0usize, n.min(self.n_cols.saturating_sub(off as usize)))
            } else {
                ((-off) as usize, n)
            };
            for r in r0..r1 {
                let c = (r as i64 + off as i64) as usize;
                y[r] += lane[r] * x[c];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::generators;
    use crate::util::rng::Pcg32;

    #[test]
    fn banded_roundtrips() {
        let mut rng = Pcg32::new(0xD1A);
        let csr = generators::banded(200, 5, &mut rng);
        let dia = Dia::from_csr(&csr, 0.5).unwrap();
        assert!(dia.n_diags() <= 6);
        let x: Vec<f64> = (0..200).map(|_| rng.gen_f64()).collect();
        let mut want = vec![0.0; 200];
        let mut got = vec![0.0; 200];
        csr.spmv(&x, &mut want);
        dia.spmv(&x, &mut got);
        for (a, b) in want.iter().zip(&got) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn stencil_works() {
        let csr = generators::stencil(400, 5);
        let dia = Dia::from_csr(&csr, 0.2).unwrap();
        let n = csr.n_rows;
        let x: Vec<f64> = (0..n).map(|i| (i % 7) as f64).collect();
        let mut want = vec![0.0; n];
        let mut got = vec![0.0; n];
        csr.spmv(&x, &mut want);
        dia.spmv(&x, &mut got);
        for (a, b) in want.iter().zip(&got) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn refuses_random_matrices() {
        let mut rng = Pcg32::new(7);
        let csr = generators::random_uniform(300, 8, &mut rng);
        match Dia::from_csr(&csr, 0.5) {
            Err(DiaError::TooSparse { fill, .. }) => assert!(fill < 0.5),
            other => panic!("expected TooSparse, got {other:?}"),
        }
    }

    #[test]
    fn identity_is_one_diagonal() {
        let dia = Dia::from_csr(&Csr::identity(64), 0.9).unwrap();
        assert_eq!(dia.offsets, vec![0]);
        let x: Vec<f64> = (0..64).map(|i| i as f64).collect();
        let mut y = vec![0.0; 64];
        dia.spmv(&x, &mut y);
        assert_eq!(x, y);
    }

    #[test]
    fn off_diagonal_bounds() {
        // Superdiagonal only: y[last] must stay 0.
        let mut coo = crate::sparse::Coo::new(4, 4);
        for r in 0..3 {
            coo.push(r, r + 1, 2.0);
        }
        let dia = Dia::from_csr(&coo.to_csr(), 0.2).unwrap();
        let mut y = vec![0.0; 4];
        dia.spmv(&[1.0, 1.0, 1.0, 1.0], &mut y);
        assert_eq!(y, vec![2.0, 2.0, 2.0, 0.0]);
    }
}
