//! Matrix-structure features — the static half of the paper's Table 3
//! feature set (the dynamic half comes from `counters::Derived`).

use super::csr::Csr;

/// Static features of a sparse matrix (Table 3, "matrix features").
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MatrixFeatures {
    /// Number of rows (`n_rows`).
    pub n_rows: usize,
    pub n_cols: usize,
    pub nnz: usize,
    /// Maximum nonzeros in any row (`nnz_max`).
    pub nnz_max: usize,
    /// Average nonzeros per row (`nnz_avg`).
    pub nnz_avg: f64,
    /// Population variance of nonzeros per row (`nnz_var`).
    pub nnz_var: f64,
}

impl MatrixFeatures {
    pub fn extract(csr: &Csr) -> Self {
        let n = csr.n_rows;
        let nnz = csr.nnz();
        let mut nnz_max = 0usize;
        let mut sum = 0.0f64;
        let mut sum_sq = 0.0f64;
        for r in 0..n {
            let k = csr.row_nnz(r);
            nnz_max = nnz_max.max(k);
            sum += k as f64;
            sum_sq += (k * k) as f64;
        }
        let nnz_avg = if n > 0 { sum / n as f64 } else { 0.0 };
        let nnz_var = if n > 0 {
            (sum_sq / n as f64) - nnz_avg * nnz_avg
        } else {
            0.0
        };
        MatrixFeatures {
            n_rows: n,
            n_cols: csr.n_cols,
            nnz,
            nnz_max,
            nnz_avg,
            nnz_var: nnz_var.max(0.0),
        }
    }
}

/// `job_var` — "maximum # allocated nnz ratio per thread" (Table 3).
///
/// Computed from the per-thread nonzero allocation of a schedule. The
/// theoretical optimum is `1 / n_threads` (0.25 for 4 threads); the
/// paper flags matrices with `job_var >= 0.45` as imbalance-limited
/// (exdata_1 reaches 0.992: one thread owns >99% of the work).
pub fn job_var(thread_nnz: &[usize]) -> f64 {
    let total: usize = thread_nnz.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let max = *thread_nnz.iter().max().unwrap();
    max as f64 / total as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;

    #[test]
    fn paper_matrix_features() {
        let mut coo = Coo::new(4, 4);
        for &(r, c, v) in &[
            (0, 1, 5.0),
            (0, 2, 2.0),
            (1, 0, 6.0),
            (1, 2, 8.0),
            (1, 3, 3.0),
            (2, 2, 4.0),
            (3, 1, 7.0),
            (3, 2, 1.0),
        ] {
            coo.push(r, c, v);
        }
        let f = MatrixFeatures::extract(&coo.to_csr());
        assert_eq!(f.n_rows, 4);
        assert_eq!(f.nnz, 8);
        assert_eq!(f.nnz_max, 3);
        assert!((f.nnz_avg - 2.0).abs() < 1e-12);
        // rows = [2,3,1,2]; var = mean(sq) - mean^2 = (4+9+1+4)/4 - 4 = 0.5
        assert!((f.nnz_var - 0.5).abs() < 1e-12);
    }

    #[test]
    fn uniform_rows_zero_variance() {
        let f = MatrixFeatures::extract(&Csr::identity(10));
        assert_eq!(f.nnz_max, 1);
        assert!((f.nnz_avg - 1.0).abs() < 1e-12);
        assert!(f.nnz_var.abs() < 1e-12);
    }

    #[test]
    fn empty_matrix() {
        let f = MatrixFeatures::extract(&Csr::zero(0, 0));
        assert_eq!(f.nnz, 0);
        assert_eq!(f.nnz_avg, 0.0);
        assert_eq!(f.nnz_var, 0.0);
    }

    #[test]
    fn job_var_balanced_and_skewed() {
        assert!((job_var(&[25, 25, 25, 25]) - 0.25).abs() < 1e-12);
        assert!((job_var(&[99, 1, 0, 0]) - 0.99).abs() < 1e-12);
        assert_eq!(job_var(&[0, 0]), 0.0);
        assert_eq!(job_var(&[100]), 1.0);
    }
}
