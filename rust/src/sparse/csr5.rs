//! CSR5 (Liu & Vinter, ICS'15) — the load-balanced format the paper
//! uses to rescue matrices whose CSR scalability is killed by skewed
//! nonzero allocation (§5.2.1, Fig 7).
//!
//! The nonzero stream is partitioned into fixed-size 2-D tiles
//! (ω lanes × σ rows; we keep the flattened `tile_nnz = ω·σ` view).
//! Per-tile descriptors follow the paper's Table 1:
//!
//! * `tile_ptr[t]`  — row id of the first nonzero of tile `t`.
//! * `bit_flag`     — one bit per nonzero: "this nonzero starts a row".
//! * `y_off[t]`     — number of row *starts* inside tile `t` before each
//!   tile (prefix offset into the per-tile output slots).
//! * `seg_off`      — simplified here to a per-tile bool: "tile begins
//!   in the middle of a row" (its leading partial sum must be carried
//!   into the previous tile's last row).
//!
//! Simplification vs. the original: nonzeros are kept in row-major
//! order inside a tile rather than transposed for SIMD lanes. The
//! property the paper exploits — *equal nonzeros per tile, hence equal
//! work per thread* — is preserved exactly; only the intra-tile SIMD
//! shuffle is elided (our SIMD story lives in the Pallas kernel, see
//! `python/compile/kernels/seg_spmv.py`, which is the same computation).

use super::csr::Csr;

#[derive(Clone, Debug)]
pub struct Csr5 {
    pub n_rows: usize,
    pub n_cols: usize,
    /// Nonzeros per tile (ω·σ).
    pub tile_nnz: usize,
    /// Row id of each tile's first nonzero; length = n_tiles.
    pub tile_ptr: Vec<u32>,
    /// Per-nonzero "starts a row" flag, aligned with `indices`/`data`.
    pub bit_flag: Vec<bool>,
    /// Per-tile count of row starts before the tile (exclusive prefix).
    pub y_off: Vec<u32>,
    /// Per-tile: starts mid-row (leading segment is a carry).
    pub seg_off: Vec<bool>,
    /// Column indices, same order as CSR.
    pub indices: Vec<u32>,
    /// Values, same order as CSR.
    pub data: Vec<f64>,
    /// Original CSR row pointer (kept for conversions/validation).
    pub ptr: Vec<usize>,
}

/// Partial products a tile range produces for rows that may be shared
/// with neighbouring ranges (the carry the threaded executor merges).
#[derive(Clone, Debug, PartialEq)]
pub struct TileCarry {
    pub row: usize,
    pub value: f64,
}

impl Csr5 {
    /// Convert from CSR with the given tile size (ω·σ). The final tile
    /// may be short (no padding needed on the CPU path).
    pub fn from_csr(csr: &Csr, tile_nnz: usize) -> Self {
        assert!(tile_nnz > 0);
        let nnz = csr.nnz();
        let n_tiles = nnz.div_ceil(tile_nnz).max(1);
        let mut bit_flag = vec![false; nnz];
        for r in 0..csr.n_rows {
            if csr.ptr[r] < csr.ptr[r + 1] {
                bit_flag[csr.ptr[r]] = true;
            }
        }
        // row_of[i]: row containing nonzero i (materialized transiently).
        let mut tile_ptr = Vec::with_capacity(n_tiles);
        let mut seg_off = Vec::with_capacity(n_tiles);
        let mut y_off = Vec::with_capacity(n_tiles);
        let mut starts_before = 0u32;
        let mut row = 0usize;
        for t in 0..n_tiles {
            let begin = t * tile_nnz;
            if begin < nnz {
                // Advance `row` to the row containing nonzero `begin`.
                while csr.ptr[row + 1] <= begin {
                    row += 1;
                }
                tile_ptr.push(row as u32);
                seg_off.push(!bit_flag[begin]);
            } else {
                tile_ptr.push(csr.n_rows.saturating_sub(1) as u32);
                seg_off.push(false);
            }
            y_off.push(starts_before);
            let end = ((t + 1) * tile_nnz).min(nnz);
            starts_before +=
                bit_flag[begin.min(nnz)..end].iter().filter(|&&b| b).count()
                    as u32;
        }
        Csr5 {
            n_rows: csr.n_rows,
            n_cols: csr.n_cols,
            tile_nnz,
            tile_ptr,
            bit_flag,
            y_off,
            seg_off,
            indices: csr.indices.clone(),
            data: csr.data.clone(),
            ptr: csr.ptr.clone(),
        }
    }

    pub fn nnz(&self) -> usize {
        self.data.len()
    }

    pub fn n_tiles(&self) -> usize {
        self.tile_ptr.len()
    }

    /// Segmented-sum SpMV over a tile range `[t0, t1)`.
    ///
    /// Complete rows are written into `y` directly; segments that may
    /// continue across the range boundary (the leading carry and the
    /// trailing open row) are returned as `TileCarry` for the caller to
    /// merge — this is exactly the cross-thread reduction CSR5 does
    /// with its `seg_off` descriptor.
    pub fn spmv_tiles(
        &self,
        t0: usize,
        t1: usize,
        x: &[f64],
        y: &mut [f64],
    ) -> Vec<TileCarry> {
        let mut carries = Vec::new();
        self.spmv_tiles_into(t0, t1, x, y, &mut carries);
        carries
    }

    /// [`Csr5::spmv_tiles`] appending carries into a caller-provided
    /// buffer — the zero-allocation serving path reuses one carry
    /// `Vec` per executor slot across requests (`exec::Scratch`). The
    /// buffer is cleared first.
    pub fn spmv_tiles_into(
        &self,
        t0: usize,
        t1: usize,
        x: &[f64],
        y: &mut [f64],
        carries: &mut Vec<TileCarry>,
    ) {
        assert_eq!(x.len(), self.n_cols);
        assert_eq!(y.len(), self.n_rows);
        carries.clear();
        let nnz = self.nnz();
        let begin = (t0 * self.tile_nnz).min(nnz);
        let end = (t1 * self.tile_nnz).min(nnz);
        if begin >= end {
            return;
        }
        let mut row = self.tile_ptr[t0] as usize;
        let mut acc = 0.0;
        let mut leading_open = self.seg_off[t0]; // continuing a row
        for i in begin..end {
            if self.bit_flag[i] {
                if leading_open {
                    // The partial before the first row start belongs to
                    // the previous range's last row.
                    carries.push(TileCarry { row, value: acc });
                    leading_open = false;
                } else if i > begin || self.bit_flag[begin] && i == begin {
                    if i > begin {
                        y[row] = acc;
                    }
                }
                // Advance to the row this nonzero starts.
                if i > begin || !self.seg_off[t0] {
                    if i == begin {
                        // first element starts a row; row is correct
                    } else {
                        row += 1;
                        while self.ptr[row + 1] <= i {
                            row += 1;
                        }
                    }
                }
                acc = 0.0;
            }
            acc += self.data[i] * x[self.indices[i] as usize];
        }
        // Trailing segment: the last row may continue into the next
        // range, so it is always a carry.
        carries.push(TileCarry { row, value: acc });
    }

    /// Sequential SpMV (single range covering all tiles + merge).
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        y.iter_mut().for_each(|v| *v = 0.0);
        let carries = self.spmv_tiles(0, self.n_tiles(), x, y);
        for c in carries {
            y[c.row] += c.value;
        }
    }

    /// Nonzeros assigned to each of `n_threads` under even tile
    /// partitioning — the quantity behind the paper's `job_var` drop
    /// from 0.992 to 0.298 on exdata_1 (Fig 7).
    pub fn thread_nnz(&self, n_threads: usize) -> Vec<usize> {
        let nt = self.n_tiles();
        let nnz = self.nnz();
        (0..n_threads)
            .map(|t| {
                let t0 = nt * t / n_threads;
                let t1 = nt * (t + 1) / n_threads;
                let b = (t0 * self.tile_nnz).min(nnz);
                let e = (t1 * self.tile_nnz).min(nnz);
                e - b
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;

    fn paper_matrix() -> Csr {
        let mut coo = Coo::new(4, 4);
        for &(r, c, v) in &[
            (0, 1, 5.0),
            (0, 2, 2.0),
            (1, 0, 6.0),
            (1, 2, 8.0),
            (1, 3, 3.0),
            (2, 2, 4.0),
            (3, 1, 7.0),
            (3, 2, 1.0),
        ] {
            coo.push(r, c, v);
        }
        coo.to_csr()
    }

    #[test]
    fn table1_descriptors() {
        // Paper Table 1: tile size 4 over the Fig 1 matrix.
        let a = Csr5::from_csr(&paper_matrix(), 4);
        assert_eq!(a.n_tiles(), 2);
        // tile_ptr = [0, 1]: tile0 starts in row0, tile1 starts in row1
        // (its first nonzero is index 4, the last nnz of row 1).
        assert_eq!(a.tile_ptr, vec![0, 1]);
        // bit_flag over nnz order [r0,r0,r1,r1,r1,r2,r3,r3]:
        assert_eq!(
            a.bit_flag,
            vec![true, false, true, false, false, true, true, false]
        );
        // tile 0 holds 2 row starts, tile 1 opens mid-row-1.
        assert_eq!(a.y_off, vec![0, 2]);
        assert_eq!(a.seg_off, vec![false, true]);
    }

    #[test]
    fn spmv_matches_csr() {
        let csr = paper_matrix();
        for tile in [1, 2, 3, 4, 8, 100] {
            let a = Csr5::from_csr(&csr, tile);
            let x = [1.0, 2.0, 3.0, 4.0];
            let mut y = [0.0f64; 4];
            a.spmv(&x, &mut y);
            assert_eq!(y, [16.0, 42.0, 12.0, 17.0], "tile_nnz={tile}");
        }
    }

    #[test]
    fn split_ranges_merge_to_same_result() {
        let csr = paper_matrix();
        let a = Csr5::from_csr(&csr, 2); // 4 tiles
        let x = [1.0, 2.0, 3.0, 4.0];
        let mut y = [0.0f64; 4];
        // Two disjoint ranges, as two threads would execute.
        let mut carries = a.spmv_tiles(0, 2, &x, &mut y);
        carries.extend(a.spmv_tiles(2, 4, &x, &mut y));
        for c in carries {
            y[c.row] += c.value;
        }
        assert_eq!(y, [16.0, 42.0, 12.0, 17.0]);
    }

    #[test]
    fn balanced_thread_nnz_on_skewed_matrix() {
        // One dense row (the exdata_1 pathology): CSR static rows give
        // one thread everything; CSR5 tiles stay balanced.
        let n = 64;
        let mut coo = Coo::new(n, n);
        for c in 0..n {
            coo.push(7, c, 1.0); // dense row
        }
        for r in 0..n {
            coo.push(r, r, 1.0);
        }
        let csr = coo.to_csr();
        let a = Csr5::from_csr(&csr, 8);
        let nnz_per = a.thread_nnz(4);
        let total: usize = nnz_per.iter().sum();
        assert_eq!(total, csr.nnz());
        let max = *nnz_per.iter().max().unwrap() as f64;
        let ratio = max / total as f64;
        assert!(ratio < 0.35, "csr5 job_var should be near 0.25: {ratio}");
    }

    #[test]
    fn empty_and_tiny() {
        let z = Csr::zero(3, 3);
        let a = Csr5::from_csr(&z, 4);
        let mut y = [9.0f64; 3];
        a.spmv(&[1.0, 1.0, 1.0], &mut y);
        assert_eq!(y, [0.0, 0.0, 0.0]);

        let i = Csr::identity(1);
        let a = Csr5::from_csr(&i, 4);
        let mut y = [0.0f64];
        a.spmv(&[3.0], &mut y);
        assert_eq!(y, [3.0]);
    }

    #[test]
    fn random_matches_csr() {
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::new(99);
        for trial in 0..20 {
            let n = 8 + rng.gen_range(64);
            let mut coo = Coo::new(n, n);
            let nnz = 1 + rng.gen_range(n * 4);
            for _ in 0..nnz {
                coo.push(rng.gen_range(n), rng.gen_range(n), rng.gen_f64());
            }
            let csr = coo.to_csr();
            let tile = 1 + rng.gen_range(16);
            let a = Csr5::from_csr(&csr, tile);
            let x: Vec<f64> = (0..n).map(|_| rng.gen_f64()).collect();
            let mut y0 = vec![0.0; n];
            let mut y1 = vec![0.0; n];
            csr.spmv(&x, &mut y0);
            a.spmv(&x, &mut y1);
            for (i, (p, q)) in y0.iter().zip(&y1).enumerate() {
                assert!(
                    (p - q).abs() < 1e-9,
                    "trial {trial} row {i}: {p} vs {q} (tile={tile})"
                );
            }
        }
    }
}
