//! Self-hosted source lint for the ft2000-spmv crate — no external
//! dependencies, no toolchain plugins: a line-level scanner over
//! `src/` that enforces the repo's safety and hot-path conventions.
//!
//! Rules (waivable per site with a `lint:allow(<rule>)` comment on
//! the offending line or within the five lines above it):
//!
//! * `safety-comment` — every `unsafe` block, `unsafe impl`, and
//!   `unsafe fn` must carry a `// SAFETY:` comment within the eight
//!   preceding lines.
//! * `unsafe-scope` — `unsafe` is only permitted in `exec/` (the
//!   disjoint-slot executors and the pool) and
//!   `util/allocprobe.rs` (the counting global allocator).
//! * `hot-alloc` — inside `fn *_into` kernels (the zero-allocation
//!   serve path), `Vec::new`, `vec!`, `.to_vec()`, and `.collect()`
//!   are banned.
//! * `no-unwrap` — non-test code in `service/`, `exec/`, and
//!   `resil/` must not call `.unwrap()` / `.expect(`
//!   (poison-recovering locks and counted error outcomes instead).
//! * `raw-clock` — `Instant::now` is banned outside the clock seams
//!   (deterministic modules: `sparse/`, `sched/`, `sim/`,
//!   `autotune/`, `mlmodel/`, `corpus/`, `counters/`, `solver/`,
//!   `reorder/`, `analysis/`, `coordinator/`, `check/`, `resil/` —
//!   fault plans and chaos replays run on the virtual step clock).
//! * `retry-budget` — in `service/` and `resil/`, a loop on a line
//!   that mentions retrying must mention its budget (or cap) within
//!   five lines: unbounded retry storms take a degraded fleet down
//!   for good. Waive with `lint:allow(retry-budget)` when the bound
//!   lives elsewhere.
//! * `atomic-ord` — every atomic operation naming a memory ordering
//!   (`Ordering::Relaxed` … `Ordering::SeqCst`) must carry an
//!   `ord:` comment on the line or within the six lines above,
//!   stating why that strength is correct. Test modules and
//!   `util/ordatomic.rs` (the instrument itself) are exempt.
//! * `relaxed-store` — a bare `Relaxed` store publishes nothing and
//!   is almost always a broken-release bug in waiting; banned
//!   outside tests unless waived with `lint:allow(relaxed-store)`
//!   plus a justification (single-writer protocol, racy-by-contract
//!   cell).
//! * `hot-seqcst` — `SeqCst` on the hot path (`exec/`, `obs/`,
//!   `service/`, `sched/`) is a full-fence tax that acquire/release
//!   almost always replaces; banned outside tests unless waived
//!   with `lint:allow(hot-seqcst)`.
//! * `crate-attrs` — `lib.rs` must carry
//!   `#![deny(unsafe_op_in_unsafe_fn)]`.
//!
//! Exit status: 0 when clean, 1 when any finding survives (printed
//! one per line as `path:line: rule: message`). CI runs this next to
//! clippy; unlike clippy it needs nothing but the sources.

use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Modules that must stay deterministic / virtual-clocked.
const CLOCK_BANNED: &[&str] = &[
    "sparse/",
    "sched/",
    "sim/",
    "autotune/",
    "mlmodel/",
    "corpus/",
    "counters/",
    "solver/",
    "reorder/",
    "analysis/",
    "coordinator/",
    "check/",
    "resil/",
];

/// Lines a waiver comment may precede its target by.
const WAIVER_WINDOW: usize = 5;

/// Lines a `SAFETY:` comment may precede its `unsafe` site by.
const SAFETY_WINDOW: usize = 8;

/// Lines an `ord:` comment may precede its atomic op by (a multi-line
/// comment block over a run of ops needs a little more reach than a
/// waiver).
const ORD_WINDOW: usize = 6;

/// The memory-ordering tokens the `atomic-ord` family of rules keys
/// on. Spelled out so `std::cmp::Ordering::Equal` never matches.
const ATOMIC_ORDS: &[&str] = &[
    "Ordering::Relaxed",
    "Ordering::Acquire",
    "Ordering::Release",
    "Ordering::AcqRel",
    "Ordering::SeqCst",
];

/// Modules forming the lock-free hot path, where `SeqCst` is banned.
const HOT_PATH: &[&str] = &["exec/", "obs/", "service/", "sched/"];

struct Finding {
    path: String,
    line: usize,
    rule: &'static str,
    msg: String,
}

fn main() -> ExitCode {
    let root = std::env::args()
        .nth(1)
        .map(PathBuf::from)
        .unwrap_or_else(|| {
            Path::new(env!("CARGO_MANIFEST_DIR")).join("src")
        });
    let mut files = Vec::new();
    if let Err(e) = collect_rs(&root, &mut files) {
        eprintln!("ft2000-lint: walking {}: {e}", root.display());
        return ExitCode::from(2);
    }
    files.sort();
    let mut findings = Vec::new();
    let mut saw_lib_attr = false;
    for path in &files {
        let rel = path
            .strip_prefix(&root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        if rel == "bin/ft2000-lint.rs" {
            continue; // rule patterns appear verbatim in this file
        }
        let text = match fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("ft2000-lint: reading {rel}: {e}");
                return ExitCode::from(2);
            }
        };
        if rel == "lib.rs" && text.contains("#![deny(unsafe_op_in_unsafe_fn)]")
        {
            saw_lib_attr = true;
        }
        scan_file(&rel, &text, &mut findings);
    }
    if !saw_lib_attr {
        findings.push(Finding {
            path: "lib.rs".into(),
            line: 1,
            rule: "crate-attrs",
            msg: "missing #![deny(unsafe_op_in_unsafe_fn)]".into(),
        });
    }
    if findings.is_empty() {
        println!("ft2000-lint: clean ({} files)", files.len());
        ExitCode::SUCCESS
    } else {
        for f in &findings {
            println!("{}:{}: {}: {}", f.path, f.line, f.rule, f.msg);
        }
        println!("ft2000-lint: {} finding(s)", findings.len());
        ExitCode::FAILURE
    }
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let p = entry.path();
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// The code part of a line: everything before a `//` comment. Naive
/// about `//` inside string literals — that can only hide code from
/// the scanner (no false findings), and the repo has none on banned
/// constructs.
fn code_part(line: &str) -> &str {
    match line.find("//") {
        Some(i) => &line[..i],
        None => line,
    }
}

/// `needle` present in `hay` with identifier-boundary on both sides.
fn has_token(hay: &str, needle: &str) -> bool {
    let mut from = 0;
    while let Some(i) = hay[from..].find(needle) {
        let start = from + i;
        let end = start + needle.len();
        let pre_ok = start == 0
            || !hay[..start]
                .chars()
                .next_back()
                .is_some_and(|c| c.is_alphanumeric() || c == '_');
        let post_ok = !hay[end..]
            .chars()
            .next()
            .is_some_and(|c| c.is_alphanumeric() || c == '_');
        if pre_ok && post_ok {
            return true;
        }
        from = end;
    }
    false
}

fn waived(lines: &[&str], i: usize, rule: &str) -> bool {
    let tag = format!("lint:allow({rule})");
    let lo = i.saturating_sub(WAIVER_WINDOW);
    lines[lo..=i].iter().any(|l| l.contains(&tag))
}

/// "budget" (or "cap") mentioned in code-or-comment within
/// `WAIVER_WINDOW` lines on either side of line `i` — close enough
/// that a reader sees the retry bound next to the loop.
fn near_budget(lines: &[&str], i: usize) -> bool {
    let lo = i.saturating_sub(WAIVER_WINDOW);
    let hi = (i + WAIVER_WINDOW).min(lines.len().saturating_sub(1));
    lines[lo..=hi]
        .iter()
        .any(|l| l.contains("budget") || has_token(l, "cap"))
}

fn has_safety_comment(lines: &[&str], i: usize) -> bool {
    let lo = i.saturating_sub(SAFETY_WINDOW);
    lines[lo..=i].iter().any(|l| l.contains("SAFETY:"))
}

/// An `ord:` comment (boundary-checked so `record:` never matches) on
/// the line or within `ORD_WINDOW` lines above it.
fn has_ord_comment(lines: &[&str], i: usize) -> bool {
    let lo = i.saturating_sub(ORD_WINDOW);
    lines[lo..=i].iter().any(|l| {
        let mut from = 0;
        while let Some(j) = l[from..].find("ord:") {
            let start = from + j;
            let pre_ok = start == 0
                || !l[..start]
                    .chars()
                    .next_back()
                    .is_some_and(|c| c.is_alphanumeric() || c == '_');
            if pre_ok {
                return true;
            }
            from = start + 4;
        }
        false
    })
}

/// Does this code line declare a function whose name ends in `_into`?
fn declares_into_fn(code: &str) -> bool {
    let mut from = 0;
    while let Some(i) = code[from..].find("fn ") {
        let start = from + i;
        // Word boundary before `fn`.
        let pre_ok = start == 0
            || !code[..start]
                .chars()
                .next_back()
                .is_some_and(|c| c.is_alphanumeric() || c == '_');
        if pre_ok {
            let rest = &code[start + 3..];
            let name: String = rest
                .chars()
                .take_while(|c| c.is_alphanumeric() || *c == '_')
                .collect();
            if name.ends_with("_into") {
                return true;
            }
        }
        from = start + 3;
    }
    false
}

fn scan_file(rel: &str, text: &str, findings: &mut Vec<Finding>) {
    let lines: Vec<&str> = text.lines().collect();
    let in_exec = rel.starts_with("exec/");
    let unsafe_ok = in_exec || rel == "util/allocprobe.rs";
    let unwrap_banned =
        in_exec || rel.starts_with("service/") || rel.starts_with("resil/");
    let clock_banned = CLOCK_BANNED.iter().any(|m| rel.starts_with(m));
    let retry_scope =
        rel.starts_with("service/") || rel.starts_with("resil/");
    // The instrument defines the passthrough ops; every ordering in
    // the crate is documented *at the call site*, not inside it.
    let ord_exempt = rel == "util/ordatomic.rs";
    let hot_path = HOT_PATH.iter().any(|m| rel.starts_with(m));
    let mut in_tests = false;
    let mut depth: i64 = 0;
    let mut into_pending = false;
    let mut into_active = false;
    let mut into_base: i64 = 0;
    let mut push = |line: usize, rule: &'static str, msg: String| {
        findings.push(Finding { path: rel.to_string(), line, rule, msg });
    };
    for (i, &raw) in lines.iter().enumerate() {
        let ln = i + 1;
        let trimmed = raw.trim_start();
        if trimmed.starts_with("#[cfg(test)]") {
            // Repo convention: the test module is the tail of the
            // file, so hot-path and unwrap rules stop here.
            in_tests = true;
        }
        let code = code_part(raw);

        if has_token(code, "unsafe") {
            if !unsafe_ok && !waived(&lines, i, "unsafe-scope") {
                push(
                    ln,
                    "unsafe-scope",
                    format!(
                        "`unsafe` outside exec/ and util/allocprobe.rs \
                         in {rel}"
                    ),
                );
            }
            if !has_safety_comment(&lines, i)
                && !waived(&lines, i, "safety-comment")
            {
                push(
                    ln,
                    "safety-comment",
                    "`unsafe` without a `// SAFETY:` comment within 8 \
                     lines above"
                        .to_string(),
                );
            }
        }

        if !in_tests
            && unwrap_banned
            && (code.contains(".unwrap()") || code.contains(".expect("))
            && !waived(&lines, i, "no-unwrap")
        {
            push(
                ln,
                "no-unwrap",
                "unwrap/expect in serve-path module (recover or return \
                 a counted error)"
                    .to_string(),
            );
        }

        // Substring match on "retry" on purpose: `retry_budget` and
        // `submit_with_retry` have `_` boundaries that `has_token`
        // would treat as mid-identifier and skip.
        if !in_tests
            && retry_scope
            && code.contains("retry")
            && (has_token(code, "for")
                || has_token(code, "while")
                || has_token(code, "loop"))
            && !near_budget(&lines, i)
            && !waived(&lines, i, "retry-budget")
        {
            push(
                ln,
                "retry-budget",
                "retry loop with no budget/cap in sight (bound it, or \
                 name the bound within 5 lines)"
                    .to_string(),
            );
        }

        if clock_banned
            && code.contains("Instant::now")
            && !waived(&lines, i, "raw-clock")
        {
            push(
                ln,
                "raw-clock",
                "raw Instant::now in a deterministic module (take time \
                 through a clock seam)"
                    .to_string(),
            );
        }

        if !in_tests
            && !ord_exempt
            && ATOMIC_ORDS.iter().any(|o| code.contains(o))
        {
            if !has_ord_comment(&lines, i)
                && !waived(&lines, i, "atomic-ord")
            {
                push(
                    ln,
                    "atomic-ord",
                    "atomic op without an `ord:` comment within 6 lines \
                     above stating why this ordering is correct"
                        .to_string(),
                );
            }
            if code.contains(".store(")
                && code.contains("Ordering::Relaxed")
                && !waived(&lines, i, "relaxed-store")
            {
                push(
                    ln,
                    "relaxed-store",
                    "bare Relaxed store (publishes nothing — use \
                     Release, or waive with the single-writer/racy-ok \
                     justification)"
                        .to_string(),
                );
            }
            if hot_path
                && code.contains("Ordering::SeqCst")
                && !waived(&lines, i, "hot-seqcst")
            {
                push(
                    ln,
                    "hot-seqcst",
                    "SeqCst on the hot path (full fence — acquire/\
                     release almost always suffices)"
                        .to_string(),
                );
            }
        }

        if into_active
            && !in_tests
            && (code.contains("Vec::new")
                || code.contains("vec!")
                || code.contains(".to_vec()")
                || code.contains(".collect()"))
            && !waived(&lines, i, "hot-alloc")
        {
            push(
                ln,
                "hot-alloc",
                "allocation in a `*_into` kernel (reuse the scratch \
                 arena)"
                    .to_string(),
            );
        }

        // Function-extent tracking for the hot-alloc rule.
        if !into_active && !in_tests && declares_into_fn(code) {
            into_pending = true;
            into_base = depth;
        }
        let opens = code.matches('{').count() as i64;
        let closes = code.matches('}').count() as i64;
        if into_pending && opens > 0 {
            into_pending = false;
            into_active = true;
        }
        depth += opens - closes;
        if into_active && depth <= into_base {
            into_active = false;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Rules fired by `scan_file` on a synthetic source, as rule
    /// names. `main()` never lints `bin/ft2000-lint.rs` itself, so
    /// these fixtures can contain banned constructs verbatim.
    fn rules_for(rel: &str, src: &str) -> Vec<&'static str> {
        let mut findings = Vec::new();
        scan_file(rel, src, &mut findings);
        findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn retry_budget_rule_fires_and_waives() {
        let unbounded = "for attempt in 0..3 { retry(); }\n";
        assert!(rules_for("service/shard.rs", unbounded)
            .contains(&"retry-budget"));
        assert!(
            rules_for("resil/chaos.rs", unbounded)
                .contains(&"retry-budget"),
            "resil/ is in scope for retry-budget"
        );
        assert!(
            !rules_for("sim/queue.rs", unbounded)
                .contains(&"retry-budget"),
            "rule is scoped to service/ and resil/"
        );

        let bounded = "for attempt in 0..retry_budget { retry(); }\n";
        assert!(
            rules_for("service/shard.rs", bounded).is_empty(),
            "naming the budget on the loop line satisfies the rule"
        );
        let near = "// bounded by the admission budget below\n\
                    while retry_pending() { step(); }\n";
        assert!(
            rules_for("resil/mod.rs", near).is_empty(),
            "a budget mention within 5 lines satisfies the rule"
        );

        let waived = "// lint:allow(retry-budget) bound lives in caller\n\
                      loop { if !retry() { break; } }\n";
        assert!(rules_for("service/batch.rs", waived).is_empty());

        let in_tests = "#[cfg(test)]\nmod tests {\n\
                        for attempt in 0..3 { retry(); }\n}\n";
        assert!(
            !rules_for("service/shard.rs", in_tests)
                .contains(&"retry-budget"),
            "test-module code is exempt"
        );
    }

    #[test]
    fn resil_is_clock_banned() {
        let src = "let t = Instant::now();\n";
        assert!(rules_for("resil/health.rs", src).contains(&"raw-clock"));
        assert!(
            !rules_for("obs/trace.rs", src).contains(&"raw-clock"),
            "obs/ keeps its wall clock"
        );
    }

    #[test]
    fn resil_unwrap_is_banned_outside_tests() {
        let src = "let v = q.pop().unwrap();\n";
        assert!(rules_for("resil/chaos.rs", src).contains(&"no-unwrap"));
    }
}
