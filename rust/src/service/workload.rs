//! Deterministic traffic generators for the replay harness.
//!
//! A workload is (a) which matrix each request targets — uniform or
//! Zipf-skewed popularity over the registered corpus, the skew real
//! serving traffic shows — and (b) when requests arrive: open-loop
//! Poisson, open-loop bursty (on/off modulated Poisson), or
//! closed-loop (a fixed client population, arrivals driven by
//! completions inside the replay engine). Everything is keyed by an
//! explicit `util::rng` seed, so a replay is bit-reproducible.

use crate::util::rng::Pcg32;

/// Matrix-popularity distribution over `n` registered matrices.
#[derive(Clone, Copy, Debug)]
pub enum Popularity {
    Uniform,
    /// Zipf with exponent `s`: rank 0 (the first registered matrix)
    /// is the most popular.
    Zipf { s: f64 },
}

impl Popularity {
    /// Relative request mass of popularity rank `rank` (rank 0 is the
    /// most popular matrix) — the weight shard-placement policies use
    /// to decide which matrices are hot enough to replicate.
    pub fn weight(&self, rank: usize) -> f64 {
        match self {
            Popularity::Uniform => 1.0,
            Popularity::Zipf { s } => ((rank + 1) as f64).powf(-s),
        }
    }

    /// Per-registry-id placement weights for a corpus served in rank
    /// order: `weights[ids[rank]]` accumulates the request mass of
    /// every rank mapped to that id (registration may deduplicate
    /// several ranks onto one id). The shard-placement input.
    pub fn placement_weights(
        &self,
        ids: &[usize],
        registry_len: usize,
    ) -> Vec<f64> {
        let mut weights = vec![0.0f64; registry_len];
        for (rank, &id) in ids.iter().enumerate() {
            weights[id] += self.weight(rank);
        }
        weights
    }
}

/// Arrival process of the request stream.
#[derive(Clone, Copy, Debug)]
pub enum Arrivals {
    /// Open loop: Poisson arrivals at `rate` requests/second.
    Open { rate: f64 },
    /// Open loop, on/off bursts: within each `period_s`, the first
    /// `duty` fraction (clamped to `[0, 1]` at generation) arrives at
    /// `rate * burst`, the remainder at `rate / burst`. `period_s`
    /// must be positive.
    Bursty { rate: f64, burst: f64, period_s: f64, duty: f64 },
    /// Closed loop: `clients` concurrent clients, each issuing its
    /// next request the moment the previous one completes. Arrival
    /// times are produced by the replay engine, not the generator.
    Closed { clients: usize },
}

/// Full workload specification.
#[derive(Clone, Copy, Debug)]
pub struct WorkloadSpec {
    pub requests: usize,
    pub popularity: Popularity,
    pub arrivals: Arrivals,
    pub seed: u64,
}

/// One generated request: virtual arrival time (seconds; 0 for
/// closed-loop, where the replay engine schedules issues) and the
/// index into the served matrix-id list.
#[derive(Clone, Copy, Debug)]
pub struct GenRequest {
    pub arrival_s: f64,
    pub matrix_idx: usize,
}

impl WorkloadSpec {
    /// Generate the request stream over `n_matrices` registered
    /// matrices, sorted by arrival time.
    pub fn generate(&self, n_matrices: usize) -> Vec<GenRequest> {
        assert!(n_matrices > 0, "empty corpus");
        if let Arrivals::Bursty { period_s, .. } = self.arrivals {
            assert!(
                period_s > 0.0,
                "bursty arrivals need period_s > 0, got {period_s}"
            );
        }
        let mut rng = Pcg32::new(self.seed);
        let mut out = Vec::with_capacity(self.requests);
        let mut t = 0.0f64;
        for _ in 0..self.requests {
            let matrix_idx = match self.popularity {
                Popularity::Uniform => rng.gen_range(n_matrices),
                Popularity::Zipf { s } => rng.gen_zipf(n_matrices, s),
            };
            let arrival_s = match self.arrivals {
                Arrivals::Open { rate } => {
                    t += exp_interval(&mut rng, rate);
                    t
                }
                Arrivals::Bursty { rate, burst, period_s, duty } => {
                    // duty outside [0,1] would silently degenerate to
                    // always-on (>1) or always-off (<0); clamp it so
                    // the on/off structure survives bad configs.
                    let duty = duty.clamp(0.0, 1.0);
                    let burst = burst.max(1.0);
                    let phase = (t / period_s).fract();
                    let r = if phase < duty { rate * burst } else { rate / burst };
                    t += exp_interval(&mut rng, r);
                    t
                }
                Arrivals::Closed { .. } => 0.0,
            };
            out.push(GenRequest { arrival_s, matrix_idx });
        }
        out
    }
}

/// Exponential inter-arrival sample for a Poisson process.
fn exp_interval(rng: &mut Pcg32, rate: f64) -> f64 {
    let rate = rate.max(1e-9);
    let u = rng.gen_f64();
    -(1.0 - u).ln() / rate
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(pop: Popularity, arr: Arrivals) -> WorkloadSpec {
        WorkloadSpec { requests: 2000, popularity: pop, arrivals: arr, seed: 42 }
    }

    #[test]
    fn deterministic_for_fixed_seed() {
        let s = spec(Popularity::Zipf { s: 1.2 }, Arrivals::Open { rate: 100.0 });
        let a = s.generate(16);
        let b = s.generate(16);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.matrix_idx, y.matrix_idx);
            assert_eq!(x.arrival_s.to_bits(), y.arrival_s.to_bits());
        }
    }

    #[test]
    fn zipf_concentrates_on_head() {
        let s = spec(Popularity::Zipf { s: 1.3 }, Arrivals::Open { rate: 100.0 });
        let reqs = s.generate(32);
        // Continuous-approximation CDF puts ~53% of zipf(1.3) mass on
        // the first 4 of 32 ranks; uniform would put 12.5%.
        let head = reqs.iter().filter(|r| r.matrix_idx < 4).count();
        assert!(
            head > reqs.len() * 2 / 5,
            "zipf head share too small: {head}/{}",
            reqs.len()
        );
        assert!(reqs.iter().all(|r| r.matrix_idx < 32));
    }

    #[test]
    fn uniform_spreads() {
        let s = spec(Popularity::Uniform, Arrivals::Open { rate: 100.0 });
        let reqs = s.generate(8);
        let mut seen = [false; 8];
        for r in &reqs {
            seen[r.matrix_idx] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn open_arrivals_monotone_and_near_rate() {
        let s = spec(Popularity::Uniform, Arrivals::Open { rate: 500.0 });
        let reqs = s.generate(4);
        for w in reqs.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
        }
        let span = reqs.last().unwrap().arrival_s;
        let empirical = reqs.len() as f64 / span;
        assert!(
            (empirical / 500.0 - 1.0).abs() < 0.2,
            "empirical rate {empirical} too far from 500"
        );
    }

    #[test]
    fn bursty_has_dense_and_sparse_stretches() {
        let s = spec(
            Popularity::Uniform,
            Arrivals::Bursty { rate: 100.0, burst: 8.0, period_s: 1.0, duty: 0.5 },
        );
        let reqs = s.generate(4);
        // Count arrivals in the on-phase vs off-phase of each period.
        let (mut on, mut off) = (0usize, 0usize);
        for r in &reqs {
            if (r.arrival_s % 1.0) < 0.5 {
                on += 1;
            } else {
                off += 1;
            }
        }
        assert!(on > off * 4, "burstiness not visible: on={on} off={off}");
    }

    #[test]
    fn bursty_duty_clamps_to_unit_interval() {
        let gen = |duty: f64| {
            spec(
                Popularity::Uniform,
                Arrivals::Bursty {
                    rate: 200.0,
                    burst: 4.0,
                    period_s: 1.0,
                    duty,
                },
            )
            .generate(4)
        };
        // duty > 1 must behave exactly like duty == 1 (always-on), not
        // silently degenerate to some other phase arithmetic.
        let (hi, one) = (gen(1.5), gen(1.0));
        for (a, b) in hi.iter().zip(&one) {
            assert_eq!(a.arrival_s.to_bits(), b.arrival_s.to_bits());
            assert_eq!(a.matrix_idx, b.matrix_idx);
        }
        // duty < 0 must behave exactly like duty == 0 (always-off).
        let (lo, zero) = (gen(-0.3), gen(0.0));
        for (a, b) in lo.iter().zip(&zero) {
            assert_eq!(a.arrival_s.to_bits(), b.arrival_s.to_bits());
        }
        // And the two edges really differ: always-on runs burst^2
        // faster than always-off.
        let span_on = hi.last().unwrap().arrival_s;
        let span_off = lo.last().unwrap().arrival_s;
        assert!(
            span_off > span_on * 8.0,
            "on-span {span_on} vs off-span {span_off}"
        );
    }

    #[test]
    #[should_panic(expected = "period_s > 0")]
    fn bursty_rejects_nonpositive_period() {
        spec(
            Popularity::Uniform,
            Arrivals::Bursty { rate: 10.0, burst: 2.0, period_s: 0.0, duty: 0.5 },
        )
        .generate(2);
    }

    #[test]
    fn popularity_weights_rank_matrices() {
        let z = Popularity::Zipf { s: 1.2 };
        assert!(z.weight(0) > z.weight(1));
        assert!(z.weight(1) > z.weight(7));
        assert!((z.weight(0) - 1.0).abs() < 1e-12);
        let u = Popularity::Uniform;
        assert_eq!(u.weight(0), u.weight(100));
    }

    #[test]
    fn closed_loop_has_zero_arrivals() {
        let s = spec(Popularity::Uniform, Arrivals::Closed { clients: 8 });
        assert!(s.generate(4).iter().all(|r| r.arrival_s == 0.0));
    }
}
