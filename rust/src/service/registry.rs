//! Matrix registry — content-fingerprinted store of servable matrices.
//!
//! A serving deployment loads each matrix once (from the synthetic
//! corpus or a MatrixMarket file), pays the feature-extraction cost
//! once, and addresses it by a stable id afterwards. Registration is
//! idempotent: re-registering identical content returns the existing
//! id, so the plan cache keyed by fingerprint never rebuilds a plan
//! for a matrix it has already seen under another name.

use std::collections::HashMap;

use anyhow::{anyhow, Context, Result};

use crate::check::{self, CheckReport};
use crate::corpus::suite::SuiteSpec;
use crate::sparse::{mm, Csr, MatrixFeatures};

/// Content fingerprint of a CSR matrix: FNV-1a over the dimensions,
/// row pointers, column indices, and value bit patterns. Stable
/// across processes (no address-dependent state), so plans keyed by
/// it are reproducible run to run.
pub fn fingerprint(csr: &Csr) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    let mut mix = |v: u64| {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(PRIME);
        }
    };
    mix(csr.n_rows as u64);
    mix(csr.n_cols as u64);
    mix(csr.nnz() as u64);
    for &p in &csr.ptr {
        mix(p as u64);
    }
    for &c in &csr.indices {
        mix(c as u64);
    }
    for &v in &csr.data {
        mix(v.to_bits());
    }
    h
}

/// One registered matrix with its precomputed serving metadata.
#[derive(Clone, Debug)]
pub struct MatrixEntry {
    pub id: usize,
    pub name: String,
    pub fingerprint: u64,
    pub csr: Csr,
    pub features: MatrixFeatures,
}

/// The registry: id-addressable, deduplicated by content fingerprint.
#[derive(Clone, Debug, Default)]
pub struct MatrixRegistry {
    entries: Vec<MatrixEntry>,
    by_fingerprint: HashMap<u64, usize>,
    by_name: HashMap<String, usize>,
    /// Matrices rejected by admission checking
    /// ([`MatrixRegistry::try_register`]) — counted, never served.
    rejected: usize,
}

impl MatrixRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Matrices refused by [`MatrixRegistry::try_register`] so far.
    pub fn rejected(&self) -> usize {
        self.rejected
    }

    /// Checked admission: run the structural verifier
    /// (`check::check_csr`) and register only clean matrices. A bad
    /// matrix is a counted rejection (see
    /// [`MatrixRegistry::rejected`]) carrying the findings — never a
    /// panic and never a served entry.
    pub fn try_register(
        &mut self,
        name: &str,
        csr: Csr,
    ) -> std::result::Result<usize, CheckReport> {
        let report = check::check_csr(name, &csr);
        if !report.is_clean() {
            self.rejected += 1;
            return Err(report);
        }
        Ok(self.register(name, csr))
    }

    /// Register a matrix; returns its id. Identical content (same
    /// fingerprint) is deduplicated to the first id, regardless of
    /// name. Trusted-input path (synthetic corpus, roundtrips);
    /// untrusted loads go through [`MatrixRegistry::try_register`].
    pub fn register(&mut self, name: &str, csr: Csr) -> usize {
        debug_assert!(
            check::check_csr(name, &csr).is_clean(),
            "register() is for trusted input; use try_register"
        );
        let fp = fingerprint(&csr);
        if let Some(&id) = self.by_fingerprint.get(&fp) {
            self.by_name.entry(name.to_string()).or_insert(id);
            return id;
        }
        let id = self.entries.len();
        let features = MatrixFeatures::extract(&csr);
        self.entries.push(MatrixEntry {
            id,
            name: name.to_string(),
            fingerprint: fp,
            csr,
            features,
        });
        self.by_fingerprint.insert(fp, id);
        self.by_name.insert(name.to_string(), id);
        id
    }

    pub fn get(&self, id: usize) -> Option<&MatrixEntry> {
        self.entries.get(id)
    }

    /// Panicking accessor for ids handed out by this registry.
    pub fn entry(&self, id: usize) -> &MatrixEntry {
        &self.entries[id]
    }

    pub fn lookup_name(&self, name: &str) -> Option<usize> {
        self.by_name.get(name).copied()
    }

    pub fn ids(&self) -> Vec<usize> {
        (0..self.entries.len()).collect()
    }

    pub fn iter(&self) -> impl Iterator<Item = &MatrixEntry> {
        self.entries.iter()
    }

    pub fn total_nnz(&self) -> usize {
        self.entries.iter().map(|e| e.csr.nnz()).sum()
    }

    /// Register up to `limit` matrices of a synthetic suite, sampled
    /// with a deterministic stride so every structural class is
    /// represented (suite entries are grouped by class). Returns the
    /// registered ids in sampling order.
    pub fn register_suite(
        &mut self,
        spec: &SuiteSpec,
        limit: Option<usize>,
    ) -> Vec<usize> {
        let entries = spec.entries();
        let total = entries.len();
        let take = limit.unwrap_or(total).min(total).max(1);
        let mut ids = Vec::with_capacity(take);
        for i in 0..take {
            let e = &entries[i * total / take];
            let m = spec.materialize(e);
            ids.push(self.register(&e.name, m.csr));
        }
        ids
    }

    /// Register MatrixMarket content from any reader under `name`.
    /// Untrusted input end to end: a payload that fails to *parse*
    /// (malformed header, non-finite values, oversized dims, short
    /// files) is as much a counted rejection as one that parses into
    /// a structurally corrupt matrix — both bump
    /// [`MatrixRegistry::rejected`], neither ever panics or serves.
    pub fn register_mtx_reader<R: std::io::Read>(
        &mut self,
        name: &str,
        reader: R,
    ) -> Result<usize> {
        let csr = match mm::read_csr(reader) {
            Ok(csr) => csr,
            Err(e) => {
                self.rejected += 1;
                return Err(anyhow!("{name}: {e}"));
            }
        };
        self.try_register(name, csr)
            .map_err(|report| anyhow!("{name}: rejected: {report}"))
    }

    /// Register a MatrixMarket file under its path as the name (the
    /// file-backed wrapper of [`MatrixRegistry::register_mtx_reader`];
    /// an unopenable file is an I/O error, not a counted rejection —
    /// nothing was admitted for checking).
    pub fn register_mtx(&mut self, path: &str) -> Result<usize> {
        let f = std::fs::File::open(path)
            .with_context(|| format!("opening {path}"))?;
        self.register_mtx_reader(path, f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::generators;
    use crate::util::rng::Pcg32;

    #[test]
    fn fingerprint_distinguishes_content() {
        let mut rng = Pcg32::new(7);
        let a = generators::banded(64, 3, &mut rng);
        let b = generators::banded(64, 3, &mut rng); // different values
        assert_ne!(fingerprint(&a), fingerprint(&b));
        assert_eq!(fingerprint(&a), fingerprint(&a.clone()));
        // A value flip must change the fingerprint.
        let mut c = a.clone();
        c.data[0] += 1.0;
        assert_ne!(fingerprint(&a), fingerprint(&c));
    }

    #[test]
    fn register_deduplicates_by_content() {
        let mut rng = Pcg32::new(9);
        let m = generators::random_uniform(128, 4, &mut rng);
        let mut reg = MatrixRegistry::new();
        let a = reg.register("first", m.clone());
        let b = reg.register("alias", m);
        assert_eq!(a, b);
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.lookup_name("first"), Some(a));
        assert_eq!(reg.lookup_name("alias"), Some(a));
        assert_eq!(reg.entry(a).features.nnz, reg.entry(a).csr.nnz());
    }

    #[test]
    fn try_register_rejects_corrupt_matrices_as_counted_errors() {
        let mut rng = Pcg32::new(11);
        let good = generators::random_uniform(64, 4, &mut rng);
        let mut bad = good.clone();
        bad.indices[0] = 64; // column out of bounds
        let mut reg = MatrixRegistry::new();
        let report = reg.try_register("bad", bad).unwrap_err();
        assert!(!report.is_clean());
        assert!(report
            .findings
            .iter()
            .any(|f| f.invariant == "col-bounds"));
        assert_eq!(reg.rejected(), 1);
        assert_eq!(reg.len(), 0, "rejected matrices are never served");
        // Clean content still admits.
        let id = reg.try_register("good", good).unwrap();
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.rejected(), 1);
        assert!(reg.get(id).is_some());
    }

    #[test]
    fn register_suite_covers_classes() {
        let mut reg = MatrixRegistry::new();
        let spec = SuiteSpec::tiny();
        let ids = reg.register_suite(&spec, Some(9));
        assert_eq!(ids.len(), 9);
        assert_eq!(reg.len(), 9);
        // Stride sampling across class-grouped entries: names span
        // more than one structural class.
        let classes: std::collections::HashSet<String> = reg
            .iter()
            .map(|e| e.name.rsplitn(2, '_').nth(1).unwrap_or("").to_string())
            .collect();
        assert!(classes.len() >= 5, "classes: {classes:?}");
    }

    #[test]
    fn register_suite_is_deterministic() {
        let spec = SuiteSpec::tiny();
        let mut a = MatrixRegistry::new();
        let mut b = MatrixRegistry::new();
        a.register_suite(&spec, Some(6));
        b.register_suite(&spec, Some(6));
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.fingerprint, y.fingerprint);
            assert_eq!(x.name, y.name);
        }
    }
}
