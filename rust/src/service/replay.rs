//! Virtual-time replay of a workload through the serve engine.
//!
//! The queueing timeline (arrivals, batch formation, service,
//! completion) runs in *virtual* time with an explicit cost model, so
//! a replay with a fixed seed produces bit-identical batch
//! composition and latency percentiles on any machine — the property
//! the acceptance tests pin. The kernels still really execute
//! (verifying the serving path and measuring achieved Gflops); the
//! measured-throughput row of the report is the only
//! machine-dependent output.
//!
//! Single virtual server, FIFO queue, same-matrix coalescing up to
//! `max_batch` after a fixed batching window — the policy the live
//! worker pool in [`super::batch`] implements in wall-clock time.

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::autotune::StageObs;
use crate::exec::SPMM_COL_BLOCK;
use crate::obs::scaling::{GapComponents, ScalingProfiler};
use crate::obs::trace::SCHED_NONE;
use crate::obs::{chrome_document, ClockMode, Stage, TraceRecorder};
use crate::sched::panel_core_range;
use crate::sim::topology::Topology;
use crate::util::json::Json;

use super::plan::{PlanConfig, Planner};
use super::registry::MatrixRegistry;
use super::shard::{PlacementPolicy, ShardPlacement};
use super::telemetry::{
    batch_histogram_table, report_json, report_table, shard_table,
    ShardSnapshot,
};
use super::workload::{Arrivals, GenRequest, WorkloadSpec};
use super::{ServeEngine, ServeStats};

/// Deterministic service-time model of one batched dispatch.
///
/// `dispatch` is the fixed per-launch cost (queue pop, plan lookup,
/// thread wake). The kernel term charges streaming the matrix once
/// per column block of the batch plus one FMA per nonzero per vector,
/// divided across threads — the same structure as
/// `exec::spmm_threaded`, which is why batching wins: one dispatch
/// and one matrix stream serve many vectors.
///
/// Two terms model the paper's scalability ceiling, and together they
/// give latency a *knee* in the thread count (what the autotuner's
/// hill-climb hunts): `sync_s` charges fork/join fan-out per extra
/// worker, and `sat_threads` caps the parallel speedup of the
/// memory-bound kernel term at one panel's worth of cores —
/// FT-2000+ SpMV stops scaling once the local panel's bandwidth
/// saturates (paper §4), so threads past the knee add sync cost and
/// nothing else.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    pub dispatch_s: f64,
    /// Seconds per nonzero to stream A (per column block).
    pub stream_a_s: f64,
    /// Seconds per nonzero per vector for the FMA + x access.
    pub fma_s: f64,
    /// Fork/join cost per worker beyond the first.
    pub sync_s: f64,
    /// Threads beyond this add no kernel speedup (panel bandwidth
    /// saturation — 8 cores per FT-2000+ panel).
    pub sat_threads: usize,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            dispatch_s: 30e-6,
            stream_a_s: 0.4e-9,
            fma_s: 0.15e-9,
            sync_s: 2e-6,
            sat_threads: 8,
        }
    }
}

impl CostModel {
    pub fn service_s(&self, nnz: usize, batch: usize, threads: usize) -> f64 {
        let blocks = batch.div_ceil(SPMM_COL_BLOCK).max(1) as f64;
        let th = threads.max(1);
        let eff = th.min(self.sat_threads.max(1)) as f64;
        self.dispatch_s
            + self.sync_s * (th - 1) as f64
            + (nnz as f64 * blocks * self.stream_a_s
                + nnz as f64 * batch as f64 * self.fma_s)
                / eff
    }

    /// Serial-equivalent kernel work of one dispatch (the `T1` the
    /// kernel term of [`CostModel::service_s`] divides by the
    /// effective parallelism).
    pub fn work_s(&self, nnz: usize, batch: usize) -> f64 {
        let blocks = batch.div_ceil(SPMM_COL_BLOCK).max(1) as f64;
        nnz as f64 * blocks * self.stream_a_s
            + nnz as f64 * batch as f64 * self.fma_s
    }

    /// Deterministic gap-to-linear decomposition of one modeled
    /// dispatch, term for term the same arithmetic as
    /// [`CostModel::service_s`]: the dispatch + fork/join terms are
    /// overhead, the model has no lane raggedness (imbalance 0), and
    /// what remains of the gap is exactly the kernel time the
    /// bandwidth cap refused to parallelize —
    /// `T1 * (1/eff - 1/threads)`, nonzero iff `threads >
    /// sat_threads`. The components therefore sum to the observed gap
    /// *exactly*, which is the identity the acceptance test pins on a
    /// deterministic replay.
    pub fn components(
        &self,
        nnz: usize,
        batch: usize,
        threads: usize,
    ) -> GapComponents {
        let th = threads.max(1);
        let eff = th.min(self.sat_threads.max(1)) as f64;
        let work_s = self.work_s(nnz, batch);
        let kernel_s = work_s / eff;
        let dispatch_s = self.dispatch_s + self.sync_s * (th - 1) as f64;
        GapComponents::from_parts(
            th, work_s, kernel_s, dispatch_s, 0.0, 0.0, false,
        )
    }
}

/// Replay policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct ReplayConfig {
    /// Largest same-matrix group one dispatch may coalesce.
    pub max_batch: usize,
    /// Virtual wait after the server frees up, letting concurrent
    /// arrivals accumulate into a batch (open-loop modes).
    pub batch_window_s: f64,
    /// Admission bound on the virtual queue (open-loop modes):
    /// arrivals beyond this many pending requests are rejected and
    /// counted, mirroring the live bounded [`super::RequestQueue`].
    /// 0 = unbounded.
    pub queue_cap: usize,
    /// Really execute the kernels (measures achieved Gflops and
    /// exercises the full serving path). `false` replays the queueing
    /// model only.
    pub execute: bool,
    /// Engines built *by the replay harness* (the virtual panels of
    /// [`replay_sharded`]) carry a persistent executor pool pinned to
    /// their panel core range and plan panel-wide kernels; `false`
    /// keeps the per-request scoped-thread baseline with the default
    /// plan width. (For [`replay`] the caller supplies the engine and
    /// this knob is moot.) Each mode is deterministic; the modeled
    /// service times differ because pinned engines partition one slot
    /// per panel core.
    pub pooled: bool,
    /// Attach an online autotuner to every engine *built by the
    /// replay harness* ([`replay_sharded`]'s virtual panels), clocked
    /// by the deterministic cost model (`wall_clock` is forced off)
    /// and thread-bounded by each panel's core range. For [`replay`]
    /// the caller supplies the engine, so it attaches the tuner
    /// itself ([`ServeEngine::with_tuner`]) and this knob is moot.
    pub tune: Option<crate::autotune::AutotuneConfig>,
    /// Attach a *virtual-clock* span recorder to every engine built
    /// by the replay harness ([`replay_sharded`]'s panels): spans are
    /// stamped on the deterministic replay timeline and exported per
    /// shard via [`ShardedReplayReport::export_chrome`]. For
    /// [`replay`] the caller supplies the engine, so it attaches the
    /// recorder itself ([`ServeEngine::with_trace`], mode
    /// [`ClockMode::Virtual`]); the harness drives whatever recorder
    /// the engine carries.
    pub trace: Option<crate::obs::TraceConfig>,
    pub cost: CostModel,
}

impl Default for ReplayConfig {
    fn default() -> Self {
        ReplayConfig {
            max_batch: 16,
            batch_window_s: 200e-6,
            queue_cap: 0,
            execute: true,
            pooled: true,
            tune: None,
            trace: None,
            cost: CostModel::default(),
        }
    }
}

/// The finished replay: telemetry snapshot + cache accounting.
#[derive(Clone, Debug)]
pub struct ReplayReport {
    pub stats: ServeStats,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Virtual makespan (last completion time).
    pub duration_s: f64,
    /// Number of matrices the workload was served from.
    pub matrices: usize,
    /// Per-matrix tuning summaries when the serving engine autotuned.
    pub autotune: Option<Vec<crate::autotune::TunerSummary>>,
}

impl ReplayReport {
    pub fn throughput_rps(&self) -> f64 {
        if self.duration_s > 0.0 {
            self.stats.requests as f64 / self.duration_s
        } else {
            0.0
        }
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    pub fn print(&self) {
        report_table(
            format!(
                "Serving replay report ({} matrices served)",
                self.matrices
            ),
            &self.stats,
            self.cache_hits,
            self.cache_misses,
            self.duration_s,
        )
        .print();
        if self.stats.batches > 0 {
            batch_histogram_table(&self.stats).print();
        }
        if let Some(summaries) = &self.autotune {
            if !summaries.is_empty() {
                crate::autotune::autotune_table(summaries).print();
            }
        }
    }

    pub fn to_json(&self) -> Json {
        let base = report_json(
            &self.stats,
            self.cache_hits,
            self.cache_misses,
            self.duration_s,
        );
        match &self.autotune {
            Some(summaries) => {
                let mut obj = match base {
                    Json::Obj(o) => o,
                    _ => unreachable!("report_json returns an object"),
                };
                obj.insert(
                    "autotune".into(),
                    crate::autotune::autotune_json(summaries),
                );
                Json::Obj(obj)
            }
            None => base,
        }
    }
}

/// One dispatched (possibly coalesced) group, as seen by the cost
/// model and the tuning feedback loop.
struct Dispatched {
    threads: usize,
    nnz: usize,
    fingerprint: u64,
    /// Tuner arm this dispatch ran (autotuned engines only).
    arm: Option<usize>,
}

/// Executes dispatches against the engine, memoizing one
/// deterministic input vector per matrix.
struct Dispatcher<'a> {
    engine: &'a ServeEngine,
    /// Maps workload matrix index -> registry id.
    ids: &'a [usize],
    execute: bool,
    inputs: HashMap<usize, Vec<f64>>,
}

impl Dispatcher<'_> {
    /// Dispatch a coalesced group of `size` requests against matrix
    /// `matrix_idx`; returns what the cost model (and the tuner
    /// feedback) needs.
    fn run(&mut self, matrix_idx: usize, size: usize) -> Dispatched {
        let id = self.ids[matrix_idx];
        let entry = self.engine.registry.entry(id);
        let nnz = entry.csr.nnz();
        let fingerprint = entry.fingerprint;
        if self.execute {
            let n_cols = entry.csr.n_cols;
            let x = self
                .inputs
                .entry(id)
                .or_insert_with(|| vec![1.0; n_cols]);
            let xs: Vec<&[f64]> = (0..size).map(|_| x.as_slice()).collect();
            // Replay discards outputs too: ride the scratch-arena
            // serve path, same as the live drain loop.
            // Replay traffic is generated against the registry, so
            // every id resolves. lint:allow(no-unwrap)
            let out = self
                .engine
                .serve_batch(id, &xs)
                .expect("replay serves only registered ids");
            Dispatched { threads: out.threads, nnz, fingerprint, arm: out.arm }
        } else {
            // The model-only path resolves its plan through the same
            // engine helper as the executed path (cache + promoted
            // winner + tuner arm pick), so both replays of one seed
            // share a bit-identical timeline by construction.
            let t_lookup = Instant::now();
            let (plan, plan_hit, arm) = self.engine.plan_for_dispatch(entry);
            let lookup_us = t_lookup.elapsed().as_secs_f64() * 1e6;
            let sched = crate::autotune::ladder::schedule_code(
                plan.effective_schedule(size),
            ) as usize
                + 1;
            // The executed path's spans come from the engine; the
            // model path records its own so traced model-only
            // replays still decompose by stage. Durations are the
            // real (wall) cost of the code; timestamps follow the
            // recorder's virtual clock.
            if let Some(rec) = self.engine.trace() {
                rec.set_kernel_ctx(sched);
                if rec.sampled() {
                    let now = rec.now_us();
                    rec.record(
                        0,
                        Stage::PlanLookup,
                        sched,
                        now - lookup_us,
                        lookup_us,
                    );
                    if !plan_hit {
                        rec.record(
                            0,
                            Stage::Partition,
                            sched,
                            now - lookup_us,
                            lookup_us,
                        );
                    }
                }
            }
            let t_reduce = Instant::now();
            self.engine.telemetry.record_batch(
                id,
                size,
                0.0,
                0.0,
                plan.effective_schedule_name(size),
            );
            if let Some(rec) = self.engine.trace() {
                rec.record_elapsed(
                    0,
                    Stage::Reduce,
                    sched,
                    t_reduce.elapsed().as_secs_f64() * 1e6,
                );
            }
            // Effective (not configured) parallelism, the same count
            // the executed path reports — execute=true and model-only
            // replays of one seed share a bit-identical timeline.
            Dispatched {
                threads: plan.effective_threads(size),
                nnz,
                fingerprint,
                arm,
            }
        }
    }

    /// Model-only replays have no lane tallies to measure, so the
    /// cost model's own deterministic decomposition feeds the scaling
    /// profiler — gap attribution works on bit-reproducible replays
    /// too. Executed replays skip this: `dispatch_into` already
    /// recorded the *measured* components for the same batch.
    fn attribute(&self, disp: &Dispatched, batch: usize, c: &GapComponents) {
        if self.execute {
            return;
        }
        self.engine.scaling().record(
            disp.fingerprint,
            disp.threads.max(1),
            batch,
            c,
        );
    }

    /// Close the tuning loop on the *virtual* clock: the modeled
    /// service time of this dispatch becomes the tuner's observation
    /// (one per-request share per coalesced request), and promotions
    /// land in the engine's plan cache. Wall-clock tuners are skipped
    /// — the engine already observed real time in `execute_batch`.
    fn feedback(
        &self,
        disp: &Dispatched,
        service_s: f64,
        batch: usize,
        comps: &GapComponents,
    ) {
        let Some(arm) = disp.arm else { return };
        let Some(tuner) = self.engine.tuner() else { return };
        if tuner.wall_clock() {
            return;
        }
        let per_request_ms = service_s * 1e3 / batch.max(1) as f64;
        // The modeled service time is all kernel as far as the
        // measured stage columns go (the model has no lookup/reduce
        // split), but the cost model's gap attribution is exact — the
        // retraining dataset learns the saturation residual.
        let stages = StageObs {
            kernel_ms: service_s * 1e3,
            imbalance_ms: comps.imbalance_s * 1e3,
            overhead_ms: comps.overhead_s * 1e3,
            residual_ms: comps.residual_s.max(0.0) * 1e3,
            ..StageObs::default()
        };
        let t0 = Instant::now();
        let promoted = tuner.observe_staged(
            disp.fingerprint,
            arm,
            per_request_ms,
            batch,
            &stages,
        );
        if let Some(rec) = self.engine.trace() {
            rec.record_elapsed(
                0,
                Stage::AutotuneObserve,
                SCHED_NONE,
                t0.elapsed().as_secs_f64() * 1e6,
            );
        }
        if let Some(promoted) = promoted {
            self.engine.plans.replace(disp.fingerprint, promoted);
        }
    }
}

/// Replay `spec` against the engine over the registered `ids`
/// (workload matrix index i -> ids[i]). The engine should be fresh —
/// the report snapshots its cumulative telemetry and cache counters.
pub fn replay(
    engine: &ServeEngine,
    ids: &[usize],
    spec: &WorkloadSpec,
    cfg: &ReplayConfig,
) -> Result<ReplayReport> {
    ensure!(!ids.is_empty(), "no matrices registered to serve");
    ensure!(spec.requests > 0, "empty workload");
    for &id in ids {
        ensure!(
            engine.registry.get(id).is_some(),
            "unknown registry id {id}"
        );
    }
    let reqs = spec.generate(ids.len());
    let mut d = Dispatcher {
        engine,
        ids,
        execute: cfg.execute,
        inputs: HashMap::new(),
    };
    let duration_s = match spec.arrivals {
        Arrivals::Closed { clients } => {
            replay_closed(&mut d, &reqs, clients.max(1), cfg)
        }
        _ => replay_open(&mut d, &reqs, cfg),
    };
    let stats = engine.telemetry.snapshot();
    let (cache_hits, cache_misses) = engine.plans.stats();
    Ok(ReplayReport {
        stats,
        cache_hits,
        cache_misses,
        duration_s,
        matrices: ids.len(),
        autotune: engine.tuner().map(|t| t.summaries()),
    })
}

/// A finished sharded replay: one [`ReplayReport`] per shard plus the
/// parallel makespan.
#[derive(Clone, Debug)]
pub struct ShardedReplayReport {
    pub shards: Vec<ReplayReport>,
    /// Modeled panel core ranges, parallel to `shards`.
    pub cores: Vec<(usize, usize)>,
    /// Makespan of the slowest shard (shards run in parallel).
    pub duration_s: f64,
    /// Per-shard virtual-clock span recorders when
    /// [`ReplayConfig::trace`] was on (parallel to `shards`; empty
    /// otherwise).
    pub traces: Vec<Arc<TraceRecorder>>,
    /// Per-shard unified engine metrics snapshots
    /// ([`ServeEngine::metrics_snapshot`]), captured before the
    /// harness engines wound down (parallel to `shards`).
    pub metrics: Vec<Json>,
    /// Fleet scalability roll-up: every shard engine's
    /// [`ScalingProfiler`] merged into one `ft2000.scaling.v1`
    /// document (queue-wait summary from the merged stats).
    pub scaling: Json,
}

impl ShardedReplayReport {
    /// Fleet roll-up across all shards.
    pub fn merged(&self) -> ReplayReport {
        let mut stats = ServeStats::default();
        let (mut hits, mut misses) = (0u64, 0u64);
        let mut matrices = 0usize;
        let mut autotune: Option<Vec<crate::autotune::TunerSummary>> = None;
        for r in &self.shards {
            stats.merge(&r.stats);
            hits += r.cache_hits;
            misses += r.cache_misses;
            matrices = matrices.max(r.matrices);
            if let Some(s) = &r.autotune {
                autotune
                    .get_or_insert_with(Vec::new)
                    .extend(s.iter().cloned());
            }
        }
        ReplayReport {
            stats,
            cache_hits: hits,
            cache_misses: misses,
            duration_s: self.duration_s,
            matrices,
            autotune,
        }
    }

    pub fn snapshots(&self) -> Vec<ShardSnapshot> {
        self.shards
            .iter()
            .enumerate()
            .map(|(i, r)| ShardSnapshot {
                shard: i,
                cores: self.cores[i],
                stats: r.stats.clone(),
                cache_hits: r.cache_hits,
                cache_misses: r.cache_misses,
                duration_s: r.duration_s,
            })
            .collect()
    }

    pub fn print(&self) {
        shard_table(&self.snapshots()).print();
        let merged = self.merged();
        report_table(
            format!(
                "Sharded serving replay report ({} shards, merged)",
                self.shards.len()
            ),
            &merged.stats,
            merged.cache_hits,
            merged.cache_misses,
            self.duration_s,
        )
        .print();
        if merged.stats.batches > 0 {
            batch_histogram_table(&merged.stats).print();
        }
        if let Some(summaries) = &merged.autotune {
            if !summaries.is_empty() {
                crate::autotune::autotune_table(summaries).print();
            }
        }
    }

    pub fn to_json(&self) -> Json {
        let merged = self.merged();
        let mut obj = match merged.to_json() {
            Json::Obj(o) => o,
            _ => unreachable!("report_json returns an object"),
        };
        obj.insert(
            "shards".into(),
            Json::Arr(self.shards.iter().map(|r| r.to_json()).collect()),
        );
        Json::Obj(obj)
    }

    /// Merge every shard's spans into one Chrome `trace_event`
    /// document, `pid` = shard index (empty when tracing was off).
    pub fn export_chrome(&self) -> Json {
        let mut events = Vec::new();
        for (i, rec) in self.traces.iter().enumerate() {
            events.extend(rec.chrome_events(i));
        }
        chrome_document(events)
    }

    /// Fleet metrics document mirroring
    /// `ShardedServer::metrics_snapshot`: merged serve roll-up plus
    /// the per-shard engine snapshots under one schema tag.
    pub fn metrics_json(&self) -> Json {
        let merged = self.merged();
        Json::Obj(
            [
                (
                    "schema".to_string(),
                    Json::Str("ft2000.metrics.sharded.v1".to_string()),
                ),
                (
                    "serve".to_string(),
                    report_json(
                        &merged.stats,
                        merged.cache_hits,
                        merged.cache_misses,
                        self.duration_s,
                    ),
                ),
                ("shards".to_string(), Json::Arr(self.metrics.clone())),
            ]
            .into_iter()
            .collect(),
        )
    }
}

/// Sharded virtual-time replay: the generated request stream is
/// routed to `shards` virtual panels by a [`ShardPlacement`] built
/// from the workload's popularity weights (hot matrices replicated,
/// cold ones homed), and each shard replays its sub-stream on its own
/// engine view (shared registry, private plan cache) in parallel
/// virtual time. The A/B against `replay` (one global server) is the
/// point: same traffic, topology-aware vs topology-blind serving.
#[allow(clippy::too_many_arguments)]
pub fn replay_sharded(
    registry: Arc<MatrixRegistry>,
    planner: &Planner,
    plan_cfg: &PlanConfig,
    ids: &[usize],
    spec: &WorkloadSpec,
    cfg: &ReplayConfig,
    shards: usize,
    policy: PlacementPolicy,
) -> Result<ShardedReplayReport> {
    ensure!(!ids.is_empty(), "no matrices registered to serve");
    ensure!(spec.requests > 0, "empty workload");
    for &id in ids {
        ensure!(registry.get(id).is_some(), "unknown registry id {id}");
    }
    let shards = shards.max(1);
    let weights: Vec<f64> =
        (0..ids.len()).map(|rank| spec.popularity.weight(rank)).collect();
    let placement = ShardPlacement::build(ids, &weights, shards, policy);
    let reqs = spec.generate(ids.len());
    let mut per_shard: Vec<Vec<GenRequest>> = vec![Vec::new(); shards];
    // Replicated (and unknown) matrices round-robin on their own
    // counter — counting homed traffic too would alias periodic hot
    // requests onto one shard.
    let mut rr_hot = 0usize;
    for r in &reqs {
        let shard = match placement.home(ids[r.matrix_idx]) {
            Some(s) => s,
            None => {
                let s = rr_hot % shards;
                rr_hot += 1;
                s
            }
        };
        per_shard[shard].push(*r);
    }
    // Closed loop: split the client population across the non-empty
    // shards proportionally to their traffic share, preserving the
    // total when `clients >= non-empty shards` (below that each
    // active shard still needs one virtual client to make progress,
    // which inflates modeled concurrency — unavoidable in a
    // split-population model).
    let clients_per: Vec<usize> = match spec.arrivals {
        Arrivals::Closed { clients } => {
            let active: Vec<usize> = (0..shards)
                .filter(|&s| !per_shard[s].is_empty())
                .collect();
            let mut by_size = active.clone();
            by_size.sort_by_key(|&s| {
                (std::cmp::Reverse(per_shard[s].len()), s)
            });
            let clients = clients.max(1);
            let (base, rem) = if active.is_empty() {
                (0, 0)
            } else {
                (clients / active.len(), clients % active.len())
            };
            let mut per = vec![0usize; shards];
            for (rank, &s) in by_size.iter().enumerate() {
                per[s] = (base + usize::from(rank < rem)).max(1);
            }
            per
        }
        _ => vec![0; shards],
    };
    let topo = Topology::ft2000plus();
    let mut out = Vec::with_capacity(shards);
    let mut cores = Vec::with_capacity(shards);
    let mut traces = Vec::new();
    let mut metrics = Vec::with_capacity(shards);
    let fleet_scaling = ScalingProfiler::new();
    let mut makespan = 0.0f64;
    for (s, sub) in per_shard.iter().enumerate() {
        let shard_cores = panel_core_range(&topo, s, shards);
        cores.push(shard_cores);
        let engine = if cfg.pooled && cfg.execute {
            ServeEngine::shared_pinned(
                registry.clone(),
                planner.clone(),
                plan_cfg.clone(),
                shard_cores,
            )
        } else if cfg.pooled {
            // Model-only pooled replay: plan panel-wide exactly like
            // the pinned engine (the width is what the cost model
            // sees), but skip spawning a resident pool no kernel
            // will ever run on.
            let mut wide = plan_cfg.clone();
            wide.n_threads =
                shard_cores.1.saturating_sub(shard_cores.0).max(1);
            ServeEngine::shared(registry.clone(), planner.clone(), wide)
        } else {
            ServeEngine::shared(
                registry.clone(),
                planner.clone(),
                plan_cfg.clone(),
            )
        };
        // Harness-built engines tune on the deterministic virtual
        // clock, thread-bounded by their panel core range — the
        // shard's tuner can never plan past its own panel.
        let engine = match cfg.tune {
            Some(mut tc) => {
                tc.wall_clock = false;
                engine.with_tuner(tc.bounded_to_cores(shard_cores))
            }
            None => engine,
        };
        // Traced shards carry a virtual-clock recorder the replay
        // loops advance; lane 0 is the dispatcher, lanes 1..=W the
        // shard pool's workers (when kernels really execute).
        let trace = cfg.trace.filter(|t| t.enabled).map(|t| {
            Arc::new(TraceRecorder::new(
                t,
                ClockMode::Virtual,
                shard_cores.1.saturating_sub(shard_cores.0) + 1,
            ))
        });
        let engine = match &trace {
            Some(rec) => engine.with_trace(rec.clone()),
            None => engine,
        };
        if let Some(rec) = trace {
            traces.push(rec);
        }
        let duration_s = if sub.is_empty() {
            0.0
        } else {
            let mut d = Dispatcher {
                engine: &engine,
                ids,
                execute: cfg.execute,
                inputs: HashMap::new(),
            };
            match spec.arrivals {
                Arrivals::Closed { .. } => {
                    replay_closed(&mut d, sub, clients_per[s], cfg)
                }
                _ => replay_open(&mut d, sub, cfg),
            }
        };
        makespan = makespan.max(duration_s);
        let stats = engine.telemetry.snapshot();
        let (cache_hits, cache_misses) = engine.plans.stats();
        metrics.push(engine.metrics_snapshot());
        fleet_scaling.merge_from(engine.scaling());
        out.push(ReplayReport {
            stats,
            cache_hits,
            cache_misses,
            duration_s,
            matrices: ids.len(),
            autotune: engine.tuner().map(|t| t.summaries()),
        });
    }
    let mut merged_stats = ServeStats::default();
    for r in &out {
        merged_stats.merge(&r.stats);
    }
    let scaling =
        fleet_scaling.snapshot(&ServeEngine::queue_wait_summary(&merged_stats));
    Ok(ShardedReplayReport {
        shards: out,
        cores,
        duration_s: makespan,
        traces,
        metrics,
        scaling,
    })
}

/// Open-loop replay: arrivals are fixed by the workload; one virtual
/// server batches what has queued while it was busy (plus the batch
/// window) and coalesces on the head request's matrix.
fn replay_open(
    d: &mut Dispatcher,
    reqs: &[GenRequest],
    cfg: &ReplayConfig,
) -> f64 {
    let n = reqs.len();
    let max_batch = cfg.max_batch.max(1);
    let cap = cfg.queue_cap;
    let rec = d.engine.trace().cloned();
    let mut i = 0usize; // next arrival to admit
    let mut queue: VecDeque<usize> = VecDeque::new();
    let mut t = 0.0f64; // server-free time
    let mut makespan = 0.0f64;
    while i < n || !queue.is_empty() {
        if queue.is_empty() {
            // Idle server: jump to the next arrival.
            t = t.max(reqs[i].arrival_s);
        }
        while i < n && reqs[i].arrival_s <= t {
            if cap > 0 && queue.len() >= cap {
                d.engine.telemetry.record_rejected(1);
            } else {
                queue.push_back(i);
            }
            if let Some(rec) = &rec {
                // Instantaneous admission decision at arrival time.
                rec.set_virtual_s(reqs[i].arrival_s);
                rec.record_elapsed(0, Stage::Admission, SCHED_NONE, 0.0);
            }
            i += 1;
        }
        // Hold the batch window, admitting late concurrent arrivals.
        let t_dispatch = t + cfg.batch_window_s;
        while i < n && reqs[i].arrival_s <= t_dispatch {
            if cap > 0 && queue.len() >= cap {
                d.engine.telemetry.record_rejected(1);
            } else {
                queue.push_back(i);
            }
            if let Some(rec) = &rec {
                rec.set_virtual_s(reqs[i].arrival_s);
                rec.record_elapsed(0, Stage::Admission, SCHED_NONE, 0.0);
            }
            i += 1;
        }
        // The admit loop above pushed at least one entry.
        // lint:allow(no-unwrap)
        let head = queue.pop_front().expect("non-empty after admit");
        let mid = reqs[head].matrix_idx;
        let mut batch = vec![head];
        let mut rest = VecDeque::with_capacity(queue.len());
        for k in queue.drain(..) {
            if reqs[k].matrix_idx == mid && batch.len() < max_batch {
                batch.push(k);
            } else {
                rest.push_back(k);
            }
        }
        queue = rest;
        // Queue wait ends at dispatch: stamp it (and the virtual
        // clock the engine's own spans will read) before running.
        if let Some(rec) = &rec {
            rec.set_virtual_s(t_dispatch);
        }
        for &k in &batch {
            let wait_ms = (t_dispatch - reqs[k].arrival_s).max(0.0) * 1e3;
            d.engine.telemetry.record_queue_wait_ms(wait_ms);
            if let Some(rec) = &rec {
                rec.record_elapsed(
                    0,
                    Stage::QueueWait,
                    SCHED_NONE,
                    wait_ms * 1e3,
                );
            }
        }
        let disp = d.run(mid, batch.len());
        let service_s =
            cfg.cost.service_s(disp.nnz, batch.len(), disp.threads);
        let comps = cfg.cost.components(disp.nnz, batch.len(), disp.threads);
        d.attribute(&disp, batch.len(), &comps);
        d.feedback(&disp, service_s, batch.len(), &comps);
        let completion = t_dispatch + service_s;
        if let Some(rec) = &rec {
            rec.set_virtual_s(completion);
            // Executed replays get real kernel spans from the engine;
            // the model path records the modeled span instead.
            if !d.execute {
                rec.record_elapsed(
                    0,
                    Stage::Kernel,
                    rec.kernel_ctx(),
                    service_s * 1e6,
                );
            }
        }
        for &k in &batch {
            d.engine.telemetry.record_latency_ms(
                (completion - reqs[k].arrival_s) * 1e3,
            );
        }
        t = completion;
        makespan = completion;
    }
    makespan
}

/// Closed-loop replay: `clients` clients each keep one request
/// outstanding, re-issuing the moment it completes; the matrix
/// sequence is consumed in issue order. Concurrency, not an arrival
/// rate, sets the load — batches form naturally once clients exceed
/// one.
fn replay_closed(
    d: &mut Dispatcher,
    reqs: &[GenRequest],
    clients: usize,
    cfg: &ReplayConfig,
) -> f64 {
    let n = reqs.len();
    let max_batch = cfg.max_batch.max(1);
    let rec = d.engine.trace().cloned();
    let mut seq = 0usize; // next matrix assignment
    // Per client: Some((issue_time, matrix_idx)) while a request is
    // outstanding.
    let mut outstanding: Vec<Option<(f64, usize)>> = Vec::new();
    for _ in 0..clients.min(n) {
        outstanding.push(Some((0.0, reqs[seq].matrix_idx)));
        seq += 1;
        if let Some(rec) = &rec {
            // Client issue = admission on the virtual timeline.
            rec.set_virtual_s(0.0);
            rec.record_elapsed(0, Stage::Admission, SCHED_NONE, 0.0);
        }
    }
    let mut t_free = 0.0f64;
    let mut completed = 0usize;
    while completed < n {
        let earliest = outstanding
            .iter()
            .flatten()
            .map(|o| o.0)
            .fold(f64::INFINITY, f64::min);
        let t_start = t_free.max(earliest);
        // FIFO among requests issued by t_start (ties by client id).
        let mut waiting: Vec<(f64, usize, usize)> = outstanding
            .iter()
            .enumerate()
            .filter_map(|(c, o)| o.map(|(ti, m)| (ti, c, m)))
            .filter(|&(ti, _, _)| ti <= t_start)
            .collect();
        waiting.sort_by(|a, b| {
            a.0.partial_cmp(&b.0)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.1.cmp(&b.1))
        });
        let mid = waiting[0].2;
        let batch: Vec<(f64, usize)> = waiting
            .iter()
            .filter(|&&(_, _, m)| m == mid)
            .take(max_batch)
            .map(|&(ti, c, _)| (ti, c))
            .collect();
        // Queue wait ends when service starts; the engine's own
        // spans read the virtual clock set here.
        if let Some(rec) = &rec {
            rec.set_virtual_s(t_start);
        }
        for &(issue, _) in &batch {
            let wait_ms = (t_start - issue).max(0.0) * 1e3;
            d.engine.telemetry.record_queue_wait_ms(wait_ms);
            if let Some(rec) = &rec {
                rec.record_elapsed(
                    0,
                    Stage::QueueWait,
                    SCHED_NONE,
                    wait_ms * 1e3,
                );
            }
        }
        let disp = d.run(mid, batch.len());
        let service_s =
            cfg.cost.service_s(disp.nnz, batch.len(), disp.threads);
        let comps = cfg.cost.components(disp.nnz, batch.len(), disp.threads);
        d.attribute(&disp, batch.len(), &comps);
        d.feedback(&disp, service_s, batch.len(), &comps);
        let completion = t_start + service_s;
        if let Some(rec) = &rec {
            rec.set_virtual_s(completion);
            if !d.execute {
                rec.record_elapsed(
                    0,
                    Stage::Kernel,
                    rec.kernel_ctx(),
                    service_s * 1e6,
                );
            }
        }
        for &(issue, c) in &batch {
            d.engine
                .telemetry
                .record_latency_ms((completion - issue) * 1e3);
            completed += 1;
            outstanding[c] = if seq < n {
                let m = reqs[seq].matrix_idx;
                seq += 1;
                if let Some(rec) = &rec {
                    // Re-issue: the next admission lands at this
                    // completion time.
                    rec.record_elapsed(0, Stage::Admission, SCHED_NONE, 0.0);
                }
                Some((completion, m))
            } else {
                None
            };
        }
        t_free = completion;
    }
    t_free
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::generators;
    use crate::service::{
        MatrixRegistry, PlanConfig, Planner, Popularity, ServeEngine,
        WorkloadSpec,
    };
    use crate::util::rng::Pcg32;

    fn fresh_engine() -> (ServeEngine, Vec<usize>) {
        let mut rng = Pcg32::new(0xAB1E);
        let mut reg = MatrixRegistry::new();
        let ids = vec![
            reg.register("banded", generators::banded(256, 4, &mut rng)),
            reg.register(
                "random",
                generators::random_uniform(256, 6, &mut rng),
            ),
            reg.register(
                "skewed",
                generators::dense_row_block(256, 2048, &mut rng),
            ),
        ];
        (
            ServeEngine::new(reg, Planner::Heuristic, PlanConfig::default()),
            ids,
        )
    }

    fn zipf_spec(requests: usize) -> WorkloadSpec {
        WorkloadSpec {
            requests,
            popularity: Popularity::Zipf { s: 1.2 },
            arrivals: Arrivals::Open { rate: 20_000.0 },
            seed: 0x5EED,
        }
    }

    #[test]
    fn open_loop_replay_serves_everything() {
        let (engine, ids) = fresh_engine();
        let report = replay(
            &engine,
            &ids,
            &zipf_spec(400),
            &ReplayConfig::default(),
        )
        .unwrap();
        assert_eq!(report.stats.requests, 400);
        assert_eq!(report.stats.latencies_ms.len(), 400);
        assert!(report.duration_s > 0.0);
        assert!(report.throughput_rps() > 0.0);
        assert!(report.hit_rate() > 0.0, "repeated matrices must hit");
        assert!(report.cache_misses as usize <= ids.len());
        assert!(
            report.stats.mean_batch() > 1.0,
            "20k req/s against a 200 us batch window must coalesce: {}",
            report.stats.mean_batch()
        );
        let p50 = report.stats.latency_percentile(50.0);
        let p99 = report.stats.latency_percentile(99.0);
        assert!(p50 > 0.0 && p99 >= p50);
    }

    #[test]
    fn replay_is_deterministic_across_fresh_engines() {
        let run = || {
            let (engine, ids) = fresh_engine();
            let cfg =
                ReplayConfig { execute: false, ..ReplayConfig::default() };
            replay(&engine, &ids, &zipf_spec(300), &cfg).unwrap()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.stats.batches, b.stats.batches);
        assert_eq!(a.duration_s.to_bits(), b.duration_s.to_bits());
        assert_eq!(a.cache_hits, b.cache_hits);
        for (x, y) in a.stats.latencies_ms.iter().zip(&b.stats.latencies_ms)
        {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // Executing the kernels must not change the virtual timeline.
        let (engine, ids) = fresh_engine();
        let c = replay(
            &engine,
            &ids,
            &zipf_spec(300),
            &ReplayConfig::default(),
        )
        .unwrap();
        assert_eq!(a.duration_s.to_bits(), c.duration_s.to_bits());
        assert_eq!(a.stats.batches, c.stats.batches);
    }

    #[test]
    fn closed_loop_batches_with_many_clients() {
        let (engine, ids) = fresh_engine();
        let spec = WorkloadSpec {
            requests: 300,
            popularity: Popularity::Zipf { s: 1.4 },
            arrivals: Arrivals::Closed { clients: 12 },
            seed: 0x5EED,
        };
        let report =
            replay(&engine, &ids, &spec, &ReplayConfig::default()).unwrap();
        assert_eq!(report.stats.requests, 300);
        assert!(
            report.stats.mean_batch() > 1.5,
            "12 closed-loop clients must coalesce: {}",
            report.stats.mean_batch()
        );
        assert!(report.hit_rate() > 0.5);
    }

    #[test]
    fn cost_model_rewards_batching() {
        let cm = CostModel::default();
        let per_req_1 = cm.service_s(100_000, 1, 4);
        let per_req_8 = cm.service_s(100_000, 8, 4) / 8.0;
        assert!(
            per_req_8 < per_req_1 / 2.0,
            "batch of 8 must amortize: {per_req_8} vs {per_req_1}"
        );
        // Monotone in batch size.
        assert!(cm.service_s(1000, 9, 4) > cm.service_s(1000, 8, 4));
    }

    #[test]
    fn cost_model_has_a_thread_knee() {
        // The paper's plateau: the kernel term stops scaling at
        // sat_threads while the sync term keeps growing, so latency
        // is not monotone in the thread count — there is a knee for
        // the autotuner to find.
        let cm = CostModel::default();
        let lat = |t| cm.service_s(200_000, 1, t);
        assert!(
            lat(cm.sat_threads) < lat(cm.sat_threads * 4),
            "past saturation, more threads must cost more"
        );
        // And for tiny matrices even the static 4-thread default
        // loses to a single thread (sync dominates the kernel).
        assert!(cm.service_s(1_000, 1, 1) < cm.service_s(1_000, 1, 4));
    }

    #[test]
    fn tuned_model_replay_promotes_below_static_width() {
        use crate::autotune::AutotuneConfig;

        // Closed loop with one client: every dispatch is a singleton,
        // so arm observations measure the thread knee with no
        // batch-amortization mixing — the cleanest convergence signal.
        let spec = WorkloadSpec {
            requests: 800,
            popularity: Popularity::Zipf { s: 1.2 },
            arrivals: Arrivals::Closed { clients: 1 },
            seed: 0x5EED,
        };
        let run = || {
            let (engine, ids) = fresh_engine();
            let engine = engine.with_tuner(AutotuneConfig {
                wall_clock: false,
                ..AutotuneConfig::default()
            });
            let cfg =
                ReplayConfig { execute: false, ..ReplayConfig::default() };
            replay(&engine, &ids, &spec, &cfg).unwrap()
        };
        let (a, b) = (run(), run());
        let summaries = a.autotune.as_ref().expect("tuned run reports");
        assert!(!summaries.is_empty());
        assert!(
            summaries.iter().any(|s| s.promotions >= 1),
            "warmed tuners must promote at least once"
        );
        // The corpus is small matrices: dispatch + sync dominate, so
        // the knee sits below the static 4-thread pick — and the
        // tuned mean must not be worse than the static arm's.
        let s = summaries
            .iter()
            .find(|s| s.diverged())
            .expect("at least one matrix tunes away from static");
        assert!(
            s.chosen_variant.n_threads < s.static_variant.n_threads,
            "{:?} vs static {:?}",
            s.chosen_variant,
            s.static_variant
        );
        assert!(
            s.chosen_mean_ms <= s.static_mean_ms,
            "tuned {} ms vs static {} ms",
            s.chosen_mean_ms,
            s.static_mean_ms
        );
        // Tuning decisions ride the virtual clock: bit-reproducible.
        assert_eq!(a.duration_s.to_bits(), b.duration_s.to_bits());
        let sb = b.autotune.as_ref().unwrap();
        assert_eq!(summaries.len(), sb.len());
        for (x, y) in summaries.iter().zip(sb) {
            assert_eq!(x.chosen_variant, y.chosen_variant);
            assert_eq!(x.promotions, y.promotions);
            assert_eq!(x.observations, y.observations);
        }
        // The JSON report carries the tuning block.
        assert!(a.to_json().get("autotune").is_some());
    }

    #[test]
    fn tuned_sharded_replay_reports_per_shard_tuning() {
        use std::sync::Arc;

        use crate::autotune::AutotuneConfig;
        use crate::service::shard::PlacementPolicy;

        let mut rng = Pcg32::new(0xAB1E);
        let mut reg = MatrixRegistry::new();
        let ids = vec![
            reg.register("banded", generators::banded(256, 4, &mut rng)),
            reg.register(
                "random",
                generators::random_uniform(256, 6, &mut rng),
            ),
            reg.register(
                "skewed",
                generators::dense_row_block(256, 2048, &mut rng),
            ),
        ];
        let cfg = ReplayConfig {
            execute: false,
            tune: Some(AutotuneConfig::default()),
            ..ReplayConfig::default()
        };
        // Two closed-loop clients split over the active shards keep
        // every dispatch a singleton (clean knee observations).
        let spec = WorkloadSpec {
            requests: 800,
            popularity: Popularity::Zipf { s: 1.2 },
            arrivals: Arrivals::Closed { clients: 2 },
            seed: 0x5EED,
        };
        let report = replay_sharded(
            Arc::new(reg),
            &Planner::Heuristic,
            &PlanConfig::default(),
            &ids,
            &spec,
            &cfg,
            4,
            PlacementPolicy::HotReplicate { hot: 1 },
        )
        .unwrap();
        let merged = report.merged();
        assert_eq!(merged.stats.requests, 800);
        let summaries = merged.autotune.as_ref().expect("tuned shards");
        assert!(!summaries.is_empty());
        // Panel-bounded ladders: no tuner may choose past its panel
        // core range (4 shards over 8 panels = 16 cores each).
        for s in summaries {
            assert!(
                s.chosen_variant.n_threads <= 16,
                "{:?} exceeds the panel bound",
                s.chosen_variant
            );
        }
        assert!(
            summaries.iter().any(|s| s.promotions >= 1),
            "sharded tuners must promote on this corpus"
        );
    }

    #[test]
    fn traced_model_replay_covers_every_stage() {
        use crate::autotune::AutotuneConfig;
        use crate::obs::TraceConfig;

        let spec = zipf_spec(300);
        let cfg = ReplayConfig { execute: false, ..ReplayConfig::default() };
        let tuned = || {
            let (engine, ids) = fresh_engine();
            let engine = engine.with_tuner(AutotuneConfig {
                wall_clock: false,
                ..AutotuneConfig::default()
            });
            (engine, ids)
        };
        // Untraced baseline timeline.
        let (engine, ids) = tuned();
        let base = replay(&engine, &ids, &spec, &cfg).unwrap();

        let (engine, ids) = tuned();
        let rec = Arc::new(TraceRecorder::new(
            TraceConfig::on(),
            ClockMode::Virtual,
            1,
        ));
        let engine = engine.with_trace(rec.clone());
        let report = replay(&engine, &ids, &spec, &cfg).unwrap();
        // Tracing must not perturb the deterministic timeline.
        assert_eq!(
            report.duration_s.to_bits(),
            base.duration_s.to_bits(),
            "tracing changed the virtual timeline"
        );
        // Queue wait is digested for every served request.
        assert_eq!(report.stats.queue_wait.count, 300);
        // The export is valid JSON and names all seven stage tags.
        let doc = rec.export_chrome();
        let parsed = crate::util::json::parse(&doc.to_string())
            .expect("chrome export must be parseable JSON");
        let events =
            parsed.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(!events.is_empty());
        let names: std::collections::BTreeSet<&str> = events
            .iter()
            .filter_map(|e| e.get("name").and_then(Json::as_str))
            .collect();
        for stage in Stage::all() {
            assert!(
                names.contains(stage.name()),
                "stage {} missing from the trace",
                stage.name()
            );
        }
        // Spans sit on the virtual timeline, inside the makespan
        // (durations are wall-measured, so starts may dip slightly
        // below zero on the very first dispatches).
        let limit = report.duration_s * 1e6 + 1.0;
        for e in events {
            let ts = e.get("ts").unwrap().as_f64().unwrap();
            assert!(ts <= limit, "span ts {ts} past the makespan");
        }
    }

    #[test]
    fn traced_sharded_replay_exports_merged_documents() {
        use std::sync::Arc;

        use crate::obs::TraceConfig;
        use crate::service::shard::PlacementPolicy;

        let mut rng = Pcg32::new(0xAB1E);
        let mut reg = MatrixRegistry::new();
        let ids = vec![
            reg.register("banded", generators::banded(256, 4, &mut rng)),
            reg.register(
                "random",
                generators::random_uniform(256, 6, &mut rng),
            ),
            reg.register(
                "skewed",
                generators::dense_row_block(256, 2048, &mut rng),
            ),
        ];
        let cfg = ReplayConfig {
            execute: false,
            trace: Some(TraceConfig::on()),
            ..ReplayConfig::default()
        };
        let report = replay_sharded(
            Arc::new(reg),
            &Planner::Heuristic,
            &PlanConfig::default(),
            &ids,
            &zipf_spec(400),
            &cfg,
            4,
            PlacementPolicy::HotReplicate { hot: 1 },
        )
        .unwrap();
        assert_eq!(report.traces.len(), 4, "one recorder per shard");
        assert_eq!(report.metrics.len(), 4, "one snapshot per shard");
        // One merged Chrome document; pid identifies the shard.
        let doc = report.export_chrome();
        let events =
            doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        assert!(!events.is_empty());
        let pids: std::collections::BTreeSet<usize> = events
            .iter()
            .map(|e| e.get("pid").and_then(Json::as_usize).unwrap())
            .collect();
        assert!(pids.len() >= 2, "several shards must contribute spans");
        // Fleet metrics document wraps the per-shard snapshots.
        let m = report.metrics_json();
        assert_eq!(
            m.get("schema").and_then(Json::as_str),
            Some("ft2000.metrics.sharded.v1")
        );
        assert_eq!(
            m.get("shards").and_then(Json::as_arr).map(|a| a.len()),
            Some(4)
        );
        assert!(m.get("serve").and_then(|s| s.get("requests")).is_some());
        // Queue wait flows into the merged digest under replay too.
        assert_eq!(report.merged().stats.queue_wait.count, 400);
    }

    #[test]
    fn bounded_virtual_queue_sheds_overload() {
        let (engine, ids) = fresh_engine();
        // Absurd arrival rate against a tiny admission bound: most of
        // the stream must be rejected, the rest served normally.
        let spec = WorkloadSpec {
            requests: 500,
            popularity: Popularity::Zipf { s: 1.2 },
            arrivals: Arrivals::Open { rate: 10_000_000.0 },
            seed: 0x5EED,
        };
        let cfg = ReplayConfig {
            queue_cap: 4,
            execute: false,
            ..ReplayConfig::default()
        };
        let report = replay(&engine, &ids, &spec, &cfg).unwrap();
        assert!(report.stats.rejected > 0, "cap 4 must reject");
        assert_eq!(
            report.stats.requests + report.stats.rejected,
            500,
            "every request either served or rejected"
        );
        // Unbounded default still serves everything.
        let (engine2, ids2) = fresh_engine();
        let cfg = ReplayConfig { execute: false, ..ReplayConfig::default() };
        let r2 = replay(&engine2, &ids2, &spec, &cfg).unwrap();
        assert_eq!(r2.stats.rejected, 0);
        assert_eq!(r2.stats.requests, 500);
    }

    #[test]
    fn sharded_replay_serves_everything_deterministically() {
        use std::sync::Arc;

        use crate::service::shard::PlacementPolicy;

        let run = || {
            let mut rng = Pcg32::new(0xAB1E);
            let mut reg = MatrixRegistry::new();
            let ids = vec![
                reg.register("banded", generators::banded(256, 4, &mut rng)),
                reg.register(
                    "random",
                    generators::random_uniform(256, 6, &mut rng),
                ),
                reg.register(
                    "skewed",
                    generators::dense_row_block(256, 2048, &mut rng),
                ),
            ];
            let cfg =
                ReplayConfig { execute: false, ..ReplayConfig::default() };
            replay_sharded(
                Arc::new(reg),
                &Planner::Heuristic,
                &PlanConfig::default(),
                &ids,
                &zipf_spec(400),
                &cfg,
                8,
                PlacementPolicy::HotReplicate { hot: 1 },
            )
            .unwrap()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.shards.len(), 8);
        let merged = a.merged();
        assert_eq!(merged.stats.requests, 400, "no request lost in routing");
        assert_eq!(merged.stats.rejected, 0);
        assert!(a.duration_s > 0.0);
        // Hot matrix 0 (zipf head) is replicated: several shards see it.
        let shards_with_head = a
            .shards
            .iter()
            .filter(|r| r.stats.per_matrix.contains_key(&0))
            .count();
        assert!(
            shards_with_head >= 4,
            "replicated head on {shards_with_head} shards only"
        );
        // Deterministic: same seed, same timeline, bit for bit.
        assert_eq!(a.duration_s.to_bits(), b.duration_s.to_bits());
        for (x, y) in a.shards.iter().zip(&b.shards) {
            assert_eq!(x.stats.batches, y.stats.batches);
            assert_eq!(x.duration_s.to_bits(), y.duration_s.to_bits());
        }
        // The merged JSON carries the per-shard array.
        let j = a.to_json();
        assert_eq!(j.get("requests").unwrap().as_usize(), Some(400));
        assert_eq!(
            j.get("shards").unwrap().as_arr().map(|a| a.len()),
            Some(8)
        );
    }

    #[test]
    fn replay_rejects_bad_input() {
        let (engine, _) = fresh_engine();
        assert!(replay(
            &engine,
            &[],
            &zipf_spec(10),
            &ReplayConfig::default()
        )
        .is_err());
        assert!(replay(
            &engine,
            &[99],
            &zipf_spec(10),
            &ReplayConfig::default()
        )
        .is_err());
    }
}
