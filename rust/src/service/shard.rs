//! Sharded, panel-aware serving — the topology-conscious request path.
//!
//! The paper's core scalability finding is that FT-2000+ SpMV stops
//! scaling the moment threads cross one of the chip's 8 NUMA panels:
//! memory traffic that leaves the local panel pays DCU hops and
//! remote-DRAM latency. A serving engine built around one global
//! queue and one undifferentiated worker pool is exactly that
//! anti-pattern — every worker touches every matrix, so the working
//! set sprays across all panels. This module shards the engine the
//! way the chip is sharded:
//!
//! * one shard per modeled panel (default 8, like FT-2000+), each
//!   with its own bounded [`RequestQueue`], pinned worker set
//!   (modeled via [`crate::sched::panel_core_range`]), and its own
//!   [`PlanCache`] + [`Telemetry`] view — no cross-shard locks on the
//!   hot path;
//! * a [`ShardPlacement`] policy that routes matrices to shards by
//!   popularity/size: hot matrices are replicated across all shards
//!   (they would overload any single panel), cold ones are homed to
//!   exactly one shard by weighted bin packing (their CSR stays in
//!   one panel's DRAM domain);
//! * an admission controller: bounded per-shard queues reject excess
//!   load ([`Admitted::Rejected`], counted in telemetry), and an
//!   optional per-request deadline sheds stale backlog at pop time —
//!   overload degrades throughput, it never panics the server;
//! * shard failover: a shard marked dark ([`ShardedServer::
//!   set_shard_down`]) gets its homed matrices re-placed onto the
//!   survivors ([`ShardPlacement::reassign_plan`], deterministic),
//!   traffic re-routes around the outage (counted in the fleet's
//!   health ledger), and [`ShardedServer::submit_with_retry`] gives
//!   producers a bounded-budget, jitter-backoff re-admission path.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::ordatomic::OrdAtomicUsize;

use crate::autotune::AutotuneConfig;
use crate::obs::scaling::ScalingProfiler;
use crate::obs::{
    chrome_document, ClockMode, Stage, TraceConfig, TraceRecorder,
};
use crate::resil::decorrelated_jitter;
use crate::resil::health::{DegradedMode, HealthTracker};
use crate::sched::panel_core_range;
use crate::sim::topology::Topology;
use crate::util::json::Json;
use crate::util::rng::Pcg32;

use super::batch::{drain_worker, PushError, Request, RequestQueue};
use super::plan::{PlanConfig, Planner};
use super::registry::MatrixRegistry;
use super::telemetry::{ServeStats, ShardSnapshot};
use super::ServeEngine;

/// How matrices are assigned to shards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// Every matrix homed to exactly one shard (weighted bin packing:
    /// heaviest matrices first onto the lightest shard).
    Home,
    /// The `hot` heaviest matrices replicated on every shard
    /// (round-robin routed); the rest homed as in [`Self::Home`].
    HotReplicate { hot: usize },
}

#[derive(Clone, Copy, Debug)]
enum Assignment {
    Replicated,
    Homed(usize),
}

/// The materialized matrix -> shard map.
#[derive(Clone, Debug)]
pub struct ShardPlacement {
    shards: usize,
    assignment: HashMap<usize, Assignment>,
}

impl ShardPlacement {
    /// Build the placement for `ids` with per-matrix weights (request
    /// mass, bytes, ... — only the ordering matters). Deterministic:
    /// ties break on the lower matrix id.
    pub fn build(
        ids: &[usize],
        weights: &[f64],
        shards: usize,
        policy: PlacementPolicy,
    ) -> ShardPlacement {
        assert_eq!(ids.len(), weights.len(), "one weight per matrix");
        let shards = shards.max(1);
        let mut ranked: Vec<(usize, f64)> =
            ids.iter().copied().zip(weights.iter().copied()).collect();
        ranked.sort_by(|a, b| {
            b.1.partial_cmp(&a.1)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.0.cmp(&b.0))
        });
        let hot = match policy {
            PlacementPolicy::Home => 0,
            PlacementPolicy::HotReplicate { hot } => hot.min(ranked.len()),
        };
        let mut assignment = HashMap::with_capacity(ranked.len());
        for &(id, _) in ranked.iter().take(hot) {
            assignment.insert(id, Assignment::Replicated);
        }
        // Weighted bin packing for the cold tail: heaviest first onto
        // the currently lightest shard.
        let mut load = vec![0.0f64; shards];
        for &(id, w) in ranked.iter().skip(hot) {
            let s = (0..shards)
                .min_by(|&a, &b| {
                    load[a]
                        .partial_cmp(&load[b])
                        .unwrap_or(std::cmp::Ordering::Equal)
                })
                .unwrap_or(0);
            load[s] += w.max(0.0);
            assignment.insert(id, Assignment::Homed(s));
        }
        ShardPlacement { shards, assignment }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// The shard a request against `matrix_id` is routed to. `salt`
    /// spreads replicated (and unknown) matrices round-robin; homed
    /// matrices always land on their home shard.
    pub fn route(&self, matrix_id: usize, salt: usize) -> usize {
        match self.assignment.get(&matrix_id) {
            Some(Assignment::Homed(s)) => *s,
            // Unknown ids still get a shard — the shard's executor
            // rejects them as an error outcome, never a panic.
            Some(Assignment::Replicated) | None => salt % self.shards,
        }
    }

    pub fn is_replicated(&self, matrix_id: usize) -> bool {
        matches!(
            self.assignment.get(&matrix_id),
            Some(Assignment::Replicated)
        )
    }

    /// The home shard of a non-replicated matrix.
    pub fn home(&self, matrix_id: usize) -> Option<usize> {
        match self.assignment.get(&matrix_id) {
            Some(Assignment::Homed(s)) => Some(*s),
            _ => None,
        }
    }

    /// Number of matrices homed to each shard.
    pub fn homed_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.shards];
        for a in self.assignment.values() {
            if let Assignment::Homed(s) = a {
                counts[*s] += 1;
            }
        }
        counts
    }

    /// Number of replicated (hot) matrices.
    pub fn replicated_count(&self) -> usize {
        self.assignment
            .values()
            .filter(|a| matches!(a, Assignment::Replicated))
            .count()
    }

    /// Failover plan for a dead shard: every matrix homed to `dead`,
    /// re-binned onto the `alive` shards (lightest current homed
    /// count first, ties on the lower shard index). Deterministic —
    /// the same outage always produces the same `(matrix, new shard)`
    /// list — and non-mutating: callers keep the overrides and drop
    /// them when the shard returns, so recovery is exactly "traffic
    /// goes home". Replicated matrices need no plan (survivors
    /// already hold them); an empty `alive` list yields an empty plan
    /// (nothing to fail over *to*).
    pub fn reassign_plan(
        &self,
        dead: usize,
        alive: &[usize],
    ) -> Vec<(usize, usize)> {
        if alive.is_empty() {
            return Vec::new();
        }
        let mut orphans: Vec<usize> = self
            .assignment
            .iter()
            .filter_map(|(id, a)| match a {
                Assignment::Homed(s) if *s == dead => Some(*id),
                _ => None,
            })
            .collect();
        orphans.sort_unstable();
        let counts = self.homed_counts();
        let mut load: Vec<(usize, usize)> = alive
            .iter()
            .map(|&s| (counts.get(s).copied().unwrap_or(0), s))
            .collect();
        let mut plan = Vec::with_capacity(orphans.len());
        for id in orphans {
            load.sort_unstable();
            load[0].0 += 1;
            plan.push((id, load[0].1));
        }
        plan
    }
}

/// Knobs of the sharded server.
#[derive(Clone, Copy, Debug)]
pub struct ShardConfig {
    /// Number of shards (modeled panels). FT-2000+ has 8.
    pub shards: usize,
    /// Per-shard queue capacity; 0 = unbounded (no admission control).
    pub queue_cap: usize,
    /// Worker threads per shard. The default of 2 workers x 4 plan
    /// threads saturates one 8-core panel.
    pub workers_per_shard: usize,
    /// Largest same-matrix group one dispatch may coalesce.
    pub max_batch: usize,
    /// Shed requests older than this at pop time; 0 disables.
    pub deadline_ms: f64,
    pub policy: PlacementPolicy,
    /// Serve on a persistent per-shard executor pool, one resident
    /// worker per panel core (default). `false` falls back to
    /// per-request scoped threads — the A/B baseline and the legacy
    /// behavior.
    pub pooled: bool,
    /// Per-shard online autotuning (wall-clock fed): each shard's
    /// engine explores plan variants thread-bounded by its own panel
    /// core range and promotes winners into its private plan cache.
    pub tune: Option<AutotuneConfig>,
    /// Stage-level span tracing: each shard gets its own wall-clock
    /// [`TraceRecorder`] (one ring per pool lane), merged into a
    /// single Chrome document by [`ShardedServer::export_chrome`]
    /// with `pid` = shard index. `None` (the default) records
    /// nothing and costs nothing on the hot path.
    pub trace: Option<TraceConfig>,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig {
            shards: 8,
            queue_cap: 1024,
            workers_per_shard: 2,
            max_batch: 16,
            deadline_ms: 0.0,
            policy: PlacementPolicy::HotReplicate { hot: 2 },
            pooled: true,
            tune: None,
            trace: None,
        }
    }
}

/// Outcome of one admission attempt.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admitted {
    /// Enqueued on this shard.
    Shard(usize),
    /// Refused by this shard's admission control (queue full or
    /// closed); already counted in the shard's telemetry.
    Rejected { shard: usize },
}

impl Admitted {
    pub fn is_rejected(&self) -> bool {
        matches!(self, Admitted::Rejected { .. })
    }
}

/// One shard: its own engine view (shared registry, private plan
/// cache + telemetry + persistent executor pool when
/// [`ShardConfig::pooled`]), its own queue, its modeled panel cores.
pub struct Shard {
    pub engine: ServeEngine,
    pub queue: RequestQueue,
    /// Modeled panel core range `[c0, c1)` (see
    /// [`crate::sched::panel_core_range`]); the shard's executor pool
    /// is sized one worker per core and *modeled* as pinned there —
    /// std has no affinity API, the point is that each shard's
    /// working set (and resident worker set) stays disjoint.
    pub cores: (usize, usize),
    /// This shard's span recorder when [`ShardConfig::trace`] is on.
    pub trace: Option<Arc<TraceRecorder>>,
}

/// The sharded serving engine.
pub struct ShardedServer {
    registry: Arc<MatrixRegistry>,
    pub shards: Vec<Shard>,
    pub placement: ShardPlacement,
    pub cfg: ShardConfig,
    rr: OrdAtomicUsize,
    /// Dark-shard bitmask (bit `s` = shard `s` is down). Advisory
    /// routing state: readers tolerate a stale value (they still land
    /// on a valid shard), so every access is Relaxed.
    down: OrdAtomicUsize,
    /// Failover overrides installed while a shard is dark:
    /// matrix id -> surviving shard. Empty whenever `down` is empty
    /// (the healthy submit path never takes this lock).
    failover: Mutex<HashMap<usize, usize>>,
    /// The router's own resilience ledger (admission failovers,
    /// bounded retries, all-dark rejections); shard engines keep
    /// their dispatch-path ledgers, [`ShardedServer::health_snapshot`]
    /// merges the fleet.
    health: HealthTracker,
    /// Router epoch for the health ledger's relative timestamps.
    t0: Instant,
}

impl ShardedServer {
    /// Build with matrices weighted by size (nnz) — the placement
    /// signal when traffic popularity is unknown.
    pub fn new(
        registry: Arc<MatrixRegistry>,
        planner: Planner,
        plan_cfg: PlanConfig,
        cfg: ShardConfig,
    ) -> Self {
        let weights: Vec<f64> =
            registry.iter().map(|e| e.csr.nnz() as f64).collect();
        Self::with_weights(registry, planner, plan_cfg, cfg, &weights)
    }

    /// Build with explicit per-matrix weights (indexed by registry
    /// id), e.g. expected request mass from a Zipf popularity model.
    pub fn with_weights(
        registry: Arc<MatrixRegistry>,
        planner: Planner,
        plan_cfg: PlanConfig,
        mut cfg: ShardConfig,
        weights: &[f64],
    ) -> Self {
        assert_eq!(
            weights.len(),
            registry.len(),
            "one weight per registered matrix"
        );
        cfg.shards = cfg.shards.max(1);
        let ids = registry.ids();
        let placement =
            ShardPlacement::build(&ids, weights, cfg.shards, cfg.policy);
        let topo = Topology::ft2000plus();
        let shards = (0..cfg.shards)
            .map(|i| {
                let cores = panel_core_range(&topo, i, cfg.shards);
                // Pooled shards get a persistent executor pool sized
                // by (and modeled-pinned to) their panel core range;
                // requests reuse those workers instead of spawning.
                let engine = if cfg.pooled {
                    ServeEngine::shared_pinned(
                        registry.clone(),
                        planner.clone(),
                        plan_cfg.clone(),
                        cores,
                    )
                } else {
                    ServeEngine::shared(
                        registry.clone(),
                        planner.clone(),
                        plan_cfg.clone(),
                    )
                };
                // Tuned shards explore within their own panel: the
                // thread ladder is clamped to the panel core range, so
                // a promotion can never plan past the cores the
                // shard's pool is pinned to.
                let engine = match cfg.tune {
                    Some(tc) => {
                        engine.with_tuner(tc.bounded_to_cores(cores))
                    }
                    None => engine,
                };
                // Traced shards carry their own wall-clock recorder:
                // lane 0 is the dispatcher, lanes 1..=W the shard's
                // pool workers (one per panel core).
                let trace = cfg.trace.filter(|t| t.enabled).map(|t| {
                    Arc::new(TraceRecorder::new(
                        t,
                        ClockMode::Wall,
                        cores.1 - cores.0 + 1,
                    ))
                });
                let engine = match &trace {
                    Some(rec) => engine.with_trace(rec.clone()),
                    None => engine,
                };
                Shard {
                    engine,
                    queue: RequestQueue::bounded(cfg.queue_cap),
                    cores,
                    trace,
                }
            })
            .collect();
        ShardedServer {
            registry,
            shards,
            placement,
            cfg,
            rr: OrdAtomicUsize::named(0, "shard.rr"),
            down: OrdAtomicUsize::named(0, "shard.down"),
            failover: Mutex::new(HashMap::new()),
            health: HealthTracker::new(),
            t0: Instant::now(),
        }
    }

    pub fn registry(&self) -> &MatrixRegistry {
        &self.registry
    }

    /// Route and enqueue one request. Replicated (and unknown)
    /// matrices round-robin on a counter that only they advance, so a
    /// periodic hot/cold interleaving in the producer cannot alias
    /// every hot request onto one shard. Rejections (bounded queue
    /// full, or closed) are counted in the owning shard's telemetry
    /// and reported — admission control, not a panic.
    pub fn submit(&self, req: Request) -> Admitted {
        let t0 = Instant::now();
        let home = match self.placement.home(req.matrix_id) {
            Some(s) => s,
            None => {
                // ord: Relaxed RMW — round-robin ticket; producers
                // only need distinct values, not ordering.
                self.rr.fetch_add(1, Ordering::Relaxed) % self.cfg.shards
            }
        };
        // ord: Relaxed load — advisory dark-shard mask; a stale read
        // still lands on a valid shard. Zero when the fleet is
        // healthy, so the hot path takes no lock.
        let mask = self.down.load(Ordering::Relaxed);
        let shard = if mask == 0 {
            home
        } else {
            // Failover overrides re-home a dark shard's matrices onto
            // survivors; the alive scan re-routes anything else still
            // pointing at darkness.
            let preferred = {
                let overrides = self
                    .failover
                    .lock()
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
                overrides.get(&req.matrix_id).copied().unwrap_or(home)
            };
            match self.first_alive(preferred, mask) {
                Some(s) => {
                    if s != home {
                        self.health.note_failed_over(1);
                    }
                    s
                }
                None => {
                    // The whole fleet is dark: a counted rejection,
                    // charged to the home shard's telemetry.
                    self.health.note_rejected(1);
                    self.shards[home].engine.telemetry.record_rejected(1);
                    return Admitted::Rejected { shard: home };
                }
            }
        };
        let admitted = match self.shards[shard].queue.try_push(req) {
            Ok(()) => Admitted::Shard(shard),
            Err(PushError::Full) | Err(PushError::Closed) => {
                self.shards[shard].engine.telemetry.record_rejected(1);
                Admitted::Rejected { shard }
            }
        };
        // Admission span (routing + enqueue/reject) on the routed
        // shard's dispatcher lane — rejections are admissions too.
        if let Some(rec) = &self.shards[shard].trace {
            rec.record_elapsed(
                0,
                Stage::Admission,
                crate::obs::trace::SCHED_NONE,
                t0.elapsed().as_secs_f64() * 1e6,
            );
        }
        admitted
    }

    /// First not-dark shard scanning from `preferred` (inclusive),
    /// wrapping; `None` when the whole fleet is dark. Shards past the
    /// mask width can never be marked down.
    fn first_alive(&self, preferred: usize, mask: usize) -> Option<usize> {
        (0..self.cfg.shards)
            .map(|k| (preferred + k) % self.cfg.shards)
            .find(|&s| {
                s >= usize::BITS as usize || mask & (1usize << s) == 0
            })
    }

    /// Whether `shard` is currently marked dark.
    pub fn is_shard_down(&self, shard: usize) -> bool {
        // ord: Relaxed load — advisory routing state (see `down`).
        shard < usize::BITS as usize
            && self.down.load(Ordering::Relaxed) & (1usize << shard) != 0
    }

    /// Mark a shard dark (outage) or bring it back. Going dark
    /// installs the deterministic failover plan
    /// ([`ShardPlacement::reassign_plan`]) as routing overrides and
    /// counts one failover per re-homed matrix; coming back clears
    /// exactly those overrides, so recovery is "traffic goes home".
    /// The router's health ledger escalates to
    /// [`DegradedMode::ReducedLanes`] while any shard is dark and
    /// recovers when the last one returns.
    pub fn set_shard_down(&self, shard: usize, down: bool) {
        if shard >= self.cfg.shards || shard >= usize::BITS as usize {
            return;
        }
        let bit = 1usize << shard;
        let mut overrides = self
            .failover
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        // ord: Relaxed load — mask writes are serialized by the
        // failover mutex held here; concurrent readers are advisory.
        let mask = self.down.load(Ordering::Relaxed);
        if down {
            if mask & bit != 0 {
                return;
            }
            // ord: Relaxed store — serialized by the failover mutex.
            self.down.store(mask | bit, Ordering::Relaxed);
            let alive: Vec<usize> = (0..self.cfg.shards)
                .filter(|&s| {
                    s != shard
                        && (s >= usize::BITS as usize
                            || (mask | bit) & (1usize << s) == 0)
                })
                .collect();
            let plan = self.placement.reassign_plan(shard, &alive);
            self.health.note_failed_over(plan.len() as u64);
            for (id, to) in plan {
                overrides.insert(id, to);
            }
            self.health.escalate(DegradedMode::ReducedLanes, self.now_ms());
        } else {
            if mask & bit == 0 {
                return;
            }
            // ord: Relaxed store — serialized by the failover mutex.
            self.down.store(mask & !bit, Ordering::Relaxed);
            overrides.retain(|id, _| self.placement.home(*id) != Some(shard));
            if mask & !bit == 0 {
                self.health.recover(self.now_ms());
            }
        }
    }

    /// Milliseconds since this router was built (the health ledger's
    /// relative clock).
    fn now_ms(&self) -> f64 {
        self.t0.elapsed().as_secs_f64() * 1e3
    }

    /// [`ShardedServer::submit`] with a bounded re-admission budget:
    /// a rejected admission is retried up to `budget` more times with
    /// decorrelated-jitter backoff (capped at 8 ms per wait), each
    /// attempt counted in the health ledger. Overload still wins —
    /// the final rejection stands once the budget is spent.
    pub fn submit_with_retry(&self, req: Request, budget: usize) -> Admitted {
        let resubmit = || Request {
            matrix_id: req.matrix_id,
            x: req.x.clone(),
            submitted: req.submitted,
        };
        let mut last = self.submit(resubmit());
        if !last.is_rejected() {
            return last;
        }
        let mut rng = Pcg32::new(0x8E7A11 ^ req.matrix_id as u64);
        let mut backoff = 1.0;
        for _attempt in 0..budget {
            backoff = decorrelated_jitter(&mut rng, backoff, 1.0, 8.0);
            if !cfg!(miri) {
                std::thread::sleep(std::time::Duration::from_secs_f64(
                    backoff / 1e3,
                ));
            }
            self.health.note_retried(1);
            last = self.submit(resubmit());
            if !last.is_rejected() {
                return last;
            }
        }
        last
    }

    /// The router's own resilience ledger (shard engines keep their
    /// dispatch-path ledgers; [`ShardedServer::health_snapshot`]
    /// merges the fleet).
    pub fn health(&self) -> &HealthTracker {
        &self.health
    }

    /// Fleet health roll-up: the router ledger merged with every
    /// shard engine's — one `ft2000.health.v1` document, the
    /// resilience counterpart of [`ShardedServer::scaling_snapshot`].
    pub fn health_snapshot(&self) -> Json {
        let fleet = HealthTracker::new();
        fleet.merge_from(&self.health);
        for s in &self.shards {
            fleet.merge_from(s.engine.health());
        }
        fleet.snapshot()
    }

    /// No more submissions; workers drain the backlogs and exit.
    pub fn close(&self) {
        for s in &self.shards {
            s.queue.close();
        }
    }

    /// Run every shard's worker set until all queues are closed and
    /// drained. Returns the number of requests served successfully
    /// (errors/shed/rejected are in the per-shard telemetry).
    pub fn serve(&self) -> usize {
        let served = OrdAtomicUsize::named(0, "shard.served");
        std::thread::scope(|s| {
            for shard in &self.shards {
                for _ in 0..self.cfg.workers_per_shard.max(1) {
                    let served = &served;
                    let cfg = self.cfg;
                    s.spawn(move || {
                        drain_worker(
                            &shard.engine,
                            &shard.queue,
                            cfg.max_batch,
                            cfg.deadline_ms,
                            served,
                        );
                    });
                }
            }
        });
        served.into_inner()
    }

    /// Per-shard report rows for [`super::telemetry::shard_table`].
    pub fn snapshots(&self, duration_s: f64) -> Vec<ShardSnapshot> {
        self.shards
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let (cache_hits, cache_misses) = s.engine.plans.stats();
                ShardSnapshot {
                    shard: i,
                    cores: s.cores,
                    stats: s.engine.telemetry.snapshot(),
                    cache_hits,
                    cache_misses,
                    duration_s,
                }
            })
            .collect()
    }

    /// Fleet roll-up of all shard stats.
    pub fn merged_stats(&self) -> ServeStats {
        let mut merged = ServeStats::default();
        for s in &self.shards {
            merged.merge(&s.engine.telemetry.snapshot());
        }
        merged
    }

    /// Total (hits, misses) across the per-shard plan caches.
    pub fn cache_totals(&self) -> (u64, u64) {
        self.shards.iter().fold((0, 0), |(h, m), s| {
            let (sh, sm) = s.engine.plans.stats();
            (h + sh, m + sm)
        })
    }

    /// Flattened per-matrix tuning summaries across all tuned shards
    /// (empty when [`ShardConfig::tune`] is off).
    pub fn autotune_summaries(&self) -> Vec<crate::autotune::TunerSummary> {
        self.shards
            .iter()
            .flat_map(|s| {
                s.engine.tuner().map(|t| t.summaries()).unwrap_or_default()
            })
            .collect()
    }

    /// (promotions, demotions) across all tuned shards.
    pub fn autotune_totals(&self) -> (u64, u64) {
        self.shards.iter().fold((0, 0), |(p, d), s| {
            match s.engine.tuner() {
                Some(t) => {
                    let (tp, td) = t.totals();
                    (p + tp, d + td)
                }
                None => (p, d),
            }
        })
    }

    /// Per-shard span recorders (empty when tracing is off).
    pub fn traces(&self) -> Vec<Arc<TraceRecorder>> {
        self.shards.iter().filter_map(|s| s.trace.clone()).collect()
    }

    /// Total spans recorded across all shard recorders.
    pub fn spans_recorded(&self) -> usize {
        self.shards
            .iter()
            .filter_map(|s| s.trace.as_ref())
            .map(|r| r.spans_recorded())
            .sum()
    }

    /// Merge every shard's spans into one Chrome `trace_event`
    /// document, `pid` = shard index so chrome://tracing groups each
    /// shard's lanes as its own process row.
    pub fn export_chrome(&self) -> Json {
        let mut events = Vec::new();
        for (i, s) in self.shards.iter().enumerate() {
            if let Some(rec) = &s.trace {
                events.extend(rec.chrome_events(i));
            }
        }
        chrome_document(events)
    }

    /// Fleet metrics document: merged serve roll-up plus every
    /// shard's unified [`ServeEngine::metrics_snapshot`] under one
    /// schema tag.
    pub fn metrics_snapshot(&self, duration_s: f64) -> Json {
        let (hits, misses) = self.cache_totals();
        let mut doc = BTreeMap::new();
        doc.insert(
            "schema".to_string(),
            Json::Str("ft2000.metrics.sharded.v1".to_string()),
        );
        doc.insert(
            "serve".to_string(),
            super::telemetry::report_json(
                &self.merged_stats(),
                hits,
                misses,
                duration_s,
            ),
        );
        doc.insert(
            "shards".to_string(),
            Json::Arr(
                self.shards
                    .iter()
                    .map(|s| s.engine.metrics_snapshot())
                    .collect(),
            ),
        );
        Json::Obj(doc)
    }

    /// Fleet scalability roll-up: every shard engine's
    /// [`ScalingProfiler`] merged into one `ft2000.scaling.v1`
    /// document, with the queue-wait summary taken from the merged
    /// stats — the sharded counterpart of
    /// [`ServeEngine::scaling_snapshot`].
    pub fn scaling_snapshot(&self) -> Json {
        let fleet = ScalingProfiler::new();
        for s in &self.shards {
            fleet.merge_from(s.engine.scaling());
        }
        fleet.snapshot(&ServeEngine::queue_wait_summary(&self.merged_stats()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::generators;
    use crate::util::rng::Pcg32;

    fn registry(n: usize) -> Arc<MatrixRegistry> {
        let mut rng = Pcg32::new(0x5AAD);
        let mut reg = MatrixRegistry::new();
        for i in 0..n {
            reg.register(
                &format!("m{i}"),
                generators::random_uniform(96 + i, 4, &mut rng),
            );
        }
        Arc::new(reg)
    }

    #[test]
    fn placement_replicates_hot_and_homes_cold() {
        let ids: Vec<usize> = (0..12).collect();
        // Zipf-ish weights: id 0 heaviest.
        let weights: Vec<f64> =
            (0..12).map(|i| 1.0 / (i + 1) as f64).collect();
        let p = ShardPlacement::build(
            &ids,
            &weights,
            4,
            PlacementPolicy::HotReplicate { hot: 2 },
        );
        assert_eq!(p.shards(), 4);
        assert!(p.is_replicated(0) && p.is_replicated(1));
        assert_eq!(p.replicated_count(), 2);
        assert!(!p.is_replicated(2));
        // Replicated matrices spread round-robin over the salt.
        let routes: Vec<usize> = (0..8).map(|s| p.route(0, s)).collect();
        assert_eq!(routes, vec![0, 1, 2, 3, 0, 1, 2, 3]);
        // Homed matrices stick to one shard regardless of salt.
        let home = p.home(5).unwrap();
        for salt in 0..8 {
            assert_eq!(p.route(5, salt), home);
        }
        // Cold tail is spread: every shard homes someone.
        let counts = p.homed_counts();
        assert_eq!(counts.iter().sum::<usize>(), 10);
        assert!(counts.iter().all(|&c| c > 0), "{counts:?}");
        // Unknown ids route somewhere valid instead of panicking.
        assert!(p.route(usize::MAX, 7) < 4);
    }

    #[test]
    fn reassign_plan_is_deterministic_and_balanced() {
        let ids: Vec<usize> = (0..9).collect();
        let weights = vec![1.0; 9];
        let p =
            ShardPlacement::build(&ids, &weights, 3, PlacementPolicy::Home);
        let plan = p.reassign_plan(0, &[1, 2]);
        assert_eq!(plan, p.reassign_plan(0, &[1, 2]), "must be a replay");
        assert_eq!(plan.len(), p.homed_counts()[0], "every orphan re-homed");
        assert!(plan.iter().all(|&(_, s)| s == 1 || s == 2));
        // Orphans spread across survivors, not dog-piled on one.
        let to1 = plan.iter().filter(|&&(_, s)| s == 1).count();
        let to2 = plan.len() - to1;
        assert!((to1 as i64 - to2 as i64).abs() <= 1, "{plan:?}");
        // Nothing to fail over to: an empty plan, not a panic.
        assert!(p.reassign_plan(0, &[]).is_empty());
    }

    #[test]
    fn placement_home_policy_replicates_nothing() {
        let ids: Vec<usize> = (0..6).collect();
        let weights = vec![1.0; 6];
        let p =
            ShardPlacement::build(&ids, &weights, 3, PlacementPolicy::Home);
        assert_eq!(p.replicated_count(), 0);
        assert_eq!(p.homed_counts(), vec![2, 2, 2]);
    }

    #[test]
    fn sharded_server_serves_and_survives_poison() {
        let reg = registry(6);
        let cfg = ShardConfig {
            shards: 4,
            queue_cap: 0,
            workers_per_shard: 1,
            ..ShardConfig::default()
        };
        let server = ShardedServer::new(
            reg.clone(),
            Planner::Heuristic,
            PlanConfig::default(),
            cfg,
        );
        let n_valid = 120usize;
        let served = std::thread::scope(|s| {
            s.spawn(|| {
                for i in 0..n_valid {
                    let id = i % reg.len();
                    let n = reg.entry(id).csr.n_cols;
                    let a = server.submit(Request::new(id, vec![1.0; n]));
                    assert!(!a.is_rejected());
                }
                // Poison: unknown matrix id mixed into valid traffic.
                server.submit(Request::new(usize::MAX, vec![1.0; 8]));
                server.close();
            });
            server.serve()
        });
        assert_eq!(served, n_valid);
        let merged = server.merged_stats();
        assert_eq!(merged.requests, n_valid as u64);
        assert_eq!(merged.errors, 1, "poison must be an error outcome");
        assert_eq!(merged.rejected, 0);
        assert_eq!(merged.digest.count, n_valid as u64);
        // The always-on profiler attributed every executed batch and
        // the fleet roll-up merges the shard profilers.
        let scal = server.scaling_snapshot();
        assert_eq!(
            scal.get("schema").and_then(Json::as_str),
            Some("ft2000.scaling.v1")
        );
        assert!(
            scal.get("batches").and_then(Json::as_f64).unwrap_or(0.0) > 0.0,
            "shard dispatches must be attributed"
        );
        // Every shard that homes a matrix saw its traffic.
        for (i, snap) in server.snapshots(1.0).iter().enumerate() {
            if server.placement.homed_counts()[i] > 0 {
                assert!(
                    snap.stats.requests > 0,
                    "shard {i} homed matrices but served nothing"
                );
            }
            assert_eq!(snap.cores.1 - snap.cores.0, 16, "4 shards x 2 panels");
        }
    }

    #[test]
    fn pooled_shards_pin_pools_to_their_panels() {
        let reg = registry(4);
        let server = ShardedServer::new(
            reg.clone(),
            Planner::Heuristic,
            PlanConfig::default(),
            ShardConfig {
                shards: 4,
                workers_per_shard: 1,
                ..ShardConfig::default()
            },
        );
        for shard in &server.shards {
            let pool = shard.engine.pool().expect("pooled by default");
            assert_eq!(pool.cores(), Some(shard.cores));
            assert_eq!(
                pool.n_workers(),
                shard.cores.1 - shard.cores.0,
                "one resident worker per panel core"
            );
        }
        // Spawn mode builds no pools (the A/B baseline).
        let spawn = ShardedServer::new(
            reg,
            Planner::Heuristic,
            PlanConfig::default(),
            ShardConfig {
                shards: 2,
                pooled: false,
                ..ShardConfig::default()
            },
        );
        assert!(spawn.shards.iter().all(|s| s.engine.pool().is_none()));
    }

    #[test]
    fn tuned_shards_bound_ladders_to_their_panels() {
        let reg = registry(3);
        let server = ShardedServer::new(
            reg.clone(),
            Planner::Heuristic,
            PlanConfig::default(),
            ShardConfig {
                shards: 2,
                queue_cap: 0,
                workers_per_shard: 1,
                tune: Some(AutotuneConfig::default()),
                ..ShardConfig::default()
            },
        );
        for shard in &server.shards {
            assert!(shard.engine.is_tuned(), "tune flag must reach shards");
        }
        let served = std::thread::scope(|s| {
            s.spawn(|| {
                for i in 0..60 {
                    let id = i % reg.len();
                    let n = reg.entry(id).csr.n_cols;
                    server.submit(Request::new(id, vec![1.0; n]));
                }
                server.close();
            });
            server.serve()
        });
        assert_eq!(served, 60);
        let summaries = server.autotune_summaries();
        assert!(!summaries.is_empty(), "tuned shards must report tuners");
        // 2 shards over 8 panels = 32 cores each; no variant may plan
        // wider than its shard's panel range.
        for s in &summaries {
            assert!(
                s.chosen_variant.n_threads <= 32,
                "{:?} exceeds the panel bound",
                s.chosen_variant
            );
            assert!(s.observations > 0, "wall-clock feedback must flow");
        }
        let untuned = ShardedServer::new(
            reg,
            Planner::Heuristic,
            PlanConfig::default(),
            ShardConfig { shards: 2, ..ShardConfig::default() },
        );
        assert!(untuned.autotune_summaries().is_empty());
        assert_eq!(untuned.autotune_totals(), (0, 0));
    }

    #[test]
    fn traced_shards_record_spans_and_export_one_document() {
        let reg = registry(4);
        let server = ShardedServer::new(
            reg.clone(),
            Planner::Heuristic,
            PlanConfig::default(),
            ShardConfig {
                shards: 2,
                queue_cap: 0,
                workers_per_shard: 1,
                trace: Some(TraceConfig::on()),
                ..ShardConfig::default()
            },
        );
        assert_eq!(server.traces().len(), 2, "one recorder per shard");
        let served = std::thread::scope(|s| {
            s.spawn(|| {
                for i in 0..40 {
                    let id = i % reg.len();
                    let n = reg.entry(id).csr.n_cols;
                    server.submit(Request::new(id, vec![1.0; n]));
                }
                server.close();
            });
            server.serve()
        });
        assert_eq!(served, 40);
        // Admission stamps at submit, QueueWait at dispatch, Kernel
        // inside the shard pools — all three must surface somewhere
        // across the fleet's recorders.
        let mut stages = std::collections::BTreeSet::new();
        for rec in server.traces() {
            for ((stage, _), _) in rec.flame_cells() {
                stages.insert(stage);
            }
        }
        for want in [Stage::Admission, Stage::QueueWait, Stage::Kernel] {
            assert!(
                stages.contains(&want.index()),
                "missing {} spans across shards",
                want.name()
            );
        }
        assert!(server.spans_recorded() >= 3 * 40);
        // One merged Chrome document; pid identifies the shard.
        let doc = server.export_chrome();
        let events =
            doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        assert!(events.len() >= 3 * 40);
        let pids: std::collections::BTreeSet<usize> = events
            .iter()
            .map(|e| e.get("pid").and_then(Json::as_usize).unwrap())
            .collect();
        assert_eq!(pids.len(), 2, "both shards must contribute spans");
        // Fleet metrics: merged roll-up plus one engine snapshot per
        // shard under the sharded schema tag.
        let m = server.metrics_snapshot(1.0);
        assert_eq!(
            m.get("schema").and_then(Json::as_str),
            Some("ft2000.metrics.sharded.v1")
        );
        let shards = m.get("shards").and_then(Json::as_arr).unwrap();
        assert_eq!(shards.len(), 2);
        for s in shards {
            assert_eq!(
                s.get("schema").and_then(Json::as_str),
                Some("ft2000.metrics.v1")
            );
        }
        assert!(m.get("serve").and_then(|s| s.get("requests")).is_some());
        // Untraced servers carry no recorders and export nothing.
        let quiet = ShardedServer::new(
            reg,
            Planner::Heuristic,
            PlanConfig::default(),
            ShardConfig { shards: 2, ..ShardConfig::default() },
        );
        assert!(quiet.traces().is_empty());
        assert_eq!(quiet.spans_recorded(), 0);
    }

    #[test]
    fn bounded_queues_reject_overload() {
        let reg = registry(2);
        let cfg = ShardConfig {
            shards: 2,
            queue_cap: 4,
            workers_per_shard: 1,
            policy: PlacementPolicy::Home,
            ..ShardConfig::default()
        };
        let server = ShardedServer::new(
            reg.clone(),
            Planner::Heuristic,
            PlanConfig::default(),
            cfg,
        );
        // No workers running: fill one home shard past capacity.
        let id = 0usize;
        let n = reg.entry(id).csr.n_cols;
        let mut rejected = 0usize;
        for _ in 0..10 {
            if server.submit(Request::new(id, vec![1.0; n])).is_rejected() {
                rejected += 1;
            }
        }
        assert_eq!(rejected, 6, "cap 4 must reject the excess");
        server.close();
        let served = server.serve();
        assert_eq!(served, 4);
        let merged = server.merged_stats();
        assert_eq!(merged.rejected, 6);
        assert_eq!(merged.requests, 4);
    }

    #[test]
    fn deadline_sheds_stale_backlog() {
        let reg = registry(1);
        let cfg = ShardConfig {
            shards: 1,
            queue_cap: 0,
            workers_per_shard: 1,
            deadline_ms: 5.0,
            ..ShardConfig::default()
        };
        let server = ShardedServer::new(
            reg.clone(),
            Planner::Heuristic,
            PlanConfig::default(),
            cfg,
        );
        let n = reg.entry(0).csr.n_cols;
        for _ in 0..8 {
            server.submit(Request::new(0, vec![1.0; n]));
        }
        // Let the backlog go stale past the 5 ms deadline, then serve.
        std::thread::sleep(std::time::Duration::from_millis(30));
        server.close();
        let served = server.serve();
        assert_eq!(served, 0, "stale backlog must be shed, not served");
        let merged = server.merged_stats();
        assert_eq!(merged.shed, 8);
        assert_eq!(merged.requests, 0);
    }
}
