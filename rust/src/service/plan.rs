//! Per-matrix execution plans, memoized by content fingerprint.
//!
//! The paper's conclusion is that format/schedule choice must be made
//! per matrix from its structure; SpChar (Sgherzi et al., 2023)
//! argues the same with decision trees. Planning is expensive — it
//! extracts static features, may run a learned selector, and converts
//! the matrix to CSR5 when tiles win — so a serving deployment does
//! it once on first request and reuses the plan for every subsequent
//! request against the same fingerprint.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::coordinator::format_select::{
    candidates, label_matrix, static_features, FormatSelector,
};
use crate::corpus::suite::SuiteSpec;
use crate::exec::{
    self, ExecPool, ExecResult, ExecStats, Scratch, SpmmResult, SpmmStats,
};
use crate::sched::{partition, Partition, Schedule};
use crate::sim::topology::Placement;
use crate::sparse::sell::{normalize_sigma, SellCSigma};
use crate::sparse::{Csr, Csr5};

/// Materialized storage format of a plan — conversion paid at plan
/// build, not per request.
#[derive(Clone, Debug)]
pub enum PlannedFormat {
    /// Serve straight from the registered CSR.
    Csr,
    /// Pre-converted CSR5 tiling (kept alongside the CSR).
    Csr5(Arc<Csr5>),
    /// Pre-converted SELL-C-σ packing (kept alongside the CSR).
    Sell(Arc<SellCSigma>),
}

/// One matrix's cached execution plan.
///
/// Everything a served request needs is materialized at build time:
/// the storage format (CSR5 conversion), the [`Partition`] for the
/// single-vector path, and the row partition + effective schedule for
/// the batched SpMM path (tile plans remap to `CsrRowBalanced`
/// there). A request is then: look up the plan, hand the cached
/// ranges to resident workers — no per-request partitioning, no
/// prefix bisection, no tiling.
#[derive(Clone, Debug)]
pub struct Plan {
    pub schedule: Schedule,
    pub n_threads: usize,
    pub placement: Placement,
    pub format: PlannedFormat,
    /// Static feature vector the decision was made from (empty for
    /// the all-zero matrix, which short-circuits to CSR static).
    pub features: Vec<f64>,
    /// Materialized single-vector partition under `schedule`.
    pub partition: Partition,
    /// Effective schedule of the batched SpMM path (see
    /// [`exec::effective_spmm_schedule`]).
    pub spmm_schedule: Schedule,
    /// Materialized row partition for the batched SpMM path.
    pub spmm_partition: Vec<Vec<(usize, usize)>>,
    /// Pre-rendered `schedule.name()` — telemetry records per-request
    /// schedule attribution on the hot path, which must not pay a
    /// `format!` (or any allocation) per dispatch.
    pub schedule_name: String,
    /// Pre-rendered `spmm_schedule.name()`.
    pub spmm_schedule_name: String,
}

impl Plan {
    pub fn format_name(&self) -> String {
        self.schedule.name()
    }

    /// The schedule a dispatch of `batch` coalesced requests actually
    /// executes — what telemetry should attribute throughput to.
    pub fn effective_schedule(&self, batch: usize) -> Schedule {
        if batch > 1 {
            self.spmm_schedule
        } else {
            self.schedule
        }
    }

    /// Pre-rendered name of [`Plan::effective_schedule`] — the
    /// allocation-free telemetry key.
    pub fn effective_schedule_name(&self, batch: usize) -> &str {
        if batch > 1 {
            &self.spmm_schedule_name
        } else {
            &self.schedule_name
        }
    }

    /// Effective parallelism of a dispatch of `batch` requests: the
    /// number of partition slots that actually carry work, computed
    /// with the executors' own slot filter
    /// ([`exec::effective_row_slots`]/[`exec::effective_tile_slots`])
    /// so it always matches what `ExecResult.threads` /
    /// `SpmmResult.threads` report — the replay cost model is
    /// identical whether or not kernels really run.
    pub fn effective_threads(&self, batch: usize) -> usize {
        if batch > 1 {
            return exec::effective_row_slots(&self.spmm_partition);
        }
        match &self.partition {
            Partition::Rows { per_thread } => {
                exec::effective_row_slots(per_thread)
            }
            Partition::Tiles { per_thread, .. }
            | Partition::SellChunks { per_thread, .. } => {
                exec::effective_tile_slots(per_thread)
            }
        }
    }

    /// Execute a single-vector request under this plan (spawn
    /// fallback; serving paths use [`Plan::execute_on`] with a pool).
    pub fn execute(&self, csr: &Csr, x: &[f64]) -> ExecResult {
        self.execute_on(csr, x, None)
    }

    /// Execute a single-vector request on the given pool's resident
    /// workers (scoped threads when `None`). Packed-format plans
    /// reuse their pre-converted CSR5/SELL structure and the memoized
    /// partition — a served request never converts or re-partitions.
    pub fn execute_on(
        &self,
        csr: &Csr,
        x: &[f64],
        pool: Option<&ExecPool>,
    ) -> ExecResult {
        let mut scratch = Scratch::new();
        self.execute_into(csr, x, pool, &mut scratch)
            .into_result(&mut scratch)
    }

    /// Single-vector execution into a caller-provided scratch arena —
    /// the zero-allocation serving path (the output stays in
    /// `scratch.y()`; see `exec::Scratch` for the take-or-borrow
    /// story).
    pub fn execute_into(
        &self,
        csr: &Csr,
        x: &[f64],
        pool: Option<&ExecPool>,
        scratch: &mut Scratch,
    ) -> ExecStats {
        match (&self.format, &self.partition) {
            (PlannedFormat::Csr5(c5), Partition::Tiles { per_thread, .. }) => {
                exec::spmv_csr5_into(pool, c5, x, per_thread, scratch)
            }
            (
                PlannedFormat::Sell(s),
                Partition::SellChunks { per_thread, .. },
            ) => exec::spmv_sell_into(pool, s, x, per_thread, scratch),
            (_, Partition::Rows { per_thread }) => {
                exec::spmv_rows_into(pool, csr, x, per_thread, scratch)
            }
            _ => unreachable!(
                "packed-format plans carry their pre-converted structure"
            ),
        }
    }

    /// Execute a coalesced batch of requests as one multi-vector SpMM
    /// (`xs` in the interleaved `exec::pack_vectors` layout; spawn
    /// fallback).
    pub fn execute_batch(
        &self,
        csr: &Csr,
        xs: &[f64],
        batch: usize,
    ) -> SpmmResult {
        self.execute_batch_on(csr, xs, batch, None)
    }

    /// Batched SpMM on the given pool, over the memoized row
    /// partition (packed-format plans pre-remapped to
    /// `CsrRowBalanced` at build time).
    pub fn execute_batch_on(
        &self,
        csr: &Csr,
        xs: &[f64],
        batch: usize,
        pool: Option<&ExecPool>,
    ) -> SpmmResult {
        exec::spmm_partitioned(
            pool,
            csr,
            xs,
            batch,
            &self.spmm_partition,
            self.spmm_schedule,
        )
    }

    /// Batched SpMM into a caller-provided scratch arena: packs the
    /// request vectors into the reused interleave buffer and leaves
    /// the outputs in `scratch.y_batch()` — the zero-allocation
    /// serving path for coalesced dispatches.
    pub fn execute_batch_into(
        &self,
        csr: &Csr,
        vectors: &[&[f64]],
        pool: Option<&ExecPool>,
        scratch: &mut Scratch,
    ) -> SpmmStats {
        exec::spmm_into(
            pool,
            csr,
            vectors,
            &self.spmm_partition,
            self.spmm_schedule,
            scratch,
        )
    }
}

/// Plan-construction parameters shared by all matrices of a service.
#[derive(Clone, Debug)]
pub struct PlanConfig {
    /// Threads per kernel launch. Defaults to 4 — one FT-2000+
    /// core-group, and machine-independent so plans are reproducible.
    pub n_threads: usize,
    pub placement: Placement,
    /// Tile size used when a CSR5 schedule is chosen.
    pub csr5_tile_nnz: usize,
    /// Chunk height (C) used when a SELL-C-σ schedule is chosen.
    pub sell_c: usize,
    /// Sorting window (σ) used when a SELL-C-σ schedule is chosen.
    pub sell_sigma: usize,
    /// Plan-cache capacity in entries; 0 = unbounded. Bounded caches
    /// evict least-recently-used plans (evicted fingerprints rebuild
    /// on their next request).
    pub cache_cap: usize,
    /// Run the alloc-free structural sanity check
    /// (`check::quick_plan_check`) on every dispatch. Defaults to on
    /// in debug builds and off in release (where the verifier is
    /// reachable via `ft2000-spmv check` and registry admission
    /// instead of the hot path).
    pub validate: bool,
}

impl Default for PlanConfig {
    fn default() -> Self {
        PlanConfig {
            n_threads: 4,
            placement: Placement::CoreGroupFirst,
            csr5_tile_nnz: 256,
            sell_c: 8,
            sell_sigma: 64,
            cache_cap: 0,
            validate: cfg!(debug_assertions),
        }
    }
}

/// How schedules are decided at plan-build time. `Clone` so a sharded
/// service can hand every shard its own planner for an independent
/// per-shard plan-cache view.
#[derive(Clone)]
pub enum Planner {
    /// Static-feature thresholds (the paper's §5 decision rules:
    /// `job_var >= 0.45` flags imbalance-limited matrices).
    Heuristic,
    /// Learned classification tree over static features
    /// (`coordinator::format_select` trained on simulated labels).
    Learned(FormatSelector),
}

impl Planner {
    /// Train the learned selector on a (small) synthetic suite. The
    /// labels come from the FT-2000+ simulator, so training cost
    /// scales with the suite; `SuiteSpec::tiny()` trains in seconds.
    pub fn train(spec: &SuiteSpec) -> Planner {
        let samples: Vec<_> = spec
            .entries()
            .iter()
            .map(|e| {
                let m = spec.materialize(e);
                label_matrix(&m.csr, &e.name)
            })
            .collect();
        Planner::Learned(FormatSelector::train(&samples))
    }

    pub fn name(&self) -> &'static str {
        match self {
            Planner::Heuristic => "heuristic",
            Planner::Learned(_) => "learned",
        }
    }

    /// Pure function of the matrix content: the schedule this planner
    /// picks. Determinism here is what makes cached plans stable
    /// across runs (tested in `tests/properties.rs`). `features` is
    /// the `static_features` vector, computed once by the caller and
    /// shared with both decision modes.
    fn choose(&self, features: &[f64], cfg: &PlanConfig) -> Schedule {
        let tile_nnz = cfg.csr5_tile_nnz;
        let picked = match self {
            Planner::Heuristic => {
                // static_features order: [n_rows, nnz_avg, nnz_var,
                // nnz_max_ratio, job_var_static, locality, x_miss_l1].
                let job_var = features[4];
                if job_var >= 0.45 {
                    // Severe imbalance: only the nnz-even tiling
                    // rescues it (paper Fig 7).
                    Schedule::Csr5Tiles { tile_nnz }
                } else if job_var >= 0.30 {
                    // Moderate imbalance: σ-window sorting evens the
                    // chunk widths, and the chunk layout vectorizes —
                    // SELL-C-σ is the related work's cross-platform
                    // answer for exactly this band.
                    Schedule::SellChunks {
                        c: cfg.sell_c,
                        sigma: cfg.sell_sigma,
                    }
                } else {
                    Schedule::CsrRowStatic
                }
            }
            Planner::Learned(sel) => {
                let cands = candidates();
                let k = sel.tree.predict(features);
                cands[k.min(cands.len() - 1)]
            }
        };
        // Normalize format parameters to the service-wide config.
        match picked {
            Schedule::Csr5Tiles { .. } => Schedule::Csr5Tiles { tile_nnz },
            Schedule::SellChunks { .. } => Schedule::SellChunks {
                c: cfg.sell_c.clamp(1, 64),
                sigma: cfg.sell_sigma.max(1),
            },
            s => s,
        }
    }
}

/// Build one plan (no caching — see [`PlanCache`]). All the
/// per-matrix work — feature extraction, schedule choice, CSR5
/// conversion, and partition materialization for both the SpMV and
/// SpMM paths — happens here, once, so plan execution is pure
/// dispatch.
pub fn build_plan(planner: &Planner, cfg: &PlanConfig, csr: &Csr) -> Plan {
    let (schedule, features) = if csr.nnz() == 0 {
        // Degenerate matrix: nothing to balance, nothing to convert.
        (Schedule::CsrRowStatic, Vec::new())
    } else {
        let features = static_features(csr);
        (planner.choose(&features, cfg), features)
    };
    build_plan_with(cfg, csr, schedule, cfg.n_threads, features)
}

/// Already-converted packed structures a plan build may share instead
/// of reconverting — the autotuner's thread ladder pays one CSR5 (or
/// SELL) conversion for the whole arm family.
#[derive(Clone, Default)]
pub struct SharedFormats {
    pub csr5: Option<Arc<Csr5>>,
    pub sell: Option<Arc<SellCSigma>>,
}

impl SharedFormats {
    pub fn none() -> Self {
        Self::default()
    }

    /// Extract the shareable conversion a plan already carries.
    pub fn of(plan: &Plan) -> Self {
        match &plan.format {
            PlannedFormat::Csr5(c5) => SharedFormats {
                csr5: Some(c5.clone()),
                ..Self::default()
            },
            PlannedFormat::Sell(s) => SharedFormats {
                sell: Some(s.clone()),
                ..Self::default()
            },
            PlannedFormat::Csr => Self::default(),
        }
    }
}

/// Build a plan for an *explicit* (schedule, thread count) pair — the
/// autotuner's candidate-variant constructor. Performs the same
/// materialization as [`build_plan`] (format conversion, SpMV + SpMM
/// partitions) but skips the planner decision; `features` is the
/// already-extracted static feature vector (may be empty). Degenerate
/// all-zero matrices are normalized to the CSR static schedule — no
/// variant can improve on a no-op.
pub fn build_plan_with(
    cfg: &PlanConfig,
    csr: &Csr,
    schedule: Schedule,
    n_threads: usize,
    features: Vec<f64>,
) -> Plan {
    build_plan_shared(
        cfg,
        csr,
        schedule,
        n_threads,
        features,
        SharedFormats::none(),
    )
}

/// [`build_plan_with`] reusing an already-converted CSR5 structure
/// (compatibility shim; see [`build_plan_shared`]).
pub fn build_plan_with_csr5(
    cfg: &PlanConfig,
    csr: &Csr,
    schedule: Schedule,
    n_threads: usize,
    features: Vec<f64>,
    shared_csr5: Option<Arc<Csr5>>,
) -> Plan {
    build_plan_shared(
        cfg,
        csr,
        schedule,
        n_threads,
        features,
        SharedFormats { csr5: shared_csr5, sell: None },
    )
}

/// [`build_plan_with`] reusing already-converted packed structures
/// when the schedule matches them (tile size for CSR5; chunk height
/// and normalized σ for SELL) — the autotuner's ladder shares one
/// conversion across all arms of a format family instead of
/// converting per arm.
pub fn build_plan_shared(
    cfg: &PlanConfig,
    csr: &Csr,
    schedule: Schedule,
    n_threads: usize,
    features: Vec<f64>,
    shared: SharedFormats,
) -> Plan {
    let schedule = if csr.nnz() == 0 {
        Schedule::CsrRowStatic
    } else {
        match schedule {
            // Sanitize degenerate chunk parameters up front so the
            // format, the partition, and the schedule name agree.
            Schedule::SellChunks { c, sigma } => Schedule::SellChunks {
                c: c.clamp(1, 64),
                sigma: sigma.max(1),
            },
            s => s,
        }
    };
    let n_threads = n_threads.max(1);
    let format = match schedule {
        Schedule::Csr5Tiles { tile_nnz } => {
            PlannedFormat::Csr5(match shared.csr5 {
                Some(c5) if c5.tile_nnz == tile_nnz => c5,
                _ => Arc::new(Csr5::from_csr(csr, tile_nnz)),
            })
        }
        Schedule::SellChunks { c, sigma } => {
            let want_sigma = normalize_sigma(c, sigma, csr.n_rows);
            PlannedFormat::Sell(match shared.sell {
                Some(s) if s.c == c && s.sigma == want_sigma => s,
                _ => Arc::new(SellCSigma::from_csr(csr, c, sigma)),
            })
        }
        _ => PlannedFormat::Csr,
    };
    let part = partition(csr, schedule, n_threads);
    debug_assert!(part.validate(csr).is_ok());
    let spmm_schedule = exec::effective_spmm_schedule(schedule);
    let spmm_partition = match (&part, spmm_schedule == schedule) {
        // Row-space plans serve batches from the same partition.
        (Partition::Rows { per_thread }, true) => per_thread.clone(),
        _ => match partition(csr, spmm_schedule, n_threads) {
            Partition::Rows { per_thread } => per_thread,
            _ => unreachable!("effective SpMM schedules are row-space"),
        },
    };
    Plan {
        schedule,
        n_threads,
        placement: cfg.placement,
        format,
        features,
        partition: part,
        spmm_schedule,
        spmm_partition,
        schedule_name: schedule.name(),
        spmm_schedule_name: spmm_schedule.name(),
    }
}

/// One cached plan plus its bookkeeping: a monotonically increasing
/// `version` (bumped by [`PlanCache::replace`] when the autotuner
/// promotes a better variant) and an LRU recency stamp.
struct CacheEntry {
    plan: Arc<Plan>,
    version: u64,
    last_used: u64,
}

#[derive(Default)]
struct CacheInner {
    plans: HashMap<u64, CacheEntry>,
    hits: u64,
    misses: u64,
    evictions: u64,
    replacements: u64,
    /// Recency clock: bumped on every touch (LRU order).
    tick: u64,
}

impl CacheInner {
    fn touch(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    /// Lookup + LRU stamp + hit accounting in one pass.
    fn hit(&mut self, fp: u64) -> Option<Arc<Plan>> {
        let t = self.touch();
        let e = self.plans.get_mut(&fp)?;
        e.last_used = t;
        self.hits += 1;
        Some(e.plan.clone())
    }

    /// Evict least-recently-used entries (never `keep`) until the
    /// cache fits `cap`. `cap == 0` means unbounded.
    fn evict_to_cap(&mut self, cap: usize, keep: u64) {
        if cap == 0 {
            return;
        }
        while self.plans.len() > cap {
            let victim = self
                .plans
                .iter()
                .filter(|(&fp, _)| fp != keep)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(&fp, _)| fp);
            match victim {
                Some(fp) => {
                    self.plans.remove(&fp);
                    self.evictions += 1;
                }
                None => break, // only `keep` left; cap 0 handled above
            }
        }
    }
}

/// Thread-safe memoization of plans by matrix fingerprint, with
/// hit/miss accounting (the serving report's cache line).
///
/// Optionally bounded ([`PlanConfig::cache_cap`]): at capacity the
/// least-recently-used entry is evicted and its fingerprint simply
/// rebuilds (as a counted miss) on its next request. Entries are
/// versioned so the online autotuner can [`PlanCache::replace`] a
/// promoted variant in place and observers can tell the plan changed.
pub struct PlanCache {
    planner: Planner,
    cfg: PlanConfig,
    inner: Mutex<CacheInner>,
}

impl PlanCache {
    pub fn new(planner: Planner, cfg: PlanConfig) -> Self {
        PlanCache { planner, cfg, inner: Mutex::new(CacheInner::default()) }
    }

    /// Lock the cache state, recovering from poison: the inner map is
    /// only mutated through short, panic-free bookkeeping sections, so
    /// a poisoned mutex (a panicked peer elsewhere in the process)
    /// leaves it consistent.
    fn state(&self) -> std::sync::MutexGuard<'_, CacheInner> {
        self.inner
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    pub fn config(&self) -> &PlanConfig {
        &self.cfg
    }

    pub fn planner_name(&self) -> &'static str {
        self.planner.name()
    }

    /// Configured capacity (0 = unbounded).
    pub fn capacity(&self) -> usize {
        self.cfg.cache_cap
    }

    /// Get the plan for `fingerprint`, building it from `csr` on the
    /// first request. Returns `(plan, hit)`. The (expensive) build
    /// runs outside the lock; if two threads race on the same new
    /// fingerprint the first insert wins — both builds produce the
    /// identical plan, so the race is benign.
    pub fn plan_for(&self, fp: u64, csr: &Csr) -> (Arc<Plan>, bool) {
        {
            let mut inner = self.state();
            if let Some(p) = inner.hit(fp) {
                return (p, true);
            }
        }
        let built = Arc::new(build_plan(&self.planner, &self.cfg, csr));
        let mut inner = self.state();
        if let Some(p) = inner.hit(fp) {
            // Lost the build race: the winner's identical plan is
            // already cached, so this request still counts as a hit
            // (misses == distinct plan builds).
            return (p, true);
        }
        inner.misses += 1;
        let t = inner.touch();
        inner.plans.insert(
            fp,
            CacheEntry { plan: built.clone(), version: 1, last_used: t },
        );
        inner.evict_to_cap(self.cfg.cache_cap, fp);
        (built, false)
    }

    /// Cache probe with an externally supplied fallback plan (the
    /// autotuner's promoted winner): a present entry is a normal hit;
    /// an absent one — e.g. after LRU eviction — installs `plan` as a
    /// counted miss *without* rebuilding the static plan. Returns
    /// `(served plan, hit)` like [`PlanCache::plan_for`].
    pub fn hit_or_install(&self, fp: u64, plan: Arc<Plan>) -> (Arc<Plan>, bool) {
        let mut inner = self.state();
        if let Some(p) = inner.hit(fp) {
            return (p, true);
        }
        inner.misses += 1;
        let t = inner.touch();
        inner.plans.insert(
            fp,
            CacheEntry { plan: plan.clone(), version: 1, last_used: t },
        );
        inner.evict_to_cap(self.cfg.cache_cap, fp);
        (plan, false)
    }

    /// Install `plan` as the served plan for `fp`, bumping the entry
    /// version — the autotuner's promotion (and demotion) hook. Does
    /// not count as a hit or a miss; returns the new version.
    pub fn replace(&self, fp: u64, plan: Arc<Plan>) -> u64 {
        let mut inner = self.state();
        let t = inner.touch();
        inner.replacements += 1;
        match inner.plans.get_mut(&fp) {
            Some(e) => {
                e.plan = plan;
                e.version += 1;
                e.last_used = t;
                e.version
            }
            None => {
                // Promoting into a slot the LRU already evicted:
                // (re)install at version 1.
                inner.plans.insert(
                    fp,
                    CacheEntry { plan, version: 1, last_used: t },
                );
                inner.evict_to_cap(self.cfg.cache_cap, fp);
                1
            }
        }
    }

    /// Version of the cached entry for `fp` (bumped by `replace`).
    pub fn version(&self, fp: u64) -> Option<u64> {
        self.state().plans.get(&fp).map(|e| e.version)
    }

    /// `(fingerprint, version)` of every cached entry — the
    /// verifier's view for the version-monotonicity invariant
    /// (`check::check_plan_cache`). Unordered.
    pub fn versions(&self) -> Vec<(u64, u64)> {
        self.state()
            .plans
            .iter()
            .map(|(&fp, e)| (fp, e.version))
            .collect()
    }

    pub fn len(&self) -> usize {
        self.state().plans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.state().plans.is_empty()
    }

    /// (hits, misses) so far.
    pub fn stats(&self) -> (u64, u64) {
        let inner = self.state();
        (inner.hits, inner.misses)
    }

    /// LRU evictions so far.
    pub fn evictions(&self) -> u64 {
        self.state().evictions
    }

    /// Autotuner plan replacements so far.
    pub fn replacements(&self) -> u64 {
        self.state().replacements
    }

    /// Hit rate over all lookups, or `None` before the first lookup —
    /// an empty cache has no rate, and telemetry renders it as `n/a`
    /// instead of a misleading 0%.
    pub fn hit_rate(&self) -> Option<f64> {
        let (h, m) = self.stats();
        if h + m == 0 {
            None
        } else {
            Some(h as f64 / (h + m) as f64)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{generators, NamedMatrix};
    use crate::service::registry::fingerprint;
    use crate::util::rng::Pcg32;

    #[test]
    fn heuristic_picks_csr5_for_imbalance() {
        let csr = NamedMatrix::Exdata1.generate();
        let plan =
            build_plan(&Planner::Heuristic, &PlanConfig::default(), &csr);
        assert!(
            matches!(plan.schedule, Schedule::Csr5Tiles { .. }),
            "exdata_1 (one thread owns >99% of nnz) must get tiles: {:?}",
            plan.schedule
        );
        assert!(matches!(plan.format, PlannedFormat::Csr5(_)));
    }

    #[test]
    fn heuristic_keeps_csr_for_regular() {
        let csr = generators::stencil(4096, 5);
        let plan =
            build_plan(&Planner::Heuristic, &PlanConfig::default(), &csr);
        assert_eq!(plan.schedule, Schedule::CsrRowStatic);
        assert!(matches!(plan.format, PlannedFormat::Csr));
    }

    /// 4-thread static split [64, 64, 64, 128] -> job_var = 0.4: the
    /// moderate-imbalance band.
    fn moderately_imbalanced() -> Csr {
        let mut coo = crate::sparse::Coo::new(256, 256);
        for r in 0..256 {
            coo.push(r, r, 1.0);
            if r >= 192 {
                coo.push(r, (r + 1) % 256, 1.0);
            }
        }
        coo.to_csr()
    }

    #[test]
    fn heuristic_picks_sell_for_moderate_imbalance() {
        let csr = moderately_imbalanced();
        let cfg = PlanConfig::default();
        let plan = build_plan(&Planner::Heuristic, &cfg, &csr);
        assert!(
            matches!(plan.schedule, Schedule::SellChunks { .. }),
            "job_var 0.4 must land in the SELL band: {:?}",
            plan.schedule
        );
        assert!(matches!(plan.format, PlannedFormat::Sell(_)));
        assert!(matches!(plan.partition, Partition::SellChunks { .. }));
        assert_eq!(
            plan.spmm_schedule,
            Schedule::CsrRowBalanced,
            "batches remap to the balanced row schedule"
        );
        assert_eq!(plan.schedule_name, plan.schedule.name());
        assert_eq!(plan.effective_schedule_name(1), plan.schedule_name);
        assert_eq!(plan.effective_schedule_name(4), "csr-balanced");
        // And it computes the right answer, bitwise vs the reference.
        let x: Vec<f64> = (0..256).map(|i| (i % 13) as f64 - 6.0).collect();
        let mut want = vec![0.0; 256];
        csr.spmv(&x, &mut want);
        let got = plan.execute(&csr, &x);
        for (i, (a, b)) in want.iter().zip(&got.y).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "row {i}: {a} vs {b}");
        }
    }

    #[test]
    fn variant_builder_shares_the_sell_conversion() {
        let csr = moderately_imbalanced();
        let cfg = PlanConfig::default();
        let static_plan = build_plan(&Planner::Heuristic, &cfg, &csr);
        let PlannedFormat::Sell(s) = &static_plan.format else {
            panic!("setup: expected a SELL plan")
        };
        // Same (c, σ): the conversion is shared, not redone.
        let shared = build_plan_shared(
            &cfg,
            &csr,
            static_plan.schedule,
            2,
            Vec::new(),
            SharedFormats::of(&static_plan),
        );
        match &shared.format {
            PlannedFormat::Sell(got) => assert!(
                Arc::ptr_eq(got, s),
                "thread-ladder variants must reuse the SELL structure"
            ),
            _ => panic!("SELL schedule lost its format"),
        }
        // A different chunk height falls back to a fresh conversion.
        let fresh = build_plan_shared(
            &cfg,
            &csr,
            Schedule::SellChunks { c: 4, sigma: 64 },
            2,
            Vec::new(),
            SharedFormats::of(&static_plan),
        );
        match &fresh.format {
            PlannedFormat::Sell(got) => assert!(!Arc::ptr_eq(got, s)),
            _ => panic!("SELL schedule lost its format"),
        }
        // Degenerate chunk parameters are sanitized, not asserted on.
        let weird = build_plan_shared(
            &cfg,
            &csr,
            Schedule::SellChunks { c: 0, sigma: 0 },
            2,
            Vec::new(),
            SharedFormats::none(),
        );
        assert!(
            matches!(weird.schedule, Schedule::SellChunks { c: 1, sigma: 1 }),
            "{:?}",
            weird.schedule
        );
    }

    #[test]
    fn plan_execution_matches_reference() {
        let mut rng = Pcg32::new(0x9A17);
        for csr in [
            NamedMatrix::Exdata1.generate(),
            generators::random_uniform(500, 8, &mut rng),
            Csr::zero(64, 64),
        ] {
            let plan =
                build_plan(&Planner::Heuristic, &PlanConfig::default(), &csr);
            let x: Vec<f64> =
                (0..csr.n_cols).map(|_| rng.gen_f64() - 0.5).collect();
            let mut want = vec![0.0; csr.n_rows];
            csr.spmv(&x, &mut want);
            let got = plan.execute(&csr, &x);
            for (i, (a, b)) in want.iter().zip(&got.y).enumerate() {
                assert!(
                    (a - b).abs() < 1e-9 * (1.0 + a.abs()),
                    "row {i}: {a} vs {b} under {:?}",
                    plan.schedule
                );
            }
            // Batch path agrees column-by-column.
            let xs = exec::pack_vectors(&[x.clone(), x.clone()]);
            let batch = plan.execute_batch(&csr, &xs, 2);
            for j in 0..2 {
                for (i, (a, b)) in
                    want.iter().zip(&batch.column(j)).enumerate()
                {
                    assert!(
                        (a - b).abs() < 1e-9 * (1.0 + a.abs()),
                        "batch col {j} row {i}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn plan_partition_is_computed_exactly_once() {
        // The bugfix this PR pins: Plan::execute used to re-partition
        // (including the full CsrRowBalanced prefix bisection) on
        // every request. The thread-local sched counter must not move
        // across repeated executions of a built plan.
        let mut rng = Pcg32::new(0x9A19);
        for csr in [
            NamedMatrix::Exdata1.generate(), // tile plan
            generators::random_uniform(400, 6, &mut rng), // row plan
            moderately_imbalanced(),         // SELL chunk plan
        ] {
            let plan =
                build_plan(&Planner::Heuristic, &PlanConfig::default(), &csr);
            let x = vec![1.0f64; csr.n_cols];
            let xs = exec::pack_vectors(&[&x, &x, &x]);
            let before = crate::sched::partition_calls();
            for _ in 0..5 {
                let _ = plan.execute(&csr, &x);
                let _ = plan.execute_batch(&csr, &xs, 3);
            }
            let pool = exec::ExecPool::new(2);
            for _ in 0..3 {
                let _ = plan.execute_on(&csr, &x, Some(&pool));
                let _ = plan.execute_batch_on(&csr, &xs, 3, Some(&pool));
            }
            assert_eq!(
                crate::sched::partition_calls(),
                before,
                "served requests must reuse the memoized partition"
            );
        }
    }

    #[test]
    fn tile_plans_memoize_row_partition_for_batches() {
        let csr = NamedMatrix::Exdata1.generate();
        let plan =
            build_plan(&Planner::Heuristic, &PlanConfig::default(), &csr);
        assert!(matches!(plan.schedule, Schedule::Csr5Tiles { .. }));
        assert!(matches!(plan.partition, Partition::Tiles { .. }));
        assert_eq!(plan.spmm_schedule, Schedule::CsrRowBalanced);
        assert_eq!(plan.spmm_partition.len(), plan.n_threads);
        assert_eq!(plan.effective_schedule(1), plan.schedule);
        assert_eq!(plan.effective_schedule(4), Schedule::CsrRowBalanced);
        // The memoized SpMM row partition covers every row once.
        let rows =
            Partition::Rows { per_thread: plan.spmm_partition.clone() };
        assert!(rows.validate(&csr).is_ok());
    }

    #[test]
    fn effective_threads_match_executed_counts() {
        // The replay cost model uses Plan::effective_threads; it must
        // equal what the executors report, including when the
        // configured width exceeds the available rows.
        let mut rng = Pcg32::new(0x9A20);
        for csr in [
            Csr::identity(2), // 2 rows under a 4-thread config
            NamedMatrix::Exdata1.generate(),
            generators::random_uniform(300, 5, &mut rng),
        ] {
            let plan =
                build_plan(&Planner::Heuristic, &PlanConfig::default(), &csr);
            let x = vec![1.0f64; csr.n_cols];
            let got = plan.execute(&csr, &x);
            assert_eq!(
                plan.effective_threads(1),
                got.threads,
                "single-vector count under {:?}",
                plan.schedule
            );
            let xs = exec::pack_vectors(&[&x, &x, &x]);
            let batch = plan.execute_batch(&csr, &xs, 3);
            assert_eq!(
                plan.effective_threads(3),
                batch.threads,
                "batched count under {:?}",
                plan.spmm_schedule
            );
        }
    }

    #[test]
    fn variant_builder_shares_the_csr5_conversion() {
        let csr = NamedMatrix::Exdata1.generate();
        let cfg = PlanConfig::default();
        let static_plan = build_plan(&Planner::Heuristic, &cfg, &csr);
        let PlannedFormat::Csr5(c5) = &static_plan.format else {
            panic!("exdata_1 must get a tile plan")
        };
        // Matching tile size: the conversion is shared, not redone.
        let shared = build_plan_with_csr5(
            &cfg,
            &csr,
            static_plan.schedule,
            2,
            Vec::new(),
            Some(c5.clone()),
        );
        match &shared.format {
            PlannedFormat::Csr5(got) => assert!(
                Arc::ptr_eq(got, c5),
                "thread-ladder variants must reuse the tile structure"
            ),
            PlannedFormat::Csr => panic!("tile schedule lost its format"),
        }
        // Mismatched tile size falls back to a fresh conversion.
        let fresh = build_plan_with_csr5(
            &cfg,
            &csr,
            Schedule::Csr5Tiles { tile_nnz: 64 },
            2,
            Vec::new(),
            Some(c5.clone()),
        );
        match &fresh.format {
            PlannedFormat::Csr5(got) => assert!(!Arc::ptr_eq(got, c5)),
            PlannedFormat::Csr => panic!("tile schedule lost its format"),
        }
    }

    #[test]
    fn cache_counts_hits_and_misses() {
        let mut rng = Pcg32::new(0x9A18);
        let a = generators::banded(256, 3, &mut rng);
        let b = generators::random_uniform(256, 4, &mut rng);
        let cache =
            PlanCache::new(Planner::Heuristic, PlanConfig::default());
        let (fa, fb) = (fingerprint(&a), fingerprint(&b));
        assert_eq!(cache.hit_rate(), None, "no lookups yet: n/a, not 0%");
        let (_, h1) = cache.plan_for(fa, &a);
        let (_, h2) = cache.plan_for(fa, &a);
        let (_, h3) = cache.plan_for(fb, &b);
        assert!(!h1 && h2 && !h3);
        assert_eq!(cache.stats(), (1, 2));
        assert_eq!(cache.len(), 2);
        assert!(
            (cache.hit_rate().unwrap() - 1.0 / 3.0).abs() < 1e-12
        );
    }

    #[test]
    fn bounded_cache_evicts_lru_and_rebuilds() {
        let mut rng = Pcg32::new(0x9A21);
        let mats: Vec<_> = (0..3)
            .map(|i| generators::random_uniform(128 + i, 4, &mut rng))
            .collect();
        let fps: Vec<u64> = mats.iter().map(fingerprint).collect();
        let cache = PlanCache::new(
            Planner::Heuristic,
            PlanConfig { cache_cap: 2, ..PlanConfig::default() },
        );
        assert_eq!(cache.capacity(), 2);
        cache.plan_for(fps[0], &mats[0]); // miss
        cache.plan_for(fps[1], &mats[1]); // miss
        cache.plan_for(fps[0], &mats[0]); // hit: 0 is now most recent
        cache.plan_for(fps[2], &mats[2]); // miss, evicts LRU entry 1
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.evictions(), 1);
        assert!(cache.version(fps[1]).is_none(), "1 was least recent");
        assert!(cache.version(fps[0]).is_some());
        // The evicted fingerprint rebuilds as a fresh miss.
        let (_, hit) = cache.plan_for(fps[1], &mats[1]);
        assert!(!hit);
        assert_eq!(cache.stats(), (1, 4));
        assert_eq!(cache.evictions(), 2, "rebuild evicted the next LRU");
    }

    #[test]
    fn replace_bumps_version_in_place() {
        let mut rng = Pcg32::new(0x9A22);
        let csr = generators::random_uniform(200, 5, &mut rng);
        let fp = fingerprint(&csr);
        let cache =
            PlanCache::new(Planner::Heuristic, PlanConfig::default());
        let (original, _) = cache.plan_for(fp, &csr);
        assert_eq!(cache.version(fp), Some(1));
        let variant = Arc::new(build_plan_with(
            &PlanConfig::default(),
            &csr,
            Schedule::CsrRowBalanced,
            2,
            original.features.clone(),
        ));
        assert_eq!(cache.replace(fp, variant.clone()), 2);
        assert_eq!(cache.version(fp), Some(2));
        assert_eq!(cache.replacements(), 1);
        let (served, hit) = cache.plan_for(fp, &csr);
        assert!(hit, "replace must not disturb hit accounting");
        assert!(Arc::ptr_eq(&served, &variant));
        assert_eq!(served.n_threads, 2);
        // Replacing an absent fingerprint installs at version 1.
        assert_eq!(cache.replace(0xDEAD, variant), 1);
    }

    #[test]
    fn hit_or_install_serves_hits_and_installs_misses() {
        let mut rng = Pcg32::new(0x9A24);
        let csr = generators::random_uniform(150, 4, &mut rng);
        let fp = fingerprint(&csr);
        let cache =
            PlanCache::new(Planner::Heuristic, PlanConfig::default());
        let (cached, _) = cache.plan_for(fp, &csr);
        let variant = Arc::new(build_plan_with(
            &PlanConfig::default(),
            &csr,
            Schedule::CsrRowBalanced,
            2,
            Vec::new(),
        ));
        // Present entry: a normal hit serving the cached plan, not
        // the supplied fallback.
        let (p, hit) = cache.hit_or_install(fp, variant.clone());
        assert!(hit);
        assert!(Arc::ptr_eq(&p, &cached));
        // Absent entry (e.g. LRU-evicted): the fallback is installed
        // as a counted miss — no static rebuild happened.
        let (p2, hit2) = cache.hit_or_install(0xF00D, variant.clone());
        assert!(!hit2);
        assert!(Arc::ptr_eq(&p2, &variant));
        assert_eq!(cache.version(0xF00D), Some(1));
        assert_eq!(
            cache.stats(),
            (1, 2),
            "one hit, one build miss, one install miss"
        );
    }

    #[test]
    fn build_plan_with_matches_reference_across_variants() {
        let mut rng = Pcg32::new(0x9A23);
        let csr = generators::random_uniform(300, 6, &mut rng);
        let x: Vec<f64> =
            (0..csr.n_cols).map(|_| rng.gen_f64() - 0.5).collect();
        let mut want = vec![0.0; csr.n_rows];
        csr.spmv(&x, &mut want);
        let cfg = PlanConfig::default();
        for schedule in [
            Schedule::CsrRowStatic,
            Schedule::CsrRowBalanced,
            Schedule::Csr5Tiles { tile_nnz: 64 },
            Schedule::SellChunks { c: 8, sigma: 32 },
        ] {
            for nt in [1usize, 2, 6] {
                let plan =
                    build_plan_with(&cfg, &csr, schedule, nt, Vec::new());
                assert_eq!(plan.n_threads, nt);
                assert_eq!(plan.schedule, schedule);
                let got = plan.execute(&csr, &x);
                for (i, (a, b)) in want.iter().zip(&got.y).enumerate() {
                    assert!(
                        (a - b).abs() < 1e-9 * (1.0 + a.abs()),
                        "row {i}: {a} vs {b} under {schedule:?} nt={nt}"
                    );
                }
            }
        }
        // Zero matrices normalize to CSR static regardless of the ask.
        let zero = Csr::zero(16, 16);
        let plan = build_plan_with(
            &cfg,
            &zero,
            Schedule::Csr5Tiles { tile_nnz: 8 },
            4,
            Vec::new(),
        );
        assert_eq!(plan.schedule, Schedule::CsrRowStatic);
    }

    #[test]
    fn cached_plan_is_stable() {
        let csr = NamedMatrix::Exdata1.generate();
        let fp = fingerprint(&csr);
        let cache =
            PlanCache::new(Planner::Heuristic, PlanConfig::default());
        let (p1, _) = cache.plan_for(fp, &csr);
        let (p2, _) = cache.plan_for(fp, &csr);
        assert!(Arc::ptr_eq(&p1, &p2), "second request must reuse the plan");
        assert_eq!(p1.schedule, p2.schedule);
    }
}
